"""Schema of the BENCH_spmv.json perf artifact (``run.py --json``).

The artifact is a single JSON object (NOT jsonl):

    {"schema": "bench-spmv/v1", "generated_unix": ..., "benches": [...],
     "records": [...], "rows": [...]}

``records`` are the machine-readable per-cell perf records the tables
append to ``tables.RECORDS``; ``rows`` are the printed CSV rows tagged
with the bench that produced them (the merge-on-write key). Because the
artifact is *merged* on every write — records of benches not rerun are
kept — a malformed record would otherwise survive forever; ``run.py``
therefore validates the full artifact (old + new records) before
writing and refuses to write on any error.
"""
from __future__ import annotations

SCHEMA = "bench-spmv/v1"

#: benches that may own records/rows (run.py's bench registry)
TABLES = frozenset({
    "table1", "table2", "table3", "table4", "table5", "fig4", "fig5",
    "spmv_overlap", "spmv_comm", "spmv_schedule", "partition", "planner",
    "roofline", "kernels", "sstep", "planner-scale",
})

#: engine-axis enums as the tables print them
ENGINE_VALUES = frozenset({"a2a", "cmp", "cyc", "mat", "a2a+ov", "cmp+ov"})
SCHEDULE_VALUES = frozenset({"cyclic", "matching"})
BALANCE_VALUES = frozenset({"rows", "commvol"})
REORDER_VALUES = frozenset({"none", "rcm"})
#: the kernel axis as the kernels table records it: jnp scan reference,
#: Pallas kernels with the flat (all-rounds-then-contract) halo body,
#: Pallas kernels with the round-pipelined halo contraction (the
#: ``--spmv-kernel`` default)
KERNEL_VALUES = frozenset({"off", "on", "pipelined"})
#: the s-step axis as the sstep table records it: ghost-zone depth of
#: the communication-avoiding filter (1 = the classic per-SpMV halo)
SSTEP_VALUES = frozenset({1, 2, 3})
#: the pattern-pass axis as the planner-scale table records it: full
#: pattern scans vs the streaming estimator (core/sketch.py); 'auto'
#: resolves before a record is written, so it never appears here
PLAN_MODE_VALUES = frozenset({"exact", "sampled"})

_NUMERIC_NONNEG = ("pred_bytes_per_device", "meas_bytes_per_device",
                   "us_per_call", "rounds", "plan_us", "t_pass_s",
                   "plan_seconds")


def validate_record(rec, where: str = "record") -> list[str]:
    """Errors of one perf record (empty list = valid)."""
    errors: list[str] = []
    if not isinstance(rec, dict):
        return [f"{where}: not an object: {rec!r}"]
    table = rec.get("table")
    if table not in TABLES:
        errors.append(f"{where}: missing or unknown 'table': {table!r} "
                      f"(known: {sorted(TABLES)})")
    if "family" not in rec:
        errors.append(f"{where}: missing required key 'family'")
    if "engine" in rec and rec["engine"] is not None \
            and rec["engine"] not in ENGINE_VALUES:
        errors.append(f"{where}: engine {rec['engine']!r} not in "
                      f"{sorted(ENGINE_VALUES)}")
    if rec.get("schedule") is not None and "schedule" in rec \
            and rec["schedule"] not in SCHEDULE_VALUES:
        errors.append(f"{where}: schedule {rec['schedule']!r} not in "
                      f"{sorted(SCHEDULE_VALUES)}")
    if "balance" in rec and rec["balance"] not in BALANCE_VALUES:
        errors.append(f"{where}: balance {rec['balance']!r} not in "
                      f"{sorted(BALANCE_VALUES)}")
    if "reorder" in rec and rec["reorder"] not in REORDER_VALUES:
        errors.append(f"{where}: reorder {rec['reorder']!r} not in "
                      f"{sorted(REORDER_VALUES)}")
    if "kernel" in rec and rec["kernel"] not in KERNEL_VALUES:
        errors.append(f"{where}: kernel {rec['kernel']!r} not in "
                      f"{sorted(KERNEL_VALUES)}")
    if "plan_mode" in rec and rec["plan_mode"] not in PLAN_MODE_VALUES:
        errors.append(f"{where}: plan_mode {rec['plan_mode']!r} not in "
                      f"{sorted(PLAN_MODE_VALUES)}")
    if "s" in rec:
        s = rec["s"]
        if not isinstance(s, int) or isinstance(s, bool) or s < 0:
            errors.append(f"{where}: s must be a nonnegative integer, "
                          f"got {s!r}")
        elif s not in SSTEP_VALUES:
            errors.append(f"{where}: s = {s} not in "
                          f"{sorted(SSTEP_VALUES)}")
    for key in _NUMERIC_NONNEG:
        if key in rec:
            v = rec[key]
            if not isinstance(v, (int, float)) or isinstance(v, bool) \
                    or v < 0:
                errors.append(f"{where}: {key} must be a nonnegative "
                              f"number, got {v!r}")
    # a measured-bytes record without its prediction (or vice versa)
    # cannot be regression-tracked — the pred/meas pair is the point
    if "meas_bytes_per_device" in rec \
            and "pred_bytes_per_device" not in rec:
        errors.append(f"{where}: meas_bytes_per_device without "
                      f"pred_bytes_per_device")
    return errors


def validate_rows(rows, where: str = "rows") -> list[str]:
    errors: list[str] = []
    if not isinstance(rows, list):
        return [f"{where}: not a list"]
    for i, r in enumerate(rows):
        if not isinstance(r, dict):
            errors.append(f"{where}[{i}]: not an object")
            continue
        for key in ("bench", "name", "us_per_call", "derived"):
            if key not in r:
                errors.append(f"{where}[{i}] ({r.get('name', '?')}): "
                              f"missing key {key!r}")
        if r.get("bench") is not None and r.get("bench") not in TABLES:
            errors.append(f"{where}[{i}]: unknown bench {r.get('bench')!r}")
    return errors


def validate_artifact(artifact) -> list[str]:
    """All schema errors of a full BENCH_spmv.json object."""
    if not isinstance(artifact, dict):
        return ["artifact is not a JSON object"]
    errors: list[str] = []
    if artifact.get("schema") != SCHEMA:
        errors.append(f"schema is {artifact.get('schema')!r}, "
                      f"expected {SCHEMA!r}")
    records = artifact.get("records")
    if not isinstance(records, list):
        errors.append("'records' missing or not a list")
    else:
        for i, rec in enumerate(records):
            errors += validate_record(
                rec, where=f"records[{i}] "
                           f"(table={rec.get('table') if isinstance(rec, dict) else '?'}, "
                           f"family={rec.get('family') if isinstance(rec, dict) else '?'})")
    errors += validate_rows(artifact.get("rows", []))
    benches = artifact.get("benches")
    if not isinstance(benches, list) or not set(benches) <= TABLES:
        errors.append(f"'benches' missing or contains unknown entries: "
                      f"{benches!r}")
    return errors


def check_artifact(path: str) -> list[str]:
    """Load + validate an artifact file; unreadable/unparsable files are
    themselves schema errors."""
    import json

    try:
        with open(path) as f:
            artifact = json.load(f)
    except OSError as e:
        return [f"{path}: cannot read: {e}"]
    except ValueError as e:
        return [f"{path}: not valid JSON: {e}"]
    return [f"{path}: {e}" for e in validate_artifact(artifact)]

"""Assemble EXPERIMENTS.md sections from the dry-run caches."""
import json
import os
import sys

CACHE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "_cache")


def load(path):
    out = {}
    p = os.path.join(CACHE, path)
    if not os.path.exists(p):
        return out
    for line in open(p):
        try:
            r = json.loads(line)
        except Exception:
            continue
        out[(r["arch"], r["shape"], r.get("mesh", ""))] = r
    return out


def fmt_bytes(b):
    if b > 1e12:
        return f"{b/1e12:.2f} TB"
    if b > 1e9:
        return f"{b/1e9:.2f} GB"
    return f"{b/1e6:.1f} MB"


def dryrun_section(recs):
    lines = ["## §Dry-run", ""]
    ok = sum(1 for r in recs.values() if r.get("status") == "ok")
    skip = sum(1 for r in recs.values() if r.get("status") == "skip")
    lines.append(f"`lower().compile()` succeeded for **{ok}** cells "
                 f"({skip} skip records per DESIGN.md §Arch-applicability); "
                 "0 failures. Per-cell compile artifacts: per-chip "
                 "argument/output/temp bytes from `memory_analysis()`, "
                 "FLOPs/bytes from the loop-aware HLO analyzer "
                 "(`cost_analysis()` kept for reference), collective bytes "
                 "parsed per op kind from the optimized HLO.")
    lines.append("")
    lines.append("| arch | shape | mesh | compile s | args/chip | temp/chip | coll bytes/chip (ag/ar/rs/a2a/cp) |")
    lines.append("|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if r.get("status") != "ok":
            continue
        m = r.get("memory", {})
        cb = r.get("coll_breakdown", {})
        coll = "/".join(fmt_bytes(cb.get(k, 0)) for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        lines.append(
            f"| {arch} | {shape} | {mesh} | {r.get('t_compile_s', 0):.0f} "
            f"| {fmt_bytes(m.get('argument_size_in_bytes', 0))} "
            f"| {fmt_bytes(m.get('temp_size_in_bytes', 0))} | {coll} |")
    skips = [(a, s) for (a, s, m), r in sorted(recs.items())
             if r.get("status") == "skip" and m == "16x16"]
    if skips:
        lines.append("")
        lines.append("Skipped cells (inapplicable shapes, DESIGN.md): " +
                     ", ".join(f"{a}×{s}" for a, s in sorted(set(skips))))
    return "\n".join(lines)


def roofline_section(recs):
    lines = ["## §Roofline", "",
             "Terms in **seconds per step** on v5e (197 TF/s bf16, 819 GB/s "
             "HBM, 50 GB/s ICI), single-pod 16×16 mesh, per chip. "
             "`useful` = MODEL_FLOPS / (HLO_FLOPs×chips); `frac(add)` = "
             "useful-compute-time / (t_c+t_m+t_coll); `frac(max)` assumes "
             "perfect overlap. The memory term is at *CPU-HLO fusion "
             "granularity* (materializes buffers a TPU fusion/Pallas kernel "
             "keeps in VMEM) — it is an upper bound and is used as the "
             "consistent metric for the §Perf iteration.", ""]
    lines.append("| arch | shape | t_compute | t_memory | t_collective | dominant | useful | frac(add) | frac(max) |")
    lines.append("|---|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh), r in sorted(recs.items()):
        if r.get("status") != "ok" or mesh != "16x16":
            continue
        lines.append(
            f"| {arch} | {shape} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} | "
            f"{r.get('roofline_fraction_overlap', 0):.4f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    base = load("dryrun.jsonl")
    print(dryrun_section(base))
    print()
    print(roofline_section(base))

"""Dry-run sweep driver: every (arch x shape x mesh) cell in an isolated
subprocess (compile memory isolation), with resume from the JSONL cache."""
import json, os, subprocess, sys, time

CACHE = os.path.join(os.path.dirname(__file__), "_cache", "dryrun.jsonl")
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

def done_keys():
    keys = set()
    if os.path.exists(CACHE):
        for line in open(CACHE):
            try:
                r = json.loads(line)
            except Exception:
                continue
            if r.get("status") in ("ok", "skip"):
                keys.add((r["arch"], r["shape"], r.get("mesh", "")))
    return keys

def main():
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.configs import ARCHS, get_config
    from repro.models.config import applicable_shapes
    cells = []
    for multi in (False, True):
        mesh = "2x16x16" if multi else "16x16"
        for arch in ARCHS:
            for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
                cells.append((arch, shape, mesh, multi))
        for eig in ("exciton200", "hubbard16", "roadnet48k"):
            # "+ov" lowers the split-phase overlap SpMV engine; the cached
            # record carries overlap_model_speedup for the scalability story
            for layout in ("stack", "panel", "pillar", "panel+ov"):
                cells.append((eig, f"fd_iter[{layout}," , mesh, multi, layout))
            # "+cmp": the sparsity-compressed neighbor-permute engine
            # (dryrun --spmv-comm compressed; chi2-scaled wire bytes).
            # The record's shape suffix order is <layout>+cmp[+ov]
            for layout, shape in (("panel", "panel+cmp"),
                                  ("panel+ov", "panel+cmp+ov")):
                cells.append((eig, f"fd_iter[{shape},", mesh, multi,
                              layout, "compressed"))
    done = done_keys()
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    for cell in cells:
        if len(cell) == 4:
            arch, shape, mesh, multi = cell
            if any(k[0] == arch and k[1] == shape and k[2] == mesh for k in done):
                print(f"skip-cached {arch} {shape} {mesh}", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--out", CACHE]
        else:
            arch, shape_prefix, mesh, multi, layout = cell[:5]
            comm = cell[5] if len(cell) > 5 else "a2a"
            if any(k[0] == arch and k[1].startswith(shape_prefix) and k[2] == mesh for k in done):
                print(f"skip-cached {arch} {layout} {comm} {mesh}", flush=True)
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--eigen", arch,
                   "--layout", layout, "--spmv-comm", comm, "--out", CACHE]
        if multi:
            cmd.append("--multi-pod")
        t0 = time.time()
        print(f"RUN {' '.join(cmd[3:])}", flush=True)
        r = subprocess.run(cmd, cwd=ROOT, env=env, capture_output=True, text=True,
                           timeout=3000)
        if r.returncode != 0:
            print(f"FAIL ({time.time()-t0:.0f}s): {r.stdout[-1500:]}\n{r.stderr[-3000:]}", flush=True)
            with open(CACHE, "a") as f:
                rec = {"arch": arch, "shape": cell[1] if len(cell)==4 else f"fd_iter[{layout}]",
                       "mesh": mesh, "status": "fail",
                       "error": (r.stderr or r.stdout)[-800:]}
                f.write(json.dumps(rec) + "\n")
        else:
            print(f"OK ({time.time()-t0:.0f}s)", flush=True)

if __name__ == "__main__":
    main()

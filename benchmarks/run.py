# One function per paper table. Print ``name,us_per_call,derived`` CSV;
# ``--json PATH`` additionally writes the machine-readable perf records
# (predicted vs measured bytes + wall times per family x engine) so the
# BENCH_*.json trajectory can track regressions across PRs.
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--large", action="store_true",
                    help="include the 1e8-dimension χ instances (minutes)")
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table5,fig4,fig5,table3,table4,"
                         "spmv_overlap,spmv_comm,spmv_schedule,partition,"
                         "kernels,sstep,planner,planner-scale,roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write a machine-readable perf artifact (e.g. "
                         "BENCH_spmv.json): per family x engine predicted "
                         "vs HLO-measured bytes and wall time, plus the "
                         "CSV rows. An existing artifact is merged, not "
                         "clobbered: records of tables NOT rerun are kept, "
                         "records of rerun tables are replaced")
    args = ap.parse_args()

    from benchmarks import tables

    benches = {
        "table1": lambda: tables.table1_chi(large=args.large),
        "table2": tables.table2_model_params,
        "table5": lambda: tables.table5_chi(large=args.large),
        "fig4": tables.fig4_scaling_model,
        "fig5": tables.fig5_panel_speedup,
        "table3": tables.table3_amortization,
        "table4": tables.table4_fd_end_to_end,
        "spmv_overlap": tables.spmv_overlap,
        "spmv_comm": tables.spmv_comm,
        "spmv_schedule": tables.spmv_schedule,
        "partition": tables.partition_table,
        "kernels": tables.kernels_table,
        "sstep": tables.sstep_table,
        "planner": tables.planner_table,
        "planner-scale": tables.planner_scale_table,
        "roofline": tables.roofline_table,
    }
    only = set(args.only.split(",")) if args.only else set(benches)
    rows = []
    row_bench = []  # which bench produced each row (for the --json merge)
    for name, fn in benches.items():
        if name in only:
            new = fn()
            rows.extend(new)
            row_bench.extend([name] * len(new))
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.json:
        ran = sorted(only & set(benches))
        records = list(tables.RECORDS)
        out_rows = [{"bench": b, "name": n, "us_per_call": u, "derived": d}
                    for b, (n, u, d) in zip(row_bench, rows)]
        benches_out = set(ran)
        if os.path.exists(args.json):
            # merge with the existing trajectory artifact: records AND
            # rows of benches that were not rerun are kept, those of
            # rerun benches are replaced (rows predating the per-row
            # `bench` tag cannot be attributed and are dropped)
            try:
                prev = json.load(open(args.json))
            except (OSError, ValueError):
                prev = None
            if prev and prev.get("schema") == "bench-spmv/v1":
                rerun = {r.get("table") for r in records} | set(ran)
                records = [r for r in prev.get("records", [])
                           if r.get("table") not in rerun] + records
                out_rows = [r for r in prev.get("rows", [])
                            if r.get("bench") not in rerun | {None}] + out_rows
                benches_out |= set(prev.get("benches", []))
        artifact = {
            "schema": "bench-spmv/v1",
            "generated_unix": int(time.time()),
            "benches": sorted(benches_out),
            "records": records,
            "rows": out_rows,
        }
        # validate the merged artifact (old + new records) before writing:
        # the merge keeps records across runs, so a malformed record would
        # otherwise survive forever (benchmarks/schema.py)
        from benchmarks.schema import validate_artifact

        schema_errors = validate_artifact(artifact)
        if schema_errors:
            for e in schema_errors:
                print(f"[bench] SCHEMA ERROR: {e}", file=sys.stderr)
            print(f"[bench] refusing to write {args.json}: "
                  f"{len(schema_errors)} malformed record(s)",
                  file=sys.stderr)
            sys.exit(2)
        with open(args.json, "w") as f:
            json.dump(artifact, f, indent=1)
        print(f"[bench] wrote {len(records)} records "
              f"({len(tables.RECORDS)} new) + {len(out_rows)} rows "
              f"-> {args.json}")


if __name__ == "__main__":
    main()

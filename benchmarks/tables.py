"""Benchmark bodies — one function per paper table/figure.

Each returns a list of CSV rows (name, us_per_call, derived) and prints a
human-readable table. χ sweeps are cached in benchmarks/_cache/chi.json
because the exact large-instance counts take minutes.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

CACHE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "_cache")
os.makedirs(CACHE_DIR, exist_ok=True)
_CHI_CACHE = os.path.join(CACHE_DIR, "chi.json")

#: Machine-readable perf records appended by benchmark bodies; drained by
#: ``run.py --json`` into the BENCH_*.json trajectory artifact so future
#: PRs can diff predicted-vs-measured bytes and wall times per engine.
RECORDS: list[dict] = []

PAPER_TABLE1 = {  # matrix -> {Np: (chi13, chi2)}
    "Exciton,L=75": {2: (0.01, 0.01), 4: (0.05, 0.04), 8: (0.11, 0.09),
                     16: (0.21, 0.20), 32: (0.42, 0.41), 64: (0.85, 0.83)},
    "Exciton,L=200": {2: (0.00, 0.00), 4: (0.02, 0.01), 8: (0.04, 0.03),
                      16: (0.08, 0.07), 32: (0.16, 0.15), 64: (0.32, 0.31)},
    "Hubbard,14,7": {2: (0.54, 0.54), 4: (1.51, 1.02), 8: (2.52, 1.53),
                     16: (3.37, 2.07), 32: (4.17, 2.65), 64: (5.58, 3.19)},
    "Hubbard,16,8": {2: (0.53, 0.53), 4: (1.50, 1.01), 8: (2.50, 1.51),
                     16: (3.37, 2.03), 32: (4.21, 2.61), 64: (5.67, 3.16)},
}
PAPER_TABLE5 = {
    "SpinChainXXZ,24,12": {2: (0.52, 0.52), 4: (1.50, 1.01), 8: (2.51, 1.52),
                           16: (3.40, 2.00), 32: (4.18, 2.49), 64: (5.15, 3.05)},
    "TopIns,100": {2: (0.02, 0.02), 4: (0.08, 0.06), 8: (0.16, 0.14),
                   16: (0.32, 0.30), 32: (0.64, 0.62), 64: (1.28, 1.26)},
}


def _family(label: str):
    from repro.matrices import Exciton, Hubbard, RoadNet, SpinChainXXZ, TopIns

    kind, *args = label.split(",")
    if kind == "Exciton":
        return Exciton(L=int(args[0].split("=")[-1]))
    if kind == "Hubbard":
        return Hubbard(int(args[0]), int(args[1]))
    if kind == "SpinChainXXZ":
        return SpinChainXXZ(int(args[0]), int(args[1]))
    if kind == "RoadNet":
        return RoadNet(n=int(args[0]))
    return TopIns(int(args[0]))


def _chi_cached(label: str, Nps=(2, 4, 8, 16, 32, 64)) -> dict:
    cache = {}
    if os.path.exists(_CHI_CACHE):
        cache = json.load(open(_CHI_CACHE))
    key = label
    if key in cache and all(str(n) in cache[key] for n in Nps):
        return {int(k): tuple(v) for k, v in cache[key].items()}
    from repro.core.metrics import chi_metrics

    fam = _family(label)
    out = {}
    for n in Nps:
        m = chi_metrics(fam, n)
        out[n] = (m.chi1, m.chi2, m.chi3)
    cache[key] = {str(k): list(v) for k, v in out.items()}
    json.dump(cache, open(_CHI_CACHE, "w"))
    return out


def _chi_table(paper: dict, labels: list[str], title: str):
    rows = []
    print(f"\n=== {title} (exact χ from sparsity patterns vs published) ===")
    print(f"{'matrix':24s} {'Np':>4s} {'chi13':>7s} {'paper':>7s} {'chi2':>7s} {'paper':>7s}")
    worst = 0.0
    t0 = time.perf_counter()
    for label in labels:
        chis = _chi_cached(label)
        for n, (c1, c2, c3) in sorted(chis.items()):
            p13, p2 = paper[label][n]
            dev = max(abs(round(c1, 2) - p13), abs(round(c2, 2) - p2))
            worst = max(worst, dev)
            print(f"{label:24s} {n:4d} {c1:7.2f} {p13:7.2f} {c2:7.2f} {p2:7.2f}")
    us = (time.perf_counter() - t0) * 1e6
    rows.append((title.replace(" ", "_"), us, f"max_dev={worst:.2f}"))
    return rows


def table1_chi(large: bool = False):
    labels = ["Exciton,L=75", "Hubbard,14,7", "Hubbard,16,8"]
    if large:
        labels.insert(1, "Exciton,L=200")
    return _chi_table(PAPER_TABLE1, labels, "Table 1 chi metrics")


def table5_chi(large: bool = False):
    labels = ["TopIns,100"]
    if large:
        labels.append("SpinChainXXZ,24,12")
    return _chi_table(PAPER_TABLE5, labels, "Table 5 chi metrics (appendix)")


def table2_model_params():
    """Table 2/6: machine-model constants — verify the fitted regime the
    paper reports (b_m/b_c ≈ 15–20, κ > 5 with irregular access higher)
    and that the v5e target sits in the same regime (DESIGN.md §3)."""
    from repro.core import perf_model as pm

    rows = []
    print("\n=== Table 2/6 machine models ===")
    print(f"{'model':14s} {'b_m GB/s':>9s} {'b_c GB/s':>9s} {'b_m/b_c':>8s} {'kappa':>6s}")
    fits = [("Exciton75", 53.3, 2.82, 7.30), ("Exciton200", 53.3, 3.10, 7.30),
            ("Hubbard14", 53.3, 2.82, 10.0), ("Hubbard16", 53.3, 2.54, 10.0),
            ("TopIns100", 53.3, 3.10, 8.28), ("SpinChain24", 53.3, 3.52, 12.2)]
    for name, bm, bc, kappa in fits:
        print(f"{name:14s} {bm:9.1f} {bc:9.2f} {bm/bc:8.1f} {kappa:6.1f}")
        assert 10 < bm / bc < 22 and kappa > 5
    v = pm.TPU_V5E
    print(f"{'tpu-v5e':14s} {v.b_m/1e9:9.1f} {v.b_c/1e9:9.2f} "
          f"{v.b_m/v.b_c:8.1f} {v.kappa:6.1f}  <- same trade-off regime")
    rows.append(("table2_regime", 0.0,
                 f"v5e_ratio={v.b_m/v.b_c:.1f} (paper cluster 15-20)"))
    return rows


def fig4_scaling_model():
    """Fig. 4: inverse Chebyshev-iteration time vs N_p from Eq. 12 with the
    paper's fitted machine constants (Table 2) and the exact χ values."""
    from repro.core import perf_model as pm

    setups = [
        ("Exciton,L=75", 16, 7.30, 2.82e9, 64),
        ("Hubbard,14,7", 8, 10.0, 2.82e9, 64),
    ]
    rows = []
    print("\n=== Fig. 4 scaling model (Eq. 12, Meggie constants) ===")
    print(f"{'matrix':16s} {'Np':>4s} {'T_model[s]':>11s} {'speedup':>8s} {'Pi':>6s} {'Pi_bound':>8s}")
    for label, S_d, kappa, b_c, n_b in setups:
        fam = _family(label)
        m = pm.MachineModel("meggie-fit", b_m=53.3e9, b_c=b_c, kappa=kappa)
        chis = _chi_cached(label)
        nnzr = fam.build_csr().n_nzr if fam.D < 2_000_000 else 2 * 9.0
        t1 = pm.cheb_iter_time(m, D=fam.D, N_p=1, n_b=n_b, chi=0.0,
                               n_nzr=nnzr, S_d=S_d)
        for n in (1, 2, 4, 8, 16, 32, 64):
            chi = chis[n][0] if n > 1 else 0.0
            t = pm.cheb_iter_time(m, D=fam.D, N_p=n, n_b=n_b, chi=chi,
                                  n_nzr=nnzr, S_d=S_d)
            eff = t1 / (n * t)
            bound = pm.parallel_efficiency_bound(m, chis[n][2] if n > 1 else 0.0)
            print(f"{label:16s} {n:4d} {t:11.4f} {t1/t:8.2f} {eff:6.2f} {bound:8.2f}")
            if n == 64:
                rows.append((f"fig4_{label}", t * 1e6, f"eff64={eff:.2f}"))
    return rows


def fig5_panel_speedup():
    """Fig. 5: panel-layout speedup s(N_col) from Eq. 15 with exact χ."""
    from repro.core import perf_model as pm

    rows = []
    print("\n=== Fig. 5 panel speedup (Eq. 15 asymptote + full Eq. 12) ===")
    print(f"{'matrix':16s} {'P':>4s} {'Ncol':>5s} {'s_eq15':>7s} "
          f"{'s_full':>7s} {'s_v5e':>7s} {'paper':>6s}")
    paper_fig5 = {("Exciton,L=75", 32): 2.69, ("Hubbard,14,7", 32): 4.98}
    for label, P in (("Exciton,L=75", 32), ("Hubbard,14,7", 32)):
        chis = _chi_cached(label)
        S_d = 16 if "Exciton" in label else 8
        n_nzr = 9.0 if "Exciton" in label else 14.0
        meg = pm.MachineModel("meggie-fit", b_m=53.3e9, b_c=2.82e9,
                              kappa=7.3 if "Exciton" in label else 10.0)
        for n_col in (1, 2, 4, 8, 16, 32):
            n_row = P // n_col
            chi_panel = chis[n_row][0] if n_row > 1 else 0.0
            s_m = pm.panel_speedup(meg, chis[P][0], chi_panel)
            s_f = pm.layout_speedup_full(meg, chi_P=chis[P][0],
                                         chi_panel=chi_panel, n_nzr=n_nzr,
                                         S_d=S_d, n_b_stack=64, n_col=n_col)
            s_t = pm.layout_speedup_full(pm.TPU_V5E, chi_P=chis[P][0],
                                         chi_panel=chi_panel, n_nzr=n_nzr,
                                         S_d=S_d, n_b_stack=64, n_col=n_col)
            pap = paper_fig5.get((label, P)) if n_col == P else None
            print(f"{label:16s} {P:4d} {n_col:5d} {s_m:7.2f} {s_f:7.2f} "
                  f"{s_t:7.2f} {pap if pap else '':>6}")
            if n_col == P:
                rows.append((f"fig5_{label}_pillar", 0.0,
                             f"s_full={s_f:.2f} paper={pap}"))
    return rows


def table3_amortization():
    """Table 3: speedup S(n) including redistribution cost (Eqs. 19-21)."""
    from repro.core import perf_model as pm

    rows = []
    print("\n=== Table 3 amortization (model, exact χ) ===")
    hdr = f"{'matrix':16s} {'Ncol':>5s} {'s':>6s} {'r':>6s} {'n*':>6s}" + \
        "".join(f" S(n={n:d})" for n in (10, 20, 30, 50, 100))
    print(hdr)
    paper_vals = {  # (matrix, Ncol) -> paper (s, n*)
        ("Hubbard,14,7", 32): (4.98, 2),
        ("Exciton,L=75", 32): (2.69, 11),
    }
    for label, P, kappa in (("Exciton,L=75", 32, 7.3), ("Hubbard,14,7", 32, 10.0)):
        chis = _chi_cached(label)
        m = pm.MachineModel("meggie-fit", b_m=53.3e9, b_c=2.82e9, kappa=kappa)
        for n_col in (2, 8, 32):
            n_row = P // n_col
            chi_panel = chis[n_row][0] if n_row > 1 else 0.0
            s = pm.panel_speedup(m, chis[P][0], chi_panel)
            r = pm.redistribution_factor(m, n_col, chi_panel)
            n_star = pm.break_even_degree(s, r)
            Ss = [pm.amortized_speedup(s, r, n) for n in (10, 20, 30, 50, 100)]
            line = f"{label:16s} {n_col:5d} {s:6.2f} {r:6.1f} {n_star:6.1f}" + \
                "".join(f" {x:7.2f}" for x in Ss)
            print(line)
            if (label, n_col) in paper_vals:
                ps, pn = paper_vals[(label, n_col)]
                rows.append((f"table3_{label}_pillar", 0.0,
                             f"s={s:.2f}(paper {ps}) nstar={n_star:.0f}(paper {pn})"))
    return rows


def table4_fd_end_to_end():
    """Table 4 (reduced scale): full FD solves with layout bookkeeping,
    validated against dense eigh."""
    import jax

    jax.config.update("jax_enable_x64", True)
    from repro.core import FDConfig, FilterDiag, make_solver_mesh
    from repro.matrices import Hubbard, SpinChainXXZ

    rows = []
    print("\n=== Table 4 FD end-to-end (reduced scale, CPU) ===")
    print(f"{'matrix':22s} {'target':>8s} {'spmvs':>8s} {'conv':>5s} "
          f"{'iters':>6s} {'redists':>8s} {'redist%':>8s} {'us/spmv':>9s}")
    cases = [
        (SpinChainXXZ(12, 6), "interior"),
        (Hubbard(8, 4, U=4.0, ranpot=1.0), "interior"),
    ]
    for mat, kind in cases:
        csr = mat.build_csr()
        w = np.linalg.eigvalsh(csr.to_dense())
        tau = float(w[len(w) // 2])
        mesh = make_solver_mesh(1, 1)
        cfg = FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8,
                       max_iters=25)
        with mesh:
            res = FilterDiag(csr, mesh, cfg).solve()
        ok = all(np.abs(w - ev).min() < 1e-7 for ev in res.eigenvalues[: res.n_converged])
        assert ok, "FD eigenvalues deviate from dense eigh"
        us = res.wall_time / max(res.total_spmvs, 1) * 1e6
        pct = 100 * res.redist_time / max(res.wall_time, 1e-9)
        print(f"{mat.describe()[:22]:22s} {tau:8.3f} {res.total_spmvs:8d} "
              f"{res.n_converged:5d} {res.iterations:6d} "
              f"{res.redistributions:8d} {pct:7.1f}% {us:9.1f}")
        rows.append((f"table4_{mat.name}", us,
                     f"conv={res.n_converged} iters={res.iterations} "
                     f"redists={res.redistributions}"))
    return rows


def spmv_overlap():
    """§Overlap engine: measured µs/call of the split-phase (overlap) SpMV
    vs the baseline engine on an 8-device panel mesh, next to the
    overlap-aware perf-model prediction T = max(T_comm, T_local) + T_halo
    (CPU host threads can't hide the exchange — the measured columns are a
    correctness+overhead check; the model columns are the hardware story)."""
    import subprocess
    import sys

    rows = []
    print("\n=== Overlap SpMV vs baseline (8 fake devices, panel 4x2) ===")
    script = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import time
import numpy as np
import jax, jax.numpy as jnp
jax.config.update('jax_enable_x64', True)
from repro.matrices import SpinChainXXZ
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
mat = SpinChainXXZ(12, 6)
csr = mat.build_csr()
D = csr.shape[0]
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
D_pad = -(-D // 8) * 8
ell = build_dist_ell(csr, 4, d_pad=D_pad, split_halo=True)
rng = np.random.default_rng(0)
X = np.zeros((D_pad, 8)); X[:D] = rng.standard_normal((D, 8))
with mesh:
    Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
    ys = {}
    for name, ov in (("baseline", False), ("overlap", True)):
        f = jax.jit(make_spmv(mesh, lay, ell, overlap=ov))
        y = f(Xs); jax.block_until_ready(y)
        n = 30
        t0 = time.perf_counter()
        for _ in range(n):
            y = f(Xs)
        jax.block_until_ready(y)
        ys[name] = np.asarray(y)
        print(f"ROW {name} {(time.perf_counter() - t0) / n * 1e6:.1f}")
err = np.abs(ys["overlap"] - ys["baseline"]).max()
assert err < 1e-11, err
print(f"HALO_FRAC {ell.halo_nnz_fraction:.4f}")
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        print(f"overlap bench subprocess failed:\n{r.stderr[-1500:]}")
        rows.append(("spmv_overlap", 0.0, "status=fail"))
        return rows
    meas = {}
    halo_frac = 0.0
    for line in r.stdout.splitlines():
        if line.startswith("ROW "):
            _, name, us = line.split()
            meas[name] = float(us)
        elif line.startswith("HALO_FRAC"):
            halo_frac = float(line.split()[1])

    # overlap-aware model prediction at the same instance (exact chi)
    from repro.core import perf_model as pm
    from repro.core.metrics import chi_metrics
    from repro.matrices import SpinChainXXZ

    fam = SpinChainXXZ(12, 6)
    chim = chi_metrics(fam, 4)
    nnzr = fam.build_csr().n_nzr
    # per-process quantities for the measured cell: panel 4x2, Ns=8 ->
    # each process holds n_b = 8/2 = 4 bundle columns
    kw = dict(D=fam.D, N_p=4, n_b=8 // 2, chi=chim.chi1, n_nzr=nnzr, S_d=8)
    print(f"{'engine':10s} {'us/call':>9s} {'model v5e':>10s} {'model meggie':>13s}")
    for name, t_v5e, t_meg in (
        ("baseline", pm.cheb_iter_time(pm.TPU_V5E, **kw),
         pm.cheb_iter_time(pm.MEGGIE, **kw)),
        ("overlap", pm.cheb_iter_time_overlap(pm.TPU_V5E, halo_frac=halo_frac, **kw),
         pm.cheb_iter_time_overlap(pm.MEGGIE, halo_frac=halo_frac, **kw)),
    ):
        print(f"{name:10s} {meas.get(name, 0.0):9.1f} {t_v5e*1e6:9.2f}us "
              f"{t_meg*1e6:12.2f}us")
        rows.append((f"spmv_{name}", meas.get(name, 0.0),
                     f"model_v5e_us={t_v5e*1e6:.2f}"))
    s_v5e = pm.overlap_speedup(pm.TPU_V5E, halo_frac=halo_frac, **kw)
    s_meg = pm.overlap_speedup(pm.MEGGIE, halo_frac=halo_frac, **kw)
    print(f"model overlap speedup: v5e {s_v5e:.2f}x  meggie {s_meg:.2f}x "
          f"(halo_frac={halo_frac:.3f}, chi1={chim.chi1:.2f})")
    rows.append(("spmv_overlap_model", 0.0,
                 f"speedup_v5e={s_v5e:.2f} speedup_meggie={s_meg:.2f} "
                 f"halo_frac={halo_frac:.3f}"))
    return rows


#: Shared harness of the spmv_comm / spmv_schedule tables: compile every
#: requested make_spmv engine on 8 fake CPU devices (panel 4x2), HLO-parse
#: the collective bytes, time the call, and assert all engines agree with
#: the first one. ``engines`` rows are (name, comm, schedule, overlap).
_ENGINE_BENCH_SCRIPT = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import time
import numpy as np
import jax, jax.numpy as jnp
jax.config.update('jax_enable_x64', True)
from repro.matrices import HubNet, RoadNet, SpinChainXXZ
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.launch.hlo_analysis import analyze_hlo
mat = {family}
engines = {engines}
csr = mat.build_csr()
D = csr.shape[0]
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
D_pad = -(-D // 8) * 8
ell = build_dist_ell(csr, 4, d_pad=D_pad, split_halo=True)
rng = np.random.default_rng(0)
X = np.zeros((D_pad, 8)); X[:D] = rng.standard_normal((D, 8))
ys = {{}}
with mesh:
    Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
    for name, comm, sched, ov in engines:
        f = jax.jit(make_spmv(mesh, lay, ell, comm=comm, schedule=sched,
                              overlap=ov))
        c = f.lower(Xs).compile()
        h = analyze_hlo(c.as_text())
        meas = int(h.coll_breakdown["all-to-all"]
                   + h.coll_breakdown["collective-permute"])
        y = f(Xs); jax.block_until_ready(y)
        n = 30
        t0 = time.perf_counter()
        for _ in range(n):
            y = f(Xs)
        jax.block_until_ready(y)
        ys[name] = np.asarray(y)
        print(f"ROW {{name}} {{(time.perf_counter() - t0) / n * 1e6:.1f}} {{meas}}")
ref = engines[0][0]
for name, *_ in engines[1:]:
    assert np.abs(ys[name] - ys[ref]).max() < 1e-11, name
print("AGREE OK")
"""


def _measure_spmv_engines(ctor: str, engines, table: str, label: str):
    """Run :data:`_ENGINE_BENCH_SCRIPT` for one matrix-ctor string and
    return ``{engine_name: (us_per_call, measured_bytes)}``, or ``None``
    on subprocess failure (already printed). The ctor string is the
    single source of truth for the instance: it is pasted into the
    measuring subprocess AND evaluated by the caller for the host-side
    prediction, so the two sides can never diverge."""
    import subprocess
    import sys

    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    env.pop("XLA_FLAGS", None)
    script = _ENGINE_BENCH_SCRIPT.format(family=ctor,
                                         engines=repr(list(engines)))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    if r.returncode != 0:
        print(f"{table} subprocess failed for {label}:\n{r.stderr[-1500:]}")
        return None
    assert "AGREE OK" in r.stdout
    out = {}
    for line in r.stdout.splitlines():
        if line.startswith("ROW "):
            _, name, us, meas = line.split()
            out[name] = (float(us), int(meas))
    return out


def spmv_comm():
    """§Compressed engine: padded a2a vs sparsity-compressed neighbor
    ppermute across a structured and a comm-imbalanced family.

    For each family x engine the table shows the pattern-predicted
    per-device SpMV exchange bytes (``planner.comm_plan``), the
    HLO-measured bytes of the compiled engine (must match exactly), and
    the measured µs/call on 8 fake CPU devices (correctness+overhead
    check; the byte columns are the hardware story — χ₂- vs χ₃-scaled
    wire volume). Every row also lands in :data:`RECORDS` for the
    ``run.py --json`` trajectory artifact."""
    rows = []
    fams = [("spinchain", "SpinChainXXZ(12, 6)"),
            ("roadnet", "RoadNet(n=4000, w=2, m=256, k=4)")]
    engines = [("a2a", "a2a", "cyclic", False),
               ("a2a+ov", "a2a", "cyclic", True),
               ("cmp", "compressed", "cyclic", False),
               ("cmp+ov", "compressed", "cyclic", True)]
    print("\n=== SpMV comm engines (8 fake devices, panel 4x2) ===")
    print(f"{'family':10s} {'engine':8s} {'pred B/dev':>11s} {'meas B/dev':>11s} "
          f"{'us/call':>9s} {'imb':>5s}")
    from repro.core.metrics import chi_metrics
    from repro.core.planner import comm_plan
    from repro.matrices import RoadNet, SpinChainXXZ

    ctors = {"RoadNet": RoadNet, "SpinChainXXZ": SpinChainXXZ}
    for label, ctor in fams:
        mat = eval(ctor, {"__builtins__": {}}, ctors)
        D_pad = -(-mat.D // 8) * 8
        cp = comm_plan(mat, 4, d_pad=D_pad)
        chim = chi_metrics(mat, 4)
        pred = {"a2a": cp.a2a_bytes_per_device(4, 8),
                "compressed": cp.permute_bytes_per_device(4, 8)}
        meas_by_eng = _measure_spmv_engines(ctor, engines, "spmv_comm", label)
        if meas_by_eng is None:
            rows.append((f"spmv_comm_{label}", 0.0, "status=fail"))
            continue
        for name, (us, meas) in meas_by_eng.items():
            p = pred["compressed" if name.startswith("cmp") else "a2a"]
            assert meas == p, (label, name, meas, p)
            print(f"{label:10s} {name:8s} {p:11d} {meas:11d} "
                  f"{us:9.1f} {chim.imbalance:5.2f}")
            rows.append((f"spmv_comm_{label}_{name}", us,
                         f"pred={p} meas={meas}"))
            RECORDS.append(dict(
                table="spmv_comm", family=label, engine=name,
                pred_bytes_per_device=int(p), meas_bytes_per_device=meas,
                us_per_call=us, chi2=chim.chi2, chi3=chim.chi3,
                imbalance=chim.imbalance))
        ratio = pred["a2a"] / max(pred["compressed"], 1)
        print(f"{label:10s} compressed moves {ratio:.2f}x fewer bytes "
              f"(chi3/chi2 = {chim.imbalance:.2f})")
        rows.append((f"spmv_comm_{label}_ratio", 0.0,
                     f"bytes_ratio={ratio:.2f} imbalance={chim.imbalance:.2f}"))
    # Table-1-style chi sweep of the imbalanced family: chi3/chi2 grows
    # with N_p — the padded engine's wire overhead grows with it, the
    # compressed engine's stays chi2-proportional
    from repro.core.metrics import chi_sweep

    rn = RoadNet(n=4000, w=2, m=256, k=4)
    print(f"\n{'RoadNet chi sweep':18s} " + "".join(
        f"{'Np=' + str(n):>9s}" for n in (2, 4, 8, 16)))
    sweep = chi_sweep(rn, Nps=(2, 4, 8, 16))
    for fieldname in ("chi2", "chi3", "imbalance"):
        vals = [getattr(sweep[n], fieldname) for n in (2, 4, 8, 16)]
        print(f"{fieldname:18s} " + "".join(f"{v:9.3f}" for v in vals))
    rows.append(("spmv_comm_roadnet_chi_sweep", 0.0,
                 "imb@P=" + "/".join(f"{sweep[n].imbalance:.1f}"
                                     for n in (2, 4, 8, 16))))
    RECORDS.append(dict(table="spmv_comm", family="roadnet",
                        chi_sweep={str(n): dict(chi2=sweep[n].chi2,
                                                chi3=sweep[n].chi3,
                                                imbalance=sweep[n].imbalance)
                                   for n in (2, 4, 8, 16)}))
    return rows


def spmv_schedule():
    """§Schedule axis: cyclic vs matching rounds of the compressed halo
    exchange, per family, next to the padded a2a reference.

    For each family x schedule the table shows the pattern-predicted
    per-device SpMV exchange bytes (``planner.comm_plan`` with the
    engine's own ``neighbor_schedule`` rounds), the HLO-measured bytes
    of the compiled engine (must match exactly), the round count, and
    the measured µs/call on 8 fake CPU devices (correctness+overhead
    check; the byte columns are the hardware story — on the
    hub-and-spoke HubNet family the cyclic rounds saturate toward the
    a2a volume while a matching packs all corridors into O(1) rounds).
    Every row also lands in :data:`RECORDS` for the ``run.py --json``
    trajectory artifact."""
    rows = []
    fams = [("spinchain", "SpinChainXXZ(12, 6)"),
            ("roadnet", "RoadNet(n=4000, w=2, m=256, k=4)"),
            ("hubnet", "HubNet(n=4000, w=2, h=4, m=192, k=4)")]
    engines = [("a2a", "a2a", "cyclic", False),
               ("cyc", "compressed", "cyclic", False),
               ("mat", "compressed", "matching", False)]
    print("\n=== SpMV neighbor schedules (8 fake devices, panel 4x2) ===")
    print(f"{'family':10s} {'engine':8s} {'rounds':>6s} {'pred B/dev':>11s} "
          f"{'meas B/dev':>11s} {'us/call':>9s}")
    from repro.core.metrics import chi_metrics
    from repro.core.planner import comm_plan
    from repro.matrices import HubNet, RoadNet, SpinChainXXZ

    ctors = {"HubNet": HubNet, "RoadNet": RoadNet,
             "SpinChainXXZ": SpinChainXXZ}
    for label, ctor in fams:
        mat = eval(ctor, {"__builtins__": {}}, ctors)
        D_pad = -(-mat.D // 8) * 8
        cp = comm_plan(mat, 4, d_pad=D_pad)
        chim = chi_metrics(mat, 4)
        pred = {"a2a": cp.a2a_bytes_per_device(4, 8)}
        n_rounds = {"a2a": 1}
        for name, sched in (("cyc", "cyclic"), ("mat", "matching")):
            pred[name] = cp.permute_bytes_per_device(4, 8, sched)
            n_rounds[name] = len(cp.permute_schedule(sched)[0])
        meas_by_eng = _measure_spmv_engines(ctor, engines, "spmv_schedule",
                                            label)
        if meas_by_eng is None:
            rows.append((f"spmv_schedule_{label}", 0.0, "status=fail"))
            continue
        for name, (us, meas) in meas_by_eng.items():
            p = pred[name]
            assert meas == p, (label, name, meas, p)
            print(f"{label:10s} {name:8s} {n_rounds[name]:6d} {p:11d} "
                  f"{meas:11d} {us:9.1f}")
            rows.append((f"spmv_schedule_{label}_{name}", us,
                         f"pred={p} meas={meas} rounds={n_rounds[name]}"))
            RECORDS.append(dict(
                table="spmv_schedule", family=label, engine=name,
                schedule={"a2a": None, "cyc": "cyclic",
                          "mat": "matching"}[name],
                rounds=n_rounds[name], pred_bytes_per_device=int(p),
                meas_bytes_per_device=meas, us_per_call=us,
                chi2=chim.chi2, chi3=chim.chi3,
                imbalance=chim.imbalance))
        win = pred["cyc"] / max(pred["mat"], 1)
        print(f"{label:10s} matching moves {win:.2f}x fewer bytes than "
              f"cyclic ({n_rounds['mat']} vs {n_rounds['cyc']} rounds)")
        rows.append((f"spmv_schedule_{label}_win", 0.0,
                     f"cyc_over_mat={win:.2f} "
                     f"rounds={n_rounds['cyc']}->{n_rounds['mat']}"))
    return rows


#: Kernel-axis bench script: the compressed+overlap engine with the jnp
#: scan body, the Pallas kernels with the flat halo body, and the Pallas
#: kernels with the round-pipelined halo contraction. The three cells
#: must be bit-identical AND emit the identical collectives — the kernel
#: axis never touches the wire.
_KERNEL_BENCH_SCRIPT = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import time
import numpy as np
import jax, jax.numpy as jnp
jax.config.update('jax_enable_x64', True)
from repro.matrices import HubNet, RoadNet, SpinChainXXZ
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.launch.hlo_analysis import analyze_hlo
mat = {family}
cells = {cells}
csr = mat.build_csr()
D = csr.shape[0]
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
D_pad = -(-D // 8) * 8
ell = build_dist_ell(csr, 4, d_pad=D_pad, split_halo=True)
rng = np.random.default_rng(0)
X = np.zeros((D_pad, 8)); X[:D] = rng.standard_normal((D, 8))
ys = {{}}
with mesh:
    Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
    for name, use_kernel, pipeline in cells:
        f = jax.jit(make_spmv(mesh, lay, ell, comm='compressed',
                              schedule='matching', overlap=True,
                              use_kernel=use_kernel, pipeline=pipeline))
        c = f.lower(Xs).compile()
        h = analyze_hlo(c.as_text())
        meas = int(h.coll_breakdown["all-to-all"]
                   + h.coll_breakdown["collective-permute"])
        y = f(Xs); jax.block_until_ready(y)
        n = 30
        t0 = time.perf_counter()
        for _ in range(n):
            y = f(Xs)
        jax.block_until_ready(y)
        ys[name] = np.asarray(y)
        print(f"ROW {{name}} {{(time.perf_counter() - t0) / n * 1e6:.1f}} {{meas}}")
ref = cells[0][0]
for name, *_ in cells[1:]:
    assert np.array_equal(ys[name], ys[ref]), name
print("AGREE OK")
"""


def kernels_table():
    """§Kernel axis: jnp scan body vs Pallas kernels (flat halo body) vs
    Pallas kernels with the round-pipelined halo contraction, on the
    compressed-matching overlap engine.

    For each family x kernel cell the table shows the pattern-predicted
    per-device exchange bytes, the HLO-measured bytes of the compiled
    cell (must match exactly — the kernel axis never touches the wire),
    and the measured µs/call on 8 fake CPU devices. On CPU the kernels
    run in Pallas interpret mode, so the µs column is a correctness+
    overhead check, not the TPU speedup story; the subprocess asserts
    all three cells bit-identical (``np.array_equal``, not a tolerance).
    Every row also lands in :data:`RECORDS` with the ``kernel`` field of
    ``schema.KERNEL_VALUES`` for the ``run.py --json`` artifact."""
    rows = []
    fams = [("spinchain", "SpinChainXXZ(12, 6)"),
            ("roadnet", "RoadNet(n=4000, w=2, m=256, k=4)")]
    # (record tag, use_kernel, pipeline) — "off" keeps the flat body so
    # the "pipelined" row isolates the round-pipelined split
    cells = [("off", False, False),
             ("on", True, False),
             ("pipelined", True, True)]
    print("\n=== SpMV kernel axis (8 fake devices, panel 4x2, cmp+ov+mat) ===")
    print(f"{'family':10s} {'kernel':10s} {'pred B/dev':>11s} "
          f"{'meas B/dev':>11s} {'us/call':>9s}")
    import subprocess
    import sys

    from repro.core.metrics import chi_metrics
    from repro.core.planner import comm_plan
    from repro.matrices import RoadNet, SpinChainXXZ

    ctors = {"RoadNet": RoadNet, "SpinChainXXZ": SpinChainXXZ}
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    env.pop("XLA_FLAGS", None)
    for label, ctor in fams:
        mat = eval(ctor, {"__builtins__": {}}, ctors)
        D_pad = -(-mat.D // 8) * 8
        cp = comm_plan(mat, 4, d_pad=D_pad)
        chim = chi_metrics(mat, 4)
        pred = cp.permute_bytes_per_device(4, 8, "matching")
        script = _KERNEL_BENCH_SCRIPT.format(family=ctor,
                                             cells=repr(cells))
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=900)
        if r.returncode != 0:
            print(f"kernels subprocess failed for {label}:\n"
                  f"{r.stderr[-1500:]}")
            rows.append((f"kernels_{label}", 0.0, "status=fail"))
            continue
        assert "AGREE OK" in r.stdout
        for line in r.stdout.splitlines():
            if not line.startswith("ROW "):
                continue
            _, name, us, meas = line.split()
            us, meas = float(us), int(meas)
            assert meas == pred, (label, name, meas, pred)
            print(f"{label:10s} {name:10s} {pred:11d} {meas:11d} {us:9.1f}")
            rows.append((f"kernels_{label}_{name}", us,
                         f"pred={pred} meas={meas} kernel={name}"))
            RECORDS.append(dict(
                table="kernels", family=label, engine="cmp+ov",
                schedule="matching", kernel=name,
                pred_bytes_per_device=int(pred),
                meas_bytes_per_device=meas, us_per_call=us,
                chi2=chim.chi2, chi3=chim.chi3,
                imbalance=chim.imbalance))
        print(f"{label:10s} three kernel cells bit-identical, "
              f"identical wire bytes")
    return rows


#: S-step-axis bench script: the full degree-8 Chebyshev filter at ghost
#: depths s = 1, 2, 3 on the plain panel engine. The s = 1 reference is
#: the classic per-SpMV halo path (``chebyshev_filter``); s > 1 runs the
#: communication-avoiding grouped applier (``make_sstep_cheb``). All
#: depths must be bit-identical; the collective census of each compiled
#: filter (exact while-loop multiplicities) is printed for the host-side
#: byte check.
_SSTEP_BENCH_SCRIPT = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import time
import numpy as np
import jax, jax.numpy as jnp
jax.config.update('jax_enable_x64', True)
from repro.matrices import HubNet, RoadNet, SpinChainXXZ
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.core.spmv import build_sstep_ell, make_sstep_cheb
from repro.core.chebyshev import chebyshev_filter
from repro.launch.hlo_analysis import collective_census
mat = {family}
comm, sched, degree = {comm!r}, {sched!r}, {degree}
csr = mat.build_csr()
D = csr.shape[0]
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
D_pad = -(-D // 8) * 8
rng = np.random.default_rng(0)
X = np.zeros((D_pad, 8)); X[:D] = rng.standard_normal((D, 8))
mu = np.linspace(1.0, 0.5, degree + 1)
ys = {{}}
with mesh:
    Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
    for s in (1, 2, 3):
        if s == 1:
            ell = build_dist_ell(csr, 4, d_pad=D_pad)
            spmv = make_spmv(mesh, lay, ell, comm=comm, schedule=sched)
            f = jax.jit(lambda V: chebyshev_filter(spmv, mu, 0.5, 0.1, V))
        else:
            sell = build_sstep_ell(csr, 4, s, d_pad=D_pad)
            app = make_sstep_cheb(mesh, lay, sell, comm=comm,
                                  schedule=sched)
            f = jax.jit(lambda V: app(V, mu, 0.5, 0.1))
        c = f.lower(Xs).compile()
        meas = sum(int(op.bytes * op.mult) for op in
                   collective_census(c.as_text())
                   if op.kind in ("all-to-all", "collective-permute"))
        y = f(Xs); jax.block_until_ready(y)
        n = 10
        t0 = time.perf_counter()
        for _ in range(n):
            y = f(Xs)
        jax.block_until_ready(y)
        ys[s] = np.asarray(y)
        print(f"ROW {{s}} {{(time.perf_counter() - t0) / n * 1e6:.1f}} {{meas}}")
for s in (2, 3):
    assert np.array_equal(ys[s], ys[1]), s
print("SSTEP AGREE OK")
"""


def sstep_table():
    """§S-step axis: the communication-avoiding depth-s filter (s = 1, 2,
    3) on the plain panel engine, per family and comm engine.

    For each cell the table shows the pattern-predicted per-device filter
    exchange bytes (s = 1: ``degree`` per-SpMV halo exchanges; s > 1: the
    whole-filter ``SpmvCommPlan.sstep_collectives`` terms — one
    single-width seed exchange plus ``ceil(degree/s) - 1`` width-doubled
    group exchanges), the census-measured bytes of the compiled filter
    (must match exactly), the exchange count, the redundant-work factor,
    and the measured µs/call on 8 fake CPU devices (correctness+overhead
    check — on CPU the round-latency term the s-step engine buys back is
    negligible; the byte/round columns are the hardware story). The
    subprocess asserts all depths bit-identical (``np.array_equal``).
    Every row lands in :data:`RECORDS` with the ``s`` field of
    ``schema.SSTEP_VALUES`` for the ``run.py --json`` artifact."""
    import subprocess
    import sys

    rows = []
    degree = 8
    fams = [("spinchain", "SpinChainXXZ(12, 6)"),
            ("hubnet", "HubNet(n=4000, w=2, h=4, m=192, k=4)")]
    engines = [("a2a", "a2a", "cyclic"),
               ("cmp", "compressed", "matching")]
    print("\n=== S-step filter axis (8 fake devices, panel 4x2, "
          f"degree {degree}) ===")
    print(f"{'family':10s} {'engine':7s} {'s':>2s} {'exchanges':>9s} "
          f"{'pred B/dev':>11s} {'meas B/dev':>11s} {'work':>6s} "
          f"{'us/call':>9s}")
    from repro.core.planner import comm_plan
    from repro.matrices import HubNet, SpinChainXXZ

    ctors = {"HubNet": HubNet, "SpinChainXXZ": SpinChainXXZ}
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    env.pop("XLA_FLAGS", None)
    n_b, S_d = 8 // 2, 8
    for label, ctor in fams:
        mat = eval(ctor, {"__builtins__": {}}, ctors)
        D_pad = -(-mat.D // 8) * 8
        for eng, comm, sched in engines:
            pred, n_ex, wf = {}, {}, {}
            for s in (1, 2, 3):
                cp = comm_plan(mat, 4, d_pad=D_pad, sstep=s) if s > 1 \
                    else comm_plan(mat, 4, d_pad=D_pad, exact=True)
                if s == 1:
                    pred[s] = degree * cp.comm_bytes_per_device(
                        comm, n_b, S_d, sched)
                    n_ex[s] = degree
                else:
                    pred[s] = sum(b * c for _, b, c in cp.sstep_collectives(
                        comm, sched, n_b, S_d, degree))
                    n_ex[s] = cp.n_groups(degree)
                wf[s] = cp.sstep_work_factor()
            script = _SSTEP_BENCH_SCRIPT.format(family=ctor, comm=comm,
                                                sched=sched, degree=degree)
            r = subprocess.run([sys.executable, "-c", script], env=env,
                               capture_output=True, text=True, timeout=900)
            if r.returncode != 0:
                print(f"sstep subprocess failed for {label}/{eng}:\n"
                      f"{r.stderr[-1500:]}")
                rows.append((f"sstep_{label}_{eng}", 0.0, "status=fail"))
                continue
            assert "SSTEP AGREE OK" in r.stdout
            for line in r.stdout.splitlines():
                if not line.startswith("ROW "):
                    continue
                _, s, us, meas = line.split()
                s, us, meas = int(s), float(us), int(meas)
                assert meas == pred[s], (label, eng, s, meas, pred[s])
                print(f"{label:10s} {eng:7s} {s:2d} {n_ex[s]:9d} "
                      f"{pred[s]:11d} {meas:11d} {wf[s]:6.3f} {us:9.1f}")
                rows.append((f"sstep_{label}_{eng}_s{s}", us,
                             f"pred={pred[s]} meas={meas} "
                             f"exchanges={n_ex[s]} work={wf[s]:.3f}"))
                RECORDS.append(dict(
                    table="sstep", family=label, engine=eng,
                    schedule=sched, s=s, rounds=n_ex[s],
                    pred_bytes_per_device=int(pred[s]),
                    meas_bytes_per_device=meas, us_per_call=us,
                    work_factor=wf[s]))
            print(f"{label:10s} {eng:7s} depths bit-identical; s=3 runs "
                  f"{n_ex[1]}->{n_ex[3]} exchanges at "
                  f"{pred[3] / max(pred[1], 1):.2f}x the bytes")
    return rows


#: Partition-cell bench script: build each planned RowMap, lower the a2a
#: and compressed-matching engines on it, HLO-parse the collective bytes,
#: time the call, and check bit-identity + un-permuted correctness.
_PARTITION_BENCH_SCRIPT = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import time
import numpy as np
import jax, jax.numpy as jnp
jax.config.update('jax_enable_x64', True)
from repro.matrices import HubNet, RoadNet, SpinChainXXZ
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.core.partition import plan_rowmap
from repro.launch.hlo_analysis import analyze_hlo
mat = {family}
cells = {cells}
csr = mat.build_csr()
D = csr.shape[0]
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
rng = np.random.default_rng(0)
X0 = rng.standard_normal((D, 8))
ref = csr.matvec(X0)
for tag, bal, ro in cells:
    rm = plan_rowmap(mat, 4, balance=bal, reorder=ro)
    ell = build_dist_ell(csr, 4, rowmap=rm)
    Xp = rm.embed(X0)
    ys = {{}}
    with mesh:
        sh = lay.vec_sharding(mesh)
        Xs = jax.device_put(jnp.asarray(Xp), sh)
        for eng, comm, sched in (("a2a", "a2a", "cyclic"),
                                 ("mat", "compressed", "matching")):
            f = jax.jit(make_spmv(mesh, lay, ell, comm=comm, schedule=sched))
            c = f.lower(Xs).compile()
            h = analyze_hlo(c.as_text())
            meas = int(h.coll_breakdown["all-to-all"]
                       + h.coll_breakdown["collective-permute"])
            y = f(Xs); jax.block_until_ready(y)
            n = 30
            t0 = time.perf_counter()
            for _ in range(n):
                y = f(Xs)
            jax.block_until_ready(y)
            ys[eng] = np.asarray(y)
            print(f"ROW {{tag}} {{eng}} "
                  f"{{(time.perf_counter() - t0) / n * 1e6:.1f}} {{meas}}")
    # engines agree bit-for-bit on the planned partition, and the
    # un-permuted result matches the reference SpMV
    assert np.array_equal(ys["a2a"], ys["mat"]), tag
    assert np.abs(rm.extract(ys["a2a"]) - ref).max() < 1e-11, tag
print("PARTITION AGREE OK")
"""


def partition_table():
    """§Partition axis: χ-aware row re-balancing (``balance="commvol"``)
    and RCM reordering (``reorder="rcm"``) per family, next to the
    equal-rows baseline.

    For each family x (balance, reorder) cell the table shows the
    pattern-predicted per-device exchange bytes of the padded a2a and the
    compressed-matching engine on the *planned* partition
    (``planner.comm_plan(rowmap=...)``), the HLO-measured bytes of the
    compiled engines (must match exactly), χ₂/χ₃ on the planned block
    sizes, and the measured µs/call on 8 fake CPU devices. The measuring
    subprocess re-plans the same deterministic map, checks all engines
    stay bit-identical on it, and that the un-permuted result equals the
    reference SpMV. Every row lands in :data:`RECORDS` for the
    ``run.py --json`` trajectory artifact."""
    import subprocess
    import sys

    rows = []
    fams = [("spinchain", "SpinChainXXZ(12, 6)"),
            ("roadnet", "RoadNet(n=4000, w=2, m=256, k=4)"),
            ("hubnet", "HubNet(n=4000, w=2, h=4, m=192, k=4)")]
    cells = [("rows", "rows", "none"), ("cv", "commvol", "none"),
             ("rcm", "rows", "rcm"), ("cv+rcm", "commvol", "rcm")]
    print("\n=== Row-partition planner (8 fake devices, panel 4x2) ===")
    print(f"{'family':10s} {'cell':8s} {'engine':6s} {'pred B/dev':>11s} "
          f"{'meas B/dev':>11s} {'us/call':>9s} {'chi2':>6s} {'chi3':>6s} "
          f"{'rows/blk':>11s}")
    from repro.core.partition import plan_rowmap
    from repro.core.planner import comm_plan
    from repro.matrices import HubNet, RoadNet, SpinChainXXZ

    ctors = {"HubNet": HubNet, "RoadNet": RoadNet,
             "SpinChainXXZ": SpinChainXXZ}
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
    env.pop("XLA_FLAGS", None)
    for label, ctor in fams:
        mat = eval(ctor, {"__builtins__": {}}, ctors)
        pred, chis, blocks = {}, {}, {}
        for tag, bal, ro in cells:
            rm = plan_rowmap(mat, 4, balance=bal, reorder=ro)
            cp = comm_plan(mat, 4, rowmap=rm)
            pred[tag] = {"a2a": cp.a2a_bytes_per_device(4, 8),
                         "mat": cp.permute_bytes_per_device(4, 8, "matching")}
            chim = cp.chi
            chis[tag] = (chim.chi2, chim.chi3)
            sizes = rm.block_sizes(4)
            blocks[tag] = f"{int(sizes.min())}..{int(sizes.max())}"
        script = _PARTITION_BENCH_SCRIPT.format(family=ctor,
                                                cells=repr(cells))
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=900)
        if r.returncode != 0:
            print(f"partition subprocess failed for {label}:\n"
                  f"{r.stderr[-1500:]}")
            rows.append((f"partition_{label}", 0.0, "status=fail"))
            continue
        assert "PARTITION AGREE OK" in r.stdout
        meas = {}
        for line in r.stdout.splitlines():
            if line.startswith("ROW "):
                _, tag, eng, us, m = line.split()
                meas[(tag, eng)] = (float(us), int(m))
        for tag, bal, ro in cells:
            for eng in ("a2a", "mat"):
                us, m = meas[(tag, eng)]
                p = pred[tag][eng]
                assert m == p, (label, tag, eng, m, p)
                print(f"{label:10s} {tag:8s} {eng:6s} {p:11d} {m:11d} "
                      f"{us:9.1f} {chis[tag][0]:6.3f} {chis[tag][1]:6.3f} "
                      f"{blocks[tag]:>11s}")
                rows.append((f"partition_{label}_{tag}_{eng}", us,
                             f"pred={p} meas={m}"))
                RECORDS.append(dict(
                    table="partition", family=label, balance=bal,
                    reorder=ro, engine=eng, pred_bytes_per_device=int(p),
                    meas_bytes_per_device=m, us_per_call=us,
                    chi2=chis[tag][0], chi3=chis[tag][1],
                    block_rows=blocks[tag]))
        base = pred["rows"]["a2a"] + pred["rows"]["mat"]
        planned = min(pred[t]["a2a"] + pred[t]["mat"]
                      for t, _, _ in cells[1:])
        print(f"{label:10s} best planned cell moves "
              f"{base / max(planned, 1):.2f}x fewer a2a+matching bytes "
              f"than equal rows")
        rows.append((f"partition_{label}_win", 0.0,
                     f"rows_over_planned={base / max(planned, 1):.2f}"))
    return rows


def planner_table():
    """§Planner: χ-driven layout choice across the bundled matrix families.

    For each family the planner (core/planner.py) ranks every
    (mesh split x layout x overlap) configuration from the sparsity
    pattern alone — no jax, no device work; the winner is what
    ``--layout auto`` runs. The ``matfree`` row plans a pattern-only
    instance (``exact_comm=False``: χ via the family's streamed/structured
    n_vc, no per-pair scan) — the path used at paper scale (D ~ 1e8)."""
    from repro.core.planner import plan_layout
    from repro.matrices import (Exciton, Hubbard, HubNet, RoadNet,
                                SpinChainXXZ, TopIns)

    rows = []
    P, Ns = 32, 64
    cases = [
        ("exciton", Exciton(L=10), {}),
        ("hubbard", Hubbard(10, 5, U=4.0, ranpot=1.0), {}),
        ("spinchain", SpinChainXXZ(14, 7), {}),
        ("topins", TopIns(12), {}),
        ("roadnet", RoadNet(), {}),
        ("hubnet", HubNet(), {}),
        ("matfree", Exciton(L=24), dict(exact_comm=False)),
    ]
    print(f"\n=== Planner: chi-driven layout choice (P={P}, Ns={Ns}, v5e) ===")
    print(f"{'family':10s} {'D':>9s} {'best':16s} {'chi1':>6s} "
          f"{'t_pass[ms]':>11s} {'speedup':>8s}  runners-up")
    for label, fam, kw in cases:
        t0 = time.perf_counter()
        plan = plan_layout(fam, P, n_search=Ns, **kw)
        us = (time.perf_counter() - t0) * 1e6
        b = plan.best
        others = ", ".join(f"{c.describe()} x{plan.speedup(c):.2f}"
                           for c in plan.candidates[1:3])
        print(f"{label:10s} {plan.D:9d} {b.describe():16s} {b.chi1:6.2f} "
              f"{b.t_pass * 1e3:11.3f} {plan.speedup(b):8.2f}  {others}")
        rows.append((f"planner_{label}", us,
                     f"best={b.describe()} comm={b.comm} sched={b.schedule} "
                     f"ov={int(b.overlap)} "
                     f"chi1={b.chi1:.2f} s={plan.speedup(b):.2f}"))
        RECORDS.append(dict(
            table="planner", family=label, best=b.describe(), comm=b.comm,
            schedule=b.schedule, overlap=b.overlap, chi1=b.chi1,
            chi_eng=b.chi_eng,
            pred_bytes_per_device=b.comm_bytes_per_device,
            t_pass_s=b.t_pass, speedup=plan.speedup(b), plan_us=us))
    return rows


def planner_scale_table():
    """§Planner-scale: streaming-planner wall time across a RoadNet D sweep.

    For each size the full planner (``plan_layout`` at P = 8) is timed in
    ``plan_mode="sampled"`` — the core/sketch.py streaming path: sampled
    χ/L estimation plus the coarsened commvol descent — and, up to
    ``EXACT_MAX_D`` rows, in ``plan_mode="exact"`` next to it, so the
    record pairs the estimated bytes with the exact planner's on the
    sizes where both exist. The sweep then *asserts* sublinear scaling
    of the sampled wall time in nnz (exponent bound 0.8, with a 50 ms
    floor against timer noise): constant-size sample work plus a handful
    of O(D) array sweeps must not track the O(nnz) exact pass."""
    from repro.core.planner import plan_layout
    from repro.matrices import RoadNet

    rows = []
    P, Ns = 8, 16
    sizes = (48_000, 192_000, 768_000, 3_072_000)
    EXACT_MAX_D = 200_000
    print(f"\n=== Planner-scale: streaming planner across D (RoadNet, "
          f"P={P}, Ns={Ns}) ===")
    print(f"{'D':>9s} {'mode':8s} {'plan[s]':>8s} {'best':16s} "
          f"{'bytes/dev':>10s} {'vs exact':>9s}")
    times: dict = {}
    for n in sizes:
        fam = RoadNet(n=n)
        nnz = fam.est_nnz()
        bytes_by_mode: dict = {}
        for mode in ("sampled",) + (("exact",) if n <= EXACT_MAX_D else ()):
            t0 = time.perf_counter()
            plan = plan_layout(fam, P, n_search=Ns, plan_mode=mode)
            dt = time.perf_counter() - t0
            b = plan.best
            bytes_by_mode[mode] = b.comm_bytes_per_device
            times[mode, n] = (dt, nnz)
            vs = (f"{bytes_by_mode['sampled'] / max(bytes_by_mode['exact'], 1):8.3f}x"
                  if "exact" in bytes_by_mode else "        -")
            print(f"{n:9d} {mode:8s} {dt:8.3f} {b.describe():16s} "
                  f"{b.comm_bytes_per_device:10d} {vs}")
            rows.append((f"planner_scale_{mode}_{n}", dt * 1e6,
                         f"D={n} nnz={nnz} best={b.describe()} "
                         f"bytes={b.comm_bytes_per_device}"))
            RECORDS.append(dict(
                table="planner-scale", family="roadnet", D=n, nnz=nnz,
                plan_mode=mode, plan_seconds=dt, best=b.describe(),
                pred_bytes_per_device=b.comm_bytes_per_device))
    (t_small, nnz_small) = times["sampled", sizes[0]]
    (t_large, nnz_large) = times["sampled", sizes[-1]]
    bound = max(t_small, 0.05) * (nnz_large / nnz_small) ** 0.8
    print(f"sampled scaling: {t_small:.3f}s @ nnz={nnz_small} -> "
          f"{t_large:.3f}s @ nnz={nnz_large} "
          f"(sublinear bound {bound:.3f}s)")
    if t_large > bound:
        raise RuntimeError(
            f"planner-scale: sampled planning time is not sublinear in "
            f"nnz — {t_large:.3f}s at nnz={nnz_large} exceeds "
            f"max(t_small, 50ms) * (nnz ratio)^0.8 = {bound:.3f}s")
    return rows


def roofline_table():
    """§Roofline source: per-cell terms from the dry-run caches.

    Rows marked ``*opt`` come from the §Perf-optimized build
    (dryrun_opt.jsonl) and are shown next to their paper-faithful
    baselines."""
    path = os.path.join(CACHE_DIR, "dryrun.jsonl")
    rows = []
    if not os.path.exists(path):
        print("\n(no dryrun cache yet — run benchmarks/sweep_dryrun.py)")
        return rows
    recs = {}
    for line in open(path):
        r = json.loads(line)
        if r.get("status") == "ok":
            recs[(r["arch"], r["shape"], r["mesh"], "")] = r
    opt_path = os.path.join(CACHE_DIR, "dryrun_opt.jsonl")
    if os.path.exists(opt_path):
        for line in open(opt_path):
            r = json.loads(line)
            if r.get("status") == "ok":
                recs[(r["arch"], r["shape"], r["mesh"], "*opt")] = r
    print("\n=== Roofline terms per dry-run cell (16x16 mesh) ===")
    print(f"{'arch':22s} {'shape':28s} {'comp[ms]':>9s} {'mem[ms]':>9s} "
          f"{'coll[ms]':>9s} {'dom':>6s} {'useful':>7s}")
    for (arch, shape, mesh, tag), r in sorted(recs.items()):
        if mesh != "16x16":
            continue
        print(f"{arch:22s} {shape + tag:28s} {r['t_compute_s']*1e3:9.1f} "
              f"{r['t_memory_s']*1e3:9.1f} {r['t_collective_s']*1e3:9.1f} "
              f"{r['dominant'][:6]:>6s} {r['useful_flops_ratio']:7.2f}")
    n_ok = sum(1 for k in recs if not k[3])
    n_opt = sum(1 for k in recs if k[3])
    rows.append(("roofline_cells", 0.0, f"cells_ok={n_ok} optimized={n_opt}"))
    return rows

#!/usr/bin/env python
"""Documentation consistency gate.

Verifies, without importing any heavy modules:

  1. every module under ``src/repro/`` has a module docstring,
  2. every ``--flag`` used by a README or ``docs/`` bash snippet exists
     in the argparse parser of the CLI the snippet invokes
     (``repro.launch.solve``, ``repro.launch.dryrun``,
     ``benchmarks.run``),
  3. every repo-relative ``*.py``/``*.md`` path referenced in the README
     or ``docs/`` exists,
  4. every function/class name the README's cross-reference table pins to
     a file is actually defined in that file,
  5. every ``FDConfig`` field and every ``--flag`` declared by the
     ``solve``/``dryrun`` CLIs is documented somewhere in the README or
     ``docs/`` — a field or flag added without documentation fails the
     gate,
  6. every internal markdown cross-link in ``docs/`` (and README links
     into ``docs/``) resolves: the target file exists and, when an
     ``#anchor`` is given, a heading with that GitHub slug exists in it.

Run standalone::

    PYTHONPATH=src python scripts/check_docs.py

or as part of the tier-1 suite via ``tests/test_docs.py``.
"""
from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
README = os.path.join(ROOT, "README.md")
DOCS_DIR = os.path.join(ROOT, "docs")

#: README CLI module -> source file holding its argparse definitions
CLI_SOURCES = {
    "repro.launch.solve": "src/repro/launch/solve.py",
    "repro.launch.dryrun": "src/repro/launch/dryrun.py",
    "benchmarks.run": "benchmarks/run.py",
}

#: Engine/planner flags that must BOTH be declared by their CLI and be
#: demonstrated in at least one README bash snippet — the README's engine
#: matrix promises one runnable example per engine, so a flag silently
#: dropped from either side fails the gate.
REQUIRED_FLAGS = {
    "repro.launch.solve": ["--layout", "--spmv-overlap", "--spmv-comm",
                           "--spmv-schedule", "--spmv-balance",
                           "--spmv-reorder", "--spmv-kernel",
                           "--spmv-sstep", "--plan-mode", "--machine",
                           "--serve", "--plan-cache"],
    "repro.launch.dryrun": ["--layout", "--plan", "--spmv-comm",
                            "--spmv-schedule", "--spmv-balance",
                            "--spmv-reorder", "--spmv-kernel",
                            "--spmv-sstep", "--plan-mode",
                            "--fit-machine", "--verify"],
    "benchmarks.run": ["--only", "--json"],
}

#: First-class documentation files: each must exist AND be referenced
#: from the README — the docs/ subsystem's headline pages cannot
#: silently drop out of the navigation.
REQUIRED_DOCS = ("docs/comm-engines.md", "docs/planner.md",
                 "docs/partitioning.md", "docs/analysis.md",
                 "docs/kernels.md", "docs/s-step.md", "docs/service.md",
                 "docs/scaling.md")

#: CLIs whose *every* declared flag must be documented in README/docs
#: (check 5). benchmarks.run is covered by REQUIRED_FLAGS only.
DOCUMENTED_CLIS = ("repro.launch.solve", "repro.launch.dryrun")


def _doc_files() -> list[tuple[str, str]]:
    """(path, text) of README.md plus every docs/*.md."""
    out = [(README, open(README).read())]
    if os.path.isdir(DOCS_DIR):
        for fn in sorted(os.listdir(DOCS_DIR)):
            if fn.endswith(".md"):
                path = os.path.join(DOCS_DIR, fn)
                out.append((path, open(path).read()))
    return out


def check_module_docstrings() -> list[str]:
    """Every module under src/repro must carry a module docstring."""
    errors = []
    for dirpath, _, filenames in os.walk(os.path.join(ROOT, "src", "repro")):
        for fn in sorted(filenames):
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path) as f:
                try:
                    tree = ast.parse(f.read())
                except SyntaxError as e:
                    errors.append(f"{os.path.relpath(path, ROOT)}: {e}")
                    continue
            if ast.get_docstring(tree) is None:
                errors.append(
                    f"{os.path.relpath(path, ROOT)}: missing module docstring")
    return errors


def _bash_commands(text: str) -> list[str]:
    """Commands from README ```bash fences, continuation lines joined."""
    cmds = []
    for block in re.findall(r"```bash\n(.*?)```", text, flags=re.S):
        joined = block.replace("\\\n", " ")
        for line in joined.splitlines():
            line = line.strip()
            if line and not line.startswith("#"):
                cmds.append(line)
    return cmds


def _declared_flags(src_path: str) -> set[str]:
    """--flags declared via add_argument in a CLI source file."""
    with open(os.path.join(ROOT, src_path)) as f:
        return set(re.findall(r"add_argument\(\s*[\"'](--[\w-]+)[\"']", f.read()))


def check_readme_flags() -> list[str]:
    """README and docs/ bash snippets may only use flags the CLIs declare."""
    errors = []
    for path, text in _doc_files():
        label = os.path.relpath(path, ROOT)
        for cmd in _bash_commands(text):
            target = next((m for m in CLI_SOURCES
                           if f"-m {m}" in cmd or CLI_SOURCES[m] in cmd), None)
            if target is None:
                continue
            declared = _declared_flags(CLI_SOURCES[target])
            # flags preceded by whitespace (so VAR=--xla... env values don't count)
            for flag in re.findall(r"(?<=\s)--[a-zA-Z][\w-]*", cmd):
                if flag not in declared:
                    errors.append(
                        f"{label}: `{flag}` not a flag of {target} "
                        f"(declared: {sorted(declared)})")
    return errors


def check_required_flags() -> list[str]:
    """Every REQUIRED_FLAGS entry must be declared by its CLI's argparse
    AND appear in a README bash snippet invoking that CLI."""
    errors = []
    with open(README) as f:
        text = f.read()
    used: dict[str, set[str]] = {m: set() for m in CLI_SOURCES}
    for cmd in _bash_commands(text):
        target = next((m for m in CLI_SOURCES
                       if f"-m {m}" in cmd or CLI_SOURCES[m] in cmd), None)
        if target:
            used[target].update(re.findall(r"(?<=\s)--[a-zA-Z][\w-]*", cmd))
    for module, flags in REQUIRED_FLAGS.items():
        declared = _declared_flags(CLI_SOURCES[module])
        for flag in flags:
            if flag not in declared:
                errors.append(f"{CLI_SOURCES[module]}: required flag "
                              f"`{flag}` not declared by {module}")
            if flag not in used[module]:
                errors.append(f"README: no bash example exercises "
                              f"`{flag}` of {module}")
    return errors


def check_readme_paths() -> list[str]:
    """Repo-relative paths in backticks must exist (README and docs/)."""
    errors = []
    for path, text in _doc_files():
        label = os.path.relpath(path, ROOT)
        for ref in set(re.findall(
                r"`((?:src|benchmarks|tests|scripts|examples|docs)"
                r"/[\w/.\-]+?\.(?:py|md))`", text)):
            if not os.path.exists(os.path.join(ROOT, ref)):
                errors.append(f"{label}: referenced path `{ref}` does not exist")
    return errors


def check_readme_symbols() -> list[str]:
    """Cross-reference rows ``path` — `name1`, `name2`...`: each name must
    be defined (def/class/assignment) in that file."""
    errors = []
    with open(README) as f:
        text = f.read()
    for path, names in re.findall(
            r"`((?:src|benchmarks)/[\w/.\-]+?\.py)`[^|\n]*?—((?:[^|\n]*?`[\w.]+`)+)",
            text):
        full = os.path.join(ROOT, path)
        if not os.path.exists(full):
            continue  # reported by check_readme_paths
        with open(full) as f:
            src = f.read()
        for name in re.findall(r"`([\w.]+)`", names):
            base = name.split(".")[-1]
            if not re.search(rf"^\s*(?:def|class)\s+{re.escape(base)}\b"
                             rf"|^\s*{re.escape(base)}\s*[=:]",
                             src, flags=re.M):
                errors.append(f"README: `{name}` not defined in {path}")
    return errors


def check_config_and_flags_documented() -> list[str]:
    """Every FDConfig field and every flag the solve/dryrun CLIs declare
    must appear somewhere in README.md or docs/ — adding a config knob
    without documenting it fails the gate."""
    errors = []
    corpus = "\n".join(text for _, text in _doc_files())
    fd_path = os.path.join(ROOT, "src", "repro", "core", "filter_diag.py")
    with open(fd_path) as f:
        tree = ast.parse(f.read())
    fields: list[str] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "FDConfig":
            fields = [st.target.id for st in node.body
                      if isinstance(st, ast.AnnAssign)]
    if not fields:
        errors.append("check_docs: FDConfig dataclass not found in "
                      "src/repro/core/filter_diag.py")
    for field in fields:
        if not re.search(rf"\b{re.escape(field)}\b", corpus):
            errors.append(f"docs: FDConfig field `{field}` appears nowhere "
                          "in README.md or docs/")
    for module in DOCUMENTED_CLIS:
        for flag in sorted(_declared_flags(CLI_SOURCES[module])):
            if flag not in corpus:
                errors.append(f"docs: {module} flag `{flag}` appears "
                              "nowhere in README.md or docs/")
    return errors


def _heading_slugs(text: str) -> set[str]:
    """GitHub anchor slugs of every markdown heading in ``text``:
    fenced code blocks are skipped (a ``# comment`` inside one is not a
    heading) and duplicate headings get the ``-1``, ``-2``… suffixes
    GitHub appends to later occurrences."""
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in text.splitlines():
        if re.match(r"\s*(```|~~~)", line):
            in_fence = not in_fence
            continue
        m = re.match(r"#{1,6}\s+(.*)", line)
        if m and not in_fence:
            h = m.group(1).strip().lower()
            h = re.sub(r"[^\w\s-]", "", h)
            slug = re.sub(r"\s", "-", h)
            n = counts.get(slug, 0)
            counts[slug] = n + 1
            slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def check_docs_links() -> list[str]:
    """Internal markdown links in README/docs/ resolve: the target file
    exists and a given #anchor matches a heading slug in it."""
    errors = []
    texts = {path: text for path, text in _doc_files()}
    for path, text in texts.items():
        label = os.path.relpath(path, ROOT)
        for target in re.findall(r"\[[^\]]*\]\(([^)\s]+)\)", text):
            if re.match(r"[a-z]+:", target):  # http:, https:, mailto:
                continue
            dest, _, anchor = target.partition("#")
            full = (path if not dest
                    else os.path.normpath(os.path.join(os.path.dirname(path),
                                                       dest)))
            if not os.path.exists(full):
                errors.append(f"{label}: link target `{target}` does not "
                              "exist")
                continue
            if anchor:
                if full not in texts:
                    try:
                        texts[full] = open(full).read()
                    except OSError:
                        errors.append(f"{label}: link target `{target}` "
                                      "is unreadable")
                        continue
                if anchor not in _heading_slugs(texts[full]):
                    errors.append(f"{label}: anchor `#{anchor}` matches no "
                                  f"heading in {os.path.relpath(full, ROOT)}")
    return errors


def check_required_docs() -> list[str]:
    """Every REQUIRED_DOCS page exists and is referenced by the README."""
    errors = []
    with open(README) as f:
        text = f.read()
    root = os.path.dirname(README)
    for doc in REQUIRED_DOCS:
        if not os.path.exists(os.path.join(root, doc)):
            errors.append(f"docs: required page `{doc}` does not exist")
        if doc not in text:
            errors.append(f"README: required docs page `{doc}` is never "
                          "referenced")
    return errors


def run_all() -> list[str]:
    errors = []
    errors += check_module_docstrings()
    errors += check_readme_flags()
    errors += check_required_flags()
    errors += check_readme_paths()
    errors += check_readme_symbols()
    errors += check_config_and_flags_documented()
    errors += check_required_docs()
    errors += check_docs_links()
    return errors


def main() -> int:
    errors = run_all()
    for e in errors:
        print(f"[check_docs] {e}")
    if errors:
        print(f"[check_docs] FAILED ({len(errors)} problems)")
        return 1
    print("[check_docs] OK — docstrings, README/docs flags/paths/symbols, "
          "FDConfig+CLI documentation coverage, and docs links consistent")
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env python
"""Static communication verifier gate.

Proves, from compiled artifacts and pattern-only plans — never by
executing a solver — that the communication the engines actually emit is
exactly the communication the paper's χ model predicts:

  1. **plan lint** (``repro.analysis.plan_lint``): NeighborPlan rounds
     are valid partial permutations covering every nonzero pair exactly
     once, H_matching <= H_cyclic, RowMap embed/extract is a bijection,
     zero-halo plans collapse to empty schedules, and SpmvCommPlan byte
     accounting is internally consistent — for SpinChain/RoadNet/HubNet
     at several shard counts x partition balances;
  1b. **s-step plan lint** (``lint_sstep``): the depth-s ghost-zone plan
     of the seventh engine axis covers the depth-1 halo, ``ghost_cum``
     is monotone with its depth-1 slice equal to the classic halo, and
     the whole-filter ``sstep_collectives`` byte totals equal
     ``moved x (2.ceil(n/s) - 1) x n_b x S_d`` for both comm engines —
     with the depth-1 plan rejected as the non-vacuity control;
  1c. **sampled-plan lint** (``core/sketch.py``): the streaming planner's
     half-fraction sampled comm plan passes ``lint_sampled_plan``, its
     confidence band contains the exact χ, per-device moved entries stay
     within tolerance of the exact plan for all three engines, and the
     matrix-free windowed ``build_dist_ell`` is bit-identical to the
     materialized-CSR build — for all three seed families, in ``--fast``
     too;
  2. **overlap dependency check** (``repro.analysis.overlap_check``):
     the jaxpr of every split-phase engine — kernel off AND kernel on —
     shows its halo collective has no data dependence on the local
     contraction (and the plain engines *fail* that check, proving the
     pass is not vacuous);
  2b. **round-pipeline proof**: the compressed split-phase engines are
     proved *round-pipelined* by the prefix-chain property
     (``check_round_pipeline``): every contraction's halo-collective
     dependence set is a prefix of the program-order ppermute chain,
     with prefix lengths 0, n, and a strict intermediate all witnessed
     — round r's contraction depends on no later round's collective.
     The unpipelined body (``make_spmv(..., pipeline=False)``) must
     *fail* the strict-interleaving condition, the non-vacuity control;
  2c. **kernel parity**: the kernelized engines (Pallas interpret mode
     on CPU) are executed once on a small cell and must be bit-identical
     (``np.array_equal``) to the jnp engines;
  3. **collective census** (``repro.analysis.census``): engine cells are
     compiled (``.lower().compile()`` only) on a fake-CPU mesh and every
     collective in the optimized HLO is attributed to a predicted term —
     zero unattributed, zero missing; kernelized cells (``+krn``) are
     attributed against the *same* terms as the jnp cells; s-step cells
     (``+s2``/``+s3``) are attributed against the grouped
     ``sstep_collectives`` terms (one single-width seed exchange plus
     width-doubled exchanges for the remaining groups);
  4. **bench artifact schema** (``benchmarks/schema.py``): the merged
     ``BENCH_spmv.json`` trajectory validates, if present;
  4b. **plan-cache lint** (``repro.service.plan_cache``): for each of the
     three seed families, a plan served through the persistent cache must
     equal the freshly planned one — candidate-for-candidate, RowMap
     arrays included — and the second fetch must be a hit that never
     re-invokes ``plan_layout``; a stale-plan bug in the service's cache
     would silently pin every tenant to a wrong engine, so this runs in
     ``--fast`` too;
  5. **linters**: ``ruff`` / ``mypy`` over ``src/repro/core`` +
     ``src/repro/analysis`` + ``src/repro/service`` when installed
     (skipped with a note when the container lacks them), plus a built-in
     unused-import scan that always runs.

Run standalone (fast subset, the tier-1 pre-commit loop)::

    python scripts/check_comm.py --fast

or the full engine grid (6 engine combos x 3 layouts x 2 balances,
~minutes)::

    python scripts/check_comm.py

The fast subset is also wired into tier-1 via ``tests/test_analysis.py``.
"""
from __future__ import annotations

import argparse
import ast
import os
import shutil
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
if ROOT not in sys.path:
    sys.path.insert(0, ROOT)  # for the benchmarks/ package
# the census and overlap sections need a multi-device mesh; must be set
# before the first jax import (harmless if jax is already imported — the
# census then raises a targeted error with this same hint)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

#: small instances of the three bench families (RoadNet ~ sparse
#: planar-ish, HubNet ~ hub-dominated); SpinChainXXZ is pattern-exact
ROADNET_SMALL = dict(n=4000, w=2, m=256, k=4)
HUBNET_SMALL = dict(n=4000, w=2, h=4, m=192, k=4)

#: the six SpMV engine combos: comm x schedule x split-phase
ENGINE_COMBOS = (
    ("a2a", "cyclic", False),
    ("a2a", "cyclic", True),
    ("compressed", "cyclic", False),
    ("compressed", "cyclic", True),
    ("compressed", "matching", False),
    ("compressed", "matching", True),
)

#: directories the linters (external and built-in) are scoped to
LINT_DIRS = ("src/repro/core", "src/repro/analysis", "src/repro/kernels",
             "src/repro/service")


def _families(fast: bool):
    from repro.matrices import HubNet, RoadNet, SpinChainXXZ

    fams = [("SpinChainXXZ(10,5)", SpinChainXXZ(10, 5))]
    if not fast:
        fams.append(("RoadNet-small", RoadNet(**ROADNET_SMALL)))
        fams.append(("HubNet-small", HubNet(**HUBNET_SMALL)))
    return fams


def check_plan_invariants(fast: bool = False) -> list[str]:
    """Section 1: pattern-only lint of plans/schedules/rowmaps."""
    from repro.analysis.plan_lint import run_plan_lint

    errors: list[str] = []
    for name, matrix in _families(fast):
        errs = run_plan_lint(matrix, n_rows=(4, 8), label=f"{name}/")
        print(f"[check_comm] plan-lint {name}: "
              f"{'OK' if not errs else f'{len(errs)} error(s)'}")
        errors += [f"plan-lint: {e}" for e in errs]
    return errors


def check_sstep_plans(fast: bool = False) -> list[str]:
    """Section 1b: depth-s ghost-zone plan lint (the seventh engine axis).

    For each family the depth-1 and depth-s plans of the SAME partition
    are cross-checked by :func:`repro.analysis.plan_lint.lint_sstep`:
    ghost coverage (the depth-s set contains the halo, ``ghost_cum``
    monotone with the depth-1 slice matching the classic plan) and byte
    accounting (``sstep_collectives`` totals equal
    ``moved x (2.ceil(n/s) - 1) x n_b x S_d`` for both comm engines).
    """
    import warnings

    from repro.analysis.plan_lint import lint_comm_plan, lint_sstep
    from repro.core.partition import plan_rowmap
    from repro.core.planner import comm_plan

    errors: list[str] = []
    depths = (2,) if fast else (2, 3)
    for name, matrix in _families(fast):
        for P in ((4,) if fast else (4, 8)):
            cp1 = comm_plan(matrix, P, exact=True)
            for s in depths:
                cell = f"{name}/P{P}+s{s}"
                cps = comm_plan(matrix, P, sstep=s)
                errs = lint_sstep(cp1, cps, label=cell)
                errs += lint_comm_plan(cps, label=cell)
                # planned-partition variant: the rowmap is planned at
                # depth s, so no stale-depth warning may fire
                rm = plan_rowmap(matrix, P, balance="commvol", sstep=s)
                with warnings.catch_warnings():
                    warnings.simplefilter("error", UserWarning)
                    cps_m = comm_plan(matrix, P, rowmap=rm, sstep=s)
                cp1_m = comm_plan(matrix, P, rowmap=rm)
                errs += lint_sstep(cp1_m, cps_m, label=cell + "+cv")
                # non-vacuity: a depth-1 plan must be rejected outright
                if not lint_sstep(cp1, cp1, label=cell):
                    errs.append(f"[{cell}] lint_sstep accepted a depth-1 "
                                f"plan — the linter is vacuous")
                print(f"[check_comm] sstep-lint {cell}: "
                      f"{'OK' if not errs else f'{len(errs)} error(s)'}")
                errors += [f"sstep-lint: {e}" for e in errs]
    return errors


def check_sampled_plans(fast: bool = False) -> list[str]:
    """Section 1c: streaming-planner lint (``core/sketch.py``).

    For each of the three seed families at P = 8:

    * the sampled comm plan (a half-fraction seeded subsample) passes
      :func:`repro.analysis.plan_lint.lint_sampled_plan` — every
      structural ``SpmvCommPlan`` invariant the engines rely on, plus a
      well-formed confidence band that contains its own center χ;
    * the band also contains the **exact** χ of the family (the
      statistical contract the estimator advertises at its level);
    * per-device moved entries of the sampled plan stay within
      ``SAMPLED_TOL`` of the exact plan's, for all three engines — the
      planner ranks candidates on these numbers;
    * the matrix-free windowed build is bit-identical to the CSR build
      (``build_dist_ell`` from windowed generator calls vs from the
      materialized CSR, every array compared with ``np.array_equal``),
      and ``collect_row_entries`` at an awkward window equals the
      one-shot pattern as a lexsorted multiset (the windowed protocol
      reorders segments by construction — docs/scaling.md).
    """
    import numpy as np

    from repro.analysis.plan_lint import lint_sampled_plan
    from repro.core.planner import comm_plan
    from repro.core.sketch import estimate_comm
    from repro.core.spmv import build_dist_ell
    from repro.matrices import HubNet, RoadNet, SpinChainXXZ
    from repro.matrices.matfree import collect_row_entries

    del fast  # the estimator contract is cheap and load-bearing: always full
    SAMPLED_TOL = 0.2
    errors: list[str] = []
    fams = [("SpinChainXXZ(12,6)", SpinChainXXZ(12, 6)),
            ("RoadNet-small", RoadNet(**ROADNET_SMALL)),
            ("HubNet-small", HubNet(**HUBNET_SMALL))]
    for name, matrix in fams:
        errs: list[str] = []
        est = estimate_comm(matrix, 8, fraction=0.5, seed=0)
        cp_s = est.comm_plan()
        cp_e = comm_plan(matrix, 8, exact=True)
        errs += lint_sampled_plan(cp_s, band=est.band, label=name)
        if not est.band.contains(cp_e.chi):
            errs.append(f"[{name}] confidence band misses the exact χ "
                        f"(chi1 {cp_e.chi.chi1:.4f} ∉ {est.band.chi1}, "
                        f"chi2 {cp_e.chi.chi2:.4f} ∉ {est.band.chi2}, or "
                        f"chi3 {cp_e.chi.chi3:.4f} ∉ {est.band.chi3})")
        for engine, sched in (("a2a", "cyclic"), ("compressed", "cyclic"),
                              ("compressed", "matching")):
            m_s = cp_s.moved_entries_per_device(engine, sched)
            m_e = cp_e.moved_entries_per_device(engine, sched)
            if abs(m_s - m_e) > SAMPLED_TOL * max(m_e, 1):
                errs.append(f"[{name}] sampled {engine}/{sched} moves "
                            f"{m_s} entries/device vs exact {m_e} "
                            f"(> {SAMPLED_TOL:.0%} off)")
        # matrix-free windowed build vs the materialized-CSR build
        d_pad = -(-matrix.D // 8) * 8
        ell_mf = build_dist_ell(matrix, 8, d_pad=d_pad)
        ell_csr = build_dist_ell(matrix.build_csr(), 8, d_pad=d_pad)
        for field in ("cols", "vals", "send_idx", "pair_counts"):
            a, b = getattr(ell_mf, field), getattr(ell_csr, field)
            if not np.array_equal(np.asarray(a), np.asarray(b)):
                errs.append(f"[{name}] matfree build_dist_ell.{field} "
                            f"differs from the CSR build (bit-identity "
                            f"broken)")
        rows = np.arange(matrix.D, dtype=np.int64)
        r1, c1, v1 = matrix.row_entries(rows)
        rw, cw, vw = collect_row_entries(matrix, rows, window=257)
        o1, ow = np.lexsort((c1, r1)), np.lexsort((cw, rw))
        if not (np.array_equal(r1[o1], rw[ow])
                and np.array_equal(c1[o1], cw[ow])
                and np.array_equal(v1[o1], vw[ow])):
            errs.append(f"[{name}] collect_row_entries(window=257) is not "
                        f"multiset-equal to the one-shot row_entries")
        print(f"[check_comm] sampled-plan {name}: "
              f"{'OK' if not errs else f'{len(errs)} error(s)'}")
        errors += [f"sampled-plan: {e}" for e in errs]
    return errors


def check_overlap(fast: bool = False) -> list[str]:
    """Section 2: jaxpr dependence proof for every engine combo.

    Split-phase engines must pass conditions (A) + (B); plain engines
    must *fail* condition (B) — their single contraction consumes the
    received halo — which proves the checker is not vacuous.
    """
    import jax

    from repro.analysis.overlap_check import check_split_phase
    from repro.core import layouts as lo
    from repro.core.planner import layout_on_mesh
    from repro.core.spmv import build_dist_ell, make_spmv
    from repro.matrices import SpinChainXXZ

    del fast  # tracing only — cheap enough to always run the full set
    errors: list[str] = []
    matrix = SpinChainXXZ(10, 5)
    mesh = lo.make_solver_mesh(4, 2)
    panel_l = layout_on_mesh(mesh, "panel")
    N_row = panel_l.n_row(mesh)
    D_pad = -(-matrix.D // 8) * 8
    ells = {split: build_dist_ell(matrix, N_row, d_pad=D_pad,
                                  split_halo=split)
            for split in (False, True)}
    n_b = 4
    V = jax.ShapeDtypeStruct((D_pad, n_b), ells[True].vals.dtype)
    for comm, schedule, overlap in ENGINE_COMBOS:
        for use_kernel in (False, True):
            tag = (f"{comm}/{schedule}{'+ov' if overlap else ''}"
                   f"{'+krn' if use_kernel else ''}")
            spmv = make_spmv(mesh, panel_l, ells[overlap],
                             use_kernel=use_kernel, overlap=overlap,
                             comm=comm, schedule=schedule)
            with mesh:
                rep = check_split_phase(spmv, V)
            if overlap:
                if not rep.ok:
                    errors += [f"overlap[{tag}]: {e}" for e in rep.errors]
                status = "OK" if rep.ok else f"{len(rep.errors)} error(s)"
                print(f"[check_comm] overlap {tag}: {status} "
                      f"({rep.independent_contractions} hideable "
                      f"contraction(s))")
            else:
                # non-vacuity: the plain engine must be reported as having
                # no contraction the exchange could hide behind
                if rep.ok:
                    errors.append(
                        f"overlap[{tag}]: plain engine unexpectedly "
                        f"passed the split-phase check — the checker is "
                        f"vacuous")
                print(f"[check_comm] overlap {tag}: fails (B) as expected"
                      if not rep.ok else
                      f"[check_comm] overlap {tag}: UNEXPECTED PASS")
    return errors


def check_pipeline(fast: bool = False) -> list[str]:
    """Section 2b: prefix-chain proof that the compressed split-phase
    engines are round-pipelined (kernel off and on), with the
    unpipelined body (``pipeline=False``) as the failing control.
    """
    import jax

    from repro.analysis.overlap_check import check_round_pipeline
    from repro.core import layouts as lo
    from repro.core.planner import layout_on_mesh
    from repro.core.spmv import build_dist_ell, make_spmv
    from repro.matrices import SpinChainXXZ

    del fast  # tracing only — cheap enough to always run the full set
    errors: list[str] = []
    matrix = SpinChainXXZ(10, 5)
    mesh = lo.make_solver_mesh(4, 2)
    panel_l = layout_on_mesh(mesh, "panel")
    N_row = panel_l.n_row(mesh)
    D_pad = -(-matrix.D // 8) * 8
    ell = build_dist_ell(matrix, N_row, d_pad=D_pad, split_halo=True)
    V = jax.ShapeDtypeStruct((D_pad, 4), ell.vals.dtype)
    for schedule in ("cyclic", "matching"):
        for use_kernel in (False, True):
            tag = f"compressed/{schedule}+ov{'+krn' if use_kernel else ''}"
            spmv = make_spmv(mesh, panel_l, ell, use_kernel=use_kernel,
                             overlap=True, comm="compressed",
                             schedule=schedule)
            with mesh:
                rep = check_round_pipeline(spmv, V)
            if not rep.ok:
                errors += [f"pipeline[{tag}]: {e}" for e in rep.errors]
            print(f"[check_comm] pipeline {tag}: "
                  f"{'OK' if rep.ok else f'{len(rep.errors)} error(s)'} "
                  f"({rep.n_rounds} round(s), prefixes "
                  f"{rep.prefix_lengths})")
            if not rep.ok:
                print(rep.describe())
            # non-vacuity control: the unpipelined body must fail the
            # strict-interleaving condition whenever there are >= 2 rounds
            flat = make_spmv(mesh, panel_l, ell, use_kernel=use_kernel,
                             overlap=True, comm="compressed",
                             schedule=schedule, pipeline=False)
            with mesh:
                rep0 = check_round_pipeline(flat, V)
            if rep0.n_rounds >= 2 and rep0.ok:
                errors.append(
                    f"pipeline[{tag}]: the unpipelined control body "
                    f"passed the prefix-chain proof — the checker is "
                    f"vacuous")
            print(f"[check_comm] pipeline {tag} control: "
                  f"{'fails as expected' if not rep0.ok else 'UNEXPECTED PASS'}")
    return errors


def check_kernel_parity(fast: bool = False) -> list[str]:
    """Section 2c: execute the kernelized engines once (Pallas interpret
    mode on CPU) and require bit-identity with the jnp engines."""
    import numpy as np
    import jax

    from repro.core import layouts as lo
    from repro.core.planner import layout_on_mesh
    from repro.core.spmv import build_dist_ell, make_spmv
    from repro.matrices import SpinChainXXZ

    errors: list[str] = []
    matrix = SpinChainXXZ(10, 5)
    mesh = lo.make_solver_mesh(4, 2)
    panel_l = layout_on_mesh(mesh, "panel")
    N_row = panel_l.n_row(mesh)
    D_pad = -(-matrix.D // 8) * 8
    ells = {split: build_dist_ell(matrix, N_row, d_pad=D_pad,
                                  split_halo=split)
            for split in (False, True)}
    rng = np.random.default_rng(7)
    V = jax.device_put(
        rng.standard_normal((D_pad, 4)).astype(ells[True].vals.dtype),
        jax.NamedSharding(mesh, panel_l.vec_pspec()))
    combos = (ENGINE_COMBOS if not fast
              else (("a2a", "cyclic", False),
                    ("compressed", "matching", True)))
    for comm, schedule, overlap in combos:
        tag = f"{comm}/{schedule}{'+ov' if overlap else ''}"
        with mesh:
            y_jnp = np.asarray(
                make_spmv(mesh, panel_l, ells[overlap], overlap=overlap,
                          comm=comm, schedule=schedule)(V))
            y_krn = np.asarray(
                make_spmv(mesh, panel_l, ells[overlap], use_kernel=True,
                          overlap=overlap, comm=comm,
                          schedule=schedule)(V))
        biteq = np.array_equal(y_jnp, y_krn)
        if not biteq:
            errors.append(
                f"kernel-parity[{tag}]: kernelized engine is not "
                f"bit-identical to the jnp engine (max diff "
                f"{np.abs(y_jnp - y_krn).max():.3e})")
        print(f"[check_comm] kernel-parity {tag}: "
              f"{'BITEQ' if biteq else 'MISMATCH'}")
    return errors


def check_census(fast: bool = False, families=("spinchain",)) -> list[str]:
    """Section 3: compile-only collective census over the engine grid."""
    from repro.analysis.census import run_census_cell
    from repro.matrices import HubNet, RoadNet, SpinChainXXZ

    mats = {"spinchain": ("SpinChainXXZ(10,5)", SpinChainXXZ(10, 5)),
            "roadnet": ("RoadNet-small", RoadNet(**ROADNET_SMALL)),
            "hubnet": ("HubNet-small", HubNet(**HUBNET_SMALL))}
    if fast:
        grid = [("panel", "a2a", "cyclic", False, "rows", "none", False, 1),
                ("panel", "compressed", "matching", True, "commvol", "rcm",
                 False, 1),
                # kernel-parity cell: the kernelized engine (Pallas
                # interpret mode) must attribute to the same terms
                ("panel", "compressed", "matching", True, "rows", "none",
                 True, 1),
                # seventh-axis cell: the s=2 engine's sstep_collectives
                # terms must attribute the grouped (single + doubled-width)
                # exchanges exactly
                ("panel", "a2a", "cyclic", False, "rows", "none", False, 2)]
        families = ("spinchain",)
    else:
        # the panel/rows column runs the full twelve-engine grid
        # (6 combos x kernel off/on); the other columns stay kernel-off
        grid = [(layout, comm, schedule, overlap, balance, "none", uk, 1)
                for layout in ("stack", "panel", "pillar")
                for comm, schedule, overlap in ENGINE_COMBOS
                for balance in ("rows", "commvol")
                for uk in ((False, True)
                           if layout == "panel" and balance == "rows"
                           else (False,))]
        # s-step column: both comm engines at s=2 plus one s=3 cell,
        # plain panel (the depth-s engine lowers the plain path)
        grid += [("panel", "a2a", "cyclic", False, "rows", "none", False, 2),
                 ("panel", "compressed", "matching", False, "rows", "none",
                  False, 2),
                 ("panel", "compressed", "cyclic", False, "commvol", "none",
                  False, 3)]
    errors: list[str] = []
    for fam in families:
        name, matrix = mats[fam]
        for (layout, comm, schedule, overlap, balance, reorder, uk,
             sstep) in grid:
            rep = run_census_cell(matrix, P_total=8, layout=layout,
                                  comm=comm, schedule=schedule,
                                  overlap=overlap, use_kernel=uk,
                                  balance=balance, reorder=reorder,
                                  sstep=sstep)
            print(f"[check_comm] census {name} {rep.cell}: "
                  f"{'OK' if rep.ok else f'{len(rep.errors)} error(s)'}")
            if not rep.ok:
                print(rep.describe())
            errors += [f"census[{name}]: {e}" for e in rep.errors]
    return errors


def check_bench_schema() -> list[str]:
    """Section 4: validate the BENCH_spmv.json perf artifact if present."""
    from benchmarks.schema import check_artifact

    path = os.path.join(ROOT, "BENCH_spmv.json")
    if not os.path.exists(path):
        print("[check_comm] bench-schema: no BENCH_spmv.json (skipped)")
        return []
    errs = check_artifact(path)
    print(f"[check_comm] bench-schema: "
          f"{'OK' if not errs else f'{len(errs)} error(s)'}")
    return [f"bench-schema: {e}" for e in errs]


def check_plan_cache(fast: bool = False) -> list[str]:
    """Section 4b: cached plan == freshly planned plan, seed families.

    Round-trips each family's plan through a real on-disk store and
    through ``cached_plan_layout``'s hit path, requiring (a) hit status
    with zero extra planner calls, (b) candidate-for-candidate equality
    (the frozen scalar fields), (c) byte-equal RowMap arrays behind every
    planned-partition candidate — the exact objects the service hands the
    solver on a hit.
    """
    import tempfile

    import numpy as np

    from repro.matrices import HubNet, RoadNet, SpinChainXXZ
    from repro.service.plan_cache import PlanCache, cached_plan_layout

    del fast  # the cache contract is cheap and load-bearing: always full
    errors: list[str] = []
    fams = [("SpinChainXXZ(10,5)", SpinChainXXZ(10, 5)),
            ("RoadNet-small", RoadNet(**ROADNET_SMALL)),
            ("HubNet-small", HubNet(**HUBNET_SMALL))]
    with tempfile.TemporaryDirectory() as tmp:
        cache = PlanCache(os.path.join(tmp, "plans.json"))
        for name, matrix in fams:
            D = matrix.D
            kw = dict(n_search=16, d_pad=-(-D // 8) * 8)
            fresh, hit0 = cached_plan_layout(matrix, 8, cache=cache, **kw)
            calls_before = cache.plan_calls
            cached, hit1 = cached_plan_layout(matrix, 8, cache=cache, **kw)
            errs: list[str] = []
            if hit0 or not hit1:
                errs.append(f"hit sequence (miss, hit) expected, got "
                            f"({hit0}, {hit1})")
            if cache.plan_calls != calls_before:
                errs.append("the hit path re-invoked plan_layout")
            if cached.candidates != fresh.candidates:
                errs.append("cached candidates differ from freshly planned")
            for c_f, c_c in zip(fresh.candidates, cached.candidates):
                if (c_f.rowmap is None) != (c_c.rowmap is None):
                    errs.append(f"rowmap presence differs in {c_f.layout}"
                                f"/{c_f.comm}")
                elif c_f.rowmap is not None and not (
                        np.array_equal(c_f.rowmap.perm, c_c.rowmap.perm)
                        and np.array_equal(c_f.rowmap.boundaries,
                                           c_c.rowmap.boundaries)):
                    errs.append(f"rowmap arrays differ in {c_f.layout}"
                                f"/{c_f.comm}/{c_f.balance}")
            if cached.best != fresh.best:
                errs.append("cached plan selects a different engine cell")
            print(f"[check_comm] plan-cache {name}: "
                  f"{'OK' if not errs else f'{len(errs)} error(s)'}")
            errors += [f"plan-cache[{name}]: {e}" for e in errs]
    return errors


def _unused_imports(path: str) -> list[str]:
    """Built-in F401-style scan: imported top-level names never used.

    Skips ``__future__`` imports, ``# noqa`` lines, and names re-exported
    via ``__all__`` (the ``__init__.py`` pattern).
    """
    src = open(path).read()
    tree = ast.parse(src)
    lines = src.splitlines()
    exported: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__":
                    try:
                        exported = set(ast.literal_eval(node.value))
                    except ValueError:
                        pass
    imported: dict = {}  # name -> lineno
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                imported[a.asname or a.name.split(".")[0]] = node.lineno
        elif isinstance(node, ast.Import):
            for a in node.names:
                imported[a.asname or a.name.split(".")[0]] = node.lineno
    used = {n.id for n in ast.walk(tree) if isinstance(n, ast.Name)}
    used |= {n.attr for n in ast.walk(tree) if isinstance(n, ast.Attribute)}
    out = []
    for name, lineno in sorted(imported.items(), key=lambda kv: kv[1]):
        if name in used or name in exported or name == "*":
            continue
        if "noqa" in lines[lineno - 1]:
            continue
        out.append(f"{path}:{lineno}: unused import {name!r}")
    return out


def check_linters() -> list[str]:
    """Section 5: ruff/mypy when installed + the built-in import scan."""
    errors: list[str] = []
    for tool, args in (("ruff", ["check"] + list(LINT_DIRS)),
                       ("mypy", list(LINT_DIRS))):
        exe = shutil.which(tool)
        if exe is None:
            print(f"[check_comm] {tool}: not installed (skipped — config "
                  f"lives in pyproject.toml)")
            continue
        proc = subprocess.run([exe] + args, cwd=ROOT, capture_output=True,
                              text=True)
        ok = proc.returncode == 0
        print(f"[check_comm] {tool}: {'OK' if ok else 'FAILED'}")
        if not ok:
            tail = (proc.stdout + proc.stderr).strip().splitlines()
            errors += [f"{tool}: {line}" for line in tail[:20]]
    scan: list[str] = []
    for d in LINT_DIRS:
        for dirpath, _, files in os.walk(os.path.join(ROOT, d)):
            for f in sorted(files):
                if f.endswith(".py"):
                    scan += _unused_imports(os.path.join(dirpath, f))
    print(f"[check_comm] import-scan: "
          f"{'OK' if not scan else f'{len(scan)} unused import(s)'}")
    return errors + scan


def run_all(fast: bool = False, census: bool = True,
            families=("spinchain",)) -> list[str]:
    errors = check_plan_invariants(fast)
    errors += check_sstep_plans(fast)
    errors += check_sampled_plans(fast)
    errors += check_overlap(fast)
    errors += check_pipeline(fast)
    errors += check_kernel_parity(fast)
    if census:
        errors += check_census(fast, families)
    errors += check_bench_schema()
    errors += check_plan_cache(fast)
    errors += check_linters()
    return errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true",
                    help="small subset (the tier-1 pre-commit loop): "
                         "SpinChain-only lint (incl. one s=2 s-step "
                         "plan cell), all overlap checks, four census "
                         "cells (incl. one +s2); the plan-cache lint "
                         "still covers all three seed families")
    ap.add_argument("--no-census", action="store_true",
                    help="skip the compile-only census section")
    ap.add_argument("--family", action="append", default=None,
                    choices=["spinchain", "roadnet", "hubnet"],
                    help="census families (full mode; default spinchain; "
                         "repeatable)")
    args = ap.parse_args()
    errors = run_all(fast=args.fast, census=not args.no_census,
                     families=tuple(args.family or ("spinchain",)))
    for e in errors:
        print(f"[check_comm] ERROR: {e}")
    print(f"[check_comm] {'PASS' if not errors else f'FAIL: {len(errors)} error(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())

"""End-to-end behaviour tests for the paper's system.

1. Filter diagonalization computes interior + extremal eigenpairs matching
   dense eigh (single device: the degenerate stack layout).
2. The Chebyshev filter amplifies exactly the targeted spectral window
   (paper Fig. 2 behaviour).
3. Training integration: a reduced LM config trains on the structured
   synthetic corpus and the loss drops materially below its initial value.
4. Checkpoint/restart mid-training reproduces the uninterrupted run.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import FDConfig, FilterDiag, make_solver_mesh
from repro.data import TokenPipeline
from repro.matrices import Hubbard, SpinChainXXZ
from repro.models import init_train_state, make_train_step
from repro.optim import AdamWConfig


@pytest.fixture(scope="module")
def spin_chain():
    mat = SpinChainXXZ(12, 6)
    csr = mat.build_csr()
    w, V = np.linalg.eigh(csr.to_dense())
    return csr, w, V


def test_fd_interior_eigenvalues_match_eigh(spin_chain):
    csr, w, _ = spin_chain
    tau = float(w[len(w) // 2])
    mesh = make_solver_mesh(1, 1)
    cfg = FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8, max_iters=25)
    with mesh:
        res = FilterDiag(csr, mesh, cfg).solve()
    assert res.n_converged >= 4
    for ev, r in zip(res.eigenvalues[:4], res.residuals[:4]):
        assert np.abs(w - ev).min() < 1e-7
        assert r <= 1e-8


def test_fd_extremal_eigenvalues(spin_chain):
    csr, w, _ = spin_chain
    mesh = make_solver_mesh(1, 1)
    # target below the spectrum; N_s >> N_t per the paper's convergence
    # guidance (a small search space trades iterations for filter degree)
    cfg = FDConfig(n_target=3, n_search=16, target=float(w[0]) - 0.1,
                   tol=1e-8, max_iters=40)
    with mesh:
        res = FilterDiag(csr, mesh, cfg).solve()
    assert res.n_converged >= 3
    got = np.sort(res.eigenvalues[:3])
    np.testing.assert_allclose(got, w[:3], atol=1e-7)


def test_fd_hubbard_with_interaction():
    mat = Hubbard(6, 3, U=4.0, ranpot=1.0)
    csr = mat.build_csr()
    w = np.linalg.eigvalsh(csr.to_dense())
    tau = float(w[len(w) // 3])
    mesh = make_solver_mesh(1, 1)
    cfg = FDConfig(n_target=3, n_search=12, target=tau, tol=1e-8, max_iters=25)
    with mesh:
        res = FilterDiag(csr, mesh, cfg).solve()
    assert res.n_converged >= 3
    for ev in res.eigenvalues[:3]:
        assert np.abs(w - ev).min() < 1e-7


def test_chebyshev_filter_amplifies_window(spin_chain):
    """p[A]v has overwhelmingly more weight on eigenvectors inside the
    search window than outside (Fig. 2, left column)."""
    from repro.core import build_filter, chebyshev_filter, scale_params, \
        build_dist_ell, make_spmv, stack
    csr, w, V = spin_chain
    D = csr.shape[0]
    mesh = make_solver_mesh(1, 1)
    lam = (float(w[0]) - 0.1, float(w[-1]) + 0.1)
    mid = len(w) // 2
    window = (w[mid] - 0.02, w[mid] + 0.02)
    poly = build_filter(window, lam, degree=600)
    with mesh:
        lay = stack(mesh)
        ell = build_dist_ell(csr, 1)
        spmv = make_spmv(mesh, lay, ell)
        x = jnp.asarray(np.random.default_rng(0).standard_normal((D, 1)))
        y = np.asarray(chebyshev_filter(spmv, jnp.asarray(poly.mu),
                                        *scale_params(*lam), x))[:, 0]
    coef = V.T @ y
    inside = (w >= window[0]) & (w <= window[1])
    far = (w < window[0] - 0.1) | (w > window[1] + 0.1)
    assert np.abs(coef[inside]).max() > 1e3 * np.abs(coef[far]).max()


def test_training_loss_decreases():
    cfg = get_smoke_config("qwen3-0.6b")
    ocfg = AdamWConfig(lr=3e-3, moment_dtype="float32", warmup_steps=5,
                       total_steps=60)
    params, opt_state = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg)
    step = jax.jit(make_train_step(cfg, ocfg), donate_argnums=(0, 1))
    losses = []
    for i in range(60):
        params, opt_state, m = step(params, opt_state, pipe.batch(i, 8, 64))
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-10:]) < np.mean(losses[:5]) - 0.5, (
        losses[:5], losses[-10:])


def test_train_restart_reproduces(tmp_path):
    """Kill training at step 7, resume from checkpoint, final params match
    the uninterrupted run bit-for-bit (deterministic pipeline + optimizer)."""
    from repro.launch.train import train

    p_full, o_full, l_full = train("qwen3-0.6b", steps=10, batch=2, seq=32,
                                   ckpt_dir=None, log_every=100)
    ck = str(tmp_path / "ck")
    # interrupted run: first 7 steps (checkpoint interval = steps//3 = 3)
    train("qwen3-0.6b", steps=7, batch=2, seq=32, ckpt_dir=ck, log_every=100)
    # resume to 10
    p_res, o_res, l_res = train("qwen3-0.6b", steps=10, batch=2, seq=32,
                                ckpt_dir=ck, log_every=100)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

"""Overlap (split-phase) SpMV engine vs the baseline engine.

The overlap engine issues the halo all_to_all before the local ELL
contraction; because the split preserves the per-row slot order it must
agree with the baseline bit-for-bit-ish (<1e-11) on every layout, for
real and complex matrices, and the split local/halo blocks must reproduce
the unsplit contraction exactly.
"""
import numpy as np
import pytest

from tests.conftest import run_distributed


def test_overlap_matches_baseline_all_layouts():
    out = run_distributed("""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import Hubbard
from repro.core import (make_solver_mesh, panel, pillar, build_dist_ell,
                        make_spmv, Layout)
mat = Hubbard(8, 4, U=2.0, ranpot=0.5)
csr = mat.build_csr()
D = csr.shape[0]
mesh = make_solver_mesh(4, 2)
rng = np.random.default_rng(0)
D_pad = -(-D // 8) * 8
for lay, P_row in ((panel(mesh), 4), (Layout("stack", ("row","col"), ()), 8),
                   (pillar(mesh), 1)):
    ell = build_dist_ell(csr, P_row, d_pad=D_pad, split_halo=True)
    Ns = 8
    X = np.zeros((D_pad, Ns)); X[:D] = rng.standard_normal((D, Ns))
    with mesh:
        Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
        Y_base = np.asarray(make_spmv(mesh, lay, ell)(Xs))
        Y_ovl = np.asarray(make_spmv(mesh, lay, ell, overlap=True)(Xs))
    ref = csr.matvec(X[:D])
    assert np.abs(Y_ovl[:D] - ref).max() < 1e-11, lay.name
    assert np.abs(Y_ovl - Y_base).max() < 1e-11, lay.name
    assert np.abs(Y_ovl[D:]).max() == 0, lay.name
    print(f"overlap {lay.name} ok")
print("OVERLAP LAYOUTS OK")
""")
    assert "OVERLAP LAYOUTS OK" in out


def test_overlap_complex_matrix():
    out = run_distributed("""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import TopIns
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
mat = TopIns(6)
csr = mat.build_csr()
assert np.iscomplexobj(csr.data)
D = csr.shape[0]
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
D_pad = -(-D // 8) * 8
ell = build_dist_ell(csr, 4, d_pad=D_pad, split_halo=True)
rng = np.random.default_rng(1)
X = np.zeros((D_pad, 4), dtype=np.complex128)
X[:D] = rng.standard_normal((D, 4)) + 1j * rng.standard_normal((D, 4))
with mesh:
    Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
    Y_base = np.asarray(make_spmv(mesh, lay, ell)(Xs))
    Y_ovl = np.asarray(make_spmv(mesh, lay, ell, overlap=True)(Xs))
ref = csr.matvec(X[:D])
assert np.abs(Y_ovl[:D] - ref).max() < 1e-11
assert np.abs(Y_ovl - Y_base).max() < 1e-11
print("OVERLAP COMPLEX OK")
""")
    assert "OVERLAP COMPLEX OK" in out


def test_split_blocks_reproduce_unsplit():
    """Host-side invariant: [local ‖ halo] split blocks contain exactly the
    unsplit entries (same per-row multiset, local columns preserved, halo
    columns rebased by R), and the split contraction equals the unsplit one
    on a dense random xfull — no devices needed."""
    from repro.core.spmv import build_dist_ell
    from repro.matrices import SpinChainXXZ

    csr = SpinChainXXZ(10, 5).build_csr()
    D = csr.shape[0]
    P_row = 4
    D_pad = -(-D // P_row) * P_row
    ell = build_dist_ell(csr, P_row, d_pad=D_pad)
    cl, vl, ch, vh = (np.asarray(a) for a in ell.split())
    cols, vals = np.asarray(ell.cols), np.asarray(ell.vals)
    R, L, P = ell.R, ell.L, ell.P
    rng = np.random.default_rng(3)
    for p in range(P):
        # entry multiset per row is preserved
        for r in range(R):
            stored = vals[p, r] != 0
            combined = sorted(zip(cols[p, r][stored], vals[p, r][stored]))
            loc = [(c, v) for c, v in zip(cl[p, r], vl[p, r]) if v != 0]
            halo = [(c + R, v) for c, v in zip(ch[p, r], vh[p, r]) if v != 0]
            assert sorted(loc + halo) == combined, (p, r)
        # split contraction == unsplit contraction on the padded ELL
        xfull = rng.standard_normal((R + P * L, 3))
        y_unsplit = np.einsum("rw,rwn->rn", vals[p], xfull[cols[p]])
        y_split = (np.einsum("rw,rwn->rn", vl[p], xfull[cl[p]])
                   + (np.einsum("rw,rwn->rn", vh[p], xfull[R + ch[p]])
                      if ch.shape[2] else 0.0))
        assert np.abs(y_split - y_unsplit).max() < 1e-12, p


@pytest.mark.slow
def test_fused_cheb_step_overlap_and_fd_solve():
    """Overlapped fused Chebyshev step matches the composed baseline, and a
    full FD solve with spmv_overlap=True converges to the same interior
    eigenvalues as dense eigh."""
    out = run_distributed("""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import SpinChainXXZ
from repro.core import (make_solver_mesh, panel, build_dist_ell, make_spmv,
                        FilterDiag, FDConfig)
from repro.core.spmv import make_fused_cheb_step
mat = SpinChainXXZ(10, 5)
csr = mat.build_csr()
D = csr.shape[0]
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
D_pad = -(-D // 8) * 8
ell = build_dist_ell(csr, 4, d_pad=D_pad, split_halo=True)
rng = np.random.default_rng(1)
W1 = np.zeros((D_pad, 4)); W1[:D] = rng.standard_normal((D, 4))
W2 = np.zeros((D_pad, 4)); W2[:D] = rng.standard_normal((D, 4))
with mesh:
    sh = lay.vec_sharding(mesh)
    w1 = jax.device_put(jnp.asarray(W1), sh)
    w2 = jax.device_put(jnp.asarray(W2), sh)
    fused = make_fused_cheb_step(mesh, lay, ell, overlap=True)(w1, w2, 0.7, -0.2)
    spmv = make_spmv(mesh, lay, ell)
    ref = 2*0.7*spmv(w1) + 2*(-0.2)*w1 - w2
assert np.abs(np.asarray(fused) - np.asarray(ref)).max() < 1e-12
print("FUSED OVERLAP OK")
w = np.linalg.eigvalsh(csr.to_dense())
tau = float(w[len(w)//2])
cfg = FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8, max_iters=25,
               spmv_overlap=True)
with mesh:
    res = FilterDiag(csr, mesh, cfg).solve()
assert res.n_converged >= 4, res.n_converged
for ev in res.eigenvalues[:4]:
    assert np.abs(w - ev).min() < 1e-7
print("FD OVERLAP OK", res.iterations)
""", timeout=1500)
    assert "FUSED OVERLAP OK" in out
    assert "FD OVERLAP OK" in out

"""Loop-aware HLO cost analyzer vs XLA ground truth on unrolled modules."""
import numpy as np
import pytest

from tests.conftest import run_distributed


def test_scan_flops_match_unrolled():
    out = run_distributed("""
import jax, jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.hlo_analysis import analyze_hlo, xla_cost_analysis
mesh = jax.make_mesh((2, 4), ("data", "model"))
L, D, B = 8, 256, 32
def f_scan(w, x):
    def body(x, wi):
        return jnp.tanh(x @ wi), None
    return lax.scan(body, x, w)[0].sum()
def f_unroll(w, x):
    for i in range(L):
        x = jnp.tanh(x @ w[i])
    return x.sum()
w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
x = jax.ShapeDtypeStruct((B, D), jnp.float32)
wsh = NamedSharding(mesh, P(None, None, "model"))
xsh = NamedSharding(mesh, P("data", None))
cs = jax.jit(f_scan, in_shardings=(wsh, xsh)).lower(w, x).compile()
cu = jax.jit(f_unroll, in_shardings=(wsh, xsh)).lower(w, x).compile()
hs, hu = analyze_hlo(cs.as_text()), analyze_hlo(cu.as_text())
true_flops = 2 * (B // 2) * D * (D // 4) * L  # per chip
assert hs.flops == true_flops, (hs.flops, true_flops)
assert abs(hu.flops - true_flops) / true_flops < 0.01
xla_unrolled = xla_cost_analysis(cu)["flops"]
assert abs(hs.flops - xla_unrolled) / xla_unrolled < 0.05
# collective bytes also scale with the trip count
ag = hs.coll_breakdown["all-gather"]
assert ag >= L * (B // 2) * (D // 4) * 4 * 0.8  # ~L per-iter gathers
print("HLO ANALYZER OK", hs.flops, ag)
""")
    assert "HLO ANALYZER OK" in out


def test_nested_scan_multiplicity():
    out = run_distributed("""
import jax, jax.numpy as jnp
from jax import lax
from repro.launch.hlo_analysis import analyze_hlo
D, INNER, OUTER = 128, 4, 6
def f(w, x):
    def outer(x, _):
        def inner(x, __):
            return jnp.tanh(x @ w), None
        x, _ = lax.scan(inner, x, None, length=INNER)
        return x, None
    x, _ = lax.scan(outer, x, None, length=OUTER)
    return x.sum()
w = jax.ShapeDtypeStruct((D, D), jnp.float32)
x = jax.ShapeDtypeStruct((8, D), jnp.float32)
c = jax.jit(f).lower(w, x).compile()
h = analyze_hlo(c.as_text())
true = 2 * 8 * D * D * INNER * OUTER
assert abs(h.flops - true) / true < 0.01, (h.flops, true)
print("NESTED OK")
""", n_devices=1)
    assert "NESTED OK" in out


def test_async_wrapped_counted_once():
    """Async dialects may re-print the ``calls=`` reference to the wrapped
    computation on the ``-done`` line; propagating both edges doubles the
    inner collective's multiplicity. The census must pin it to exactly one
    execution (regression for the ``_call_edges`` audit)."""
    from repro.launch.hlo_analysis import analyze_hlo, collective_census

    text = """HloModule m

%wrapped_a2a (wp: f32[64]) -> f32[64] {
  %wp = f32[64]{0} parameter(0)
  ROOT %wa = f32[64]{0} all-to-all(f32[64]{0} %wp), replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
}

ENTRY %main (p0: f32[64]) -> f32[64] {
  %p0 = f32[64]{0} parameter(0)
  %a2a-start = ((f32[64]{0}), f32[64]{0}, u32[]) async-start(f32[64]{0} %p0), calls=%wrapped_a2a
  ROOT %a2a-done = f32[64]{0} async-done(((f32[64]{0}), f32[64]{0}, u32[]) %a2a-start), calls=%wrapped_a2a
}
"""
    ops = collective_census(text)
    assert len(ops) == 1, ops
    op = ops[0]
    assert (op.kind, op.bytes, op.mult) == ("all-to-all", 256, 1.0), op
    # the aggregate view must agree: 256 operand bytes, not 512
    assert analyze_hlo(text).coll_bytes == 256.0

"""Per-arch smoke tests (reduced same-family configs) + model invariants.

Each assigned architecture instantiates its SMOKE config and runs one
forward/train step on CPU asserting finite loss and correct shapes, plus a
prefill->decode consistency check for the decodable families.
"""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import (init_train_state, make_batch, make_decode_step,
                          make_prefill_step, make_train_step)
from repro.models.config import applicable_shapes
from repro.optim import AdamWConfig


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    ocfg = AdamWConfig(moment_dtype="float32", warmup_steps=2, total_steps=10)
    params, opt_state = init_train_state(cfg, ocfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, batch=2, seq=32)
    step = jax.jit(make_train_step(cfg, ocfg))
    p2, o2, m = step(params, opt_state, batch)
    assert np.isfinite(float(m["loss"])), arch
    # one more step is finite and params actually changed
    p3, o3, m2 = step(p2, o2, batch)
    assert np.isfinite(float(m2["loss"])), arch
    w0 = jax.tree.leaves(params)[0]
    w1 = jax.tree.leaves(p3)[0]
    assert not np.array_equal(np.asarray(w0), np.asarray(w1))


@pytest.mark.parametrize("arch", [a for a in ARCHS
                                  if not get_config(a).encoder_only])
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    ocfg = AdamWConfig(moment_dtype="float32")
    params, _ = init_train_state(cfg, ocfg, jax.random.PRNGKey(1))
    pre = jax.jit(make_prefill_step(cfg, max_len=48))
    if cfg.family == "vlm":
        batch = make_batch(cfg, 2, 16)
    else:
        batch = {"tokens": make_batch(cfg, 2, 16)["tokens"]}
    logits, state = pre(params, batch)
    assert logits.shape == (2, cfg.vocab)
    dec = jax.jit(make_decode_step(cfg))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for pos in (16, 17, 18):
        lg, state = dec(params, state, tok, jnp.asarray(pos, jnp.int32))
        assert lg.shape == (2, cfg.vocab)
        assert np.isfinite(np.asarray(lg)).all(), (arch, pos)
        tok = jnp.argmax(lg, -1).astype(jnp.int32)


def test_full_configs_match_pool_spec():
    """The full configs carry the exact pool hyperparameters."""
    spec = {
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "qwen2.5-32b": (64, 5120, 40, 8, 27648, 152064),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
    }
    for arch, (L, d, H, kv, ff, V) in spec.items():
        c = get_config(arch)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) \
            == (L, d, H, kv, ff, V), arch
    assert get_config("granite-moe-3b-a800m").n_experts == 40
    assert get_config("granite-moe-3b-a800m").top_k == 8
    assert get_config("arctic-480b").n_experts == 128
    assert get_config("arctic-480b").top_k == 2
    assert get_config("arctic-480b").dense_residual
    assert get_config("hymba-1.5b").ssm_state == 16
    assert get_config("qwen3-0.6b").qk_norm
    assert get_config("qwen2.5-32b").qkv_bias
    assert get_config("nemotron-4-15b").activation == "squared_relu"


def test_shape_skips_per_design():
    """Skip matrix matches DESIGN.md §Arch-applicability (40 cells total)."""
    n_run, n_skip = 0, 0
    for arch in ARCHS:
        cfg = get_config(arch)
        for name, cell in applicable_shapes(cfg).items():
            if cell is None:
                n_skip += 1
            else:
                n_run += 1
    assert n_run + n_skip == 40
    assert n_run == 31  # 7 full-attn skip long_500k; hubert skips 2
    assert applicable_shapes(get_config("rwkv6-1.6b"))["long_500k"] is not None
    assert applicable_shapes(get_config("hymba-1.5b"))["long_500k"] is not None
    assert applicable_shapes(get_config("hubert-xlarge"))["decode_32k"] is None


def test_sliding_window_ring_cache_consistency():
    """Hymba decode across a window boundary == full forward (ring buffer
    wraps correctly)."""
    from repro.models.transformer import backbone, embed_batch, lm_head_table

    cfg = get_smoke_config("hymba-1.5b")  # window 16, layers (0,2) global
    ocfg = AdamWConfig(moment_dtype="float32")
    params, _ = init_train_state(cfg, ocfg, jax.random.PRNGKey(2))
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (1, 20), 0, cfg.vocab))
    pre = jax.jit(make_prefill_step(cfg, max_len=64))
    logits, state = pre(params, {"tokens": jnp.asarray(toks)})
    dec = jax.jit(make_decode_step(cfg))
    seq = toks.copy()
    for pos in range(20, 26):  # crosses the 16-token window repeatedly
        nxt = np.asarray([[pos % cfg.vocab]])
        lg, state = dec(params, state, jnp.asarray(nxt[:, 0], jnp.int32),
                        jnp.asarray(pos, jnp.int32))
        seq = np.concatenate([seq, nxt], axis=1)
        x, p_, _, _ = embed_batch(params, cfg, {"tokens": jnp.asarray(seq)})
        h, _ = backbone(params, cfg, x, p_)
        full = np.asarray(h[:, -1] @ lm_head_table(params, cfg).T)
        np.testing.assert_allclose(np.asarray(lg), full, rtol=2e-3, atol=2e-3)


def test_moe_dispatch_matches_dense_reference():
    """Capacity-dispatch MoE == per-token dense expert evaluation when no
    tokens are dropped."""
    from repro.models import moe as moe_mod
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=1, d_ff=8, vocab=32,
                      n_experts=4, top_k=2, dtype="float32")
    p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 16))
    y, aux = moe_mod.apply_moe(p, cfg, x, capacity_factor=8.0)  # no drops
    # reference: dense evaluation of the top-k experts per token
    y_ref = np.stack([
        np.asarray(moe_mod.apply_moe_decode(p, cfg, x[:, i:i + 1]))[:, 0]
        for i in range(6)
    ], axis=1)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    assert float(aux) >= 0.0


def test_n_params_estimates():
    """Config param counts are in the right ballpark for the named sizes."""
    assert 6.0e10 < get_config("deepseek-67b").n_params() < 7.5e10
    assert 4.0e11 < get_config("arctic-480b").n_params() < 5.6e11
    a = get_config("arctic-480b")
    assert a.n_active_params() < 0.1 * a.n_params()  # top-2 of 128
    assert 1.0e9 < get_config("rwkv6-1.6b").n_params() < 2.2e9

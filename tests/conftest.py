"""Shared test utilities.

Tests in this process see the default single CPU device (the dry-run's
512-device override is process-local to dryrun.py). Distributed behaviour
is tested through subprocesses (run_distributed) so each gets its own
XLA_FLAGS device count.
"""
import os
import subprocess
import sys

import pytest

import jax

jax.config.update("jax_enable_x64", True)  # f64 kernel sweeps + FD residuals

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def run_distributed(script: str, n_devices: int = 8, timeout: int = 900,
                    x64: bool = True) -> str:
    """Run ``script`` in a subprocess with n fake CPU devices; returns stdout.
    Raises on nonzero exit."""
    pre = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count={n_devices}'\n"
        "import jax\n"
        + ("jax.config.update('jax_enable_x64', True)\n" if x64 else "")
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", pre + script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(
            f"distributed subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
        )
    return r.stdout


@pytest.fixture(scope="session")
def rng():
    import numpy as np

    return np.random.default_rng(0)

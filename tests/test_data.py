"""Data pipeline: determinism, restartability, shape contracts."""
import numpy as np

from repro.configs import get_smoke_config
from repro.data import DataConfig, TokenPipeline


def test_deterministic_and_restartable():
    cfg = get_smoke_config("qwen3-0.6b")
    p1 = TokenPipeline(cfg, DataConfig(seed=7))
    p2 = TokenPipeline(cfg, DataConfig(seed=7))
    b1 = p1.batch(12, 4, 32)
    b2 = p2.batch(12, 4, 32)  # fresh pipeline, same index -> same batch
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = p1.batch(13, 4, 32)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = get_smoke_config("qwen3-0.6b")
    b = TokenPipeline(cfg).batch(0, 2, 16)
    t = np.asarray(b["tokens"])
    l = np.asarray(b["labels"])
    np.testing.assert_array_equal(l[:, :-1], t[:, 1:])
    assert (l[:, -1] == -1).all()


def test_family_contracts():
    for arch in ("hubert-xlarge", "internvl2-1b", "rwkv6-1.6b"):
        cfg = get_smoke_config(arch)
        b = TokenPipeline(cfg).batch(0, 2, 24)
        if cfg.family == "audio":
            assert b["features"].shape == (2, 24, cfg.frontend_dim)
            assert b["mask"].shape == (2, 24)
        elif cfg.family == "vlm":
            npfx = b["patches"].shape[1]
            assert b["tokens"].shape[1] + npfx == 24
        else:
            assert b["tokens"].shape == (2, 24)
            assert int(np.asarray(b["tokens"]).max()) < cfg.vocab


def test_structure_learnable():
    """The injected n-gram structure gives sub-uniform entropy (so training
    losses in the examples can actually fall below log V)."""
    cfg = get_smoke_config("qwen3-0.6b")
    pipe = TokenPipeline(cfg)
    b = pipe.batch(0, 8, 256)
    toks = np.asarray(b["tokens"])
    # successor statistics: P(next == succ[cur]) well above chance
    cur = toks[:, :-1].reshape(-1)
    nxt = toks[:, 1:].reshape(-1)
    hit = (pipe.succ[cur] == nxt).mean()
    assert hit > 0.2  # chance level would be ~1/V

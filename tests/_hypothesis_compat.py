"""Offline stand-in for the subset of `hypothesis` this test suite uses.

The container has no network access and `hypothesis` is not baked in, so a
hard import aborts collection of five tier-1 modules. When the real
library is available it is re-exported unchanged; otherwise `given` /
`settings` / `strategies` are backed by a *deterministic* example
sequence: every strategy first yields its boundary values (min, then max)
and then seeded pseudo-random draws, so each `@given` test runs
`max_examples` fixed cases. This keeps the property-style tests meaningful
(boundaries + a spread of interior points) and exactly reproducible.

Usage in test modules:

    from tests._hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:  # prefer the real thing when the environment has it
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A draw rule: example(i, rng) -> value. i==0/1 hit boundaries."""

        def __init__(self, fn):
            self._fn = fn

        def example(self, i: int, rng: random.Random):
            return self._fn(i, rng)

    class st:  # noqa: N801 - mimics `strategies as st`
        @staticmethod
        def floats(min_value, max_value, **_kw):
            def draw(i, rng):
                if i == 0:
                    return min_value
                if i == 1:
                    return max_value
                return rng.uniform(min_value, max_value)

            return _Strategy(draw)

        @staticmethod
        def integers(min_value, max_value, **_kw):
            def draw(i, rng):
                if i == 0:
                    return min_value
                if i == 1:
                    return max_value
                return rng.randint(min_value, max_value)

            return _Strategy(draw)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)

            def draw(i, rng):
                if i < len(elements):
                    return elements[i]
                return elements[rng.randrange(len(elements))]

            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique=False, **_kw):
            def draw(i, rng):
                n = min_size if i == 0 else (max_size if i == 1
                                             else rng.randint(min_size, max_size))
                out = []
                attempts = 0
                while len(out) < n and attempts < 100 * max(n, 1):
                    v = elements.example(2 + attempts, rng)
                    attempts += 1
                    if unique and v in out:
                        continue
                    out.append(v)
                return out

            return _Strategy(draw)

    def settings(max_examples: int = 10, **_kw):
        """Records max_examples on the test for `given` to pick up."""

        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        """Run the test over a fixed grid of examples per strategy kwargs."""

        def deco(fn):
            n = getattr(fn, "_compat_max_examples", 10)

            def wrapper(*args, **kwargs):
                for i in range(n):
                    rng = random.Random(f"{fn.__module__}.{fn.__name__}:{i}")
                    drawn = {k: s.example(i, rng) for k, s in strats.items()}
                    try:
                        fn(*args, **drawn, **kwargs)
                    except Exception:
                        print(f"falsifying example ({fn.__name__}, case {i}): "
                              f"{drawn}")
                        raise

            # keep a zero-arg signature for pytest (no __wrapped__: pytest
            # would otherwise resolve the strategy kwargs as fixtures)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            for key, val in fn.__dict__.items():
                if key != "_compat_max_examples":
                    wrapper.__dict__[key] = val
            return wrapper

        return deco

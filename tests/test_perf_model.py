"""Analytic performance model (paper Eqs. 11-23)."""
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import perf_model as pm
from repro.core.metrics import chi_metrics
from repro.matrices import Hubbard


def test_eq12_limits():
    """T decreases with N_p only when chi stays flat; chi growth breaks
    scaling (the paper's central claim, Fig. 4)."""
    m = pm.MEGGIE
    base = dict(D=10_000_000, n_b=64, n_nzr=14.0, S_d=8)
    t1 = pm.cheb_iter_time(m, N_p=1, chi=0.0, **base)
    t16_nochi = pm.cheb_iter_time(m, N_p=16, chi=0.0, **base)
    assert t16_nochi == pytest.approx(t1 / 16)
    t16 = pm.cheb_iter_time(m, N_p=16, chi=3.37, **base)
    eff = t1 / (16 * t16)
    assert eff < 0.5  # communication destroys parallel efficiency
    bound = pm.parallel_efficiency_bound(m, 3.37)
    assert eff < bound + 0.15


@given(chi_P=st.floats(0.1, 8.0), frac=st.floats(0.05, 0.95))
@settings(max_examples=30, deadline=None)
def test_speedup_and_amortization_consistency(chi_P, frac):
    m = pm.MEGGIE
    chi_panel = chi_P * frac
    s = pm.panel_speedup(m, chi_P, chi_panel)
    assert s >= 1.0
    r = pm.redistribution_factor(m, N_col=8, chi_panel=chi_panel)
    n_star = pm.break_even_degree(s, r)
    if np.isfinite(n_star) and n_star >= 1:
        # S(n*) == 1 by construction (Eq. 19/20)
        assert pm.amortized_speedup(s, r, n_star) == pytest.approx(1.0, rel=1e-9)
        assert pm.amortized_speedup(s, r, 10 * n_star) > 1.0
    # asymptote: S -> s
    assert pm.amortized_speedup(s, r, 10_000 * max(r, 1)) == pytest.approx(s, rel=0.01)


def test_pillar_condition_eq23():
    assert pm.pillar_condition(2.0) == 1.0  # chi >= 2 -> any n >= 1 pays off
    assert pm.pillar_condition(0.5) == 4.0


def test_hubbard_pillar_always_wins_at_16():
    """Paper: 'For the Hubbard matrices this is the case already for
    P >= 16' — chi[16] >= 2."""
    chi16 = chi_metrics(Hubbard(14, 7), 16).chi1
    assert chi16 >= 2.0
    assert pm.pillar_condition(chi16) <= 1.0


def test_table3_hubbard14_speedup_structure():
    """Qualitative reproduction of Table 3 (Hubbard14, P=32): the measured
    pillar speedup s=4.98 with kappa*bc/bm fit; our model with the exact
    chi values lands in the same regime and the break-even degree is
    small (paper: n*=2)."""
    m = pm.MachineModel("meggie-fit", b_m=53.3e9, b_c=2.82e9, kappa=10.0)
    chi32 = chi_metrics(Hubbard(14, 7), 32).chi1
    s_pillar = pm.panel_speedup(m, chi32, 0.0)  # chi[1] = 0
    assert 3.0 < s_pillar < 10.0
    r = pm.redistribution_factor(m, 32, 0.0)
    assert pm.break_even_degree(s_pillar, r) < 6


def test_tpu_regime_matches_cluster_regime():
    """b_m/b_c ratio on v5e (~16) is in the paper's 15-20 cluster range, so
    the chi thresholds transfer (DESIGN.md hardware adaptation)."""
    assert 10 < pm.TPU_V5E.b_m / pm.TPU_V5E.b_c < 20

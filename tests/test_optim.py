"""AdamW (+ quantized moments) and gradient compression."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from tests._hypothesis_compat import given, settings, st

from repro.optim import adamw


def _toy_params(key):
    k1, k2 = jax.random.split(key)
    return {"w": jax.random.normal(k1, (64, 32)), "b": jax.random.normal(k2, (32,))}


def _run_steps(moment_dtype, n=120):
    cfg = adamw.AdamWConfig(lr=5e-2, moment_dtype=moment_dtype, grad_clip=1e3,
                            warmup_steps=2, total_steps=n, weight_decay=0.0)
    params = _toy_params(jax.random.PRNGKey(0))
    target = jax.tree.map(lambda p: p * 0.0 + 1.0, params)
    state = adamw.init_state(cfg, params)

    @jax.jit
    def step(params, state):
        def loss(p):
            return sum(jnp.sum((a - b) ** 2) for a, b in
                       zip(jax.tree.leaves(p), jax.tree.leaves(target)))

        l, g = jax.value_and_grad(loss)(params)
        params, state, m = adamw.apply_updates(cfg, params, g, state)
        return params, state, l

    for _ in range(n):
        params, state, l = step(params, state)
    return params, float(l)


def test_adamw_descends():
    _, l32 = _run_steps("float32")
    assert l32 < 400.0  # started at ~4100 (sum of squares of N(0,1)-1)


@pytest.mark.parametrize("dt", ["bfloat16", "int8"])
def test_quantized_moments_track_fp32(dt):
    p32, l32 = _run_steps("float32")
    pq, lq = _run_steps(dt)
    # quantized-state training follows the fp32 trajectory and converges
    assert lq < 2.5 * l32 + 50.0, (dt, lq, l32)
    for a, b in zip(jax.tree.leaves(p32), jax.tree.leaves(pq)):
        err = np.abs(np.asarray(a) - np.asarray(b)).max()
        assert err < 0.5, (dt, err)


@given(seed=st.integers(0, 1000), scale=st.floats(1e-6, 1e3))
@settings(max_examples=20, deadline=None)
def test_q8_roundtrip_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(1000) * scale, jnp.float32)
    codes, scales = adamw._q8_encode(x)
    y = adamw._q8_decode(codes, scales, x.shape)
    # block-quantization error <= half step of the block max
    blockmax = np.abs(np.asarray(x)).max()
    assert np.abs(np.asarray(y) - np.asarray(x)).max() <= blockmax / 127.0 + 1e-12


def test_grad_clip_applied():
    cfg = adamw.AdamWConfig(grad_clip=1e-3, moment_dtype="float32")
    params = {"w": jnp.ones((8,))}
    g = {"w": jnp.full((8,), 1e3)}
    st_ = adamw.init_state(cfg, params)
    p2, _, m = adamw.apply_updates(cfg, params, g, st_)
    assert float(m["grad_norm"]) > 1e3  # reported raw norm
    assert np.abs(np.asarray(p2["w"]) - np.asarray(params["w"])).max() < 0.1


def test_cross_pod_compression_error_feedback():
    """int8 cross-pod reduce == exact mean within quantization error, and
    the error-feedback residual carries the difference."""
    out = __import__("tests.conftest", fromlist=["run_distributed"]).run_distributed(
        """
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.optim.compress import make_cross_pod_reduce
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
spec = {"w": P(None, "model")}
red = make_cross_pod_reduce(mesh, spec, enabled=True)
rng = np.random.default_rng(0)
g_global = rng.standard_normal((2, 16, 8)).astype(np.float32)  # per-pod grads
# build a pod-sharded array: dim 0 = pod-dependent value
with mesh:
    garr = jax.device_put(jnp.asarray(g_global.reshape(2*16, 8)),
                          NamedSharding(mesh, P("pod", "model")))
    # reinterpret: each pod holds [16,8] distinct grads
    g = {"w": garr.reshape(2, 16, 8)[0] * 0}  # placeholder shape [16,8]
    # simpler: run shard_map directly via the reduce on a pod-varying array
    def mk(x):
        return jax.device_put(jnp.asarray(x), NamedSharding(mesh, P(None, "model")))
    # emulate pod-varying values with an explicit pod-major concat trick:
    from jax.experimental.shard_map import shard_map
    def podval(_):
        i = jax.lax.axis_index("pod").astype(jnp.float32)
        return jnp.full((16, 8), 1.0 + i)
    pv = shard_map(podval, mesh=mesh, in_specs=(P(),), out_specs=P(), check_rep=False)(jnp.zeros(()))
    g = {"w": pv}
    e = {"w": jnp.zeros((16, 8))}
    (gm, em) = red(g, e)
    gm = np.asarray(jax.device_get(gm["w"]))
    # mean of pods holding 1.0 and 2.0 is 1.5 everywhere
    assert np.allclose(gm, 1.5, atol=2.5/127 + 1e-6), gm[:2,:2]
print("COMPRESS OK")
""", n_devices=8)
    assert "COMPRESS OK" in out

"""Schedule algebra of the compressed halo exchange (ISSUE 4).

Property-style checks of the scheduler axis ``schedule={"cyclic",
"matching"}`` (``spmv.neighbor_schedule``):

  * every decomposition covers each nonzero (sender, receiver) pair
    exactly once, every round is a valid partial permutation, every
    round's pad is exactly its max scheduled pair volume, and
    ``H_matching <= H_cyclic`` always — over a randomized family of
    pair-volume matrices including hot-row/hot-column/hub-like shapes,
  * on the hub-and-spoke HubNet family the matching schedule strictly
    undercuts the cyclic one, HLO-measured collective-permute bytes
    equal the pattern-only ``SpmvCommPlan`` prediction exactly for BOTH
    schedules, and ``--layout auto`` (the planner) picks the matching
    schedule,
  * all twelve engine combinations {a2a, compressed-cyclic,
    compressed-matching} x {plain, overlap} x {kernel off, kernel on}
    agree bit-for-bit on stack, panel, and pillar for SpinChainXXZ,
    RoadNet, and HubNet (kernel-on runs the Pallas tile kernel in
    interpret mode on CPU), including on planned commvol/rcm RowMaps,
  * the round-pipelined compressed overlap body (``pipeline=True``, the
    default) is bit-identical to the unpipelined control
    (``pipeline=False``) — per-row accumulation order is pinned to the
    ELL slot order regardless of how the halo rounds are grouped,
  * ``perf_model.schedule_comm_time`` (the round-sum cost
    T_comm = Σ_r L_r·S_d/b_c) equals the Eq. 12 comm term at the
    engine's effective χ — the two views of the schedule cost cannot
    diverge.
"""
import numpy as np
import pytest

from tests.conftest import run_distributed

from repro.core import perf_model as pm
from repro.core.metrics import chi_metrics
from repro.core.planner import comm_plan, plan_layout
from repro.core.spmv import build_dist_ell, neighbor_schedule
from repro.matrices import HubNet, RoadNet, SpinChainXXZ

HUBNET_SMALL = dict(n=4000, w=2, h=4, m=192, k=4)
ROADNET_SMALL = dict(n=4000, w=2, m=256, k=4)


def _random_pair_counts(rng) -> np.ndarray:
    """One randomized pair-volume matrix: a sparse base plus optional hot
    structure (hot row = hot sender, hot column = hot receiver, hub cycle
    = scattered heavy pairs) — the shapes that separate the schedulers."""
    P = int(rng.integers(2, 11))
    pc = rng.integers(0, 20, size=(P, P))
    pc[rng.random((P, P)) < rng.uniform(0.2, 0.9)] = 0
    kind = rng.integers(0, 4)
    if kind == 1:  # hot sender
        pc[rng.integers(P)] += rng.integers(50, 200, size=P)
    elif kind == 2:  # hot receiver
        pc[:, rng.integers(P)] += rng.integers(50, 200, size=P)
    elif kind == 3 and P > 2:  # hub cycle: heavy pairs, scattered shifts
        order = rng.permutation(P)[: max(3, P // 2)]
        for i in range(len(order)):
            pc[order[i], order[(i + 1) % len(order)]] += int(
                rng.integers(100, 300))
    np.fill_diagonal(pc, 0)
    return pc.astype(np.int64)


def _check_decomposition(pc, perms, round_L):
    """Shared schedule invariants: partial permutations, exact coverage
    of nonzero pairs, pads = per-round max scheduled volume."""
    P = pc.shape[0]
    covered = np.zeros_like(pc)
    for perm, Lk in zip(perms, round_L):
        srcs = [s for s, d in perm]
        dsts = [d for s, d in perm]
        # valid partial permutation: each device at most once per side,
        # all indices in range, no self-sends
        assert len(set(srcs)) == len(srcs), perm
        assert len(set(dsts)) == len(dsts), perm
        assert all(0 <= s < P and 0 <= d < P and s != d for s, d in perm)
        vols = [int(pc[s, d]) for s, d in perm]
        assert Lk > 0
        assert max(vols) == Lk, (perm, Lk)  # pad = round's max pair
        for s, d in perm:
            if pc[s, d]:
                covered[s, d] += 1
    # every nonzero pair moves in exactly one round; empty pairs never
    # force a round of their own (they may ride along in a cyclic perm)
    assert (covered[pc > 0] == 1).all()
    assert (covered[pc == 0] == 0).all()


def test_schedule_algebra_properties():
    """Randomized pair matrices: both decompositions are valid and
    matching never moves more than cyclic; both respect the trivial
    lower bound max(max row sum, max col sum)."""
    rng = np.random.default_rng(42)
    n_nontrivial = 0
    for _ in range(80):
        pc = _random_pair_counts(rng)
        H = {}
        for sched in ("cyclic", "matching"):
            perms, round_L = neighbor_schedule(pc, sched)
            _check_decomposition(pc, perms, round_L)
            H[sched] = sum(round_L)
        assert H["matching"] <= H["cyclic"]
        # any per-round-padded schedule pays at least the busiest
        # device's total send (or receive) volume
        lower = max(pc.sum(axis=1).max(), pc.sum(axis=0).max())
        assert H["matching"] >= lower
        n_nontrivial += H["matching"] < H["cyclic"]
    # the family of random matrices must actually exercise the win
    assert n_nontrivial > 10


def test_neighbor_schedule_rejects_unknown():
    pc = np.zeros((4, 4), dtype=np.int64)
    with pytest.raises(ValueError, match="unknown schedule"):
        neighbor_schedule(pc, "zigzag")
    # the planner validates the axis up front, even when the comm axis
    # excludes the compressed engine entirely
    with pytest.raises(ValueError, match="unknown schedule"):
        plan_layout(SpinChainXXZ(8, 4), 4, n_search=8,
                    comm=("a2a",), schedule=("zigzag",))


def test_matching_packs_compatible_hot_pairs():
    """The textbook case: two heavy pairs at different shifts with
    disjoint endpoints share one matching round, while cyclic pays both
    pads — plus a light shift-2 ring that rides along either way."""
    pc = np.zeros((4, 4), dtype=np.int64)
    pc[0, 1] = 10   # shift 1
    pc[2, 0] = 10   # shift 2 (endpoints disjoint from (0, 1))
    pc[1, 3] = 1    # shift 2
    _, cyc_L = neighbor_schedule(pc, "cyclic")
    mat_perms, mat_L = neighbor_schedule(pc, "matching")
    assert sum(cyc_L) == 20  # shift-1 round (10) + shift-2 round (10)
    assert sum(mat_L) == 10  # ONE round {(0,1),(2,0),(1,3)}, pad 10
    assert mat_perms == (((0, 1), (1, 3), (2, 0)),)


def test_matching_beats_cyclic_on_hubnet():
    """HubNet realizes the schedule-imbalanced regime: corridors on many
    distinct shifts, so H_matching strictly undercuts H_cyclic (win
    ~2x at P = 8) while χ₃/χ₂ > 1.5, and the engine's plan equals the
    pattern-only prediction for both schedules."""
    hub = HubNet(**HUBNET_SMALL)
    chim = chi_metrics(hub, 8)
    assert chim.imbalance > 1.5, chim
    cp = comm_plan(hub, 8)
    H_cyc = cp.moved_entries_per_device("compressed", "cyclic")
    H_mat = cp.moved_entries_per_device("compressed", "matching")
    assert H_mat < H_cyc, (H_mat, H_cyc)
    assert H_cyc / H_mat >= 1.8  # the greedy matching recovers ~h/2 here
    assert H_cyc <= cp.moved_entries_per_device("a2a")
    # engine plan == pattern plan, H included, for both schedulers
    ell = build_dist_ell(hub.build_csr(), 8)
    for sched, H in (("cyclic", H_cyc), ("matching", H_mat)):
        nbr = ell.neighbor_plan(schedule=sched)
        assert (nbr.perms, nbr.round_L) == cp.permute_schedule(sched)
        assert nbr.H == H
    # matching needs strictly fewer rounds than cyclic on this pattern
    assert len(cp.permute_schedule("matching")[0]) \
        < len(cp.permute_schedule("cyclic")[0])


def test_planner_picks_matching_on_hubnet():
    """--layout auto adopts the matching schedule on the hub-and-spoke
    family, at the smoke scale (P = 8) and at the paper-config scale the
    planner benchmark sweeps (P = 32)."""
    plan = plan_layout(HubNet(**HUBNET_SMALL), 8, n_search=16)
    assert plan.best.comm == "compressed", plan.report()
    assert plan.best.schedule == "matching", plan.report()
    full = plan_layout(HubNet(), 32, n_search=64)
    assert full.best.comm == "compressed", full.report()
    assert full.best.schedule == "matching", full.report()
    assert "+mat" in full.best.name


def test_schedule_comm_time_equals_chi_path():
    """perf_model.schedule_comm_time (round-sum T_comm = Σ_r L_r·S_d/b_c)
    equals the Eq. 12 comm term at the engine's effective χ for every
    (family, schedule) — the planner ranking and the round-sum view of
    the same schedule cannot disagree."""
    n_b, m = 8, pm.TPU_V5E
    for fam in (SpinChainXXZ(10, 5), HubNet(**HUBNET_SMALL)):
        cp = comm_plan(fam, 8)
        for sched in ("cyclic", "matching"):
            round_L = cp.permute_schedule(sched)[1]
            t_round = pm.schedule_comm_time(m, round_L, n_b=n_b,
                                            S_d=fam.S_d)
            chi_eng = pm.engine_chi(
                cp.moved_entries_per_device("compressed", sched),
                fam.D, 8)
            kw = dict(D=fam.D, N_p=8, n_b=n_b, n_nzr=13.0, S_d=fam.S_d)
            t_chi = (pm.cheb_iter_time(m, chi=chi_eng, **kw)
                     - pm.cheb_iter_time(m, chi=0.0, **kw))
            assert t_round == pytest.approx(t_chi, rel=1e-12)


def test_twelve_engines_bit_identical_all_layouts():
    """{a2a, compressed-cyclic, compressed-matching} x {plain, overlap}
    x {kernel off, kernel on} produce bit-for-bit identical SpMV results
    on stack, panel, and pillar for SpinChainXXZ, RoadNet, and HubNet;
    the fused Chebyshev step agrees across schedules too."""
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import HubNet, RoadNet, SpinChainXXZ
from repro.core import (make_solver_mesh, panel, pillar, build_dist_ell,
                        make_spmv, Layout)
from repro.core.spmv import make_fused_cheb_step
mesh = make_solver_mesh(4, 2)
rng = np.random.default_rng(0)
ENGINES = [(c, s, o, k) for c, s in (("a2a", "cyclic"),
                                     ("compressed", "cyclic"),
                                     ("compressed", "matching"))
           for o in (False, True) for k in (False, True)]
for mat in (SpinChainXXZ(10, 5), RoadNet(**{ROADNET_SMALL!r}),
            HubNet(**{HUBNET_SMALL!r})):
    csr = mat.build_csr()
    D = csr.shape[0]
    D_pad = -(-D // 8) * 8
    for lay, P_row in ((panel(mesh), 4),
                       (Layout("stack", ("row", "col"), ()), 8),
                       (pillar(mesh), 1)):
        ell = build_dist_ell(csr, P_row, d_pad=D_pad, split_halo=True)
        X = np.zeros((D_pad, 8)); X[:D] = rng.standard_normal((D, 8))
        with mesh:
            Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
            Y = {{eng: np.asarray(make_spmv(mesh, lay, ell, comm=eng[0],
                                            schedule=eng[1],
                                            overlap=eng[2],
                                            use_kernel=eng[3])(Xs))
                 for eng in ENGINES}}
        ref = Y[("a2a", "cyclic", False, False)]
        assert np.abs(ref[:D] - csr.matvec(X[:D])).max() < 1e-11
        for eng, got in Y.items():
            assert np.array_equal(got, ref), (mat.name, lay.name, eng)
        print(f"{{mat.name}} {{lay.name}} ok")
    # fused Chebyshev step across the schedule axis (panel layout)
    lay = panel(mesh)
    ell = build_dist_ell(csr, 4, d_pad=D_pad, split_halo=True)
    W1 = np.zeros((D_pad, 4)); W1[:D] = rng.standard_normal((D, 4))
    W2 = np.zeros((D_pad, 4)); W2[:D] = rng.standard_normal((D, 4))
    with mesh:
        sh = lay.vec_sharding(mesh)
        w1 = jax.device_put(jnp.asarray(W1), sh)
        w2 = jax.device_put(jnp.asarray(W2), sh)
        F = {{eng: np.asarray(make_fused_cheb_step(
                 mesh, lay, ell, comm=eng[0], schedule=eng[1],
                 overlap=eng[2], use_kernel=eng[3])(w1, w2, 0.7, -0.2))
             for eng in ENGINES}}
        for o in (False, True):
            ref = F[("a2a", "cyclic", o, False)]
            for s in ("cyclic", "matching"):
                for k in (False, True):
                    assert np.array_equal(F[("compressed", s, o, k)],
                                          ref), (s, o, k)
            assert np.array_equal(F[("a2a", "cyclic", o, True)], ref), o
        assert np.abs(F[("a2a", "cyclic", True, False)]
                      - F[("a2a", "cyclic", False, False)]).max() < 1e-12
    print(f"{{mat.name}} fused ok")
print("TWELVE ENGINE GRID OK")
""", timeout=1500)
    assert "TWELVE ENGINE GRID OK" in out


def test_twelve_engines_bit_identical_on_planned_partitions():
    """The twelve-engine grid (incl. the kernelized engines) stays
    bit-for-bit identical on planned (commvol / rcm) partitions of the
    hub-and-spoke family, and the HLO permute bytes still equal the
    pattern-only prediction of the planned map for both schedulers."""
    from repro.core.partition import plan_rowmap

    hub = HubNet(**HUBNET_SMALL)
    preds = {}
    for ro in ("rcm",):
        rm = plan_rowmap(hub, 4, balance="commvol", reorder=ro)
        cp = comm_plan(hub, 4, rowmap=rm)
        preds[ro] = {s: cp.permute_bytes_per_device(4, 8, s)
                     for s in ("cyclic", "matching")}
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import HubNet
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.core.partition import plan_rowmap
from repro.launch.hlo_analysis import analyze_hlo
preds = {preds!r}
hub = HubNet(**{HUBNET_SMALL!r})
csr = hub.build_csr()
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
rng = np.random.default_rng(0)
X0 = rng.standard_normal((hub.D, 8))
ref = csr.matvec(X0)
ENGINES = [(c, s, o, k) for c, s in (("a2a", "cyclic"),
                                     ("compressed", "cyclic"),
                                     ("compressed", "matching"))
           for o in (False, True) for k in (False, True)]
for ro in ("rcm",):
    rm = plan_rowmap(hub, 4, balance="commvol", reorder=ro)
    ell = build_dist_ell(csr, 4, rowmap=rm, split_halo=True)
    Xp = rm.embed(X0)
    with mesh:
        sh = lay.vec_sharding(mesh)
        Xs = jax.device_put(jnp.asarray(Xp), sh)
        Y = {{}}
        for c, s, o, k in ENGINES:
            f = jax.jit(make_spmv(mesh, lay, ell, comm=c, schedule=s,
                                  overlap=o, use_kernel=k))
            comp = f.lower(Xs).compile()
            h = analyze_hlo(comp.as_text())
            if c == "compressed" and not o:
                # the kernelized engine emits the identical exchange
                assert int(h.coll_breakdown["collective-permute"]) \
                    == preds[ro][s], (ro, s, k, h.coll_breakdown)
            Y[(c, s, o, k)] = np.asarray(f(Xs))
    base = Y[("a2a", "cyclic", False, False)]
    assert np.abs(rm.extract(base) - ref).max() < 1e-11, ro
    for key, y in Y.items():
        assert np.array_equal(y, base), (ro, key)
    print(f"planned {{ro}} ok")
print("TWELVE ENGINES PLANNED OK")
""", timeout=1500)
    assert "TWELVE ENGINES PLANNED OK" in out


def test_pipeline_matches_unpipelined_accumulation_order():
    """The round-pipelined compressed overlap body (the default,
    ``pipeline=True``) is bit-identical to the unpipelined control body
    (``pipeline=False``) for both schedulers, kernel off and on — the
    per-row addition chain is pinned to the ELL slot order no matter how
    the halo rows are grouped into round sub-blocks. Also asserts the
    pipelined path is actually taken (``halo_rounds`` built, >= 2
    rounds), so the comparison can never silently degenerate."""
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import SpinChainXXZ
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
mat = SpinChainXXZ(10, 5)
csr = mat.build_csr()
D = csr.shape[0]
D_pad = -(-D // 8) * 8
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
ell = build_dist_ell(csr, 4, d_pad=D_pad, split_halo=True)
rng = np.random.default_rng(0)
X = np.zeros((D_pad, 8)); X[:D] = rng.standard_normal((D, 8))
for sched in ("cyclic", "matching"):
    nbr = ell.neighbor_plan(split_halo=True, schedule=sched)
    assert nbr.halo_rounds is not None, sched
    assert len(nbr.halo_rounds) >= 2, sched
    with mesh:
        Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
        for k in (False, True):
            y_pipe = np.asarray(make_spmv(
                mesh, lay, ell, comm="compressed", schedule=sched,
                overlap=True, use_kernel=k)(Xs))
            y_flat = np.asarray(make_spmv(
                mesh, lay, ell, comm="compressed", schedule=sched,
                overlap=True, use_kernel=k, pipeline=False)(Xs))
            assert np.array_equal(y_pipe, y_flat), (sched, k)
    print(f"{{sched}} pipelined == unpipelined")
print("PIPELINE ORDER OK")
""")
    assert "PIPELINE ORDER OK" in out


def test_fused_dia_kernel_dispatch_bit_identical():
    """On the comm-free pillar layout the kernelized fused step
    dispatches the whole three-term recurrence to the ``cheb_dia`` DIA
    kernel (``plan_dia`` finds a diagonal form of the SpinChain local
    block) and stays bit-identical to the jnp fused step; the composed
    spmv-then-axpy path agrees to roundoff."""
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import SpinChainXXZ
from repro.core import make_solver_mesh, pillar, build_dist_ell
from repro.core.spmv import make_fused_cheb_step
from repro.kernels import ops
mat = SpinChainXXZ(10, 5)
csr = mat.build_csr()
D = csr.shape[0]
D_pad = -(-D // 8) * 8
mesh = make_solver_mesh(4, 2)
lay = pillar(mesh)
ell = build_dist_ell(csr, 1, d_pad=D_pad, split_halo=True)
# the pillar local block really is diagonal-structured: plan_dia accepts
dia = ops.plan_dia(ell.cols, ell.vals, ell.R)
assert dia is not None
assert len(dia.offsets) <= ops.DIA_MAX_DIAGS
rng = np.random.default_rng(0)
W1 = np.zeros((D_pad, 8)); W1[:D] = rng.standard_normal((D, 8))
W2 = np.zeros((D_pad, 8)); W2[:D] = rng.standard_normal((D, 8))
with mesh:
    sh = lay.vec_sharding(mesh)
    w1 = jax.device_put(jnp.asarray(W1), sh)
    w2 = jax.device_put(jnp.asarray(W2), sh)
    y_jnp = np.asarray(make_fused_cheb_step(mesh, lay, ell)(
        w1, w2, 0.7, -0.2))
    y_krn = np.asarray(make_fused_cheb_step(mesh, lay, ell,
                                            use_kernel=True)(
        w1, w2, 0.7, -0.2))
assert np.array_equal(y_jnp, y_krn)
ref = 2 * 0.7 * csr.matvec(W1[:D]) + 2 * (-0.2) * W1[:D] - W2[:D]
assert np.abs(y_jnp[:D] - ref).max() < 1e-11
print("FUSED DIA OK", len(dia.offsets), "diagonals")
""")
    assert "FUSED DIA OK" in out


def test_matching_hlo_bytes_below_cyclic_on_hubnet():
    """Acceptance: on the hub-and-spoke family the HLO-measured
    collective-permute bytes under schedule='matching' equal the
    pattern-only SpmvCommPlan prediction exactly and are strictly below
    the cyclic schedule's (which are below the padded a2a's)."""
    hub = HubNet(**HUBNET_SMALL)
    D_pad = -(-hub.D // 8) * 8
    cp = comm_plan(hub, 4, d_pad=D_pad)
    pred = {"a2a": (cp.a2a_bytes_per_device(4, 8), 0)}
    for sched in ("cyclic", "matching"):
        pred[sched] = (0, cp.permute_bytes_per_device(4, 8, sched))
    assert pred["matching"][1] < pred["cyclic"][1]
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import HubNet
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.launch.hlo_analysis import analyze_hlo
preds = {pred!r}
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
csr = HubNet(**{HUBNET_SMALL!r}).build_csr()
D_pad = -(-csr.shape[0] // 8) * 8
ell = build_dist_ell(csr, 4, d_pad=D_pad)
x = jax.ShapeDtypeStruct((D_pad, 8), jnp.float64)
with mesh:
    sh = jax.NamedSharding(mesh, lay.vec_pspec())
    for key, comm, sched in (("a2a", "a2a", "cyclic"),
                             ("cyclic", "compressed", "cyclic"),
                             ("matching", "compressed", "matching")):
        c = jax.jit(make_spmv(mesh, lay, ell, comm=comm, schedule=sched),
                    in_shardings=(sh,), out_shardings=sh
                    ).lower(x).compile()
        h = analyze_hlo(c.as_text())
        meas = (int(h.coll_breakdown["all-to-all"]),
                int(h.coll_breakdown["collective-permute"]))
        assert meas == tuple(preds[key]), (key, meas, preds[key])
        print(key, "ok", meas)
print("HLO SCHEDULE BYTES MATCH")
""")
    assert "HLO SCHEDULE BYTES MATCH" in out


@pytest.mark.slow
def test_fd_solve_matching_hubnet_8dev():
    """Full FD solve on the HubNet smoke instance: layout='auto' adopts
    the matching schedule on the mesh, converges to the dense-eigh
    spectrum, and walks the identical iteration path as the explicit
    cyclic engine (numerics-neutrality of the schedule axis)."""
    out = run_distributed(f"""
import numpy as np, jax
from repro.core import FDConfig, FilterDiag, make_solver_mesh
from repro.matrices import HubNet
mat = HubNet(**{HUBNET_SMALL!r})
csr = mat.build_csr()
w = np.linalg.eigvalsh(csr.to_dense())
tau = float(w[len(w) // 2])
mesh = make_solver_mesh(4, 2)
res = {{}}
for label, cfg in (
    ("cyclic", FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8,
                        max_iters=25, spmv_comm="compressed",
                        spmv_schedule="cyclic")),
    ("auto", FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8,
                      max_iters=25, layout="auto")),
):
    with mesh:
        fdd = FilterDiag(csr, mesh, cfg)
        if label == "auto":
            assert fdd.cfg.spmv_comm == "compressed", fdd.plan.report()
            assert fdd.cfg.spmv_schedule == "matching", fdd.plan.report()
        res[label] = fdd.solve()
    assert res[label].n_converged >= 4, (label, res[label].n_converged)
    for ev in res[label].eigenvalues[:4]:
        assert np.abs(w - ev).min() < 1e-7
print("FD MATCHING OK", res["auto"].iterations)
""", timeout=1500)
    assert "FD MATCHING OK" in out

"""Checkpointing: roundtrip, atomic commit, elastic restore, GC."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.checkpoint.checkpoint import latest_step


def _tree(key):
    a, b = jax.random.split(key)
    return {"layer": {"w": jax.random.normal(a, (16, 8)),
                      "b": jax.random.normal(b, (8,))},
            "step_count": jnp.asarray(7)}


def test_roundtrip(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(str(tmp_path), 5, t, extra={"pipeline_index": 5})
    t2, step, extra = restore(str(tmp_path), t)
    assert step == 5 and extra["pipeline_index"] == 5
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uncommitted_ignored(tmp_path):
    t = _tree(jax.random.PRNGKey(0))
    save(str(tmp_path), 1, t)
    save(str(tmp_path), 2, t)
    # corrupt step 2: remove the commit marker (simulates mid-write crash)
    os.remove(tmp_path / "step_00000002" / "_COMMITTED")
    assert latest_step(str(tmp_path)) == 1
    _, step, _ = restore(str(tmp_path), t)
    assert step == 1


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore(str(tmp_path), {"x": jnp.zeros(3)})


def test_manager_interval_and_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), interval=2, keep=2)
    t = _tree(jax.random.PRNGKey(1))
    saved = [i for i in range(10) if m.maybe_save(i, t)]
    assert saved == [0, 2, 4, 6, 8]
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_00000006", "step_00000008"]


def test_elastic_restore_across_meshes(tmp_path):
    """Save on a 2x4 mesh, restore onto 4x2 and 8x1 — logical arrays equal."""
    from tests.conftest import run_distributed

    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.checkpoint import save, restore
tree = {{"w": jnp.arange(64.0).reshape(8, 8)}}
specs = {{"w": P("data", "model")}}
mesh1 = jax.make_mesh((2, 4), ("data", "model"))
with mesh1:
    sharded = jax.device_put(tree["w"], NamedSharding(mesh1, specs["w"]))
    save(r"{tmp_path}", 3, {{"w": sharded}}, specs=specs)
for shape in [(4, 2), (8, 1), (1, 8)]:
    mesh2 = jax.make_mesh(shape, ("data", "model"))
    with mesh2:
        t2, step, _ = restore(r"{tmp_path}", tree, mesh=mesh2, specs=specs)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(t2["w"]), np.arange(64.0).reshape(8, 8))
        assert t2["w"].sharding.mesh.shape["data"] == shape[0]
print("ELASTIC OK")
""")
    assert "ELASTIC OK" in out


@pytest.mark.slow
def test_elastic_grow_restore(tmp_path):
    """Regression for the grow direction the old suite never exercised:
    save on a *2-device* mesh, restore onto the full 8-device mesh. The
    manifest must record the saving mesh shape (the elastic-restart
    debugging contract in the module docstring), and the restored array
    must land re-sharded across all 8 devices with identical values."""
    from tests.conftest import run_distributed

    out = run_distributed(f"""
import json, os
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P, NamedSharding
from repro.checkpoint import save, restore
tree = {{"w": jnp.arange(128.0).reshape(16, 8)}}
specs = {{"w": P("rows", None)}}
# an explicit 2-device submesh: the "cluster" before it grew
small = Mesh(np.array(jax.devices()[:2]).reshape(2), ("rows",))
sharded = jax.device_put(tree["w"], NamedSharding(small, specs["w"]))
save(r"{tmp_path}", 11, {{"w": sharded}}, specs=specs)
with open(os.path.join(r"{tmp_path}", "step_00000011", "manifest.json")) as f:
    meta = json.load(f)
assert meta["mesh"] == {{"axes": ["rows"], "shape": [2]}}, meta["mesh"]
big = jax.make_mesh((8,), ("rows",))
t2, step, _ = restore(r"{tmp_path}", tree, mesh=big, specs=specs)
assert step == 11
np.testing.assert_array_equal(np.asarray(t2["w"]), np.arange(128.0).reshape(16, 8))
assert t2["w"].sharding.mesh.shape["rows"] == 8
assert len(t2["w"].sharding.device_set) == 8
print("GROW OK")
""")
    assert "GROW OK" in out

"""χ communication metrics: exactness, paper-table reproduction, invariants."""
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core.metrics import chi_bruteforce, chi_from_nvc, chi_metrics
from repro.matrices import Exciton, Hubbard, SpinChainXXZ, TopIns, uniform_partition


# ---------------------------------------------------------- paper tables --

@pytest.mark.parametrize("Np,chi13,chi2", [
    (2, 0.54, 0.54), (4, 1.51, 1.02), (8, 2.52, 1.53),
    (16, 3.37, 2.07), (32, 4.17, 2.65), (64, 5.58, 3.19),
])
def test_hubbard14_table1(Np, chi13, chi2):
    m = chi_metrics(Hubbard(14, 7), Np)
    assert round(m.chi1, 2) == chi13
    assert round(m.chi3, 2) == chi13
    assert round(m.chi2, 2) == chi2


@pytest.mark.parametrize("Np,chi13,chi2", [
    (2, 0.53, 0.53), (4, 1.50, 1.01), (8, 2.50, 1.51),
    (16, 3.37, 2.03), (32, 4.21, 2.61), (64, 5.67, 3.16),
])
def test_hubbard16_table1(Np, chi13, chi2):
    m = chi_metrics(Hubbard(16, 8), Np)
    assert round(m.chi1, 2) == chi13
    assert round(m.chi2, 2) == chi2


@pytest.mark.parametrize("Np,chi13", [(2, 0.01), (4, 0.05), (8, 0.11)])
def test_exciton75_table1(Np, chi13):
    assert round(chi_metrics(Exciton(L=75), Np).chi1, 2) == chi13


@pytest.mark.parametrize("Np,chi13", [(2, 0.02), (4, 0.08), (8, 0.16), (16, 0.32)])
def test_topins100_table5(Np, chi13):
    assert round(chi_metrics(TopIns(100), Np).chi1, 2) == chi13


def test_spinchain24_table5_small_np():
    m = chi_metrics(SpinChainXXZ(24, 12), 2)
    assert round(m.chi1, 2) == 0.52


# ------------------------------------------------------------- exactness --

@given(n=st.integers(6, 10), P=st.integers(2, 7), seed=st.integers(0, 100))
@settings(max_examples=12, deadline=None)
def test_hubbard_structured_matches_bruteforce(n, P, seed):
    """Tensor-product n_vc == brute-force distinct counting, incl. random
    (non-uniform) boundaries that cut inside spin sectors."""
    k = n // 2
    hub = Hubbard(n, k)
    rng = np.random.default_rng(seed)
    cuts = np.sort(rng.choice(np.arange(1, hub.D), size=P - 1, replace=False))
    boundaries = np.concatenate([[0], cuts, [hub.D]])
    csr = hub.build_csr()
    bf = chi_bruteforce(csr, P, boundaries)
    stv = hub.n_vc(boundaries)
    assert np.array_equal(bf.n_vc, stv)


@pytest.mark.parametrize("fam", [Exciton(L=3), TopIns(5), SpinChainXXZ(10, 5)])
def test_generator_pattern_matches_csr(fam):
    """row_cols streaming == the explicit CSR pattern."""
    csr = fam.build_csr()
    for P in (2, 3, 5):
        bf = chi_bruteforce(csr, P)
        stream = fam.n_vc(uniform_partition(fam.D, P))
        assert np.array_equal(bf.n_vc, stream)


def test_nnzr_formulas():
    e = Exciton(L=10)
    assert abs(e.build_csr().n_nzr - (9 - 6 / e.S)) < 1e-9
    t = TopIns(6)
    assert abs(t.build_csr().n_nzr - (12 - 12 / 6)) < 1e-9
    h = Hubbard(10, 5)
    assert abs(h.build_csr().n_nzr - 10) < 1e-9  # = n_sites at half filling
    s = SpinChainXXZ(12, 6)
    assert abs(s.build_csr().n_nzr - (0.5 * 12 + 1)) < 1e-9


def test_hermitian_patterns():
    for fam in (Exciton(L=2), TopIns(4), Hubbard(6, 3, U=1.0), SpinChainXXZ(8, 4)):
        A = fam.build_csr().to_dense()
        assert np.abs(A - A.conj().T).max() < 1e-12, fam.name


# ------------------------------------------------------------ invariants --

@given(P=st.integers(2, 16))
@settings(max_examples=8, deadline=None)
def test_chi_invariants(P):
    m = chi_metrics(Hubbard(8, 4), P)
    # chi2 <= chi3 (max >= mean), chi1 ~ chi3 for uniform partitions
    assert m.chi2 <= m.chi3 + 1e-12
    assert m.chi3 / max(m.chi1, 1e-12) == pytest.approx(1.0, rel=0.35)
    assert 0 < m.efficiency_bound(0.05) <= 1.0


def test_chi_zero_single_process():
    m = chi_metrics(Hubbard(8, 4), 1)
    assert m.chi1 == m.chi2 == m.chi3 == 0.0

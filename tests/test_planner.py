"""χ-driven layout planner (core/planner.py): pattern-only predictions
match the engine and the compiled HLO, the ranking picks the layouts the
paper predicts, and ``layout="auto"`` is numerics-neutral."""
import numpy as np
import pytest

from tests.conftest import run_distributed

from repro.core import perf_model as pm
from repro.core.metrics import chi_bruteforce
from repro.core.planner import comm_plan, estimate_nnzr, plan_layout
from repro.core.spmv import Partition, build_dist_ell
from repro.matrices import Exciton, Hubbard, SpinChainXXZ


def test_comm_plan_matches_engine():
    """Pattern-only L, n_vc, pair counts, and the compressed neighbor
    schedules (cyclic AND matching rounds) equal build_dist_ell's, for
    families & CSR."""
    for mat, P in ((SpinChainXXZ(10, 5), 4),
                   (Hubbard(8, 4, U=2.0, ranpot=0.5), 8),
                   (Exciton(L=4), 4)):
        csr = mat.build_csr()
        D = csr.shape[0]
        D_pad = -(-D // P) * P
        ell = build_dist_ell(csr, P, d_pad=D_pad)
        for src in (mat, csr):
            cp = comm_plan(src, P, d_pad=D_pad)
            assert cp.exact
            assert cp.L == ell.L, (mat.name, cp.L, ell.L)
            assert (cp.n_vc == ell.n_vc).all()
            assert (cp.pair_counts == np.asarray(ell.pair_counts)).all()
            nb, S_d = 8, ell.vals.dtype.itemsize
            assert cp.a2a_bytes_per_device(nb, S_d) == P * ell.L * nb * S_d
            for sched in ("cyclic", "matching"):
                nbr = ell.neighbor_plan(schedule=sched)
                assert cp.permute_schedule(sched) == (nbr.perms, nbr.round_L)
                assert cp.moved_entries_per_device("compressed", sched) \
                    == nbr.H
                assert cp.permute_bytes_per_device(nb, S_d, sched) \
                    == nbr.H * nb * S_d
                assert cp.permute_bytes_per_device(nb, S_d, sched) <= \
                    cp.a2a_bytes_per_device(nb, S_d)


def test_comm_plan_chi_matches_bruteforce():
    """χ derived from the comm plan equals the reference CSR computation
    on the same (engine) partition boundaries."""
    mat = SpinChainXXZ(10, 5)
    csr = mat.build_csr()
    P = 4
    for d_pad in (None, -(-csr.shape[0] // 8) * 8):  # default & custom pad
        cp = comm_plan(mat, P, d_pad=d_pad)
        bnds = Partition(csr.shape[0], P, d_pad).boundaries()
        ref = chi_bruteforce(csr, P, boundaries=bnds)
        assert cp.chi.chi1 == pytest.approx(ref.chi1)
        assert cp.chi.chi2 == pytest.approx(ref.chi2)
        assert cp.chi.chi3 == pytest.approx(ref.chi3)
    # a precomputed n_vc skips the pattern pass but yields the same chi
    pre = comm_plan(mat, P, n_vc=cp.n_vc,
                    d_pad=-(-csr.shape[0] // 8) * 8)
    assert not pre.exact
    assert pre.chi.chi1 == pytest.approx(cp.chi.chi1)


def test_planner_chi_matches_measured_hlo_volume():
    """The all_to_all volume the planner predicts from the sparsity
    pattern equals the HLO-measured per-chip collective volume of the
    compiled SpMV, bit-for-bit."""
    mat = SpinChainXXZ(10, 5)
    cp = comm_plan(mat, 4, d_pad=-(-mat.D // 8) * 8)
    pred = cp.a2a_bytes_per_device(4, 8)  # panel 4x2, Ns=8 -> n_b = 4, f64
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import SpinChainXXZ
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.launch.hlo_analysis import analyze_hlo
mat = SpinChainXXZ(10, 5)
csr = mat.build_csr()
D = csr.shape[0]
D_pad = -(-D // 8) * 8
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
ell = build_dist_ell(csr, 4, d_pad=D_pad)
x = jax.ShapeDtypeStruct((D_pad, 8), jnp.float64)
with mesh:
    sh = jax.NamedSharding(mesh, lay.vec_pspec())
    c = jax.jit(make_spmv(mesh, lay, ell), in_shardings=(sh,),
                out_shardings=sh).lower(x).compile()
h = analyze_hlo(c.as_text())
assert h.coll_breakdown["all-to-all"] == {pred}, h.coll_breakdown
print("A2A VOLUME MATCHES", h.coll_breakdown["all-to-all"])
""")
    assert "A2A VOLUME MATCHES" in out


def test_planner_picks_pillar_when_it_fits():
    """High-χ matrix (Hubbard: χ[16] > 2, pillar always pays per Eq. 23)
    with n_col = P available -> the comm-free vertical layer wins."""
    mat = Hubbard(8, 4, U=2.0, ranpot=0.5)
    for overlap in ((False,), (False, True)):
        plan = plan_layout(mat, 8, n_search=32, overlap=overlap)
        assert plan.best.layout == "pillar", plan.report()
        assert plan.best.n_row == 1 and plan.best.n_col == 8
        assert plan.best.chi1 == 0.0  # comm-free filter
    assert plan.speedup(plan.best) > 1.5


def test_planner_picks_panel_overlap_when_pillar_excluded():
    """Same high-χ matrix, but n_search not divisible by P so the pillar
    does not fit -> panel with the overlap engine wins, and overlap beats
    the additive candidate of the same split and comm engine."""
    mat = Hubbard(8, 4, U=2.0, ranpot=0.5)
    plan = plan_layout(mat, 8, n_search=12)
    assert all(c.n_col < 8 for c in plan.candidates)
    best = plan.best
    assert best.layout == "panel" and best.overlap, plan.report()
    by_key = {(c.n_row, c.n_col, c.comm, c.overlap, c.balance, c.reorder): c
              for c in plan.candidates}
    add = by_key[(best.n_row, best.n_col, best.comm, False,
                  best.balance, best.reorder)]
    assert best.t_pass < add.t_pass


def test_planner_ranking_is_model_consistent():
    """Candidate times reproduce the perf model fed each comm engine's
    exact wire volume (engine_chi of the comm_plan bytes) — planned
    (balance/reorder) candidates are scored on their own rowmap's
    counts."""
    mat = SpinChainXXZ(10, 5)
    n_nzr = estimate_nnzr(mat)
    plan = plan_layout(mat, 8, n_search=16, degree=50)
    assert plan.degree == 50
    for c in plan.candidates:
        if c.n_row > 1:
            cp = comm_plan(mat, c.n_row, rowmap=c.rowmap)
            moved = cp.moved_entries_per_device(c.comm, c.schedule)
            assert c.chi_eng == pytest.approx(
                pm.engine_chi(moved, mat.D, c.n_row))
            assert c.comm_bytes_per_device == cp.comm_bytes_per_device(
                c.comm, plan.n_search // c.n_col, mat.S_d, c.schedule)
        else:
            assert c.chi_eng == 0.0 and c.comm_bytes_per_device == 0
        kw = dict(D=mat.D, N_p=c.n_row, n_b=plan.n_search // c.n_col,
                  chi=c.chi_eng, n_nzr=n_nzr, S_d=mat.S_d)
        t_ref = (pm.cheb_iter_time_overlap(pm.TPU_V5E, **kw) if c.overlap
                 else pm.cheb_iter_time(pm.TPU_V5E, **kw))
        assert c.t_iter == pytest.approx(t_ref)
        assert c.t_pass == pytest.approx(50 * c.t_iter + 2 * c.t_redist)
        assert c.redistribute == (c.n_col > 1)
        # planned partitions only appear where they can matter, and carry
        # the map they were scored on
        if (c.balance, c.reorder) != ("rows", "none"):
            assert c.rowmap is not None and c.n_row > 1 and c.chi1 > 0
        else:
            assert c.rowmap is None
    # the compressed engine never predicts MORE wire bytes than a2a at
    # the same split AND partition, the matching rounds never more than
    # the cyclic ones, and all engine/partition variants are enumerated
    by_key = {(c.n_row, c.n_col, c.comm, c.schedule, c.overlap,
               c.balance, c.reorder): c for c in plan.candidates}
    assert any(c.comm == "compressed" for c in plan.candidates)
    assert any(c.schedule == "matching" for c in plan.candidates)
    assert any(c.balance == "commvol" for c in plan.candidates)
    assert all(c.schedule == "cyclic" for c in plan.candidates
               if c.comm == "a2a")
    for c in plan.candidates:
        if c.comm == "compressed":
            a2a = by_key[(c.n_row, c.n_col, "a2a", "cyclic", c.overlap,
                          c.balance, c.reorder)]
            assert c.comm_bytes_per_device <= a2a.comm_bytes_per_device
            if c.schedule == "matching":
                cyc = by_key[(c.n_row, c.n_col, "compressed", "cyclic",
                              c.overlap, c.balance, c.reorder)]
                assert c.comm_bytes_per_device <= cyc.comm_bytes_per_device
    # stack pays no redistribution
    stack = [c for c in plan.candidates if c.n_col == 1]
    assert stack and all(c.t_redist == 0.0 for c in stack)


def test_auto_plan_scores_engine_partition():
    """FilterDiag(layout='auto') must score the padded partition the
    engine builds: with D % P != 0 the plan's panel candidate predicts
    exactly the built operator's all_to_all bytes (same d_pad, same L)."""
    out = run_distributed("""
import jax
from repro.core import FDConfig, FilterDiag, make_solver_mesh, build_dist_ell
from repro.matrices import SpinChainXXZ
mat = SpinChainXXZ(12, 6)   # D = 924, not divisible by 8
mesh = make_solver_mesh(4, 2)
cfg = FDConfig(n_target=4, n_search=16, layout="auto")
with mesh:
    fdd = FilterDiag(mat, mesh, cfg)
cands = {(c.comm, c.schedule): c for c in fdd.plan.candidates
         if (c.n_row, c.n_col) == (4, 2) and not c.overlap
         and c.balance == "rows" and c.reorder == "none"}
# the engine operators the (4,2) panel candidates would run: same global
# padding as FilterDiag (d_pad = ceil(D/8)*8), 4 row shards
ell42 = build_dist_ell(mat.build_csr(), 4, d_pad=-(-mat.D // 8) * 8)
engine = ell42.P * ell42.L * (16 // 2) * mat.S_d
assert cands[("a2a", "cyclic")].comm_bytes_per_device == engine, (
    cands[("a2a", "cyclic")].comm_bytes_per_device, engine, ell42.L)
for sched in ("cyclic", "matching"):
    engine_cmp = ell42.neighbor_plan(schedule=sched).H * (16 // 2) * mat.S_d
    got = cands[("compressed", sched)].comm_bytes_per_device
    assert got == engine_cmp, (sched, got, engine_cmp)
print("AUTO PLAN PARTITION OK", engine, engine_cmp)
""")
    assert "AUTO PLAN PARTITION OK" in out


def test_layout_on_mesh_panel_row_axis_rules():
    """Explicitly requested row axes that don't exist fail loudly instead
    of silently degenerating to a pillar-like layout; with no explicit
    request the conventional axis (row > model > first) is used."""
    import jax
    from repro.core.planner import default_row_axes, layout_on_mesh

    mesh = jax.make_mesh((1,), ("x",))
    with pytest.raises(ValueError, match="row axis"):
        layout_on_mesh(mesh, "panel", row_axes=("row",))
    assert default_row_axes(mesh) == ("x",)
    assert layout_on_mesh(mesh, "panel").dist_axes == ("x",)
    mesh2 = jax.make_mesh((1,), ("model",))
    assert default_row_axes(mesh2) == ("model",)


def test_fdconfig_auto_single_device_is_numerics_neutral():
    """layout='auto' on one device degenerates to the stack algorithm and
    reproduces the explicit-layout eigenvalues exactly."""
    import jax
    from repro.core import FDConfig, FilterDiag, make_solver_mesh

    mat = SpinChainXXZ(8, 4)
    csr = mat.build_csr()
    w = np.linalg.eigvalsh(csr.to_dense())
    tau = float(w[len(w) // 2])
    mesh = make_solver_mesh(1, 1)
    res = {}
    for lay in ("panel", "auto"):
        cfg = FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8,
                       max_iters=20, layout=lay)
        with mesh:
            fdd = FilterDiag(csr, mesh, cfg)
            if lay == "auto":
                assert fdd.plan is not None
                assert fdd.plan.best.n_row * fdd.plan.best.n_col == 1
                assert cfg.layout == "auto"  # caller's config untouched
            res[lay] = fdd.solve()
    assert res["auto"].n_converged >= 4
    np.testing.assert_array_equal(res["auto"].eigenvalues,
                                  res["panel"].eigenvalues)


@pytest.mark.slow
def test_solve_layout_auto_roundtrip_8dev():
    """--layout auto end-to-end on an 8-device mesh: the planner picks the
    split, FD converges, and the eigenvalues match dense eigh."""
    out = run_distributed("""
import numpy as np, jax
from repro.core.filter_diag import FDConfig
from repro.launch.solve import solve
from repro.matrices import SpinChainXXZ
csr = SpinChainXXZ(12, 6).build_csr()
w = np.linalg.eigvalsh(csr.to_dense())
tau = float(w[len(w)//2])
fd = FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8, max_iters=25,
              layout="auto")
res = solve("SpinChainXXZ", dict(n_sites=12, n_up=6), fd, 1, 1, verbose=True)
assert fd.layout == "auto"  # caller's config is not mutated by planning
assert res.n_converged >= 4, res.n_converged
for ev in res.eigenvalues[:4]:
    assert np.abs(w - ev).min() < 1e-7
print("AUTO SOLVE OK")
""", timeout=1500)
    assert "[auto] running" in out  # planner resolved the split
    assert "AUTO SOLVE OK" in out

"""Static communication verifier (src/repro/analysis) in the tier-1 loop.

Positive direction: the fast ``scripts/check_comm.py`` gate passes on the
repo as-is (plan lint, overlap checks, census cells, bench schema).
Negative direction: each pass catches its planted defect — a double-sent
neighbor pair (plan lint), a halo collective that depends on the local
contraction (overlap checker), and a spurious all-gather smuggled into a
compiled cell (census). The slow test compiles the full engine grid
(6 engine combos x 3 layouts x 2 balances) for all three bench families.
"""
import json
import os
import subprocess
import sys

import pytest

from tests.conftest import run_distributed

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")
sys.path.insert(0, SRC)


# ------------------------------------------------------------- plan lint --

def test_plan_lint_clean_spinchain():
    from repro.analysis.plan_lint import run_plan_lint
    from repro.matrices import SpinChainXXZ

    assert run_plan_lint(SpinChainXXZ(10, 5), label="spin/") == []


def test_lint_rounds_catches_double_send():
    import numpy as np

    from repro.analysis.plan_lint import lint_rounds

    pc = np.zeros((4, 4), dtype=np.int64)
    pc[0, 1] = 3
    pc[2, 3] = 2
    # pair (0, 1) scheduled twice — would corrupt the engine's contiguous
    # per-round receive-slot layout
    perms = (((0, 1), (2, 3)), ((0, 1),))
    errs = lint_rounds(pc, perms, (3, 3), label="planted")
    assert any("double-sent" in e for e in errs), errs


def test_lint_rounds_catches_invalid_round_and_dropped_pair():
    import numpy as np

    from repro.analysis.plan_lint import lint_rounds

    pc = np.zeros((4, 4), dtype=np.int64)
    pc[0, 1] = pc[2, 1] = pc[1, 0] = 2
    # round 0 sends two sources to device 1 (not a partial permutation)
    # and includes a self-send; pair (1, 0) is never scheduled
    perms = (((0, 1), (2, 1), (3, 3)),)
    errs = lint_rounds(pc, perms, (2,), label="planted")
    assert any("repeats a destination" in e for e in errs), errs
    assert any("self-send" in e for e in errs), errs
    assert any("scheduled in no round" in e for e in errs), errs


# ------------------------------------------------------ census attribution --

def _op(kind, nbytes, mult, name="op"):
    from repro.launch.hlo_analysis import CollectiveOp

    return CollectiveOp(kind=kind, bytes=nbytes, mult=mult, name=name,
                        computation="main")


def test_attribute_flags_spurious_and_missing():
    from repro.analysis.census import ExpectedTerm, attribute

    expected = [ExpectedTerm("halo", "all-to-all", 7680, 6),
                ExpectedTerm("gram", "all-reduce", 512, 1)]
    # exact match passes
    ok = attribute([_op("all-to-all", 7680, 6.0), _op("all-reduce", 512, 1.0)],
                   expected, cell="cell")
    assert ok.ok, ok.errors
    # a spurious all-gather is unattributed; a short halo term is missing
    bad = attribute([_op("all-to-all", 7680, 5.0), _op("all-reduce", 512, 1.0),
                     _op("all-gather", 2048, 1.0, name="all-gather.1")],
                    expected, cell="cell")
    assert not bad.ok
    assert any("unattributed" in e and "all-gather" in e for e in bad.errors)
    assert any("missing collective" in e and "halo" in e for e in bad.errors)


def test_attribute_accepts_alt_bytes():
    from repro.analysis.census import ExpectedTerm, attribute

    # XLA may print the moved subset instead of the full slice — both are
    # admissible for the same term, nothing else is
    term = ExpectedTerm("redist", "all-to-all", 2048, 2, alt_bytes=(1024,))
    assert attribute([_op("all-to-all", 1024, 2.0)], [term]).ok
    assert attribute([_op("all-to-all", 2048, 2.0)], [term]).ok
    assert not attribute([_op("all-to-all", 512, 2.0)], [term]).ok


# ----------------------------------------------------------- bench schema --

def test_schema_accepts_repo_artifact():
    from benchmarks.schema import check_artifact

    path = os.path.join(ROOT, "BENCH_spmv.json")
    if not os.path.exists(path):
        pytest.skip("no BENCH_spmv.json in the repo")
    assert check_artifact(path) == []


def test_schema_rejects_malformed_records():
    from benchmarks.schema import validate_artifact, validate_record

    assert validate_record({"table": "nope", "family": "x"})
    assert any("engine" in e for e in validate_record(
        {"table": "spmv_comm", "family": "x", "engine": "warp"}))
    assert any("nonnegative" in e for e in validate_record(
        {"table": "spmv_comm", "family": "x", "us_per_call": -1.0}))
    assert any("meas_bytes_per_device without" in e for e in validate_record(
        {"table": "spmv_comm", "family": "x", "meas_bytes_per_device": 8}))
    art = {"schema": "bench-spmv/v0", "records": [], "rows": [],
           "benches": ["spmv_comm", "bogus"]}
    errs = validate_artifact(art)
    assert any("schema is" in e for e in errs)
    assert any("bogus" in e for e in errs)


def test_run_refuses_to_write_malformed_artifact(tmp_path):
    """run.py --json must reject a merge that would persist a malformed
    record (records of non-rerun tables survive forever otherwise)."""
    # the malformed record belongs to a table that is NOT rerun, so the
    # merge would keep it — validation must catch it anyway
    bad = {"schema": "bench-spmv/v1", "generated_unix": 0,
           "benches": ["spmv_comm"],
           "records": [{"table": "spmv_comm", "family": "x",
                        "us_per_call": -5.0}],
           "rows": []}
    path = tmp_path / "BENCH_spmv.json"
    path.write_text(json.dumps(bad))
    r = subprocess.run(
        [sys.executable, "-m", "benchmarks.run",
         "--only", "table2", "--json", str(path)],
        capture_output=True, text=True, cwd=ROOT,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=600)
    assert r.returncode == 2, r.stdout + r.stderr
    assert "SCHEMA ERROR" in r.stderr
    # the malformed artifact was not overwritten
    assert json.loads(path.read_text()) == bad


# -------------------------------------------------- overlap checker (jaxpr) --

def test_overlap_checker_positive_and_negative():
    out = run_distributed("""
from repro.analysis.overlap_check import check_split_phase
from repro.core import layouts as lo
from repro.core.planner import layout_on_mesh
from repro.core.spmv import build_dist_ell, make_spmv
from repro.matrices import SpinChainXXZ

matrix = SpinChainXXZ(10, 5)
mesh = lo.make_solver_mesh(4, 2)
panel_l = layout_on_mesh(mesh, "panel")
D_pad = -(-matrix.D // 8) * 8
V = jax.ShapeDtypeStruct((D_pad, 4), jax.numpy.float64)
for overlap in (True, False):
    ell = build_dist_ell(matrix, 4, d_pad=D_pad, split_halo=overlap)
    spmv = make_spmv(mesh, panel_l, ell, overlap=overlap, comm="compressed",
                     schedule="matching")
    with mesh:
        rep = check_split_phase(spmv, V)
    if overlap:
        assert rep.ok, rep.describe()
        assert rep.independent_contractions >= 1
    else:
        # plain engine: the single contraction consumes the received halo
        assert not rep.ok
        assert any("no contraction is independent" in e for e in rep.errors)
print("OVERLAP OK")
""")
    assert "OVERLAP OK" in out


def test_overlap_checker_catches_dependent_halo():
    out = run_distributed("""
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.analysis.overlap_check import check_split_phase
from repro.core import layouts as lo

mesh = lo.make_solver_mesh(4, 2)

# planted defect: the ppermute payload is the *output* of the local scan,
# so the exchange cannot start before local compute finishes
def bad_engine(x):
    def body(c, i):
        return c * 1.0001 + i, None
    y, _ = lax.scan(body, x, jax.numpy.arange(4.0))
    h = lax.ppermute(y, "row", [(i, (i + 1) % 4) for i in range(4)])
    return y + h

fn = shard_map(bad_engine, mesh=mesh, in_specs=P(None, None),
               out_specs=P(None, None), check_rep=False)
x = jax.ShapeDtypeStruct((16, 8), jax.numpy.float64)
with mesh:
    rep = check_split_phase(fn, x)
assert not rep.ok
assert any("depends on contraction" in e for e in rep.errors), rep.errors
print("DEPENDENT HALO CAUGHT")
""")
    assert "DEPENDENT HALO CAUGHT" in out


def test_round_pipeline_prefix_chain_proof():
    """check_round_pipeline proves the pipelined compressed engine's
    prefix-chain property (round-r contraction depends on no later
    round's collective; prefix lengths 0, n, and a strict intermediate
    all witnessed) and rejects both the unpipelined control body
    (pipeline=False — no strict prefix) and a planted out-of-order
    dependence (a contraction consuming round 2 without round 1)."""
    out = run_distributed("""
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.analysis.overlap_check import check_round_pipeline
from repro.core import layouts as lo
from repro.core.planner import layout_on_mesh
from repro.core.spmv import build_dist_ell, make_spmv
from repro.matrices import SpinChainXXZ

matrix = SpinChainXXZ(10, 5)
mesh = lo.make_solver_mesh(4, 2)
panel_l = layout_on_mesh(mesh, "panel")
D_pad = -(-matrix.D // 8) * 8
ell = build_dist_ell(matrix, 4, d_pad=D_pad, split_halo=True)
V = jax.ShapeDtypeStruct((D_pad, 4), jax.numpy.float64)
for use_kernel in (False, True):
    spmv = make_spmv(mesh, panel_l, ell, use_kernel=use_kernel,
                     overlap=True, comm="compressed", schedule="cyclic")
    with mesh:
        rep = check_round_pipeline(spmv, V)
    assert rep.ok, rep.describe()
    assert rep.n_rounds >= 2
    assert 0 in rep.prefix_lengths and rep.n_rounds in rep.prefix_lengths
    assert any(0 < k < rep.n_rounds for k in rep.prefix_lengths)
    # the unpipelined control witnesses only {0, n} and must fail
    flat = make_spmv(mesh, panel_l, ell, use_kernel=use_kernel,
                     overlap=True, comm="compressed", schedule="cyclic",
                     pipeline=False)
    with mesh:
        rep0 = check_round_pipeline(flat, V)
    assert not rep0.ok
    assert rep0.prefix_lengths == [0, rep0.n_rounds]
    assert any("not round-pipelined" in e for e in rep0.errors)

# planted defect: a contraction that consumes round 2's buffer without
# round 1's — the dependence set {c2} is not a prefix of (c1, c2)
def bad_engine(x):
    fwd = [(i, (i + 1) % 4) for i in range(4)]
    h1 = lax.ppermute(x, "row", fwd)
    h2 = lax.ppermute(x, "row", [(i, (i + 2) % 4) for i in range(4)])
    def body(c, w):
        return c + w * h2, None
    y, _ = lax.scan(body, x * 0.5, jnp.arange(3.0))
    return y + h1

fn = shard_map(bad_engine, mesh=mesh, in_specs=P(None, None),
               out_specs=P(None, None), check_rep=False)
x = jax.ShapeDtypeStruct((16, 8), jax.numpy.float64)
with mesh:
    bad = check_round_pipeline(fn, x)
assert not bad.ok
assert any("not a prefix" in e for e in bad.errors), bad.errors
print("PIPELINE PROOF OK")
""")
    assert "PIPELINE PROOF OK" in out


# --------------------------------------------------------- census (compile) --

def test_census_catches_spurious_allgather():
    out = run_distributed("""
from repro.analysis.census import run_census_cell
from repro.matrices import SpinChainXXZ

def wrap(iteration, mesh, stack_l):
    from jax import lax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    axes = stack_l.dist_axes
    def mutated(V):
        Vs, G = iteration(V)
        # planted defect: a resharding all-gather the comm plan never
        # predicted (kept live so XLA cannot elide it)
        gath = shard_map(lambda x: lax.all_gather(x, axes, tiled=True),
                        mesh=mesh, in_specs=P(axes, None),
                        out_specs=P(None, None), check_rep=False)(Vs)
        return Vs + 0.0 * gath[: Vs.shape[0]], G
    return mutated

rep = run_census_cell(SpinChainXXZ(10, 5), P_total=8, comm="a2a", wrap=wrap)
assert not rep.ok, rep.describe()
assert any("unattributed" in e and "all-gather" in e for e in rep.errors), \\
    rep.errors
# the clean cell still passes
clean = run_census_cell(SpinChainXXZ(10, 5), P_total=8, comm="a2a")
assert clean.ok, clean.describe()
print("SPURIOUS ALLGATHER CAUGHT")
""")
    assert "SPURIOUS ALLGATHER CAUGHT" in out


# ------------------------------------------------------------- gate script --

def test_check_comm_fast_gate():
    """The fast comm gate (the pre-commit loop entry point) passes."""
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "check_comm.py"),
         "--fast"],
        capture_output=True, text=True, cwd=ROOT, timeout=600,
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[check_comm] PASS" in r.stdout


def test_dryrun_verify_flag():
    """`dryrun --eigen ... --verify` attributes the production-mesh cell's
    collectives and exits zero when everything matches."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--eigen",
         "roadnet48k", "--layout", "panel", "--spmv-comm", "compressed",
         "--spmv-schedule", "matching", "--verify"],
        capture_output=True, text=True, cwd=ROOT, timeout=600,
        env=dict({k: v for k, v in os.environ.items() if k != "XLA_FLAGS"},
                 PYTHONPATH=SRC))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "census[" in r.stdout and ": OK" in r.stdout


@pytest.mark.slow
def test_census_full_engine_grid_all_families():
    """Full grid: 6 engine combos x {stack, panel, pillar} x {rows,
    commvol} on SpinChain, RoadNet-small, and HubNet-small — zero
    unattributed and zero missing collectives everywhere."""
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "scripts", "check_comm.py"),
         "--family", "spinchain", "--family", "roadnet",
         "--family", "hubnet"],
        capture_output=True, text=True, cwd=ROOT, timeout=3000,
        env={k: v for k, v in os.environ.items() if k != "XLA_FLAGS"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[check_comm] PASS" in r.stdout

"""Communication-avoiding s-step filter axis (ISSUE 8).

The seventh engine axis ``spmv_sstep``: the degree-n Chebyshev filter
applied in ⌈n/s⌉ depth-s ghost exchanges (``build_sstep_ell`` +
``make_sstep_cheb``) instead of n per-SpMV halo exchanges.

  * property tests: the depth-s ghost set of every shard equals BFS
    reachability over the boolean pattern powers A^1..A^s (minus the
    owned rows), is monotone in s, and at s = 1 the builder round-trips
    bit-exactly to ``DistEll`` — on random patterns and on planned
    commvol/rcm RowMaps,
  * the full filter is bit-identical across depths s ∈ {1, 2, 3} for
    {a2a, compressed-cyclic, compressed-matching} x {plain, overlap}
    x {kernel off, kernel on} on SpinChainXXZ, RoadNet, and HubNet,
    including on planned RowMaps (degree >= 4: the degenerate degree-3
    tail regroups one FMA on the base path — see docs/s-step.md),
  * the depth-s ``comm_plan`` volumes match the built operator exactly,
    and scoring an s > 1 plan on a RowMap planned at depth 1 warns
    (stale cuts silently under-count the depth-s volumes),
  * ``MachineModel.fit`` recovers (κ, b_c, α) exactly from synthetic
    Eq. 12 + α·rounds samples once a tiny-halo cell breaks the
    rounds/bytes collinearity — and leaves α at 0 without rounds data,
  * the planner keeps s = 1 under the default (bandwidth-bound) machine
    and promotes an s > 1 candidate to the best halo-bearing
    configuration under the high-latency model,
  * the bench artifact schema enums the new ``s`` field and the
    merge-on-write path refuses to propagate a malformed record.
"""
import json
import os
import subprocess
import sys
import warnings

import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st
from tests.conftest import ROOT, run_distributed

from repro.core import perf_model as pm
from repro.core.partition import plan_rowmap
from repro.core.planner import comm_plan, plan_layout
from repro.core.spmv import build_dist_ell, build_sstep_ell, sstep_ghosts
from repro.matrices import HubNet, RoadNet, SpinChainXXZ
from repro.matrices.sparse import CSR

HUBNET_SMALL = dict(n=4000, w=2, h=4, m=192, k=4)
ROADNET_SMALL = dict(n=4000, w=2, m=256, k=4)


def _random_pattern_csr(rng, n, density) -> CSR:
    """Random sparse pattern with values: symmetric support plus the
    diagonal, so BFS depth has nontrivial growth."""
    a = rng.random((n, n)) < density
    a |= a.T
    np.fill_diagonal(a, True)
    indptr = np.zeros(n + 1, dtype=np.int64)
    indptr[1:] = a.sum(axis=1).cumsum()
    indices = np.concatenate([np.flatnonzero(a[i]) for i in range(n)])
    data = rng.standard_normal(indices.size)
    return CSR(indptr=indptr, indices=indices.astype(np.int64),
               data=data, shape=(n, n))


def _padded_pattern(csr: CSR, P: int):
    """Pattern CSR over the padded position space [0, P*R)."""
    D = csr.shape[0]
    R = -(-D // P)
    indptr = np.concatenate(
        [csr.indptr,
         np.full(P * R - D, csr.indptr[-1], dtype=np.int64)])
    return indptr, np.asarray(csr.indices, dtype=np.int64), R


@settings(max_examples=12)
@given(n=st.integers(8, 48), P=st.integers(2, 4), s=st.integers(1, 3),
       seed=st.integers(0, 10_000))
def test_sstep_ghosts_equal_bfs_reachability(n, P, s, seed):
    """Every shard's depth-d ghost set (d <= s) equals the boolean-power
    reachability of A^d from its owned rows, minus the owned rows — and
    the depths recorded are the FIRST-reached depths."""
    rng = np.random.default_rng(seed)
    csr = _random_pattern_csr(rng, n, density=rng.uniform(0.03, 0.25))
    indptr, cols, R = _padded_pattern(csr, P)
    ghosts = sstep_ghosts(indptr, cols, P, R, s)
    D = csr.shape[0]
    B = np.zeros((P * R, P * R), dtype=bool)
    for i in range(D):
        B[i, cols[indptr[i]:indptr[i + 1]]] = True
    for p, (gpos, gdep) in enumerate(ghosts):
        owned = np.zeros(P * R, dtype=bool)
        owned[p * R:(p + 1) * R] = True
        reach = owned.copy()
        first_depth = {}
        for d in range(1, s + 1):
            nxt = (reach @ B) | reach
            for j in np.flatnonzero(nxt & ~reach):
                first_depth[int(j)] = d
            reach = nxt
        want = np.array(sorted(first_depth), dtype=np.int64)
        assert np.array_equal(gpos, want), (p, s)
        assert np.array_equal(gdep,
                              np.array([first_depth[int(j)] for j in want],
                                       dtype=np.int64)), p


@settings(max_examples=8)
@given(n=st.integers(8, 40), P=st.integers(2, 4), seed=st.integers(0, 10_000))
def test_sstep_ghosts_monotone_in_depth(n, P, seed):
    """Ghost sets grow monotonically with s, and the depth-d slice of a
    deeper BFS equals the depth-d BFS (the plan at s is a refinement,
    never a recomputation, of the plan at s-1)."""
    rng = np.random.default_rng(seed)
    csr = _random_pattern_csr(rng, n, density=rng.uniform(0.03, 0.25))
    indptr, cols, R = _padded_pattern(csr, P)
    per_s = [sstep_ghosts(indptr, cols, P, R, s) for s in (1, 2, 3)]
    for p in range(P):
        prev: set = set()
        for si, s in enumerate((1, 2, 3)):
            gpos, gdep = per_s[si][p]
            cur = set(gpos.tolist())
            assert prev <= cur, (p, s)
            prev = cur
            # depth-d slice agrees with the shallower BFS
            for sj in range(si):
                gp_j, _ = per_s[sj][p]
                mask = gdep <= sj + 1
                assert np.array_equal(np.sort(gpos[mask]), gp_j), (p, s)


def test_sstep_s1_roundtrips_to_dist_ell():
    """s = 1 is the existing engine: ``build_sstep_ell(..., 1)``
    re-expressed via ``as_dist_ell`` is bit-identical to
    ``build_dist_ell`` — cols, vals, send plan, pair counts — on random
    patterns, on SpinChain, and on a planned commvol+rcm RowMap."""
    rng = np.random.default_rng(5)
    cases = []
    for _ in range(3):
        csr = _random_pattern_csr(rng, int(rng.integers(16, 60)),
                                  density=rng.uniform(0.05, 0.2))
        cases.append((csr, None))
    hub = HubNet(**HUBNET_SMALL)
    cases.append((SpinChainXXZ(8, 4).build_csr(), None))
    cases.append((hub.build_csr(),
                  plan_rowmap(hub, 4, balance="commvol", reorder="rcm")))
    for csr, rm in cases:
        ell = build_dist_ell(csr, 4, rowmap=rm)
        sell = build_sstep_ell(csr, 4, 1, rowmap=rm)
        assert (sell.R, sell.L, sell.G) == (ell.R, ell.L, int(ell.n_vc.max()))
        back = sell.as_dist_ell()
        assert np.array_equal(np.asarray(back.cols), np.asarray(ell.cols))
        assert np.array_equal(np.asarray(back.vals), np.asarray(ell.vals))
        assert np.array_equal(np.asarray(back.send_idx),
                              np.asarray(ell.send_idx))
        assert np.array_equal(back.pair_counts, ell.pair_counts)


def test_sstep_comm_plan_matches_builder():
    """The pattern-only depth-s plan and the built operator agree on L,
    per-pair volumes, and ghost counts — equal partition and planned
    RowMap — so the census/byte predictions are exact by construction."""
    hub = HubNet(**HUBNET_SMALL)
    for s in (2, 3):
        for rm in (None, plan_rowmap(hub, 4, balance="commvol", sstep=s)):
            cp = comm_plan(hub, 4, rowmap=rm, sstep=s)
            sell = build_sstep_ell(hub, 4, s, rowmap=rm)
            assert cp.L == sell.L, (s, rm)
            assert np.array_equal(cp.pair_counts, sell.pair_counts)
            assert np.array_equal(np.asarray(cp.n_vc), np.asarray(sell.n_vc))
            assert cp.ghost_cum == sell.ghost_cum
            assert cp.ghost_cum[s] == int(np.asarray(sell.n_vc).max())


def test_sstep_plan_warns_on_stale_rowmap_depth():
    """Satellite 6: scoring an s > 1 plan on a RowMap planned at depth 1
    warns (its cuts never optimized the depth-s volumes); a map planned
    at the right depth stays silent."""
    mat = SpinChainXXZ(10, 5)
    rm1 = plan_rowmap(mat, 4, balance="commvol")
    with pytest.warns(UserWarning, match="sstep"):
        comm_plan(mat, 4, rowmap=rm1, sstep=2)
    rm2 = plan_rowmap(mat, 4, balance="commvol", sstep=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        comm_plan(mat, 4, rowmap=rm2, sstep=2)
        comm_plan(mat, 4, rowmap=rm1)  # depth-1 scoring never warns


def test_sstep_bit_identity_engine_grid():
    """The depth-s filter is bit-identical to the s = 1 reference across
    the engine grid on SpinChainXXZ: {a2a, compressed-cyclic,
    compressed-matching} x {plain, overlap} x s ∈ {2, 3}, with the
    kernelized (Pallas interpret) cells at both depths."""
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import SpinChainXXZ
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.core.spmv import build_sstep_ell, make_sstep_cheb
from repro.core.chebyshev import chebyshev_filter
mat = SpinChainXXZ(10, 5)
csr = mat.build_csr()
D = csr.shape[0]
D_pad = -(-D // 8) * 8
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
degree = 8
mu = np.linspace(1.0, 0.5, degree + 1)
rng = np.random.default_rng(0)
X = np.zeros((D_pad, 8)); X[:D] = rng.standard_normal((D, 8))
ENGINES = [("a2a", "cyclic"), ("compressed", "cyclic"),
           ("compressed", "matching")]
with mesh:
    Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
    ell = build_dist_ell(csr, 4, d_pad=D_pad)
    spmv = make_spmv(mesh, lay, ell)
    ref = np.asarray(jax.jit(
        lambda V: chebyshev_filter(spmv, mu, 0.5, 0.1, V))(Xs))
    for s in (2, 3):
        sell = build_sstep_ell(csr, 4, s, d_pad=D_pad)
        for comm, sched in ENGINES:
            for ov in (False, True):
                for krn in ((False, True) if (comm, ov) in
                            (("a2a", False), ("compressed", True))
                            else (False,)):
                    app = make_sstep_cheb(mesh, lay, sell, comm=comm,
                                          schedule=sched, overlap=ov,
                                          use_kernel=krn)
                    y = np.asarray(jax.jit(
                        lambda V: app(V, mu, 0.5, 0.1))(Xs))
                    assert np.array_equal(y, ref), (s, comm, sched, ov,
                                                    krn)
        print(f"s={{s}} grid ok")
print("SSTEP GRID OK")
""", timeout=1500)
    assert "SSTEP GRID OK" in out


def test_sstep_bit_identity_families_and_planned_rowmap():
    """Depth-2/3 bit-identity on the comm-imbalanced families — RoadNet
    and HubNet — including HubNet on a planned commvol RowMap (the map
    planned at the same depth the engine ships)."""
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import HubNet, RoadNet
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.core.partition import plan_rowmap
from repro.core.spmv import build_sstep_ell, make_sstep_cheb
from repro.core.chebyshev import chebyshev_filter
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
degree = 8
mu = np.linspace(1.0, 0.5, degree + 1)
rng = np.random.default_rng(0)
cases = [(RoadNet(**{ROADNET_SMALL!r}), None, 2),
         (HubNet(**{HUBNET_SMALL!r}), None, 3),
         (HubNet(**{HUBNET_SMALL!r}), "commvol", 2)]
for mat, bal, s in cases:
    csr = mat.build_csr()
    rm = plan_rowmap(mat, 4, balance=bal, sstep=s) if bal else None
    D_pad = rm.D_pad if rm else -(-csr.shape[0] // 8) * 8
    ell = build_dist_ell(csr, 4, d_pad=None if rm else D_pad, rowmap=rm)
    sell = build_sstep_ell(csr, 4, s, d_pad=None if rm else D_pad,
                           rowmap=rm)
    X = np.zeros((D_pad, 8))
    X0 = rng.standard_normal((csr.shape[0], 8))
    X[:csr.shape[0]] = X0
    Xp = rm.embed(X0) if rm else X
    with mesh:
        Xs = jax.device_put(jnp.asarray(Xp), lay.vec_sharding(mesh))
        spmv = make_spmv(mesh, lay, ell)
        ref = np.asarray(jax.jit(
            lambda V: chebyshev_filter(spmv, mu, 0.5, 0.1, V))(Xs))
        for comm, sched in (("a2a", "cyclic"), ("compressed", "matching")):
            app = make_sstep_cheb(mesh, lay, sell, comm=comm,
                                  schedule=sched)
            y = np.asarray(jax.jit(lambda V: app(V, mu, 0.5, 0.1))(Xs))
            assert np.array_equal(y, ref), (mat.name, bal, s, comm)
    print(f"{{type(mat).__name__}} bal={{bal}} s={{s}} ok")
print("SSTEP FAMILIES OK")
""", timeout=1500)
    assert "SSTEP FAMILIES OK" in out


def test_machine_fit_recovers_alpha():
    """Satellite 1: κ, b_c, and α are recovered exactly from synthetic
    Eq. 12 + α·rounds samples. The tiny-halo cell (rounds > 0 at χ = 0)
    is what de-collinearizes the latency column from the bytes column —
    exactly the cell ``dryrun --fit-machine`` emits."""
    true = dict(b_m=8.0e11, b_c=4.5e10, kappa=6.5, alpha=25e-6)
    D, N_p, n_nzr, S_d, S_i = 1 << 20, 8, 13.0, 8, 4
    cells = [(0.0, 0.0, 8), (0.4, 1.0, 8), (0.9, 3.0, 8), (0.4, 1.0, 2),
             (0.0, 2.0, 8), (1.5, 1.0, 4), (0.2, 5.0, 8)]
    samples = []
    for chi, rounds, n_b in cells:
        scale = n_b * D / N_p
        t = (scale * (S_d + S_i) * n_nzr / n_b / true["b_m"]
             + true["kappa"] * scale * S_d / true["b_m"]
             + scale * chi * S_d / true["b_c"]
             + true["alpha"] * rounds)
        samples.append(dict(t=t, D=D, N_p=N_p, n_b=n_b, chi=chi,
                            n_nzr=n_nzr, S_d=S_d, rounds=rounds))
    fit = pm.MachineModel.fit(samples, b_m=true["b_m"], S_i=S_i)
    assert fit.kappa == pytest.approx(true["kappa"], rel=1e-8)
    assert fit.b_c == pytest.approx(true["b_c"], rel=1e-8)
    assert fit.alpha == pytest.approx(true["alpha"], rel=1e-8)
    # without any rounds data the latency column is dropped, alpha = 0
    no_rounds = [dict(s, rounds=0.0) for s in samples]
    fit0 = pm.MachineModel.fit(no_rounds, b_m=true["b_m"], S_i=S_i)
    assert fit0.alpha == 0.0


def test_machine_model_roundtrips_alpha(tmp_path):
    """save/load keeps the α field; older JSON without it loads as 0."""
    m = pm.MachineModel("x", b_m=1e12, b_c=5e10, kappa=7.0, alpha=3e-5)
    path = tmp_path / "m.json"
    pm.save_machine(m, str(path))
    assert pm.load_machine(str(path)).alpha == m.alpha
    legacy = json.loads(path.read_text())
    legacy.pop("alpha")
    path.write_text(json.dumps(legacy))
    assert pm.load_machine(str(path)).alpha == 0.0


def test_planner_sstep_default_vs_high_latency():
    """Acceptance: under the default bandwidth-bound machine the best
    plan keeps s = 1; under the high-latency model the best *halo-
    bearing* candidate is an s > 1 cell (comm-free pillar splits, which
    pay no α at all, are allowed to stay on top overall)."""
    hub = HubNet(**HUBNET_SMALL)
    default = plan_layout(hub, 8, n_search=16, sstep=(1, 2, 3))
    assert default.best.sstep == 1, default.report()
    high = plan_layout(hub, 8, n_search=16, sstep=(1, 2, 3),
                       machine=pm.TPU_V5E_HIGHLAT)
    halo = [c for c in high.candidates if c.comm_bytes_per_device > 0]
    assert halo, high.report()
    assert halo[0].sstep > 1, high.report()


def test_bench_schema_s_field():
    """Satellite 2: the ``s`` field is enum'd {1, 2, 3} and nonnegative;
    malformed depths are schema errors."""
    from benchmarks.schema import SSTEP_VALUES, validate_record

    assert SSTEP_VALUES == {1, 2, 3}
    base = dict(table="sstep", family="hubnet", s=2,
                pred_bytes_per_device=10, meas_bytes_per_device=10)
    assert validate_record(base) == []
    for bad in (5, -1, 0, True, 2.0, "2"):
        errs = validate_record(dict(base, s=bad))
        assert errs, bad
        assert any("s" in e for e in errs)


def test_bench_merge_refuses_malformed_sstep_record(tmp_path):
    """Satellite 2 negative test: the merge-on-write path re-validates
    the FULL artifact (old + new records); a malformed ``s`` record
    already in the trajectory of a bench NOT being rerun makes run.py
    refuse to write (exit 2) and leave the file untouched."""
    art = {"schema": "bench-spmv/v1", "generated_unix": 1,
           "benches": ["sstep"],
           "records": [{"table": "sstep", "family": "hubnet", "s": 99}],
           "rows": [{"bench": "sstep", "name": "sstep_x", "us_per_call": 1.0,
                     "derived": ""}]}
    path = tmp_path / "BENCH_spmv.json"
    path.write_text(json.dumps(art))
    before = path.read_text()
    env = dict(os.environ, PYTHONPATH=ROOT)
    r = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks", "run.py"),
         "--only", "table2", "--json", str(path)],
        env=env, capture_output=True, text=True, timeout=600)
    assert r.returncode == 2, r.stdout + r.stderr
    assert "SCHEMA ERROR" in r.stderr
    assert "s = 99" in r.stderr
    assert path.read_text() == before


def test_fdconfig_rejects_invalid_sstep():
    """FilterDiag validates the axis up front."""
    import jax

    from repro.core import FDConfig, FilterDiag, make_solver_mesh

    jax.config.update("jax_enable_x64", True)
    mat = SpinChainXXZ(8, 4)
    mesh = make_solver_mesh(1, 1)
    with mesh, pytest.raises(ValueError, match="spmv_sstep"):
        FilterDiag(mat.build_csr(), mesh, FDConfig(spmv_sstep=0))


@pytest.mark.slow
def test_fd_solve_sstep_bit_identical_8dev():
    """Full FD solve with spmv_sstep ∈ {2, 3} walks the bit-identical
    iteration path as the s = 1 solver on the 4x2 mesh."""
    out = run_distributed(f"""
import numpy as np, jax
from repro.core import FDConfig, FilterDiag, make_solver_mesh
from repro.matrices import SpinChainXXZ
mat = SpinChainXXZ(10, 5)
csr = mat.build_csr()
w = np.linalg.eigvalsh(csr.to_dense())
tau = float(w[len(w) // 2])
mesh = make_solver_mesh(4, 2)
res = {{}}
for s in (1, 2, 3):
    cfg = FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8,
                   max_iters=12, spmv_sstep=s)
    with mesh:
        res[s] = FilterDiag(csr, mesh, cfg).solve()
for s in (2, 3):
    assert res[s].iterations == res[1].iterations, s
    assert np.array_equal(res[s].eigenvalues, res[1].eigenvalues), s
print("FD SSTEP OK", res[1].iterations)
""", timeout=1500)
    assert "FD SSTEP OK" in out

"""χ-aware row partitioning (ISSUE 5): the RowMap embed, commvol
boundaries, RCM reorder, and their integration with the engines and the
planner.

  * the identity map reproduces ``spmv.Partition`` exactly and the
    embed→extract round trip is bit-identical,
  * RCM is a valid permutation that reduces the pattern bandwidth,
  * commvol boundaries are valid (monotone, capped) and **strictly
    reduce** the engine-exact wire volumes on the comm-imbalanced
    families at P = 8 (never worse anywhere — the equal-rows guard),
  * ``comm_plan(rowmap=...)`` equals ``build_dist_ell(rowmap=...)``'s
    counts and schedules exactly,
  * the ``L = max(L, 1)`` floor bugfix: a zero-halo partition builds
    ``L = 0``, predicts zero bytes, and the compiled engine moves zero
    collective bytes,
  * the planner's fifth axis: commvol candidates are enumerated, carry
    their rowmap, and ``--layout auto`` selects a commvol candidate on
    hubnet48k at P = 8 (acceptance),
  * slow: a full FD solve under ``balance="commvol", reorder="rcm"``
    converges to the dense spectrum, with bit-exact un-permutation of
    the search vectors.
"""
import numpy as np
import pytest

from tests.conftest import run_distributed

from repro.core.partition import (RowMap, commvol_boundaries,
                                  partition_plan_default,
                                  pattern_bandwidth, plan_rowmap,
                                  rcm_permutation)
from repro.core.planner import comm_plan, plan_layout
from repro.core.spmv import Partition, build_dist_ell
from repro.matrices import HubNet, RoadNet, SpinChainXXZ
from repro.matrices.sparse import csr_from_coo

HUBNET_SMALL = dict(n=4000, w=2, h=4, m=192, k=4)
ROADNET_SMALL = dict(n=4000, w=2, m=256, k=4)


def _block_diag_csr(rng, n=16, blocks=2):
    """Dense block-diagonal CSR whose 2-shard partition has zero halo."""
    r, c, v = [], [], []
    for b in range(blocks):
        A = rng.standard_normal((n, n))
        A = A + A.T
        rr, cc = np.nonzero(np.ones((n, n)))
        r.append(rr + b * n)
        c.append(cc + b * n)
        v.append(A[rr, cc])
    return csr_from_coo(np.concatenate(r), np.concatenate(c),
                        np.concatenate(v), (blocks * n, blocks * n))


def test_rowmap_identity_matches_partition():
    """RowMap.rows is the Partition fast path: same boundaries, identity
    embed, and build_dist_ell treats it as the default partition."""
    for D, P, d_pad in ((252, 4, None), (924, 8, 928), (100, 8, None)):
        part = Partition(D, P, d_pad)
        rm = RowMap.rows(D, P, d_pad)
        assert rm.identity
        assert rm.R == part.R and rm.D_pad == part.D_pad
        assert np.array_equal(rm.boundaries, part.boundaries())
        assert np.array_equal(rm.pos, np.arange(D))
        assert np.array_equal(rm.block_sizes(), np.diff(part.boundaries()))
    mat = SpinChainXXZ(10, 5)
    csr = mat.build_csr()
    e_plain = build_dist_ell(csr, 4)
    e_map = build_dist_ell(csr, 4, rowmap=RowMap.rows(csr.shape[0], 4))
    assert np.array_equal(np.asarray(e_plain.cols), np.asarray(e_map.cols))
    assert np.array_equal(np.asarray(e_plain.vals), np.asarray(e_map.vals))
    assert e_plain.L == e_map.L
    # conflicting d_pad is rejected
    with pytest.raises(ValueError, match="d_pad"):
        build_dist_ell(csr, 4, d_pad=123456,
                       rowmap=RowMap.rows(csr.shape[0], 4))


def test_embed_extract_roundtrip_bit_identical():
    """extract(embed(X)) == X bit-for-bit; pads are exactly zero; the
    map's accessors are mutually consistent at every grouped level."""
    rng = np.random.default_rng(3)
    mat = HubNet(**HUBNET_SMALL)
    for bal, ro in (("commvol", "none"), ("rows", "rcm"),
                    ("commvol", "rcm")):
        rm = plan_rowmap(mat, 8, balance=bal, reorder=ro)
        X = rng.standard_normal((mat.D, 5))
        Xp = rm.embed(X)
        assert Xp.shape == (rm.D_pad, 5)
        assert np.array_equal(rm.extract(Xp), X)  # bit-identical
        assert not Xp[~rm.valid_mask()].any()     # pads exactly zero
        assert rm.block_sizes().sum() == mat.D
        # perm is a permutation; pos is a bijection into [0, D_pad)
        assert np.array_equal(np.sort(rm.perm), np.arange(mat.D))
        assert len(np.unique(rm.pos)) == mat.D
        for n_row in (8, 4, 2, 1):
            sizes = rm.block_sizes(n_row)
            assert sizes.sum() == mat.D
            R = rm.level_R(n_row)
            for p in (0, n_row - 1):
                rows, off = rm.shard_rows(p, n_row)
                assert len(rows) == sizes[p]
                assert np.array_equal(rm.pos[rows], p * R + off)


def test_rcm_is_valid_and_reduces_bandwidth():
    """RCM on a row-shuffled banded pattern restores a small bandwidth
    (and is deterministic)."""
    rng = np.random.default_rng(0)
    n, w = 600, 3
    perm0 = rng.permutation(n)
    inv0 = np.argsort(perm0)
    rows, cols = [], []
    for d in range(-w, w + 1):
        i = np.arange(max(0, -d), min(n, n - d))
        rows.append(inv0[i])
        cols.append(inv0[i + d])
    csr = csr_from_coo(np.concatenate(rows), np.concatenate(cols),
                       np.ones(sum(len(r) for r in rows)), (n, n))
    bw_before = pattern_bandwidth(csr)
    assert bw_before > 10 * w  # the shuffle destroyed locality
    perm = rcm_permutation(csr)
    assert np.array_equal(np.sort(perm), np.arange(n))
    bw_after = pattern_bandwidth(csr, perm)
    assert bw_after <= 2 * w + 1  # RCM restores the band
    assert np.array_equal(perm, rcm_permutation(csr))  # deterministic
    # the full planned map on RoadNet strictly reduces the bandwidth too
    rn = RoadNet(**ROADNET_SMALL)
    assert pattern_bandwidth(rn, rcm_permutation(rn)) < pattern_bandwidth(rn)


def test_commvol_boundaries_valid_and_strictly_reduce_wire():
    """commvol cuts are monotone, ≥1 row, capped — and on the
    comm-imbalanced 48k families at P = 8 they STRICTLY reduce the
    engine-exact wire volumes (the acceptance regime), while the
    never-worse guard holds everywhere."""
    # acceptance instance: hubnet48k at P = 8
    hub = HubNet()
    rm = plan_rowmap(hub, 8, balance="commvol")
    sizes = np.diff(rm.boundaries)
    assert (sizes >= 1).all() and sizes.max() <= -(-hub.D // 8) * 1.5
    assert rm.D_pad % 8 == 0 and rm.R == sizes.max()
    cp_rows = comm_plan(hub, 8)
    cp_cv = comm_plan(hub, 8, rowmap=rm)
    H_rows = cp_rows.moved_entries_per_device("compressed", "matching")
    H_cv = cp_cv.moved_entries_per_device("compressed", "matching")
    assert H_cv < H_rows, (H_cv, H_rows)  # strict reduction
    # the composite wire objective (what the descent minimizes) drops too
    def wire(cp):
        return (cp.moved_entries_per_device("a2a")
                + cp.moved_entries_per_device("compressed", "cyclic")
                + cp.moved_entries_per_device("compressed", "matching"))
    assert wire(cp_cv) < wire(cp_rows)
    # roadnet-small at P = 8: the a2a pad strictly drops
    rn = RoadNet(**ROADNET_SMALL)
    cp_r = comm_plan(rn, 8)
    cp_c = comm_plan(rn, 8, rowmap=plan_rowmap(rn, 8, balance="commvol"))
    assert cp_c.moved_entries_per_device("a2a") \
        < cp_r.moved_entries_per_device("a2a")
    # never-worse guard: on a pattern commvol cannot improve (uniform
    # band), the equal cuts are kept verbatim
    sc = SpinChainXXZ(8, 4)
    b = commvol_boundaries(sc, 4)
    from repro.matrices.sparse import uniform_partition
    assert (np.diff(b) >= 1).all()
    eq = uniform_partition(sc.D, 4)
    cp_eq = comm_plan(sc, 4)
    cp_cv2 = comm_plan(sc, 4, rowmap=plan_rowmap(sc, 4, balance="commvol"))
    assert wire(cp_cv2) <= wire(cp_eq)


def test_comm_plan_rowmap_matches_engine():
    """Pattern-only counts on a planned map equal build_dist_ell's, for
    families AND CSR, including both neighbor schedules — and χ is
    evaluated on the planned block sizes."""
    mat = HubNet(**HUBNET_SMALL)
    csr = mat.build_csr()
    for bal, ro in (("rows", "rcm"), ("commvol", "rcm")):
        rm = plan_rowmap(mat, 4, balance=bal, reorder=ro)
        assert not rm.identity
        ell = build_dist_ell(csr, 4, rowmap=rm)
        for src in (mat, csr):
            cp = comm_plan(src, 4, rowmap=rm)
            assert cp.exact and cp.rowmap is rm
            assert cp.L == ell.L
            assert (cp.n_vc == ell.n_vc).all()
            assert (cp.pair_counts == np.asarray(ell.pair_counts)).all()
            for sched in ("cyclic", "matching"):
                nbr = ell.neighbor_plan(schedule=sched)
                assert cp.permute_schedule(sched) == (nbr.perms, nbr.round_L)
                assert cp.moved_entries_per_device("compressed", sched) \
                    == nbr.H
            chim = cp.chi
            assert (chim.n_vm == rm.block_sizes(4)).all()
            assert chim.chi3 == pytest.approx(4 * cp.n_vc.max() / mat.D)


def test_zero_halo_partition_is_comm_free():
    """Bugfix: a partition with no remote columns builds L = 0 (no
    phantom 1-entry pad), the prediction is zero bytes, and the compiled
    engines move zero collective bytes while staying correct."""
    rng = np.random.default_rng(0)
    csr = _block_diag_csr(rng)
    ell = build_dist_ell(csr, 2, d_pad=32)
    assert ell.L == 0
    assert ell.comm_bytes_per_spmv == 0
    assert ell.pair_counts is not None and not ell.pair_counts.any()
    cp = comm_plan(csr, 2, d_pad=32)
    assert cp.L == 0
    assert cp.a2a_bytes_per_device(4, 8) == 0
    assert cp.moved_entries_per_device("compressed") == 0
    nbr = ell.neighbor_plan()
    assert nbr.H == 0 and nbr.perms == ()
    out = run_distributed("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import make_solver_mesh, build_dist_ell, make_spmv, Layout
from repro.matrices.sparse import csr_from_coo
from repro.launch.hlo_analysis import analyze_hlo
rng = np.random.default_rng(0)
r, c, v = [], [], []
for b in range(2):
    A = rng.standard_normal((16, 16)); A = A + A.T
    rr, cc = np.nonzero(np.ones((16, 16)))
    r.append(rr + b*16); c.append(cc + b*16); v.append(A[rr, cc])
csr = csr_from_coo(np.concatenate(r), np.concatenate(c),
                   np.concatenate(v), (32, 32))
mesh = make_solver_mesh(2, 1)
lay = Layout("stack", ("row",), ())
X = rng.standard_normal((32, 4))
ys = {}
with mesh:
    sh = lay.vec_sharding(mesh)
    Xs = jax.device_put(jnp.asarray(X), sh)
    for kw in (dict(), dict(overlap=True), dict(comm="compressed"),
               dict(comm="compressed", overlap=True)):
        ell = build_dist_ell(csr, 2, d_pad=32, split_halo=True)
        f = jax.jit(make_spmv(mesh, lay, ell, **kw),
                    in_shardings=(sh,), out_shardings=sh)
        comp = f.lower(jax.ShapeDtypeStruct((32, 4), jnp.float64)).compile()
        h = analyze_hlo(comp.as_text())
        assert h.coll_breakdown.get("all-to-all", 0) == 0, (kw, h.coll_breakdown)
        assert h.coll_breakdown.get("collective-permute", 0) == 0, kw
        ys[tuple(sorted(kw))] = np.asarray(f(Xs))
ref = csr.matvec(X)
for kw, y in ys.items():
    assert np.abs(y - ref).max() < 1e-11, kw
print("ZERO HALO COMM FREE OK")
""", n_devices=2)
    assert "ZERO HALO COMM FREE OK" in out


def test_planner_partition_axis_acceptance_hubnet48k():
    """Acceptance: at P = 8 on hubnet48k the planner enumerates the
    commvol partition, scores it with engine-exact bytes from its own
    rowmap, and `--layout auto` SELECTS a commvol candidate whose wire
    bytes strictly undercut every equal-rows candidate of the same
    configuration."""
    hub = HubNet()  # the hubnet48k instance
    assert partition_plan_default(hub)
    plan = plan_layout(hub, 8, n_search=32)
    best = plan.best
    assert best.balance == "commvol", plan.report()
    assert best.rowmap is not None
    by_key = {(c.n_row, c.n_col, c.comm, c.schedule, c.overlap,
               c.balance, c.reorder): c for c in plan.candidates}
    rows_twin = by_key[(best.n_row, best.n_col, best.comm, best.schedule,
                        best.overlap, "rows", "none")]
    assert best.comm_bytes_per_device < rows_twin.comm_bytes_per_device
    assert best.t_pass <= rows_twin.t_pass
    assert "+cv" in best.name
    # both partitions of every engine remain enumerated
    assert any(c.balance == "rows" for c in plan.candidates)
    # candidate counts carry through: the best candidate's bytes equal a
    # fresh comm_plan on its own map
    cp = comm_plan(hub, best.n_row, rowmap=best.rowmap)
    assert best.comm_bytes_per_device == cp.comm_bytes_per_device(
        best.comm, plan.n_search // best.n_col, hub.S_d, best.schedule)


def test_filterdiag_auto_adopts_commvol_on_hubnet():
    """FDConfig(layout='auto') on an 8-device mesh adopts the commvol
    partition on the hub-and-spoke family and builds its operators from
    the SAME map the winner was scored on."""
    out = run_distributed(f"""
import numpy as np, jax
from repro.core import FDConfig, FilterDiag, make_solver_mesh
from repro.core.planner import comm_plan
from repro.matrices import HubNet
mat = HubNet(**{HUBNET_SMALL!r})
mesh = make_solver_mesh(4, 2)
cfg = FDConfig(n_target=4, n_search=16, layout="auto")
with mesh:
    fdd = FilterDiag(mat, mesh, cfg)
best = fdd.plan.best
if best.balance == "commvol":
    assert fdd.rowmap is best.rowmap
    assert fdd.cfg.spmv_balance == "commvol"
    assert fdd.D_pad == fdd.rowmap.D_pad
    assert fdd.ell_stack.rowmap is fdd.rowmap
else:
    assert fdd.rowmap is None
# the stack operator's realized bytes equal the winner's scoring
cp = comm_plan(mat, 8, rowmap=best.rowmap)
assert fdd.ell_stack.L == cp.L, (fdd.ell_stack.L, cp.L)
assert (np.asarray(fdd.ell_stack.pair_counts) == cp.pair_counts).all()
print("AUTO PARTITION OK", best.describe())
""")
    assert "AUTO PARTITION OK" in out


@pytest.mark.slow
def test_fd_solve_commvol_rcm_8dev():
    """Full FD solve on the HubNet smoke instance under every partition
    mode: converges to the dense-eigh spectrum (eigenvalues are
    invariant under the similarity transform), and gather_global
    un-permutes padded vectors bit-exactly."""
    out = run_distributed(f"""
import numpy as np, jax
from repro.core import FDConfig, FilterDiag, make_solver_mesh
from repro.matrices import HubNet
mat = HubNet(**{HUBNET_SMALL!r})
csr = mat.build_csr()
w = np.linalg.eigvalsh(csr.to_dense())
tau = float(w[len(w) // 2])
mesh = make_solver_mesh(4, 2)
evs = {{}}
for bal, ro in (("rows", "none"), ("commvol", "none"), ("commvol", "rcm")):
    cfg = FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8,
                   max_iters=25, spmv_comm="compressed",
                   spmv_schedule="matching", spmv_balance=bal,
                   spmv_reorder=ro)
    with mesh:
        fdd = FilterDiag(csr, mesh, cfg)
        if ro == "rcm":
            # an rcm map never degenerates (the permutation is real);
            # commvol alone may keep the equal cuts on this instance
            assert fdd.rowmap is not None
        if fdd.rowmap is not None:
            # bit-exact round trip of the embed on the live driver
            X = np.random.default_rng(1).standard_normal((mat.D, 3))
            assert np.array_equal(fdd.gather_global(fdd.rowmap.embed(X)), X)
        res = fdd.solve()
    assert res.n_converged >= 4, (bal, ro, res.n_converged)
    for ev in res.eigenvalues[:4]:
        assert np.abs(w - ev).min() < 1e-7, (bal, ro, ev)
    evs[(bal, ro)] = np.sort(res.eigenvalues[:4])
# the spectrum is partition-invariant to solver tolerance
for key, e in evs.items():
    assert np.abs(e - evs[("rows", "none")]).max() < 1e-7, key
print("FD PARTITION OK")
""", timeout=2000)
    assert "FD PARTITION OK" in out

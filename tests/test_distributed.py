"""Distributed core: SpMV comm plan, TSQR, redistribution, FD on a panel
mesh — all in 8-device subprocesses. Includes the exact Eq. 17/18
redistribution-volume check against HLO-parsed collective bytes."""
import numpy as np
import pytest

from tests.conftest import run_distributed


def test_spmv_all_layouts_and_tsqr():
    out = run_distributed("""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import SpinChainXXZ, Hubbard
from repro.core import (make_solver_mesh, panel, stack, pillar, build_dist_ell,
                        make_spmv, make_tsqr, make_svqb, Layout)
mat = Hubbard(8, 4, U=2.0, ranpot=0.5)
csr = mat.build_csr()
D = csr.shape[0]
mesh = make_solver_mesh(4, 2)
rng = np.random.default_rng(0)
for lay, P_row in ((panel(mesh), 4), (Layout("stack", ("row","col"), ()), 8),
                   (pillar(mesh), 1)):
    D_pad = -(-D // 8) * 8
    ell = build_dist_ell(csr, P_row, d_pad=D_pad)
    Ns = 8
    X = np.zeros((D_pad, Ns)); X[:D] = rng.standard_normal((D, Ns))
    with mesh:
        Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
        Y = np.asarray(make_spmv(mesh, lay, ell)(Xs))
    err = np.abs(Y[:D] - csr.matvec(X[:D])).max()
    assert err < 1e-11, (lay.name, err)
    assert np.abs(Y[D:]).max() == 0
    print(f"spmv {lay.name} ok")
# TSQR orthogonality + R upper triangular with positive diagonal
st = Layout("stack", ("row","col"), ())
with mesh:
    Xs = jax.device_put(jnp.asarray(X), st.vec_sharding(mesh))
    Q, R = make_tsqr(mesh, st)(Xs)
    Qh, Rh = np.asarray(Q), np.asarray(R)
assert np.abs(Qh.T @ Qh - np.eye(8)).max() < 1e-12
assert np.abs(np.tril(Rh, -1)).max() < 1e-12
assert (np.diag(Rh).real > 0).all()
assert np.abs(Qh @ Rh - X).max() < 1e-11  # QR reproduces V
print("TSQR OK")
""")
    assert "TSQR OK" in out


def test_redistribution_volume_matches_eq17():
    """Explicit redistribution all_to_all bytes == Eq. 17/18 exactly."""
    out = run_distributed("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import make_solver_mesh, panel, Layout
from repro.core.redistribute import make_redistribute, redistribution_volume
from repro.launch.hlo_analysis import analyze_hlo
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
st = Layout("stack", ("row", "col"), ())
D_pad, Ns, P_total, N_col = 512, 8, 8, 2
to_panel, to_stack = make_redistribute(mesh, st, lay)
x = jax.ShapeDtypeStruct((D_pad, Ns), jnp.float64)
with mesh:
    c = jax.jit(to_panel, in_shardings=(jax.NamedSharding(mesh, st.vec_pspec()),),
                out_shardings=jax.NamedSharding(mesh, lay.vec_pspec())).lower(x).compile()
h = analyze_hlo(c.as_text())
pred = redistribution_volume(D_pad, Ns, P_total, N_col, S_d=8)
per_chip_pred = pred["bytes_total"] / P_total
# all_to_all operand per chip includes the local (kept) slice: D/P*Ns*S_d
atoa = h.coll_breakdown["all-to-all"]
full_local = D_pad // P_total * Ns * 8
assert atoa in (per_chip_pred, full_local), (atoa, per_chip_pred, full_local)
moved = atoa - (full_local - per_chip_pred) if atoa == full_local else atoa
assert abs(moved - per_chip_pred) < 1e-9
print("VOLUME OK", atoa, per_chip_pred)
""")
    assert "VOLUME OK" in out


@pytest.mark.slow
def test_fd_panel_interior_eigenvalues():
    """FD with two layers of parallelism on a 4x2 mesh finds interior
    eigenvalues of SpinChainXXZ(12,6) matching dense eigh."""
    out = run_distributed("""
import numpy as np, jax
from repro.matrices import SpinChainXXZ
from repro.core import make_solver_mesh, FilterDiag, FDConfig
mat = SpinChainXXZ(12, 6)
csr = mat.build_csr()
w = np.linalg.eigvalsh(csr.to_dense())
tau = float(w[len(w)//2])
mesh = make_solver_mesh(4, 2)
cfg = FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8, max_iters=25)
with mesh:
    res = FilterDiag(csr, mesh, cfg).solve()
assert res.n_converged >= 4, res.n_converged
for ev in res.eigenvalues[:4]:
    assert np.abs(w - ev).min() < 1e-7
assert res.redistributions == 2 * res.iterations
print("FD PANEL OK", res.iterations, res.redistributions)
""", timeout=1500)
    assert "FD PANEL OK" in out


def test_fused_cheb_step_matches_composition():
    out = run_distributed("""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import SpinChainXXZ
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.core.spmv import make_fused_cheb_step
mat = SpinChainXXZ(10, 5)
csr = mat.build_csr()
D = csr.shape[0]
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
D_pad = -(-D // 8) * 8
ell = build_dist_ell(csr, 4, d_pad=D_pad)
rng = np.random.default_rng(1)
W1 = np.zeros((D_pad, 4)); W1[:D] = rng.standard_normal((D, 4))
W2 = np.zeros((D_pad, 4)); W2[:D] = rng.standard_normal((D, 4))
with mesh:
    sh = lay.vec_sharding(mesh)
    w1 = jax.device_put(jnp.asarray(W1), sh)
    w2 = jax.device_put(jnp.asarray(W2), sh)
    fused = make_fused_cheb_step(mesh, lay, ell)(w1, w2, 0.7, -0.2)
    spmv = make_spmv(mesh, lay, ell)
    ref = 2*0.7*spmv(w1) + 2*(-0.2)*w1 - w2
err = np.abs(np.asarray(fused) - np.asarray(ref)).max()
assert err < 1e-12, err
print("FUSED OK")
""")
    assert "FUSED OK" in out


@pytest.mark.slow
def test_production_mesh_and_shardings_small():
    """shardings rules produce valid, divisible specs for every arch on a
    small (2,2[,2]) stand-in mesh; lower+compile a smoke train step."""
    out = run_distributed("""
import jax, jax.numpy as jnp, functools
from repro.configs import ARCHS, get_smoke_config
from repro.models import transformer as tfm, steps as steps_mod
from repro.optim import adamw
from repro.launch.shardings import param_pspecs, opt_pspecs, batch_pspecs, to_shardings
from repro.launch.dryrun import batch_specs
for multi in (False, True):
    mesh = (jax.make_mesh((2,2,2), ("pod","data","model")) if multi
            else jax.make_mesh((2,4), ("data","model")))
    for arch in ("qwen3-0.6b", "granite-moe-3b-a800m", "rwkv6-1.6b",
                 "hymba-1.5b", "hubert-xlarge"):
        cfg = get_smoke_config(arch)
        ocfg = adamw.AdamWConfig(moment_dtype="float32")
        pshape = jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))
        pspec = param_pspecs(cfg, mesh, pshape)
        psh = to_shardings(mesh, pspec)
        oshape = jax.eval_shape(functools.partial(adamw.init_state, ocfg), pshape)
        osh = to_shardings(mesh, opt_pspecs(cfg, mesh, oshape, pspec))
        batch = batch_specs(cfg, 8, 32)
        bsh = to_shardings(mesh, batch_pspecs(cfg, mesh, batch))
        step = steps_mod.make_train_step(cfg, ocfg)
        c = jax.jit(step, in_shardings=(psh, osh, bsh),
                    out_shardings=(psh, osh, None)).lower(pshape, oshape, batch).compile()
        assert c is not None
        print("lowered", arch, "multi" if multi else "single")
print("SHARDINGS OK")
""", timeout=2400, x64=False)
    assert "SHARDINGS OK" in out

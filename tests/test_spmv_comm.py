"""Sparsity-compressed (neighbor-permute) SpMV engine vs the padded a2a.

Property-style checks of the engine grid {a2a, compressed} x
{plain, overlap} x {kernel off, kernel on}:

  * all eight engines agree on every layout (stack/panel/pillar), for a
    structured pattern (SpinChainXXZ) and a comm-imbalanced one
    (RoadNet) — compressed is bit-identical to its a2a counterpart
    because the halo re-base never re-sorts ELL slots, and kernel-on is
    bit-identical to kernel-off because the Pallas tile kernel
    accumulates in the same slot order (the schedule axis completes the
    twelve-engine grid in ``test_spmv_schedule.py``),
  * the compressed engine's HLO-measured collective-permute bytes equal
    the pattern-only ``comm_plan`` prediction exactly and never exceed
    the padded all_to_all volume — strictly less on RoadNet, by at least
    0.8x the measured χ₃/χ₂ imbalance factor,
  * ``--layout auto`` (the planner) picks the compressed engine on the
    RoadNet family,
  * ``DistEll.halo_nnz_fraction`` counts from masks without
    materializing the local/halo split,
  * ``MachineModel.fit`` recovers (b_c, κ) exactly from synthetic
    Eq. 12 samples.
"""
import numpy as np
import pytest

from tests.conftest import run_distributed

from repro.core import perf_model as pm
from repro.core.metrics import chi_metrics
from repro.core.planner import comm_plan, plan_layout
from repro.core.spmv import build_dist_ell
from repro.matrices import RoadNet, SpinChainXXZ

ROADNET_SMALL = dict(n=4000, w=2, m=256, k=4)


def test_all_engines_agree_all_layouts():
    """a2a, compressed, both overlap variants, and their kernelized
    counterparts agree on stack, panel, and pillar, for a structured and
    an imbalanced pattern; the compressed engines are bit-identical to
    their a2a counterparts and kernel-on to kernel-off."""
    out = run_distributed("""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import RoadNet, SpinChainXXZ
from repro.core import (make_solver_mesh, panel, pillar, build_dist_ell,
                        make_spmv, Layout)
from repro.core.spmv import make_fused_cheb_step
mesh = make_solver_mesh(4, 2)
rng = np.random.default_rng(0)
for mat in (SpinChainXXZ(10, 5), RoadNet(n=4000, w=2, m=256, k=4)):
    csr = mat.build_csr()
    D = csr.shape[0]
    D_pad = -(-D // 8) * 8
    for lay, P_row in ((panel(mesh), 4),
                       (Layout("stack", ("row", "col"), ()), 8),
                       (pillar(mesh), 1)):
        ell = build_dist_ell(csr, P_row, d_pad=D_pad, split_halo=True)
        X = np.zeros((D_pad, 8)); X[:D] = rng.standard_normal((D, 8))
        with mesh:
            Xs = jax.device_put(jnp.asarray(X), lay.vec_sharding(mesh))
            Y = {(c, o, k): np.asarray(make_spmv(mesh, lay, ell, comm=c,
                                                 overlap=o,
                                                 use_kernel=k)(Xs))
                 for c in ("a2a", "compressed") for o in (False, True)
                 for k in (False, True)}
        ref = csr.matvec(X[:D])
        assert np.abs(Y[("a2a", False, False)][:D] - ref).max() < 1e-11
        # compressed == a2a bit-for-bit (same slot-order accumulation)
        # and kernel-on == kernel-off (the tile kernel accumulates in
        # the identical slot order)
        base = Y[("a2a", False, False)]
        ov = Y[("a2a", True, False)]
        for k in (False, True):
            assert np.array_equal(Y[("compressed", False, k)], base), k
            assert np.array_equal(Y[("compressed", True, k)], ov), k
            assert np.array_equal(Y[("a2a", False, k)], base), k
            assert np.array_equal(Y[("a2a", True, k)], ov), k
        # split-phase vs combined: same order, same sums
        assert np.abs(ov - base).max() < 1e-11
        print(f"{mat.name} {lay.name} ok")
    # fused Chebyshev step: all four engines vs the composed baseline
    lay = panel(mesh)
    ell = build_dist_ell(csr, 4, d_pad=D_pad, split_halo=True)
    W1 = np.zeros((D_pad, 4)); W1[:D] = rng.standard_normal((D, 4))
    W2 = np.zeros((D_pad, 4)); W2[:D] = rng.standard_normal((D, 4))
    with mesh:
        sh = lay.vec_sharding(mesh)
        w1 = jax.device_put(jnp.asarray(W1), sh)
        w2 = jax.device_put(jnp.asarray(W2), sh)
        base = np.asarray(make_fused_cheb_step(mesh, lay, ell)(
            w1, w2, 0.7, -0.2))
        for c in ("a2a", "compressed"):
            for o in (False, True):
                got = np.asarray(make_fused_cheb_step(
                    mesh, lay, ell, comm=c, overlap=o)(w1, w2, 0.7, -0.2))
                assert np.abs(got - base).max() < 1e-12, (c, o)
    print(f"{mat.name} fused ok")
print("ENGINE GRID OK")
""")
    assert "ENGINE GRID OK" in out


def test_compressed_hlo_bytes_match_plan():
    """HLO-measured collective bytes of both engines equal the pattern-only
    comm_plan predictions bit-for-bit; compressed <= a2a always, and
    strictly less on the imbalanced RoadNet — by at least 0.8x the
    measured χ₃/χ₂ factor."""
    preds = {}
    for label, mat in (("spinchain", SpinChainXXZ(10, 5)),
                       ("roadnet", RoadNet(**ROADNET_SMALL))):
        D_pad = -(-mat.D // 8) * 8
        cp = comm_plan(mat, 4, d_pad=D_pad)
        preds[label] = (cp.a2a_bytes_per_device(4, 8),
                        cp.permute_bytes_per_device(4, 8))
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import RoadNet, SpinChainXXZ
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.launch.hlo_analysis import analyze_hlo
preds = {preds!r}
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
for label, mat in (("spinchain", SpinChainXXZ(10, 5)),
                   ("roadnet", RoadNet(n=4000, w=2, m=256, k=4))):
    csr = mat.build_csr()
    D_pad = -(-csr.shape[0] // 8) * 8
    ell = build_dist_ell(csr, 4, d_pad=D_pad)
    x = jax.ShapeDtypeStruct((D_pad, 8), jnp.float64)
    meas = {{}}
    with mesh:
        sh = jax.NamedSharding(mesh, lay.vec_pspec())
        for comm in ("a2a", "compressed"):
            c = jax.jit(make_spmv(mesh, lay, ell, comm=comm),
                        in_shardings=(sh,), out_shardings=sh
                        ).lower(x).compile()
            h = analyze_hlo(c.as_text())
            meas[comm] = (int(h.coll_breakdown["all-to-all"]),
                          int(h.coll_breakdown["collective-permute"]))
    pred_a2a, pred_cmp = preds[label]
    # each engine moves ONLY its own collective kind, in exactly the
    # pattern-predicted volume
    assert meas["a2a"] == (pred_a2a, 0), (label, meas["a2a"], pred_a2a)
    assert meas["compressed"] == (0, pred_cmp), (label,
                                                 meas["compressed"], pred_cmp)
    assert pred_cmp <= pred_a2a
    print(f"{{label}}: a2a {{pred_a2a}} vs permute {{pred_cmp}}")
print("HLO BYTES MATCH")
""")
    assert "HLO BYTES MATCH" in out
    # RoadNet: the win is at least 0.8x the measured imbalance factor at
    # this row count (the chi3/chi2 > 2 regime itself is asserted at P=8
    # in test_roadnet_imbalance_and_auto_selects_compressed)
    rn = RoadNet(**ROADNET_SMALL)
    chim = chi_metrics(rn, 4)
    a2a, cmp_ = preds["roadnet"]
    assert a2a > cmp_  # strictly less on the imbalanced family
    assert a2a / cmp_ >= 0.8 * chim.imbalance, (a2a, cmp_, chim.imbalance)
    # structured pattern: compressed still never pays more than a2a
    a2a_s, cmp_s = preds["spinchain"]
    assert cmp_s <= a2a_s


def test_engines_agree_and_hlo_matches_under_commvol():
    """ISSUE-5 satellite: on a planned commvol partition of the
    comm-imbalanced RoadNet — planned at the finest level P = 8 and
    consumed *grouped* at the panel's 4 row shards, exactly like
    FilterDiag's stack/panel pair — all four {a2a, compressed} x
    {plain, overlap} engines stay bit-identical and the HLO-measured
    bytes equal the ``comm_plan(rowmap=...)`` prediction exactly. At
    the plan level the commvol a2a pad strictly undercuts equal rows."""
    from repro.core.partition import plan_rowmap

    rn = RoadNet(**ROADNET_SMALL)
    rm = plan_rowmap(rn, 8, balance="commvol")
    assert not rm.identity
    assert rm.D_pad % 4 == 0  # grouped level exists
    cp_cv = comm_plan(rn, 4, rowmap=rm)
    pred_a2a = cp_cv.a2a_bytes_per_device(4, 8)
    pred_cmp = cp_cv.permute_bytes_per_device(4, 8)
    # at the plan level the reduction is strict
    assert comm_plan(rn, 8, rowmap=rm).moved_entries_per_device("a2a") \
        < comm_plan(rn, 8).moved_entries_per_device("a2a")
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import RoadNet
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.core.partition import plan_rowmap
from repro.launch.hlo_analysis import analyze_hlo
rn = RoadNet(**{ROADNET_SMALL!r})
csr = rn.build_csr()
rm = plan_rowmap(rn, 8, balance="commvol")
ell = build_dist_ell(csr, 4, rowmap=rm, split_halo=True)
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
rng = np.random.default_rng(0)
X0 = rng.standard_normal((rn.D, 8))
Xp = rm.embed(X0)
ys, meas = {{}}, {{}}
with mesh:
    sh = lay.vec_sharding(mesh)
    Xs = jax.device_put(jnp.asarray(Xp), sh)
    for c in ("a2a", "compressed"):
        for o in (False, True):
            f = jax.jit(make_spmv(mesh, lay, ell, comm=c, overlap=o))
            comp = f.lower(Xs).compile()
            h = analyze_hlo(comp.as_text())
            meas[(c, o)] = (int(h.coll_breakdown["all-to-all"]),
                            int(h.coll_breakdown["collective-permute"]))
            ys[(c, o)] = np.asarray(f(Xs))
ref = ys[("a2a", False)]
for k, y in ys.items():
    assert np.array_equal(y, ref), k
assert np.abs(rm.extract(ref) - csr.matvec(X0)).max() < 1e-11
assert meas[("a2a", False)] == ({pred_a2a}, 0), meas
assert meas[("compressed", False)] == (0, {pred_cmp}), meas
print("COMMVOL ENGINES OK", meas)
""")
    assert "COMMVOL ENGINES OK" in out


def test_roadnet_imbalance_and_auto_selects_compressed():
    """The RoadNet family realizes χ₃/χ₂ > 2 at P = 8 (the paper's severe
    comm-imbalance regime) and the χ-driven planner adopts the compressed
    engine for it."""
    rn = RoadNet()  # default D = 48000 instance (the roadnet48k config)
    chim = chi_metrics(rn, 8)
    assert chim.imbalance > 2, chim
    plan = plan_layout(rn, 8, n_search=32)
    assert plan.best.comm == "compressed", plan.report()
    # the compressed candidate's wire bytes undercut a2a by ~the imbalance
    cp = comm_plan(rn, 8)
    ratio = (cp.moved_entries_per_device("a2a")
             / cp.moved_entries_per_device("compressed"))
    assert ratio >= 0.8 * chim.imbalance


def test_empty_pairs_are_skipped():
    """RoadNet's corridor occupies one cyclic shift; all shifts with no
    pattern pairs must be absent from the schedule (no wasted rounds)."""
    rn = RoadNet(**ROADNET_SMALL)
    cp = comm_plan(rn, 8)
    perms, round_L = cp.permute_schedule()
    assert len(perms) < 7  # strictly fewer rounds than all-pairs
    assert all(l > 0 for l in round_L)
    ell = build_dist_ell(rn.build_csr(), 8)
    nbr = ell.neighbor_plan()
    assert nbr.perms == perms and nbr.round_L == round_L


def test_halo_nnz_fraction_mask_only():
    """halo_nnz_fraction comes straight from cols/vals masks — no split
    arrays are materialized — and equals the split-derived count."""
    ell = build_dist_ell(SpinChainXXZ(10, 5).build_csr(), 4)
    frac = ell.halo_nnz_fraction
    assert ell.cols_loc is None  # the property did NOT materialize a split
    cl, vl, ch, vh = ell.split()
    n_halo = int(np.count_nonzero(np.asarray(vh)))
    n_loc = int(np.count_nonzero(np.asarray(vl)))
    assert frac == pytest.approx(n_halo / (n_halo + n_loc))
    assert 0.0 < frac < 1.0


def test_machine_model_fit_recovers_constants():
    """fit() inverts Eq. 12 exactly on synthetic samples; chi-free sample
    sets leave b_c unidentified (inf) instead of garbage."""
    true = pm.MachineModel("true", b_m=819e9, b_c=47e9, kappa=6.3)
    samples = []
    for N_p, n_b, chi in ((8, 8, 2.0), (4, 16, 1.0), (2, 32, 0.4),
                          (8, 8, 0.0)):
        t = pm.cheb_iter_time(true, D=100_000, N_p=N_p, n_b=n_b, chi=chi,
                              n_nzr=13.0, S_d=8)
        samples.append(dict(t=t, D=100_000, N_p=N_p, n_b=n_b, chi=chi,
                            n_nzr=13.0, S_d=8))
    fit = pm.MachineModel.fit(samples, b_m=true.b_m)
    assert fit.b_c == pytest.approx(true.b_c, rel=1e-9)
    assert fit.kappa == pytest.approx(true.kappa, rel=1e-9)
    # round-trip through the JSON format dryrun --fit-machine writes
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "m.json")
        pm.save_machine(fit, path)
        back = pm.load_machine(path)
    assert back == dataclass_replace_name(fit)
    # comm-free samples: kappa fitted, b_c honestly unidentified
    free = [s for s in samples if s["chi"] == 0.0]
    fit0 = pm.MachineModel.fit(free, b_m=true.b_m)
    assert fit0.kappa == pytest.approx(true.kappa, rel=1e-9)
    assert fit0.b_c == float("inf")


def dataclass_replace_name(m: pm.MachineModel) -> pm.MachineModel:
    """fit() stamps name='fitted'; save/load must preserve it verbatim."""
    return pm.MachineModel(name=m.name, b_m=m.b_m, b_c=m.b_c, kappa=m.kappa)


@pytest.mark.slow
def test_fd_solve_compressed_roadnet_8dev():
    """Full FD solve on the RoadNet smoke instance with the compressed
    overlap engine: converges to the dense-eigh spectrum, and the auto
    planner on the full instance picks a compressed candidate."""
    out = run_distributed("""
import numpy as np, jax
from repro.core import FDConfig, FilterDiag, make_solver_mesh
from repro.matrices import RoadNet
mat = RoadNet(n=2000, w=2, m=128, k=4)
csr = mat.build_csr()
w = np.linalg.eigvalsh(csr.to_dense())
tau = float(w[len(w) // 2])
mesh = make_solver_mesh(4, 2)
res = {}
for comm in ("a2a", "compressed"):
    cfg = FDConfig(n_target=4, n_search=16, target=tau, tol=1e-8,
                   max_iters=25, spmv_overlap=True, spmv_comm=comm)
    with mesh:
        res[comm] = FilterDiag(csr, mesh, cfg).solve()
    assert res[comm].n_converged >= 4, (comm, res[comm].n_converged)
    for ev in res[comm].eigenvalues[:4]:
        assert np.abs(w - ev).min() < 1e-7
# both engines walk the identical iteration path
np.testing.assert_array_equal(res["a2a"].eigenvalues,
                              res["compressed"].eigenvalues)
print("FD COMPRESSED OK", res["compressed"].iterations)
""", timeout=1500)
    assert "FD COMPRESSED OK" in out

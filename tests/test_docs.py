"""Documentation gate in the tier-1 loop: runs scripts/check_docs.py —
every module under src/repro has a docstring, README/docs snippets only
reference flags/paths/symbols that actually exist, every FDConfig field
and solve/dryrun CLI flag is documented somewhere in README or docs/,
and all docs/ cross-links resolve."""
import importlib.util
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(ROOT, "scripts", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_consistent():
    cd = _load_check_docs()
    errors = cd.run_all()
    assert not errors, "\n".join(errors)


def test_readme_exists_with_quickstart():
    readme = open(os.path.join(ROOT, "README.md")).read()
    assert "## Quickstart" in readme
    assert 'python -m pytest -x -q' in readme
    assert '-m "not slow"' in readme
    assert "--layout auto" in readme
    # the docs/ subsystem is linked from the README
    assert "docs/comm-engines.md" in readme
    assert "docs/planner.md" in readme
    assert "docs/partitioning.md" in readme


def test_gate_detects_undocumented_and_broken_links(tmp_path):
    """The coverage gate is not vacuous: pointed at an empty README and a
    docs dir with dangling links, it reports every FDConfig field and
    CLI flag as undocumented and flags both kinds of broken link."""
    cd = _load_check_docs()
    fake_readme = tmp_path / "README.md"
    fake_readme.write_text("# empty\n")
    docs = tmp_path / "docs"
    docs.mkdir()
    (docs / "broken.md").write_text(
        "# broken\n[gone](missing.md)\n[bad anchor](broken.md#nope)\n"
        "[good anchor](broken.md#broken)\n")
    cd.README, cd.DOCS_DIR = str(fake_readme), str(docs)
    errs = cd.check_config_and_flags_documented()
    assert any("`spmv_schedule`" in e for e in errs)  # FDConfig field
    assert any("`--spmv-schedule`" in e for e in errs)  # CLI flag
    assert any("`spmv_balance`" in e for e in errs)   # partition field
    assert any("`spmv_reorder`" in e for e in errs)
    assert any("`--spmv-balance`" in e for e in errs)  # partition flags
    assert any("`--spmv-reorder`" in e for e in errs)
    assert any("`spmv_sstep`" in e for e in errs)     # s-step axis
    assert any("`--spmv-sstep`" in e for e in errs)
    link_errs = cd.check_docs_links()
    assert any("missing.md" in e for e in link_errs)
    assert any("#nope" in e for e in link_errs)
    assert not any("#broken" in e for e in link_errs)
    # required headline docs: an empty README (and missing pages) trips
    # both the existence and the navigation check for every page
    doc_errs = cd.check_required_docs()
    assert any("docs/partitioning.md" in e and "does not exist" in e
               for e in doc_errs)
    assert any("docs/partitioning.md" in e and "referenced" in e
               for e in doc_errs)
    assert any("docs/s-step.md" in e and "does not exist" in e
               for e in doc_errs)

"""Documentation gate in the tier-1 loop: runs scripts/check_docs.py —
every module under src/repro has a docstring, and README snippets only
reference flags/paths/symbols that actually exist."""
import importlib.util
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(ROOT, "scripts", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_consistent():
    cd = _load_check_docs()
    errors = cd.run_all()
    assert not errors, "\n".join(errors)


def test_readme_exists_with_quickstart():
    readme = open(os.path.join(ROOT, "README.md")).read()
    assert "## Quickstart" in readme
    assert 'python -m pytest -x -q' in readme
    assert '-m "not slow"' in readme
    assert "--layout auto" in readme

"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with
hypothesis sweeps over shapes/dtypes/offset patterns."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from tests._hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.cheb_dia import cheb_dia
from repro.kernels.ell_gather import build_tiles, ell_gather_spmv


def _mk_dia(rng, R, offsets, dtype):
    dvals = rng.standard_normal((len(offsets), R)).astype(dtype)
    idx = np.arange(R)
    for d, o in enumerate(offsets):
        dvals[d, (idx + o < 0) | (idx + o >= R)] = 0.0
    return dvals


@pytest.mark.parametrize("R,nb,br,bn", [
    (64, 128, 8, 128), (256, 128, 64, 128), (512, 256, 512, 128),
    (1024, 384, 256, 128),
])
def test_cheb_dia_shapes(R, nb, br, bn):
    rng = np.random.default_rng(R + nb)
    offsets = (-(R // 3), -7, -1, 0, 2, 9, R // 4)
    dvals = _mk_dia(rng, R, offsets, np.float32)
    x = rng.standard_normal((R, nb)).astype(np.float32)
    w1 = rng.standard_normal((R, nb)).astype(np.float32)
    w2 = rng.standard_normal((R, nb)).astype(np.float32)
    y_ref = np.asarray(ref.cheb_dia_ref(offsets, dvals, x, w1, w2, 1.1, -0.3))
    y = np.asarray(cheb_dia(offsets, jnp.asarray(dvals), jnp.asarray(x),
                            jnp.asarray(w1), jnp.asarray(w2), 1.1, -0.3,
                            br=br, bn=bn, interpret=True))
    np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)


@given(
    seed=st.integers(0, 10_000),
    roff=st.lists(st.integers(-96, 96), min_size=1, max_size=6, unique=True),
    dtype=st.sampled_from([np.float32, np.float64]),
)
@settings(max_examples=15, deadline=None)
def test_cheb_dia_hypothesis(seed, roff, dtype):
    R, nb = 128, 128
    rng = np.random.default_rng(seed)
    offsets = tuple(sorted(roff))
    dvals = _mk_dia(rng, R, offsets, dtype)
    x = rng.standard_normal((R, nb)).astype(dtype)
    w1 = rng.standard_normal((R, nb)).astype(dtype)
    w2 = rng.standard_normal((R, nb)).astype(dtype)
    a, b = float(rng.normal()), float(rng.normal())
    y_ref = np.asarray(ref.cheb_dia_ref(offsets, dvals, x, w1, w2, a, b))
    y = np.asarray(cheb_dia(offsets, jnp.asarray(dvals), jnp.asarray(x),
                            jnp.asarray(w1), jnp.asarray(w2), a, b,
                            br=64, bn=128, interpret=True))
    tol = 1e-4 if dtype == np.float32 else 1e-10
    np.testing.assert_allclose(y, y_ref, rtol=tol, atol=tol)


def test_cheb_dia_complex_via_ops():
    rng = np.random.default_rng(3)
    R, nb = 128, 128
    offsets = (-8, -1, 0, 1, 8)
    dv = (rng.standard_normal((5, R)) + 1j * rng.standard_normal((5, R))).astype(np.complex64)
    idx = np.arange(R)
    for d, o in enumerate(offsets):
        dv[d, (idx + o < 0) | (idx + o >= R)] = 0.0
    x = (rng.standard_normal((R, nb)) + 1j * rng.standard_normal((R, nb))).astype(np.complex64)
    w1 = x * 0.3
    w2 = x[::-1] * 0.7
    y_ref = np.asarray(ref.cheb_dia_ref(offsets, jnp.asarray(dv), jnp.asarray(x),
                                        jnp.asarray(w1), jnp.asarray(w2), 0.9, 0.05))
    y = np.asarray(ops.cheb_dia(offsets, jnp.asarray(dv), jnp.asarray(x),
                                jnp.asarray(w1), jnp.asarray(w2), 0.9, 0.05,
                                interpret=True))
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)


def test_cheb_dia_halo_region():
    """x longer than R (halo appended) with offsets pointing into it."""
    rng = np.random.default_rng(4)
    R, Rx, nb = 128, 256, 128
    offsets = (0, 100)  # reaches into [R, Rx)
    dvals = rng.standard_normal((2, R)).astype(np.float32)  # all valid: i+100 < 256
    x = rng.standard_normal((Rx, nb)).astype(np.float32)
    w1 = rng.standard_normal((R, nb)).astype(np.float32)
    w2 = rng.standard_normal((R, nb)).astype(np.float32)
    y_ref = np.asarray(ref.cheb_dia_ref(offsets, dvals, x, w1, w2, 1.0, 0.0))
    y = np.asarray(cheb_dia(offsets, jnp.asarray(dvals), jnp.asarray(x),
                            jnp.asarray(w1), jnp.asarray(w2), 1.0, 0.0,
                            br=64, bn=128, interpret=True))
    np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)


@given(seed=st.integers(0, 1000), W=st.integers(1, 12),
       density=st.floats(0.2, 1.0))
@settings(max_examples=10, deadline=None)
def test_ell_gather_tiles(seed, W, density):
    rng = np.random.default_rng(seed)
    R, Rx, nb = 256, 2048, 128
    cols = rng.integers(0, Rx, size=(R, W)).astype(np.int32)
    vals = rng.standard_normal((R, W)).astype(np.float32)
    vals[rng.random((R, W)) >= density] = 0.0
    x = rng.standard_normal((Rx, nb)).astype(np.float32)
    tile_cb, tcols, tvals = build_tiles(cols, vals, Rx, br=256, bc=512)
    y_ref = np.asarray(ref.ell_spmv_ref(jnp.asarray(cols), jnp.asarray(vals),
                                        jnp.asarray(x)))
    y = np.asarray(ell_gather_spmv(jnp.asarray(tile_cb), jnp.asarray(tcols),
                                   jnp.asarray(tvals), jnp.asarray(x),
                                   br=256, bc=512, bn=128, interpret=True))
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)


@given(
    seed=st.integers(0, 10_000),
    W=st.integers(1, 16),
    density=st.floats(0.1, 1.0),
    br=st.sampled_from([8, 32, 64, 256]),
    bc=st.sampled_from([256, 512, 1024]),
)
@settings(max_examples=12, deadline=None)
def test_build_tiles_partition_properties(seed, W, density, br, bc):
    """Tile-format invariants over random (W, density, br, bc):

    * every stored nonzero lands in exactly one tile slot, at its
      original (row, global column, value) — the (row, col, val)
      multisets of the ELL block and the tile batch are equal;
    * every padded slot holds value exactly 0 at a tile-local column
      inside [0, bc) — masked slots contribute a bit-neutral ``+ 0.0``;
    * the tiled contraction is *bit-identical* to the jnp scan reference
      (not just close): the tiles preserve the slot accumulation order.
    """
    rng = np.random.default_rng(seed)
    R, Rx, nb = 256, 2048, 8
    cols = rng.integers(0, Rx, size=(R, W)).astype(np.int32)
    vals = rng.standard_normal((R, W))
    vals[rng.random((R, W)) >= density] = 0.0
    x = rng.standard_normal((Rx, nb))
    tile_cb, tcols, tvals = build_tiles(cols, vals, Rx, br=br, bc=bc)
    RB, T = tile_cb.shape
    got = []
    for rb in range(RB):
        for t in range(T):
            cb = int(tile_cb[rb, t])
            tc, tv = tcols[rb, t], tvals[rb, t]
            assert ((tc >= 0) & (tc < bc)).all()  # tile-local columns
            rr, ww = np.nonzero(tv != 0)
            got += [(rb * br + int(r), cb * bc + int(tc[r, w]),
                     float(tv[r, w])) for r, w in zip(rr, ww)]
    rr, ww = np.nonzero(vals != 0)
    want = [(int(r), int(cols[r, w]), float(vals[r, w]))
            for r, w in zip(rr, ww)]
    assert sorted(got) == sorted(want)
    y_ref = np.asarray(ref.ell_spmv_ref(jnp.asarray(cols), jnp.asarray(vals),
                                        jnp.asarray(x)))
    y = np.asarray(ell_gather_spmv(jnp.asarray(tile_cb), jnp.asarray(tcols),
                                   jnp.asarray(tvals), jnp.asarray(x),
                                   br=br, bc=bc, bn=nb, interpret=True))
    assert np.array_equal(y, y_ref)


def test_ell_spmv_tiled_threads_accumulator_bit_identically():
    """The y0 operand of the tile kernel prepends the accumulator to the
    per-element addition chain — bit-identical to threading the same
    accumulator through the scan reference (the split-phase engines'
    local-then-halo order depends on this)."""
    rng = np.random.default_rng(11)
    R, Rx, nb, br, bc = 64, 512, 8, 8, 256
    cols = rng.integers(0, Rx, size=(R, 5)).astype(np.int32)
    vals = rng.standard_normal((R, 5))
    x = rng.standard_normal((Rx, nb))
    y0 = rng.standard_normal((R, nb))
    tile_cb, tcols, tvals = build_tiles(cols, vals, Rx, br=br, bc=bc)
    y_ref = np.asarray(ref.ell_spmv_acc_ref(jnp.asarray(y0),
                                            jnp.asarray(cols),
                                            jnp.asarray(vals),
                                            jnp.asarray(x)))
    y = np.asarray(ops.ell_spmv_tiled(jnp.asarray(tile_cb),
                                      jnp.asarray(tcols),
                                      jnp.asarray(tvals), jnp.asarray(x),
                                      y0=jnp.asarray(y0), br=br, bc=bc,
                                      interpret=True))
    assert np.array_equal(y, y_ref)


def test_plan_ell_tiles_fallback_seams():
    """plan_ell_tiles returns None exactly at its documented refusal
    seams — abstract operands (the dryrun surrogate), non-float dtypes,
    empty blocks, and rows no br candidate divides — and a real plan
    round-trips through ``.arrays()``."""
    rng = np.random.default_rng(0)
    P, R, W, Rx = 2, 64, 3, 256
    cols = rng.integers(0, Rx, size=(P, R, W)).astype(np.int32)
    vals = rng.standard_normal((P, R, W))
    plan = ops.plan_ell_tiles(cols, vals, Rx)
    assert plan is not None and plan.br in ops.ELL_BR_CANDIDATES
    assert len(plan.arrays()) == 3
    # abstract operands (ShapeDtypeStruct = the dryrun surrogate seam)
    abs_cols = jax.ShapeDtypeStruct(cols.shape, cols.dtype)
    assert ops.plan_ell_tiles(abs_cols, vals, Rx) is None
    assert ops.plan_ell_tiles(cols, jax.ShapeDtypeStruct(
        vals.shape, vals.dtype), Rx) is None
    # non-real-float values (complex ELL blocks keep the jnp path)
    assert ops.plan_ell_tiles(cols, vals.astype(np.complex128), Rx) is None
    # empty block and ragged rows
    assert ops.plan_ell_tiles(cols[:, :, :0], vals[:, :, :0], Rx) is None
    assert ops.plan_ell_tiles(cols[:, :60], vals[:, :60], Rx) is None  # 60: no br
    # a tracer is not concrete either (jit-staged operator arrays)
    assert jax.jit(lambda c: ops.plan_ell_tiles(c, vals, Rx) is None)(cols)


def test_ell_spmv_tiled_ragged_nb_falls_back_to_ref(monkeypatch):
    """On the real-hardware path (interpret=False) a vector count with
    no kernel block (nb=5) must take the scan fallback — pinned by
    making the kernel itself raise — and without the fallback operands
    the seam is a loud ValueError, not silent garbage."""
    rng = np.random.default_rng(1)
    R, Rx, nb = 64, 512, 5
    cols = rng.integers(0, Rx, size=(R, 4)).astype(np.int32)
    vals = rng.standard_normal((R, 4))
    x = rng.standard_normal((Rx, nb))
    tile_cb, tcols, tvals = build_tiles(cols, vals, Rx, br=8, bc=256)

    def boom(*a, **k):
        raise AssertionError("kernel must not be called on the fallback seam")

    monkeypatch.setattr(ops, "ell_gather_spmv", boom)
    y = np.asarray(ops.ell_spmv_tiled(tile_cb, tcols, tvals,
                                      jnp.asarray(x), br=8, bc=256,
                                      cols=jnp.asarray(cols),
                                      vals=jnp.asarray(vals),
                                      interpret=False))
    y_ref = np.asarray(ref.ell_spmv_ref(jnp.asarray(cols), jnp.asarray(vals),
                                        jnp.asarray(x)))
    assert np.array_equal(y, y_ref)
    with pytest.raises(ValueError, match="no kernel-compatible bn"):
        ops.ell_spmv_tiled(tile_cb, tcols, tvals, jnp.asarray(x),
                           br=8, bc=256, interpret=False)


def test_cheb_dia_fallback_seams_never_touch_kernel(monkeypatch):
    """Every documented ref-fallback seam of ops.cheb_dia — ragged R
    (no br), ragged nb (no bn on the hardware path), x rows not a
    multiple of br, force_ref — takes the reference path without
    invoking the Pallas kernel, pinned by making the kernel raise."""
    def boom(*a, **k):
        raise AssertionError("kernel must not be called on a fallback seam")

    monkeypatch.setattr(ops, "_cheb_dia_kernel", boom)
    rng = np.random.default_rng(2)

    def case(R, nb, Rx, **kw):
        offsets = (-1, 0, 1)
        dvals = _mk_dia(rng, R, offsets, np.float64)
        x = rng.standard_normal((Rx, nb))
        w1 = rng.standard_normal((R, nb))
        w2 = rng.standard_normal((R, nb))
        y = np.asarray(ops.cheb_dia(offsets, jnp.asarray(dvals),
                                    jnp.asarray(x), jnp.asarray(w1),
                                    jnp.asarray(w2), 0.9, -0.1, **kw))
        y_ref = np.asarray(ref.cheb_dia_ref(offsets, dvals, x, w1, w2,
                                            0.9, -0.1))
        assert np.array_equal(y, y_ref), (R, nb, Rx, kw)

    case(100, 128, 100, interpret=True)   # ragged R: no br divides 100
    case(128, 100, 128, interpret=False)  # ragged nb on the hardware path
    case(512, 128, 700, interpret=True)   # x rows not a multiple of br=512
    case(128, 128, 128, interpret=True, force_ref=True)


def test_cheb_dia_complex_fallback_decides_once(monkeypatch):
    """A complex operand on a fallback seam runs ONE complex reference
    call — the ref-vs-kernel decision precedes the 4-plane real
    decomposition (the regression this pins: deciding per real plane ran
    four reference calls on every fallback)."""
    calls = []
    real_ref = ref.cheb_dia_ref

    def counting_ref(*a, **k):
        calls.append(a)
        return real_ref(*a, **k)

    monkeypatch.setattr(ref, "cheb_dia_ref", counting_ref)
    rng = np.random.default_rng(5)
    R, nb = 128, 128
    offsets = (-1, 0, 1)
    dv = (rng.standard_normal((3, R))
          + 1j * rng.standard_normal((3, R))).astype(np.complex128)
    x = (rng.standard_normal((R, nb))
         + 1j * rng.standard_normal((R, nb))).astype(np.complex128)
    y = np.asarray(ops.cheb_dia(offsets, jnp.asarray(dv), jnp.asarray(x),
                                jnp.asarray(x * 0.2), jnp.asarray(x * 0.1),
                                0.8, 0.3, interpret=True, force_ref=True))
    assert len(calls) == 1  # one complex ref call, not four real planes
    y_ref = np.asarray(real_ref(offsets, dv, x, x * 0.2, x * 0.1, 0.8, 0.3))
    assert np.array_equal(y, y_ref)


def test_pick_block_and_too_small():
    """_pick_block returns the first dividing candidate or None; the
    interpret-mode _too_small guard trips exactly below 8 rows or an
    empty vector block."""
    assert ops._pick_block(256, (256, 128)) == 256
    assert ops._pick_block(384, (256, 128)) == 128
    assert ops._pick_block(100, (256, 128, 64, 32, 16, 8)) is None
    assert ops._pick_block(100, (256, 128, 64, 32, 16, 8, 4, 2, 1)) == 4
    w = np.zeros((4, 8))
    assert ops._too_small(np.zeros((1, 4)), w)       # R < 8
    assert ops._too_small(np.zeros((1, 16)), np.zeros((16, 0)))  # nb < 1
    assert not ops._too_small(np.zeros((1, 16)), np.zeros((16, 8)))


def test_dia_matches_matrix_family():
    """DIA kernel on the actual Exciton stencil == CSR matvec."""
    from repro.matrices import Exciton
    from repro.matrices.matfree import dia_from_family

    fam = Exciton(L=2)  # D = 375
    offsets, dvals, R = dia_from_family(fam, pad_to=128)
    csr = fam.build_csr()
    rng = np.random.default_rng(0)
    nb = 128
    x = (rng.standard_normal((R, nb)) + 1j * rng.standard_normal((R, nb))).astype(np.complex64)
    x[fam.D:] = 0
    w1 = np.zeros_like(x)
    w2 = np.zeros_like(x)
    y = np.asarray(ops.cheb_dia(tuple(offsets), jnp.asarray(dvals), jnp.asarray(x),
                                jnp.asarray(w1), jnp.asarray(w2), 0.5, 0.0,
                                interpret=True))
    y_ref = csr.matvec(np.asarray(x)[: fam.D])
    np.testing.assert_allclose(y[: fam.D], y_ref, rtol=2e-4, atol=2e-4)

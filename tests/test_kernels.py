"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU), with
hypothesis sweeps over shapes/dtypes/offset patterns."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from tests._hypothesis_compat import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.cheb_dia import cheb_dia
from repro.kernels.ell_gather import build_tiles, ell_gather_spmv


def _mk_dia(rng, R, offsets, dtype):
    dvals = rng.standard_normal((len(offsets), R)).astype(dtype)
    idx = np.arange(R)
    for d, o in enumerate(offsets):
        dvals[d, (idx + o < 0) | (idx + o >= R)] = 0.0
    return dvals


@pytest.mark.parametrize("R,nb,br,bn", [
    (64, 128, 8, 128), (256, 128, 64, 128), (512, 256, 512, 128),
    (1024, 384, 256, 128),
])
def test_cheb_dia_shapes(R, nb, br, bn):
    rng = np.random.default_rng(R + nb)
    offsets = (-(R // 3), -7, -1, 0, 2, 9, R // 4)
    dvals = _mk_dia(rng, R, offsets, np.float32)
    x = rng.standard_normal((R, nb)).astype(np.float32)
    w1 = rng.standard_normal((R, nb)).astype(np.float32)
    w2 = rng.standard_normal((R, nb)).astype(np.float32)
    y_ref = np.asarray(ref.cheb_dia_ref(offsets, dvals, x, w1, w2, 1.1, -0.3))
    y = np.asarray(cheb_dia(offsets, jnp.asarray(dvals), jnp.asarray(x),
                            jnp.asarray(w1), jnp.asarray(w2), 1.1, -0.3,
                            br=br, bn=bn, interpret=True))
    np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)


@given(
    seed=st.integers(0, 10_000),
    roff=st.lists(st.integers(-96, 96), min_size=1, max_size=6, unique=True),
    dtype=st.sampled_from([np.float32, np.float64]),
)
@settings(max_examples=15, deadline=None)
def test_cheb_dia_hypothesis(seed, roff, dtype):
    R, nb = 128, 128
    rng = np.random.default_rng(seed)
    offsets = tuple(sorted(roff))
    dvals = _mk_dia(rng, R, offsets, dtype)
    x = rng.standard_normal((R, nb)).astype(dtype)
    w1 = rng.standard_normal((R, nb)).astype(dtype)
    w2 = rng.standard_normal((R, nb)).astype(dtype)
    a, b = float(rng.normal()), float(rng.normal())
    y_ref = np.asarray(ref.cheb_dia_ref(offsets, dvals, x, w1, w2, a, b))
    y = np.asarray(cheb_dia(offsets, jnp.asarray(dvals), jnp.asarray(x),
                            jnp.asarray(w1), jnp.asarray(w2), a, b,
                            br=64, bn=128, interpret=True))
    tol = 1e-4 if dtype == np.float32 else 1e-10
    np.testing.assert_allclose(y, y_ref, rtol=tol, atol=tol)


def test_cheb_dia_complex_via_ops():
    rng = np.random.default_rng(3)
    R, nb = 128, 128
    offsets = (-8, -1, 0, 1, 8)
    dv = (rng.standard_normal((5, R)) + 1j * rng.standard_normal((5, R))).astype(np.complex64)
    idx = np.arange(R)
    for d, o in enumerate(offsets):
        dv[d, (idx + o < 0) | (idx + o >= R)] = 0.0
    x = (rng.standard_normal((R, nb)) + 1j * rng.standard_normal((R, nb))).astype(np.complex64)
    w1 = x * 0.3
    w2 = x[::-1] * 0.7
    y_ref = np.asarray(ref.cheb_dia_ref(offsets, jnp.asarray(dv), jnp.asarray(x),
                                        jnp.asarray(w1), jnp.asarray(w2), 0.9, 0.05))
    y = np.asarray(ops.cheb_dia(offsets, jnp.asarray(dv), jnp.asarray(x),
                                jnp.asarray(w1), jnp.asarray(w2), 0.9, 0.05,
                                interpret=True))
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)


def test_cheb_dia_halo_region():
    """x longer than R (halo appended) with offsets pointing into it."""
    rng = np.random.default_rng(4)
    R, Rx, nb = 128, 256, 128
    offsets = (0, 100)  # reaches into [R, Rx)
    dvals = rng.standard_normal((2, R)).astype(np.float32)  # all valid: i+100 < 256
    x = rng.standard_normal((Rx, nb)).astype(np.float32)
    w1 = rng.standard_normal((R, nb)).astype(np.float32)
    w2 = rng.standard_normal((R, nb)).astype(np.float32)
    y_ref = np.asarray(ref.cheb_dia_ref(offsets, dvals, x, w1, w2, 1.0, 0.0))
    y = np.asarray(cheb_dia(offsets, jnp.asarray(dvals), jnp.asarray(x),
                            jnp.asarray(w1), jnp.asarray(w2), 1.0, 0.0,
                            br=64, bn=128, interpret=True))
    np.testing.assert_allclose(y, y_ref, rtol=2e-5, atol=2e-5)


@given(seed=st.integers(0, 1000), W=st.integers(1, 12),
       density=st.floats(0.2, 1.0))
@settings(max_examples=10, deadline=None)
def test_ell_gather_tiles(seed, W, density):
    rng = np.random.default_rng(seed)
    R, Rx, nb = 256, 2048, 128
    cols = rng.integers(0, Rx, size=(R, W)).astype(np.int32)
    vals = rng.standard_normal((R, W)).astype(np.float32)
    vals[rng.random((R, W)) >= density] = 0.0
    x = rng.standard_normal((Rx, nb)).astype(np.float32)
    tile_cb, tcols, tvals = build_tiles(cols, vals, Rx, br=256, bc=512)
    y_ref = np.asarray(ref.ell_spmv_ref(jnp.asarray(cols), jnp.asarray(vals),
                                        jnp.asarray(x)))
    y = np.asarray(ell_gather_spmv(jnp.asarray(tile_cb), jnp.asarray(tcols),
                                   jnp.asarray(tvals), jnp.asarray(x),
                                   br=256, bc=512, bn=128, interpret=True))
    np.testing.assert_allclose(y, y_ref, rtol=2e-4, atol=2e-4)


def test_dia_matches_matrix_family():
    """DIA kernel on the actual Exciton stencil == CSR matvec."""
    from repro.matrices import Exciton
    from repro.matrices.matfree import dia_from_family

    fam = Exciton(L=2)  # D = 375
    offsets, dvals, R = dia_from_family(fam, pad_to=128)
    csr = fam.build_csr()
    rng = np.random.default_rng(0)
    nb = 128
    x = (rng.standard_normal((R, nb)) + 1j * rng.standard_normal((R, nb))).astype(np.complex64)
    x[fam.D:] = 0
    w1 = np.zeros_like(x)
    w2 = np.zeros_like(x)
    y = np.asarray(ops.cheb_dia(tuple(offsets), jnp.asarray(dvals), jnp.asarray(x),
                                jnp.asarray(w1), jnp.asarray(w2), 0.5, 0.0,
                                interpret=True))
    y_ref = csr.matvec(np.asarray(x)[: fam.D])
    np.testing.assert_allclose(y[: fam.D], y_ref, rtol=2e-4, atol=2e-4)

"""Fault-tolerance runtime: straggler detection + supervisor restart."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.runtime import HealthMonitor, StepTimer, Supervisor
from repro.runtime.supervisor import SupervisorConfig


def test_step_timer_ewma():
    t = StepTimer(alpha=0.5)
    for dt in (1.0, 1.0, 3.0):
        t.observe(dt)
    assert 1.0 < t.ewma < 3.0
    assert t.count == 3


def test_straggler_detection():
    hm = HealthMonitor(n_hosts=8, k_sigma=3.0)
    for step in range(20):
        for h in range(8):
            hm.report(h, 1.0 + 0.01 * np.sin(h + step) + (2.0 if h == 5 else 0.0))
    assert hm.stragglers() == [5]
    fr = hm.rebalance_fractions()
    assert fr[5] == min(fr)  # straggler gets the smallest share
    assert abs(sum(fr) - 1.0) < 1e-9


def test_dead_host_detection():
    import time

    hm = HealthMonitor(n_hosts=3, heartbeat_timeout=0.05)
    time.sleep(0.1)
    hm.report(0, 1.0)
    dead = hm.dead()
    assert 1 in dead and 2 in dead and 0 not in dead


def test_supervisor_restart_from_checkpoint(tmp_path):
    """Inject a fault at step 7; the run restarts from the last committed
    checkpoint and completes with identical final state to a clean run."""

    def init_state():
        return {"x": jnp.zeros(()), "hist": jnp.zeros(20)}

    def step_fn(state, step):
        return {"x": state["x"] + step, "hist": state["hist"].at[step].set(step)}

    faults = {"armed": True}

    def fault_hook(step):
        if step == 7 and faults["armed"]:
            faults["armed"] = False
            raise RuntimeError("simulated node failure")

    sup = Supervisor(str(tmp_path), SupervisorConfig(checkpoint_interval=3,
                                                     max_restarts=2))
    state, step = sup.run(init_state=init_state, step_fn=step_fn, n_steps=12,
                          fault_hook=fault_hook)
    assert step == 12
    assert sup.restarts == 1
    clean = init_state()
    for i in range(12):
        clean = step_fn(clean, i)
    np.testing.assert_array_equal(np.asarray(state["hist"]), np.asarray(clean["hist"]))
    assert float(state["x"]) == float(clean["x"])


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    def init_state():
        return {"x": jnp.zeros(())}

    def step_fn(state, step):
        raise RuntimeError("always broken")

    sup = Supervisor(str(tmp_path), SupervisorConfig(max_restarts=2))
    with pytest.raises(RuntimeError):
        sup.run(init_state=init_state, step_fn=step_fn, n_steps=3)

"""Statistical test harness of the streaming planner (``core/sketch.py``).

The sampled estimator's contract is statistical, so the tests are too:

  * **convergence** — sampled χ / per-pair ``L_qp`` converge to the
    exact pattern pass as the sample fraction → 1, and at fraction 1
    they are *equal* (the estimator degrades gracefully into the exact
    counter: π = 1, HT weight 1);
  * **coverage** — the advertised :data:`repro.core.sketch.CONF_LEVEL`
    confidence band contains the exact χ at (at least) its advertised
    rate over seeds;
  * **determinism** — same ``(seed, fraction)`` → bit-identical
    estimate, the property the plan cache keys rely on;
  * **plan quality** — the coarsened-descent RowMap's engine-exact wire
    bytes stay within 10% of the exact planner's on every D ≤ 1e6 seed
    family, and the twelve-engine grid stays bit-identical on sampled
    RowMaps (8-device subprocess);
  * **gating** — ``plan_layout`` above the partition gate warns (naming
    ``--plan-mode sampled``), below it and on the sampled path it stays
    silent; ``plan_mode='auto'`` resolves exact below / sampled above.

The slow acceptance test plans AND solves a 10⁷-row matrix-free RoadNet
on the 8-device host mesh through the solve CLI (skipped when the host
lacks the memory headroom).
"""
import warnings

import numpy as np
import pytest

from repro.core.metrics import chi_metrics
from repro.core.partition import partition_plan_default, plan_rowmap
from repro.core.planner import comm_plan, plan_layout
from repro.core.sketch import (CONF_LEVEL, ChiBand, coarsened_commvol_boundaries,
                               default_fraction, estimate_comm,
                               sampled_comm_plan)
from repro.matrices import HubNet, RoadNet, SpinChainXXZ
from tests._hypothesis_compat import given, settings, st
from tests.conftest import run_distributed

ROADNET_SMALL = dict(n=4000, w=2, m=256, k=4)
HUBNET_SMALL = dict(n=4000, w=2, h=4, m=192, k=4)

#: the D ≤ 1e6 seed families every quality assertion sweeps
FAMILIES = [
    ("spinchain", lambda: SpinChainXXZ(12, 6)),
    ("roadnet", lambda: RoadNet(**ROADNET_SMALL)),
    ("hubnet", lambda: HubNet(**HUBNET_SMALL)),
]

ENGINES = (("a2a", "cyclic"), ("compressed", "cyclic"),
           ("compressed", "matching"))


def _rel_err(est, cp_exact) -> float:
    """Worst relative error of the estimate across χ metrics and the
    engine-facing aggregates (L, total n_vc)."""
    errs = [abs(getattr(est.chi, m) - getattr(cp_exact.chi, m))
            / max(getattr(cp_exact.chi, m), 1e-12)
            for m in ("chi1", "chi2", "chi3")]
    errs.append(abs(est.L - cp_exact.L) / max(cp_exact.L, 1))
    errs.append(abs(int(est.n_vc.sum()) - int(cp_exact.n_vc.sum()))
                / max(int(cp_exact.n_vc.sum()), 1))
    return max(errs)


# --------------------------------------------------------------------------
# convergence
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name,make", FAMILIES)
def test_full_fraction_is_exact(name, make):
    """At fraction 1 the sampled pass IS the exact pass: pair_counts,
    n_vc, L, and χ all equal ``comm_plan(exact=True)`` bit-for-bit."""
    matrix = make()
    est = estimate_comm(matrix, 8, fraction=1.0, seed=0)
    cp_e = comm_plan(matrix, 8, exact=True)
    assert np.array_equal(est.pair_counts, cp_e.pair_counts), name
    assert np.array_equal(est.n_vc, cp_e.n_vc), name
    assert est.L == cp_e.L
    for m in ("chi1", "chi2", "chi3"):
        assert getattr(est.chi, m) == pytest.approx(getattr(cp_e.chi, m))
    # and the sampled plan's engine-exact wire numbers match too
    cp_s = est.comm_plan()
    for engine, sched in ENGINES:
        assert cp_s.moved_entries_per_device(engine, sched) \
            == cp_e.moved_entries_per_device(engine, sched), (name, engine)


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(max_examples=6, deadline=None)
def test_sampled_chi_converges_as_fraction_to_one(seed):
    """Property over seeds: the worst χ/L/n_vc relative error shrinks
    along the fraction ladder 0.25 → 0.5 → 1.0 (within a fluctuation
    allowance — separate subsamples), is bounded at half fraction, and
    vanishes at fraction 1."""
    matrix = RoadNet(**ROADNET_SMALL)
    cp_e = comm_plan(matrix, 8, exact=True)
    errs = [_rel_err(estimate_comm(matrix, 8, fraction=f, seed=seed), cp_e)
            for f in (0.25, 0.5, 1.0)]
    assert errs[2] == 0.0
    assert errs[0] <= 0.5, errs
    assert errs[1] <= 0.25, errs


@pytest.mark.parametrize("name,make", FAMILIES)
def test_half_fraction_within_planner_tolerance(name, make):
    """At fraction 0.5 every engine's per-device moved entries stay
    within 20% of exact on all three families — the same contract
    ``scripts/check_comm.py`` gates on."""
    matrix = make()
    cp_s = sampled_comm_plan(matrix, 8, fraction=0.5, seed=0)
    cp_e = comm_plan(matrix, 8, exact=True)
    assert not cp_s.exact and cp_e.exact
    for engine, sched in ENGINES:
        m_s = cp_s.moved_entries_per_device(engine, sched)
        m_e = cp_e.moved_entries_per_device(engine, sched)
        assert abs(m_s - m_e) <= 0.2 * max(m_e, 1), (name, engine, m_s, m_e)


# --------------------------------------------------------------------------
# confidence bands
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name,make", FAMILIES)
def test_band_coverage_at_advertised_rate(name, make):
    """Empirical coverage over seeds ≥ the advertised CONF_LEVEL: the
    band of a fraction-0.35 estimate contains the exact χ (all three
    metrics at once) in at least CONF_LEVEL of 24 seeded draws."""
    matrix = make()
    exact_chi = chi_metrics(matrix, 8)
    seeds = range(24)
    hits = 0
    for seed in seeds:
        est = estimate_comm(matrix, 8, fraction=0.35, seed=seed)
        assert est.band.valid()
        assert est.band.level == CONF_LEVEL
        # a band that excluded its own center would be a broken error
        # model regardless of the truth
        assert est.band.contains(est.chi)
        hits += est.band.contains(exact_chi)
    assert hits / len(seeds) >= CONF_LEVEL, (name, hits)


def test_band_validity_contract():
    """ChiBand.valid() rejects malformed levels and inverted/negative
    intervals; contains() is per-metric conjunction."""
    good = ChiBand(0.8, (0.0, 1.0), (0.5, 2.0), (1.0, 4.0))
    assert good.valid()
    assert not ChiBand(1.0, (0.0, 1.0), (0.5, 2.0), (1.0, 4.0)).valid()
    assert not ChiBand(0.8, (1.0, 0.5), (0.5, 2.0), (1.0, 4.0)).valid()
    assert not ChiBand(0.8, (-0.1, 1.0), (0.5, 2.0), (1.0, 4.0)).valid()
    chi = chi_metrics(RoadNet(**ROADNET_SMALL), 8)
    wide = ChiBand(0.8, (0.0, 1e9), (0.0, 1e9), (0.0, 1e9))
    assert wide.contains(chi)
    miss_one = ChiBand(0.8, (0.0, 1e9), (0.0, 1e9),
                       (chi.chi3 + 1.0, chi.chi3 + 2.0))
    assert not miss_one.contains(chi)


# --------------------------------------------------------------------------
# determinism
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name,make", FAMILIES)
def test_estimates_deterministic_per_seed(name, make):
    """Same (seed, fraction) → bit-identical estimate (pair_counts,
    band, sampled-row count); a different seed re-draws the sample."""
    matrix = make()
    a = estimate_comm(matrix, 8, fraction=0.4, seed=3)
    b = estimate_comm(matrix, 8, fraction=0.4, seed=3)
    assert np.array_equal(a.pair_counts, b.pair_counts)
    assert np.array_equal(a.n_vc, b.n_vc)
    assert a.band == b.band and a.sampled_rows == b.sampled_rows
    c = estimate_comm(matrix, 8, fraction=0.4, seed=4)
    assert c.sampled_rows > 0
    assert not np.array_equal(a.pair_counts, c.pair_counts) \
        or a.band != c.band, "different seeds drew an identical sample"
    # the coarsened descent is deterministic too
    b1 = coarsened_commvol_boundaries(matrix, 8, fraction=0.4, seed=3)
    b2 = coarsened_commvol_boundaries(matrix, 8, fraction=0.4, seed=3)
    assert np.array_equal(b1, b2)


def test_default_fraction_targets_sample_not_nnz():
    """default_fraction covers small instances fully and shrinks toward
    the fixed sample target at generator scale — the sublinearity lever."""
    assert default_fraction(1000, 8) == 1.0
    assert default_fraction(65_536, 8) == 1.0
    f7 = default_fraction(10_000_000, 8)
    assert 0 < f7 < 0.01
    assert f7 * 10_000_000 == pytest.approx(65_536, rel=0.01)


# --------------------------------------------------------------------------
# coarsened descent plan quality
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name,make", FAMILIES)
@pytest.mark.parametrize("P", [4, 8])
def test_sampled_rowmap_wire_within_10pct_of_exact(name, make, P):
    """On every D ≤ 1e6 seed family, the sampled-path RowMap's
    **engine-exact** wire bytes (full-pattern ``comm_plan`` evaluated on
    the sampled map) are within 10% of the exact planner's map, for all
    three engines — and never worse than equal rows on the composite
    objective the descent minimizes."""
    matrix = make()
    rm_s = plan_rowmap(matrix, P, balance="commvol", plan_mode="sampled")
    rm_e = plan_rowmap(matrix, P, balance="commvol")
    cp_s = comm_plan(matrix, P, rowmap=rm_s) if not rm_s.identity \
        else comm_plan(matrix, P)
    cp_e = comm_plan(matrix, P, rowmap=rm_e) if not rm_e.identity \
        else comm_plan(matrix, P)
    cp_rows = comm_plan(matrix, P)

    def wire(cp):
        return sum(cp.moved_entries_per_device(e, s) for e, s in ENGINES)

    for engine, sched in ENGINES:
        m_s = cp_s.moved_entries_per_device(engine, sched)
        m_e = cp_e.moved_entries_per_device(engine, sched)
        assert m_s <= 1.10 * max(m_e, 1), (name, P, engine, m_s, m_e)
    assert wire(cp_s) <= wire(cp_rows), (name, P)


def test_coarsened_boundaries_are_valid_cuts():
    """Boundaries are monotone, span [0, D], have P+1 entries, and the
    trivial regimes (P = 1, D ≤ P) collapse to equal cuts."""
    matrix = RoadNet(**ROADNET_SMALL)
    b = coarsened_commvol_boundaries(matrix, 8, fraction=0.5, seed=0)
    assert b.shape == (9,) and b[0] == 0 and b[-1] == matrix.D
    assert (np.diff(b) > 0).all()
    assert np.array_equal(coarsened_commvol_boundaries(matrix, 1),
                          np.array([0, matrix.D]))


# --------------------------------------------------------------------------
# gating: the warning and the plan_mode axis
# --------------------------------------------------------------------------


def _big_family():
    # past PARTITION_PLAN_MAX_D = 1e6 but cheap to sample (w=1 band)
    return RoadNet(n=1_200_000, w=1, m=400, k=2)


def test_plan_layout_warns_above_gate_and_names_the_escape_hatch():
    """Exact planning above the partition gate drops the balance axis
    with a UserWarning naming the gate constants and --plan-mode
    sampled. The sampled-χ comm pass is pre-seeded via n_vc_by_row so
    the test never pays a full pattern pass."""
    fam = _big_family()
    assert not partition_plan_default(fam, 2)
    n_vc = {2: np.array([400, 400], dtype=np.int64)}
    with pytest.warns(UserWarning, match="--plan-mode sampled"):
        plan_layout(fam, 2, n_search=4, splits=[(2, 1)],
                    n_vc_by_row=n_vc, plan_mode="exact")
    with pytest.warns(UserWarning, match="PARTITION_PLAN_MAX_D"):
        plan_layout(fam, 2, n_search=4, splits=[(2, 1)],
                    n_vc_by_row=n_vc, plan_mode="exact")


def test_plan_layout_silent_below_gate_and_on_sampled_path():
    """No warning below the gate (exact) nor above it when the caller
    took the escape hatch (plan_mode='sampled')."""
    small = RoadNet(**ROADNET_SMALL)
    with warnings.catch_warnings():
        warnings.simplefilter("error", UserWarning)
        plan_layout(small, 2, n_search=4, splits=[(2, 1)])
        plan_layout(_big_family(), 2, n_search=4, splits=[(2, 1)],
                    plan_mode="sampled")


def test_plan_mode_auto_resolves_by_gate():
    """partition_plan_default with a plan_mode: exact keeps the gate,
    sampled/auto always plan."""
    big, small = _big_family(), RoadNet(**ROADNET_SMALL)
    assert partition_plan_default(small, 8, "exact")
    assert not partition_plan_default(big, 8, "exact")
    for mode in ("sampled", "auto"):
        assert partition_plan_default(big, 8, mode)
        assert partition_plan_default(small, 8, mode)
    with pytest.raises(ValueError, match="rcm"):
        plan_rowmap(small, 4, balance="commvol", reorder="rcm",
                    plan_mode="sampled")


def test_auto_mode_below_gate_matches_exact_bit_for_bit():
    """On the seed families plan_mode='auto' (and even 'sampled', whose
    default fraction covers these D fully) plans the identical RowMap to
    'exact' — the byte-compatibility contract of the CLI default."""
    for name, make in FAMILIES:
        matrix = make()
        assert default_fraction(matrix.D, 8) == 1.0
        rm_e = plan_rowmap(matrix, 8, balance="commvol", plan_mode="exact")
        rm_a = plan_rowmap(matrix, 8, balance="commvol", plan_mode="auto")
        assert np.array_equal(rm_e.boundaries, rm_a.boundaries), name


# --------------------------------------------------------------------------
# twelve-engine bit-identity on a sampled RowMap
# --------------------------------------------------------------------------


def test_twelve_engines_bit_identical_on_sampled_rowmap():
    """The full engine grid {a2a, compressed-cyclic, compressed-matching}
    × {plain, overlap} × {kernel off, on} stays bit-for-bit identical on
    a RowMap planned by the *sampled* path at forced half fraction (so
    the map genuinely comes from a subsample), and extract() recovers
    the CSR matvec — the acceptance criterion's grid check."""
    rn = RoadNet(**ROADNET_SMALL)
    rm = plan_rowmap(rn, 8, balance="commvol", plan_mode="sampled",
                     sample_fraction=0.5)
    out = run_distributed(f"""
import numpy as np, jax, jax.numpy as jnp
from repro.matrices import RoadNet
from repro.core import make_solver_mesh, panel, build_dist_ell, make_spmv
from repro.core.partition import plan_rowmap
rn = RoadNet(**{ROADNET_SMALL!r})
csr = rn.build_csr()
rm = plan_rowmap(rn, 8, balance="commvol", plan_mode="sampled",
                 sample_fraction=0.5)
ell = build_dist_ell(csr, 4, rowmap=rm, split_halo=True)
mesh = make_solver_mesh(4, 2)
lay = panel(mesh)
rng = np.random.default_rng(0)
X0 = rng.standard_normal((rn.D, 8))
Xp = rm.embed(X0)
ENGINES = [(c, s, o, k) for c, s in (("a2a", "cyclic"),
                                     ("compressed", "cyclic"),
                                     ("compressed", "matching"))
           for o in (False, True) for k in (False, True)]
with mesh:
    sh = lay.vec_sharding(mesh)
    Xs = jax.device_put(jnp.asarray(Xp), sh)
    Y = {{}}
    for c, s, o, k in ENGINES:
        f = jax.jit(make_spmv(mesh, lay, ell, comm=c, schedule=s,
                              overlap=o, use_kernel=k))
        Y[(c, s, o, k)] = np.asarray(f(Xs))
base = Y[("a2a", "cyclic", False, False)]
assert np.abs(rm.extract(base) - csr.matvec(X0)).max() < 1e-11
for key, y in Y.items():
    assert np.array_equal(y, base), key
print("SAMPLED ROWMAP TWELVE ENGINES OK")
""", timeout=1500)
    assert "SAMPLED ROWMAP TWELVE ENGINES OK" in out


# --------------------------------------------------------------------------
# the 10^7-row acceptance run
# --------------------------------------------------------------------------


def _mem_available_gb() -> float:
    try:
        with open("/proc/meminfo") as f:
            for line in f:
                if line.startswith("MemAvailable:"):
                    return int(line.split()[1]) / 1e6
    except OSError:
        pass
    return 0.0


@pytest.mark.slow
def test_solve_cli_plans_and_solves_1e7_matfree():
    """A D = 10⁷ matrix-free RoadNet plans (--plan-mode sampled) and
    solves one macro-iteration on the 8-device host mesh through the
    real CLI — no CSR is ever materialized (the family streams windowed
    row_entries into the shard builds)."""
    if _mem_available_gb() < 6.0:
        pytest.skip("needs ~6 GB available memory for the 1e7 panels")
    import os
    import subprocess
    import sys

    from tests.conftest import SRC

    env = dict(os.environ, PYTHONPATH=SRC,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.solve", "--family", "RoadNet",
         "--params", "n=10000000,w=1,m=1200,k=2", "--layout", "auto",
         "--plan-mode", "sampled", "--n-target", "2", "--n-search", "8",
         "--max-iters", "1"],
        env=env, capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"

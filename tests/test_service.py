"""Eigensolve service: plan cache, batching demux, fault-injection resume.

Three concerns, matching the service contract:

  * the **plan cache** — pattern hashing is slot-order invariant and
    size/family distinct, Plan JSON round-trips losslessly (verified down
    to ``comm_plan`` bytes recomputed from the restored RowMap), a cache
    hit never calls the planner, and a corrupt store degrades to a miss
    on read / an explicit refusal on write;
  * **batching** — compatible requests share one panel and demux
    bit-identically to solo solves (in-process here; on the real
    8-device mesh in the slow subprocess test);
  * **fault injection** — a job killed at an injected iteration (and, in
    the harsher variant, with the newest checkpoint's ``_COMMITTED``
    marker destroyed) resumes from the last committed step and converges
    to bit-identical Ritz values.
"""
import json
import os

import numpy as np
import pytest

import jax

from repro.core import FDConfig, FilterDiag, make_solver_mesh
from repro.core import perf_model as pm
from repro.core.planner import comm_plan, plan_layout
from repro.matrices import get_family
from repro.matrices.sparse import CSR
from repro.runtime import StragglerWatchdog, Supervisor, SupervisorConfig
from repro.service import (
    CACHE_VERSION,
    EigenService,
    FilterDiagJob,
    PlanCache,
    SolveRequest,
    cache_key,
    cached_plan_layout,
    machine_fingerprint,
    pattern_hash,
    plan_from_json,
    plan_to_json,
)
from repro.service import plan_cache as plan_cache_mod
from tests._hypothesis_compat import given, settings, st


# ------------------------------------------------------- pattern hash --


def _random_csr(D: int, seed: int, avg_deg: int = 4) -> CSR:
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    for r in range(D):
        deg = rng.integers(1, 2 * avg_deg)
        c = rng.integers(0, D, size=deg)
        rows.append(np.full(len(c), r)), cols.append(c)
    rows, cols = np.concatenate(rows), np.concatenate(cols)
    indptr = np.zeros(D + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    return CSR(indptr=np.cumsum(indptr), indices=cols.astype(np.int64),
               data=None, shape=(D, D))


def _shuffle_within_rows(m: CSR, seed: int) -> CSR:
    """Same pattern, different ELL slot order: permute each row's entries."""
    rng = np.random.default_rng(seed)
    idx = np.concatenate([
        m.indptr[r] + rng.permutation(m.indptr[r + 1] - m.indptr[r])
        for r in range(m.shape[0])
    ]) if m.shape[0] else np.zeros(0, dtype=np.int64)
    return CSR(indptr=m.indptr, indices=m.indices[idx], data=None,
               shape=m.shape)


@settings(max_examples=8)
@given(D=st.integers(min_value=2, max_value=60),
       seed=st.integers(min_value=0, max_value=10**6))
def test_pattern_hash_slot_order_invariant(D, seed):
    """The hash sees the canonical pattern, not the storage order."""
    m = _random_csr(D, seed)
    assert pattern_hash(m) == pattern_hash(_shuffle_within_rows(m, seed + 1))
    # duplicated entries collapse to the same canonical pattern too
    dup = CSR(indptr=m.indptr * 2,
              indices=np.repeat(m.indices, 2),
              data=None, shape=m.shape)
    assert pattern_hash(m) == pattern_hash(dup)


def test_pattern_hash_distinct_across_families_and_sizes():
    mats = [
        get_family("SpinChainXXZ", n_sites=8, n_up=4),
        get_family("SpinChainXXZ", n_sites=10, n_up=5),
        get_family("RoadNet", n=500, w=2, m=64, k=4),
        get_family("HubNet", n=500, w=2, h=4, m=48, k=4),
    ]
    hashes = [pattern_hash(m) for m in mats]
    assert len(set(hashes)) == len(hashes)


# -------------------------------------------------- plan serialization --


@settings(max_examples=3)
@given(spec=st.sampled_from([
    ("SpinChainXXZ", dict(n_sites=8, n_up=4)),
    ("RoadNet", dict(n=500, w=2, m=64, k=4)),
    ("HubNet", dict(n=500, w=2, h=4, m=48, k=4)),
]))
def test_plan_roundtrip_lossless(spec):
    """plan -> JSON -> plan preserves every candidate (scalars AND the
    RowMap), verified independently by recomputing the comm plan from the
    restored RowMap: byte counts must reproduce exactly."""
    family, params = spec
    mat = get_family(family, **params)
    D = mat.shape[0] if hasattr(mat, "shape") else mat.D
    plan = plan_layout(mat, 8, n_search=16, d_pad=-(-D // 8) * 8)
    plan2 = plan_from_json(json.loads(json.dumps(plan_to_json(plan))))
    assert plan2.candidates == plan.candidates  # scalar fields (frozen eq)
    for c, c2 in zip(plan.candidates, plan2.candidates):
        if c.rowmap is None:
            assert c2.rowmap is None
            continue
        np.testing.assert_array_equal(c.rowmap.perm, c2.rowmap.perm)
        np.testing.assert_array_equal(c.rowmap.boundaries,
                                      c2.rowmap.boundaries)
        assert (c.rowmap.R, c.rowmap.sstep) == (c2.rowmap.R, c2.rowmap.sstep)
    best, best2 = plan.best, plan2.best
    if best2.rowmap is not None:       # mirror plan_layout's comm_plan calls
        cp = comm_plan(mat, best2.n_row, rowmap=best2.rowmap)
    else:
        cp = comm_plan(mat, best2.n_row, d_pad=-(-D // 8) * 8,
                       sstep=best2.sstep)
    S_d = getattr(mat, "S_d", 8)
    n_b = plan.n_search // best2.n_col
    assert (cp.comm_bytes_per_device(best2.comm, n_b, S_d, best2.schedule)
            == best.comm_bytes_per_device)


# ------------------------------------------------------- cache behavior --


def _spin_mat():
    return get_family("SpinChainXXZ", n_sites=8, n_up=4)


def test_cache_hit_skips_planner(tmp_path, monkeypatch):
    """Second identical request comes from disk: plan_layout not called,
    and the cached plan selects the byte-identical engine cell."""
    calls = {"n": 0}
    real = plan_cache_mod.planner.plan_layout

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(plan_cache_mod.planner, "plan_layout", counting)
    cache = PlanCache(str(tmp_path / "plans.json"))
    mat = _spin_mat()
    plan1, hit1 = cached_plan_layout(mat, 4, n_search=8, cache=cache)
    plan2, hit2 = cached_plan_layout(mat, 4, n_search=8, cache=cache)
    assert (hit1, hit2) == (False, True)
    assert calls["n"] == 1 and cache.plan_calls == 1
    assert cache.hits == 1 and cache.misses == 1
    assert plan2.candidates == plan1.candidates
    assert plan2.best == plan1.best
    # different n_search is a different key -> planner runs again
    _, hit3 = cached_plan_layout(mat, 4, n_search=16, cache=cache)
    assert not hit3 and calls["n"] == 2


def test_cache_version_bump_invalidates(tmp_path, monkeypatch):
    cache = PlanCache(str(tmp_path / "plans.json"))
    mat = _spin_mat()
    _, hit = cached_plan_layout(mat, 4, n_search=8, cache=cache)
    assert not hit
    monkeypatch.setattr(plan_cache_mod, "CACHE_VERSION", CACHE_VERSION + 1)
    _, hit = cached_plan_layout(mat, 4, n_search=8, cache=cache)
    assert not hit, "a version bump must miss, never misapply old plans"


def test_cache_key_machine_fingerprint(tmp_path):
    """A re-calibrated machine model (same name, new constants) must not
    hit plans fit to the old constants."""
    m1 = pm.TPU_V5E
    m2 = pm.MachineModel(name=m1.name, b_m=m1.b_m, b_c=m1.b_c,
                         kappa=m1.kappa * 1.01, alpha=m1.alpha)
    assert machine_fingerprint(m1) != machine_fingerprint(m2)
    assert (cache_key("ph", 8, m1, n_search=16)
            != cache_key("ph", 8, m2, n_search=16))


def test_corrupt_store_miss_on_get_refuse_on_put(tmp_path):
    path = tmp_path / "plans.json"
    mat = _spin_mat()
    cache = PlanCache(str(path))
    plan, _ = cached_plan_layout(mat, 4, n_search=8, cache=cache)
    # truncated write / garbage: reads degrade to a miss ...
    path.write_text("{not json")
    assert cache.get("anything") is None
    # ... and writes refuse to merge into corruption
    with pytest.raises(ValueError, match="refusing to merge"):
        cache.put("k", plan)
    # schema-invalid (valid JSON): same contract
    path.write_text(json.dumps({"schema": "bogus", "entries": {}}))
    assert cache.get("anything") is None
    with pytest.raises(ValueError, match="refusing to merge"):
        cache.put("k", plan)


def test_merge_on_write_keeps_existing_entries(tmp_path):
    cache = PlanCache(str(tmp_path / "plans.json"))
    mat = _spin_mat()
    cached_plan_layout(mat, 4, n_search=8, cache=cache)
    cached_plan_layout(mat, 4, n_search=16, cache=cache)
    with open(cache.path) as f:
        store = json.load(f)
    assert len(store["entries"]) == 2


def test_concurrent_writers_lose_no_records(tmp_path):
    """Several *processes* merging into one store concurrently: every
    record survives (the put path read-merge-writes under an exclusive
    lock) and no reader ever observes a torn file (writes land via
    atomic tmp+rename, so a concurrent load parses a complete store or
    none)."""
    import subprocess
    import sys

    from tests.conftest import SRC

    path = tmp_path / "plans.json"
    cache = PlanCache(str(path))
    mat = _spin_mat()
    plan, _ = cached_plan_layout(mat, 4, n_search=8, cache=cache)
    plan_json = json.dumps(plan_to_json(plan))
    (tmp_path / "plan.json").write_text(plan_json)
    n_writers, n_keys = 6, 5
    script = (
        "import json, sys\n"
        "from repro.service import PlanCache, plan_from_json\n"
        "wid = int(sys.argv[1])\n"
        f"plan = plan_from_json(json.load(open({str(tmp_path / 'plan.json')!r})))\n"
        f"cache = PlanCache({str(path)!r})\n"
        f"for j in range({n_keys}):\n"
        "    cache.put(f'writer{wid}-key{j}', plan)\n"
    )
    procs = [subprocess.Popen([sys.executable, "-c", script, str(i)],
                              env=dict(os.environ, PYTHONPATH=SRC),
                              stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE, text=True)
             for i in range(n_writers)]
    # poll the store while the writers race: every observed state must
    # be complete, parseable JSON (the atomic-rename contract)
    while any(p.poll() is None for p in procs):
        if path.exists():
            try:
                store = json.loads(path.read_text())
            except ValueError as e:  # pragma: no cover - the defect
                for p in procs:
                    p.kill()
                raise AssertionError(f"torn store observed mid-race: {e}")
            assert "entries" in store
    for p in procs:
        out, err = p.communicate()
        assert p.returncode == 0, f"writer failed:\n{out}\n{err}"
    store = json.loads(path.read_text())
    keys = {f"writer{i}-key{j}"
            for i in range(n_writers) for j in range(n_keys)}
    missing = keys - set(store["entries"])
    assert not missing, f"concurrent merge lost {len(missing)}: {missing}"
    # and every record is still a loadable, well-formed plan
    fresh = PlanCache(str(path))
    for k in sorted(keys):
        got = fresh.get(k)
        assert got is not None and got.best == plan.best, k


def test_sampled_plan_keys_distinct_from_exact(tmp_path):
    """plan_mode is part of the cache key: a sampled plan of a pattern
    never hits the exact plan of the same pattern (and vice versa),
    while each mode hits itself."""
    cache = PlanCache(str(tmp_path / "plans.json"))
    mat = _spin_mat()
    _, hit_e = cached_plan_layout(mat, 4, n_search=8, cache=cache,
                                  plan_mode="exact")
    _, hit_s = cached_plan_layout(mat, 4, n_search=8, cache=cache,
                                  plan_mode="sampled")
    assert (hit_e, hit_s) == (False, False), \
        "sampled plan hit the exact entry of the same pattern"
    _, hit_e2 = cached_plan_layout(mat, 4, n_search=8, cache=cache,
                                   plan_mode="exact")
    _, hit_s2 = cached_plan_layout(mat, 4, n_search=8, cache=cache,
                                   plan_mode="sampled")
    assert (hit_e2, hit_s2) == (True, True)
    assert cache.plan_calls == 2
    assert cache_key("ph", 4, pm.TPU_V5E, n_search=8, plan_mode="exact") \
        != cache_key("ph", 4, pm.TPU_V5E, n_search=8, plan_mode="sampled")


def test_probe_pattern_hash_above_threshold():
    """Families past PATTERN_HASH_PROBE_D hash from a deterministic row
    probe (milliseconds at D = 10⁷): stable across calls, distinct
    across sizes and families, and orthogonal to the full-pattern hash
    space used below the threshold."""
    from repro.matrices import HubNet, RoadNet

    big = RoadNet(n=3_000_000, w=1, m=400, k=2)
    assert big.D > plan_cache_mod.PATTERN_HASH_PROBE_D
    h1, h2 = pattern_hash(big), pattern_hash(big)
    assert h1 == h2
    assert h1 != pattern_hash(RoadNet(n=3_000_001, w=1, m=400, k=2))
    assert h1 != pattern_hash(HubNet(n=3_000_000, w=1, h=4, m=400, k=2))
    # below the threshold the full pattern pass is used — the small
    # family's hash is unaffected by the probe fast path
    small = RoadNet(n=4000, w=2, m=256, k=4)
    assert pattern_hash(small) == pattern_hash(small.build_csr())


# --------------------------------------------- fault-injection resume --


def _make_fd(n_search=8, max_iters=30, seed=3):
    mat = _spin_mat()
    cfg = FDConfig(n_search=n_search, n_target=4, target=-1.5, tol=1e-8,
                   max_iters=max_iters, seed=seed)
    mesh = make_solver_mesh(1, 1)
    return FilterDiag(mat, mesh, cfg)


def test_fault_injection_resume_bit_identical(tmp_path):
    """Kill the job at injected iteration k; the supervisor restores the
    last committed step and the finished Ritz values match the
    uninterrupted run exactly (same ops on bit-identically restored
    state)."""
    clean = _make_fd().solve()
    assert clean.n_converged == 4

    faults = {"armed": True}

    def fault_hook(step):
        if step == 4 and faults["armed"]:
            faults["armed"] = False
            raise RuntimeError("simulated node failure mid-sweep")

    sup = Supervisor(str(tmp_path), SupervisorConfig(checkpoint_interval=1,
                                                     max_restarts=2))
    job = FilterDiagJob(_make_fd())
    state = sup.run_job(job, fault_hook=fault_hook)
    assert sup.restarts == 1 and not faults["armed"]
    res = job.result(state)
    np.testing.assert_array_equal(res.eigenvalues, clean.eigenvalues)
    np.testing.assert_array_equal(res.residuals, clean.residuals)
    assert res.iterations == clean.iterations


def test_crash_mid_checkpoint_falls_back_to_committed(tmp_path):
    """The harsher crash: the failure also destroys the newest
    checkpoint's ``_COMMITTED`` marker (a mid-write crash). Resume must
    use the previous committed step — and still finish bit-identically."""
    clean = _make_fd().solve()

    faults = {"armed": True}

    def fault_hook(step):
        if step >= 5 and faults["armed"]:
            faults["armed"] = False
            newest = max(n for n in os.listdir(tmp_path)
                         if n.startswith("step_") and not n.endswith(".tmp"))
            os.remove(tmp_path / newest / "_COMMITTED")
            raise RuntimeError("node died while committing")

    sup = Supervisor(str(tmp_path), SupervisorConfig(checkpoint_interval=1,
                                                     max_restarts=2))
    job = FilterDiagJob(_make_fd())
    state = sup.run_job(job, fault_hook=fault_hook)
    assert sup.restarts == 1
    res = job.result(state)
    np.testing.assert_array_equal(res.eigenvalues, clean.eigenvalues)
    np.testing.assert_array_equal(res.residuals, clean.residuals)


def test_resume_refuses_mismatched_rowmap(tmp_path):
    """A checkpoint written under one row decomposition must not silently
    continue under another."""
    from repro.core.partition import plan_rowmap
    from repro.service.jobs import pack_state, unpack_state

    fd = _make_fd()
    state = fd.init_state()
    tree, extra = pack_state(state, fd)
    mat = _spin_mat()
    rm = plan_rowmap(mat, 2, balance="commvol")
    cfg = FDConfig(n_search=8, spmv_balance="commvol")
    fd2 = FilterDiag(mat, make_solver_mesh(1, 1), cfg, rowmap=rm)
    with pytest.raises(ValueError, match="rowmap"):
        unpack_state(tree, extra, fd2)


def test_straggler_watchdog_flags_spike():
    wd = StragglerWatchdog(k_sigma=3.0, warmup=3, min_slack=1e-3)
    assert not any(wd.observe(i, 0.1) for i in range(8))
    assert wd.observe(8, 0.5)          # 5x spike after a steady baseline
    assert wd.flagged and wd.flagged[-1][0] == 8


# ----------------------------------------------------- batching demux --


_REQS = dict(family="SpinChainXXZ", params=dict(n_sites=8, n_up=4),
             n_target=3, n_search=8, tol=1e-8, max_iters=30)


def test_duplicate_request_id_rejected():
    svc = EigenService()
    svc.submit(SolveRequest("a", **_REQS))
    with pytest.raises(ValueError, match="duplicate"):
        svc.submit(SolveRequest("a", **_REQS))


def test_batched_demux_matches_solo_inprocess(tmp_path):
    """Two co-batched requests (different targets/seeds/degrees) demux to
    the exact solo results; the shared plan comes through the cache."""
    cache = PlanCache(str(tmp_path / "plans.json"))

    def run(ids):
        svc = EigenService(plan_cache=cache,
                           ckpt_root=str(tmp_path / ("_".join(ids))))
        reqs = {"a": SolveRequest("a", **_REQS, target=-1.5, seed=11),
                "b": SolveRequest("b", **_REQS, target=0.5, seed=22)}
        for i in ids:
            svc.submit(reqs[i])
        return svc.drain()

    both = run(["a", "b"])
    solo_a = run(["a"])["a"]
    solo_b = run(["b"])["b"]
    for solo, rid in ((solo_a, "a"), (solo_b, "b")):
        np.testing.assert_array_equal(both[rid].eigenvalues, solo.eigenvalues)
        np.testing.assert_array_equal(both[rid].residuals, solo.residuals)
        assert both[rid].iterations == solo.iterations
        assert both[rid].total_spmvs == solo.total_spmvs
    # one pattern, three drains: planned exactly once
    assert cache.plan_calls == 1 and cache.hits >= 2


@pytest.mark.slow
def test_batched_demux_bit_identical_8dev():
    """Acceptance: on the 8-device mesh the batched panel demuxes
    bit-identically to solo solves — same planned engine cell, extra
    columns only."""
    from tests.conftest import run_distributed

    out = run_distributed("""
import numpy as np
from repro.service import EigenService, SolveRequest

REQS = dict(family="SpinChainXXZ", params=dict(n_sites=10, n_up=5),
            n_target=3, n_search=16, tol=1e-8, max_iters=30)

def run(ids):
    svc = EigenService()
    reqs = {"a": SolveRequest("a", **REQS, target=-3.0, seed=11),
            "b": SolveRequest("b", **REQS, target=0.0, seed=22)}
    for i in ids:
        svc.submit(reqs[i])
    return svc.drain()

both = run(["a", "b"])
solo = {"a": run(["a"])["a"], "b": run(["b"])["b"]}
for rid in ("a", "b"):
    assert np.array_equal(both[rid].eigenvalues, solo[rid].eigenvalues), rid
    assert np.array_equal(both[rid].residuals, solo[rid].residuals), rid
    assert both[rid].iterations == solo[rid].iterations
print("DEMUX OK", both["a"].iterations, both["b"].iterations)
""", timeout=1800)
    assert "DEMUX OK" in out

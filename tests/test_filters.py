"""Filter polynomial construction (window Chebyshev expansion + Jackson)."""
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core.filters import (build_filter, degree_for, jackson_damping,
                                window_coeffs)


def _cheb_eval(mu, x):
    t = np.arccos(np.clip(x, -1, 1))
    return np.cos(np.outer(t, np.arange(len(mu)))) @ mu


@given(a=st.floats(-0.9, 0.5), w=st.floats(0.05, 0.4), n=st.integers(40, 200))
@settings(max_examples=20, deadline=None)
def test_window_coeffs_approximate_indicator(a, w, n):
    b = min(a + w, 0.95)
    mu = window_coeffs(a, b, n) * jackson_damping(n)
    xs = np.linspace(-0.99, 0.99, 801)
    y = _cheb_eval(mu, xs)
    inside = (xs > a + 3.5 / n) & (xs < b - 3.5 / n)
    outside = (xs < a - 3.5 / n) | (xs > b + 3.5 / n)
    if inside.any():
        assert y[inside].min() > 0.4
    if outside.any():
        assert np.abs(y[outside]).max() < 0.55
        # far outside, the Jackson-damped filter is tiny
        far = (xs < a - 12 / n) | (xs > b + 12 / n)
        if far.any():
            assert np.abs(y[far]).max() < 0.12


def test_filterpoly_eval_matches_direct():
    poly = build_filter((-0.1, 0.1), (-2.0, 2.0), degree=64)
    lam = np.linspace(-1.9, 1.9, 100)
    x = 2.0 / 4.0 * lam  # alpha*lam + beta with beta=0
    np.testing.assert_allclose(poly.eval(lam), _cheb_eval(poly.mu, x),
                               rtol=1e-10, atol=1e-12)


@given(w1=st.floats(1e-4, 0.1), w2=st.floats(1e-4, 0.1))
@settings(max_examples=20, deadline=None)
def test_degree_monotone_in_width(w1, w2):
    inc = (-1.0, 1.0)
    d1 = degree_for((-w1, w1), inc)
    d2 = degree_for((-w2, w2), inc)
    if w1 < w2:
        assert d1 >= d2
    assert d1 % 32 == 0  # bucketing bounds recompiles


def test_filter_amplifies_target_over_rest():
    poly = build_filter((0.2, 0.3), (-1.0, 1.0), degree=160)
    inside = poly.eval(np.array([0.25]))[0]
    far = np.abs(poly.eval(np.linspace(-0.9, -0.1, 50))).max()
    assert inside > 10 * far

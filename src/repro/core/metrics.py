"""Communication metrics χ₁, χ₂, χ₃ (paper Eqs. 8–10).

Computed directly from the matrix sparsity pattern, prior to running any
code. All metrics are zero for N_p = 1. The metrics depend only on the row
partition (uniform by default, Eq. 1).

    χ₁ = max_p  n_vc(p) / n_vm(p)          (remote / local accesses)
    χ₂ = Σ_p    n_vc(p) / D                (aggregate comm volume / D)
    χ₃ = N_p · max_p n_vc(p) / D           (parallel-efficiency bound)

Equivalences (paper §3.1): χ₁ ≈ χ₃ since n_vm ≈ D/N_p; χ₂ ≈ χ₃ unless the
communication volume is imbalanced — ``imbalance`` > 2…3 signals that the
partition should be re-balanced: ``balance="commvol"`` in the partition
planner (``core/partition.py``), whose planned boundaries/block sizes
feed back into these same metrics via ``planner.comm_plan(rowmap=...)``.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from ..matrices.families import MatrixFamily
from ..matrices.sparse import CSR, uniform_partition

__all__ = ["ChiMetrics", "chi_metrics", "chi_from_nvc", "chi_bruteforce", "chi_sweep"]


@dataclasses.dataclass(frozen=True)
class ChiMetrics:
    N_p: int
    D: int
    chi1: float
    chi2: float
    chi3: float
    n_vc: np.ndarray  # per-process distinct remote columns
    n_vm: np.ndarray  # per-process local vector entries

    @property
    def imbalance(self) -> float:
        """χ₃/χ₂ — above ~2–3 indicates severe comm-volume imbalance."""
        return self.chi3 / self.chi2 if self.chi2 > 0 else 1.0

    def efficiency_bound(self, bc_over_bm: float) -> float:
        """Π ≲ min{1, χ₃⁻¹ · b_c/b_m}  (Eq. 11)."""
        if self.chi3 == 0:
            return 1.0
        return min(1.0, bc_over_bm / self.chi3)

    def row(self) -> str:
        return f"{self.N_p:4d}  chi1,3={self.chi1:6.2f}  chi2={self.chi2:6.2f}"


def chi_from_nvc(n_vc: np.ndarray, n_vm: np.ndarray, D: int) -> ChiMetrics:
    n_vc = np.asarray(n_vc, dtype=np.int64)
    n_vm = np.asarray(n_vm, dtype=np.int64)
    P = len(n_vc)
    if P == 1:
        return ChiMetrics(1, D, 0.0, 0.0, 0.0, n_vc * 0, n_vm)
    return ChiMetrics(
        N_p=P,
        D=D,
        chi1=float((n_vc / np.maximum(n_vm, 1)).max()),
        chi2=float(n_vc.sum() / D),
        chi3=float(P * n_vc.max() / D),
        n_vc=n_vc,
        n_vm=n_vm,
    )


def chi_metrics(matrix: MatrixFamily, N_p: int, boundaries: np.ndarray | None = None) -> ChiMetrics:
    """Exact χ metrics for a matrix family at N_p processes."""
    if boundaries is None:
        boundaries = uniform_partition(matrix.D, N_p)
    n_vc = matrix.n_vc(boundaries)
    return chi_from_nvc(n_vc, matrix.n_vm(boundaries), matrix.D)


def chi_bruteforce(csr: CSR, N_p: int, boundaries: np.ndarray | None = None) -> ChiMetrics:
    """Reference χ computation from an explicit CSR pattern (tests)."""
    D = csr.shape[0]
    if boundaries is None:
        boundaries = uniform_partition(D, N_p)
    n_vc = np.zeros(N_p, dtype=np.int64)
    for p in range(N_p):
        a, b = int(boundaries[p]), int(boundaries[p + 1])
        lo, hi = int(csr.indptr[a]), int(csr.indptr[b])
        cols = csr.indices[lo:hi]
        n_vc[p] = np.unique(cols[(cols < a) | (cols >= b)]).size
    return chi_from_nvc(n_vc, np.diff(np.asarray(boundaries, dtype=np.int64)), D)


def chi_sweep(matrix: MatrixFamily, Nps=(2, 4, 8, 16, 32, 64)) -> dict[int, ChiMetrics]:
    """Table-1-style sweep over process counts."""
    return {Np: chi_metrics(matrix, Np) for Np in Nps}

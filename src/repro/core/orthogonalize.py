"""Communication-avoiding orthogonalization in the stack layout.

TSQR (Demmel et al. [11]): local QR per row shard, then a butterfly tree
over the horizontal axis — log2(P) ppermute rounds exchanging only the
small N_s x N_s R factors. Aggregate communication O(P log P * N_s^2),
independent of D (the paper's requirement for the stack layout).

SVQB (Stathopoulos & Wu [41]): Gram matrix via one all-reduce (the
MPI_Allreduce of the paper, volume P * N_s^2), then a replicated eigen-
decomposition. Cheaper but numerically weaker — the paper uses TSQR for
large N_s; we provide both.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from .layouts import Layout

__all__ = ["make_tsqr", "make_svqb", "make_gram"]


def _flat_axis_index(mesh: Mesh, axes: tuple[str, ...]):
    """Linearized device index over the given mesh axes (row-major)."""
    idx = jnp.zeros((), jnp.int32)
    for a in axes:
        idx = idx * mesh.shape[a] + lax.axis_index(a)
    return idx


def _qr_fixed(M):
    Q, R = jnp.linalg.qr(M)
    d = jnp.diagonal(R)
    s = jnp.where(jnp.abs(d) > 0, d / jnp.abs(d), jnp.ones_like(d))
    return Q * jnp.conj(s)[None, :], R / s[:, None]


def make_tsqr(mesh: Mesh, layout: Layout):
    """tsqr(V) -> (Q, R) with V [D_pad, N_s] in the stack layout.

    Requires the horizontal process count to be a power of two (true for
    all production meshes); the butterfly leaves every shard with the same
    global R and its local Q block.
    """
    dist = layout.dist_axes
    P_row = layout.n_row(mesh)
    levels = int(math.log2(P_row)) if P_row > 1 else 0
    if 2**levels != P_row:
        raise ValueError(f"TSQR butterfly needs power-of-two shards, got {P_row}")
    vec_spec = layout.vec_pspec()

    def local_fn(Vb):
        Q0, R = _qr_fixed(Vb)  # local [R_loc, Ns] -> Q0 [R_loc, Ns], R [Ns, Ns]
        acc = None
        if levels:
            idx = _flat_axis_index(mesh, dist)
            for lvl in range(levels):
                bit = 1 << lvl
                perm = [(i, i ^ bit) for i in range(P_row)]
                R_peer = lax.ppermute(R, dist, perm)
                am_lo = (idx & bit) == 0
                # stack in consistent (lo above hi) order on both partners
                A = jnp.where(am_lo,
                              jnp.concatenate([R, R_peer], axis=0),
                              jnp.concatenate([R_peer, R], axis=0))
                Qf, R = _qr_fixed(A)
                Ns = R.shape[0]
                mine = jnp.where(am_lo, 0, 1)
                Qblk = lax.dynamic_slice_in_dim(Qf, mine * Ns, Ns, axis=0)  # [Ns, Ns]
                acc = Qblk if acc is None else acc @ Qblk
        Q = Q0 if acc is None else Q0 @ acc
        return Q, R

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(vec_spec,),
        out_specs=(vec_spec, P()),
        check_rep=False,
    )
    return fn


def make_gram(mesh: Mesh, layout: Layout):
    """gram(V, W) = V^H W with one all-reduce over the horizontal axes."""
    dist = layout.dist_axes
    vec_spec = layout.vec_pspec()

    def local_fn(Vb, Wb):
        g = jnp.conj(Vb).T @ Wb
        return lax.psum(g, dist) if dist else g

    return shard_map(local_fn, mesh=mesh, in_specs=(vec_spec, vec_spec),
                     out_specs=P(), check_rep=False)


def make_svqb(mesh: Mesh, layout: Layout, eps: float = 1e-14):
    """svqb(V) -> orthonormal basis of span(V) (Gram + eigh, one allreduce)."""
    gram = make_gram(mesh, layout)

    def svqb(V):
        G = gram(V, V)
        d = jnp.real(jnp.diagonal(G))
        s = 1.0 / jnp.sqrt(jnp.maximum(d, eps))
        Gs = G * s[:, None] * s[None, :]
        w, U = jnp.linalg.eigh(Gs)
        w = jnp.maximum(jnp.real(w), eps * jnp.max(jnp.real(w)))
        T = (s[:, None] * U) / jnp.sqrt(w)[None, :]
        return V @ T.astype(V.dtype)

    return svqb

"""Chebyshev filter evaluation — paper Algorithm 2.

Evaluates V <- p[A]V for p(x) = sum_k mu_k T_k(x) using the three-term
recurrence, with the fused SpMV+axpy step (kernel fusion keeps the vector
traffic factor at κ=5 instead of 6 — paper §3.2).

The recurrence runs entirely in the chosen vector layout; the only
communication is the halo all_to_all inside each SpMV (horizontal layer).
Also provides KPM moment accumulation (used for the DOS panels, Figs 7/8).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

__all__ = ["scale_params", "chebyshev_filter", "chebyshev_filter_sstep",
           "kpm_moments"]


def scale_params(lambda_l: float, lambda_r: float) -> tuple[float, float]:
    """alpha, beta mapping spec(A) in [λl, λr] onto [-1, 1] (Alg. 2 step 1)."""
    alpha = 2.0 / (lambda_r - lambda_l)
    beta = (lambda_l + lambda_r) / (lambda_l - lambda_r)
    return alpha, beta


def chebyshev_filter(spmv, mu, alpha: float, beta: float, V, fused_step=None):
    """Return p[A]V given the distributed ``spmv`` closure.

    ``mu`` is a length-(n+1) coefficient array (n >= 2). Uses two workspace
    matrices W1, W2 (three live vectors total, as in the paper's memory
    accounting). The k-loop is a ``lax.scan`` so the compiled HLO contains
    a single fused iteration body regardless of the degree.

    ``fused_step(w1, w2, alpha, beta)``, when given (built with
    :func:`~repro.core.spmv.make_fused_cheb_step`), replaces the inline
    ``2a·spmv(w1) + 2b·w1 - w2`` recurrence step — same expression
    evaluated inside one shard_map body (or a single fused Pallas kernel
    for comm-free DIA operators), so the result is bit-identical while
    the vector traffic stays at the paper's κ = 5.
    """
    mu = jnp.asarray(mu, dtype=V.real.dtype if jnp.iscomplexobj(V) else V.dtype)
    n = mu.shape[0] - 1
    assert n >= 2, "filter degree must be >= 2"
    a = jnp.asarray(alpha, mu.dtype)
    b = jnp.asarray(beta, mu.dtype)

    if fused_step is None:
        def fused_step(w1, w2, alpha_, beta_):
            return 2 * a * spmv(w1) + 2 * b * w1 - w2  # fused SpMV+axpy

    W1 = a * spmv(V) + b * V                     # T1
    W2 = fused_step(W1, V, alpha, beta)          # T2
    Y = mu[0] * V + mu[1] * W1 + mu[2] * W2

    def body(carry, mu_k):
        Y, Tkm1, Tkm2 = carry
        Tk = fused_step(Tkm1, Tkm2, alpha, beta)
        Y = Y + mu_k * Tk
        return (Y, Tk, Tkm1), None

    if n >= 3:
        (Y, _, _), _ = lax.scan(body, (Y, W2, W1), mu[3:])
    return Y


def chebyshev_filter_sstep(group, mu, alpha: float, beta: float, V, s: int):
    """Communication-avoiding filter evaluation: ⌈n/s⌉ ghost exchanges.

    ``group(n_steps, first)`` (built by
    :func:`~repro.core.spmv.make_sstep_cheb`) returns a fused closure
    running ONE depth-s ghost exchange followed by ``n_steps`` recurrence
    steps on the extended block, returning the owned step outputs
    stacked (``[n_steps, D, nb]``) plus the shifted carries. The
    degree-n loop is split into a first group (seeds off V alone, so its
    exchange ships single width), a ``lax.scan`` over the uniform middle
    groups (one fused HLO body — the s-step analogue of the base
    filter's scanned step), and an explicit tail group of the n mod s
    leftover steps. The μ-accumulation happens HERE, in the main graph,
    with the identical op tree to :func:`chebyshev_filter` — the init
    ``mu0·V + mu1·T1 + mu2·T2`` followed by scanned ``Y + mu_k·T_k``
    updates — so XLA's fused-multiply-add choices match and the result
    is bit-identical to the s=1 engines for every s.
    """
    mu = jnp.asarray(mu, dtype=V.real.dtype if jnp.iscomplexobj(V) else V.dtype)
    n = int(mu.shape[0]) - 1
    s = int(s)
    assert n >= 2, "filter degree must be >= 2"
    assert s >= 2, "s=1 is the per-step engine grid (chebyshev_filter)"
    n_groups = -(-n // s)
    s1 = min(s, n)

    def acc(Yk, mu_T):
        mu_k, Tk = mu_T
        return Yk + mu_k * Tk, None

    Ts, w1, w2 = group(s1, True)(V, alpha, beta)
    Y = mu[0] * V + mu[1] * Ts[0] + mu[2] * Ts[1]
    if s1 > 2:
        Y, _ = lax.scan(acc, Y, (mu[3:1 + s1], Ts[2:]))
    if s1 == n:
        return Y
    r_tail = n - (n_groups - 1) * s
    n_mid = (n_groups - 1) - (0 if r_tail == s else 1)
    if n_mid:
        g = group(s, False)
        mus_mid = mu[1 + s:1 + s + n_mid * s].reshape(n_mid, s)

        def body(carry, mus_k):
            Yk, a1, a2 = carry
            Ts_k, a1, a2 = g(a1, a2, alpha, beta)
            Yk, _ = lax.scan(acc, Yk, (mus_k, Ts_k))
            return (Yk, a1, a2), None

        (Y, w1, w2), _ = lax.scan(body, (Y, w1, w2), mus_mid)
    if r_tail != s:
        Ts_t, w1, w2 = group(r_tail, False)(w1, w2, alpha, beta)
        Y, _ = lax.scan(acc, Y, (mu[1 + n - r_tail:], Ts_t))
    return Y


def kpm_moments(spmv, alpha: float, beta: float, V, n_moments: int):
    """KPM moments mu_m = tr[T_m(A~)] estimated with the stochastic trace
    over the columns of V (used for the density-of-states panels)."""
    a = jnp.asarray(alpha, V.real.dtype if jnp.iscomplexobj(V) else V.dtype)
    b = jnp.asarray(beta, a.dtype)

    def dot(x, y):
        return jnp.real(jnp.sum(jnp.conj(x) * y))

    T0 = V
    T1 = a * spmv(V) + b * V
    m0 = dot(V, T0)
    m1 = dot(V, T1)

    def body(carry, _):
        Tkm1, Tkm2 = carry
        Tk = 2 * a * spmv(Tkm1) + 2 * b * Tkm1 - Tkm2
        return (Tk, Tkm1), dot(V, Tk)

    (_, _), ms = lax.scan(body, (T1, T0), None, length=n_moments - 2)
    return jnp.concatenate([jnp.stack([m0, m1]), ms])


def kpm_dos(moments: np.ndarray, n_bins: int = 512, jackson: bool = True):
    """Reconstruct the normalized DOS on [-1, 1] from KPM moments."""
    M = len(moments)
    mu = np.asarray(moments, dtype=np.float64).copy()
    if jackson:
        k = np.arange(M)
        g = ((M - k + 1) * np.cos(np.pi * k / (M + 1))
             + np.sin(np.pi * k / (M + 1)) / np.tan(np.pi / (M + 1))) / (M + 1)
        mu *= g
    x = np.cos(np.pi * (np.arange(n_bins) + 0.5) / n_bins)
    Tm = np.cos(np.outer(np.arccos(x), np.arange(M)))
    w = (2.0 - (np.arange(M) == 0)) * mu / mu[0]
    rho = (Tm @ w) / (np.pi * np.sqrt(1 - x**2))
    return x[::-1], rho[::-1]

"""χ-driven layout & overlap planner — the perf model as the control path.

The paper's central observation is that the communication metric χ (Eqs.
8–10, ``core/metrics.py``) is computable **from the sparsity pattern
alone**, before any code runs, and predicts when each of the two
orthogonal layers of parallelism wins:

  * low χ   → the horizontal layer scales: keep ``stack``/wide ``panel``
              row meshes (D sliced over many processes),
  * high χ  → SpMV communication destroys scaling (Eq. 11): shrink the
              row mesh — at the extreme the ``pillar`` layout (n_col = P)
              makes the filter communication-free — and pay the explicit
              redistribution (Eqs. 17/18) instead,
  * overlap → the split-phase SpMV engine (``spmv.py overlap=True``)
              replaces the additive χ term of Eq. 12 with
              ``max(T_comm, T_local)`` (``perf_model.cheb_iter_time_overlap``),
              shifting the stack↔pillar break-even point,
  * comm    → the horizontal exchange itself is an axis: the padded
              ``all_to_all`` moves ``P·L`` entries per device (χ₃-scaled —
              it physically realizes the imbalance bound), while the
              compressed neighbor-permute engine
              (``spmv.py comm="compressed"``) moves ``H = Σ_r L_r``
              (≈ χ₂-scaled, empty pairs skipped) — on comm-imbalanced
              patterns (χ₃/χ₂ > 2–3, e.g. the RoadNet family) the
              compressed engine wins by that factor,
  * schedule → *how* the compressed engine derives its permute rounds
              (``spmv.neighbor_schedule``): ``"cyclic"`` pays one round
              per nonzero cyclic shift (pad = that shift's max pair),
              ``"matching"`` extracts greedy max-weight matchings so hot
              pairs of different shifts share one round's pad — on
              hub-and-spoke patterns (the HubNet family) the cyclic
              rounds each carry a full hub corridor while a matching
              packs them all into O(1) rounds,
  * partition → the row decomposition itself is a candidate axis
              (``core/partition.py``): ``balance="commvol"`` plans
              non-uniform shard boundaries that shrink the hot blocks
              before any scheduling, ``reorder="rcm"`` re-orders the
              rows first — χ and every byte prediction are evaluated
              on the *planned* partition, so the metric edits the
              layout it measures.

This module enumerates candidate configurations — mesh splits
``n_row × n_col`` with ``n_row · n_col = P``, vector layouts
{stack, panel, pillar}, comm engine {a2a, compressed-cyclic,
compressed-matching}, overlap on/off, redistribution on/off (stack runs
redistribution-free; panel/pillar pay Eq. 17/18 twice per filter pass,
amortized per Eqs. 19–21) — scores each with the analytic model fed the
**engine-exact** wire bytes predicted by :func:`comm_plan`, and returns
a ranked :class:`Plan`. It is wired into the production entry points:

  * ``FDConfig(layout="auto")``          → :func:`plan_for_mesh` inside
    ``FilterDiag`` (choice restricted to layouts the given mesh realizes),
  * ``repro.launch.solve --layout auto`` → :func:`plan_layout` before mesh
    construction (free choice of the split),
  * ``repro.launch.dryrun --plan``       → ranking printed next to the
    measured HLO all-to-all volume of the lowered iteration,
  * ``benchmarks/run.py --only planner`` → sweep over the bundled families.

Everything here is host-side numpy; no jax computation is launched.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..matrices.sparse import CSR
from . import perf_model as pm
from .layouts import Layout, panel, pillar
from .metrics import ChiMetrics, chi_from_nvc
from .partition import (PLAN_MODES, SPMV_BALANCES, SPMV_REORDERS, RowMap,
                        partition_plan_default, plan_rowmap)
from .redistribute import redistribution_volume
from .spmv import (SPMV_COMM_ENGINES, SPMV_SCHEDULES, Partition,
                   neighbor_schedule)

__all__ = [
    "SpmvCommPlan", "Candidate", "Plan", "comm_plan", "exact_comm_default",
    "default_row_axes", "estimate_nnzr", "plan_layout", "plan_for_mesh",
    "layout_on_mesh", "DEFAULT_PLAN_DEGREE",
]


def exact_comm_default(matrix) -> bool:
    """Whether the exact per-pair pattern pass is affordable for
    ``matrix`` — the single policy behind ``comm_plan(exact=None)`` and
    the dry-run's schedule building: CSR inputs, small instances, and
    reach-limited families (whose ``_remote_cols`` scan is windowed to
    block boundaries) are exact; unbounded generators at paper scale fall
    back to the n_vc estimate (no compressed-engine ranking)."""
    from ..matrices.sparse import CSR as _CSR

    D = matrix.shape[0] if isinstance(matrix, _CSR) else matrix.D
    return (isinstance(matrix, _CSR) or D <= 2_000_000
            or getattr(matrix, "reach", None) is not None)

#: Planning-time Chebyshev degree when the caller has not run the filter
#: selector yet. FD filter degrees are O(100) at paper tolerances (Table 4),
#: far above the pillar break-even n* = 2/χ[P] (Eq. 23) for high-χ matrices.
DEFAULT_PLAN_DEGREE = 100


# --------------------------------------------------------------------------
# pattern-only communication plan
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SpmvCommPlan:
    """Pattern-derived stats of the SpMV engines' exchanges at ``n_row``
    horizontal shards.

    ``L`` is the padded per-(sender, receiver) slot count the a2a engine
    uses (``build_dist_ell``): with ``exact=True`` it is the true maximum
    pair volume, so :meth:`a2a_bytes_per_device` equals the HLO-measured
    per-chip all_to_all operand of ``make_spmv`` bit-for-bit; with
    ``exact=False`` it is the χ-based estimate ``ceil(max n_vc / (P-1))``
    (the same convention as the dry-run's bandwidth-matched surrogate).

    ``pair_counts`` (exact path only) are the true per-pair volumes L_qp,
    from which :meth:`permute_schedule` reproduces the compressed engine's
    neighbor rounds for either scheduler (cyclic shifts or greedy
    matchings) — :meth:`permute_bytes_per_device` then equals the
    HLO-measured per-chip collective-permute volume bit-for-bit. Without
    pair counts the compressed volume is conservatively estimated as
    ``max n_vc`` (the best any per-round-padded schedule can do when one
    receiver concentrates the traffic).
    """

    n_row: int
    D: int
    L: int
    n_vc: np.ndarray
    exact: bool
    d_pad: int | None = None
    pair_counts: np.ndarray | None = None  # [P, P] L_qp (sender q -> recv p)
    #: ghost-zone depth the stats describe: 1 = the per-SpMV halo (the
    #: classic plan), s > 1 = the depth-s ghost set of the s-step engine
    #: (χ(A^s)-derived volumes; always an exact pattern pass)
    sstep: int = 1
    #: [s+1] max-over-shards ghost count at BFS depth ≤ d (d = 0 is 0) —
    #: the s-step engine's redundant-work statistic
    ghost_cum: tuple | None = None
    #: schedule name -> (perms, round_L) memo — the greedy matching
    #: decomposition is O(P² log P), and plan_layout asks for it several
    #: times per candidate
    _sched_cache: dict = dataclasses.field(default_factory=dict, repr=False,
                                           compare=False)
    #: planned row decomposition the counts were computed on (None =
    #: the equal-rows Partition) — χ is evaluated on ITS block sizes
    rowmap: RowMap | None = dataclasses.field(default=None, repr=False,
                                              compare=False)

    @property
    def chi(self) -> ChiMetrics:
        """χ metrics evaluated on the *planned* partition: real rows per
        block come from the rowmap when one is set (``balance="commvol"``
        blocks are non-uniform), else from the equal-rows cuts."""
        if self.rowmap is not None:
            n_vm = self.rowmap.block_sizes(self.n_row)
        else:
            bnds = Partition(self.D, self.n_row, self.d_pad).boundaries()
            n_vm = np.diff(bnds)
        return chi_from_nvc(self.n_vc, n_vm, self.D)

    def a2a_bytes_per_device(self, n_b: int, S_d: int) -> int:
        """Operand bytes of one SpMV's all_to_all on each device (the
        ``[P, L, n_b]`` send buffer)."""
        if self.n_row <= 1:
            return 0
        return self.n_row * self.L * n_b * S_d

    def permute_schedule(self, schedule: str = "cyclic",
                         ) -> tuple[tuple[tuple[tuple[int, int], ...], ...],
                                    tuple[int, ...]]:
        """(perms, round_L) of the compressed engine under ``schedule``
        (``"cyclic"`` shifts or greedy ``"matching"`` rounds), via the
        same ``spmv.neighbor_schedule`` the engine itself uses —
        predicted and executed schedules cannot diverge."""
        if self.pair_counts is None:
            raise ValueError("permute_schedule needs exact pair counts")
        if schedule not in self._sched_cache:
            self._sched_cache[schedule] = neighbor_schedule(
                self.pair_counts, schedule)
        return self._sched_cache[schedule]

    def moved_entries_per_device(self, comm: str = "a2a",
                                 schedule: str = "cyclic") -> int:
        """Vector entries one device moves per SpMV column: ``P·L`` for the
        padded all_to_all, ``H = Σ_r L_r`` of the ``schedule`` rounds for
        the compressed engine.

        Without exact pair counts the compressed volume is a *lower bound*
        (``max n_vc`` — what a per-round-padded schedule can never beat);
        the planner refuses to rank compressed candidates on that bound
        (see :func:`plan_layout`), so it is diagnostics-only.
        """
        if self.n_row <= 1:
            return 0
        if comm == "a2a":
            return self.n_row * self.L
        if comm != "compressed":
            raise ValueError(f"unknown comm engine {comm!r}")
        if self.pair_counts is not None:
            return int(sum(self.permute_schedule(schedule)[1]))
        return int(self.n_vc.max())  # estimated-path lower bound

    def permute_bytes_per_device(self, n_b: int, S_d: int,
                                 schedule: str = "cyclic") -> int:
        """Total ppermute operand bytes of one SpMV on each device."""
        return self.moved_entries_per_device("compressed", schedule) \
            * n_b * S_d

    def comm_bytes_per_device(self, comm: str, n_b: int, S_d: int,
                              schedule: str = "cyclic") -> int:
        """Predicted per-device SpMV exchange bytes of engine ``comm``
        with compressed rounds derived by ``schedule``."""
        return self.moved_entries_per_device(comm, schedule) * n_b * S_d

    def spmv_collectives(self, comm: str, schedule: str, n_b: int, S_d: int
                         ) -> tuple[tuple[str, int, int], ...]:
        """Static (HLO kind, operand bytes, op count) triples of ONE SpMV's
        halo exchange — the collective-census contract of the engine
        (``repro.analysis.census`` attributes every measured collective to
        one of these terms, scaled by the filter degree).

        ``"a2a"`` emits one ``all-to-all`` over the padded ``[P, L, n_b]``
        send buffer; ``"compressed"`` emits one ``collective-permute`` per
        ``schedule`` round, each moving its ``round_L[r] * n_b`` slots. A
        zero-halo partition (L == 0 or a single shard) emits nothing.
        """
        if self.n_row <= 1 or self.L == 0:
            return ()
        if comm == "a2a":
            return (("all-to-all", self.n_row * self.L * n_b * S_d, 1),)
        if comm != "compressed":
            raise ValueError(f"unknown comm engine {comm!r}")
        _, round_L = self.permute_schedule(schedule)
        return tuple(("collective-permute", Lk * n_b * S_d, 1)
                     for Lk in round_L)

    # ----------------------------------------------------- s-step stats --

    @property
    def level_R(self) -> int:
        """Padded rows per shard the plan's volumes were computed on."""
        if self.rowmap is not None and not self.rowmap.identity:
            return self.rowmap.level_R(self.n_row)
        if self.d_pad is not None:
            return self.d_pad // self.n_row
        return -(-self.D // self.n_row)

    def n_groups(self, degree: int) -> int:
        """Exchanges of a degree-n s-step filter: ⌈n/s⌉."""
        return -(-int(degree) // self.sstep)

    def rounds_per_exchange(self, comm: str, schedule: str = "cyclic") -> int:
        """Collective rounds one exchange launches: 1 for the a2a engine,
        the schedule's round count for the compressed engine (the α
        latency multiplier of the perf model)."""
        if self.n_row <= 1 or self.L == 0:
            return 0
        if comm == "a2a":
            return 1
        if comm != "compressed":
            raise ValueError(f"unknown comm engine {comm!r}")
        return len(self.permute_schedule(schedule)[1])

    def sstep_work_factor(self) -> float:
        """Matrix-traffic inflation of the s-step engine: the group's
        steps also contract the ghost rows still needed at later depths,
        ``1 + Σ_{d=1}^{s-1} ghosts(≤d) / (s·R)`` (exactly 1 at s = 1 —
        depth-1 ghosts have no rows of their own)."""
        if self.sstep < 2 or not self.ghost_cum:
            return 1.0
        extra = float(sum(self.ghost_cum[1:self.sstep]))
        return 1.0 + extra / (self.sstep * max(self.level_R, 1))

    def sstep_collectives(self, comm: str, schedule: str, n_b: int, S_d: int,
                          degree: int) -> tuple[tuple[str, int, int], ...]:
        """Whole-filter (HLO kind, operand bytes, op count) terms of the
        s-step engine at filter degree ``degree`` — the census contract
        of the seventh axis. The first group ships the single-width seed
        (``n_b`` columns); every later group ships the width-doubled
        ``[w1 | w2]`` payload in the SAME collective, so a2a emits one
        single-width + ``⌈n/s⌉ - 1`` double-width all-to-alls, and the
        compressed engine emits that pattern per schedule round. Unlike
        :meth:`spmv_collectives` these terms already cover the whole
        filter — they are NOT scaled by the degree again.
        """
        if self.sstep < 2:
            raise ValueError("sstep_collectives needs a depth-s plan "
                             "(comm_plan(..., sstep>=2))")
        if self.n_row <= 1 or self.L == 0:
            return ()
        ng = self.n_groups(degree)
        if comm == "a2a":
            b1 = self.n_row * self.L * n_b * S_d
            terms = [("all-to-all", b1, 1)]
            if ng > 1:
                terms.append(("all-to-all", 2 * b1, ng - 1))
            return tuple(terms)
        if comm != "compressed":
            raise ValueError(f"unknown comm engine {comm!r}")
        _, round_L = self.permute_schedule(schedule)
        terms = []
        for Lk in round_L:
            terms.append(("collective-permute", Lk * n_b * S_d, 1))
            if ng > 1:
                terms.append(("collective-permute", 2 * Lk * n_b * S_d,
                              ng - 1))
        return tuple(terms)


def _remote_cols(matrix, a: int, b: int, chunk: int = 2_000_000) -> np.ndarray:
    """Distinct columns outside [a, b) referenced by rows [a, b)."""
    if isinstance(matrix, CSR):
        lo, hi = int(matrix.indptr[a]), int(matrix.indptr[b])
        cols = matrix.indices[lo:hi]
        return np.unique(cols[(cols < a) | (cols >= b)])
    parts = []
    for lo, hi in matrix._scan_ranges(a, b):
        for c0 in range(lo, hi, chunk):
            _, cols = matrix.row_cols(np.arange(c0, min(c0 + chunk, hi),
                                                dtype=np.int64))
            cols = cols[(cols < a) | (cols >= b)]
            if cols.size:
                parts.append(np.unique(cols))
    return np.unique(np.concatenate(parts)) if parts else np.empty(0, np.int64)


def _mapped_row_cols(matrix, rows: np.ndarray, chunk: int = 2_000_000):
    """Pattern columns of an arbitrary row set (mapped-partition pass)."""
    if isinstance(matrix, CSR):
        from ..matrices.sparse import gather_row_entry_idx

        gather, _ = gather_row_entry_idx(matrix.indptr, rows)
        yield matrix.indices[gather].astype(np.int64)
        return
    for lo in range(0, len(rows), chunk):
        _, cols = matrix.row_cols(rows[lo: lo + chunk])
        yield np.asarray(cols, dtype=np.int64)


def comm_plan(matrix, n_row: int, *, d_pad: int | None = None,
              exact: bool | None = None,
              n_vc: np.ndarray | None = None,
              rowmap: RowMap | None = None,
              sstep: int = 1) -> SpmvCommPlan:
    """Communication plan of the SpMV engine at ``n_row`` shards, computed
    from the sparsity pattern without building the operator.

    ``exact`` controls whether ``L`` comes from true per-pair distinct
    counts (matches ``build_dist_ell`` exactly; cost ~ one pattern pass) or
    from the aggregate n_vc counts (cheap at any D via the family's
    streamed/structured ``n_vc``). Default: exact for CSR inputs, small
    instances, and reach-limited families (their pattern pass is windowed
    to block boundaries); estimated otherwise above D = 2·10⁶. Only the
    exact path carries per-pair counts, so only it can rank the
    compressed engine. A precomputed ``n_vc`` (on the same
    ``Partition(D, n_row, d_pad)`` boundaries) skips the pattern pass
    entirely and implies the estimated-L path.

    ``rowmap`` evaluates the plan on a *planned* partition
    (``core/partition.py``: ``balance="commvol"`` boundaries and/or the
    RCM row order) instead of the equal-rows one — always an exact pass
    (its per-pair counts are what justify a planned map at all), and
    :attr:`SpmvCommPlan.chi` is then computed on the planned block
    sizes. ``L == 0`` (a zero-halo partition) predicts zero bytes, which
    the engines realize exactly — no phantom 1-entry pad.

    ``sstep > 1`` computes the **depth-s ghost-zone** stats instead of
    the per-SpMV halo: per-pair volumes are the χ(A^s)-derived distinct
    BFS-reachable positions (the same ``spmv.sstep_ghosts`` pass
    ``build_sstep_ell`` runs, so predicted == built), and
    :attr:`SpmvCommPlan.ghost_cum` carries the per-depth redundant-work
    counts. The depth-s pass is always exact (it needs the full
    pattern); it warns when scored on a :class:`RowMap` planned at a
    different depth (a stale s=1 map's cuts silently under-count the
    depth-s volumes they never optimized).
    """
    D = matrix.shape[0] if isinstance(matrix, CSR) else matrix.D
    sstep = int(sstep)
    if sstep < 1:
        raise ValueError(f"sstep must be >= 1, got {sstep}")
    if sstep > 1:
        return _sstep_comm_plan(matrix, D, n_row, sstep, d_pad=d_pad,
                                rowmap=rowmap)
    if rowmap is not None and not rowmap.identity:
        if rowmap.D != D:
            raise ValueError("rowmap.D does not match the matrix")
        R = rowmap.level_R(n_row)
        if n_row <= 1:
            return SpmvCommPlan(1, D, 0, np.zeros(1, np.int64), True,
                                rowmap.D_pad, rowmap=rowmap)
        pos = rowmap.pos
        L = 0
        n_vc = np.zeros(n_row, dtype=np.int64)
        pair_counts = np.zeros((n_row, n_row), dtype=np.int64)
        for p in range(n_row):
            rows_g, _ = rowmap.shard_rows(p, n_row)
            parts = []
            for cols in _mapped_row_cols(matrix, rows_g):
                cpos = pos[cols]
                cpos = cpos[cpos // R != p]
                if cpos.size:
                    parts.append(np.unique(cpos))
            if not parts:
                continue
            remote = np.unique(np.concatenate(parts))
            n_vc[p] = remote.size
            pair_counts[:, p] = np.bincount(remote // R, minlength=n_row)
            L = max(L, int(pair_counts[:, p].max()))
        return SpmvCommPlan(n_row, D, L, n_vc, True, rowmap.D_pad,
                            pair_counts=pair_counts, rowmap=rowmap)
    part = Partition(D, n_row, d_pad)
    bnds = part.boundaries()
    if n_row <= 1:
        return SpmvCommPlan(1, D, 0, np.zeros(1, np.int64), True, d_pad)
    if n_vc is not None:
        n_vc = np.asarray(n_vc, dtype=np.int64)
        L = -(-int(n_vc.max()) // (n_row - 1))
        return SpmvCommPlan(n_row, D, L, n_vc, False, d_pad)
    if exact is None:
        exact = exact_comm_default(matrix)
    if not exact:
        n_vc = matrix.n_vc(bnds)
        L = -(-int(n_vc.max()) // (n_row - 1))
        return SpmvCommPlan(n_row, D, L, n_vc, False, d_pad)
    L = 0
    n_vc = np.zeros(n_row, dtype=np.int64)
    pair_counts = np.zeros((n_row, n_row), dtype=np.int64)
    for p in range(n_row):
        a, b = int(bnds[p]), int(bnds[p + 1])
        cols = _remote_cols(matrix, a, b)
        if not cols.size:
            continue
        n_vc[p] = cols.size
        pair_counts[:, p] = np.bincount(part.owner(cols), minlength=n_row)
        L = max(L, int(pair_counts[:, p].max()))
    return SpmvCommPlan(n_row, D, L, n_vc, True, d_pad,
                        pair_counts=pair_counts)


def _sstep_comm_plan(matrix, D: int, n_row: int, sstep: int, *,
                     d_pad: int | None, rowmap: RowMap | None
                     ) -> SpmvCommPlan:
    """Depth-s ghost-zone stats via the engine's own BFS
    (``spmv.sstep_ghosts``) over the pattern in position space."""
    import warnings

    from .partition import _pattern_csr
    from .spmv import sstep_ghosts

    mapped = rowmap is not None and not rowmap.identity
    if mapped and rowmap.D != D:
        raise ValueError("rowmap.D does not match the matrix")
    if mapped and int(getattr(rowmap, "sstep", 1)) != sstep:
        warnings.warn(
            f"comm_plan(sstep={sstep}) scored on a RowMap planned at "
            f"sstep={getattr(rowmap, 'sstep', 1)} — its cuts were not "
            f"optimized for the depth-{sstep} ghost volumes, so the "
            f"redistribution/byte accounting may under-count; re-plan "
            f"with plan_rowmap(..., sstep={sstep})",
            UserWarning, stacklevel=3)
    if n_row <= 1:
        return SpmvCommPlan(1, D, 0, np.zeros(1, np.int64), True,
                            rowmap.D_pad if mapped else d_pad,
                            sstep=sstep, ghost_cum=(0,) * (sstep + 1),
                            rowmap=rowmap)
    indptr, cols = _pattern_csr(matrix)
    if mapped:
        R = rowmap.level_R(n_row)
        pos = rowmap.pos
        rows = np.repeat(np.arange(D, dtype=np.int64), np.diff(indptr))
        prow, pcol = pos[rows], pos[cols]
        order = np.lexsort((pcol, prow))
        prow, pcol = prow[order], pcol[order]
        counts = np.bincount(prow, minlength=n_row * R)
        indptr_pos = np.concatenate([[0], np.cumsum(counts)])
        cols_pos = pcol
        pad = rowmap.D_pad
    else:
        R = (d_pad // n_row) if d_pad is not None else -(-D // n_row)
        # equal-rows cuts put row g at position g; pad rows are empty
        indptr_pos = np.concatenate(
            [indptr, np.full(n_row * R - D, indptr[-1], dtype=indptr.dtype)])
        cols_pos = cols
        pad = d_pad
    ghosts = sstep_ghosts(indptr_pos, cols_pos, n_row, R, sstep)
    n_vc = np.zeros(n_row, dtype=np.int64)
    pair_counts = np.zeros((n_row, n_row), dtype=np.int64)
    ghost_cum = np.zeros(sstep + 1, dtype=np.int64)
    for p, (gpos, gdep) in enumerate(ghosts):
        n_vc[p] = gpos.size
        if gpos.size:
            pair_counts[:, p] = np.bincount(gpos // R, minlength=n_row)
        for d in range(1, sstep + 1):
            ghost_cum[d] = max(ghost_cum[d], int((gdep <= d).sum()))
    L = int(pair_counts.max()) if pair_counts.size else 0
    return SpmvCommPlan(n_row, D, L, n_vc, True, pad,
                        pair_counts=pair_counts, sstep=sstep,
                        ghost_cum=tuple(int(g) for g in ghost_cum),
                        rowmap=rowmap)


def estimate_nnzr(matrix, probe_rows: int = 4096) -> float:
    """Average stored nonzeros per row: exact for CSR, leading-row probe
    for generator families (pattern rows are statistically homogeneous)."""
    if isinstance(matrix, CSR):
        return matrix.n_nzr
    rows = np.arange(0, min(matrix.D, probe_rows), dtype=np.int64)
    r, _ = matrix.row_cols(rows)
    return len(r) / len(rows)


# --------------------------------------------------------------------------
# candidate scoring
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One scored configuration of the two parallelism layers."""

    layout: str        # "stack" | "panel" | "pillar"
    n_row: int         # horizontal layer width (D split)
    n_col: int         # vertical layer width (bundle split)
    overlap: bool      # split-phase SpMV engine on
    comm: str          # "a2a" (padded all_to_all) | "compressed" (ppermute)
    schedule: str      # compressed rounds: "cyclic" | "matching"
    redistribute: bool # pays Eq. 17/18 twice per filter pass (n_col > 1)
    chi1: float        # χ₁ of the filter layout's row partition
    chi2: float
    chi_eng: float     # effective χ of the comm engine (exact wire volume)
    t_iter: float      # one Chebyshev iteration [s] (Eq. 12 / overlap model)
    t_redist: float    # one redistribution [s] (Eq. 17/18 over b_c)
    t_pass: float      # degree·t_iter + 2·t_redist [s]
    comm_bytes_per_device: int  # predicted SpMV exchange operand bytes
    balance: str = "rows"   # row partition: "rows" | "commvol"
    reorder: str = "none"   # row order: "none" | "rcm"
    kernel: bool = False    # fused Pallas kernel engine (κ=5 traffic term)
    sstep: int = 1          # ghost-zone depth (s-step filter; 1 = per-SpMV)
    #: the planned RowMap behind a non-default balance/reorder (shared by
    #: every candidate of that combo; None for the equal-rows partition).
    #: FilterDiag builds its operators from exactly this map, so the
    #: scored χ/bytes are the ones the engines realize.
    rowmap: RowMap | None = dataclasses.field(default=None, repr=False,
                                              compare=False)

    @property
    def name(self) -> str:
        """Layout name with the dry-run's ``+cv``/``+rcm`` partition and
        ``+cmp``/``+mat``/``+ov`` engine suffixes (``+cv`` = commvol
        boundaries, ``+rcm`` = RCM row order, ``+cmp`` =
        compressed-cyclic, ``+mat`` = compressed with the matching
        scheduler, ``+s2``/``+s3`` = the s-step ghost-zone depth)."""
        suffix = ""
        if self.balance == "commvol":
            suffix += "+cv"
        if self.reorder == "rcm":
            suffix += "+rcm"
        if self.comm == "compressed":
            suffix += "+cmp" if self.schedule == "cyclic" else "+mat"
        if self.overlap:
            suffix += "+ov"
        if self.kernel:
            suffix += "+krn"
        if self.sstep > 1:
            suffix += f"+s{self.sstep}"
        return self.layout + suffix

    def describe(self) -> str:
        return f"{self.name}({self.n_row}x{self.n_col})"

    def row(self) -> str:
        return (f"{self.describe():22s} {self.chi1:7.2f} {self.chi_eng:7.2f} "
                f"{self.t_iter * 1e3:9.3f} {self.t_redist * 1e3:9.3f} "
                f"{self.t_pass * 1e3:10.2f}")


@dataclasses.dataclass(frozen=True)
class Plan:
    """Ranked candidate configurations (best first) for one matrix."""

    matrix: str
    D: int
    n_devices: int
    n_search: int
    degree: int
    machine: str
    candidates: tuple[Candidate, ...]

    @property
    def best(self) -> Candidate:
        return self.candidates[0]

    @property
    def baseline(self) -> Candidate:
        """Speedup reference: the additive a2a stack candidate on the
        equal-rows partition (n_col = 1, no overlap, padded all_to_all —
        the paper's reference point) when it was enumerated, otherwise
        the slowest candidate (``report()`` says which)."""
        for c in self.candidates:
            if c.n_col == 1 and not c.overlap and c.comm == "a2a" \
                    and c.balance == "rows" and c.reorder == "none" \
                    and c.sstep == 1:
                return c
        return max(self.candidates, key=lambda c: c.t_pass)

    def speedup(self, c: Candidate) -> float:
        """Predicted filter-pass speedup of ``c`` over :attr:`baseline`."""
        return self.baseline.t_pass / c.t_pass

    def report(self) -> str:
        base = self.baseline
        vs = ("additive a2a stack"
              if base.n_col == 1 and not base.overlap and base.comm == "a2a"
              else f"slowest candidate {base.describe()}")
        lines = [
            f"layout plan: {self.matrix}  D={self.D}  P={self.n_devices}  "
            f"N_s={self.n_search}  degree={self.degree}  machine={self.machine}",
            f"{'config':22s} {'chi1':>7s} {'chi_eng':>7s} {'t_iter':>9s} "
            f"{'t_redist':>9s} {'t_pass':>10s} {'speedup':>8s}   "
            f"(ms; speedup vs {vs})",
        ]
        for i, c in enumerate(self.candidates):
            mark = " <- best" if i == 0 else ""
            lines.append(f"{c.row()} {self.speedup(c):8.2f}{mark}")
        return "\n".join(lines)


def _matrix_label(matrix) -> str:
    if isinstance(matrix, CSR):
        return f"CSR{matrix.shape}"
    return matrix.describe() if hasattr(matrix, "describe") else str(matrix)


def plan_layout(matrix, n_devices: int, *, n_search: int,
                degree: int = DEFAULT_PLAN_DEGREE,
                machine: pm.MachineModel = pm.TPU_V5E,
                overlap: tuple[bool, ...] = (False, True),
                comm: tuple[str, ...] = ("a2a", "compressed"),
                schedule: tuple[str, ...] = ("cyclic", "matching"),
                balance: tuple[str, ...] = ("rows", "commvol"),
                reorder: tuple[str, ...] = ("none",),
                kernel: tuple[bool, ...] = (False,),
                sstep: tuple[int, ...] = (1,),
                splits=None, S_d: int | None = None,
                n_nzr: float | None = None, d_pad: int | None = None,
                exact_comm: bool | None = None,
                n_vc_by_row: dict | None = None,
                comm_plan_by_row: dict | None = None,
                plan_mode: str = "exact", sample_seed: int = 0,
                sample_fraction: float | None = None) -> Plan:
    """Enumerate and rank layout/engine configurations for ``matrix`` on
    ``n_devices`` devices with an ``n_search``-wide vector bundle.

    ``splits`` restricts the candidate ``(n_row, n_col)`` meshes (default:
    every n_col dividing both P and n_search). ``overlap``, ``comm``, and
    ``schedule`` select which SpMV engines to consider — the full grid is
    {a2a, compressed-cyclic, compressed-matching} × {additive, overlap};
    variants are only generated where they differ from the additive a2a
    model (χ > 0). Every candidate is scored with its **engine-exact**
    wire volume: ``comm_plan`` predicts the padded all_to_all's ``P·L``
    (χ₃-scaled) or the neighbor-permute schedule's ``H = Σ_r L_r``
    (per-round pads of the cyclic or matching rounds) moved entries,
    which become the effective χ of the iteration-time model
    (``perf_model.engine_chi``). The ranking key is the predicted time of
    one filter pass, ``degree`` Chebyshev iterations plus two
    redistributions (Alg. 1 steps 7/9).

    ``balance`` × ``reorder`` is the fifth axis — the **row partition
    itself** (``core/partition.py``): each non-default combination plans
    one :class:`~repro.core.partition.RowMap` at the finest level P and
    scores every split on that map's grouped boundaries with the same
    engine-exact byte predictions (``comm_plan(rowmap=...)``), so the χ
    the planner ranks is the χ the built operator realizes. Planned
    combinations need the full per-row pattern pass and are skipped when
    it is unaffordable (``partition.partition_plan_default``) or when a
    split has no halo exchange at all. Ties prefer the equal-rows,
    natural-order partition.

    ``kernel`` widens the grid with the fused-Pallas-kernel variant of
    each engine (``make_spmv(use_kernel=True)`` +
    ``make_fused_cheb_step``), scored by clamping the machine's κ
    vector-traffic factor to the fused kernel's κ = 5
    (``perf_model.fused_kernel_machine``) — the wire bytes are
    unchanged, only the memory-traffic term improves. The axis defaults
    to off (``(False,)``); pass ``kernel=(False, True)`` to let the
    ranking decide (``--spmv-kernel`` with ``--layout auto`` does).

    ``sstep`` widens the grid with the **seventh axis** — the s-step
    ghost-zone depth of the communication-avoiding filter
    (``make_sstep_cheb``). An s>1 candidate replaces the per-SpMV halo
    with one depth-s exchange per s recurrence steps: per iteration it
    pays ``(2·⌈n/s⌉-1)/n`` of the depth-s exchange bytes (later groups
    ship the width-doubled ``[w1|w2]`` payload), ``⌈n/s⌉·rounds/n`` of
    the machine's per-round α latency, and a matrix-traffic term
    inflated by the redundant ghost-row contractions
    (``SpmvCommPlan.sstep_work_factor``). With the default α = 0 model
    s=1 always wins (strictly fewer bytes and no saved latency to
    cash); only a latency-bound machine justifies s>1 — exactly the
    planner behavior the acceptance gate checks. s>1 candidates are
    enumerated on the default partition with ``overlap=False`` only
    (the depth-s pass needs the exact pattern; steps ≥ 1 of a group
    have a data dependence on the ghosts, so only step 0 could ever
    overlap — the additive model is the honest one).

    ``n_vc_by_row`` maps n_row -> precomputed n_vc counts (on
    ``Partition(D, n_row, d_pad)`` boundaries) and ``comm_plan_by_row``
    maps n_row -> a full precomputed :class:`SpmvCommPlan` (same
    ``d_pad``), so callers that already paid the pattern pass — e.g. the
    dry-run — are not charged again; both apply to the equal-rows combo
    only.

    ``plan_mode`` ∈ ``partition.PLAN_MODES`` selects the pattern-pass
    strategy. ``"exact"`` (the default) is today's behavior: full
    per-pair passes where affordable, and the balance/reorder axis is
    **dropped with a ``UserWarning``** when the instance exceeds the
    ``partition_plan_default`` gate. ``"sampled"`` routes every pattern
    pass through ``core/sketch.py`` — seeded row-subsample χ/L_qp
    estimates (``sample_seed``/``sample_fraction``) and the coarsened
    commvol descent — so planning stays affordable at any D; sampled
    plans carry estimated per-pair counts (``exact=False``), so the
    compressed engines still rank, while s>1 candidates (which demand
    the exact pattern) are skipped, as is ``reorder="rcm"``. ``"auto"``
    resolves to exact below the gate and sampled above it.
    """
    P = int(n_devices)
    D = matrix.shape[0] if isinstance(matrix, CSR) else matrix.D
    if S_d is None:
        S_d = matrix.S_d if hasattr(matrix, "S_d") else (
            matrix.data.dtype.itemsize if getattr(matrix, "data", None) is not None else 8)
    if n_nzr is None:
        n_nzr = estimate_nnzr(matrix)
    if splits is None:
        splits = [(P // c, c) for c in range(1, P + 1)
                  if P % c == 0 and n_search % c == 0]
    if not splits:
        raise ValueError(f"no (n_row, n_col) split of P={P} divides n_search={n_search}")
    for sch in set(schedule):
        # validated up front so a typo is caught even when the comm axis
        # happens to exclude "compressed"
        if sch not in SPMV_SCHEDULES:
            raise ValueError(f"unknown schedule {sch!r}")
    ssteps = tuple(dict.fromkeys(int(s) for s in sstep))
    for s in ssteps:
        if s < 1:
            raise ValueError(f"sstep values must be >= 1, got {s}")
    partitions: list[tuple[str, str]] = []
    for bal in dict.fromkeys(balance):
        if bal not in SPMV_BALANCES:
            raise ValueError(f"unknown balance {bal!r} "
                             f"(expected one of {SPMV_BALANCES})")
        for ro in dict.fromkeys(reorder):
            if ro not in SPMV_REORDERS:
                raise ValueError(f"unknown reorder {ro!r} "
                                 f"(expected one of {SPMV_REORDERS})")
            partitions.append((bal, ro))
    if plan_mode not in PLAN_MODES:
        raise ValueError(f"unknown plan_mode {plan_mode!r} "
                         f"(expected one of {PLAN_MODES})")
    plan_ok = partition_plan_default(matrix, P)
    use_sampled = plan_mode == "sampled" or (plan_mode == "auto"
                                             and not plan_ok)

    plans: dict[int, SpmvCommPlan] = dict(comm_plan_by_row or {})
    sstep_plans: dict[tuple[int, int], SpmvCommPlan] = {}  # (n_row, s>1)
    mapped_plans: dict[tuple[str, str, int], SpmvCommPlan] = {}
    rowmaps: dict[tuple[str, str], RowMap] = {}
    pattern = None  # one pattern pass shared by every planned combo
    cands: list[Candidate] = []
    gate_warned = False
    for bal, ro in partitions:
        default_part = bal == "rows" and ro == "none"
        if not default_part:
            if not plan_ok and not use_sampled:
                # per-row pattern pass unaffordable at this D/P — the
                # axis is dropped, but never silently
                if not gate_warned:
                    import warnings

                    from .partition import (PARTITION_PLAN_MAX_D,
                                            PARTITION_PLAN_MAX_P)
                    warnings.warn(
                        f"plan_layout: dropping the balance/reorder "
                        f"partition axis — D={D}, P={P} exceeds the "
                        f"exact partition-planner gate "
                        f"(PARTITION_PLAN_MAX_D={PARTITION_PLAN_MAX_D}, "
                        f"PARTITION_PLAN_MAX_P={PARTITION_PLAN_MAX_P}); "
                        f"pass plan_mode='sampled' (CLI: --plan-mode "
                        f"sampled) to plan it from a row subsample "
                        f"instead", UserWarning, stacklevel=2)
                    gate_warned = True
                continue
            if use_sampled and ro != "none":
                continue  # RCM needs the full adjacency — exact-only
            if (bal, ro) not in rowmaps:
                if use_sampled:
                    rowmaps[(bal, ro)] = plan_rowmap(
                        matrix, P, balance=bal, reorder=ro,
                        plan_mode="sampled", sample_seed=sample_seed,
                        sample_fraction=sample_fraction)
                else:
                    if pattern is None:
                        from .partition import _pattern_csr

                        pattern = _pattern_csr(matrix)
                    rowmaps[(bal, ro)] = plan_rowmap(matrix, P,
                                                     balance=bal,
                                                     reorder=ro,
                                                     pattern=pattern)
            rowmap = rowmaps[(bal, ro)]
            if rowmap.identity:
                continue  # the planned map degenerated to equal rows —
                # its candidates would be pure duplicates
        for n_row, n_col in splits:
            if n_row * n_col != P:
                raise ValueError(f"split {n_row}x{n_col} != P={P}")
            if default_part:
                if n_row not in plans:
                    n_vc_pre = (n_vc_by_row or {}).get(n_row)
                    if (use_sampled and n_row > 1 and n_vc_pre is None
                            and exact_comm is not True):
                        from .sketch import sampled_comm_plan

                        plans[n_row] = sampled_comm_plan(
                            matrix, n_row, d_pad=d_pad,
                            fraction=sample_fraction, seed=sample_seed)
                    else:
                        plans[n_row] = comm_plan(
                            matrix, n_row, d_pad=d_pad, exact=exact_comm,
                            n_vc=n_vc_pre)
                cp = plans[n_row]
            else:
                key = (bal, ro, n_row)
                if key not in mapped_plans:
                    if use_sampled:
                        from .sketch import sampled_comm_plan

                        mapped_plans[key] = sampled_comm_plan(
                            matrix, n_row, rowmap=rowmap,
                            fraction=sample_fraction, seed=sample_seed)
                    else:
                        mapped_plans[key] = comm_plan(matrix, n_row,
                                                      rowmap=rowmap)
                cp = mapped_plans[key]
            chim = cp.chi
            chi1 = chim.chi1 if n_row > 1 else 0.0
            if not default_part and chi1 <= 0.0:
                # no halo exchange to re-balance: the planned partition
                # is a pure duplicate of the equal-rows candidate
                continue
            n_b = n_search // n_col
            name = "stack" if n_col == 1 else (
                "pillar" if n_col == P else "panel")
            t_red = 0.0
            if n_col > 1:
                # per-device moved bytes of one redistribution (Eq. 18
                # total spread over P devices) through the inter-process
                # bandwidth
                t_red = (redistribution_volume(D, n_search, P, n_col, S_d)
                         ["bytes_total"] / P / machine.b_c)
            engines: list[tuple[str, str]] = []
            for eng in sorted(set(comm)):
                if eng not in SPMV_COMM_ENGINES:
                    raise ValueError(f"unknown comm engine {eng!r}")
                if eng == "a2a":
                    engines.append((eng, "cyclic"))  # schedule is a no-op
                    continue
                for sch in sorted(set(schedule)):
                    engines.append((eng, sch))
            for eng, sch in engines:
                if eng == "compressed" and chi1 <= 0.0:
                    continue  # no halo exchange: compressed == a2a
                if eng == "compressed" and cp.pair_counts is None:
                    # estimated-path n_vc gives only a lower bound on the
                    # schedule volume — never claim a compressed win the
                    # pattern hasn't proven
                    continue
                for s in ssteps:
                    if s == 1:
                        moved = cp.moved_entries_per_device(eng, sch)
                        rounds = float(cp.rounds_per_exchange(eng, sch))
                        wf = 1.0
                        bytes_dev = cp.comm_bytes_per_device(eng, n_b,
                                                             S_d, sch)
                    else:
                        # seventh axis: default partition only (the
                        # depth-s BFS needs the exact pattern; a planned
                        # map would need re-planning at depth s), and
                        # only where there is an exchange to avoid
                        if not default_part or chi1 <= 0.0 or not cp.exact:
                            continue
                        if (n_row, s) not in sstep_plans:
                            sstep_plans[(n_row, s)] = comm_plan(
                                matrix, n_row, d_pad=d_pad, sstep=s)
                        cps = sstep_plans[(n_row, s)]
                        ng = cps.n_groups(degree)
                        # bytes per iteration: one single-width + ng-1
                        # double-width exchanges over the whole filter
                        moved = (cps.moved_entries_per_device(eng, sch)
                                 * (2 * ng - 1) / degree)
                        rounds = (cps.rounds_per_exchange(eng, sch)
                                  * ng / degree)
                        wf = cps.sstep_work_factor()
                        bytes_dev = int(round(moved * n_b * S_d))
                    chi_eng = pm.engine_chi(moved, D, n_row)
                    kw = dict(D=D, N_p=n_row, n_b=n_b, chi=chi_eng,
                              n_nzr=n_nzr, S_d=S_d)
                    for ov in sorted(set(overlap)):
                        if ov and chi1 <= 0.0:
                            continue  # overlap is a no-op without an exchange
                        if ov and s > 1:
                            continue  # steps >= 1 depend on the ghosts
                        for kn in sorted(set(kernel)):
                            mk = (pm.fused_kernel_machine(machine)
                                  if kn else machine)
                            t_iter = (pm.cheb_iter_time_overlap(
                                          mk, **kw, rounds=rounds)
                                      if ov else pm.cheb_iter_time(
                                          mk, **kw, rounds=rounds,
                                          work_factor=wf))
                            cands.append(Candidate(
                                layout=name, n_row=n_row, n_col=n_col,
                                overlap=ov, comm=eng, schedule=sch,
                                redistribute=n_col > 1,
                                chi1=chi1, chi2=chim.chi2, chi_eng=chi_eng,
                                t_iter=t_iter, t_redist=t_red,
                                t_pass=degree * t_iter + 2.0 * t_red,
                                comm_bytes_per_device=bytes_dev,
                                balance=bal, reorder=ro, kernel=kn,
                                sstep=s,
                                rowmap=None if default_part else rowmap,
                            ))
    if not cands:
        raise ValueError(
            f"no candidate survived for P={P}, n_search={n_search}, "
            f"overlap={overlap}, splits={splits} — overlap-only planning "
            f"needs at least one split with chi > 0 (n_row > 1)")
    # ties prefer fewer wire bytes first (the overlap model hides a
    # fully-overlapped exchange, so engines/partitions that differ only
    # in moved bytes tie on time — the lighter wire footprint is the
    # robust choice), then the simpler configuration: a2a before
    # compressed, cyclic rounds before matching, equal rows before
    # commvol, natural order before rcm, additive before overlap
    cands.sort(key=lambda c: (c.t_pass, c.comm_bytes_per_device,
                              c.comm != "a2a", c.schedule != "cyclic",
                              c.balance != "rows", c.reorder != "none",
                              c.overlap, c.kernel, c.sstep, c.n_col))
    return Plan(matrix=_matrix_label(matrix), D=D, n_devices=P,
                n_search=n_search, degree=degree, machine=machine.name,
                candidates=tuple(cands))


# --------------------------------------------------------------------------
# mesh-constrained planning (FDConfig.layout = "auto")
# --------------------------------------------------------------------------


def _mesh_size(mesh) -> int:
    n = 1
    for a in mesh.axis_names:
        n *= mesh.shape[a]
    return n


def default_row_axes(mesh) -> tuple[str, ...]:
    """Horizontal-layer (D-sharding) axes of a mesh by naming convention:
    the solver mesh's ``row`` axis, else the production mesh's ``model``
    axis (the dry-run maps the horizontal layer there), else the first
    axis. Returns () only for a mesh with no axes."""
    names = tuple(mesh.axis_names)
    for preferred in ("row", "model"):
        if preferred in names:
            return (preferred,)
    return names[:1]


def plan_for_mesh(matrix, mesh, *, n_search: int, row_axes=None,
                  **kwargs) -> Plan:
    """Rank the layouts realizable on an **existing** mesh: stack (all axes
    on D), panel (``row_axes`` × the rest; default
    :func:`default_row_axes`), pillar (all axes on bundles). Used by
    ``FilterDiag`` when ``FDConfig.layout == "auto"`` — the mesh shape is
    already fixed, so only the layout and overlap choice remain.
    """
    P = _mesh_size(mesh)
    if row_axes is None:
        row_axes = default_row_axes(mesh)
    row_axes = tuple(a for a in row_axes if a in mesh.axis_names)
    n_row = 1
    for a in row_axes:
        n_row *= mesh.shape[a]
    splits = []
    for nr, nc in ((P, 1), (n_row, P // max(n_row, 1)), (1, P)):
        if nr >= 1 and nc >= 1 and nr * nc == P and n_search % nc == 0 \
                and (nr, nc) not in splits:
            splits.append((nr, nc))
    return plan_layout(matrix, P, n_search=n_search, splits=splits, **kwargs)


def layout_on_mesh(mesh, layout_name: str, row_axes=None) -> Layout:
    """Materialize a planner layout choice as a ``Layout`` on ``mesh``.

    ``row_axes`` defaults to :func:`default_row_axes`; passing axes
    explicitly raises if none of them exist on the mesh (a panel without
    a row axis would silently be a pillar)."""
    base = layout_name.removesuffix("+ov")
    if base == "stack":
        return Layout("stack", tuple(mesh.axis_names), ())
    if base == "pillar":
        return pillar(mesh)
    if base == "panel":
        if row_axes is None:
            row_axes = default_row_axes(mesh)
        row_axes = tuple(a for a in row_axes if a in mesh.axis_names)
        if not row_axes:
            raise ValueError(
                f"panel layout needs a row axis, but mesh axes "
                f"{mesh.axis_names} contain none of the requested row axes")
        return panel(mesh, row_axes=row_axes)
    raise ValueError(f"unknown layout {layout_name!r}")

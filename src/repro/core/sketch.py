"""Sampled pattern statistics: streaming-scale planning for D ≥ 10⁷.

The paper's metric χ (Eqs. 8–10) is a *pattern statistic*: it depends on
the sparsity pattern alone, not on any matrix values or executed code.
Statistics subsample — a planner does not need every row to estimate
them. This module is the sampled counterpart of the exact pattern passes
in ``core/partition.py`` / ``core/planner.py``, and is what
``plan_mode="sampled"`` (CLI ``--plan-mode sampled``) routes through:

  * :func:`estimate_comm` — estimate the per-pair distinct volumes
    ``L_qp`` (and from them n_vc, χ₁/χ₂/χ₃) from a seeded row subsample
    with a Horvitz–Thompson-style scale-up, plus an explicit
    **confidence band** per χ metric from deterministic sample folds.
    :meth:`SampledCommEstimate.comm_plan` wraps the estimates in the
    same :class:`~repro.core.planner.SpmvCommPlan` the exact pass
    produces (``exact=False``, estimated ``pair_counts``), so the
    planner's scoring, the compressed-engine schedules, and the plan
    linter all consume them unchanged.

  * :func:`coarsened_commvol_boundaries` — ``commvol_boundaries``' cut
    descent run on a supernode-coarsened cost graph: rows are bucketed,
    per-bucket ``α·nnz + β·cut`` costs are aggregated from the sample
    (HT-weighted), the descent moves cuts at bucket granularity, and a
    row-granularity refinement pass then polishes the cuts on the
    sampled pattern. The never-worse-than-equal-rows guard is kept
    (under the sampled objective). At ``fraction >= 1`` the sampled
    pattern *is* the exact pattern, so the estimators degrade gracefully
    into their exact counterparts — the statistical test harness
    (``tests/test_sketch.py``) asserts exactly that convergence.

The estimator: sample each block's rows without replacement at realized
rate ``r = m/n``. A distinct remote column with row-multiplicity ``d``
(it appears in ``d`` of the block's rows) is *observed* with probability
``π(d) = 1 − (1−r)^d``. We cannot see ``d`` directly, but the observed
mean incidences-per-distinct-column ``μ = t/u`` identifies it:
``E[μ | observed] = d·r / π(d)``, which is strictly increasing in d, so
a bisection inverts it per (sender, receiver) pair. The
Horvitz–Thompson scale-up ``L̂ = u / π(d̂)`` is then clipped to the
logical bounds ``[u, min(t/r, n_sender)]`` (at ``r = 1``: ``L̂ = u``
exactly). Confidence bands come from K deterministic folds of the
sample (fold = rank within the block mod K): the per-fold χ estimates,
the full-sample center, and a Richardson-style extrapolation span an
interval that is padded and advertised at :data:`CONF_LEVEL`.

Everything is deterministic per ``(seed, fraction)``: one
``np.random.default_rng(seed)`` is consumed block-by-block in a fixed
order, so the same call always yields the same plan — the property the
plan cache and the test harness both rely on.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..matrices.sparse import CSR, gather_row_entry_idx
from .metrics import ChiMetrics, chi_from_nvc
from .partition import RowMap, _WireObjective, _normalize_boundaries, equal_cuts

__all__ = ["SAMPLE_TARGET_ROWS", "MIN_BLOCK_SAMPLE", "MIN_BUCKET_SAMPLE",
           "DEFAULT_FOLDS", "CONF_LEVEL", "ChiBand", "SampledCommEstimate",
           "default_fraction", "estimate_comm", "sampled_comm_plan",
           "coarsened_commvol_boundaries"]

#: Total sampled rows the default fraction aims for — enough that every
#: block of a P ≤ 64 partition sees thousands of rows, small enough that
#: a D = 10⁷ instance samples under 1% of its rows.
SAMPLE_TARGET_ROWS = 65_536

#: Per-block floor on sampled rows for the χ/L_qp estimator (blocks
#: smaller than this are read in full).
MIN_BLOCK_SAMPLE = 64

#: Per-bucket floor for the coarsened descent's cost aggregation (B is
#: large, so a handful of rows per bucket suffices).
MIN_BUCKET_SAMPLE = 4

#: Fold count of the confidence-band construction.
DEFAULT_FOLDS = 5

#: Advertised coverage of :class:`ChiBand` — the statistical test
#: harness checks the realized coverage over seeds against this rate.
CONF_LEVEL = 0.8

#: Band padding: half-widths are ``_BAND_SPREAD_PAD · (fold spread)``
#: plus ``_BAND_REL_PAD · center`` — the additive relative term keeps
#: zero-spread bands (e.g. fully sampled blocks) honestly non-degenerate.
_BAND_SPREAD_PAD = 0.75
_BAND_REL_PAD = 0.05


def default_fraction(D: int, n_blocks: int = 1) -> float:
    """Sampling fraction targeting :data:`SAMPLE_TARGET_ROWS` rows total
    (with at least :data:`MIN_BLOCK_SAMPLE` expected per block)."""
    target = max(SAMPLE_TARGET_ROWS, MIN_BLOCK_SAMPLE * n_blocks)
    return min(1.0, target / max(int(D), 1))


def _sample_block(rng: np.random.Generator, a: int, b: int,
                  fraction: float, min_rows: int) -> np.ndarray:
    """Sorted distinct row indices sampled from [a, b).

    Draws with replacement and deduplicates — conditioned on its size the
    result is a uniform without-replacement subset, and the draw count
    ``-n·ln(1-f)`` makes the expected distinct count ≈ ``f·n``. The
    realized rate ``m/n`` (not ``f``) feeds the HT scale-up.
    """
    n = int(b) - int(a)
    if n <= 0:
        return np.empty(0, dtype=np.int64)
    want = max(min(n, int(min_rows)), int(np.ceil(fraction * n)))
    if fraction >= 1.0 or want >= n:
        return np.arange(a, b, dtype=np.int64)
    draws = max(int(np.ceil(-n * np.log1p(-want / n))), want)
    return np.unique(rng.integers(a, b, size=draws))


def _rows_cols(matrix, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(row, col) pattern incidences of ``rows`` for a CSR or a family."""
    rows = np.asarray(rows, dtype=np.int64)
    if isinstance(matrix, CSR):
        gather, counts = gather_row_entry_idx(matrix.indptr, rows)
        return np.repeat(rows, counts), matrix.indices[gather].astype(np.int64)
    r, c = matrix.row_cols(rows)
    return np.asarray(r, dtype=np.int64), np.asarray(c, dtype=np.int64)


def _dedup_pairs(r: np.ndarray, c: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Distinct (row, col) pairs, sorted by (row, col) — families may emit
    duplicate entries, and the HT multiplicity model counts *rows*."""
    if not len(r):
        return r, c
    order = np.lexsort((c, r))
    r, c = r[order], c[order]
    keep = np.ones(len(r), dtype=bool)
    keep[1:] = (r[1:] != r[:-1]) | (c[1:] != c[:-1])
    return r[keep], c[keep]


def _invert_multiplicity(mu: np.ndarray, r: float) -> np.ndarray:
    """Solve ``μ = d·r / (1 − (1−r)^d)`` for the row-multiplicity d ≥ 1.

    The right side is strictly increasing in d (from 1 at d = 1 toward
    ``d·r``), so a vectorized bisection converges unconditionally; the
    upper bracket ``2μ/r`` satisfies ``g(d) ≥ d·r = 2μ ≥ μ``.
    """
    mu = np.maximum(np.asarray(mu, dtype=np.float64), 1.0)
    if r >= 1.0:
        return mu
    log1mr = np.log1p(-r)

    def g(d):
        return d * r / -np.expm1(d * log1mr)

    lo = np.ones_like(mu)
    hi = np.maximum(2.0 * mu / r, 2.0)
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        low = g(mid) < mu
        lo = np.where(low, mid, lo)
        hi = np.where(low, hi, mid)
    return 0.5 * (lo + hi)


def _estimate_sender_counts(owner: np.ndarray, col: np.ndarray, r: float,
                            P: int, sender_cap: np.ndarray) -> np.ndarray:
    """Per-sender estimated distinct remote columns of one receiver.

    ``owner``/``col`` are the receiver's deduplicated (sampled row,
    remote col) incidences, reduced to the column's owner block and
    partition-space column id. Per sender the observed distinct count
    ``u`` and incidence count ``t`` give ``μ = t/u``; the inverted
    multiplicity yields the inclusion probability ``π`` and the HT
    estimate ``u/π``, clipped to ``[u, min(t/r, sender size)]``.
    """
    est = np.zeros(P, dtype=np.int64)
    if not len(owner):
        return est
    order = np.lexsort((col, owner))
    o, c = owner[order], col[order]
    new = np.ones(len(o), dtype=bool)
    new[1:] = (o[1:] != o[:-1]) | (c[1:] != c[:-1])
    u = np.bincount(o[new], minlength=P).astype(np.float64)
    t = np.bincount(o, minlength=P).astype(np.float64)
    nz = u > 0
    if not nz.any():
        return est
    if r >= 1.0:
        est[nz] = u[nz].astype(np.int64)
        return est
    d = _invert_multiplicity(t[nz] / u[nz], r)
    pi = -np.expm1(d * np.log1p(-r))
    raw = u[nz] / np.maximum(pi, 1e-300)
    hi = np.maximum(u[nz], np.minimum(t[nz] / r, sender_cap[nz]))
    est[nz] = np.round(np.clip(raw, u[nz], hi)).astype(np.int64)
    return est


# --------------------------------------------------------------------------
# sampled χ / L_qp estimation with confidence bands
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChiBand:
    """Per-metric confidence intervals of a sampled χ estimate."""

    level: float
    chi1: tuple[float, float]
    chi2: tuple[float, float]
    chi3: tuple[float, float]

    def valid(self) -> bool:
        """Structural validity: advertised level in (0, 1), lo ≤ hi."""
        return (0.0 < self.level < 1.0
                and all(lo <= hi and lo >= 0.0
                        for lo, hi in (self.chi1, self.chi2, self.chi3)))

    def contains(self, chi: ChiMetrics) -> bool:
        """Whether every metric of ``chi`` falls inside its interval."""
        return all(lo <= v <= hi for v, (lo, hi) in
                   ((chi.chi1, self.chi1), (chi.chi2, self.chi2),
                    (chi.chi3, self.chi3)))


@dataclasses.dataclass(frozen=True)
class SampledCommEstimate:
    """Sampled communication statistics of one partition of one matrix.

    ``pair_counts[q, p]`` is the estimated distinct-column volume sender
    q ships receiver p; ``n_vc`` its column sums (the estimated Eq. 5
    counts), ``chi`` the χ metrics on the real per-block row counts
    ``n_vm``, and ``band`` the per-metric confidence intervals.
    """

    n_row: int
    D: int
    fraction: float
    seed: int
    sampled_rows: int
    pair_counts: np.ndarray
    n_vc: np.ndarray
    n_vm: np.ndarray
    chi: ChiMetrics
    band: ChiBand
    d_pad: int | None = None
    rowmap: RowMap | None = dataclasses.field(default=None, repr=False,
                                              compare=False)

    @property
    def L(self) -> int:
        """Estimated max per-pair volume — the a2a engine's pad."""
        return int(self.pair_counts.max()) if self.pair_counts.size else 0

    def comm_plan(self):
        """The estimate as a :class:`~repro.core.planner.SpmvCommPlan`
        (``exact=False`` but with per-pair counts, so the planner can
        rank the compressed engines on it)."""
        from .planner import SpmvCommPlan  # lazy: planner imports us lazily

        return SpmvCommPlan(self.n_row, self.D, self.L, self.n_vc, False,
                            self.d_pad, pair_counts=self.pair_counts,
                            rowmap=self.rowmap)


def _partition_geometry(matrix, n_row: int, d_pad: int | None,
                        rowmap: RowMap | None):
    """(boundaries, R, n_vm, perm, pos, d_pad) of the sampling space.

    Without a rowmap the space is the natural row order with the
    equal-rows ``Partition`` cuts; with one it is the *reordered* row
    order (block g = reordered rows ``[bnd[g·k], bnd[(g+1)·k])``, which
    is contiguous for any level with ``rowmap.P % n_row == 0``), with
    columns mapped through the position embed.
    """
    D = matrix.shape[0] if isinstance(matrix, CSR) else matrix.D
    if rowmap is not None and not rowmap.identity:
        if rowmap.D != D:
            raise ValueError("rowmap.D does not match the matrix")
        if rowmap.P % n_row:
            raise ValueError(f"rowmap planned at P={rowmap.P} cannot be "
                             f"sampled at level n_row={n_row} "
                             f"(P % n_row != 0)")
        k = rowmap.P // n_row
        bnds = rowmap.boundaries[::k].astype(np.int64)
        R = rowmap.level_R(n_row)
        return bnds, R, np.diff(bnds), rowmap.perm, rowmap.pos, rowmap.D_pad
    R = (d_pad // n_row) if d_pad is not None else -(-D // n_row)
    bnds = np.minimum(np.arange(n_row + 1, dtype=np.int64) * R, D)
    return bnds, R, np.diff(bnds), None, None, d_pad


def estimate_comm(matrix, n_row: int, *, d_pad: int | None = None,
                  rowmap: RowMap | None = None, fraction: float | None = None,
                  seed: int = 0, folds: int = DEFAULT_FOLDS
                  ) -> SampledCommEstimate:
    """Estimate per-pair volumes and χ of ``matrix`` at ``n_row`` shards
    from a seeded row subsample (see the module docstring for the
    estimator). ``rowmap`` evaluates the planned partition instead of
    the equal-rows one — the sampled analogue of
    ``planner.comm_plan(rowmap=...)``. Deterministic per
    ``(seed, fraction)``.
    """
    D = matrix.shape[0] if isinstance(matrix, CSR) else matrix.D
    P = int(n_row)
    bnds, R, n_vm, perm, pos, d_pad_out = _partition_geometry(
        matrix, P, d_pad, rowmap)
    zero_chi = chi_from_nvc(np.zeros(max(P, 1), np.int64), n_vm, D)
    if P <= 1:
        band = ChiBand(CONF_LEVEL, (0.0, 0.0), (0.0, 0.0), (0.0, 0.0))
        return SampledCommEstimate(
            1, D, 1.0, seed, 0, np.zeros((1, 1), np.int64),
            np.zeros(1, np.int64), n_vm, zero_chi, band, d_pad_out,
            rowmap if rowmap is not None and not rowmap.identity else None)
    if fraction is None:
        fraction = default_fraction(D, P)
    folds = max(int(folds), 1)
    rng = np.random.default_rng(seed)
    cap = n_vm.astype(np.float64)
    pair_counts = np.zeros((P, P), dtype=np.int64)
    n_vc_fold = np.zeros((folds, P), dtype=np.int64)
    fold_rate = np.ones((folds, P), dtype=np.float64)
    sampled_total = 0
    for p in range(P):
        a, b = int(bnds[p]), int(bnds[p + 1])
        idx = _sample_block(rng, a, b, fraction, MIN_BLOCK_SAMPLE)
        m = idx.size
        if m == 0:
            continue
        sampled_total += m
        rate = m / (b - a)
        fold_of = np.arange(m, dtype=np.int64) % folds
        rows_fetch = perm[idx] if perm is not None else idx
        rinc, cinc = _rows_cols(matrix, rows_fetch)
        if pos is not None:
            cpart = pos[cinc]
        else:
            cpart = cinc
        # keep remote incidences only, dedup per (row, col)
        owner_inc = np.minimum(cpart // R, P - 1)
        keep = owner_inc != p
        rinc, cpart = _dedup_pairs(rinc[keep], cpart[keep])
        owner_inc = np.minimum(cpart // R, P - 1)
        # fold of each incidence, via the sampled-row rank (rows_fetch is
        # unsorted under a reorder perm: argsort + searchsorted)
        if perm is not None:
            o = np.argsort(rows_fetch, kind="stable")
            rank = o[np.searchsorted(rows_fetch[o], rinc)]
        else:
            rank = np.searchsorted(rows_fetch, rinc)
        finc = fold_of[rank]
        pair_counts[:, p] = _estimate_sender_counts(
            owner_inc, cpart, rate, P, cap)
        for k in range(folds):
            mk = int((fold_of == k).sum())
            if mk == 0:
                continue
            fold_rate[k, p] = mk / (b - a)
            sel = finc == k
            n_vc_fold[k, p] = _estimate_sender_counts(
                owner_inc[sel], cpart[sel], fold_rate[k, p], P, cap).sum()
    n_vc = pair_counts.sum(axis=0)
    center = chi_from_nvc(n_vc, n_vm, D)
    fold_chis = [chi_from_nvc(n_vc_fold[k], n_vm, D) for k in range(folds)]
    intervals = {}
    for metric in ("chi1", "chi2", "chi3"):
        cv = getattr(center, metric)
        fv = np.array([getattr(fc, metric) for fc in fold_chis])
        vals = np.concatenate([fv, [cv, 2.0 * cv - fv.mean()]])
        spread = float(vals.max() - vals.min())
        pad = _BAND_SPREAD_PAD * spread + _BAND_REL_PAD * cv
        intervals[metric] = (max(0.0, float(vals.min()) - pad),
                             float(vals.max()) + pad)
    band = ChiBand(CONF_LEVEL, intervals["chi1"], intervals["chi2"],
                   intervals["chi3"])
    return SampledCommEstimate(
        P, D, float(fraction), int(seed), sampled_total, pair_counts,
        n_vc, n_vm, center, band, d_pad_out,
        rowmap if rowmap is not None and not rowmap.identity else None)


def sampled_comm_plan(matrix, n_row: int, *, d_pad: int | None = None,
                      rowmap: RowMap | None = None,
                      fraction: float | None = None, seed: int = 0):
    """:func:`estimate_comm` wrapped as the ``SpmvCommPlan`` the planner
    scores — the drop-in sampled replacement for ``comm_plan``."""
    return estimate_comm(matrix, n_row, d_pad=d_pad, rowmap=rowmap,
                         fraction=fraction, seed=seed).comm_plan()


# --------------------------------------------------------------------------
# coarsened commvol descent
# --------------------------------------------------------------------------


def coarsened_commvol_boundaries(matrix, P: int, *, alpha: float = 1.0,
                                 beta: float = 4.0,
                                 fraction: float | None = None,
                                 seed: int = 0, n_buckets: int | None = None,
                                 sweeps: int = 3, growth: float = 1.5,
                                 refine_passes: int = 3) -> np.ndarray:
    """``commvol_boundaries`` on a bucket-coarsened, row-sampled cost
    graph: non-uniform block cuts without a full pattern pass.

    Three deterministic stages:

    1. **HT-weighted prefix balance** — rows are bucketed into
       ``B ≈ 64·P`` equal supernodes; each bucket's cost
       ``Σ w_r (α·nnz(r) + β·cut(r))`` is aggregated from its sampled
       rows (weight ``w_r`` = inverse realized sampling rate) and
       re-swept as cuts move, exactly like the exact planner's seed.
    2. **Coarse cut descent** — the ``_WireObjective`` greedy descent in
       bucket-index space on the bucket-level sampled pattern (unique
       (row bucket, col bucket) pairs), from both the prefix seed and
       the equal bucket cuts.
    3. **Row-granularity refinement** — the same descent on the sampled
       pattern laid out at full row resolution (only sampled rows carry
       entries), polishing the coarse cuts to row precision.

    The equal-rows cuts participate as a candidate throughout and win
    ties, so the result is never worse than ``balance="rows"`` *under
    the sampled objective*. At ``fraction >= 1`` stage 3 sees the exact
    pattern and the descent matches ``commvol_boundaries``' quality.
    """
    D = matrix.shape[0] if isinstance(matrix, CSR) else matrix.D
    if P <= 1 or D <= P:
        return equal_cuts(D, P)
    equal = equal_cuts(D, P)
    B = int(n_buckets) if n_buckets else min(D, max(64 * P, 1024))
    bedges = equal_cuts(D, B)
    if fraction is None:
        fraction = default_fraction(D, B)
    rng = np.random.default_rng(seed)
    idx_parts = []
    w_parts = []
    for bkt in range(B):
        a, b = int(bedges[bkt]), int(bedges[bkt + 1])
        s = _sample_block(rng, a, b, fraction, MIN_BUCKET_SAMPLE)
        if s.size:
            idx_parts.append(s)
            w_parts.append(np.full(s.size, (b - a) / s.size))
    if not idx_parts:
        return equal
    srows = np.concatenate(idx_parts)           # sorted distinct rows
    w = np.concatenate(w_parts)                 # HT weight per sampled row
    rinc, cinc = _dedup_pairs(*_rows_cols(matrix, srows))
    if not len(rinc):
        return equal
    rank = np.searchsorted(srows, rinc)         # sampled-row id of each inc.
    n_s = srows.size
    nnz_s = np.bincount(rank, minlength=n_s).astype(np.float64)
    bucket_of = np.searchsorted(bedges, srows, side="right") - 1
    cap = int(-(-D // P) * growth)

    def row_costs(bnds: np.ndarray) -> np.ndarray:
        blk_row = np.searchsorted(bnds, srows, side="right") - 1
        blk_col = np.searchsorted(bnds, cinc, side="right") - 1
        cut = np.bincount(rank, weights=(blk_col != blk_row[rank]),
                          minlength=n_s)
        return w * (alpha * nnz_s + beta * cut)

    # stage 1: HT-weighted prefix balance over bucket costs
    bnds = equal
    cost_s = row_costs(bnds)
    for _ in range(sweeps):
        cb = np.bincount(bucket_of, weights=cost_s, minlength=B)
        cum = np.concatenate([[0.0], np.cumsum(cb)])
        targets = cum[-1] * np.arange(1, P, dtype=np.float64) / P
        inner = bedges[np.clip(np.searchsorted(cum, targets, side="left"),
                               0, B)]
        new = _normalize_boundaries(
            np.concatenate([[0], inner, [D]]), D, P, cap)
        if (new == bnds).all():
            break
        bnds = new
        cost_s = row_costs(bnds)

    # stage 2: coarse descent on the bucket-level sampled pattern
    brow = bucket_of[rank]
    bcol = np.searchsorted(bedges, cinc, side="right") - 1
    bpair_r, bpair_c = _dedup_pairs(brow, bcol)
    indptr_b = np.concatenate(
        [[0], np.cumsum(np.bincount(bpair_r, minlength=B))])
    cb = np.bincount(bucket_of, weights=cost_s, minlength=B)
    obj_b = _WireObjective(indptr_b.astype(np.int64),
                           bpair_c.astype(np.int64), P, cost=cb)
    cap_b = max(int(-(-B // P) * growth), 2)
    seed_b = _normalize_boundaries(
        np.searchsorted(bedges, bnds), B, P, cap_b)
    starts_b = [seed_b, equal_cuts(B, P)]
    coarse = []
    for start in starts_b:
        b_ref, _ = obj_b.refine(start, cap_b, passes=max(refine_passes, 1))
        coarse.append(_normalize_boundaries(bedges[b_ref], D, P, cap))

    # stage 3: row-granularity refinement on the sampled pattern at full
    # row resolution (only sampled rows carry entries/cost)
    indptr_s = np.concatenate(
        [[0], np.cumsum(np.bincount(rinc, minlength=D))]).astype(np.int64)
    cost_vec = np.zeros(D, dtype=np.float64)
    cost_vec[srows] = cost_s
    obj = _WireObjective(indptr_s, cinc, P, cost=cost_vec)
    J_equal, _ = obj.evaluate(equal)
    cand: list[tuple[tuple[int, int], np.ndarray]] = [(J_equal, equal)]
    seen_starts = {tuple(equal)}
    for start in [*coarse, bnds]:
        key = tuple(int(x) for x in start)
        if key in seen_starts:
            continue
        seen_starts.add(key)
        if refine_passes > 0:
            b_ref, J_ref = obj.refine(start, cap, passes=refine_passes)
            cand.append((J_ref, b_ref))
        else:
            cand.append((obj.evaluate(start)[0], start))
    J_best, best = min(cand, key=lambda t: t[0])
    # never-worse guard (sampled objective): keep the equal cuts unless
    # the descent strictly reduced the wire objective
    return equal if J_best[0] >= J_equal[0] else best

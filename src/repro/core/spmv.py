"""Distributed sparse matrix–(multiple)-vector multiplication engine.

Host side (`Partition`, `CommPlan`, `build_dist_ell`): given a matrix
family (or CSR) and the number of row shards P, build

  * equal row blocks  R = ceil(D/P)  (the paper's "nearly equidistant"
    row indices; the tail block is zero-padded),
  * per-shard ELL blocks with *remapped* columns: local columns map to
    [0, R), remote columns map into a halo region [R, R + P*L),
  * a communication plan: for every (sender q -> receiver p) pair the
    sorted list of local entries q must ship to p, padded to the max
    pair volume L.

Device side (`make_spmv`): a ``shard_map`` function executing the paper's
distributed SpMV: gather send slots -> single ``all_to_all`` over the
horizontal (``row``) mesh axes -> local ELL contraction against
``[x_local ‖ halo]``. The all_to_all moves exactly ``P * L * n_b * S_d``
bytes per device — L is the padded max per-pair volume derived from the
paper's n_vc counts, so the measured (HLO) collective volume equals the
pattern-only prediction of ``planner.comm_plan`` bit-for-bit
(tests/test_planner.py) and the χ-metric estimate up to the imbalance
factor χ₃/χ₂.

Overlap execution model (``make_spmv(..., overlap=True)``): each shard's
ELL block is split once, on the host, into a *local* part (columns in
``[0, R)`` — entries resolvable without communication) and a *halo* part
(columns in the remote region ``[R, R + P*L)``), in the spirit of
node-aware SpMV (Bienz, Gropp & Olson, arXiv:1612.08060). The device body
then

  1. launches the halo ``all_to_all`` (no dependence on the local part),
  2. contracts the local ELL while the bytes are in flight,
  3. contracts the halo ELL against the received buffer and accumulates.

On backends with async collectives XLA schedules (1) and (2) concurrently,
hiding the communication behind local work; the cost model becomes

  T = max(T_comm, T_local) + T_halo

instead of the additive ``T = T_comm + T_local+halo`` of Eq. 12 — see
``perf_model.cheb_iter_time_overlap``. Within every output row the split
engine accumulates local entries (ascending column) before halo entries,
which is exactly the unsplit ELL slot order, so baseline and overlapped
engines agree bit-for-bit up to associativity-free summation order.

The vertical (``col``) mesh axes shard the vector bundle; no SpMV
communication crosses them (the paper's central point).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..matrices.families import MatrixFamily
from ..matrices.sparse import CSR, csr_to_ell
from .layouts import Layout

__all__ = ["Partition", "DistEll", "build_dist_ell", "make_spmv", "make_fused_cheb_step"]


# --------------------------------------------------------------------------
# host side
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Partition:
    """Equal-block row partition: block p owns rows [p*R, min((p+1)*R, D)).

    ``d_pad`` (a multiple of P, >= D) fixes the padded global extent so that
    stack- and panel-layout engines over the same vectors agree on shapes;
    defaults to ceil(D/P)*P.
    """

    D: int
    P: int
    d_pad: int | None = None

    @property
    def D_pad(self) -> int:
        if self.d_pad is not None:
            assert self.d_pad % self.P == 0 and self.d_pad >= self.D
            return self.d_pad
        return (-(-self.D // self.P)) * self.P

    @property
    def R(self) -> int:
        return self.D_pad // self.P

    def boundaries(self) -> np.ndarray:
        return np.minimum(np.arange(self.P + 1, dtype=np.int64) * self.R, self.D)

    def owner(self, cols: np.ndarray) -> np.ndarray:
        return np.minimum(cols // self.R, self.P - 1)


@dataclasses.dataclass
class DistEll:
    """Pytree of device arrays for the distributed ELL SpMV.

    All arrays carry a leading P axis that is sharded over the horizontal
    mesh axes inside ``make_spmv``. The four ``*_loc`` / ``*_halo`` fields
    are the split-phase form consumed by the overlap engine; they are
    populated on demand by :meth:`split` (or eagerly with
    ``build_dist_ell(..., split_halo=True)``).
    """

    cols: jax.Array  # [P, R, W] int32, remapped columns
    vals: jax.Array  # [P, R, W] matrix dtype
    send_idx: jax.Array  # [P, P, L] int32 local row indices to ship
    R: int = dataclasses.field(metadata=dict(static=True))
    L: int = dataclasses.field(metadata=dict(static=True))
    P: int = dataclasses.field(metadata=dict(static=True))
    D: int = dataclasses.field(metadata=dict(static=True))
    n_vc: np.ndarray | None = None  # exact per-shard remote counts (diagnostics)
    cols_loc: jax.Array | None = None   # [P, R, W_loc] columns in [0, R)
    vals_loc: jax.Array | None = None   # [P, R, W_loc]
    cols_halo: jax.Array | None = None  # [P, R, W_halo] columns in [0, P*L)
    vals_halo: jax.Array | None = None  # [P, R, W_halo]

    @property
    def comm_bytes_per_spmv(self) -> int:
        """all_to_all payload per vector column, summed over shards."""
        return self.P * self.P * self.L * self.vals.dtype.itemsize

    @property
    def halo_nnz_fraction(self) -> float:
        """Fraction of stored nonzeros in the halo part (perf-model input)."""
        cl, vl, ch, vh = self.split()
        n_halo = int(np.count_nonzero(np.asarray(vh)))
        n_loc = int(np.count_nonzero(np.asarray(vl)))
        return n_halo / max(n_halo + n_loc, 1)

    def split(self):
        """Split the combined ELL into (cols_loc, vals_loc, cols_halo,
        vals_halo) for the overlap engine; cached after the first call.

        Local columns keep their [0, R) indices; halo columns are rebased
        into the received buffer, i.e. [0, P*L). Per row, the split parts
        preserve the combined slot order (local ascending, then halo
        ascending), so split + unsplit contractions sum in the same order.
        """
        if self.cols_loc is not None:
            return self.cols_loc, self.vals_loc, self.cols_halo, self.vals_halo
        cols = np.asarray(self.cols)
        vals = np.asarray(self.vals)
        P, R, W = cols.shape
        stored = vals != 0
        is_halo = stored & (cols >= self.R)
        is_loc = stored & ~is_halo
        W_loc = int(is_loc.sum(axis=2).max()) if W else 0
        W_halo = int(is_halo.sum(axis=2).max()) if W else 0
        W_loc = max(W_loc, 1)  # keep the local block non-degenerate
        cols_loc = np.zeros((P, R, W_loc), dtype=cols.dtype)
        vals_loc = np.zeros((P, R, W_loc), dtype=vals.dtype)
        cols_halo = np.zeros((P, R, W_halo), dtype=cols.dtype)
        vals_halo = np.zeros((P, R, W_halo), dtype=vals.dtype)
        for p in range(P):
            for part, mask, carr, varr, rebase in (
                ("loc", is_loc[p], cols_loc[p], vals_loc[p], 0),
                ("halo", is_halo[p], cols_halo[p], vals_halo[p], self.R),
            ):
                rows, slots = np.nonzero(mask)
                if not len(rows):
                    continue
                counts = np.bincount(rows, minlength=R)
                out_slot = np.arange(len(rows)) - np.repeat(
                    np.cumsum(counts) - counts, counts
                )
                carr[rows, out_slot] = cols[p][rows, slots] - rebase
                varr[rows, out_slot] = vals[p][rows, slots]
        self.cols_loc = jnp.asarray(cols_loc)
        self.vals_loc = jnp.asarray(vals_loc)
        self.cols_halo = jnp.asarray(cols_halo)
        self.vals_halo = jnp.asarray(vals_halo)
        return self.cols_loc, self.vals_loc, self.cols_halo, self.vals_halo


def _pattern_chunks(matrix, rows):
    r, c, v = matrix.row_entries(rows)
    return r, c, v


def build_dist_ell(
    matrix: MatrixFamily | CSR,
    P_row: int,
    dtype=None,
    d_pad: int | None = None,
    split_halo: bool = False,
) -> DistEll:
    """Build per-shard ELL blocks + comm plan for P_row horizontal shards.

    With ``split_halo=True`` the local/halo split consumed by the overlap
    engine is built eagerly (otherwise ``make_spmv(..., overlap=True)``
    materializes it lazily on first use).
    """
    if isinstance(matrix, CSR):
        D = matrix.shape[0]
        get_rows = lambda a, b: _csr_rows(matrix, a, b)
    else:
        D = matrix.D
        get_rows = lambda a, b: matrix.row_entries(np.arange(a, b, dtype=np.int64))
    part = Partition(D, P_row, d_pad)
    R = part.R
    per_shard = []
    for p in range(P_row):
        a, b = int(p * R), int(min(max((p + 1) * R, 0), D))
        a = min(a, D)
        rows, cols, vals = get_rows(a, b)
        per_shard.append((a, b, rows, cols, vals))

    # remote needs per (receiver p, owner q)
    need: list[dict[int, np.ndarray]] = []
    for p, (a, b, rows, cols, vals) in enumerate(per_shard):
        remote = np.unique(cols[(cols < a) | (cols >= b)])
        owners = part.owner(remote)
        need.append({int(q): remote[owners == q] for q in np.unique(owners)})
    L = max((len(v) for d in need for v in d.values()), default=0)
    L = max(L, 1)  # keep shapes non-degenerate

    send_idx = np.zeros((P_row, P_row, L), dtype=np.int32)
    for p, d in enumerate(need):
        for q, glob in d.items():
            send_idx[q, p, : len(glob)] = (glob - q * R).astype(np.int32)

    # local ELL with remapped columns
    W = 0
    shard_ell = []
    for p, (a, b, rows, cols, vals) in enumerate(per_shard):
        local = (cols >= a) & (cols < b)
        newcols = np.empty_like(cols)
        newcols[local] = cols[local] - a
        rem = ~local
        if rem.any():
            rc = cols[rem]
            q = part.owner(rc)
            # slot of each remote col within need[p][q] (sorted): searchsorted
            slot = np.empty(len(rc), dtype=np.int64)
            for qq in np.unique(q):
                m = q == qq
                slot[m] = np.searchsorted(need[p][int(qq)], rc[m])
            newcols[rem] = R + q * L + slot
        # rows relative to block start, build padded ELL
        rel = rows - a
        order = np.lexsort((newcols, rel))
        rel, newcols, vals = rel[order], newcols[order], vals[order]
        counts = np.bincount(rel, minlength=R)
        W = max(W, int(counts.max()) if len(counts) else 0)
        shard_ell.append((rel, newcols, vals, counts))

    vdtype = np.dtype(dtype) if dtype is not None else shard_ell[0][2].dtype
    cols_arr = np.zeros((P_row, R, W), dtype=np.int32)
    vals_arr = np.zeros((P_row, R, W), dtype=vdtype)
    for p, (rel, newcols, vals, counts) in enumerate(shard_ell):
        slot = np.arange(len(rel)) - np.repeat(np.cumsum(counts) - counts, counts)
        cols_arr[p, rel, slot] = newcols
        vals_arr[p, rel, slot] = vals.astype(vdtype)

    n_vc = np.array([sum(len(v) for v in d.values()) for d in need], dtype=np.int64)
    ell = DistEll(
        cols=jnp.asarray(cols_arr),
        vals=jnp.asarray(vals_arr),
        send_idx=jnp.asarray(send_idx),
        R=R,
        L=L,
        P=P_row,
        D=D,
        n_vc=n_vc,
    )
    if split_halo:
        ell.split()
    return ell


def _csr_rows(csr: CSR, a: int, b: int):
    lo, hi = int(csr.indptr[a]), int(csr.indptr[b])
    counts = np.diff(csr.indptr[a : b + 1])
    rows = np.repeat(np.arange(a, b, dtype=np.int64), counts)
    return rows, csr.indices[lo:hi].astype(np.int64), csr.data[lo:hi]


# --------------------------------------------------------------------------
# device side
# --------------------------------------------------------------------------


def _ell_contract(acc, cols, vals, xsrc):
    """W-step scan accumulation of an ELL block into acc — shared by the
    baseline and overlap engines so they stay bit-for-bit equivalent (no
    [R, W, nb] temporary materialized after fusion)."""
    def body(acc, cw):
        c, v = cw
        return acc + v[:, None] * jnp.take(xsrc, c, axis=0), None

    acc, _ = lax.scan(body, acc, (cols.T, vals.T))
    return acc


def _local_spmv(cols, vals, send_idx, x, dist_axes, P_row, L, use_kernel=False):
    """Per-device body: halo exchange + ELL contraction. x: [R, nb] local."""
    R, W = cols.shape
    nb = x.shape[1]
    if P_row > 1:
        send = jnp.take(x, send_idx, axis=0)  # [P, L, nb]
        halo = lax.all_to_all(send, dist_axes, split_axis=0, concat_axis=0, tiled=False)
        xfull = jnp.concatenate([x, halo.reshape(P_row * L, nb)], axis=0)
    else:
        xfull = x
    if use_kernel:
        from ..kernels import ops as kops

        return kops.ell_spmv(cols, vals, xfull)
    acc0 = jnp.zeros((R, nb), dtype=jnp.result_type(vals.dtype, x.dtype))
    return _ell_contract(acc0, cols, vals, xfull)


def _local_spmv_overlap(cols_loc, vals_loc, cols_halo, vals_halo, send_idx, x,
                        dist_axes, P_row, L, use_kernel=False):
    """Split-phase per-device body: launch the halo exchange, contract the
    local ELL while bytes are in flight, then contract the halo ELL.

    The all_to_all has no data dependence on the local contraction, so on
    backends with async collectives XLA hides it behind step 2 — the
    ``T = max(T_comm, T_local) + T_halo`` execution model."""
    R = cols_loc.shape[0]
    nb = x.shape[1]
    if P_row > 1:
        send = jnp.take(x, send_idx, axis=0)  # [P, L, nb]
        halo = lax.all_to_all(send, dist_axes, split_axis=0, concat_axis=0,
                              tiled=False).reshape(P_row * L, nb)
    else:
        halo = jnp.zeros((0, nb), dtype=x.dtype)
    if use_kernel:
        from ..kernels import ops as kops

        return kops.ell_spmv_split(cols_loc, vals_loc, cols_halo, vals_halo,
                                   x, halo)

    acc0 = jnp.zeros((R, nb), dtype=jnp.result_type(vals_loc.dtype, x.dtype))
    acc = _ell_contract(acc0, cols_loc, vals_loc, x)  # overlaps the exchange
    if cols_halo.shape[1]:
        acc = _ell_contract(acc, cols_halo, vals_halo, halo)
    return acc


def make_spmv(mesh: Mesh, layout: Layout, ell: DistEll, *, use_kernel: bool = False,
              overlap: bool = False):
    """Return spmv(x) on the global padded array X [D_pad, N_s'] where the
    layout's dist axes shard D and bundle axes shard N_s.

    ``overlap=True`` selects the split-phase engine that issues the halo
    all_to_all before the local contraction so communication can hide
    behind local work (identical results; summation order preserved)."""
    dist = layout.dist_axes
    vec_spec = layout.vec_pspec()
    plan_spec = P(dist if dist else None, None, None)

    if overlap:
        cols_loc, vals_loc, cols_halo, vals_halo = ell.split()

        def local_fn_ov(cl, vl, ch, vh, send_idx, x):
            # cl/vl [1, R, W_loc]; ch/vh [1, R, W_halo]; send_idx [1, P, L]
            return _local_spmv_overlap(
                cl[0], vl[0], ch[0], vh[0], send_idx[0], x, dist, ell.P,
                ell.L, use_kernel
            )

        fn = shard_map(
            local_fn_ov,
            mesh=mesh,
            in_specs=(plan_spec,) * 5 + (vec_spec,),
            out_specs=vec_spec,
            check_rep=False,
        )

        def spmv_ov(x):
            return fn(cols_loc, vals_loc, cols_halo, vals_halo, ell.send_idx, x)

        return spmv_ov

    def local_fn(cols, vals, send_idx, x):
        # cols/vals [1, R, W]; send_idx [1, P, L]; x [R, nb_loc]
        return _local_spmv(
            cols[0], vals[0], send_idx[0], x, dist, ell.P, ell.L, use_kernel
        )

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(plan_spec, plan_spec, plan_spec, vec_spec),
        out_specs=vec_spec,
        check_rep=False,
    )

    def spmv(x):
        return fn(ell.cols, ell.vals, ell.send_idx, x)

    return spmv


def make_fused_cheb_step(mesh: Mesh, layout: Layout, ell: DistEll, *, use_kernel: bool = False,
                         overlap: bool = False):
    """w2' = 2a (A w1) + 2b w1 - w2 — the paper's fused SpMV+axpy kernel
    (Alg. 2 step 7), computed in one shard_map body so XLA (or the Pallas
    kernel) fuses the axpy with the contraction (κ = 5, not 6). With
    ``overlap=True`` the SpMV inside uses the split-phase engine."""
    dist = layout.dist_axes
    vec_spec = layout.vec_pspec()
    plan_spec = P(dist if dist else None, None, None)

    if overlap:
        cols_loc, vals_loc, cols_halo, vals_halo = ell.split()

        def local_fn(cl, vl, ch, vh, send_idx, w1, w2, a, b):
            y = _local_spmv_overlap(cl[0], vl[0], ch[0], vh[0], send_idx[0],
                                    w1, dist, ell.P, ell.L, use_kernel)
            return 2.0 * a * y + 2.0 * b * w1 - w2

        fn = shard_map(
            local_fn,
            mesh=mesh,
            in_specs=(plan_spec,) * 5 + (vec_spec, vec_spec, P(), P()),
            out_specs=vec_spec,
            check_rep=False,
        )

        def step_ov(w1, w2, alpha, beta):
            rdt = jnp.zeros((), dtype=w1.dtype).real.dtype
            a = jnp.asarray(alpha, dtype=rdt)
            b = jnp.asarray(beta, dtype=rdt)
            return fn(cols_loc, vals_loc, cols_halo, vals_halo, ell.send_idx,
                      w1, w2, a, b)

        return step_ov

    def local_fn(cols, vals, send_idx, w1, w2, a, b):
        y = _local_spmv(cols[0], vals[0], send_idx[0], w1, dist, ell.P, ell.L, use_kernel)
        return 2.0 * a * y + 2.0 * b * w1 - w2

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(plan_spec, plan_spec, plan_spec, vec_spec, vec_spec, P(), P()),
        out_specs=vec_spec,
        check_rep=False,
    )

    def step(w1, w2, alpha, beta):
        rdt = jnp.zeros((), dtype=w1.dtype).real.dtype  # real part dtype (complex-safe)
        a = jnp.asarray(alpha, dtype=rdt)
        b = jnp.asarray(beta, dtype=rdt)
        return fn(ell.cols, ell.vals, ell.send_idx, w1, w2, a, b)

    return step

"""Distributed sparse matrix–(multiple)-vector multiplication engine.

Host side (`Partition`, `CommPlan`, `build_dist_ell`): given a matrix
family (or CSR) and the number of row shards P, build

  * row blocks of the partition — equal blocks R = ceil(D/P) by default
    (the paper's "nearly equidistant" row indices; the tail block is
    zero-padded), or a *planned* decomposition when a
    ``core/partition.py`` RowMap is passed (``balance="commvol"``
    non-uniform boundaries and/or the ``reorder="rcm"`` row order,
    realized as an embed into an equal-block padded position space),
  * per-shard ELL blocks with *remapped* columns: local columns map to
    [0, R), remote columns map into a halo region [R, R + P*L),
  * a communication plan: for every (sender q -> receiver p) pair the
    sorted list of local entries q must ship to p, padded to the max
    pair volume L.

Device side (`make_spmv`): a ``shard_map`` function executing the paper's
distributed SpMV: gather send slots -> single ``all_to_all`` over the
horizontal (``row``) mesh axes -> local ELL contraction against
``[x_local ‖ halo]``. The all_to_all moves exactly ``P * L * n_b * S_d``
bytes per device — L is the padded max per-pair volume derived from the
paper's n_vc counts, so the measured (HLO) collective volume equals the
pattern-only prediction of ``planner.comm_plan`` bit-for-bit
(tests/test_planner.py) and the χ-metric estimate up to the imbalance
factor χ₃/χ₂.

Overlap execution model (``make_spmv(..., overlap=True)``): each shard's
ELL block is split once, on the host, into a *local* part (columns in
``[0, R)`` — entries resolvable without communication) and a *halo* part
(columns in the remote region ``[R, R + P*L)``), in the spirit of
node-aware SpMV (Bienz, Gropp & Olson, arXiv:1612.08060). The device body
then

  1. launches the halo ``all_to_all`` (no dependence on the local part),
  2. contracts the local ELL while the bytes are in flight,
  3. contracts the halo ELL against the received buffer and accumulates.

On backends with async collectives XLA schedules (1) and (2) concurrently,
hiding the communication behind local work; the cost model becomes

  T = max(T_comm, T_local) + T_halo

instead of the additive ``T = T_comm + T_local+halo`` of Eq. 12 — see
``perf_model.cheb_iter_time_overlap``. Within every output row the split
engine accumulates local entries (ascending column) before halo entries,
which is exactly the unsplit ELL slot order, so baseline and overlapped
engines agree bit-for-bit up to associativity-free summation order.

Sparsity-compressed execution model (``make_spmv(..., comm="compressed")``):
the single padded ``all_to_all`` physically realizes the paper's χ₃ — every
(sender, receiver) pair moves L slots even when its true volume L_qp is
tiny or zero, so each device pays ``P * L`` entries per vector column
regardless of the imbalance factor χ₃/χ₂. The compressed engine instead
walks a *neighbor schedule* derived from the per-pair true volumes
(:meth:`DistEll.neighbor_plan`): a sequence of ``lax.ppermute`` rounds,
each round an arbitrary (partial) permutation of the shards padded only
to that round's max scheduled pair volume, with empty pairs never
scheduled at all. Total moved entries drop from ``P * L`` (χ₃-scaled) to
``H = Σ_r L_r`` — the node-aware idea of Bienz, Gropp & Olson
(arXiv:1612.08060): exchange only what the pattern requires, with actual
neighbors.

*How the rounds are derived is itself an axis* (:func:`neighbor_schedule`,
``schedule={"cyclic", "matching"}``):

  * ``"cyclic"`` — one round per cyclic shift k with a nonzero pair; the
    round's perm is the full shift permutation and its pad is that
    shift's max pair volume ``L_k = max_q L_{q -> q+k}``. Simple and
    contention-free, but one hot receiver at shift k taxes all P pairs
    of that round.
  * ``"matching"`` — greedy max-weight matchings extracted from the
    pair-volume matrix (in the spirit of Birkhoff decompositions): hot
    pairs from *different* shifts share one round's pad whenever their
    endpoints are disjoint, so ``H_matching <= H_cyclic`` always (the
    scheduler falls back to the cyclic rounds if greedy packing ever
    paid more — see :func:`neighbor_schedule`). On hub-and-spoke
    patterns (``matrices/hubnet.py``) the cyclic schedule pays one
    full-sized round per hub shift while a matching packs all hub
    corridors into O(1) rounds.

The halo columns are re-based into the compact round-concatenated
receive buffer **without re-sorting the ELL slots**, so the accumulation
order per output row is identical to the a2a engine and all six engine
combinations ({a2a, compressed-cyclic, compressed-matching} x
{plain, overlap}) agree bit-for-bit. ``comm="compressed"`` composes with
``overlap=True``: the permute rounds launch first, the local block
contracts while the bytes are in flight, and the halo block contracts
against the compact buffer last.

The vertical (``col``) mesh axes shard the vector bundle; no SpMV
communication crosses them (the paper's central point).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from ..matrices.families import MatrixFamily
from ..matrices.sparse import CSR
from .layouts import Layout
from .partition import RowMap

__all__ = ["Partition", "DistEll", "NeighborPlan", "build_dist_ell",
           "make_spmv", "make_fused_cheb_step", "neighbor_schedule",
           "SstepEll", "SstepNeighbor", "build_sstep_ell", "sstep_ghosts",
           "make_sstep_cheb", "SPMV_COMM_ENGINES", "SPMV_SCHEDULES"]

#: Horizontal-layer communication engines of ``make_spmv``.
SPMV_COMM_ENGINES = ("a2a", "compressed")

#: Round schedulers of the compressed engine (``make_spmv(schedule=...)``).
SPMV_SCHEDULES = ("cyclic", "matching")


def neighbor_schedule(pair_counts: np.ndarray, schedule: str = "cyclic",
                      ) -> tuple[tuple[tuple[tuple[int, int], ...], ...],
                                 tuple[int, ...]]:
    """Decompose the pair-volume matrix into the compressed engine's
    permutation rounds.

    Returns ``(perms, round_L)`` for true per-pair volumes
    ``pair_counts[q, p]`` (sender q -> receiver p): ``perms[r]`` is round
    r's ``lax.ppermute`` permutation — a tuple of ``(src, dst)`` device
    pairs in which every device appears at most once as source and at
    most once as destination — and ``round_L[r]`` is the round's pad,
    the max volume among its scheduled nonzero pairs. Every round moves
    exactly ``round_L[r]`` slots per device, so per-device moved entries
    are ``H = sum(round_L)``; pairs with zero volume are never given a
    round of their own.

    ``schedule="cyclic"``: one round per cyclic shift k with at least one
    nonzero pair; the perm is the full shift permutation
    ``j -> (j + k) % P`` and the pad is that shift's max pair volume
    ``L_k = max_q L_{q -> (q+k) % P}`` — one hot receiver taxes all P
    pairs of its round.

    ``schedule="matching"``: greedy max-weight matching decomposition (in
    the spirit of Birkhoff decompositions / node-aware SpMV schedules):
    nonzero pairs are taken in descending volume and placed first-fit
    into the earliest round where both endpoints are still free, so hot
    pairs from *different* cyclic shifts share one round's pad instead
    of each taxing its own round. Should greedy packing ever pay more
    than the cyclic rounds, the cyclic decomposition is returned
    instead — ``H_matching <= H_cyclic`` holds by construction.

    Single source of truth for the round derivation — the engine
    (``DistEll.neighbor_plan``) and the planner's byte prediction
    (``planner.SpmvCommPlan.permute_schedule``) both call it, which is
    what keeps predicted == HLO-measured exact.
    """
    pc = np.asarray(pair_counts)
    P = pc.shape[0]
    q = np.arange(P)
    cyc_perms, cyc_L = [], []
    for k in range(1, P):
        Lk = int(pc[q, (q + k) % P].max())
        if Lk:
            cyc_perms.append(tuple((j, int((j + k) % P)) for j in range(P)))
            cyc_L.append(Lk)
    cyclic = (tuple(cyc_perms), tuple(cyc_L))
    if schedule == "cyclic":
        return cyclic
    if schedule != "matching":
        raise ValueError(f"unknown schedule {schedule!r} "
                         f"(expected one of {SPMV_SCHEDULES})")
    # first-fit-descending greedy matchings: the (volume desc, src, dst)
    # key makes the decomposition deterministic, and descending order
    # makes each round's pad the volume of the pair that opened it
    pairs = sorted(((int(pc[s, d]), s, d)
                    for s in range(P) for d in range(P)
                    if s != d and pc[s, d]),
                   key=lambda t: (-t[0], t[1], t[2]))
    rounds: list[dict] = []
    for w, s, d in pairs:
        for r in rounds:
            if s not in r["src"] and d not in r["dst"]:
                break
        else:
            r = dict(src=set(), dst=set(), pairs=[], L=w)
            rounds.append(r)
        r["src"].add(s)
        r["dst"].add(d)
        r["pairs"].append((s, d))
    perms = tuple(tuple(sorted(r["pairs"])) for r in rounds)
    round_L = tuple(r["L"] for r in rounds)
    if sum(round_L) > sum(cyc_L):
        return cyclic  # never schedule worse than the cyclic rounds
    return perms, round_L


# --------------------------------------------------------------------------
# host side
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Partition:
    """Equal-block row partition: block p owns rows [p*R, min((p+1)*R, D)).

    ``d_pad`` (a multiple of P, >= D) fixes the padded global extent so that
    stack- and panel-layout engines over the same vectors agree on shapes;
    defaults to ceil(D/P)*P.

    This is the ``balance="rows"``, ``reorder="none"`` fast path of the
    partition planner — non-uniform / reordered decompositions are
    expressed by ``core/partition.py``'s :class:`~repro.core.partition.
    RowMap` and consumed via ``build_dist_ell(..., rowmap=...)``.
    """

    D: int
    P: int
    d_pad: int | None = None

    @property
    def D_pad(self) -> int:
        if self.d_pad is not None:
            assert self.d_pad % self.P == 0 and self.d_pad >= self.D
            return self.d_pad
        return (-(-self.D // self.P)) * self.P

    @property
    def R(self) -> int:
        return self.D_pad // self.P

    def boundaries(self) -> np.ndarray:
        return np.minimum(np.arange(self.P + 1, dtype=np.int64) * self.R, self.D)

    def owner(self, cols: np.ndarray) -> np.ndarray:
        return np.minimum(cols // self.R, self.P - 1)


@dataclasses.dataclass
class NeighborPlan:
    """Static schedule of the compressed (neighbor-permute) halo exchange.

    One ``lax.ppermute`` round per entry of ``perms``: round ``r``
    applies the (partial) permutation ``perms[r]`` — a tuple of
    ``(src, dst)`` device pairs produced by :func:`neighbor_schedule`
    (the full shift permutation for the cyclic scheduler, only the
    matched pairs for the matching scheduler) — with every send segment
    padded to ``round_L[r]`` slots. A device absent from a round's
    permutation receives zeros there and references none of those slots.
    Pairs with zero volume are never scheduled, so they move no bytes at
    all. The receive buffers concatenate in round order into a compact
    halo region of ``H = sum(round_L)`` entries (vs ``P * L`` for the
    padded a2a). ``cols_halo_nbr`` is only needed by the overlap variant
    and is filled lazily (``DistEll.neighbor_plan(split_halo=True)``) so
    the plain compressed engine never materializes the local/halo split.

    ``halo_rounds`` is the round-pipelined form of the split halo block:
    one ELL sub-block ``(cols_r, vals_r)`` per permute round, holding —
    complete, in the original slot order — every halo row whose LAST
    needed sender lands in round r (each round is a partial permutation,
    so rounds partition the halo by sender and a row completes exactly
    when its highest-round sender arrives). ``cols_r`` keeps the compact
    [0, H) positions, all ``< sum(round_L[:r+1])``, so sub-block r
    gathers only from the concatenated prefix of rounds ``<= r`` — the
    pipelined engine can contract it while round r+1's ``ppermute`` is
    still in flight, and because each row's halo entries are contracted
    atomically in slot order the result is bit-identical to the
    unpipelined engines. Built lazily from concrete operands; stays
    ``None`` on surrogate (shape-only) operators, where the engine falls
    back to the all-rounds-then-contract body.
    """

    perms: tuple[tuple[tuple[int, int], ...], ...]  # per-round (src, dst)
    round_L: tuple[int, ...]  # per-round pad: max scheduled pair volume
    send_nbr: jax.Array       # [P, H] int32 local rows to ship, round-major
    cols_nbr: jax.Array       # [P, R, W] combined cols, halo re-based to [R, R+H)
    cols_halo_nbr: jax.Array | None = None  # [P, R, W_halo] split halo cols in [0, H)
    halo_rounds: tuple | None = None  # per-round ([P,R,W_r] cols, vals) sub-blocks

    @property
    def H(self) -> int:
        """Per-device moved entries per vector column (Σ_r L_r)."""
        return int(sum(self.round_L))

    def scheduled_pairs(self) -> tuple[tuple[int, int], ...]:
        """All (src, dst) device pairs across rounds, in round order —
        the introspection surface of the static plan linter
        (``repro.analysis.plan_lint``): a pair scheduled twice or a round
        that is not a partial permutation shows up directly here."""
        return tuple(p for perm in self.perms for p in perm)


@dataclasses.dataclass
class DistEll:
    """Pytree of device arrays for the distributed ELL SpMV.

    All arrays carry a leading P axis that is sharded over the horizontal
    mesh axes inside ``make_spmv``. The four ``*_loc`` / ``*_halo`` fields
    are the split-phase form consumed by the overlap engine; they are
    populated on demand by :meth:`split` (or eagerly with
    ``build_dist_ell(..., split_halo=True)``). ``pair_counts`` holds the
    true per-(sender, receiver) volumes L_qp behind the comm plan;
    :meth:`neighbor_plan` turns them into the compressed engine's
    ppermute schedule — cyclic-shift or matching rounds — lazily, cached
    per scheduler in ``nbr``.
    """

    cols: jax.Array  # [P, R, W] int32, remapped columns
    vals: jax.Array  # [P, R, W] matrix dtype
    send_idx: jax.Array  # [P, P, L] int32 local row indices to ship
    R: int = dataclasses.field(metadata=dict(static=True))
    L: int = dataclasses.field(metadata=dict(static=True))
    P: int = dataclasses.field(metadata=dict(static=True))
    D: int = dataclasses.field(metadata=dict(static=True))
    n_vc: np.ndarray | None = None  # exact per-shard remote counts (diagnostics)
    pair_counts: np.ndarray | None = None  # [P, P] true volumes L_qp (q -> p)
    cols_loc: jax.Array | None = None   # [P, R, W_loc] columns in [0, R)
    vals_loc: jax.Array | None = None   # [P, R, W_loc]
    cols_halo: jax.Array | None = None  # [P, R, W_halo] columns in [0, P*L)
    vals_halo: jax.Array | None = None  # [P, R, W_halo]
    nbr: dict | None = None  # schedule name -> NeighborPlan (cached)
    rowmap: RowMap | None = None  # planned row decomposition (None = equal rows)

    @property
    def comm_bytes_per_spmv(self) -> int:
        """all_to_all payload per vector column, summed over shards."""
        return self.P * self.P * self.L * self.vals.dtype.itemsize

    @property
    def halo_nnz_fraction(self) -> float:
        """Fraction of stored nonzeros in the halo part (perf-model input).

        Computed directly from masks on the combined ``cols``/``vals`` —
        no local/halo device arrays are materialized for a count.
        """
        cols = np.asarray(self.cols)
        vals = np.asarray(self.vals)
        stored = vals != 0
        n_halo = int(np.count_nonzero(stored & (cols >= self.R)))
        n_all = int(np.count_nonzero(stored))
        return n_halo / max(n_all, 1)

    def split(self):
        """Split the combined ELL into (cols_loc, vals_loc, cols_halo,
        vals_halo) for the overlap engine; cached after the first call.

        Local columns keep their [0, R) indices; halo columns are rebased
        into the received buffer, i.e. [0, P*L). Per row, the split parts
        preserve the combined slot order (local ascending, then halo
        ascending), so split + unsplit contractions sum in the same order.
        """
        if self.cols_loc is not None:
            return self.cols_loc, self.vals_loc, self.cols_halo, self.vals_halo
        cols = np.asarray(self.cols)
        vals = np.asarray(self.vals)
        P, R, W = cols.shape
        stored = vals != 0
        is_halo = stored & (cols >= self.R)
        is_loc = stored & ~is_halo
        W_loc = int(is_loc.sum(axis=2).max()) if W else 0
        W_halo = int(is_halo.sum(axis=2).max()) if W else 0
        W_loc = max(W_loc, 1)  # keep the local block non-degenerate
        cols_loc = np.zeros((P, R, W_loc), dtype=cols.dtype)
        vals_loc = np.zeros((P, R, W_loc), dtype=vals.dtype)
        cols_halo = np.zeros((P, R, W_halo), dtype=cols.dtype)
        vals_halo = np.zeros((P, R, W_halo), dtype=vals.dtype)
        for p in range(P):
            for part, mask, carr, varr, rebase in (
                ("loc", is_loc[p], cols_loc[p], vals_loc[p], 0),
                ("halo", is_halo[p], cols_halo[p], vals_halo[p], self.R),
            ):
                rows, slots = np.nonzero(mask)
                if not len(rows):
                    continue
                counts = np.bincount(rows, minlength=R)
                out_slot = np.arange(len(rows)) - np.repeat(
                    np.cumsum(counts) - counts, counts
                )
                carr[rows, out_slot] = cols[p][rows, slots] - rebase
                varr[rows, out_slot] = vals[p][rows, slots]
        self.cols_loc = jnp.asarray(cols_loc)
        self.vals_loc = jnp.asarray(vals_loc)
        self.cols_halo = jnp.asarray(cols_halo)
        self.vals_halo = jnp.asarray(vals_halo)
        return self.cols_loc, self.vals_loc, self.cols_halo, self.vals_halo

    # ------------------------------------------------- compressed engine --

    def _round_offsets(self, schedule: str):
        """(perms, round_L, off_by_pair): the scheduler's permutation
        rounds, the per-round pads, and each scheduled (sender, receiver)
        pair's offset into the concatenated receive buffer (-1 = the pair
        is in no round, i.e. moves nothing)."""
        if self.pair_counts is None:
            raise ValueError(
                "compressed engine needs per-pair volumes; rebuild the "
                "operator with build_dist_ell (pair_counts=None)")
        perms, round_L = neighbor_schedule(self.pair_counts, schedule)
        off_by_pair = np.full((self.P, self.P), -1, dtype=np.int64)
        H = 0
        for perm, Lk in zip(perms, round_L):
            for s, d in perm:
                off_by_pair[s, d] = H
            H += Lk
        return perms, round_L, off_by_pair

    def _rebase_halo(self, cols, vals, halo_mask_base, off_by_pair, base):
        """Re-base halo columns ``halo_mask_base + q*L + slot`` (a2a receive
        layout) into ``base + off(q, p) + slot`` (compact round buffer),
        touching only stored entries — the ELL slot layout is unchanged, so
        the compressed contraction accumulates in the baseline's order."""
        out = []
        for p in range(self.P):
            cp = cols[p].copy()
            halo = (vals[p] != 0) & (cp >= halo_mask_base)
            if halo.any():
                c = cp[halo] - halo_mask_base
                q, slot = c // self.L, c % self.L
                off = off_by_pair[q, p]
                assert (off >= 0).all(), "stored halo entry in no round"
                cp[halo] = (base + off + slot).astype(cp.dtype)
            out.append(cp)
        return np.stack(out)

    def neighbor_plan(self, split_halo: bool = False,
                      schedule: str = "cyclic") -> NeighborPlan:
        """Compressed-engine schedule + re-based device arrays; cached per
        scheduler (``schedule={"cyclic", "matching"}``).

        ``send_nbr[q]`` concatenates, round-major, the first L_r send
        slots of the pair q is the source of in round r (zeros where q is
        idle); ``cols_nbr`` is the combined ELL with halo columns re-based
        into ``[R, R + H)``. ``split_halo=True`` additionally fills
        ``cols_halo_nbr`` (the split-phase halo block re-based into
        ``[0, H)``) for the overlap variant — the plain compressed engine
        skips the split entirely.
        """
        if self.nbr is None:
            self.nbr = {}
        plan = self.nbr.get(schedule)
        if plan is None:
            perms, round_L, off_by_pair = self._round_offsets(schedule)
            P = self.P
            send_idx = np.asarray(self.send_idx)
            H = int(sum(round_L))
            send_nbr = np.zeros((P, max(H, 1)), dtype=np.int32)
            off = 0
            for perm, Lk in zip(perms, round_L):
                for s, d in perm:
                    send_nbr[s, off:off + Lk] = send_idx[s, d, :Lk]
                off += Lk
            cols_nbr = self._rebase_halo(np.asarray(self.cols),
                                         np.asarray(self.vals),
                                         self.R, off_by_pair, self.R)
            plan = NeighborPlan(
                perms=perms, round_L=round_L,
                send_nbr=jnp.asarray(send_nbr),
                cols_nbr=jnp.asarray(cols_nbr),
            )
            self.nbr[schedule] = plan
        if split_halo and plan.cols_halo_nbr is None:
            _, _, ch, vh = self.split()
            _, _, off_by_pair = self._round_offsets(schedule)
            # split halo cols already sit at base 0 (values q*L + slot)
            ch_nbr = (self._rebase_halo(np.asarray(ch), np.asarray(vh),
                                        0, off_by_pair, 0)
                      if ch.shape[2] else np.asarray(ch))
            plan.cols_halo_nbr = jnp.asarray(ch_nbr)
        if (split_halo and plan.halo_rounds is None
                and _host_concrete(plan.cols_halo_nbr)
                and _host_concrete(self.vals_halo)):
            plan.halo_rounds = _build_halo_rounds(
                np.asarray(plan.cols_halo_nbr), np.asarray(self.vals_halo),
                plan.round_L)
        return plan


def _host_concrete(a) -> bool:
    """True when ``a`` is a concrete host-readable array (not a tracer,
    not a ShapeDtypeStruct surrogate) — gate for lazy host-side planning
    such as the pipelined round sub-blocks and the kernel tile batches."""
    from ..kernels.ops import is_concrete

    return a is not None and is_concrete(a)


def _build_halo_rounds(ch_nbr: np.ndarray, vh: np.ndarray,
                       round_L: tuple[int, ...]) -> tuple:
    """Group the split halo block by completion round (host side).

    ``ch_nbr`` holds compact [0, H) halo positions (round-major), ``vh``
    the matching values; entry positions in ``[Σ round_L[:r], Σ
    round_L[:r+1])`` arrive in round r. A row is assigned to the round of
    its HIGHEST-round entry — the earliest point at which every one of
    its halo operands has been received — and its entries are packed into
    that round's ELL sub-block in the original slot order. Positions are
    NOT re-based: sub-block r gathers from the concatenated prefix buffer
    of rounds <= r, whose length ``Σ round_L[:r+1]`` bounds every packed
    position by construction.
    """
    P, R, Wh = ch_nbr.shape
    stored = vh != 0
    ends = np.cumsum(np.asarray(round_L, dtype=np.int64))
    rounds = []
    if Wh:
        rnd = np.searchsorted(ends, ch_nbr, side="right")
        row_last = np.where(stored, rnd, -1).max(axis=2)  # [P, R]
    else:
        row_last = np.full((P, R), -1, dtype=np.int64)
    for r in range(len(round_L)):
        m = stored & (row_last == r)[:, :, None] if Wh else np.zeros(
            (P, R, 0), dtype=bool)
        Wr = int(m.sum(axis=2).max()) if Wh else 0
        cr = np.zeros((P, R, Wr), dtype=np.int32)
        vr = np.zeros((P, R, Wr), dtype=vh.dtype)
        for p in range(P):
            rows, slots = np.nonzero(m[p])
            if not len(rows):
                continue
            counts = np.bincount(rows, minlength=R)
            out_slot = np.arange(len(rows)) - np.repeat(
                np.cumsum(counts) - counts, counts)
            cr[p, rows, out_slot] = ch_nbr[p, rows, slots]
            vr[p, rows, out_slot] = vh[p, rows, slots]
        rounds.append((jnp.asarray(cr), jnp.asarray(vr)))
    return tuple(rounds)


def _pattern_chunks(matrix, rows):
    r, c, v = matrix.row_entries(rows)
    return r, c, v


def build_dist_ell(
    matrix: MatrixFamily | CSR,
    P_row: int,
    dtype=None,
    d_pad: int | None = None,
    split_halo: bool = False,
    rowmap: RowMap | None = None,
) -> DistEll:
    """Build per-shard ELL blocks + comm plan for P_row horizontal shards.

    With ``split_halo=True`` the local/halo split consumed by the overlap
    engine is built eagerly (otherwise ``make_spmv(..., overlap=True)``
    materializes it lazily on first use).

    ``rowmap`` makes the row decomposition a planned quantity
    (``core/partition.py``): shard p owns the rows the map places at
    positions ``[p·R, (p+1)·R)`` of the padded position space — possibly
    non-uniform (``balance="commvol"``) and/or RCM-reordered
    (``reorder="rcm"``). The map may be planned at any level whose
    ``D_pad`` is divisible by ``P_row``, so the stack- and panel-level
    operators of one solve share a single map. Without a map (or with
    the identity map) the equal-rows :class:`Partition` fast path is
    taken, and ``d_pad`` keeps its historical meaning.

    ``L`` is the *true* max per-pair volume: **zero** when no shard
    needs any remote column, in which case the engines skip the halo
    exchange entirely and the pattern-only byte prediction (0) stays
    exact — empty pairs are never charged a phantom 1-entry pad.
    """
    if rowmap is not None:
        if rowmap.D != (matrix.shape[0] if isinstance(matrix, CSR)
                        else matrix.D):
            raise ValueError("rowmap.D does not match the matrix")
        if d_pad is not None and d_pad != rowmap.D_pad:
            raise ValueError(f"d_pad={d_pad} conflicts with the rowmap's "
                             f"D_pad={rowmap.D_pad}")
        if not rowmap.identity:
            ell = _build_dist_ell_mapped(matrix, P_row, rowmap, dtype)
            if split_halo:
                ell.split()
            return ell
        d_pad = rowmap.D_pad
    if isinstance(matrix, CSR):
        D = matrix.shape[0]
        get_rows = lambda a, b: _csr_rows(matrix, a, b)
    else:
        from ..matrices.matfree import collect_row_entries

        # windowed generator protocol: shard blocks of a streaming-scale
        # family never materialize one whole-shard COO temporary
        D = matrix.D
        get_rows = lambda a, b: collect_row_entries(
            matrix, np.arange(a, b, dtype=np.int64))
    part = Partition(D, P_row, d_pad)
    R = part.R
    per_shard = []
    for p in range(P_row):
        a, b = int(p * R), int(min(max((p + 1) * R, 0), D))
        a = min(a, D)
        rows, cols, vals = get_rows(a, b)
        per_shard.append((a, b, rows, cols, vals))

    # remote needs per (receiver p, owner q)
    need: list[dict[int, np.ndarray]] = []
    for p, (a, b, rows, cols, vals) in enumerate(per_shard):
        remote = np.unique(cols[(cols < a) | (cols >= b)])
        owners = part.owner(remote)
        need.append({int(q): remote[owners == q] for q in np.unique(owners)})
    L = max((len(v) for d in need for v in d.values()), default=0)

    # true per-pair volumes L_qp (sender q -> receiver p) — the compressed
    # engine's neighbor schedule and the planner's χ₂-scaled byte
    # prediction both derive from these
    pair_counts = np.zeros((P_row, P_row), dtype=np.int64)
    send_idx = np.zeros((P_row, P_row, L), dtype=np.int32)
    for p, d in enumerate(need):
        for q, glob in d.items():
            pair_counts[q, p] = len(glob)
            send_idx[q, p, : len(glob)] = (glob - q * R).astype(np.int32)

    # local ELL with remapped columns
    W = 0
    shard_ell = []
    for p, (a, b, rows, cols, vals) in enumerate(per_shard):
        local = (cols >= a) & (cols < b)
        newcols = np.empty_like(cols)
        newcols[local] = cols[local] - a
        rem = ~local
        if rem.any():
            rc = cols[rem]
            q = part.owner(rc)
            # slot of each remote col within need[p][q] (sorted): searchsorted
            slot = np.empty(len(rc), dtype=np.int64)
            for qq in np.unique(q):
                m = q == qq
                slot[m] = np.searchsorted(need[p][int(qq)], rc[m])
            newcols[rem] = R + q * L + slot
        # rows relative to block start, build padded ELL
        rel = rows - a
        order = np.lexsort((newcols, rel))
        rel, newcols, vals = rel[order], newcols[order], vals[order]
        counts = np.bincount(rel, minlength=R)
        W = max(W, int(counts.max()) if len(counts) else 0)
        shard_ell.append((rel, newcols, vals, counts))

    vdtype = (np.dtype(dtype) if dtype is not None
              else shard_ell[0][2].dtype if shard_ell else np.float64)
    cols_arr = np.zeros((P_row, R, W), dtype=np.int32)
    vals_arr = np.zeros((P_row, R, W), dtype=vdtype)
    for p, (rel, newcols, vals, counts) in enumerate(shard_ell):
        slot = np.arange(len(rel)) - np.repeat(np.cumsum(counts) - counts, counts)
        cols_arr[p, rel, slot] = newcols
        vals_arr[p, rel, slot] = vals.astype(vdtype)

    n_vc = np.array([sum(len(v) for v in d.values()) for d in need], dtype=np.int64)
    ell = DistEll(
        cols=jnp.asarray(cols_arr),
        vals=jnp.asarray(vals_arr),
        send_idx=jnp.asarray(send_idx),
        R=R,
        L=L,
        P=P_row,
        D=D,
        n_vc=n_vc,
        pair_counts=pair_counts,
        rowmap=rowmap,
    )
    if split_halo:
        ell.split()
    return ell


def _build_dist_ell_mapped(matrix, P_row: int, rowmap: RowMap,
                           dtype=None) -> DistEll:
    """``build_dist_ell`` body for a non-identity :class:`RowMap`.

    Identical output semantics as the fast path, expressed in *position*
    space: shard p's ELL row i holds the matrix row the map places at
    position ``p·R + i`` (pad positions stay all-zero rows), local
    columns are position offsets, remote columns index the halo region
    ``R + q·L + slot`` with slots assigned in ascending *position* order
    per pair — so the per-row slot order (and hence the accumulation
    order of every engine) follows the mapped layout exactly the way the
    fast path follows the natural one.
    """
    D = rowmap.D
    R = rowmap.level_R(P_row)
    pos = rowmap.pos
    if isinstance(matrix, CSR):
        get_rows = lambda rows_g: _csr_rows_at(matrix, rows_g)
    else:
        from ..matrices.matfree import collect_row_entries

        get_rows = lambda rows_g: collect_row_entries(matrix, rows_g)
    per_shard = []
    for p in range(P_row):
        rows_g, _ = rowmap.shard_rows(p, P_row)
        rows, cols, vals = get_rows(rows_g)
        per_shard.append((rows, cols, vals))

    # remote needs per (receiver p, owner q), as sorted sender positions
    need: list[dict[int, np.ndarray]] = []
    for p, (rows, cols, vals) in enumerate(per_shard):
        cpos = pos[cols]
        remote = np.unique(cpos[(cpos // R) != p])
        owners = remote // R
        need.append({int(q): remote[owners == q] for q in np.unique(owners)})
    L = max((len(v) for d in need for v in d.values()), default=0)

    pair_counts = np.zeros((P_row, P_row), dtype=np.int64)
    send_idx = np.zeros((P_row, P_row, L), dtype=np.int32)
    for p, d in enumerate(need):
        for q, spos in d.items():
            pair_counts[q, p] = len(spos)
            send_idx[q, p, : len(spos)] = (spos - q * R).astype(np.int32)

    W = 0
    shard_ell = []
    for p, (rows, cols, vals) in enumerate(per_shard):
        cpos = pos[cols]
        local = (cpos // R) == p
        newcols = np.empty(len(cols), dtype=np.int64)
        newcols[local] = cpos[local] - p * R
        rem = ~local
        if rem.any():
            rc = cpos[rem]
            q = rc // R
            slot = np.empty(len(rc), dtype=np.int64)
            for qq in np.unique(q):
                m = q == qq
                slot[m] = np.searchsorted(need[p][int(qq)], rc[m])
            newcols[rem] = R + q * L + slot
        rel = pos[rows] - p * R
        order = np.lexsort((newcols, rel))
        rel, newcols, vals = rel[order], newcols[order], vals[order]
        counts = np.bincount(rel, minlength=R)
        W = max(W, int(counts.max()) if len(counts) else 0)
        shard_ell.append((rel, newcols, vals, counts))

    vdtype = (np.dtype(dtype) if dtype is not None
              else shard_ell[0][2].dtype if shard_ell else np.float64)
    cols_arr = np.zeros((P_row, R, W), dtype=np.int32)
    vals_arr = np.zeros((P_row, R, W), dtype=vdtype)
    for p, (rel, newcols, vals, counts) in enumerate(shard_ell):
        slot = np.arange(len(rel)) - np.repeat(np.cumsum(counts) - counts, counts)
        cols_arr[p, rel, slot] = newcols
        vals_arr[p, rel, slot] = vals.astype(vdtype)

    n_vc = np.array([sum(len(v) for v in d.values()) for d in need],
                    dtype=np.int64)
    return DistEll(
        cols=jnp.asarray(cols_arr),
        vals=jnp.asarray(vals_arr),
        send_idx=jnp.asarray(send_idx),
        R=R,
        L=L,
        P=P_row,
        D=D,
        n_vc=n_vc,
        pair_counts=pair_counts,
        rowmap=rowmap,
    )


def _csr_rows(csr: CSR, a: int, b: int):
    lo, hi = int(csr.indptr[a]), int(csr.indptr[b])
    counts = np.diff(csr.indptr[a : b + 1])
    rows = np.repeat(np.arange(a, b, dtype=np.int64), counts)
    return rows, csr.indices[lo:hi].astype(np.int64), csr.data[lo:hi]


def _csr_rows_at(csr: CSR, rows_g: np.ndarray):
    """(rows, cols, vals) of an arbitrary (not necessarily contiguous)
    row set — the mapped partition's accessor."""
    from ..matrices.sparse import gather_row_entry_idx

    rows_g = np.asarray(rows_g, dtype=np.int64)
    gather, counts = gather_row_entry_idx(csr.indptr, rows_g)
    rows = np.repeat(rows_g, counts)
    return rows, csr.indices[gather].astype(np.int64), csr.data[gather]


# --------------------------------------------------------------------------
# device side
# --------------------------------------------------------------------------


def _ell_contract(acc, cols, vals, xsrc):
    """W-step scan accumulation of an ELL block into acc — shared by the
    baseline and overlap engines so they stay bit-for-bit equivalent (no
    [R, W, nb] temporary materialized after fusion)."""
    def body(acc, cw):
        c, v = cw
        return acc + v[:, None] * jnp.take(xsrc, c, axis=0), None

    acc, _ = lax.scan(body, acc, (cols.T, vals.T))
    return acc


def _contract_block(acc, cols, vals, xsrc, tiles):
    """Contract one ELL block into ``acc`` — Pallas tile kernel when a
    per-device tile batch ``(tile_cb, tcols, tvals, br, bc)`` is given,
    the jnp scan otherwise. Both paths thread ``acc`` and visit stored
    entries in ascending-column order, so the choice never changes a
    bit of the result."""
    if tiles is None:
        return _ell_contract(acc, cols, vals, xsrc)
    from ..kernels import ops as kops

    tile_cb, tcols, tvals, br, bc = tiles
    return kops.ell_spmv_tiled(tile_cb, tcols, tvals, xsrc, y0=acc,
                               br=br, bc=bc, cols=cols, vals=vals)


def _local_spmv(cols, vals, send_idx, x, dist_axes, P_row, L, tiles=None):
    """Per-device body: halo exchange + ELL contraction. x: [R, nb] local.

    ``L == 0`` means no shard needs any remote column (a zero-halo
    partition) — the exchange is skipped entirely, so the engine moves
    exactly the zero bytes the pattern-only prediction charges."""
    R, W = cols.shape
    nb = x.shape[1]
    if P_row > 1 and L:
        send = jnp.take(x, send_idx, axis=0)  # [P, L, nb]
        halo = lax.all_to_all(send, dist_axes, split_axis=0, concat_axis=0, tiled=False)
        xfull = jnp.concatenate([x, halo.reshape(P_row * L, nb)], axis=0)
    else:
        xfull = x
    acc0 = jnp.zeros((R, nb), dtype=jnp.result_type(vals.dtype, x.dtype))
    return _contract_block(acc0, cols, vals, xfull, tiles)


def _local_spmv_overlap(cols_loc, vals_loc, cols_halo, vals_halo, send_idx, x,
                        dist_axes, P_row, L, tiles=None):
    """Split-phase per-device body: launch the halo exchange, contract the
    local ELL while bytes are in flight, then contract the halo ELL.

    The all_to_all has no data dependence on the local contraction, so on
    backends with async collectives XLA hides it behind step 2 — the
    ``T = max(T_comm, T_local) + T_halo`` execution model. The halo
    contraction THREADS the local accumulator (whether the local block
    ran in the tile kernel or the jnp scan), preserving the unsplit slot
    order."""
    R = cols_loc.shape[0]
    nb = x.shape[1]
    if P_row > 1 and L:
        send = jnp.take(x, send_idx, axis=0)  # [P, L, nb]
        halo = lax.all_to_all(send, dist_axes, split_axis=0, concat_axis=0,
                              tiled=False).reshape(P_row * L, nb)
    else:
        halo = jnp.zeros((0, nb), dtype=x.dtype)
    acc0 = jnp.zeros((R, nb), dtype=jnp.result_type(vals_loc.dtype, x.dtype))
    acc = _contract_block(acc0, cols_loc, vals_loc, x, tiles)  # overlaps comm
    if cols_halo.shape[1]:
        acc = _ell_contract(acc, cols_halo, vals_halo, halo)
    return acc


def _halo_parts_nbr(x, send_nbr, dist_axes, perms, round_L):
    """Launch all compressed ``ppermute`` rounds; return the per-round
    received segments (round r's segment is [round_L[r], nb]). Every
    round depends only on ``x``/``send_nbr`` — never on another round or
    on any contraction — so async-collective backends pipeline them
    freely and the round-pipelined engine can consume segment r while
    round r+1 is still in flight."""
    parts = []
    off = 0
    for perm, Lk in zip(perms, round_L):
        seg = jnp.take(x, send_nbr[off:off + Lk], axis=0)  # [Lk, nb]
        parts.append(lax.ppermute(seg, dist_axes, perm=list(perm)))
        off += Lk
    return parts


def _halo_exchange_nbr(x, send_nbr, dist_axes, perms, round_L):
    """Compressed halo exchange: one ``ppermute`` round per scheduled
    permutation, each padded to that round's max scheduled pair volume
    only; the received segments concatenate into the compact [H, nb] halo
    buffer (devices outside a round's perm receive zeros there)."""
    parts = _halo_parts_nbr(x, send_nbr, dist_axes, perms, round_L)
    if not parts:
        return jnp.zeros((0, x.shape[1]), dtype=x.dtype)
    return jnp.concatenate(parts, axis=0)


def _local_spmv_nbr(cols_nbr, vals, send_nbr, x, dist_axes, P_row, nbr: NeighborPlan,
                    tiles=None):
    """Compressed per-device body: neighbor-permute rounds + combined ELL
    contraction against ``[x_local ‖ compact halo]``. The ELL slot layout
    equals the baseline's, so the accumulation order (and hence the result,
    bit-for-bit) matches the a2a engine."""
    R, W = cols_nbr.shape
    nb = x.shape[1]
    if P_row > 1 and nbr.H:
        halo = _halo_exchange_nbr(x, send_nbr, dist_axes,
                                  nbr.perms, nbr.round_L)
        xfull = jnp.concatenate([x, halo], axis=0)
    else:
        xfull = x
    acc0 = jnp.zeros((R, nb), dtype=jnp.result_type(vals.dtype, x.dtype))
    return _contract_block(acc0, cols_nbr, vals, xfull, tiles)


def _local_spmv_nbr_overlap(cols_loc, vals_loc, cols_halo_nbr, vals_halo,
                            send_nbr, x, dist_axes, P_row, nbr: NeighborPlan,
                            tiles=None):
    """Compressed split-phase body WITHOUT round pipelining: launch the
    permute rounds, contract the local ELL while the (χ₂-proportional)
    bytes are in flight, contract the whole halo ELL against the compact
    receive buffer last. Kept as the fallback for surrogate operators
    (no concrete values to derive round sub-blocks from) and as the
    negative control of the round-pipeline split-phase proof
    (``make_spmv(..., pipeline=False)``)."""
    R = cols_loc.shape[0]
    nb = x.shape[1]
    if P_row > 1 and nbr.H:
        halo = _halo_exchange_nbr(x, send_nbr, dist_axes,
                                  nbr.perms, nbr.round_L)
    else:
        halo = jnp.zeros((0, nb), dtype=x.dtype)
    acc0 = jnp.zeros((R, nb), dtype=jnp.result_type(vals_loc.dtype, x.dtype))
    acc = _contract_block(acc0, cols_loc, vals_loc, x, tiles)  # overlaps comm
    if cols_halo_nbr.shape[1]:
        acc = _ell_contract(acc, cols_halo_nbr, vals_halo, halo)
    return acc


def _local_spmv_nbr_pipelined(cols_loc, vals_loc, halo_rounds, send_nbr, x,
                              dist_axes, P_row, nbr: NeighborPlan,
                              tiles=None):
    """Round-pipelined compressed split-phase body.

    All permute rounds launch up front (mutually independent), the local
    block contracts while they fly, and then round r's ELL sub-block —
    the halo rows COMPLETED by round r, i.e. whose last needed sender
    lands in round r — contracts against the concatenated prefix of
    received segments ``parts[:r+1]``. Contraction r therefore depends
    on collectives 1..r and on no later round: on async-collective
    backends round r+1's ppermute is in flight while round r's rows
    contract (the split-phase proof in ``analysis/overlap_check.py``
    checks exactly this prefix-chain dependence structure).

    Bit-identity with the unpipelined engines is by construction: each
    halo row appears in exactly one sub-block with ALL its halo entries
    in the original slot order, gathered from prefix-buffer positions
    identical to the full compact buffer's, so the per-element addition
    chain (local slots, then halo slots ascending) is unchanged — the
    sub-blocks only reorder which ROWS contract early, never the order
    of any row's summands."""
    R = cols_loc.shape[0]
    nb = x.shape[1]
    parts = (_halo_parts_nbr(x, send_nbr, dist_axes, nbr.perms, nbr.round_L)
             if P_row > 1 and nbr.H else [])
    acc0 = jnp.zeros((R, nb), dtype=jnp.result_type(vals_loc.dtype, x.dtype))
    acc = _contract_block(acc0, cols_loc, vals_loc, x, tiles)  # overlaps comm
    buf = jnp.zeros((0, nb), dtype=x.dtype)
    for part, (cols_r, vals_r) in zip(parts, halo_rounds):
        buf = jnp.concatenate([buf, part], axis=0)  # prefix of rounds <= r
        if cols_r.shape[1]:
            acc = _ell_contract(acc, cols_r, vals_r, buf)
    return acc


def _dev_tiles(plan, arrays):
    """Per-device tile tuple for :func:`_contract_block` from an
    :class:`~repro.kernels.ops.EllTilePlan` and the shard_map-delivered
    (already shard-indexed) device arrays; None when no plan exists."""
    if plan is None:
        return None
    tile_cb, tcols, tvals = arrays
    return (tile_cb, tcols, tvals, plan.br, plan.bc)


def _validate_engine(comm: str, schedule: str) -> None:
    if comm not in SPMV_COMM_ENGINES:
        raise ValueError(f"unknown comm engine {comm!r} "
                         f"(expected one of {SPMV_COMM_ENGINES})")
    if schedule not in SPMV_SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r} "
                         f"(expected one of {SPMV_SCHEDULES})")
    if comm != "compressed" and schedule != "cyclic":
        raise ValueError(f"schedule={schedule!r} only applies to "
                         f"comm='compressed' (got comm={comm!r})")


def _build_engine(mesh: Mesh, layout: Layout, ell: DistEll, *, use_kernel: bool,
                  overlap: bool, comm: str, schedule: str, pipeline: bool,
                  fused: bool):
    """Shared builder behind :func:`make_spmv` and
    :func:`make_fused_cheb_step`: assembles the per-engine plan arrays,
    the per-device contraction body and the ``shard_map`` wrapper. With
    ``fused=True`` the returned closure computes the fused Chebyshev
    step ``2a (A w1) + 2b w1 - w2`` in the same body (and, for
    kernel-enabled comm-free diagonal-structured operators, dispatches
    the whole step to the ``cheb_dia`` Pallas kernel)."""
    _validate_engine(comm, schedule)
    dist = layout.dist_axes
    vec_spec = layout.vec_pspec()

    def pspec(a):
        return P(dist if dist else None, *((None,) * (a.ndim - 1)))

    kops = None
    if use_kernel:
        from ..kernels import ops as kops_mod

        kops = kops_mod

    if fused and use_kernel and (ell.P == 1 or ell.L == 0):
        # comm-free operator: try the fused DIA Chebyshev kernel for the
        # whole step (bit-identical: ascending offsets == ascending
        # columns == the ELL slot order, same fused epilogue expression)
        dia = kops.plan_dia(ell.cols, ell.vals, ell.R)
        if dia is not None:
            offsets = dia.offsets

            def local_fn_dia(dv, w1, w2, a, b):
                return kops.cheb_dia(offsets, dv[0], w1, w1, w2, a, b)

            fn = shard_map(
                local_fn_dia,
                mesh=mesh,
                in_specs=(pspec(dia.dvals), vec_spec, vec_spec, P(), P()),
                out_specs=vec_spec,
                check_rep=False,
            )

            def step_dia(w1, w2, alpha, beta):
                rdt = jnp.zeros((), dtype=w1.dtype).real.dtype
                a = jnp.asarray(alpha, dtype=rdt)
                b = jnp.asarray(beta, dtype=rdt)
                return fn(dia.dvals, w1, w2, a, b)

            return step_dia

    if comm == "compressed":
        nbr = ell.neighbor_plan(split_halo=overlap, schedule=schedule)
        if overlap:
            cols_loc, vals_loc, _, vals_halo = ell.split()
            tiles_plan = (kops.plan_ell_tiles(cols_loc, vals_loc, ell.R)
                          if use_kernel else None)
            tile_args = list(tiles_plan.arrays()) if tiles_plan else []
            rounds = nbr.halo_rounds if pipeline else None
            if rounds is not None:
                n_r = len(rounds)
                args = ([cols_loc, vals_loc, nbr.send_nbr]
                        + [a for cv in rounds for a in cv] + tile_args)

                def body(x, cl, vl, send_nbr, *rest):
                    rnds = tuple((rest[2 * i], rest[2 * i + 1])
                                 for i in range(n_r))
                    return _local_spmv_nbr_pipelined(
                        cl, vl, rnds, send_nbr, x, dist, ell.P, nbr,
                        _dev_tiles(tiles_plan, rest[2 * n_r:]))
            else:
                args = [cols_loc, vals_loc, nbr.cols_halo_nbr, vals_halo,
                        nbr.send_nbr] + tile_args

                def body(x, cl, vl, ch, vh, send_nbr, *rest):
                    return _local_spmv_nbr_overlap(
                        cl, vl, ch, vh, send_nbr, x, dist, ell.P, nbr,
                        _dev_tiles(tiles_plan, rest))
        else:
            tiles_plan = (kops.plan_ell_tiles(nbr.cols_nbr, ell.vals,
                                              ell.R + nbr.H)
                          if use_kernel else None)
            args = ([nbr.cols_nbr, ell.vals, nbr.send_nbr]
                    + (list(tiles_plan.arrays()) if tiles_plan else []))

            def body(x, cols_nbr, vals, send_nbr, *rest):
                return _local_spmv_nbr(cols_nbr, vals, send_nbr, x, dist,
                                       ell.P, nbr,
                                       _dev_tiles(tiles_plan, rest))
    elif overlap:
        cols_loc, vals_loc, cols_halo, vals_halo = ell.split()
        tiles_plan = (kops.plan_ell_tiles(cols_loc, vals_loc, ell.R)
                      if use_kernel else None)
        args = ([cols_loc, vals_loc, cols_halo, vals_halo, ell.send_idx]
                + (list(tiles_plan.arrays()) if tiles_plan else []))

        def body(x, cl, vl, ch, vh, send_idx, *rest):
            return _local_spmv_overlap(cl, vl, ch, vh, send_idx, x, dist,
                                       ell.P, ell.L,
                                       _dev_tiles(tiles_plan, rest))
    else:
        tiles_plan = (kops.plan_ell_tiles(ell.cols, ell.vals,
                                          ell.R + ell.P * ell.L)
                      if use_kernel else None)
        args = ([ell.cols, ell.vals, ell.send_idx]
                + (list(tiles_plan.arrays()) if tiles_plan else []))

        def body(x, cols, vals, send_idx, *rest):
            return _local_spmv(cols, vals, send_idx, x, dist, ell.P, ell.L,
                               _dev_tiles(tiles_plan, rest))

    n_args = len(args)
    plan_specs = tuple(pspec(a) for a in args)

    if fused:
        def local_fn_fused(*ins):
            dev = [a[0] for a in ins[:n_args]]
            w1, w2, a, b = ins[n_args:]
            y = body(w1, *dev)
            return 2.0 * a * y + 2.0 * b * w1 - w2

        fn = shard_map(
            local_fn_fused,
            mesh=mesh,
            in_specs=plan_specs + (vec_spec, vec_spec, P(), P()),
            out_specs=vec_spec,
            check_rep=False,
        )

        def step(w1, w2, alpha, beta):
            rdt = jnp.zeros((), dtype=w1.dtype).real.dtype  # complex-safe
            a = jnp.asarray(alpha, dtype=rdt)
            b = jnp.asarray(beta, dtype=rdt)
            return fn(*args, w1, w2, a, b)

        return step

    def local_fn(*ins):
        dev = [a[0] for a in ins[:n_args]]
        (x,) = ins[n_args:]
        return body(x, *dev)

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=plan_specs + (vec_spec,),
        out_specs=vec_spec,
        check_rep=False,
    )

    def spmv(x):
        return fn(*args, x)

    return spmv


def make_spmv(mesh: Mesh, layout: Layout, ell: DistEll, *,
              use_kernel: bool = False, overlap: bool = False,
              comm: str = "a2a", schedule: str = "cyclic",
              pipeline: bool = True):
    """Return spmv(x) on the global padded array X [D_pad, N_s'] where the
    layout's dist axes shard D and bundle axes shard N_s.

    ``overlap=True`` selects the split-phase engine that issues the halo
    exchange before the local contraction so communication can hide
    behind local work (identical results; summation order preserved).
    ``comm`` picks the horizontal-layer exchange: ``"a2a"`` (one
    all_to_all padded to the global max pair volume L — moved bytes scale
    with χ₃) or ``"compressed"`` (neighbor ppermute rounds padded per
    round — moved bytes ≈ χ₂-scaled, empty pairs skipped). ``schedule``
    picks how the compressed engine's rounds are derived from the
    pair-volume matrix: ``"cyclic"`` (one round per nonzero cyclic
    shift) or ``"matching"`` (greedy max-weight matchings — hot pairs of
    different shifts share one round's pad; see
    :func:`neighbor_schedule`).

    ``use_kernel=True`` dispatches the local block to the Pallas
    ``ell_gather`` tile kernel (interpret mode off-TPU); the kernel
    threads the same accumulator chain as the jnp scan, so kernel-on and
    kernel-off engines agree bit-for-bit. ``pipeline`` (compressed +
    overlap only) selects the round-pipelined halo contraction — round
    r's completed rows contract while round r+1's ppermute is in
    flight; ``pipeline=False`` keeps the all-rounds-then-contract body
    (the negative control of the split-phase round proof). All twelve
    engine combinations ({a2a, cmp-cyclic, cmp-matching} x {plain,
    overlap} x {kernel off, on}) agree bit-for-bit."""
    return _build_engine(mesh, layout, ell, use_kernel=use_kernel,
                         overlap=overlap, comm=comm, schedule=schedule,
                         pipeline=pipeline, fused=False)


def make_fused_cheb_step(mesh: Mesh, layout: Layout, ell: DistEll, *,
                         use_kernel: bool = False, overlap: bool = False,
                         comm: str = "a2a", schedule: str = "cyclic",
                         pipeline: bool = True):
    """w2' = 2a (A w1) + 2b w1 - w2 — the paper's fused SpMV+axpy kernel
    (Alg. 2 step 7), computed in one shard_map body so XLA (or the Pallas
    kernel) fuses the axpy with the contraction (κ = 5, not 6). With
    ``overlap=True`` the SpMV inside uses the split-phase engine (round-
    pipelined halo contraction when ``comm="compressed"`` and
    ``pipeline=True``); with ``comm="compressed"`` it uses the
    neighbor-permute halo exchange, whose rounds come from the
    ``schedule`` scheduler (same options as :func:`make_spmv`). With
    ``use_kernel=True`` a comm-free diagonal-structured operator runs the
    whole step in the fused ``cheb_dia`` Pallas kernel; otherwise the
    local block uses the ``ell_gather`` tile kernel and the epilogue
    fuses in XLA."""
    return _build_engine(mesh, layout, ell, use_kernel=use_kernel,
                         overlap=overlap, comm=comm, schedule=schedule,
                         pipeline=pipeline, fused=True)


# --------------------------------------------------------------------------
# s-step (communication-avoiding) engine axis
# --------------------------------------------------------------------------


def sstep_ghosts(indptr: np.ndarray, cols: np.ndarray, P_row: int, R: int,
                 s: int) -> list[tuple[np.ndarray, np.ndarray]]:
    """Per-shard depth-``s`` ghost zones of a position-space pattern.

    ``(indptr, cols)`` is a CSR pattern over the padded position space
    ``[0, P_row * R)`` (pad positions have empty rows). For each shard p
    a breadth-first search from its owned positions ``[p*R, (p+1)*R)``
    collects every position first reached at depth d ∈ [1, s] — exactly
    the reachability frontier of the pattern powers A^1 .. A^s, so the
    depth-d ghost set is a statistic of A^d alone. Returns, per shard,
    ``(gpos, gdep)``: ghost positions sorted ascending (≡ sorted by
    (owner, position) since owner = pos // R is monotone) and each
    ghost's BFS depth. Single source of truth for the builder
    (:func:`build_sstep_ell`) and the planner's χ(A^s) statistics
    (``planner.comm_plan(sstep=...)``) — which is what keeps the s-step
    byte prediction exact.
    """
    from ..matrices.sparse import gather_row_entry_idx

    indptr = np.asarray(indptr, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    D_pos = P_row * R
    assert len(indptr) == D_pos + 1, "pattern must cover the padded space"
    out = []
    for p in range(P_row):
        seen = np.zeros(D_pos, dtype=bool)
        seen[p * R:(p + 1) * R] = True
        frontier = np.arange(p * R, (p + 1) * R, dtype=np.int64)
        gpos_parts: list[np.ndarray] = []
        gdep_parts: list[np.ndarray] = []
        for d in range(1, s + 1):
            if not frontier.size:
                break
            gather, _ = gather_row_entry_idx(indptr, frontier)
            nxt = np.unique(cols[gather])
            new = nxt[~seen[nxt]]
            if not new.size:
                break
            seen[new] = True
            gpos_parts.append(new)
            gdep_parts.append(np.full(new.size, d, dtype=np.int64))
            frontier = new
        if gpos_parts:
            gpos = np.concatenate(gpos_parts)
            gdep = np.concatenate(gdep_parts)
            order = np.argsort(gpos, kind="stable")
            gpos, gdep = gpos[order], gdep[order]
        else:
            gpos = np.zeros(0, dtype=np.int64)
            gdep = np.zeros(0, dtype=np.int64)
        out.append((gpos, gdep))
    return out


@dataclasses.dataclass
class SstepNeighbor:
    """Compressed-engine schedule of the depth-s ghost exchange.

    Same permutation rounds as :class:`NeighborPlan` (both come from
    :func:`neighbor_schedule`, here applied to the depth-s pair-volume
    matrix); ``gather`` maps ghost slot j of each shard into the compact
    round-concatenated receive buffer (``off_by_pair[owner_j] +
    rank_within_pair_j``), so the gathered ghost block is identical to
    the a2a engine's — the per-step ELLs are comm-engine independent.
    """

    perms: tuple[tuple[tuple[int, int], ...], ...]
    round_L: tuple[int, ...]
    send_nbr: np.ndarray  # [P, max(H, 1)] int32, round-major send slots
    gather: np.ndarray    # [P, G] int32 into the compact [H] buffer

    @property
    def H(self) -> int:
        return int(sum(self.round_L))


@dataclasses.dataclass
class SstepEll:
    """Depth-s ghost-zone operator: per-step ELL blocks + one exchange plan.

    Shard p's extended address space is ``[0, R + G)``: owned rows at
    their local offsets, ghost j (of the ascending-position ghost list)
    at address ``R + j`` (``G`` is the max ghost count over shards; pad
    slots beyond a shard's own ghost count are never referenced). Step i
    of a group (0-indexed) holds the ELL rows whose outputs are still
    needed — owned rows plus ghosts at BFS depth ≤ s-1-i; deeper ghost
    rows are all-zero rows. Every row's entries are sorted by
    ``(owner(col) != owner(row), owner(col), position(col))`` — for
    owned rows that reproduces :class:`DistEll`'s slot order exactly,
    and for ghost rows it reproduces the order of the row's HOME shard,
    so each recurrence step accumulates in the same order everywhere and
    the s-step engines agree bit-for-bit with the s=1 engines.

    ``steps[i] = (cols, vals)`` with shapes [P, R+G, W_i]; the exchange
    plan (``send_idx``/``pair_counts``/``gather_a2a``) covers the full
    depth-s ghost set, so one exchange feeds all s recurrence steps of a
    group. ``ghost_cum[d]`` is the max-over-shards count of ghosts at
    depth ≤ d (the planner's redundant-work statistic).
    """

    steps: tuple  # s x (cols [P, R+G, W_i] int32, vals [P, R+G, W_i])
    send_idx: jax.Array    # [P, P, L] int32 local rows to ship (depth-s)
    gather_a2a: jax.Array  # [P, G] int32 into the padded [P*L] a2a buffer
    R: int
    G: int
    L: int
    P: int
    D: int
    s: int
    n_vc: np.ndarray | None = None          # per-shard ghost counts
    pair_counts: np.ndarray | None = None   # [P, P] depth-s volumes L_qp
    ghost_cum: tuple | None = None          # [s+1] max ghosts at depth <= d
    ghost_owner: np.ndarray | None = None   # [P, G] host: owner of ghost j
    ghost_rank: np.ndarray | None = None    # [P, G] host: rank within pair
    cols_loc: np.ndarray | None = None  # [P, R, W_loc] step-0 local prefix
    vals_loc: np.ndarray | None = None
    cols_post: np.ndarray | None = None  # [P, R+G, W_post] step-0 remainder
    vals_post: np.ndarray | None = None
    nbr: dict | None = None  # schedule name -> SstepNeighbor (cached)
    rowmap: RowMap | None = None

    def n_groups(self, degree: int) -> int:
        """ceil(degree / s) exchanges for a degree-term filter."""
        return -(-int(degree) // self.s)

    def split(self):
        """Step-0 split for the overlap engine: ``(cols_loc, vals_loc)``
        is the owned rows' local-address prefix (contracted while the
        exchange is in flight), ``(cols_post, vals_post)`` the owned
        rows' ghost-address suffix plus the full ghost rows — contracted
        against the extended vector afterwards, threading the
        accumulator, so the per-row summand order is unchanged."""
        if self.cols_loc is not None:
            return self.cols_loc, self.vals_loc, self.cols_post, self.vals_post
        cols = np.asarray(self.steps[0][0])
        vals = np.asarray(self.steps[0][1])
        Pn, RG, W = cols.shape
        R = self.R
        stored = vals != 0
        own_row = np.zeros((Pn, RG, 1), dtype=bool)
        own_row[:, :R, :] = True
        pre = stored & own_row & (cols < R)
        post = stored & ~pre
        W_loc = max(int(pre.sum(axis=2).max()) if W else 0, 1)
        W_post = int(post.sum(axis=2).max()) if W else 0
        cols_loc = np.zeros((Pn, R, W_loc), dtype=np.int32)
        vals_loc = np.zeros((Pn, R, W_loc), dtype=vals.dtype)
        cols_post = np.zeros((Pn, RG, W_post), dtype=np.int32)
        vals_post = np.zeros((Pn, RG, W_post), dtype=vals.dtype)
        for p in range(Pn):
            for mask, carr, varr, nrows in (
                (pre[p, :R], cols_loc[p], vals_loc[p], R),
                (post[p], cols_post[p], vals_post[p], RG),
            ):
                rows_, slots = np.nonzero(mask)
                if not len(rows_):
                    continue
                counts = np.bincount(rows_, minlength=nrows)
                out_slot = np.arange(len(rows_)) - np.repeat(
                    np.cumsum(counts) - counts, counts)
                carr[rows_, out_slot] = cols[p, :nrows][rows_, slots]
                varr[rows_, out_slot] = vals[p, :nrows][rows_, slots]
        # cached as HOST arrays: split() may first run inside a jit trace
        # (the group builders are lazy), and caching device arrays made
        # under a trace would leak tracers into later traces.
        self.cols_loc = cols_loc
        self.vals_loc = vals_loc
        self.cols_post = cols_post
        self.vals_post = vals_post
        return self.cols_loc, self.vals_loc, self.cols_post, self.vals_post

    def neighbor_plan(self, schedule: str = "cyclic") -> SstepNeighbor:
        """Compressed-engine rounds over the depth-s pair volumes; cached
        per scheduler. The ghost gather indexes the compact buffer at
        each scheduled pair's round offset, so the gathered block equals
        the a2a engine's bit-for-bit."""
        if self.nbr is None:
            self.nbr = {}
        plan = self.nbr.get(schedule)
        if plan is not None:
            return plan
        if self.pair_counts is None:
            raise ValueError("compressed s-step engine needs per-pair "
                             "volumes (pair_counts=None)")
        perms, round_L = neighbor_schedule(self.pair_counts, schedule)
        off_by_pair = np.full((self.P, self.P), -1, dtype=np.int64)
        H = 0
        for perm, Lk in zip(perms, round_L):
            for src, dst in perm:
                off_by_pair[src, dst] = H
            H += Lk
        send_idx = np.asarray(self.send_idx)
        send_nbr = np.zeros((self.P, max(H, 1)), dtype=np.int32)
        off = 0
        for perm, Lk in zip(perms, round_L):
            for src, dst in perm:
                send_nbr[src, off:off + Lk] = send_idx[src, dst, :Lk]
            off += Lk
        gather = np.zeros((self.P, self.G), dtype=np.int32)
        for p in range(self.P):
            ng = int(self.n_vc[p])
            if ng:
                own = self.ghost_owner[p, :ng]
                offg = off_by_pair[own, p]
                assert (offg >= 0).all(), "ghost with unscheduled sender"
                gather[p, :ng] = (offg + self.ghost_rank[p, :ng]
                                  ).astype(np.int32)
        plan = SstepNeighbor(perms=perms, round_L=round_L,
                             send_nbr=send_nbr, gather=gather)
        self.nbr[schedule] = plan
        return plan

    def as_dist_ell(self) -> DistEll:
        """s=1 round trip: the depth-1 ghost operator re-expressed in
        :class:`DistEll`'s halo addressing (``R + owner*L + rank``) —
        bit-identical to ``build_dist_ell`` by construction (same per-row
        slot order, same send plan, same widths)."""
        if self.s != 1:
            raise ValueError("as_dist_ell requires s == 1")
        cols = np.array(np.asarray(self.steps[0][0])[:, :self.R, :],
                        dtype=np.int32)
        vals = np.asarray(self.steps[0][1])[:, :self.R, :]
        for p in range(self.P):
            m = cols[p] >= self.R
            if m.any():
                j = cols[p][m] - self.R
                cols[p][m] = (self.R + self.ghost_owner[p, j] * self.L
                              + self.ghost_rank[p, j]).astype(np.int32)
        return DistEll(cols=jnp.asarray(cols), vals=jnp.asarray(vals),
                       send_idx=self.send_idx, R=self.R, L=self.L,
                       P=self.P, D=self.D, n_vc=self.n_vc,
                       pair_counts=self.pair_counts, rowmap=self.rowmap)


def build_sstep_ell(
    matrix: MatrixFamily | CSR,
    P_row: int,
    sstep: int,
    dtype=None,
    d_pad: int | None = None,
    split_halo: bool = False,
    rowmap: RowMap | None = None,
) -> SstepEll:
    """Build the depth-``sstep`` ghost-zone operator for P_row shards.

    BFS over the pattern from each shard's rows collects the depth-s
    ghost set (:func:`sstep_ghosts`); the exchange plan ships it in ONE
    collective per group of s recurrence steps, and per-step ELL blocks
    over the extended address space ``[0, R + G)`` apply the operator to
    owned + still-needed ghost rows. ``sstep=1`` reproduces today's
    :class:`DistEll` bit-exactly (see :meth:`SstepEll.as_dist_ell`).
    Accepts the same ``rowmap`` planned decompositions as
    ``build_dist_ell`` — the BFS runs in position space.
    """
    s = int(sstep)
    if s < 1:
        raise ValueError(f"sstep must be >= 1 (got {sstep})")
    D = matrix.shape[0] if isinstance(matrix, CSR) else matrix.D
    pos = None
    if rowmap is not None:
        if rowmap.D != D:
            raise ValueError("rowmap.D does not match the matrix")
        if d_pad is not None and d_pad != rowmap.D_pad:
            raise ValueError(f"d_pad={d_pad} conflicts with the rowmap's "
                             f"D_pad={rowmap.D_pad}")
        if rowmap.identity:
            R = Partition(D, P_row, rowmap.D_pad).R
        else:
            R = rowmap.level_R(P_row)
            pos = rowmap.pos
    else:
        R = Partition(D, P_row, d_pad).R
    D_pos = P_row * R

    if isinstance(matrix, CSR):
        rows, cols, vals = _csr_rows(matrix, 0, D)
    else:
        rows, cols, vals = matrix.row_entries(np.arange(D, dtype=np.int64))
        rows = np.asarray(rows, dtype=np.int64)
        cols = np.asarray(cols, dtype=np.int64)
        vals = np.asarray(vals)
    if pos is not None:
        rows = pos[rows]
        cols = pos[cols]
    # stable (position-row, position-col) sort: duplicate entries keep
    # their fetch order, exactly like build_dist_ell's per-shard lexsort
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    indptr = np.zeros(D_pos + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(np.bincount(rows, minlength=D_pos))

    ghosts = sstep_ghosts(indptr, cols, P_row, R, s)
    n_vc = np.array([g.size for g, _ in ghosts], dtype=np.int64)
    G = int(n_vc.max()) if len(n_vc) else 0

    # depth-s exchange plan: true per-pair volumes, within-pair slots in
    # ascending position order (= DistEll's need-set order at s=1)
    pair_counts = np.zeros((P_row, P_row), dtype=np.int64)
    for p, (gpos, _) in enumerate(ghosts):
        if gpos.size:
            pair_counts[:, p] = np.bincount(gpos // R, minlength=P_row)
    L = int(pair_counts.max()) if pair_counts.size else 0
    send_idx = np.zeros((P_row, P_row, L), dtype=np.int32)
    ghost_owner = np.zeros((P_row, G), dtype=np.int64)
    ghost_rank = np.zeros((P_row, G), dtype=np.int64)
    for p, (gpos, _) in enumerate(ghosts):
        if not gpos.size:
            continue
        own = gpos // R
        starts = np.searchsorted(own, np.arange(P_row))
        rank = np.arange(gpos.size) - starts[own]
        for q in np.unique(own):
            m = own == q
            send_idx[int(q), p, :int(m.sum())] = (gpos[m] - int(q) * R
                                                  ).astype(np.int32)
        ghost_owner[p, :gpos.size] = own
        ghost_rank[p, :gpos.size] = rank
    gather_a2a = (ghost_owner * L + ghost_rank).astype(np.int32)

    cum = np.zeros((max(P_row, 1), s + 1), dtype=np.int64)
    for p, (_, gdep) in enumerate(ghosts):
        for d in range(1, s + 1):
            cum[p, d] = int((gdep <= d).sum())
    ghost_cum = tuple(int(v) for v in cum.max(axis=0))

    # per-shard entry lists for every row that is an OUTPUT of some step
    # (owned rows + ghosts at depth <= s-1), sorted per row by the
    # universal (owner != row_owner, owner, position) key
    from ..matrices.sparse import gather_row_entry_idx

    shard_data = []
    for p, (gpos, gdep) in enumerate(ghosts):
        inc = gdep <= s - 1
        inc_pos = np.concatenate([np.arange(p * R, (p + 1) * R,
                                            dtype=np.int64), gpos[inc]])
        inc_ext = np.concatenate([np.arange(R, dtype=np.int64),
                                  R + np.nonzero(inc)[0]])
        inc_owner = np.concatenate([np.full(R, p, dtype=np.int64),
                                    gpos[inc] // R])
        inc_depth = np.concatenate([np.zeros(R, dtype=np.int64), gdep[inc]])
        gather, counts = gather_row_entry_idx(indptr, inc_pos)
        e_cols = cols[gather]
        e_vals = vals[gather]
        e_row = np.repeat(inc_ext, counts)
        e_rowner = np.repeat(inc_owner, counts)
        e_depth = np.repeat(inc_depth, counts)
        e_own = e_cols // R
        local_m = e_own == p
        e_addr = np.empty(e_cols.size, dtype=np.int64)
        e_addr[local_m] = e_cols[local_m] - p * R
        if (~local_m).any():
            rc = e_cols[~local_m]
            idx = np.searchsorted(gpos, rc)
            ok = (idx < gpos.size) & (gpos[np.minimum(idx, max(gpos.size - 1,
                                                               0))] == rc)
            if not ok.all():
                raise AssertionError("s-step BFS closure violated: an "
                                     "output row references a position "
                                     "outside the depth-s ghost zone")
            e_addr[~local_m] = R + idx
        remote_flag = (e_own != e_rowner).astype(np.int64)
        e_order = np.lexsort((e_cols, e_own, remote_flag, e_row))
        e_row = e_row[e_order]
        e_addr = e_addr[e_order]
        e_vals = e_vals[e_order]
        e_depth = e_depth[e_order]
        rcounts = np.bincount(e_row, minlength=R + G)
        slot = np.arange(e_row.size) - np.repeat(
            np.cumsum(rcounts) - rcounts, rcounts)
        shard_data.append((e_row, e_addr, e_vals, e_depth, slot))

    vdtype = np.dtype(dtype) if dtype is not None else vals.dtype
    steps = []
    for i in range(s):
        lim = s - 1 - i
        W_i = 0
        for e_row, e_addr, e_vals, e_depth, slot in shard_data:
            m = e_depth <= lim
            if m.any():
                W_i = max(W_i, int(slot[m].max()) + 1)
        ci = np.zeros((P_row, R + G, W_i), dtype=np.int32)
        vi = np.zeros((P_row, R + G, W_i), dtype=vdtype)
        for p, (e_row, e_addr, e_vals, e_depth, slot) in enumerate(
                shard_data):
            m = e_depth <= lim
            ci[p, e_row[m], slot[m]] = e_addr[m]
            vi[p, e_row[m], slot[m]] = e_vals[m].astype(vdtype)
        steps.append((jnp.asarray(ci), jnp.asarray(vi)))

    sell = SstepEll(
        steps=tuple(steps),
        send_idx=jnp.asarray(send_idx),
        gather_a2a=jnp.asarray(gather_a2a),
        R=R, G=G, L=L, P=P_row, D=D, s=s,
        n_vc=n_vc,
        pair_counts=pair_counts,
        ghost_cum=ghost_cum,
        ghost_owner=ghost_owner,
        ghost_rank=ghost_rank,
        rowmap=rowmap,
    )
    if split_halo:
        sell.split()
    return sell


def _build_sstep_group(mesh: Mesh, layout: Layout, sell: SstepEll, *,
                       n_steps: int, first: bool, use_kernel: bool,
                       overlap: bool, comm: str, schedule: str):
    """One fused s-step GROUP: a single depth-s ghost exchange followed by
    ``n_steps`` three-term recurrence steps applied on the extended block.

    The first group ships only ``V`` (the recurrence seeds off one
    vector); later groups ship ``[w1 | w2]`` width-doubled in the same
    collective, so a degree-n filter runs ⌈n/s⌉ exchanges total. Step i
    contracts the step-i ELL (outputs valid at depth ≤ s-1-i), applies
    the same fused epilogue expression as the s=1 engines, and shifts
    the recurrence carries — all inside one shard_map body. The owned
    slices of the step outputs come back STACKED (``[n_steps, R, nb]``
    per shard) so the μ-accumulation runs in the caller's main graph
    with exactly the same op tree as :func:`chebyshev_filter` — keeping
    XLA's fused-multiply-add formation, and therefore the bits,
    identical to the s=1 engines. With ``overlap=True`` the
    exchange is launched first and step 0's local prefix contracts while
    the ghost bytes fly (steps >= 1 have a data dependence on the ghosts
    and cannot overlap anything). With ``use_kernel=True`` step 0's
    block dispatches to the Pallas ``ell_gather`` tile kernel.
    """
    _validate_engine(comm, schedule)
    dist = layout.dist_axes
    vec_spec = layout.vec_pspec()

    def pspec(a):
        return P(dist if dist else None, *((None,) * (a.ndim - 1)))

    kops = None
    if use_kernel:
        from ..kernels import ops as kops_mod

        kops = kops_mod

    R, G = sell.R, sell.G
    has_halo = sell.P > 1 and G > 0
    nbrp = sell.neighbor_plan(schedule) if comm == "compressed" else None
    if comm == "compressed":
        ex_args = [nbrp.send_nbr, nbrp.gather]
    else:
        ex_args = [sell.send_idx, sell.gather_a2a]

    later = [a for cv in sell.steps[1:n_steps] for a in cv]
    if overlap:
        cl, vl, cpost, vpost = sell.split()
        tiles_plan = kops.plan_ell_tiles(cl, vl, R) if use_kernel else None
        step0 = [cl, vl, cpost, vpost]
    else:
        c0, v0 = sell.steps[0]
        tiles_plan = (kops.plan_ell_tiles(c0, v0, R + G)
                      if use_kernel else None)
        step0 = [c0, v0]
    tile_args = list(tiles_plan.arrays()) if tiles_plan else []
    args = ex_args + step0 + later + tile_args
    n_ex = len(ex_args)
    n0 = n_ex + len(step0)
    n_later = 2 * (n_steps - 1)
    n_args = len(args)

    def group_dev(w1, w2, a, b, dev):
        ex = dev[:n_ex]
        sarrs = dev[n_ex:n0]
        later_arrs = dev[n0:n0 + n_later]
        tiles = _dev_tiles(tiles_plan, dev[n0 + n_later:])
        nb = w1.shape[1]
        adt = jnp.result_type(sarrs[1].dtype, w1.dtype)
        payload = w1 if first else jnp.concatenate([w1, w2], axis=1)
        if has_halo:
            if comm == "compressed":
                send_nbr, gather = ex
                buf = _halo_exchange_nbr(payload, send_nbr, dist,
                                         nbrp.perms, nbrp.round_L)
            else:
                send_idx, gather = ex
                buf = lax.all_to_all(
                    jnp.take(payload, send_idx, axis=0), dist,
                    split_axis=0, concat_axis=0, tiled=False,
                ).reshape(sell.P * sell.L, payload.shape[1])

        def take_ghosts():
            if has_halo:
                return jnp.take(buf, gather, axis=0)  # [G, payload width]
            return jnp.zeros((G, payload.shape[1]), dtype=payload.dtype)

        if overlap:
            cl_, vl_, cpost_, vpost_ = sarrs
            # local prefix contracts while the ghost exchange is in flight
            y_pre = _contract_block(jnp.zeros((R, nb), dtype=adt),
                                    cl_, vl_, w1, tiles)
            ghosts = take_ghosts()
            w1e = jnp.concatenate([w1, ghosts[:, :nb]], axis=0)
            w2e = (None if first
                   else jnp.concatenate([w2, ghosts[:, nb:]], axis=0))
            y = jnp.concatenate([y_pre, jnp.zeros((G, nb), dtype=adt)],
                                axis=0)
            if cpost_.shape[1]:
                y = _ell_contract(y, cpost_, vpost_, w1e)
        else:
            c0_, v0_ = sarrs
            ghosts = take_ghosts()
            w1e = jnp.concatenate([w1, ghosts[:, :nb]], axis=0)
            w2e = (None if first
                   else jnp.concatenate([w2, ghosts[:, nb:]], axis=0))
            y = _contract_block(jnp.zeros((R + G, nb), dtype=adt),
                                c0_, v0_, w1e, tiles)

        ts = []
        for i in range(n_steps):
            if i:
                ci, vi = later_arrs[2 * (i - 1)], later_arrs[2 * i - 1]
                y = _ell_contract(jnp.zeros((R + G, nb), dtype=adt),
                                  ci, vi, w1e)
            if first and i == 0:
                t = a * y + b * w1e
            else:
                t = 2.0 * a * y + 2.0 * b * w1e - w2e
            ts.append(t[:R])
            w2e, w1e = w1e, t
        return jnp.stack(ts), w1e[:R], w2e[:R]

    plan_specs = tuple(pspec(a) for a in args)
    vec_in = (vec_spec,) if first else (vec_spec, vec_spec)
    stk_spec = P(None, *tuple(vec_spec))

    def local_fn(*ins):
        dev = [a[0] for a in ins[:n_args]]
        if first:
            w1, a, b = ins[n_args:]
            return group_dev(w1, None, a, b, dev)
        w1, w2, a, b = ins[n_args:]
        return group_dev(w1, w2, a, b, dev)

    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=plan_specs + vec_in + (P(), P()),
        out_specs=(stk_spec, vec_spec, vec_spec),
        check_rep=False,
    )

    if first:
        def group1(V, alpha, beta):
            rdt = jnp.zeros((), dtype=V.dtype).real.dtype
            a = jnp.asarray(alpha, dtype=rdt)
            b = jnp.asarray(beta, dtype=rdt)
            return fn(*args, V, a, b)

        return group1

    def group(w1, w2, alpha, beta):
        rdt = jnp.zeros((), dtype=w1.dtype).real.dtype
        a = jnp.asarray(alpha, dtype=rdt)
        b = jnp.asarray(beta, dtype=rdt)
        return fn(*args, w1, w2, a, b)

    return group


def make_sstep_cheb(mesh: Mesh, layout: Layout, sell: SstepEll, *,
                    use_kernel: bool = False, overlap: bool = False,
                    comm: str = "a2a", schedule: str = "cyclic"):
    """Communication-avoiding Chebyshev filter application (seventh engine
    axis, ``spmv_sstep = sell.s``): ``apply(V, mu, alpha, beta)`` runs
    the whole degree-n filter in ⌈n/s⌉ depth-s ghost exchanges — s
    three-term recurrence steps per exchange — instead of n per-SpMV
    halo exchanges. Composes with the comm engine (``a2a`` /
    ``compressed`` + scheduler), the overlap split of step 0, and the
    Pallas tile kernel, and agrees bit-for-bit with every s=1 engine.
    ``s == 1`` callers should use :func:`make_fused_cheb_step` /
    :func:`make_spmv` (one exchange per step IS the s=1 engine)."""
    from .chebyshev import chebyshev_filter_sstep

    if sell.s < 2:
        raise ValueError("make_sstep_cheb requires s >= 2; the s=1 axis "
                         "point is the existing make_spmv engine grid")
    cache: dict = {}

    def factory(n_steps: int, first: bool):
        key = (int(n_steps), bool(first))
        if key not in cache:
            cache[key] = _build_sstep_group(
                mesh, layout, sell, n_steps=key[0], first=key[1],
                use_kernel=use_kernel, overlap=overlap, comm=comm,
                schedule=schedule)
        return cache[key]

    def apply(V, mu, alpha, beta):
        return chebyshev_filter_sstep(factory, mu, alpha, beta, V, sell.s)

    return apply

"""Lanczos spectral inclusion interval (Alg. 1 step 1).

A few Lanczos steps on a random vector give Ritz value bounds; the residual
of the extremal Ritz pairs provides a rigorous safety margin so that
spec(A) ⊂ [λ_l, λ_r] (required for the Chebyshev map to stay in [-1,1]).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

__all__ = ["lanczos_interval"]


def lanczos_interval(spmv, D: int, D_pad: int, dtype, key, steps: int = 30,
                     safety: float = 1.05, mask=None):
    """Return (lambda_l, lambda_r) enclosing spec(A).

    ``spmv`` acts on [D_pad, 1] arrays (any distributed layout); the
    tridiagonal coefficients are accumulated on the host (they are scalars,
    so this costs one tiny transfer per step — the paper's preparatory
    phase is negligible and we keep it simple). Padding rows are kept
    exactly zero so the padded operator's null modes never enter the
    Krylov space: by default the pad is the tail [D:D_pad) (the
    equal-rows partition), while a planned row decomposition
    (``core/partition.py``) passes its own ``mask`` — a [D_pad] bool of
    valid positions — because its pad rows sit at each block's end, not
    at the global end.
    """
    v = jax.random.normal(key, (D_pad, 1)).astype(dtype)
    if mask is None:
        mask = jnp.arange(D_pad) < D
    v = v * jnp.asarray(mask)[:, None]
    v = v / jnp.linalg.norm(v)
    alphas, betas = [], []
    v_prev = jnp.zeros_like(v)
    beta = 0.0
    for k in range(steps):
        w = spmv(v)
        a = float(jnp.real(jnp.vdot(v, w)))
        w = w - a * v - beta * v_prev
        b = float(jnp.linalg.norm(w))
        alphas.append(a)
        betas.append(b)
        if b < 1e-12:
            break
        v_prev, v = v, w / b
    T = np.diag(alphas)
    off = betas[: len(alphas) - 1]
    T += np.diag(off, 1) + np.diag(off, -1)
    theta, Y = np.linalg.eigh(T)
    resid = betas[len(alphas) - 1] * np.abs(Y[-1, :])  # Ritz residual bounds
    lo = float(theta[0] - resid[0])
    hi = float(theta[-1] + resid[-1])
    mid, half = 0.5 * (lo + hi), 0.5 * (hi - lo)
    return mid - safety * half, mid + safety * half

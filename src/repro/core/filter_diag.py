"""Filter diagonalization driver — paper Algorithm 1.

Orchestrates the two orthogonal layers of parallelism:

  stack layout : orthogonalization (TSQR), Ritz extraction, convergence
  panel layout : Chebyshev polynomial filter (bulk of all SpMVs)
  steps 7 / 9  : explicit redistribution between the two layouts

The driver is layout-generic: with ``n_col = 1`` it degenerates to the
classic single-layer stack algorithm (the paper's baseline); with
``n_col = P`` the filter runs in the pillar layout (comm-free SpMV).

The filter layout is picked by ``FDConfig.layout``: an explicit name
("stack" / "panel" / "pillar") materialized on the given mesh, or
``"auto"``, which runs the χ-driven planner (``core/planner.py``) over
the layouts the mesh realizes and adopts the minimum-predicted-time
configuration — including whether to use the split-phase overlap SpMV
engine, which halo-exchange engine to run, and how the compressed
engine's permute rounds are scheduled (``FDConfig.spmv_overlap``,
``FDConfig.spmv_comm``, and ``FDConfig.spmv_schedule`` are then set from
the plan; ``spmv_comm="compressed"`` replaces the padded all_to_all with
per-pair-sized ppermute rounds, and ``spmv_schedule="matching"`` derives
those rounds from greedy max-weight matchings instead of cyclic shifts).
A ``panel_layout`` passed explicitly to ``FilterDiag`` overrides all of
them.

The row partition itself is part of the engine configuration
(``FDConfig.spmv_balance`` / ``FDConfig.spmv_reorder``,
``core/partition.py``): ``spmv_balance="commvol"`` re-balances the
shard boundaries so hot blocks shrink before scheduling, and
``spmv_reorder="rcm"`` applies a bandwidth-reducing row order first —
eigenvalues are unchanged and :meth:`FilterDiag.gather_global`
un-permutes vectors back to the original row order. Both are planned
once at the finest level (P_total) so the stack- and panel-level
operators share one map, and ``layout="auto"`` decides them together
with the other engine axes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

from jax.sharding import Mesh

from . import filters
from .chebyshev import chebyshev_filter, scale_params
from .lanczos import lanczos_interval
from .layouts import Layout
from .orthogonalize import make_gram, make_svqb, make_tsqr
from .redistribute import make_redistribute
from .spmv import (build_dist_ell, build_sstep_ell, make_fused_cheb_step,
                   make_spmv, make_sstep_cheb)

__all__ = ["FDConfig", "FDResult", "FDState", "FilterDiag"]


@dataclasses.dataclass
class FDConfig:
    n_target: int = 10          # N_t requested eigenpairs
    n_search: int = 40          # N_s search vectors (N_s >> N_t)
    target: float = 0.0         # τ
    tol: float = 1e-10          # residual convergence threshold (paper)
    max_iters: int = 50
    lanczos_steps: int = 30
    search_expand: float = 1.5  # search-interval growth factor
    degree_cap: int = 200_000
    sharpness: float = 6.0
    ortho: str = "tsqr"         # or "svqb"
    redist_impl: str = "explicit"  # or "gspmd"
    layout: str = "panel"       # filter layout: stack | panel | pillar | auto
    spmv_overlap: bool = False  # split-phase SpMV: hide halo exchange
    spmv_comm: str = "a2a"      # halo exchange: a2a | compressed (ppermute)
    spmv_schedule: str = "cyclic"  # compressed rounds: cyclic | matching
    spmv_balance: str = "rows"  # row partition: rows | commvol (planned cuts)
    spmv_reorder: str = "none"  # row order: none | rcm (bandwidth-reducing)
    spmv_kernel: bool = False   # Pallas kernels for the local contraction
    spmv_sstep: int = 1         # s-step filter: depth-s ghosts, ceil(n/s) exchanges
    plan_mode: str = "auto"     # pattern passes: exact | sampled | auto (gate)
    dtype: str = "float64"
    seed: int = 7


@dataclasses.dataclass
class FDResult:
    eigenvalues: np.ndarray
    residuals: np.ndarray
    n_converged: int
    iterations: int
    total_spmvs: int
    redistributions: int
    wall_time: float
    redist_time: float
    history: list


@dataclasses.dataclass
class FDState:
    """Explicit iteration state of one FD solve (Algorithm 1 unrolled).

    Everything the outer loop carries between iterations lives here, so a
    solve can be driven step-by-step (``FilterDiag.step``), checkpointed
    at iteration boundaries (``service/jobs.py`` packs ``V`` as the pytree
    leaf and the host fields as the manifest extra), and resumed from the
    last committed step — the resumed trajectory is bit-identical to the
    uninterrupted one because every per-iteration quantity is recomputed
    from (V, lam) by the same deterministic ops.

    ``pending`` is transient within one iteration only: ``step_analyze``
    stashes the filter coefficients it chose and ``step_filter`` consumes
    them; at checkpoint boundaries it is always ``None``.
    """

    V: jax.Array | None            # search block [D_pad, N_s], stack layout
    lam: tuple                     # Lanczos inclusion interval (λ_l, λ_r)
    iteration: int = 0
    total_spmvs: int = 0
    redistributions: int = 0
    redist_time: float = 0.0
    wall_time: float = 0.0
    history: list = dataclasses.field(default_factory=list)
    pending: tuple | None = None   # (mu [deg+1], degree) awaiting step_filter
    done: bool = False
    result: FDResult | None = None


class FilterDiag:
    """Filter diagonalization on a (row x col) solver mesh.

    ``matrix`` may be a MatrixFamily or a CSR — both expose the sparsity
    pattern, which ``build_dist_ell`` turns into per-shard ELL blocks plus
    the halo communication plan (and which the planner consumes when
    ``cfg.layout == "auto"``; the chosen plan is kept on ``self.plan``).
    """

    def __init__(self, matrix, mesh: Mesh, cfg: FDConfig,
                 panel_layout: Layout | None = None,
                 rowmap=None):
        if panel_layout is None and cfg.layout == "auto":
            # the planner decides spmv_overlap — work on a copy so the
            # caller's config object is not mutated
            cfg = dataclasses.replace(cfg)
        self.cfg = cfg
        self.mesh = mesh
        self.plan = None
        # an explicitly passed rowmap (e.g. the one the solve CLI's auto
        # plan already computed) is used verbatim — no re-planning
        self.rowmap = rowmap
        self.panel_layout = panel_layout or self._resolve_layout(matrix, mesh, cfg)
        # stack shards D over all axes, panel-row axes slowest ("matching")
        self.stack_layout = Layout(
            "stack", self.panel_layout.dist_axes + self.panel_layout.bundle_axes, ()
        )
        self.P_total = self.stack_layout.n_row(mesh)
        self.N_row = self.panel_layout.n_row(mesh)
        self.N_col = self.panel_layout.n_col(mesh)
        if cfg.n_search % max(self.N_col, 1):
            raise ValueError("n_search must be divisible by N_col")
        if cfg.spmv_sstep < 1:
            raise ValueError(f"spmv_sstep must be >= 1 "
                             f"(got {cfg.spmv_sstep})")
        dt = jnp.dtype(cfg.dtype)
        if getattr(matrix, "is_complex", False) and not jnp.issubdtype(dt, jnp.complexfloating):
            dt = jnp.dtype("complex128" if dt == jnp.float64 else "complex64")
        self.dtype = dt
        D = matrix.shape[0] if hasattr(matrix, "shape") else matrix.D
        self.D = D
        # planned row decomposition (core/partition.py): the auto planner
        # may have handed one over; an explicit spmv_balance/spmv_reorder
        # plans it here, at the finest level P_total, so the stack- and
        # panel-level operators below share one map
        if self.rowmap is None and (cfg.spmv_balance, cfg.spmv_reorder) \
                != ("rows", "none"):
            from .partition import plan_rowmap

            self.rowmap = plan_rowmap(matrix, self.P_total,
                                      balance=cfg.spmv_balance,
                                      reorder=cfg.spmv_reorder,
                                      sstep=cfg.spmv_sstep,
                                      plan_mode=cfg.plan_mode)
            if self.rowmap.identity:
                self.rowmap = None  # planned map degenerated to equal rows
        # one padded extent for both layouts (the planned map's when set)
        self.D_pad = (self.rowmap.D_pad if self.rowmap is not None
                      else -(-D // self.P_total) * self.P_total)
        self.ell_stack = build_dist_ell(matrix, self.P_total, dtype=dt,
                                        d_pad=self.D_pad,
                                        split_halo=cfg.spmv_overlap,
                                        rowmap=self.rowmap)
        if self.N_col > 1:
            self.ell_panel = build_dist_ell(matrix, self.N_row, dtype=dt,
                                            d_pad=self.D_pad,
                                            split_halo=cfg.spmv_overlap,
                                            rowmap=self.rowmap)
        else:
            self.ell_panel = self.ell_stack
        # s-step filter operator (seventh engine axis): depth-s ghost
        # zones at the panel level only — Lanczos and the Ritz residual
        # are single SpMVs, so the stack operator stays s=1
        self.sell_panel = (
            build_sstep_ell(matrix, self.N_row, cfg.spmv_sstep, dtype=dt,
                            d_pad=self.D_pad, rowmap=self.rowmap)
            if cfg.spmv_sstep > 1 else None
        )
        self._build_fns(matrix)

    # ------------------------------------------------------------------
    def _resolve_layout(self, matrix, mesh: Mesh, cfg: FDConfig) -> Layout:
        """Materialize ``cfg.layout`` on the mesh; ``"auto"`` runs the
        χ-driven planner over {stack, panel, pillar} × {a2a,
        compressed-cyclic, compressed-matching} × {overlap on/off} ×
        {equal-rows, commvol} partitions and also decides
        ``cfg.spmv_overlap``, ``cfg.spmv_comm``, ``cfg.spmv_schedule``,
        ``cfg.spmv_balance``/``cfg.spmv_reorder``, and
        ``cfg.spmv_kernel`` (an explicitly requested reorder or kernel
        widens the corresponding planner axis)."""
        from .planner import layout_on_mesh, plan_for_mesh

        if cfg.layout == "auto":
            # plan on the engine's padded partition (build_dist_ell below
            # uses d_pad = ceil(D/P)*P) so the scored χ/L are the ones the
            # built operator will actually realize
            P = 1
            for a in mesh.axis_names:
                P *= mesh.shape[a]
            D = matrix.shape[0] if hasattr(matrix, "shape") else matrix.D
            self.plan = plan_for_mesh(
                matrix, mesh, n_search=cfg.n_search,
                d_pad=-(-D // P) * P,
                reorder=tuple(dict.fromkeys(("none", cfg.spmv_reorder))),
                kernel=tuple(dict.fromkeys((False, cfg.spmv_kernel))),
                sstep=tuple(dict.fromkeys((1, cfg.spmv_sstep))),
                plan_mode=cfg.plan_mode)
            best = self.plan.best
            cfg.spmv_overlap = best.overlap
            cfg.spmv_comm = best.comm
            cfg.spmv_schedule = best.schedule
            cfg.spmv_balance = best.balance
            cfg.spmv_reorder = best.reorder
            cfg.spmv_kernel = best.kernel
            cfg.spmv_sstep = best.sstep
            # the operators below are built from exactly the map the
            # winning candidate was scored on
            if self.rowmap is None:
                self.rowmap = best.rowmap
            return layout_on_mesh(mesh, best.layout)
        if cfg.layout in ("stack", "panel", "pillar"):
            return layout_on_mesh(mesh, cfg.layout)
        raise ValueError(f"unknown FDConfig.layout {cfg.layout!r} "
                         "(expected stack | panel | pillar | auto)")

    def _build_fns(self, matrix):
        mesh, cfg = self.mesh, self.cfg
        self.spmv_stack = make_spmv(mesh, self.stack_layout, self.ell_stack,
                                    use_kernel=cfg.spmv_kernel,
                                    overlap=cfg.spmv_overlap,
                                    comm=cfg.spmv_comm,
                                    schedule=cfg.spmv_schedule)
        self.spmv_panel = (
            make_spmv(mesh, self.panel_layout, self.ell_panel,
                      use_kernel=cfg.spmv_kernel,
                      overlap=cfg.spmv_overlap, comm=cfg.spmv_comm,
                      schedule=cfg.spmv_schedule)
            if self.N_col > 1 else self.spmv_stack
        )
        # kernelized recurrence step: the fused 2a·A·w1 + 2b·w1 - w2 body
        # (single shard_map / cheb_dia dispatch) used by the filter loop
        self.fused_step_panel = (
            make_fused_cheb_step(mesh, self.panel_layout, self.ell_panel,
                                 use_kernel=True,
                                 overlap=cfg.spmv_overlap,
                                 comm=cfg.spmv_comm,
                                 schedule=cfg.spmv_schedule)
            if cfg.spmv_kernel else None
        )
        # s-step filter applier (spmv_sstep > 1): the whole degree-n
        # filter in ceil(n/s) depth-s ghost exchanges, bit-identical to
        # the per-step engines (core/spmv.py make_sstep_cheb)
        self.cheb_sstep = (
            make_sstep_cheb(mesh, self.panel_layout, self.sell_panel,
                            use_kernel=cfg.spmv_kernel,
                            overlap=cfg.spmv_overlap,
                            comm=cfg.spmv_comm,
                            schedule=cfg.spmv_schedule)
            if self.sell_panel is not None else None
        )
        if cfg.ortho == "tsqr":
            self._tsqr = make_tsqr(mesh, self.stack_layout)
            self.orthogonalize = jax.jit(lambda V: self._tsqr(V)[0])
        else:
            self.orthogonalize = jax.jit(make_svqb(mesh, self.stack_layout))
        self.gram = make_gram(mesh, self.stack_layout)
        self.to_panel, self.to_stack = make_redistribute(
            mesh, self.stack_layout, self.panel_layout, impl=cfg.redist_impl
        )
        self.to_panel = jax.jit(self.to_panel)
        self.to_stack = jax.jit(self.to_stack)

        def ritz(V):
            AV = self.spmv_stack(V)
            H = self.gram(V, AV)  # [Ns, Ns] replicated
            H = 0.5 * (H + jnp.conj(H.T))
            theta, Y = jnp.linalg.eigh(H)
            # residual norms: || AV y - θ V y ||
            AVY = AV @ Y.astype(AV.dtype)
            VY = V @ Y.astype(V.dtype)
            Rm = AVY - VY * theta[None, :].astype(VY.dtype)
            res = jnp.sqrt(jnp.sum(jnp.abs(Rm) ** 2, axis=0))
            return theta, Y, res, VY

        self.ritz = jax.jit(ritz)
        self._cheb_cache: dict[int, Callable] = {}

    def _cheb(self, degree: int):
        if degree not in self._cheb_cache:
            if self.cheb_sstep is not None:
                run = self.cheb_sstep
            else:
                spmv = self.spmv_panel
                fused_step = self.fused_step_panel

                def run(V, mu, alpha, beta):
                    return chebyshev_filter(spmv, mu, alpha, beta, V,
                                            fused_step=fused_step)

            self._cheb_cache[degree] = jax.jit(run)
        return self._cheb_cache[degree]

    # ------------------------------------------------------------------
    def random_search_vectors(self, key) -> jax.Array:
        cfg = self.cfg
        if self.rowmap is None:
            V = jax.random.normal(key, (self.D_pad, cfg.n_search)).astype(self.dtype)
            V = V * (jnp.arange(self.D_pad)[:, None] < self.D)
        else:
            # planned partition: draw in row space and embed at the map's
            # positions (interior pads stay exactly zero)
            V0 = jax.random.normal(key, (self.D, cfg.n_search)).astype(self.dtype)
            V = jnp.zeros((self.D_pad, cfg.n_search), dtype=self.dtype)
            V = V.at[jnp.asarray(self.rowmap.pos)].set(V0)
        return jax.device_put(V, self.stack_layout.vec_sharding(self.mesh))

    def gather_global(self, V) -> np.ndarray:
        """Rows of a padded [D_pad, ...] vector block in the **original**
        row order [D, ...] — the eigenvector un-permutation of a planned
        partition (identity gather for the equal-rows layout). The
        embed→extract round trip is bit-exact."""
        Vh = np.asarray(V)
        if self.rowmap is None:
            return Vh[: self.D]
        return Vh[self.rowmap.pos]

    def _intervals(self, theta, res, lam, cfg: FDConfig | None = None):
        """Adaptive target & search intervals from the current Ritz data.

        Intervals are bounding boxes of the closest Ritz values rather than
        symmetric windows around τ: for extremal targets (τ outside the
        spectrum) a τ-centered window would keep covering ≫ N_s eigenvalues
        and FD would stall — the paper's Fig. 2 (right column) failure.
        """
        cfg = cfg if cfg is not None else self.cfg
        d = np.abs(theta - cfg.target)
        order = np.argsort(d)
        spec_w = lam[1] - lam[0]
        sel_t = theta[order[: min(cfg.n_target, len(order))]]
        # anchor on τ (clipped into the spectrum): with random start vectors
        # the Ritz values cluster in the spectral bulk, and a pure bounding
        # box would lock the filter onto the wrong region
        tau_c = float(np.clip(cfg.target, lam[0], lam[1]))
        lo = min(float(sel_t.min()), tau_c)
        hi = max(float(sel_t.max()), tau_c)
        pad_t = max(1e-8 * spec_w, 0.05 * (hi - lo))
        target = (lo - pad_t, hi + pad_t)
        n_s = min(int(0.75 * cfg.n_search), len(order))
        sel_s = theta[order[:n_s]]
        s_lo = min(float(sel_s.min()), target[0])
        s_hi = max(float(sel_s.max()), target[1])
        mid = 0.5 * (s_lo + s_hi)
        half = max(0.5 * (s_hi - s_lo),
                   cfg.search_expand * 0.5 * (target[1] - target[0]))
        # pad outward so wanted states sit on the filter plateau, not on the
        # Jackson transition slope (slope width ~ pi/n of the mapped axis)
        pad_s = 0.15 * half
        lo_s = max(mid - half - pad_s, lam[0])
        hi_s = min(mid + half + pad_s, lam[1])
        # extremal targets: widen the outward side by ~the transition width
        # (0.75 of the inner span) so edge states sit on the filter plateau
        # instead of the Jackson slope — without collapsing the degree the
        # way fully opening the window to the inclusion bound would
        if cfg.target <= float(theta.min()):
            lo_s = max(lam[0], target[0] - 0.75 * (hi_s - target[0]))
        if cfg.target >= float(theta.max()):
            hi_s = min(lam[1], target[1] + 0.75 * (target[1] - lo_s))
        search = (lo_s, hi_s)
        return target, search

    # ------------------------------------------------------------------
    # explicit-state iteration API (resumable jobs, service batching)
    # ------------------------------------------------------------------
    def init_state(self, key=None) -> FDState:
        """Fresh :class:`FDState`: Lanczos inclusion interval + random
        search block. ``solve`` is exactly ``init_state`` followed by
        ``step`` until ``done``."""
        cfg = self.cfg
        key = key if key is not None else jax.random.PRNGKey(cfg.seed)
        k0, k1 = jax.random.split(key)
        t0 = time.perf_counter()
        lam = lanczos_interval(
            self.spmv_stack, self.D, self.D_pad, self.dtype, k0,
            cfg.lanczos_steps,
            mask=(None if self.rowmap is None
                  else jnp.asarray(self.rowmap.valid_mask())),
        )
        V = self.random_search_vectors(k1)
        return FDState(V=V, lam=lam, total_spmvs=cfg.lanczos_steps,
                       wall_time=time.perf_counter() - t0)

    def step_analyze(self, state: FDState, cfg: FDConfig | None = None,
                     verbose: bool = False) -> FDState:
        """First half of one outer iteration: orthogonalize, Ritz extract,
        adapt the intervals, and either finish the solve (``state.done``)
        or stash the chosen filter in ``state.pending``.

        ``cfg`` overrides the convergence-relevant fields (target, tol,
        n_target, …) — the service batcher passes per-request configs
        while sharing this solver's operators.
        """
        cfg = cfg if cfg is not None else self.cfg
        t_begin = time.perf_counter()
        it = state.iteration
        if it >= cfg.max_iters:
            # not converged within max_iters — report best effort
            theta, Y, res, VY = self.ritz(self.orthogonalize(state.V))
            theta_h, res_h = np.asarray(theta), np.asarray(res)
            order = np.argsort(np.abs(theta_h - cfg.target))[: cfg.n_target]
            state.wall_time += time.perf_counter() - t_begin
            state.done = True
            state.result = FDResult(
                eigenvalues=theta_h[order], residuals=res_h[order],
                n_converged=int((res_h[order] <= cfg.tol).sum()),
                iterations=cfg.max_iters, total_spmvs=state.total_spmvs,
                redistributions=state.redistributions,
                wall_time=state.wall_time,
                redist_time=state.redist_time, history=state.history,
            )
            return state
        V = self.orthogonalize(state.V)
        theta, Y, res, VY = self.ritz(V)
        state.total_spmvs += cfg.n_search
        theta_h = np.asarray(theta)
        res_h = np.asarray(res)
        target, search = self._intervals(theta_h, res_h, state.lam, cfg=cfg)
        in_t = (theta_h >= target[0]) & (theta_h <= target[1])
        conv = in_t & (res_h <= cfg.tol)
        state.history.append(
            dict(iter=it, n_conv=int(conv.sum()), search=search,
                 best_res=float(res_h[in_t].min()) if in_t.any() else float("nan"))
        )
        if verbose:
            print(f"[fd] it={it:3d} conv={int(conv.sum()):4d}/{cfg.n_target} "
                  f"search=({search[0]:+.4e},{search[1]:+.4e}) "
                  f"best_res={state.history[-1]['best_res']:.2e}")
        if conv.sum() >= cfg.n_target:
            order = np.argsort(np.abs(theta_h - cfg.target))
            sel = order[conv[order]][: max(cfg.n_target, int(conv.sum()))]
            state.wall_time += time.perf_counter() - t_begin
            state.done = True
            state.result = FDResult(
                eigenvalues=theta_h[sel], residuals=res_h[sel],
                n_converged=int(conv.sum()), iterations=it,
                total_spmvs=state.total_spmvs,
                redistributions=state.redistributions,
                wall_time=state.wall_time,
                redist_time=state.redist_time, history=state.history,
            )
            return state
        poly = filters.build_filter(
            search, state.lam, sharpness=cfg.sharpness,
            n_max=cfg.degree_cap,
        )
        # start the filter from the Ritz basis (better conditioning)
        state.V = VY
        state.pending = (np.asarray(poly.mu), poly.degree)
        state.wall_time += time.perf_counter() - t_begin
        return state

    def step_filter(self, state: FDState,
                    cfg: FDConfig | None = None) -> FDState:
        """Second half of one outer iteration: apply the pending Chebyshev
        filter in the panel layout (redistributing if N_col > 1) and
        advance the iteration counter."""
        cfg = cfg if cfg is not None else self.cfg
        t_begin = time.perf_counter()
        mu_h, degree = state.pending
        alpha, beta = scale_params(*state.lam)
        mu = jnp.asarray(mu_h)
        V = state.V
        t0 = time.perf_counter()
        if self.N_col > 1:
            V = self.to_panel(V)
            jax.block_until_ready(V)
            state.redistributions += 1
            state.redist_time += time.perf_counter() - t0
        V = self._cheb(degree)(V, mu, alpha, beta)
        state.total_spmvs += degree * cfg.n_search
        t0 = time.perf_counter()
        if self.N_col > 1:
            V = self.to_stack(V)
            jax.block_until_ready(V)
            state.redistributions += 1
            state.redist_time += time.perf_counter() - t0
        state.V = V
        state.pending = None
        state.iteration += 1
        state.wall_time += time.perf_counter() - t_begin
        return state

    def step(self, state: FDState, verbose: bool = False) -> FDState:
        """One full outer iteration (analyze + filter) — the unit the
        resumable-job driver checkpoints at."""
        state = self.step_analyze(state, verbose=verbose)
        if not state.done:
            state = self.step_filter(state)
        return state

    def solve(self, key=None, verbose: bool = False) -> FDResult:
        state = self.init_state(key)
        while not state.done:
            state = self.step(state, verbose=verbose)
        return state.result

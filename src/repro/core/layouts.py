"""Distributed vector layouts — the two orthogonal layers of parallelism.

The search vectors form a D x N_s matrix V. A *panel* layout distributes V
over an (N_row x N_col) Cartesian process grid (paper Fig. 3):

  * horizontal layer — the D axis is sliced across ``N_row`` processes
    (SpMV communicates along this axis: each shard gathers the remote
    vector entries its nonzeros reference — the χ metric counts them),
  * vertical layer   — the N_s axis is sliced across ``N_col`` process
    columns into bundles of N_s/N_col vectors (no SpMV communication
    crosses it; orthogonalization communicates along this axis).

The three named layouts on a P = 8 device mesh, showing which slice of
the D x N_s vector block each device p0..p7 owns::

        stack (8x1)          panel (4x2)          pillar (1x8)
      N_col = 1            N_row x N_col        N_row = 1
      +-----------+        +-----+-----+      +--+--+--+--+--+--+--+--+
   D  | p0        |        | p0  | p1  |      |p0|p1|p2|p3|p4|p5|p6|p7|
   |  | p1        |        +-----+-----+      |  |  |  |  |  |  |  |  |
   v  | p2        |        | p2  | p3  |      |  |  |  |  |  |  |  |  |
      |  ...      |        +-----+-----+      |  |  |  |  |  |  |  |  |
      | p7        |        |  ...      |      |  |  |  |  |  |  |  |  |
      +-----------+        +-----+-----+      +--+--+--+--+--+--+--+--+
        -> N_s                -> N_s               -> N_s

``stack``  = N_col = 1: D over all P — orthogonalization-friendly, but the
SpMV halo exchange spans all P processes (χ grows with N_row).
``pillar`` = N_row = 1: N_s over all P — every device holds all of D, the
filter's SpMV needs **no communication**, at the price of redistributing
V before/after each filter pass (Alg. 1 steps 7/9, ``redistribute.py``).
``panel``  = everything in between.

On a JAX mesh the horizontal layer maps to the ``row`` axis and the
vertical layer to the ``col`` axis (for the LM production mesh these are
the ``model`` / ``data`` axes; the multi-pod ``pod`` axis extends the
vertical layer — pods never communicate during the polynomial filter).
The χ-driven planner (``planner.py``) chooses between these layouts from
the sparsity pattern when ``FDConfig.layout == "auto"``.
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["Layout", "stack", "pillar", "panel", "make_solver_mesh", "SOLVER_ROW", "SOLVER_COL"]

SOLVER_ROW = "row"  # horizontal layer (D)
SOLVER_COL = "col"  # vertical layer (N_s)


def make_solver_mesh(n_row: int, n_col: int, *, pods: int = 1, devices=None) -> Mesh:
    """Eigensolver mesh. With pods > 1 the pod axis multiplies the vertical
    layer (bundles of vectors across pods — zero SpMV communication)."""
    if pods > 1:
        return jax.make_mesh((pods, n_row, n_col), ("pod", SOLVER_ROW, SOLVER_COL),
                             devices=devices)
    return jax.make_mesh((n_row, n_col), (SOLVER_ROW, SOLVER_COL), devices=devices)


@dataclasses.dataclass(frozen=True)
class Layout:
    """A distributed layout of the D x N_s vector matrix on a mesh."""

    name: str
    dist_axes: tuple[str, ...]  # mesh axes sharding the D axis
    bundle_axes: tuple[str, ...]  # mesh axes sharding the N_s axis

    def vec_pspec(self) -> P:
        """PartitionSpec for V of shape (D, N_s)."""
        return P(self.dist_axes or None, self.bundle_axes or None)

    def vec_sharding(self, mesh: Mesh) -> NamedSharding:
        return NamedSharding(mesh, self.vec_pspec())

    def n_row(self, mesh: Mesh) -> int:
        return _axes_size(mesh, self.dist_axes)

    def n_col(self, mesh: Mesh) -> int:
        return _axes_size(mesh, self.bundle_axes)

    def describe(self, mesh: Mesh) -> str:
        return f"{self.name}({self.n_row(mesh)}x{self.n_col(mesh)})"


def _axes_size(mesh: Mesh, axes: tuple[str, ...]) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def stack(mesh: Mesh) -> Layout:
    """N_col = 1: D sharded over every mesh axis."""
    return Layout("stack", tuple(mesh.axis_names), ())


def pillar(mesh: Mesh) -> Layout:
    """N_row = 1: N_s sharded over every mesh axis (SpMV comm-free)."""
    return Layout("pillar", (), tuple(mesh.axis_names))


def panel(mesh: Mesh, row_axes=(SOLVER_ROW,), col_axes=None) -> Layout:
    """General N_row x N_col panel on the given mesh axes."""
    row_axes = tuple(row_axes)
    if col_axes is None:
        col_axes = tuple(a for a in mesh.axis_names if a not in row_axes)
    return Layout("panel", row_axes, tuple(col_axes))

"""χ-aware row partitioning — the horizontal layer's row decomposition
as a *planned* quantity.

Every SpMV engine so far consumed the fixed equal-rows partition of
``spmv.Partition`` (the paper's "nearly equidistant" row indices, Eq. 1).
The communication metric χ is computed from the sparsity pattern alone,
but on comm-imbalanced families (RoadNet, HubNet) the hot blocks it
flags were immutable: the planner could route *around* them (compressed
engine, matching rounds) but never *shrink* them. This module closes
that loop — the pattern-only metric now edits the layout it measures:

  * ``balance="commvol"`` computes **non-uniform shard boundaries** by
    prefix-balancing a per-row cost

        c(r) = α·nnz(r) + β·cut(r)

    where ``cut(r)`` counts the entries of row r whose column falls
    outside r's current block (the rows that generate halo traffic).
    Blocks rich in cut entries get fewer rows, so the per-block remote
    volumes n_vc — and with them χ₂/χ₃, the padded a2a's ``L`` and the
    neighbor schedules' round pads — drop on imbalanced patterns. The
    balancing iterates a few deterministic sweeps (cut counts depend on
    the boundaries they produce) and caps block growth so the padded
    extent stays bounded.

  * ``reorder="rcm"`` applies a reverse-Cuthill-McKee bandwidth-reducing
    row permutation *before* partitioning, in the spirit of node-aware
    SpMV preprocessing (Bienz, Gropp & Olson, arXiv:1612.08060):
    eigenvalues are unchanged (a symmetric permutation is a similarity
    transform) and eigenvectors are un-permuted on output
    (:meth:`RowMap.extract` / ``FilterDiag.gather_global``).

Both are realized by one object, :class:`RowMap`: an **embed of the D
global rows into a padded position space** of ``D_pad = P·R`` slots in
which every shard owns an equal, contiguous slice of positions. Row g
lives at ``pos(g) = p·R + (r - boundaries[p])`` where r is g's position
in the (possibly reordered) row order and p its planned block. Keeping
the *position* space uniform is what lets the rest of the stack stay
unchanged: ``shard_map``/``NamedSharding`` still see equal blocks, the
stack↔panel redistribution and TSQR operate on positions and never
notice the map, and any level n_row dividing P reuses the same map by
grouping (``owner = pos // (D_pad/n_row)``) — the stack- and
panel-level operators of ``FilterDiag`` stay consistent by
construction. Pad positions (``row_of < 0``) hold exact zeros
everywhere, so they never enter Grams, norms, or Krylov spaces
(``lanczos_interval`` masks them explicitly).

``Partition`` (``core/spmv.py``) remains the ``balance="rows"``,
``reorder="none"`` fast path — ``build_dist_ell`` only takes the
generalized path when a non-identity :class:`RowMap` is passed.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from ..matrices.sparse import CSR, gather_row_entry_idx

__all__ = ["RowMap", "SPMV_BALANCES", "SPMV_REORDERS", "PLAN_MODES",
           "equal_cuts", "plan_rowmap", "rcm_permutation",
           "commvol_boundaries", "partition_plan_default"]

#: Row-balance modes of the partition planner (``FDConfig.spmv_balance``).
SPMV_BALANCES = ("rows", "commvol")

#: Row-reorder modes of the partition planner (``FDConfig.spmv_reorder``).
SPMV_REORDERS = ("none", "rcm")

#: Planning modes (``FDConfig.plan_mode`` / ``--plan-mode``): ``exact``
#: walks the full pattern (gated by :func:`partition_plan_default`),
#: ``sampled`` estimates from a seeded row subsample (``core/sketch.py``
#: — affordable at any D), ``auto`` = exact below the gate, sampled
#: above it.
PLAN_MODES = ("exact", "sampled", "auto")

#: Largest D for which the partition planner's full pattern pass
#: (per-row nnz + cut counts, RCM adjacency) is considered affordable.
PARTITION_PLAN_MAX_D = 1_000_000

#: Largest shard count at which the planner enumerates planned
#: partitions by default — the cut descent is O(P · passes · grid)
#: objective evaluations, each O(P²), so very wide meshes (the 256-chip
#: dry-run) keep the equal-rows partition unless a map is planned
#: explicitly.
PARTITION_PLAN_MAX_P = 64


def partition_plan_default(matrix, P: int | None = None,
                           plan_mode: str = "exact") -> bool:
    """Whether ``plan_rowmap`` is affordable for ``matrix`` (and shard
    count ``P``, when given) — the single policy behind the planner's
    balance/reorder axis gating. Unlike the χ pattern pass (windowed by
    ``reach``), the exact partition planner needs per-row costs over
    *all* rows, so instance size matters; the cut descent additionally
    scales with the shard count. ``plan_mode="sampled"`` (and ``"auto"``,
    which falls back to sampling above the gate) plans from a row
    subsample (``core/sketch.py``) and is affordable at any size."""
    if plan_mode not in PLAN_MODES:
        raise ValueError(f"unknown plan_mode {plan_mode!r} "
                         f"(expected one of {PLAN_MODES})")
    if plan_mode in ("sampled", "auto"):
        return True
    D = matrix.shape[0] if isinstance(matrix, CSR) else matrix.D
    return D <= PARTITION_PLAN_MAX_D and (P is None
                                          or P <= PARTITION_PLAN_MAX_P)


# --------------------------------------------------------------------------
# the row map
# --------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class RowMap:
    """Planned row decomposition: reorder permutation + (possibly
    non-uniform) block boundaries, realized as an embed of global rows
    into a padded equal-block position space.

    ``perm[r]`` is the original row occupying reordered position r
    (identity for ``reorder="none"``); ``boundaries`` are the P+1 block
    cuts in reordered row space; ``R`` is the per-block padded extent —
    every block p owns positions ``[p·R, (p+1)·R)`` and places its
    ``boundaries[p+1]-boundaries[p]`` real rows at the slice's start,
    zero-pad after. ``D_pad = P·R``. Any shard count Q with
    ``D_pad % Q == 0`` reuses the map by grouping positions.
    """

    D: int
    P: int
    balance: str
    reorder: str
    perm: np.ndarray         # [D] original row at each reordered position
    boundaries: np.ndarray   # [P+1] block cuts in reordered row space
    R: int                   # padded rows per plan-level block
    #: ghost-zone depth the map was planned/validated at (the
    #: ``spmv_sstep`` axis). A map planned at s=1 scored under an s>1
    #: comm plan under-counts the depth-s volumes its cuts were never
    #: optimized for — ``planner.comm_plan`` warns on the mismatch.
    sstep: int = 1
    _pos: np.ndarray | None = dataclasses.field(default=None, repr=False)
    _row_of: np.ndarray | None = dataclasses.field(default=None, repr=False)

    @property
    def D_pad(self) -> int:
        return self.P * self.R

    @property
    def identity(self) -> bool:
        """True when the map is exactly the ``Partition`` fast path:
        untouched row order and the equal-rows boundaries at this R —
        either by construction (``balance="rows", reorder="none"``) or
        because a planned map degenerated to it (e.g. the commvol
        never-worse guard kept the equal cuts)."""
        if self.balance == "rows" and self.reorder == "none":
            return True
        eq = np.minimum(np.arange(self.P + 1, dtype=np.int64) * self.R,
                        self.D)
        return bool(np.array_equal(self.boundaries, eq)
                    and np.array_equal(self.perm,
                                       np.arange(self.D, dtype=np.int64)))

    @property
    def pos(self) -> np.ndarray:
        """[D] padded position of every original row (the embed)."""
        if self._pos is None:
            pos = np.empty(self.D, dtype=np.int64)
            for p in range(self.P):
                a, b = int(self.boundaries[p]), int(self.boundaries[p + 1])
                pos[self.perm[a:b]] = p * self.R + np.arange(b - a)
            self._pos = pos
        return self._pos

    @property
    def row_of(self) -> np.ndarray:
        """[D_pad] original row at every padded position, -1 at pads."""
        if self._row_of is None:
            row_of = np.full(self.D_pad, -1, dtype=np.int64)
            row_of[self.pos] = np.arange(self.D, dtype=np.int64)
            self._row_of = row_of
        return self._row_of

    def valid_mask(self) -> np.ndarray:
        """[D_pad] bool: positions holding a real row (False = pad)."""
        return self.row_of >= 0

    def is_bijection(self) -> bool:
        """True iff the embed is injective into [0, D_pad) and ``row_of``
        inverts it on every real row — i.e. ``extract(embed(X)) == X``
        holds structurally. The static plan linter
        (``repro.analysis.plan_lint``) gates on this."""
        pos = self.pos
        if pos.size != self.D:
            return False
        if pos.size and (pos.min() < 0 or pos.max() >= self.D_pad):
            return False
        if np.unique(pos).size != self.D:
            return False
        return bool((self.row_of[pos] == np.arange(self.D)).all())

    def level_R(self, n_row: int) -> int:
        """Padded rows per shard at a grouped level of ``n_row`` shards."""
        if self.D_pad % n_row:
            raise ValueError(f"D_pad={self.D_pad} not divisible by "
                             f"n_row={n_row} (map planned at P={self.P})")
        return self.D_pad // n_row

    def owner(self, rows: np.ndarray, n_row: int | None = None) -> np.ndarray:
        """Shard owning each original row id at level ``n_row``
        (default: the plan level P)."""
        R = self.level_R(n_row) if n_row is not None else self.R
        return self.pos[np.asarray(rows, dtype=np.int64)] // R

    def block_sizes(self, n_row: int | None = None) -> np.ndarray:
        """Real rows per shard at level ``n_row`` (the n_vm of Eq. 3)."""
        if n_row is None or n_row == self.P:
            return np.diff(self.boundaries.astype(np.int64))
        R = self.level_R(n_row)
        return np.bincount(self.pos // R, minlength=n_row)

    def shard_rows(self, p: int, n_row: int | None = None
                   ) -> tuple[np.ndarray, np.ndarray]:
        """(original rows, local offsets) owned by shard ``p`` at level
        ``n_row``, ordered by position."""
        R = self.level_R(n_row) if n_row is not None else self.R
        rows = self.row_of[p * R: (p + 1) * R]
        off = np.nonzero(rows >= 0)[0]
        return rows[off], off

    def embed(self, X: np.ndarray) -> np.ndarray:
        """Scatter row-space data [D, ...] into position space [D_pad, ...]
        (pads exactly zero). ``extract(embed(X))`` is bit-identical to X."""
        X = np.asarray(X)
        out = np.zeros((self.D_pad,) + X.shape[1:], dtype=X.dtype)
        out[self.pos] = X
        return out

    def extract(self, Xp: np.ndarray) -> np.ndarray:
        """Gather position-space data [D_pad, ...] back to the original
        row order [D, ...] — the eigenvector un-permutation."""
        return np.asarray(Xp)[self.pos]

    def describe(self) -> str:
        sizes = self.block_sizes()
        return (f"RowMap(balance={self.balance}, reorder={self.reorder}, "
                f"P={self.P}, R={self.R}, rows/block "
                f"{int(sizes.min())}..{int(sizes.max())})")

    # ------------------------------------------------------- constructors --

    @classmethod
    def rows(cls, D: int, P: int, d_pad: int | None = None) -> "RowMap":
        """The identity map — exactly ``Partition(D, P, d_pad)``."""
        if d_pad is not None and d_pad % P:
            raise ValueError(f"d_pad={d_pad} not divisible by P={P}")
        R = (d_pad if d_pad is not None else (-(-D // P)) * P) // P
        if P * R < D:
            raise ValueError(f"d_pad={d_pad} < D={D}")
        boundaries = np.minimum(np.arange(P + 1, dtype=np.int64) * R, D)
        return cls(D=D, P=P, balance="rows", reorder="none",
                   perm=np.arange(D, dtype=np.int64),
                   boundaries=boundaries, R=R)


# --------------------------------------------------------------------------
# pattern access
# --------------------------------------------------------------------------


def _pattern_csr(matrix, chunk: int = 2_000_000):
    """(indptr, cols) pattern of ``matrix`` in original row order, columns
    sorted (and deduplicated) within each row."""
    if isinstance(matrix, CSR):
        D = matrix.shape[0]
        rows = np.repeat(np.arange(D, dtype=np.int64),
                         np.diff(matrix.indptr))
        cols = matrix.indices.astype(np.int64)
    else:
        D = matrix.D
        parts_r, parts_c = [], []
        for lo in range(0, D, chunk):
            r, c = matrix.row_cols(np.arange(lo, min(lo + chunk, D),
                                             dtype=np.int64))
            parts_r.append(np.asarray(r, dtype=np.int64))
            parts_c.append(np.asarray(c, dtype=np.int64))
        rows = np.concatenate(parts_r)
        cols = np.concatenate(parts_c)
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    if len(rows):  # drop duplicate (row, col) pairs — families may emit them
        keep = np.ones(len(rows), dtype=bool)
        keep[1:] = (rows[1:] != rows[:-1]) | (cols[1:] != cols[:-1])
        rows, cols = rows[keep], cols[keep]
    indptr = np.zeros(D + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    return np.cumsum(indptr), cols


def _reordered_pattern(indptr, cols, perm):
    """Pattern re-expressed in reordered space: row r of the output is
    original row ``perm[r]``, with columns mapped through the inverse
    permutation."""
    D = len(indptr) - 1
    inv = np.empty(D, dtype=np.int64)
    inv[perm] = np.arange(D, dtype=np.int64)
    gather, counts = gather_row_entry_idx(indptr, perm)
    indptr_r = np.concatenate([[0], np.cumsum(counts)])
    return indptr_r, inv[cols[gather]]


def equal_cuts(D: int, P: int) -> np.ndarray:
    """The engine's equal-rows block cuts — ``Partition.boundaries()``:
    ``min(p·ceil(D/P), D)``. This (NOT the round-based
    ``uniform_partition``) is the baseline every planned partition is
    compared against, so the never-worse guard and the degenerate-map
    detection agree with what ``balance="rows"`` actually builds."""
    R = -(-D // P)
    return np.minimum(np.arange(P + 1, dtype=np.int64) * R, D)


# --------------------------------------------------------------------------
# reorder: reverse Cuthill-McKee
# --------------------------------------------------------------------------


def rcm_permutation(matrix, pattern=None) -> np.ndarray:
    """Reverse-Cuthill-McKee row permutation of the symmetric pattern.

    Deterministic: BFS from the lowest-(degree, index) unvisited vertex,
    visiting neighbors in ascending (degree, index) order, final order
    reversed. Returns ``perm`` with ``perm[r]`` = the original row at
    reordered position r, so ``A_reordered[r, s] = A[perm[r], perm[s]]``
    — a similarity transform (eigenvalues unchanged). ``pattern`` may
    carry a precomputed ``(indptr, cols)`` pair to skip the pattern
    pass.
    """
    indptr, cols = pattern if pattern is not None else _pattern_csr(matrix)
    D = len(indptr) - 1
    deg = np.diff(indptr)
    visited = np.zeros(D, dtype=bool)
    order = np.empty(D, dtype=np.int64)
    seeds = np.lexsort((np.arange(D), deg))
    si = 0
    k = 0
    q: deque[int] = deque()
    while k < D:
        while visited[seeds[si]]:
            si += 1
        s = int(seeds[si])
        visited[s] = True
        q.append(s)
        while q:
            u = q.popleft()
            order[k] = u
            k += 1
            nbrs = cols[indptr[u]: indptr[u + 1]]
            nbrs = nbrs[(nbrs != u) & ~visited[nbrs]]
            if nbrs.size:
                nbrs = np.unique(nbrs)  # sorted, distinct
                nbrs = nbrs[np.argsort(deg[nbrs], kind="stable")]
                visited[nbrs] = True
                q.extend(nbrs.tolist())
    return order[::-1].copy()


def pattern_bandwidth(matrix, perm: np.ndarray | None = None) -> int:
    """max |pos(col) - pos(row)| of the pattern under ``perm`` (identity
    if None) — the quantity RCM minimizes heuristically."""
    indptr, cols = _pattern_csr(matrix)
    D = len(indptr) - 1
    if perm is not None:
        indptr, cols = _reordered_pattern(indptr, cols, perm)
    rows = np.repeat(np.arange(D, dtype=np.int64), np.diff(indptr))
    return int(np.abs(cols - rows).max()) if len(rows) else 0


# --------------------------------------------------------------------------
# balance: comm-volume prefix balancing + greedy cut descent
# --------------------------------------------------------------------------


def _normalize_boundaries(b: np.ndarray, D: int, P: int, cap: int) -> np.ndarray:
    """Project block cuts onto the feasible set: monotone, ≥ 1 row and
    ≤ ``cap`` rows per block (requires P ≤ D ≤ P·cap)."""
    b = b.astype(np.int64).copy()
    b[0], b[P] = 0, D
    for p in range(1, P):          # forward: respect the left neighbor
        b[p] = min(max(b[p], b[p - 1] + 1), b[p - 1] + cap)
    for p in range(P - 1, 0, -1):  # backward: respect the right neighbor
        b[p] = min(max(b[p], b[p + 1] - cap), b[p + 1] - 1)
    sizes = np.diff(b)
    if (sizes < 1).any() or (sizes > cap).any():
        # infeasible request (D < P or cap too tight) — fall back to the
        # equal-rows cuts rather than produce a broken map
        return equal_cuts(D, P)
    return b


class _WireObjective:
    """Engine-exact wire volume of a contiguous block partition of the
    (reordered) pattern, with incremental re-evaluation under single-cut
    moves.

    The per-(sender, receiver) distinct volumes are exactly what
    ``build_dist_ell`` realizes: ``pc[q, p]`` counts the distinct columns
    in block q that rows of block p reference. Receiver p's remote set
    ``S_p`` depends only on p's *own* cuts; the split of ``S_p`` among
    senders is a ``searchsorted`` against the full cut vector. Moving
    one cut therefore only recomputes two remote sets — everything else
    is O(P log nnz).

    The objective is the sum of the engines' per-device moved entries:
    the padded all_to_all's ``P·L`` plus the cyclic and matching round
    sums ``H = Σ_r L_r`` — reducing it reduces what every engine puts on
    the wire.
    """

    def __init__(self, indptr: np.ndarray, cols: np.ndarray, P: int,
                 cost: np.ndarray | None = None):
        self.indptr = indptr
        self.cols = cols
        self.P = P
        #: cumulative per-row cost (len D+1); candidate cut positions are
        #: drawn from its quantiles, so they cluster where the rows that
        #: source halo traffic cluster (hub regions) instead of being
        #: uniformly spaced
        self.cumcost = (np.concatenate([[0.0], np.cumsum(cost)])
                        if cost is not None else None)

    def remote_set(self, a: int, b: int) -> np.ndarray:
        """Sorted distinct columns outside [a, b) referenced by rows
        [a, b) — receiver block (a, b)'s remote needs."""
        c = self.cols[self.indptr[a]: self.indptr[b]]
        return np.unique(c[(c < a) | (c >= b)])

    def remote_sets(self, bnds: np.ndarray) -> list[np.ndarray]:
        return [self.remote_set(int(bnds[p]), int(bnds[p + 1]))
                for p in range(self.P)]

    def pair_counts(self, bnds: np.ndarray, S: list[np.ndarray]) -> np.ndarray:
        pc = np.zeros((self.P, self.P), dtype=np.int64)
        for p, Sp in enumerate(S):
            if Sp.size:
                pc[:, p] = np.diff(np.searchsorted(Sp, bnds))
        return pc

    #: above this shard count the descent objective substitutes the
    #: cyclic round sum for the matching one — the greedy matching
    #: decomposition is a Python first-fit over up to P² pairs and the
    #: descent evaluates the objective thousands of times (H_matching ≤
    #: H_cyclic always, so the substitution only over-counts, never
    #: under-counts, the wire)
    MATCHING_EVAL_MAX_P = 32

    def value(self, pc: np.ndarray) -> tuple[int, int]:
        """(wire, progress): ``wire`` is the engines' moved-entry total
        ``P·L + H_cyclic + H_matching``; ``progress`` (Σ pc², the
        tie-break) rewards splitting *individual* hot pairs even while
        the max-based wire terms are still pinned by other pairs — the
        descent needs it to split several hub regions one cut at a
        time."""
        from .spmv import neighbor_schedule  # lazy: avoids an import cycle

        if not pc.any():
            return (0, 0)
        L = int(pc.max())
        # vectorized cyclic round sum Σ_k max_q pc[q, (q+k) % P] — the
        # descent calls this thousands of times, so it must not build
        # the schedule's permutation tuples
        P = self.P
        q = np.arange(P)
        shifted = pc[q[:, None], (q[:, None] + q[None, :]) % P]  # [q, k]
        H_cyc = int(shifted[:, 1:].max(axis=0).sum())
        H_mat = (int(sum(neighbor_schedule(pc, "matching")[1]))
                 if P <= self.MATCHING_EVAL_MAX_P else H_cyc)
        return (P * L + H_cyc + H_mat,
                int((pc.astype(np.int64) ** 2).sum()))

    def evaluate(self, bnds: np.ndarray, S: list[np.ndarray] | None = None
                 ) -> tuple[tuple[int, int], list[np.ndarray]]:
        S = self.remote_sets(bnds) if S is None else S
        return self.value(self.pair_counts(bnds, S)), S

    def refine(self, bnds: np.ndarray, cap: int, *, passes: int = 3,
               grid: int = 13) -> tuple[np.ndarray, tuple[int, int]]:
        """Greedy coordinate descent on the P-1 interior cuts: each cut
        tries a coarse grid of feasible positions, then a finer grid
        around the best, and keeps any strict improvement. Deterministic
        (fixed grids, fixed pass count; both scaled down at large P —
        the eval count is O(P·passes·grid))."""
        if self.P > self.MATCHING_EVAL_MAX_P:
            passes = min(passes, 2)
            grid = min(grid, 9)
        b = bnds.astype(np.int64).copy()
        J, S = self.evaluate(b)
        for _ in range(passes):
            improved = False
            for p in range(1, self.P):
                lo = max(int(b[p - 1]) + 1, int(b[p + 1]) - cap)
                hi = min(int(b[p + 1]) - 1, int(b[p - 1]) + cap)
                if hi <= lo:
                    continue
                span = hi - lo
                best_c, best_J, best_S2 = int(b[p]), J, None
                seen = {int(b[p])}
                for level in range(2):
                    center = best_c
                    width = span if level == 0 else max(span // grid, grid)
                    cands = np.linspace(center - width / 2,
                                        center + width / 2, grid)
                    if level == 0 and self.cumcost is not None:
                        # cost-quantile candidates: equal-cost split points
                        # of the window, clustered inside cost-dense (hub)
                        # stretches a uniform grid would mostly miss
                        clo, chi_ = self.cumcost[lo], self.cumcost[hi]
                        q = clo + (chi_ - clo) * np.arange(1, grid) / grid
                        cands = np.concatenate([
                            cands, np.searchsorted(self.cumcost, q) - 1])
                    cands = np.unique(np.clip(
                        cands.astype(np.int64), lo, hi))
                    for c in cands:
                        c = int(c)
                        if c in seen:
                            continue
                        seen.add(c)
                        trial = b.copy()
                        trial[p] = c
                        S2 = list(S)
                        S2[p - 1] = self.remote_set(int(trial[p - 1]), c)
                        S2[p] = self.remote_set(c, int(trial[p + 1]))
                        Jt = self.value(self.pair_counts(trial, S2))
                        if Jt < best_J:
                            best_c, best_J, best_S2 = c, Jt, S2
                if best_c != int(b[p]) and best_S2 is not None:
                    b[p] = best_c
                    J = best_J
                    S = best_S2
                    improved = True
            if not improved:
                break
        return b, J


def commvol_boundaries(matrix, P: int, *, perm: np.ndarray | None = None,
                       alpha: float = 1.0, beta: float = 4.0,
                       sweeps: int = 3, growth: float = 1.5,
                       refine_passes: int = 3,
                       pattern=None) -> np.ndarray:
    """Non-uniform block cuts minimizing the engines' wire volumes.

    Two stages, both deterministic:

    1. **Prefix-balanced seed** — per-row cost ``c(r) = α·nnz(r) +
       β·cut(r)`` where ``cut(r)`` counts entries of (reordered) row r
       whose column lies outside r's current block (the rows that source
       halo traffic). Each of ``sweeps`` iterations recomputes the cut
       counts on the current boundaries and prefix-balances the
       cumulative cost into P equal parts, so cost-dense (hub) stretches
       get fewer rows per block.

    2. **Greedy cut descent** — from both the seed and the equal-rows
       cuts, each interior cut coordinate-descends on the engine-exact
       wire objective ``P·L + H_cyclic + H_matching`` (the per-device
       moved entries of the padded a2a and both neighbor schedules,
       computed from the same distinct per-pair counts
       ``build_dist_ell`` realizes). This is what actually *splits* hot
       structures across cuts — e.g. a hub region's corridor source
       halves its pair pad when a cut lands inside it.

    The equal-rows cuts participate as a candidate, so the result is
    **never worse** than ``balance="rows"`` under this objective.
    ``growth`` caps any block at ``ceil(D/P·growth)`` rows so the padded
    extent ``R = max block size`` stays bounded. ``pattern`` may carry a
    precomputed ``(indptr, cols)`` pair (original row order) to skip the
    pattern pass.
    """
    indptr, cols = pattern if pattern is not None else _pattern_csr(matrix)
    D = len(indptr) - 1
    if perm is not None:
        indptr, cols = _reordered_pattern(indptr, cols, perm)
    if P <= 1 or D <= P:
        return equal_cuts(D, P)
    nnz_row = np.diff(indptr).astype(np.float64)
    row_ids = np.repeat(np.arange(D, dtype=np.int64),
                        np.diff(indptr))
    cap = int(-(-D // P) * growth)
    equal = equal_cuts(D, P)
    bnds = equal
    for _ in range(sweeps):
        blk_row = np.searchsorted(bnds, row_ids, side="right") - 1
        blk_col = np.searchsorted(bnds, cols, side="right") - 1
        cut = np.bincount(row_ids, weights=(blk_col != blk_row),
                          minlength=D)
        cost = alpha * nnz_row + beta * cut
        cum = np.concatenate([[0.0], np.cumsum(cost)])
        targets = cum[-1] * np.arange(1, P, dtype=np.float64) / P
        inner = np.searchsorted(cum, targets, side="left")
        new = _normalize_boundaries(
            np.concatenate([[0], inner, [D]]), D, P, cap)
        if (new == bnds).all():
            break
        bnds = new
    # final per-row cost on the seed boundaries — drives the descent's
    # cost-quantile candidate positions
    blk_row = np.searchsorted(bnds, row_ids, side="right") - 1
    blk_col = np.searchsorted(bnds, cols, side="right") - 1
    cut = np.bincount(row_ids, weights=(blk_col != blk_row), minlength=D)
    obj = _WireObjective(indptr, cols, P, cost=alpha * nnz_row + beta * cut)
    J_equal, _ = obj.evaluate(equal)
    cand: list[tuple[tuple[int, int], np.ndarray]] = [(J_equal, equal)]
    starts = [equal] if (bnds == equal).all() else [bnds, equal]
    for start in starts:
        if refine_passes > 0:
            b_ref, J_ref = obj.refine(start, cap, passes=refine_passes)
            cand.append((J_ref, b_ref))
        else:
            cand.append((obj.evaluate(start)[0], start))
    J_best, best = min(cand, key=lambda t: t[0])
    # never-worse guard: keep the equal-rows cuts unless the descent
    # strictly reduced the wire objective (the Σpc² tie-break alone does
    # not justify a non-uniform map)
    return equal if J_best[0] >= J_equal[0] else best


# --------------------------------------------------------------------------
# orchestration
# --------------------------------------------------------------------------


def plan_rowmap(matrix, P: int, *, balance: str = "rows",
                reorder: str = "none", d_pad: int | None = None,
                block_multiple: int = 1, alpha: float = 1.0,
                beta: float = 4.0, sweeps: int = 3,
                growth: float = 1.5, refine_passes: int = 3,
                pattern=None, sstep: int = 1, plan_mode: str = "exact",
                sample_seed: int = 0,
                sample_fraction: float | None = None) -> RowMap:
    """Plan the row decomposition of ``matrix`` at ``P`` shards.

    ``balance`` ∈ :data:`SPMV_BALANCES` picks the block cuts (equal rows
    vs comm-volume prefix balancing); ``reorder`` ∈ :data:`SPMV_REORDERS`
    optionally applies the RCM permutation first. ``d_pad`` is honored
    only by the identity combination (the ``Partition`` convention);
    planned maps derive their own padding ``R = max block size``,
    rounded up to ``block_multiple`` so callers embedding the map into a
    larger device count (e.g. the dry-run's production mesh) get a
    divisible ``D_pad``. ``pattern`` may carry a precomputed
    ``(indptr, cols)`` pair so callers planning several maps of one
    matrix (the planner's balance × reorder axis) pay the pattern pass
    once. ``sstep`` stamps the ghost-zone depth the map is intended for
    (:attr:`RowMap.sstep`); the cut objective itself stays the depth-1
    wire volume (a proxy for the depth-s one — the stamp is what lets
    ``planner.comm_plan`` warn when a map is scored at a different
    depth, rather than silently under-counting).

    ``plan_mode`` ∈ :data:`PLAN_MODES` selects the exact full-pattern
    pass or the sampled one (``core/sketch.py``:
    ``coarsened_commvol_boundaries`` driven by ``sample_seed`` /
    ``sample_fraction``); ``auto`` resolves via
    :func:`partition_plan_default`. The sampled path supports
    ``balance`` only — ``reorder="rcm"`` needs the full adjacency and
    raises.

    Deterministic: same matrix, same arguments → the same map.
    """
    if int(sstep) < 1:
        raise ValueError(f"sstep must be >= 1, got {sstep}")
    if balance not in SPMV_BALANCES:
        raise ValueError(f"unknown balance {balance!r} "
                         f"(expected one of {SPMV_BALANCES})")
    if reorder not in SPMV_REORDERS:
        raise ValueError(f"unknown reorder {reorder!r} "
                         f"(expected one of {SPMV_REORDERS})")
    if plan_mode not in PLAN_MODES:
        raise ValueError(f"unknown plan_mode {plan_mode!r} "
                         f"(expected one of {PLAN_MODES})")
    D = matrix.shape[0] if isinstance(matrix, CSR) else matrix.D
    if plan_mode == "auto":
        plan_mode = ("exact" if partition_plan_default(matrix, P)
                     else "sampled")
    if balance == "rows" and reorder == "none":
        rm = RowMap.rows(D, P, d_pad)
        if block_multiple > 1 and rm.R % block_multiple:
            R = -(-rm.R // block_multiple) * block_multiple
            rm = RowMap.rows(D, P, R * P)
        rm.sstep = int(sstep)
        return rm
    if plan_mode == "sampled":
        if reorder != "none":
            raise ValueError(
                f"plan_mode='sampled' cannot plan reorder={reorder!r} — "
                f"the RCM pass needs the full adjacency; use "
                f"plan_mode='exact' below the gate or reorder='none'")
        from .sketch import coarsened_commvol_boundaries  # lazy: no cycle

        boundaries = coarsened_commvol_boundaries(
            matrix, P, alpha=alpha, beta=beta, fraction=sample_fraction,
            seed=sample_seed, sweeps=sweeps, growth=growth,
            refine_passes=refine_passes)
        R = max(int(np.diff(boundaries).max()) if P else 0, 1)
        R = -(-R // block_multiple) * block_multiple
        return RowMap(D=D, P=P, balance=balance, reorder=reorder,
                      perm=np.arange(D, dtype=np.int64),
                      boundaries=np.asarray(boundaries, dtype=np.int64),
                      R=R, sstep=int(sstep))
    if pattern is None:
        pattern = _pattern_csr(matrix)
    perm = (rcm_permutation(matrix, pattern=pattern) if reorder == "rcm"
            else np.arange(D, dtype=np.int64))
    if balance == "commvol":
        boundaries = commvol_boundaries(
            matrix, P, perm=perm if reorder == "rcm" else None,
            alpha=alpha, beta=beta, sweeps=sweeps, growth=growth,
            refine_passes=refine_passes, pattern=pattern)
    else:
        boundaries = equal_cuts(D, P)
    R = int(np.diff(boundaries).max()) if P else 0
    R = max(R, 1)
    R = -(-R // block_multiple) * block_multiple
    return RowMap(D=D, P=P, balance=balance, reorder=reorder, perm=perm,
                  boundaries=np.asarray(boundaries, dtype=np.int64), R=R,
                  sstep=int(sstep))

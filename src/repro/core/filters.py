"""Filter polynomial construction (Chebyshev window expansion).

The filter polynomial p(x) = sum_k mu_k T_k(x) approximates the indicator
function of the *search interval* mapped to x-space, optionally smoothed by
Jackson damping (the paper constructs filters per Pieper et al. [28]).
The polynomial is large inside the search interval and small outside of the
red boxes of Fig. 2.
"""
from __future__ import annotations

import dataclasses
import numpy as np

__all__ = ["window_coeffs", "jackson_damping", "FilterPoly", "build_filter", "degree_for"]


def jackson_damping(n: int) -> np.ndarray:
    """Jackson kernel coefficients g_0..g_n."""
    M = n + 1
    k = np.arange(M)
    return ((M - k + 1) * np.cos(np.pi * k / (M + 1))
            + np.sin(np.pi * k / (M + 1)) / np.tan(np.pi / (M + 1))) / (M + 1)


def window_coeffs(a: float, b: float, n: int) -> np.ndarray:
    """Chebyshev coefficients of the indicator of [a, b] ⊂ [-1, 1].

    mu_0 = (acos(a) - acos(b)) / pi
    mu_k = 2 (sin(k acos(a)) - sin(k acos(b))) / (k pi),  k >= 1
    """
    a = float(np.clip(a, -1.0, 1.0))
    b = float(np.clip(b, -1.0, 1.0))
    ta, tb = np.arccos(a), np.arccos(b)
    k = np.arange(1, n + 1)
    mu = np.empty(n + 1)
    mu[0] = (ta - tb) / np.pi
    mu[1:] = 2.0 * (np.sin(k * ta) - np.sin(k * tb)) / (k * np.pi)
    return mu


@dataclasses.dataclass(frozen=True)
class FilterPoly:
    mu: np.ndarray  # Chebyshev coefficients (damped)
    degree: int
    search: tuple[float, float]  # search interval in eigenvalue units
    inclusion: tuple[float, float]  # [λl, λr]

    def eval(self, lam: np.ndarray) -> np.ndarray:
        """Evaluate p(λ) on eigenvalue-axis points (for tests/plots)."""
        alpha = 2.0 / (self.inclusion[1] - self.inclusion[0])
        beta = (self.inclusion[0] + self.inclusion[1]) / (self.inclusion[0] - self.inclusion[1])
        x = np.clip(alpha * np.asarray(lam) + beta, -1.0, 1.0)
        t = np.arccos(x)
        return np.cos(np.outer(t, np.arange(len(self.mu)))) @ self.mu


def degree_for(search: tuple[float, float], inclusion: tuple[float, float],
               sharpness: float = 6.0, n_min: int = 20, n_max: int = 200_000,
               bucket: int = 32) -> int:
    """Heuristic filter degree: resolution ∝ 1 / (x-space half width).

    The Jackson-damped window has transition width ≈ pi/n in x-space; we
    demand the transition be a fraction of the window half-width. Degrees
    are bucketed (rounded up to a multiple of ``bucket``) to bound the
    number of distinct compiled Chebyshev loops in the FD driver.
    """
    lam_l, lam_r = inclusion
    alpha = 2.0 / (lam_r - lam_l)
    half_w = 0.5 * (search[1] - search[0]) * alpha  # x-space half width
    n = int(np.ceil(sharpness / max(half_w, 1e-12)))
    n = int(np.clip(n, n_min, n_max))
    return -(-n // bucket) * bucket


def build_filter(search: tuple[float, float], inclusion: tuple[float, float],
                 degree: int | None = None, damped: bool = True, **deg_kw) -> FilterPoly:
    lam_l, lam_r = inclusion
    alpha = 2.0 / (lam_r - lam_l)
    beta = (lam_l + lam_r) / (lam_l - lam_r)
    if degree is None:
        degree = degree_for(search, inclusion, **deg_kw)
    a = alpha * search[0] + beta
    b = alpha * search[1] + beta
    mu = window_coeffs(min(a, b), max(a, b), degree)
    if damped:
        mu = mu * jackson_damping(degree)
    return FilterPoly(mu=mu, degree=degree, search=tuple(search), inclusion=tuple(inclusion))

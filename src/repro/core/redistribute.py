"""Redistribution of vectors between the stack and panel layouts (paper §3.4).

The redistribution is the explicit price paid for running the Chebyshev
filter in a panel/pillar layout (vertical layer active) while
orthogonalization runs in the stack layout (horizontal layer only,
``layouts.py``). Each process row exchanges slices with itself only —
for "matching" layouts the collective never crosses the ``row`` axes::

      stack (4x2 mesh, N_col=2)            panel (4x2)
      +----------+                      +------+------+
      | p0       |  <- row 0 ->         | p0   | p1   |    all_to_all
      | p1       |                      |      |      |    within the
      +----------+                      +------+------+    pair {p0,p1}
      | p2       |  <- row 1 ->         | p2   | p3   |    (and {p2,p3},
      | p3       |                      |      |      |     ...): tiles
      +----------+                      +------+------+    of D/P x N_s/2
      |  ...     |                      |     ...     |
      +----------+                      +-------------+

Per device this moves exactly (N_s·D/P)(1 − 1/N_col) entries each way
(Eqs. 17–18, :func:`redistribution_volume`); the planner
(``planner.py``) charges two such exchanges per filter pass when ranking
panel/pillar candidates against the redistribution-free stack. Amortized
over a degree-n filter the cost is r/n Chebyshev iterations (Eqs. 19–21,
``perf_model.redistribution_factor``).

Two implementations (Alg. 1 steps 7 and 9):

  * ``explicit`` — the paper-faithful collective: one `all_to_all` along
    the vertical (``col``) mesh axes, tiled over the N_s axis on the way
    out and the D axis on the way back. For matching layouts communication
    stays strictly within a panel row (paper Fig. 6) — in mesh terms the
    collective never crosses the ``row`` axes. Volume per device is
    exactly (N_s·D/P)(1 − 1/N_col) entries (Eqs. 17–18).

  * ``gspmd`` — `lax.with_sharding_constraint` to the target sharding;
    XLA chooses the collective schedule. Used as a §Perf comparison point.

Shuffling for contiguous storage (paper Fig. 6 right) is XLA's problem on
TPU — the tiled all_to_all already produces the canonical layout.
"""
from __future__ import annotations

from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from .layouts import Layout

__all__ = ["make_redistribute", "redistribution_volume"]


def redistribution_volume(D: int, N_s: int, P_total: int, N_col: int, S_d: int) -> dict:
    """Exact communication volumes of one redistribution (Eqs. 17–18)."""
    per_row = N_s * D * (N_col - 1) / P_total * S_d
    total = N_s * D * (1 - 1.0 / N_col) * S_d
    return {"bytes_per_process_row": per_row, "bytes_total": total}


def make_redistribute(mesh: Mesh, stack_layout: Layout, panel_layout: Layout,
                      impl: str = "explicit"):
    """Return (to_panel(V), to_stack(V)) closures.

    ``stack_layout`` must shard D over all mesh axes with the panel's row
    axes leading, so that the stack slice index b = i_row * N_col + j_col
    gives the paper's "matching layouts" (communication only within panel
    rows).
    """
    col_axes = panel_layout.bundle_axes
    if not col_axes:  # N_col = 1: layouts coincide
        return (lambda V: V), (lambda V: V)
    if impl == "gspmd":
        s_stack = stack_layout.vec_sharding(mesh)
        s_panel = panel_layout.vec_sharding(mesh)

        def to_panel(V):
            return lax.with_sharding_constraint(V, s_panel)

        def to_stack(V):
            return lax.with_sharding_constraint(V, s_stack)

        return to_panel, to_stack

    if impl != "explicit":
        raise ValueError(f"unknown redistribution impl {impl!r}")

    stack_spec = stack_layout.vec_pspec()
    panel_spec = panel_layout.vec_pspec()

    def _to_panel_local(Vb):
        # Vb: stack-local [D/P, N_s] -> panel-local [D/N_row, N_s/N_col]
        return lax.all_to_all(Vb, col_axes, split_axis=1, concat_axis=0, tiled=True)

    def _to_stack_local(Vb):
        # Vb: panel-local [D/N_row, N_s/N_col] -> stack-local [D/P, N_s]
        return lax.all_to_all(Vb, col_axes, split_axis=0, concat_axis=1, tiled=True)

    to_panel = shard_map(_to_panel_local, mesh=mesh, in_specs=(stack_spec,),
                         out_specs=panel_spec, check_rep=False)
    to_stack = shard_map(_to_stack_local, mesh=mesh, in_specs=(panel_spec,),
                         out_specs=stack_spec, check_rep=False)
    return to_panel, to_stack

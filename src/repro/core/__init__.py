"""Core — the paper's contribution: two orthogonal layers of parallelism
for block eigensolvers (layouts, χ metrics, distributed SpMV, Chebyshev
filter, communication-avoiding orthogonalization, redistribution, the FD
driver, the analytic performance model, and the χ-driven layout planner
that turns the model into the control path)."""
from .layouts import Layout, make_solver_mesh, panel, pillar, stack
from .metrics import ChiMetrics, chi_bruteforce, chi_from_nvc, chi_metrics, chi_sweep
from .partition import (RowMap, SPMV_BALANCES, SPMV_REORDERS,
                        commvol_boundaries, plan_rowmap, rcm_permutation)
from .spmv import DistEll, Partition, build_dist_ell, make_fused_cheb_step, make_spmv
from .chebyshev import chebyshev_filter, kpm_moments, scale_params
from .filters import FilterPoly, build_filter, degree_for, jackson_damping, window_coeffs
from .orthogonalize import make_gram, make_svqb, make_tsqr
from .redistribute import make_redistribute, redistribution_volume
from .lanczos import lanczos_interval
from .filter_diag import FDConfig, FDResult, FDState, FilterDiag
from .planner import Candidate, Plan, SpmvCommPlan, comm_plan, plan_for_mesh, plan_layout
from . import perf_model

__all__ = [
    "Layout", "make_solver_mesh", "panel", "pillar", "stack",
    "ChiMetrics", "chi_bruteforce", "chi_from_nvc", "chi_metrics", "chi_sweep",
    "RowMap", "SPMV_BALANCES", "SPMV_REORDERS",
    "commvol_boundaries", "plan_rowmap", "rcm_permutation",
    "DistEll", "Partition", "build_dist_ell", "make_fused_cheb_step", "make_spmv",
    "chebyshev_filter", "kpm_moments", "scale_params",
    "FilterPoly", "build_filter", "degree_for", "jackson_damping", "window_coeffs",
    "make_gram", "make_svqb", "make_tsqr",
    "make_redistribute", "redistribution_volume",
    "lanczos_interval",
    "FDConfig", "FDResult", "FDState", "FilterDiag",
    "Candidate", "Plan", "SpmvCommPlan", "comm_plan", "plan_for_mesh", "plan_layout",
    "perf_model",
]

"""Analytic performance model — paper Eqs. (7), (11)–(23).

All quantities are per *process*; bandwidths in bytes/s. The model is
hardware-agnostic: feed Meggie constants (b_m = 53.3 GB/s, b_c ≈ 2.8 GB/s)
to reproduce the paper's tables, or TPU v5e constants (b_m = 819 GB/s,
b_c = 50 GB/s ICI — the same b_m/b_c ≈ 16 regime) to predict our target.

Beyond the paper: ``cheb_iter_time_overlap`` models the split-phase SpMV
engine (spmv.py ``overlap=True``), replacing Eq. 12's additive χ term with
``T = max(T_comm, T_local) + T_halo`` — communication hides behind local
work until χ·S_d/b_c exceeds the local memory time.

The χ argument of both iteration-time models is the *effective* χ of a
concrete comm engine — the vector entries it actually moves per device,
normalized like Eq. 8 (:func:`engine_chi`). The padded all_to_all engine
moves ``P·L`` entries (χ₃-scaled: every pair pays the global max pair
volume); the compressed neighbor-permute engine moves ``H = Σ_r L_r``,
the round-sum of its schedule's per-round pads (cyclic-shift or
greedy-matching rounds, ``spmv.neighbor_schedule``) — equivalently the
round-sum cost ``T_comm = Σ_r L_r·S_d/b_c`` of
:func:`schedule_comm_time`. Feeding each engine's exact wire volume
through the same Eq. 12 / overlap form is how the planner ranks the
{a2a, compressed-cyclic, compressed-matching} × {additive, overlap}
grid.

``MachineModel.fit`` calibrates b_c and κ from measured iteration times
(``dryrun --fit-machine``) so rankings can use the machine actually under
the workload instead of the hardcoded MEGGIE / TPU_V5E constants.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

__all__ = ["MachineModel", "MEGGIE", "TPU_V5E", "TPU_V5E_HIGHLAT",
           "engine_chi",
           "FUSED_KERNEL_KAPPA", "fused_kernel_machine",
           "schedule_comm_time",
           "cheb_iter_time", "cheb_iter_time_overlap", "overlap_speedup",
           "panel_speedup", "redistribution_factor", "amortized_speedup",
           "break_even_degree", "pillar_condition", "parallel_efficiency_bound",
           "save_machine", "load_machine"]


@dataclasses.dataclass(frozen=True)
class MachineModel:
    name: str
    b_m: float  # memory bandwidth per process [B/s]
    b_c: float  # effective inter-process communication bandwidth [B/s]
    kappa: float  # vector traffic factor (>=5 for the fused kernel)
    #: per-collective-round launch latency [s] — the α of the s-step cost
    #: model α·⌈n/s⌉ + β·bytes(s). Zero (the default) reproduces the
    #: pure-bandwidth Eq. 12 exactly; only a latency-bound model can make
    #: the planner prefer spmv_sstep > 1.
    alpha: float = 0.0

    @property
    def bc_over_bm(self) -> float:
        return self.b_c / self.b_m

    @classmethod
    def fit(cls, samples, *, b_m: float, name: str = "fitted",
            S_i: int = 4) -> "MachineModel":
        """Least-squares fit of (κ, b_c, α) to measured iteration times.

        Each sample is a dict with keys ``t`` (measured seconds of one
        fused Chebyshev iteration) plus the Eq. 12 inputs ``D, N_p, n_b,
        chi, n_nzr, S_d`` and optionally ``rounds`` (collective rounds
        launched during the measured iteration). Eq. 12 + the round
        latency term is linear in κ, 1/b_c and α once b_m is fixed (the
        paper fits the bandwidth part the same way, b_m from STREAM):

            t = scale·(S_d+S_i)·n_nzr/n_b / b_m  +  κ·scale·S_d/b_m
                +  (1/b_c)·scale·χ·S_d           +  α·rounds

        with ``scale = n_b·D/N_p``. At least one sample must have χ > 0
        to identify b_c; with only χ = 0 samples the fit is deliberately
        comm-free (κ-only calibration, e.g. single-device runs) and b_c
        stays +inf. When χ > 0 samples ARE present but the fitted comm
        coefficient comes out non-positive (noisy timings, e.g. fake CPU
        devices where communication is a memcpy), b_c is also left at
        +inf and a ``RuntimeWarning`` flags that the model prices
        communication as free — a ranking built on it would favor max-χ
        layouts.

        α is identifiable only when the ``rounds`` column is not
        collinear with the χ·bytes column — i.e. the samples include
        *small-message* cells whose round count varies while their wire
        bytes stay tiny (``dryrun --fit-machine`` emits such tiny-halo
        cells for exactly this purpose). Without any ``rounds`` data the
        latency column is dropped and α stays 0.
        """
        import warnings

        samples = list(samples)
        if not samples:
            raise ValueError("MachineModel.fit needs at least one sample")
        rows, rhs = [], []
        for s in samples:
            scale = s["n_b"] * s["D"] / s["N_p"]
            mat_term = scale * (s["S_d"] + S_i) * s["n_nzr"] / s["n_b"] / b_m
            rows.append([scale * s["S_d"] / b_m, scale * s["chi"] * s["S_d"],
                         float(s.get("rounds", 0.0))])
            rhs.append(s["t"] - mat_term)
        A = np.asarray(rows, dtype=np.float64)
        y = np.asarray(rhs, dtype=np.float64)
        has_comm = bool((A[:, 1] > 0).any())
        has_rounds = bool((A[:, 2] > 0).any())
        keep = [0] + ([1] if has_comm else []) + ([2] if has_rounds else [])
        sol_k, *_ = np.linalg.lstsq(A[:, keep], y, rcond=None)
        sol = np.zeros(3)
        sol[keep] = sol_k
        kappa = float(max(sol[0], 0.0))
        inv_bc = float(max(sol[1], 0.0)) if has_comm else 0.0
        alpha = float(max(sol[2], 0.0)) if has_rounds else 0.0
        b_c = (1.0 / inv_bc) if inv_bc > 0 else float("inf")
        if has_comm and inv_bc == 0.0:
            warnings.warn(
                "MachineModel.fit: chi > 0 samples present but the fitted "
                "comm coefficient is non-positive (timings do not scale "
                "with chi on this host); b_c left at +inf — the model "
                "treats communication as FREE and is unsuitable for "
                "comm-sensitive planning", RuntimeWarning, stacklevel=2)
        return cls(name=name, b_m=b_m, b_c=b_c, kappa=kappa, alpha=alpha)


#: Vector-traffic factor of the fused Chebyshev kernel (paper §3.2): the
#: fused SpMV+axpy step reads W1 once and streams W2/V, so κ = 5 instead
#: of the unfused engine's measured 6–7.3.
FUSED_KERNEL_KAPPA = 5.0


def fused_kernel_machine(m: MachineModel) -> MachineModel:
    """Machine model as seen by the fused Pallas kernel engines
    (``make_spmv(use_kernel=True)`` + ``make_fused_cheb_step``): the κ
    vector-traffic factor clamps to :data:`FUSED_KERNEL_KAPPA` — the
    planner scores kernel candidates with this model so the κ=5 fused
    term enters the ranking only where the kernel actually runs."""
    if m.kappa <= FUSED_KERNEL_KAPPA:
        return m
    return dataclasses.replace(m, name=m.name + "+krn",
                               kappa=FUSED_KERNEL_KAPPA)


MEGGIE = MachineModel("meggie-socket", b_m=53.3e9, b_c=2.82e9, kappa=7.3)
# v5e chip: 819 GB/s HBM; ICI ~50 GB/s per link. kappa=5 assumes the fused
# Pallas Chebyshev kernel reads W1 once and streams W2/V.
TPU_V5E = MachineModel("tpu-v5e-chip", b_m=819e9, b_c=50e9, kappa=5.0)
#: A latency-bound variant of the v5e model (e.g. DCN-bridged slices or
#: host-mediated collectives): 50 μs per collective round. This is the
#: regime where the s-step engine's α·⌈n/s⌉ round saving beats its
#: doubled-width β·bytes(s) cost — exposed as a builtin so the planner's
#: s>1 behavior is reproducible from the CLIs.
TPU_V5E_HIGHLAT = MachineModel("tpu-v5e-highlat", b_m=819e9, b_c=50e9,
                               kappa=5.0, alpha=50e-6)


def save_machine(m: MachineModel, path: str) -> None:
    """Persist a (fitted) machine model as JSON (``dryrun --fit-machine``)."""
    with open(path, "w") as f:
        json.dump({"name": m.name, "b_m": m.b_m, "b_c": m.b_c,
                   "kappa": m.kappa, "alpha": m.alpha}, f)


def load_machine(path: str) -> MachineModel:
    """Load a machine model saved by :func:`save_machine`."""
    with open(path) as f:
        d = json.load(f)
    return MachineModel(name=d["name"], b_m=float(d["b_m"]),
                        b_c=float(d["b_c"]), kappa=float(d["kappa"]),
                        alpha=float(d.get("alpha", 0.0)))


#: Built-in machine models addressable by name on the CLIs.
BUILTIN_MACHINES = {"tpu-v5e": TPU_V5E, "meggie": MEGGIE,
                    "tpu-v5e-highlat": TPU_V5E_HIGHLAT}


def resolve_machine(name_or_path: str) -> MachineModel:
    """CLI ``--machine`` resolution shared by solve and dryrun: a builtin
    name (:data:`BUILTIN_MACHINES`) or a JSON path written by
    ``dryrun --fit-machine`` / :func:`save_machine`."""
    m = BUILTIN_MACHINES.get(name_or_path)
    if m is not None:
        return m
    try:
        return load_machine(name_or_path)
    except FileNotFoundError:
        raise ValueError(
            f"--machine {name_or_path!r} is neither a builtin model "
            f"({sorted(BUILTIN_MACHINES)}) nor a readable JSON path "
            f"(save one with `dryrun --fit-machine`)") from None


def engine_chi(moved_entries_per_device: float, D: int, N_p: int) -> float:
    """Effective χ of a comm engine: the vector entries it physically moves
    per device and vector column, over the local block size D/N_p (the
    normalization of Eq. 8). The padded all_to_all moves ``P·L`` entries
    (χ₃-scaled); the compressed neighbor schedule moves ``H = Σ_k L_k``
    (χ₂-scaled). Feed the result to the ``chi`` argument of
    :func:`cheb_iter_time` / :func:`cheb_iter_time_overlap`."""
    if N_p <= 1:
        return 0.0
    return moved_entries_per_device * N_p / D


def schedule_comm_time(m: MachineModel, round_L, *, n_b: int,
                       S_d: int) -> float:
    """Round-sum communication cost of a neighbor-permute schedule:

        T_comm = Σ_r L_r · n_b · S_d / b_c

    where ``round_L[r]`` is round r's pad (the max scheduled pair volume,
    ``spmv.neighbor_schedule``) — each round's permute moves exactly
    ``L_r · n_b · S_d`` operand bytes per device. This is *identical* to
    the Eq. 12 comm term evaluated at the engine's effective χ:
    ``engine_chi(H, D, N_p) · S_d / b_c · (n_b · D / N_p)`` with
    ``H = Σ_r L_r`` — the planner's χ-based ranking and the round-sum
    view of the schedule cannot disagree (asserted in
    tests/test_spmv_schedule.py).
    """
    return float(sum(round_L)) * n_b * S_d / m.b_c


def cheb_iter_time(m: MachineModel, *, D: int, N_p: int, n_b: int, chi: float,
                   n_nzr: float, S_d: int, S_i: int = 4,
                   rounds: float = 0.0, work_factor: float = 1.0) -> float:
    """Eq. (12): execution time of one fused Chebyshev-filter iteration.

    ``rounds`` is the number of collective rounds launched per iteration
    (1 for the a2a engine, the schedule's round count for the compressed
    engine, ``⌈n/s⌉·rounds_per_exchange / n`` for the s-step engine) —
    each costs the machine's ``alpha`` launch latency on top of the
    bandwidth terms. ``work_factor`` scales the matrix-traffic term for
    engines that contract redundant rows (the s-step ghost-zone rows:
    ``1 + Σ_{d<s} ghosts(d) / (s·R)``). The defaults reproduce the
    pure Eq. 12 value bit-for-bit.
    """
    per_entry = ((S_d + S_i) * n_nzr * work_factor / n_b
                 + m.kappa * S_d) / m.b_m + chi * S_d / m.b_c
    return per_entry * n_b * D / N_p + m.alpha * rounds


def cheb_iter_time_overlap(m: MachineModel, *, D: int, N_p: int, n_b: int,
                           chi: float, n_nzr: float, S_d: int, S_i: int = 4,
                           halo_frac: float | None = None,
                           rounds: float = 0.0) -> float:
    """Overlap-aware variant of Eq. (12): ``T = max(T_comm, T_local) + T_halo``.

    The split-phase engine (``make_spmv(..., overlap=True)``) issues the
    halo all_to_all before the local contraction, so the additive χ term of
    Eq. 12 is replaced by a max: communication is free whenever
    ``T_comm <= T_local``. The halo contraction (``halo_frac`` of the
    nonzeros, reading the received buffer) cannot be hidden and stays
    additive.

    ``halo_frac`` defaults to ``min(1, chi / n_nzr)`` — every communicated
    vector entry feeds at least one halo nonzero (exact value available
    from ``DistEll.halo_nnz_fraction``). ``rounds`` adds the machine's
    per-round ``alpha`` launch latency (the collective must be *issued*
    before local work can hide its bytes, so the latency term stays
    additive).
    """
    if N_p <= 1 or chi <= 0:
        return cheb_iter_time(m, D=D, N_p=N_p, n_b=n_b, chi=0.0,
                              n_nzr=n_nzr, S_d=S_d, S_i=S_i)
    if halo_frac is None:
        halo_frac = min(1.0, chi / max(n_nzr, 1e-12))
    nnz_halo = halo_frac * n_nzr
    nnz_loc = n_nzr - nnz_halo
    scale = n_b * D / N_p
    t_comm = chi * S_d / m.b_c * scale
    # the kappa vector-traffic term belongs to the local phase (W1/W2/V
    # streaming happens while bytes are in flight)
    t_local = ((S_d + S_i) * nnz_loc / n_b + m.kappa * S_d) / m.b_m * scale
    t_halo = (S_d + S_i) * nnz_halo / n_b / m.b_m * scale
    return max(t_comm, t_local) + t_halo + m.alpha * rounds


def overlap_speedup(m: MachineModel, *, D: int, N_p: int, n_b: int, chi: float,
                    n_nzr: float, S_d: int, S_i: int = 4,
                    halo_frac: float | None = None) -> float:
    """Predicted additive/overlap time ratio (>1 when hiding the halo
    exchange behind local work pays; ->1 when χ ≈ 0 or comm dominates)."""
    t_add = cheb_iter_time(m, D=D, N_p=N_p, n_b=n_b, chi=chi, n_nzr=n_nzr,
                           S_d=S_d, S_i=S_i)
    t_ov = cheb_iter_time_overlap(m, D=D, N_p=N_p, n_b=n_b, chi=chi,
                                  n_nzr=n_nzr, S_d=S_d, S_i=S_i,
                                  halo_frac=halo_frac)
    return t_add / t_ov


def parallel_efficiency_bound(m: MachineModel, chi3: float) -> float:
    """Eq. (11): Π ≲ min{1, χ₃⁻¹ b_c/b_m}."""
    if chi3 <= 0:
        return 1.0
    return min(1.0, m.bc_over_bm / chi3)


def panel_speedup(m: MachineModel, chi_P: float, chi_panel: float) -> float:
    """Eq. (15): s = (κ b_c/b_m + χ[P]) / (κ b_c/b_m + χ[P/N_col])."""
    k = m.kappa * m.bc_over_bm
    return (k + chi_P) / (k + chi_panel)


def layout_speedup_full(m: MachineModel, *, chi_P: float, chi_panel: float,
                        n_nzr: float, S_d: int, n_b_stack: int, n_col: int,
                        S_i: int = 4) -> float:
    """Panel speedup from the *full* Eq. 12 (keeps the matrix-traffic term
    that Eq. 15 drops). At pillar layouts the per-column block shrinks to
    n_b/N_col, so the matrix term re-enters — this reproduces the paper's
    *measured* Table 3 values (e.g. Hubbard14 pillar s≈5, not the Eq.-15
    asymptote ≈9)."""

    def per_entry(n_b, chi):
        return ((S_d + S_i) * n_nzr / max(n_b, 1) + m.kappa * S_d) / m.b_m \
            + chi * S_d / m.b_c

    return per_entry(n_b_stack, chi_P) / per_entry(n_b_stack / n_col, chi_panel)


def redistribution_factor(m: MachineModel, N_col: int, chi_panel: float) -> float:
    """Eq. (21): r = (1 - 1/N_col) / (κ b_c/b_m + χ[P/N_col]).

    One redistribution costs r Chebyshev iterations in the panel layout.
    """
    return (1.0 - 1.0 / N_col) / (m.kappa * m.bc_over_bm + chi_panel)


def amortized_speedup(s: float, r: float, n: int) -> float:
    """Eq. (19): S = s·n / (n + 2r), filter degree n."""
    return s * n / (n + 2.0 * r)


def break_even_degree(s: float, r: float) -> float:
    """Eq. (20): n* = 2r / (s - 1); panel pays off for n > n*."""
    if s <= 1.0:
        return float("inf")
    return 2.0 * r / (s - 1.0)


def pillar_condition(chi_P: float) -> float:
    """Eq. (23): pillar pays off for n >= 2/χ[P]; always if χ[P] >= 2."""
    if chi_P <= 0:
        return float("inf")
    return 2.0 / chi_P

"""Analytic performance model — paper Eqs. (7), (11)–(23).

All quantities are per *process*; bandwidths in bytes/s. The model is
hardware-agnostic: feed Meggie constants (b_m = 53.3 GB/s, b_c ≈ 2.8 GB/s)
to reproduce the paper's tables, or TPU v5e constants (b_m = 819 GB/s,
b_c = 50 GB/s ICI — the same b_m/b_c ≈ 16 regime) to predict our target.

Beyond the paper: ``cheb_iter_time_overlap`` models the split-phase SpMV
engine (spmv.py ``overlap=True``), replacing Eq. 12's additive χ term with
``T = max(T_comm, T_local) + T_halo`` — communication hides behind local
work until χ·S_d/b_c exceeds the local memory time.
"""
from __future__ import annotations

import dataclasses

__all__ = ["MachineModel", "MEGGIE", "TPU_V5E", "cheb_iter_time",
           "cheb_iter_time_overlap", "overlap_speedup",
           "panel_speedup", "redistribution_factor", "amortized_speedup",
           "break_even_degree", "pillar_condition", "parallel_efficiency_bound"]


@dataclasses.dataclass(frozen=True)
class MachineModel:
    name: str
    b_m: float  # memory bandwidth per process [B/s]
    b_c: float  # effective inter-process communication bandwidth [B/s]
    kappa: float  # vector traffic factor (>=5 for the fused kernel)

    @property
    def bc_over_bm(self) -> float:
        return self.b_c / self.b_m


MEGGIE = MachineModel("meggie-socket", b_m=53.3e9, b_c=2.82e9, kappa=7.3)
# v5e chip: 819 GB/s HBM; ICI ~50 GB/s per link. kappa=5 assumes the fused
# Pallas Chebyshev kernel reads W1 once and streams W2/V.
TPU_V5E = MachineModel("tpu-v5e-chip", b_m=819e9, b_c=50e9, kappa=5.0)


def cheb_iter_time(m: MachineModel, *, D: int, N_p: int, n_b: int, chi: float,
                   n_nzr: float, S_d: int, S_i: int = 4) -> float:
    """Eq. (12): execution time of one fused Chebyshev-filter iteration."""
    per_entry = ((S_d + S_i) * n_nzr / n_b + m.kappa * S_d) / m.b_m + chi * S_d / m.b_c
    return per_entry * n_b * D / N_p


def cheb_iter_time_overlap(m: MachineModel, *, D: int, N_p: int, n_b: int,
                           chi: float, n_nzr: float, S_d: int, S_i: int = 4,
                           halo_frac: float | None = None) -> float:
    """Overlap-aware variant of Eq. (12): ``T = max(T_comm, T_local) + T_halo``.

    The split-phase engine (``make_spmv(..., overlap=True)``) issues the
    halo all_to_all before the local contraction, so the additive χ term of
    Eq. 12 is replaced by a max: communication is free whenever
    ``T_comm <= T_local``. The halo contraction (``halo_frac`` of the
    nonzeros, reading the received buffer) cannot be hidden and stays
    additive.

    ``halo_frac`` defaults to ``min(1, chi / n_nzr)`` — every communicated
    vector entry feeds at least one halo nonzero (exact value available
    from ``DistEll.halo_nnz_fraction``).
    """
    if N_p <= 1 or chi <= 0:
        return cheb_iter_time(m, D=D, N_p=N_p, n_b=n_b, chi=0.0,
                              n_nzr=n_nzr, S_d=S_d, S_i=S_i)
    if halo_frac is None:
        halo_frac = min(1.0, chi / max(n_nzr, 1e-12))
    nnz_halo = halo_frac * n_nzr
    nnz_loc = n_nzr - nnz_halo
    scale = n_b * D / N_p
    t_comm = chi * S_d / m.b_c * scale
    # the kappa vector-traffic term belongs to the local phase (W1/W2/V
    # streaming happens while bytes are in flight)
    t_local = ((S_d + S_i) * nnz_loc / n_b + m.kappa * S_d) / m.b_m * scale
    t_halo = (S_d + S_i) * nnz_halo / n_b / m.b_m * scale
    return max(t_comm, t_local) + t_halo


def overlap_speedup(m: MachineModel, *, D: int, N_p: int, n_b: int, chi: float,
                    n_nzr: float, S_d: int, S_i: int = 4,
                    halo_frac: float | None = None) -> float:
    """Predicted additive/overlap time ratio (>1 when hiding the halo
    exchange behind local work pays; ->1 when χ ≈ 0 or comm dominates)."""
    t_add = cheb_iter_time(m, D=D, N_p=N_p, n_b=n_b, chi=chi, n_nzr=n_nzr,
                           S_d=S_d, S_i=S_i)
    t_ov = cheb_iter_time_overlap(m, D=D, N_p=N_p, n_b=n_b, chi=chi,
                                  n_nzr=n_nzr, S_d=S_d, S_i=S_i,
                                  halo_frac=halo_frac)
    return t_add / t_ov


def parallel_efficiency_bound(m: MachineModel, chi3: float) -> float:
    """Eq. (11): Π ≲ min{1, χ₃⁻¹ b_c/b_m}."""
    if chi3 <= 0:
        return 1.0
    return min(1.0, m.bc_over_bm / chi3)


def panel_speedup(m: MachineModel, chi_P: float, chi_panel: float) -> float:
    """Eq. (15): s = (κ b_c/b_m + χ[P]) / (κ b_c/b_m + χ[P/N_col])."""
    k = m.kappa * m.bc_over_bm
    return (k + chi_P) / (k + chi_panel)


def layout_speedup_full(m: MachineModel, *, chi_P: float, chi_panel: float,
                        n_nzr: float, S_d: int, n_b_stack: int, n_col: int,
                        S_i: int = 4) -> float:
    """Panel speedup from the *full* Eq. 12 (keeps the matrix-traffic term
    that Eq. 15 drops). At pillar layouts the per-column block shrinks to
    n_b/N_col, so the matrix term re-enters — this reproduces the paper's
    *measured* Table 3 values (e.g. Hubbard14 pillar s≈5, not the Eq.-15
    asymptote ≈9)."""

    def per_entry(n_b, chi):
        return ((S_d + S_i) * n_nzr / max(n_b, 1) + m.kappa * S_d) / m.b_m \
            + chi * S_d / m.b_c

    return per_entry(n_b_stack, chi_P) / per_entry(n_b_stack / n_col, chi_panel)


def redistribution_factor(m: MachineModel, N_col: int, chi_panel: float) -> float:
    """Eq. (21): r = (1 - 1/N_col) / (κ b_c/b_m + χ[P/N_col]).

    One redistribution costs r Chebyshev iterations in the panel layout.
    """
    return (1.0 - 1.0 / N_col) / (m.kappa * m.bc_over_bm + chi_panel)


def amortized_speedup(s: float, r: float, n: int) -> float:
    """Eq. (19): S = s·n / (n + 2r), filter degree n."""
    return s * n / (n + 2.0 * r)


def break_even_degree(s: float, r: float) -> float:
    """Eq. (20): n* = 2r / (s - 1); panel pays off for n > n*."""
    if s <= 1.0:
        return float("inf")
    return 2.0 * r / (s - 1.0)


def pillar_condition(chi_P: float) -> float:
    """Eq. (23): pillar pays off for n >= 2/χ[P]; always if χ[P] >= 2."""
    if chi_P <= 0:
        return float("inf")
    return 2.0 / chi_P

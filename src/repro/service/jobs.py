"""Resumable FilterDiag jobs: FDState <-> checkpoint bridge + job driver.

``FilterDiag.step`` advances an explicit :class:`~repro.core.filter_diag.FDState`
one outer iteration at a time; this module makes that state durable. A
state is split into

  * a **pytree** — the search block ``V`` (the only device array the
    loop carries; every other per-iteration quantity is recomputed from
    it), saved as a leaf by ``checkpoint.save``,
  * a **manifest extra** — the host-side fields (Lanczos interval,
    iteration counter, SpMV/redistribution tallies, history, and the
    finished result, if any) as plain JSON. Floats survive the JSON
    round trip exactly (repr round-trip), so a restored solve continues
    on bit-identical host data.

The RowMap a planned partition solved on is *not* checkpointed: the job
is reconstructed from its config (matrix + plan) and ``plan_rowmap`` is
deterministic, so the rebuilt solver carries the identical map; the
manifest records the map's fingerprint (D/P/R + boundary/perm hashes)
and ``unpack_state`` refuses to resume onto a mismatched one — a solve
checkpointed under one row decomposition must never silently continue
under another.

:class:`FilterDiagJob` implements the job protocol the runtime
supervisor drives (``runtime/supervisor.py`` ``run_job``): template /
init / step / pack / unpack / done. A job killed mid-Chebyshev-sweep
resumes from the last committed iteration boundary and converges to the
same eigenpairs (tests/test_service.py injects exactly that fault).
"""
from __future__ import annotations

import hashlib
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from ..core.filter_diag import FDResult, FDState, FilterDiag

__all__ = ["rowmap_fingerprint", "pack_state", "unpack_state",
           "state_template", "FilterDiagJob"]


def rowmap_fingerprint(rowmap) -> str | None:
    """Stable fingerprint of a planned row decomposition (None for the
    equal-rows identity partition)."""
    if rowmap is None:
        return None
    h = hashlib.sha256()
    h.update(f"{rowmap.D}/{rowmap.P}/{rowmap.R}/{rowmap.sstep}".encode())
    h.update(np.ascontiguousarray(rowmap.perm, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(rowmap.boundaries,
                                  dtype=np.int64).tobytes())
    return h.hexdigest()[:16]


def _result_to_json(r: FDResult | None):
    if r is None:
        return None
    return {
        "eigenvalues": [float(x) for x in np.asarray(r.eigenvalues)],
        "residuals": [float(x) for x in np.asarray(r.residuals)],
        "n_converged": int(r.n_converged), "iterations": int(r.iterations),
        "total_spmvs": int(r.total_spmvs),
        "redistributions": int(r.redistributions),
        "wall_time": float(r.wall_time), "redist_time": float(r.redist_time),
        "history": r.history,
    }


def _result_from_json(j) -> FDResult | None:
    if j is None:
        return None
    return FDResult(
        eigenvalues=np.asarray(j["eigenvalues"], dtype=np.float64),
        residuals=np.asarray(j["residuals"], dtype=np.float64),
        n_converged=int(j["n_converged"]), iterations=int(j["iterations"]),
        total_spmvs=int(j["total_spmvs"]),
        redistributions=int(j["redistributions"]),
        wall_time=float(j["wall_time"]), redist_time=float(j["redist_time"]),
        history=_history_from_json(j["history"]),
    )


def _history_from_json(hist) -> list:
    # JSON turns the search tuple into a list; restore the native shape
    return [dict(h, search=tuple(h["search"])) for h in hist]


def pack_state(state: FDState, fd: FilterDiag) -> tuple[dict, dict]:
    """(pytree, extra) of a state at an iteration boundary."""
    assert state.pending is None, \
        "checkpoint only at iteration boundaries (pending filter unset)"
    extra = {
        "lam": [float(state.lam[0]), float(state.lam[1])],
        "iteration": int(state.iteration),
        "total_spmvs": int(state.total_spmvs),
        "redistributions": int(state.redistributions),
        "redist_time": float(state.redist_time),
        "wall_time": float(state.wall_time),
        "history": state.history,
        "done": bool(state.done),
        "result": _result_to_json(state.result),
        "rowmap": rowmap_fingerprint(fd.rowmap),
    }
    return {"V": state.V}, extra


def unpack_state(tree: dict, extra: dict, fd: FilterDiag) -> FDState:
    """Rebuild an FDState from a restored (pytree, extra) pair, verifying
    the solver's row decomposition matches the one checkpointed."""
    saved = extra.get("rowmap")
    here = rowmap_fingerprint(fd.rowmap)
    if saved != here:
        raise ValueError(f"checkpointed rowmap {saved!r} does not match the "
                         f"solver's {here!r} — a solve must resume on the "
                         f"row decomposition it was planned with")
    return FDState(
        V=tree["V"], lam=tuple(extra["lam"]),
        iteration=int(extra["iteration"]),
        total_spmvs=int(extra["total_spmvs"]),
        redistributions=int(extra["redistributions"]),
        redist_time=float(extra["redist_time"]),
        wall_time=float(extra["wall_time"]),
        history=_history_from_json(extra["history"]),
        done=bool(extra["done"]),
        result=_result_from_json(extra.get("result")),
    )


def state_template(fd: FilterDiag, n_search: int | None = None) -> dict:
    """Zero pytree with the checkpointed structure/shapes — what
    ``checkpoint.restore`` needs to re-materialize a state without
    running the (expensive) Lanczos init."""
    n_s = n_search if n_search is not None else fd.cfg.n_search
    return {"V": jnp.zeros((fd.D_pad, n_s), dtype=fd.dtype)}


class FilterDiagJob:
    """One resumable solve: the job protocol ``Supervisor.run_job`` drives.

    ``init`` runs Lanczos + the random search draw; ``step`` is one outer
    FD iteration; ``pack``/``unpack`` bridge to ``checkpoint/``. The
    V-leaf spec is the stack layout's PartitionSpec so an elastic restore
    re-shards straight onto the (possibly different) mesh.
    """

    def __init__(self, fd: FilterDiag, key=None, verbose: bool = False):
        self.fd = fd
        self.key = key
        self.verbose = verbose
        self.mesh = fd.mesh
        self.specs = {"V": fd.stack_layout.vec_pspec()}

    def template(self) -> dict:
        return state_template(self.fd)

    def init(self) -> FDState:
        state = self.fd.init_state(self.key)
        return state

    def step(self, state: FDState) -> FDState:
        return self.fd.step(state, verbose=self.verbose)

    def done(self, state: FDState) -> bool:
        return state.done

    def step_index(self, state: FDState) -> int:
        return state.iteration

    def pack(self, state: FDState) -> tuple[dict, dict]:
        return pack_state(state, self.fd)

    def unpack(self, tree: dict, extra: dict) -> FDState:
        state = unpack_state(tree, extra, self.fd)
        # restored leaves may arrive replicated — pin the stack sharding
        state.V = jnp.asarray(state.V)
        if self.mesh is not None:
            state.V = jax.device_put(
                state.V, NamedSharding(self.mesh, self.specs["V"]))
        return state

    def result(self, state: FDState) -> Any:
        return state.result

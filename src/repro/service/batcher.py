"""Request queue + batcher: the vertical layer as a batching dimension.

Every SpMV/filter op of the engine grid acts column-wise independently —
``spmv(V)[:, j]`` depends only on ``V[:, j]``, the Chebyshev recurrence
is elementwise over columns, and the stack↔panel redistribution only
*moves* values. So vectors from different filter-diagonalization
requests can share one panel: the batcher concatenates the pending
filter blocks of compatible requests into one ``[D_pad, Σ n_b]`` panel,
runs ONE redistribute + Chebyshev sweep + redistribute, and demuxes
per-request column slices — bit-identically to serving each request
alone (tests/test_service.py asserts exact equality on the 8-device
mesh).

Compatibility = same ``pattern_hash`` (same operator), same engine plan
(the winning :class:`~repro.core.planner.Candidate` cell), same
``n_search``/dtype. Requests differ in target, tolerance, n_target and
seed: per-request **orthogonalization and Ritz extraction run on the
request's own slice** (the same ops a solo solve runs — batching never
mixes columns across requests through a Gram matrix), and per-request
filter polynomials ride the shared sweep as **per-column μ columns**,
zero-padded to the longest degree. Padding is exact: a zero coefficient
contributes ``Y + 0·T_k``, which is bitwise ``Y``, so a request batched
with a higher-degree neighbour computes exactly its solo filter.

The Lanczos inclusion interval is a property of the *operator*, not the
request, so the group computes it once from the service seed — which is
also what makes a request's result independent of its co-batched
neighbours. s-step plans (``spmv_sstep > 1``) fall back to per-request
filter application (the s-step applier's μ-regrouping is 1-D); analyze
steps still share the solver.

:class:`BatchedJob` wraps a group in the resumable-job protocol, so a
whole batch checkpoints/resumes through ``runtime/supervisor.py`` like a
solo job. :class:`EigenService` is the front end: submit requests,
``drain()`` plans each distinct pattern once (through the persistent
plan cache), groups compatible requests, and returns per-request
:class:`~repro.core.filter_diag.FDResult`.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from ..core import make_solver_mesh
from ..core import perf_model as pm
from ..core.chebyshev import scale_params
from ..core.filter_diag import FDConfig, FDResult, FDState, FilterDiag
from ..core.lanczos import lanczos_interval
from ..core.planner import Candidate
from ..runtime import StragglerWatchdog, Supervisor, SupervisorConfig
from ..matrices import get_family
from .jobs import pack_state, state_template, unpack_state
from .plan_cache import PlanCache, cached_plan_layout, pattern_hash

__all__ = ["SolveRequest", "request_compat_key", "BatchedJob",
           "EigenService"]


@dataclasses.dataclass
class SolveRequest:
    """One tenant's eigenproblem: which operator, which eigenpairs.

    ``family``/``params`` name the matrix (``matrices.get_family``); a
    prebuilt matrix/CSR can be passed via ``matrix`` instead. Engine
    choice is NOT part of a request — the service plans it (or takes the
    cached plan) per pattern.
    """

    req_id: str
    family: str | None = None
    params: dict = dataclasses.field(default_factory=dict)
    n_target: int = 4
    n_search: int = 16
    target: float = 0.0
    tol: float = 1e-9
    max_iters: int = 40
    seed: int = 7
    matrix: Any = None

    def resolve_matrix(self):
        if self.matrix is not None:
            return self.matrix
        if self.family is None:
            raise ValueError(f"request {self.req_id}: neither family nor "
                             f"matrix given")
        return get_family(self.family, **self.params)


def request_compat_key(phash: str, best: Candidate, n_search: int,
                       dtype: str) -> tuple:
    """Requests sharing this key may share one panel: same operator
    pattern, same engine cell (every planned axis), same bundle width
    and dtype."""
    return (phash, best.layout, best.n_row, best.n_col, best.overlap,
            best.comm, best.schedule, best.balance, best.reorder,
            best.kernel, best.sstep, n_search, dtype)


@dataclasses.dataclass
class _Entry:
    """Per-request slot inside a batch group."""

    req: SolveRequest
    cfg: FDConfig
    state: FDState | None = None


class BatchedJob:
    """A compatible request group as one resumable job.

    State is the dict of per-request :class:`FDState`; one job ``step``
    advances every active request by one outer iteration — per-request
    analyze on its own slice, then a single shared filter sweep over the
    concatenated pending blocks. Implements the supervisor's job
    protocol (template/init/step/done/pack/unpack), so fault injection
    and resume work identically to solo jobs.
    """

    def __init__(self, fd: FilterDiag, requests: list[SolveRequest],
                 service_seed: int = 0, verbose: bool = False):
        self.fd = fd
        self.verbose = verbose
        self.service_seed = service_seed
        self.entries = [
            _Entry(req=r, cfg=dataclasses.replace(
                fd.cfg, n_target=r.n_target, target=r.target, tol=r.tol,
                max_iters=r.max_iters, seed=r.seed))
            for r in requests
        ]
        self.mesh = fd.mesh
        n = fd.cfg.n_search
        self.specs = {e.req.req_id: {"V": fd.stack_layout.vec_pspec()}
                      for e in self.entries}
        self._template = {e.req.req_id: state_template(fd, n)
                          for e in self.entries}

    # ---------------------------------------------------- job protocol --
    def template(self) -> dict:
        return self._template

    def init(self) -> dict:
        """Group Lanczos (an operator property, from the service seed —
        NOT the request seeds, so results are independent of batch
        composition) + per-request search draws from the request seeds,
        exactly the split a solo ``FilterDiag.init_state`` performs."""
        fd = self.fd
        k0 = jax.random.split(jax.random.PRNGKey(self.service_seed))[0]
        t0 = time.perf_counter()
        lam = lanczos_interval(
            fd.spmv_stack, fd.D, fd.D_pad, fd.dtype, k0,
            fd.cfg.lanczos_steps,
            mask=(None if fd.rowmap is None
                  else jnp.asarray(fd.rowmap.valid_mask())),
        )
        dt = time.perf_counter() - t0
        for e in self.entries:
            k1 = jax.random.split(jax.random.PRNGKey(e.cfg.seed))[1]
            e.state = FDState(V=fd.random_search_vectors(k1), lam=lam,
                              total_spmvs=fd.cfg.lanczos_steps,
                              wall_time=dt)
        return {e.req.req_id: e.state for e in self.entries}

    def step(self, states: dict) -> dict:
        fd = self.fd
        for e in self.entries:
            e.state = states[e.req.req_id]
        active = [e for e in self.entries if not e.state.done]
        # per-request analyze on the request's own slice — the identical
        # ops (tsqr, ritz, host logic) a solo solve runs on that block
        for e in active:
            e.state = fd.step_analyze(e.state, cfg=e.cfg,
                                      verbose=self.verbose)
        pend = [e for e in active if not e.state.done]
        if pend:
            if fd.cheb_sstep is not None:
                # s-step applier regroups μ 1-D — apply per request
                for e in pend:
                    e.state = fd.step_filter(e.state, cfg=e.cfg)
            else:
                self._filter_batched(pend)
        return {e.req.req_id: e.state for e in self.entries}

    def _filter_batched(self, pend: list[_Entry]):
        """One shared Chebyshev sweep over the concatenated pending
        blocks, per-column μ zero-padded to the longest degree."""
        fd = self.fd
        lam = pend[0].state.lam
        alpha, beta = scale_params(*lam)
        widths = [e.cfg.n_search for e in pend]
        degrees = [e.state.pending[1] for e in pend]
        n_max = max(degrees)
        Mu = np.zeros((n_max + 1, sum(widths)))
        col = 0
        for e, w in zip(pend, widths):
            mu_h, deg = e.state.pending
            Mu[: deg + 1, col: col + w] = np.asarray(mu_h)[:, None]
            col += w
        V = jnp.concatenate([e.state.V for e in pend], axis=1)
        t0 = time.perf_counter()
        redist = 0
        redist_time = 0.0
        if fd.N_col > 1:
            V = fd.to_panel(V)
            jax.block_until_ready(V)
            redist += 1
            redist_time += time.perf_counter() - t0
        V = fd._cheb(n_max)(V, jnp.asarray(Mu), alpha, beta)
        t0 = time.perf_counter()
        if fd.N_col > 1:
            V = fd.to_stack(V)
            jax.block_until_ready(V)
            redist += 1
            redist_time += time.perf_counter() - t0
        col = 0
        for e, w, deg in zip(pend, widths, degrees):
            st = e.state
            st.V = V[:, col: col + w]
            col += w
            st.pending = None
            st.iteration += 1
            # solo accounting: the request's own degree x its own width
            st.total_spmvs += deg * w
            st.redistributions += redist
            st.redist_time += redist_time

    def done(self, states: dict) -> bool:
        return all(s.done for s in states.values())

    def step_index(self, states: dict) -> int:
        return max(s.iteration for s in states.values())

    def pack(self, states: dict) -> tuple[dict, dict]:
        trees, extras = {}, {}
        for rid, s in states.items():
            trees[rid], extras[rid] = pack_state(s, self.fd)
        return trees, {"requests": extras}

    def unpack(self, trees: dict, extra: dict) -> dict:
        out = {}
        for e in self.entries:
            rid = e.req.req_id
            st = unpack_state(trees[rid], extra["requests"][rid], self.fd)
            st.V = jnp.asarray(st.V)
            e.state = st
            out[rid] = st
        return out

    def results(self, states: dict) -> dict[str, FDResult]:
        return {rid: s.result for rid, s in states.items()}


class EigenService:
    """Multi-tenant front end: submit requests, drain to results.

    ``drain()`` resolves each distinct sparsity pattern once, plans it
    through the persistent plan cache (repeat patterns skip the planner),
    groups requests by :func:`request_compat_key`, and runs each group as
    one :class:`BatchedJob` — supervised with checkpoint/resume when a
    checkpoint root is given, plain loop otherwise.
    """

    def __init__(self, *, plan_cache: PlanCache | None = None,
                 machine: pm.MachineModel | None = None,
                 ckpt_root: str | None = None,
                 service_seed: int = 0,
                 supervisor_cfg: SupervisorConfig | None = None,
                 verbose: bool = False):
        self.plan_cache = plan_cache
        self.machine = machine if machine is not None else pm.TPU_V5E
        self.ckpt_root = ckpt_root
        self.service_seed = service_seed
        self.supervisor_cfg = supervisor_cfg or SupervisorConfig(
            checkpoint_interval=1, keep_checkpoints=3)
        self.verbose = verbose
        self.queue: list[SolveRequest] = []
        self.plans: dict[tuple, Any] = {}   # (pattern hash, n_search) -> Plan
        self.cache_hits = 0

    def submit(self, req: SolveRequest) -> str:
        if any(r.req_id == req.req_id for r in self.queue):
            raise ValueError(f"duplicate request id {req.req_id!r}")
        self.queue.append(req)
        return req.req_id

    # ------------------------------------------------------------------
    def _plan(self, matrix, n_devices: int, n_search: int):
        phash = pattern_hash(matrix)
        pkey = (phash, n_search)  # the chosen n_col must divide n_search
        if pkey not in self.plans:
            D = matrix.shape[0] if hasattr(matrix, "shape") else matrix.D
            plan, hit = cached_plan_layout(
                matrix, n_devices, n_search=n_search, cache=self.plan_cache,
                machine=self.machine, d_pad=-(-D // n_devices) * n_devices)
            self.plans[pkey] = plan
            self.cache_hits += int(hit)
        return phash, self.plans[pkey]

    def drain(self, fault_hook=None) -> dict[str, FDResult]:
        """Solve every queued request; returns ``{req_id: FDResult}``."""
        n_devices = len(jax.devices())
        groups: dict[tuple, list] = {}
        mats: dict[tuple, Any] = {}
        plans: dict[tuple, Candidate] = {}
        for req in self.queue:
            mat = req.resolve_matrix()
            phash, plan = self._plan(mat, n_devices, req.n_search)
            best = plan.best
            ckey = request_compat_key(phash, best, req.n_search, "float64")
            groups.setdefault(ckey, []).append(req)
            mats.setdefault(ckey, mat)
            plans.setdefault(ckey, best)
        self.queue = []
        results: dict[str, FDResult] = {}
        for i, (ckey, reqs) in enumerate(groups.items()):
            results.update(self._run_group(
                mats[ckey], plans[ckey], reqs, group_idx=i,
                fault_hook=fault_hook))
        return results

    def _run_group(self, mat, best: Candidate, reqs: list[SolveRequest],
                   group_idx: int, fault_hook=None) -> dict[str, FDResult]:
        # the chosen (n_row x n_col) split realizes the planned layout —
        # same convention as launch/solve.py's auto path
        cfg = FDConfig(
            n_search=reqs[0].n_search, layout="panel",
            spmv_overlap=best.overlap, spmv_comm=best.comm,
            spmv_schedule=best.schedule, spmv_balance=best.balance,
            spmv_reorder=best.reorder, spmv_kernel=best.kernel,
            spmv_sstep=best.sstep, seed=self.service_seed)
        mesh = make_solver_mesh(best.n_row, best.n_col)
        with mesh:
            fd = FilterDiag(mat, mesh, cfg, rowmap=best.rowmap)
            job = BatchedJob(fd, reqs, service_seed=self.service_seed,
                             verbose=self.verbose)
            if self.ckpt_root is not None:
                sup = Supervisor(
                    os.path.join(self.ckpt_root, f"group_{group_idx:03d}"),
                    self.supervisor_cfg)
                states = sup.run_job(job, fault_hook=fault_hook,
                                     watchdog=StragglerWatchdog())
            else:
                states = job.init()
                while not job.done(states):
                    states = job.step(states)
            return job.results(states)

"""Eigensolve-as-a-service: plan cache, request batching, resumable jobs.

The paper's vertical layer — bundles of search vectors distributed over
process columns — is exactly a request-batching dimension: columns are
independent through every SpMV/filter op, so vectors from *different*
filter-diagonalization requests can share one panel. This package turns
the one-shot :class:`~repro.core.filter_diag.FilterDiag` solver into a
schedulable, cacheable, resumable service:

  * ``plan_cache``  — persistent χ-planner results keyed by
    ``(pattern_hash, P, machine fingerprint)``: repeat matrices skip
    ``plan_layout`` entirely and select the byte-identical engine plan,
  * ``jobs``        — resumable FilterDiag jobs: the explicit
    :class:`~repro.core.filter_diag.FDState` pytree checkpointed at
    iteration boundaries and driven by the runtime supervisor,
  * ``batcher``     — request queue + batcher packing compatible
    concurrent requests into one panel as extra ``n_b`` columns, with
    per-request demux bit-identical to solo solves.
"""
from .plan_cache import (CACHE_VERSION, PlanCache, cache_key,
                         cached_plan_layout, machine_fingerprint,
                         pattern_hash, plan_from_json, plan_to_json)
from .jobs import FilterDiagJob, pack_state, unpack_state
from .batcher import BatchedJob, EigenService, SolveRequest, request_compat_key

__all__ = [
    "CACHE_VERSION", "PlanCache", "cache_key", "cached_plan_layout",
    "machine_fingerprint", "pattern_hash", "plan_from_json", "plan_to_json",
    "FilterDiagJob", "pack_state", "unpack_state",
    "BatchedJob", "EigenService", "SolveRequest", "request_compat_key",
]

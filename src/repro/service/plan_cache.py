"""Persistent plan cache: χ-planner results keyed by the sparsity pattern.

``plan_layout`` costs 25–256 ms per plan on the seed families (BENCH
``plan_us``) and scales with the pattern pass — pure waste when the same
matrix family/size is solved repeatedly, as a service does. This module
serializes :class:`~repro.core.planner.Plan` (candidates, engine axes,
and the planned :class:`~repro.core.partition.RowMap`) losslessly to a
merge-on-write JSON store following the ``benchmarks/schema.py``
discipline: a single versioned JSON object, fully validated before every
merge, atomically replaced on write.

Cache key design (the service's multi-tenant contract):

  * ``pattern_hash`` — SHA-256 of the canonical (sorted, deduplicated)
    CSR pattern from ``partition._pattern_csr``. Sorting makes the hash
    invariant under ELL slot-order permutation of the same matrix (the
    planner's inputs are pattern-only, so so is the key); D is folded in,
    making different sizes/families distinct.
  * ``P`` (device count) and the **machine-model fingerprint** (name +
    the exact b_m/b_c/κ/α constants) — a re-calibrated machine must not
    hit stale plans.
  * every remaining ``plan_layout`` argument that shapes the result
    (n_search, degree, d_pad, axis tuples, splits) is folded into a
    params digest, and :data:`CACHE_VERSION` is part of the key — bump it
    on ANY planner-axis change (new engine axis, changed ranking key) so
    old stores are ignored wholesale rather than misapplied.

A cache hit skips ``plan_layout`` entirely (the service asserts this via
a call counter) while selecting the byte-identical engine plan: the
round-trip is lossless, including the RowMap the candidate was scored
on, so ``comm_plan`` recomputed from the cached candidate reproduces the
original ``comm_bytes_per_device`` exactly.
"""
from __future__ import annotations

import fcntl
import hashlib
import json
import os
from typing import Any

import numpy as np

from ..core import partition, planner
from ..core import perf_model as pm
from ..core.partition import RowMap
from ..core.planner import Candidate, Plan

__all__ = ["SCHEMA", "CACHE_VERSION", "pattern_hash", "machine_fingerprint",
           "cache_key", "plan_to_json", "plan_from_json", "validate_store",
           "PlanCache", "cached_plan_layout"]

SCHEMA = "plan-cache/v1"

#: Bump on ANY planner-axis change (new engine axis, changed ranking
#: key, changed Candidate fields): the version is part of every cache
#: key, so stale entries miss instead of misapplying. The current value
#: corresponds to the seven-axis grid (layout x overlap x comm x
#: schedule x partition x kernel x s-step).
CACHE_VERSION = 1


# ---------------------------------------------------------------- keys --

#: D above which ``pattern_hash`` probes generator families instead of
#: materializing the canonical CSR (matches the streaming planner's
#: regime: a 10^7-row matrix-free instance must key the cache without a
#: full pattern pass). CSR inputs always hash the full pattern.
PATTERN_HASH_PROBE_D = 2_000_000
_PATTERN_PROBE_ROWS = 4096


def pattern_hash(matrix) -> str:
    """SHA-256 of the canonical sparsity pattern (sorted, deduplicated
    CSR) — invariant under ELL slot-order permutation of the same
    matrix, distinct across families and sizes.

    Generator families past :data:`PATTERN_HASH_PROBE_D` rows are hashed
    from a deterministic evenly-spaced row probe of ``row_cols`` instead
    (sorted per probe row, so the same slot-order invariance holds on
    the probed subset): materializing the canonical CSR is exactly the
    O(nnz) pass the sampled planner exists to avoid. The probe keys on D
    plus the probed rows' exact column sets — distinct seeds/params of
    the same family produce distinct column sets on 4096 spread rows."""
    h = hashlib.sha256()
    D = int(matrix.D) if hasattr(matrix, "D") else int(matrix.shape[0])
    if hasattr(matrix, "row_cols") and D > PATTERN_HASH_PROBE_D:
        rows = np.unique(np.linspace(0, D - 1,
                                     _PATTERN_PROBE_ROWS).astype(np.int64))
        r, c = matrix.row_cols(rows)
        order = np.lexsort((c, r))
        h.update(b"pattern-probe/v1:")
        h.update(np.int64(D).tobytes())
        h.update(np.ascontiguousarray(r[order], dtype=np.int64).tobytes())
        h.update(np.ascontiguousarray(c[order], dtype=np.int64).tobytes())
        return h.hexdigest()
    indptr, cols = partition._pattern_csr(matrix)
    h.update(b"pattern/v1:")
    h.update(np.int64(len(indptr) - 1).tobytes())
    h.update(np.ascontiguousarray(indptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(cols, dtype=np.int64).tobytes())
    return h.hexdigest()


def machine_fingerprint(machine: pm.MachineModel) -> str:
    """Name + exact model constants: a re-fit machine misses old plans."""
    return (f"{machine.name}:bm={machine.b_m!r}:bc={machine.b_c!r}"
            f":k={machine.kappa!r}:a={machine.alpha!r}")


def _params_digest(params: dict) -> str:
    blob = json.dumps(params, sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def cache_key(phash: str, n_devices: int, machine: pm.MachineModel,
              **params: Any) -> str:
    """Store key ``(pattern_hash, P, machine fingerprint)`` plus a digest
    of every other plan-shaping argument and the cache version."""
    return (f"{phash}/P{n_devices}/{machine_fingerprint(machine)}"
            f"/{_params_digest(params)}/v{CACHE_VERSION}")


# ------------------------------------------------------- serialization --

def _rowmap_to_json(rm: RowMap | None):
    if rm is None:
        return None
    identity_perm = bool(np.array_equal(
        rm.perm, np.arange(rm.D, dtype=np.int64)))
    return {
        "D": int(rm.D), "P": int(rm.P), "R": int(rm.R),
        "balance": rm.balance, "reorder": rm.reorder,
        "sstep": int(rm.sstep),
        # identity permutations (balance-only maps) compress to null
        "perm": None if identity_perm else [int(x) for x in rm.perm],
        "boundaries": [int(x) for x in rm.boundaries],
    }


def _rowmap_from_json(j) -> RowMap | None:
    if j is None:
        return None
    D = int(j["D"])
    perm = (np.arange(D, dtype=np.int64) if j["perm"] is None
            else np.asarray(j["perm"], dtype=np.int64))
    return RowMap(D=D, P=int(j["P"]), balance=j["balance"],
                  reorder=j["reorder"], perm=perm,
                  boundaries=np.asarray(j["boundaries"], dtype=np.int64),
                  R=int(j["R"]), sstep=int(j["sstep"]))


_CANDIDATE_SCALARS = ("layout", "n_row", "n_col", "overlap", "comm",
                      "schedule", "redistribute", "chi1", "chi2", "chi_eng",
                      "t_iter", "t_redist", "t_pass",
                      "comm_bytes_per_device", "balance", "reorder",
                      "kernel", "sstep")


def _candidate_to_json(c: Candidate) -> dict:
    out = {k: getattr(c, k) for k in _CANDIDATE_SCALARS}
    out["rowmap"] = _rowmap_to_json(c.rowmap)
    return out


def _candidate_from_json(j: dict) -> Candidate:
    kw = {k: j[k] for k in _CANDIDATE_SCALARS}
    for k in ("n_row", "n_col", "comm_bytes_per_device", "sstep"):
        kw[k] = int(kw[k])
    for k in ("chi1", "chi2", "chi_eng", "t_iter", "t_redist", "t_pass"):
        kw[k] = float(kw[k])
    return Candidate(rowmap=_rowmap_from_json(j.get("rowmap")), **kw)


def plan_to_json(plan: Plan) -> dict:
    """Lossless JSON form of a Plan (floats round-trip exactly via repr)."""
    return {
        "matrix": plan.matrix, "D": int(plan.D),
        "n_devices": int(plan.n_devices), "n_search": int(plan.n_search),
        "degree": int(plan.degree), "machine": plan.machine,
        "candidates": [_candidate_to_json(c) for c in plan.candidates],
    }


def plan_from_json(j: dict) -> Plan:
    return Plan(matrix=j["matrix"], D=int(j["D"]),
                n_devices=int(j["n_devices"]), n_search=int(j["n_search"]),
                degree=int(j["degree"]), machine=j["machine"],
                candidates=tuple(_candidate_from_json(c)
                                 for c in j["candidates"]))


# ----------------------------------------------------------- the store --

def validate_store(store) -> list[str]:
    """All schema errors of a plan-cache store object (empty = valid) —
    the ``benchmarks/schema.py`` discipline: a malformed entry merged
    once would otherwise survive forever."""
    if not isinstance(store, dict):
        return ["store is not a JSON object"]
    errors: list[str] = []
    if store.get("schema") != SCHEMA:
        errors.append(f"schema is {store.get('schema')!r}, "
                      f"expected {SCHEMA!r}")
    entries = store.get("entries")
    if not isinstance(entries, dict):
        return errors + ["'entries' missing or not an object"]
    for key, ent in entries.items():
        where = f"entries[{key[:32]}…]" if len(key) > 32 else f"entries[{key}]"
        if not isinstance(ent, dict) or "plan" not in ent:
            errors.append(f"{where}: missing 'plan'")
            continue
        pj = ent["plan"]
        if not isinstance(pj, dict):
            errors.append(f"{where}: 'plan' not an object")
            continue
        for field in ("matrix", "D", "n_devices", "n_search", "degree",
                      "machine", "candidates"):
            if field not in pj:
                errors.append(f"{where}: plan missing {field!r}")
        cands = pj.get("candidates")
        if not isinstance(cands, list) or not cands:
            errors.append(f"{where}: plan has no candidates")
            continue
        for i, cj in enumerate(cands):
            missing = [k for k in _CANDIDATE_SCALARS
                       if not isinstance(cj, dict) or k not in cj]
            if missing:
                errors.append(f"{where}: candidates[{i}] missing {missing}")
    return errors


class PlanCache:
    """Merge-on-write JSON store of serialized plans.

    ``get``/``put`` count ``hits``/``misses``/``plan_calls`` so the
    service (and the acceptance test) can assert the hit path never
    invoked the planner. A corrupt store never crashes a solve: ``get``
    treats it as empty; ``put`` refuses to merge into it (explicit
    ``ValueError`` listing the schema errors) so corruption cannot
    propagate.
    """

    def __init__(self, path: str):
        self.path = str(path)
        self.hits = 0
        self.misses = 0
        self.plan_calls = 0

    # -- store I/O ------------------------------------------------------
    def _load(self) -> dict | None:
        """The validated store object, or None when absent/corrupt."""
        try:
            with open(self.path) as f:
                store = json.load(f)
        except (OSError, ValueError):
            return None
        return store if not validate_store(store) else None

    def get(self, key: str) -> Plan | None:
        store = self._load()
        ent = (store or {}).get("entries", {}).get(key)
        if ent is None:
            self.misses += 1
            return None
        self.hits += 1
        return plan_from_json(ent["plan"])

    def put(self, key: str, plan: Plan):
        """Merge ``key -> plan`` into the store and atomically rewrite.

        Existing entries are kept (merge-on-write); the merged store is
        fully re-validated before the write, and an existing-but-invalid
        store is refused rather than silently clobbered.

        Concurrent writers are safe: the read-merge-validate-write cycle
        runs under an exclusive ``flock`` on a ``.lock`` sidecar (held by
        every ``put``, so two processes cannot interleave their reads and
        drop each other's entries), the temp file is per-PID (two writers
        never scribble on one buffer), and the final ``os.replace`` keeps
        readers crash-consistent — a reader never observes a torn store,
        locked or not.
        """
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        with open(self.path + ".lock", "w") as lock:
            fcntl.flock(lock, fcntl.LOCK_EX)
            try:
                store: dict
                if os.path.exists(self.path):
                    try:
                        with open(self.path) as f:
                            store = json.load(f)
                    except ValueError as e:
                        raise ValueError(
                            f"{self.path}: existing store is not valid "
                            f"JSON ({e}); refusing to merge") from e
                    errors = validate_store(store)
                    if errors:
                        raise ValueError(
                            f"{self.path}: existing store is invalid, "
                            f"refusing to merge: {errors}")
                else:
                    store = {"schema": SCHEMA, "entries": {}}
                store["entries"][key] = {"plan": plan_to_json(plan)}
                errors = validate_store(store)
                if errors:
                    raise ValueError(
                        f"refusing to write invalid store: {errors}")
                tmp = f"{self.path}.tmp.{os.getpid()}"
                with open(tmp, "w") as f:
                    json.dump(store, f)
                os.replace(tmp, self.path)
            finally:
                fcntl.flock(lock, fcntl.LOCK_UN)


def cached_plan_layout(matrix, n_devices: int, *, n_search: int,
                       cache: PlanCache | None = None,
                       machine: pm.MachineModel = pm.TPU_V5E,
                       degree: int | None = None,
                       **kwargs) -> tuple[Plan, bool]:
    """``plan_layout`` behind the cache: returns ``(plan, hit)``.

    On a miss the fresh plan is stored under the full key (pattern hash,
    P, machine fingerprint, params digest, cache version); on a hit
    ``plan_layout`` is never called — ``cache.plan_calls`` counts the
    planner invocations this wrapper made. ``kwargs`` are forwarded to
    ``plan_layout`` verbatim and folded into the key.
    """
    degree = degree if degree is not None else planner.DEFAULT_PLAN_DEGREE
    if cache is None:
        plan = planner.plan_layout(matrix, n_devices, n_search=n_search,
                                   degree=degree, machine=machine, **kwargs)
        return plan, False
    key = cache_key(pattern_hash(matrix), n_devices, machine,
                    n_search=n_search, degree=degree, **kwargs)
    plan = cache.get(key)
    if plan is not None:
        return plan, True
    cache.plan_calls += 1
    plan = planner.plan_layout(matrix, n_devices, n_search=n_search,
                               degree=degree, machine=machine, **kwargs)
    cache.put(key, plan)
    return plan, False

"""Paper config: Hubbard, n_sites=16, n_fermions=8 (D = 165,636,900) with
U=25, ranpot=1 — Fig. 1/8, Table 1/4. Interior targets in partially
filled spectral gaps (tau = 15, 40, 66)."""
from ..core.filter_diag import FDConfig

MATRIX = dict(family="Hubbard", n_sites=16, n_fermions=8, U=25.0, ranpot=1.0)
CONFIG = dict(
    matrix=MATRIX,
    fd=FDConfig(n_target=100, n_search=512, target=15.0, tol=1e-10),
    layouts=("stack", "panel", "pillar"),
)
SMOKE = dict(
    matrix=dict(family="Hubbard", n_sites=8, n_fermions=4, U=4.0, ranpot=1.0),
    fd=FDConfig(n_target=4, n_search=16, target=2.0, tol=1e-8, max_iters=12),
)

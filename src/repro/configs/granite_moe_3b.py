"""granite-moe-3b-a800m [moe] — top-8 routing
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
32L d_model=1536 24H (GQA kv=8) expert d_ff=512 vocab=49155, MoE 40e top-8."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155,
    n_experts=40, top_k=8,
    activation="swiglu", norm="rmsnorm", rope_theta=1e4,
    tie_embeddings=True,
    # 40 tiny (512-wide) experts: ZeRO-stored, replicated-at-compute
    # dispatch is collective-free (EXPERIMENTS §Perf — the EP all_to_all
    # formulation was collective-bound at ~54 s/step on 256 chips)
    moe_expert_sharding="data_zero",
)

SMOKE = ModelConfig(
    name="granite-moe-3b-smoke", family="moe",
    n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_ff=64, vocab=512,
    n_experts=8, top_k=2, tie_embeddings=True, dtype="float32", loss_chunk=32,
)

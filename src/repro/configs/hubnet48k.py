"""HubNet config: D = 48,000 hub-and-spoke "airline" Laplacian — the
schedule-imbalanced family where the corridors land on many distinct
cyclic shifts (χ₃/χ₂ ≈ 5 at P = 32), so the cyclic neighbor schedule
pays one full-sized round per corridor shift while a greedy matching
packs all corridors into O(1) rounds (``--spmv-schedule matching``,
H_cyclic/H_matching ≈ 2–3); the χ-driven planner picks the matching
schedule here (``--layout auto``). FD targets the low
(smooth/community) end of the Laplacian spectrum."""
from ..core.filter_diag import FDConfig

MATRIX = dict(family="HubNet", n=48000, w=2, h=5, m=512, k=4)
CONFIG = dict(
    matrix=MATRIX,
    fd=FDConfig(n_target=16, n_search=64, target=0.0, tol=1e-10,
                spmv_comm="compressed", spmv_schedule="matching"),
    layouts=("stack", "panel", "pillar"),
)
SMOKE = dict(
    matrix=dict(family="HubNet", n=4000, w=2, h=4, m=192, k=4),
    fd=FDConfig(n_target=4, n_search=16, target=0.0, tol=1e-8, max_iters=12,
                spmv_comm="compressed", spmv_schedule="matching"),
)

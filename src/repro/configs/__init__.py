"""Architecture registry: the 10 assigned pool configs + the paper's own
eigenproblem configs. ``get_config(name)`` returns the full ModelConfig;
``get_smoke_config(name)`` a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib

ARCHS = [
    "deepseek-67b",
    "qwen3-0.6b",
    "qwen2.5-32b",
    "nemotron-4-15b",
    "internvl2-1b",
    "granite-moe-3b-a800m",
    "arctic-480b",
    "hymba-1.5b",
    "hubert-xlarge",
    "rwkv6-1.6b",
]

EIGEN_CONFIGS = ["exciton200", "hubbard16", "roadnet48k", "hubnet48k"]

_MODULES = {
    "deepseek-67b": "deepseek_67b",
    "qwen3-0.6b": "qwen3_0p6b",
    "qwen2.5-32b": "qwen2p5_32b",
    "nemotron-4-15b": "nemotron_4_15b",
    "internvl2-1b": "internvl2_1b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "arctic-480b": "arctic_480b",
    "hymba-1.5b": "hymba_1p5b",
    "hubert-xlarge": "hubert_xlarge",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "exciton200": "exciton200",
    "hubbard16": "hubbard16",
    "roadnet48k": "roadnet48k",
    "hubnet48k": "hubnet48k",
}


def _mod(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown config {name!r}; available: {sorted(_MODULES)}")
    return importlib.import_module(f".{_MODULES[name]}", __package__)


def get_config(name: str):
    return _mod(name).CONFIG


def get_smoke_config(name: str):
    return _mod(name).SMOKE

"""qwen2.5-32b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf].
64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=27648, vocab=152064,
    qkv_bias=True, activation="swiglu", norm="rmsnorm", rope_theta=1e6,
    param_sharding="fsdp_tp",
)

SMOKE = ModelConfig(
    name="qwen2.5-32b-smoke", family="dense",
    n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256, vocab=512,
    qkv_bias=True, dtype="float32", loss_chunk=32,
)

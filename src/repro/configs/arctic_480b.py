"""arctic-480b [moe] — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].
35L d_model=7168 56H (GQA kv=8) expert d_ff=4864 vocab=32000.

Memory policy: FSDP+TP param/optimizer-state sharding and int8 AdamW
moments — 480B params cannot hold fp32 optimizer state on one pod (the
paper's 'pillar trades memory for performance' caveat, on the optimizer
axis)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000,
    n_experts=128, top_k=2, dense_residual=True, dense_d_ff=4864,
    activation="swiglu", norm="rmsnorm", rope_theta=1e4,
    param_sharding="fsdp_tp", optimizer_dtype="int8",
)

SMOKE = ModelConfig(
    name="arctic-480b-smoke", family="moe",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=64, vocab=512,
    n_experts=8, top_k=2, dense_residual=True, dense_d_ff=64,
    dtype="float32", loss_chunk=32,
)

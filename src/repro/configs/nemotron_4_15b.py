"""nemotron-4-15b [dense] — GQA, squared-ReLU [arXiv:2402.16819; unverified].
32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b", family="dense",
    n_layers=32, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=24576, vocab=256000,
    activation="squared_relu", norm="layernorm", rope_theta=1e4,
    param_sharding="fsdp_tp",
)

SMOKE = ModelConfig(
    name="nemotron-4-15b-smoke", family="dense",
    n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_ff=256, vocab=512,
    activation="squared_relu", norm="layernorm", dtype="float32", loss_chunk=32,
)

"""RoadNet config: D = 48,000 ring road + commuter corridor — the
comm-imbalanced family (χ₃/χ₂ ≈ 4 at P = 8) where the padded all_to_all
engine loses its imbalance factor on the wire and the sparsity-compressed
neighbor-permute engine (``--spmv-comm compressed``) wins it back; the
χ-driven planner picks the compressed engine here (``--layout auto``).
FD targets the low (smooth/community) end of the Laplacian spectrum."""
from ..core.filter_diag import FDConfig

MATRIX = dict(family="RoadNet", n=48000, w=2, m=1200, k=4)
CONFIG = dict(
    matrix=MATRIX,
    fd=FDConfig(n_target=16, n_search=64, target=0.0, tol=1e-10,
                spmv_comm="compressed"),
    layouts=("stack", "panel", "pillar"),
)
SMOKE = dict(
    matrix=dict(family="RoadNet", n=4000, w=2, m=256, k=4),
    fd=FDConfig(n_target=4, n_search=16, target=0.0, tol=1e-8, max_iters=12,
                spmv_comm="compressed"),
)

"""internvl2-1b [vlm] — InternViT + InternLM2 [arXiv:2404.16821; hf].
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655. The InternViT
frontend is a STUB per the assignment: input_specs provides precomputed
patch embeddings projected by a linear frontend."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
    d_ff=4864, vocab=151655,
    embed_inputs=True, frontend_dim=1024, n_prefix_embeds=256,
    activation="swiglu", norm="rmsnorm", rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="internvl2-1b-smoke", family="vlm",
    n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_ff=192, vocab=512,
    embed_inputs=True, frontend_dim=48, n_prefix_embeds=8,
    dtype="float32", loss_chunk=32,
)

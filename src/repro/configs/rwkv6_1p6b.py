"""rwkv6-1.6b [ssm] — Finch, data-dependent decay [arXiv:2404.05892;
unverified]. 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536.
32 heads of size 64 (RWKV6 head_size=64). O(1)-state decode => runs the
long_500k cell."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm", attn_free=True,
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536,
    norm="layernorm",
)

SMOKE = ModelConfig(
    name="rwkv6-1.6b-smoke", family="ssm", attn_free=True,
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=224, vocab=512,
    norm="layernorm", dtype="float32", loss_chunk=32,
)

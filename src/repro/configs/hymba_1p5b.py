"""hymba-1.5b [hybrid] — parallel attn+mamba heads [arXiv:2411.13676; hf].
32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Sliding-window attention (2048) everywhere except 3 global layers
(first/middle/last, per the Hymba paper); meta-tokens omitted (DESIGN.md)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", hybrid=True,
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, ssm_state=16, ssm_expand=2,
    sliding_window=2048, global_attn_layers=(0, 15, 31),
    activation="swiglu", norm="rmsnorm", rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid", hybrid=True,
    n_layers=3, d_model=80, n_heads=5, n_kv_heads=1, d_ff=192, vocab=512,
    ssm_state=8, sliding_window=16, global_attn_layers=(0, 2),
    dtype="float32", loss_chunk=32,
)

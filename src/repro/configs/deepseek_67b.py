"""deepseek-67b [dense] — llama-arch [arXiv:2401.02954; hf].
95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=22016, vocab=102400,
    activation="swiglu", norm="rmsnorm", rope_theta=1e4,
    param_sharding="fsdp_tp",
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke", family="dense",
    n_layers=3, d_model=128, n_heads=8, n_kv_heads=2, d_ff=352, vocab=512,
    activation="swiglu", norm="rmsnorm", dtype="float32", loss_chunk=32,
)

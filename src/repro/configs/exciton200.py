"""Paper config: Exciton, L=200 (D = 193,443,603) — Fig. 1/7, Table 1/4.
FD setup follows Table 4: N_s=384 search vectors, N_t=100 targets at the
lower spectral edge, pillar layout on 256+ chips."""
from ..core.filter_diag import FDConfig

MATRIX = dict(family="Exciton", L=200)
CONFIG = dict(
    matrix=MATRIX,
    fd=FDConfig(n_target=100, n_search=384, target=-0.4, tol=1e-10),
    layouts=("stack", "panel", "pillar"),
)
SMOKE = dict(
    matrix=dict(family="Exciton", L=4),
    fd=FDConfig(n_target=4, n_search=16, target=-1.2, tol=1e-8, max_iters=12),
)

"""hubert-xlarge [audio] — encoder-only, w2v2 arch [arXiv:2106.07447;
unverified]. 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-unit
prediction). The conv waveform frontend is a STUB per the assignment:
input_specs provides precomputed frame embeddings (frontend_dim=512).
Encoder-only => no decode/long shapes (DESIGN.md §Arch-applicability)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504,
    encoder_only=True, embed_inputs=True, frontend_dim=512,
    activation="gelu", norm="layernorm",
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", family="audio",
    n_layers=3, d_model=96, n_heads=4, n_kv_heads=4, d_ff=192, vocab=64,
    encoder_only=True, embed_inputs=True, frontend_dim=32,
    activation="gelu", norm="layernorm", dtype="float32", loss_chunk=32,
)

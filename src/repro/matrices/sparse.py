"""CSR / ELL sparse utilities and row partitioning (host-side, numpy).

These are the host-side building blocks of the distributed SpMV engine:
the partitioner in ``core/spmv.py`` consumes CSR patterns produced here.
"""
from __future__ import annotations

import dataclasses
import numpy as np

__all__ = ["CSR", "uniform_partition", "csr_from_coo", "csr_to_ell",
           "gather_row_entry_idx"]


def gather_row_entry_idx(indptr, rows):
    """(entry_idx, counts): indices into a CSR's ``indices``/``data``
    arrays selecting the entries of the (arbitrary, not necessarily
    contiguous) row set ``rows``, concatenated in the given row order.

    Single home of the variable-length row-gather idiom used by the
    mapped-partition builders (``spmv._csr_rows_at``,
    ``planner._mapped_row_cols``, ``partition._reordered_pattern``).
    """
    indptr = np.asarray(indptr)
    rows = np.asarray(rows, dtype=np.int64)
    counts = np.diff(indptr)[rows]
    starts = indptr[:-1][rows]
    total = int(counts.sum())
    idx = (np.arange(total, dtype=np.int64)
           - np.repeat(np.cumsum(counts) - counts, counts)
           + np.repeat(starts, counts))
    return idx, counts


@dataclasses.dataclass
class CSR:
    """Compressed-row-storage matrix. ``data`` may be None (pattern only)."""

    indptr: np.ndarray  # int64, shape (D+1,)
    indices: np.ndarray  # int64, shape (nnz,)
    data: np.ndarray | None  # float64/complex128 or None
    shape: tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    @property
    def n_nzr(self) -> float:
        return self.nnz / self.shape[0]

    def row_slice(self, a: int, b: int) -> "CSR":
        """Rows [a:b) as a (b-a) x D CSR."""
        lo, hi = int(self.indptr[a]), int(self.indptr[b])
        return CSR(
            indptr=self.indptr[a : b + 1] - lo,
            indices=self.indices[lo:hi],
            data=None if self.data is None else self.data[lo:hi],
            shape=(b - a, self.shape[1]),
        )

    def to_dense(self) -> np.ndarray:
        D0, D1 = self.shape
        out = np.zeros((D0, D1), dtype=self.data.dtype if self.data is not None else np.float64)
        rows = np.repeat(np.arange(D0), np.diff(self.indptr))
        out[rows, self.indices] = 1.0 if self.data is None else self.data
        return out

    def matvec(self, x: np.ndarray) -> np.ndarray:
        """Reference (numpy) SpMV / SpMMV, x of shape (D,) or (D, n_b)."""
        rows = np.repeat(np.arange(self.shape[0]), np.diff(self.indptr))
        contrib = self.data[:, None] * x[self.indices] if x.ndim == 2 else self.data * x[self.indices]
        out = np.zeros((self.shape[0],) + x.shape[1:], dtype=np.result_type(self.data, x))
        np.add.at(out, rows, contrib)
        return out


def uniform_partition(D: int, P: int) -> np.ndarray:
    """Row boundaries k_0..k_P (Eq. in Sec 3.4): k_p = round(p * D / P)."""
    return np.round(np.arange(P + 1) * (D / P)).astype(np.int64)


def csr_from_coo(rows, cols, vals, shape) -> CSR:
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    vals = None if vals is None else np.asarray(vals)[order]
    # coalesce duplicates
    if len(rows):
        key_same = np.zeros(len(rows), dtype=bool)
        key_same[1:] = (rows[1:] == rows[:-1]) & (cols[1:] == cols[:-1])
        if key_same.any():
            grp = np.cumsum(~key_same) - 1
            n = grp[-1] + 1
            r2 = np.zeros(n, dtype=np.int64)
            c2 = np.zeros(n, dtype=np.int64)
            r2[grp[::-1]] = rows[::-1]
            c2[grp[::-1]] = cols[::-1]
            if vals is not None:
                v2 = np.zeros(n, dtype=vals.dtype)
                np.add.at(v2, grp, vals)
                vals = v2
            rows, cols = r2, c2
    indptr = np.zeros(shape[0] + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    indptr = np.cumsum(indptr)
    return CSR(indptr=indptr, indices=cols, data=vals, shape=tuple(shape))


def csr_to_ell(csr: CSR, width: int | None = None, pad_col: int = 0):
    """Convert CSR to padded ELL: (cols[D, W], vals[D, W], valid[D, W]).

    Padded entries point at ``pad_col`` with value 0 so a dense gather +
    multiply-accumulate is exact. This is the host-side layout used by the
    Pallas kernel and the jnp reference.
    """
    D = csr.shape[0]
    counts = np.diff(csr.indptr)
    W = int(counts.max()) if width is None else width
    if W < counts.max():
        raise ValueError(f"ELL width {W} < max row nnz {counts.max()}")
    cols = np.full((D, W), pad_col, dtype=np.int32)
    dtype = csr.data.dtype if csr.data is not None else np.float64
    vals = np.zeros((D, W), dtype=dtype)
    valid = np.zeros((D, W), dtype=bool)
    slot = (np.arange(csr.nnz) - np.repeat(csr.indptr[:-1], counts)).astype(np.int64)
    rows = np.repeat(np.arange(D), counts)
    cols[rows, slot] = csr.indices
    if csr.data is not None:
        vals[rows, slot] = csr.data
    valid[rows, slot] = True
    return cols, vals, valid

"""Hubbard matrix — ScaMaC-pattern-equivalent generator.

1-D Hubbard chain (open boundaries) with n_sites sites and n_fermions
electrons per spin orientation:

    H = -t sum_{<ij>,sigma} c†_{i,sigma} c_{j,sigma}
        + U sum_i n_{i,up} n_{i,dn}  + ranpot * sum_i eps_i (n_{i,up}+n_{i,dn})

Basis: |up> (x) |dn>, index i = i_up * D_spin + i_dn, each spin sector in
increasing-bitmask (combinadic) order. Dimension D = C(n_sites,n_fermions)^2.

Pattern facts reproduced exactly (Table 1): n_nzr = n_sites at half filling
for U = ranpot = 0 (hops only; the diagonal is stored only when U or ranpot
is nonzero), and the chi metrics are computed *exactly* at any D through the
tensor-product structure: remote-column counting reduces to the D_spin-sized
single-spin hop graph (O(D_spin) per block instead of O(D)).
"""
from __future__ import annotations

import numpy as np

from .basis import binom_table, enumerate_masks, hop_neighbors, rank_masks
from .families import MatrixFamily, register


@register
class Hubbard(MatrixFamily):
    name = "Hubbard"
    is_complex = False

    def __init__(
        self,
        n_sites: int = 8,
        n_fermions: int = 4,
        t: float = 1.0,
        U: float = 0.0,
        ranpot: float = 0.0,
        seed: int = 42,
    ):
        self.n_sites, self.n_fermions = int(n_sites), int(n_fermions)
        self.t, self.U, self.ranpot = float(t), float(U), float(ranpot)
        C = binom_table(self.n_sites)
        self.D_spin = int(C[self.n_sites, self.n_fermions])
        if self.D_spin > 40_000_000:
            raise MemoryError("spin sector too large to enumerate")
        self.masks = enumerate_masks(self.n_sites, self.n_fermions)
        rng = np.random.default_rng(seed)
        self.eps = rng.uniform(-1.0, 1.0, size=self.n_sites)
        # single-spin hop graph (CSR over the spin sector)
        src, tgt_masks, _ = hop_neighbors(self.masks, self.n_sites, self.n_fermions)
        tgt = rank_masks(tgt_masks, self.n_sites, self.n_fermions)
        order = np.argsort(src, kind="stable")
        src, tgt = src[order], tgt[order]
        self.adj_indptr = np.zeros(self.D_spin + 1, dtype=np.int64)
        np.add.at(self.adj_indptr, src + 1, 1)
        self.adj_indptr = np.cumsum(self.adj_indptr)
        self.adj_targets = tgt
        self.reach = None  # n_vc is overridden (tensor-product structured)

    @property
    def D(self) -> int:
        return self.D_spin * self.D_spin

    @property
    def has_diag(self) -> bool:
        return self.U != 0.0 or self.ranpot != 0.0

    # -------------------------------------------------------- pattern ----

    def _adj_expand(self, idx: np.ndarray):
        """Vectorized (row_repeat, targets) for many spin rows at once."""
        idx = np.asarray(idx, dtype=np.int64)
        counts = (self.adj_indptr[idx + 1] - self.adj_indptr[idx]).astype(np.int64)
        total = int(counts.sum())
        row_rep = np.repeat(idx, counts)
        starts = np.repeat(self.adj_indptr[idx], counts)
        offs = np.arange(total, dtype=np.int64) - np.repeat(
            np.cumsum(counts) - counts, counts
        )
        return row_rep, self.adj_targets[starts + offs], counts

    def row_cols(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        Ds = self.D_spin
        i_up, i_dn = rows // Ds, rows % Ds
        out_r, out_c = [], []
        if self.has_diag:
            out_r.append(rows)
            out_c.append(rows)
        # dn hops: col = i_up*Ds + j_dn
        rep_dn, tgt_dn, cnt_dn = self._adj_expand(i_dn)
        out_r.append(np.repeat(rows, cnt_dn))
        out_c.append(np.repeat(i_up, cnt_dn) * Ds + tgt_dn)
        # up hops: col = j_up*Ds + i_dn
        rep_up, tgt_up, cnt_up = self._adj_expand(i_up)
        out_r.append(np.repeat(rows, cnt_up))
        out_c.append(tgt_up * Ds + np.repeat(i_dn, cnt_up))
        return np.concatenate(out_r), np.concatenate(out_c)

    def row_entries(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        Ds = self.D_spin
        i_up, i_dn = rows // Ds, rows % Ds
        out_r, out_c, out_v = [], [], []
        if self.has_diag:
            up_m, dn_m = self.masks[i_up], self.masks[i_dn]
            dbl = np.bitwise_count(up_m & dn_m).astype(np.float64)
            pot = np.zeros(len(rows))
            for s in range(self.n_sites):
                occ = ((up_m >> s) & 1) + ((dn_m >> s) & 1)
                pot += self.eps[s] * occ
            out_r.append(rows)
            out_c.append(rows)
            out_v.append(self.U * dbl + self.ranpot * pot)
        rep_dn, tgt_dn, cnt_dn = self._adj_expand(i_dn)
        out_r.append(np.repeat(rows, cnt_dn))
        out_c.append(np.repeat(i_up, cnt_dn) * Ds + tgt_dn)
        out_v.append(np.full(tgt_dn.shape, -self.t))
        rep_up, tgt_up, cnt_up = self._adj_expand(i_up)
        out_r.append(np.repeat(rows, cnt_up))
        out_c.append(tgt_up * Ds + np.repeat(i_dn, cnt_up))
        out_v.append(np.full(tgt_up.shape, -self.t))
        return np.concatenate(out_r), np.concatenate(out_c), np.concatenate(out_v)

    # ------------------------------------------------- structured n_vc ----

    def _targets_bool(self, ups: "np.ndarray | range") -> np.ndarray:
        """Union of spin-hop targets over the given source rows, as bool[Ds]."""
        out = np.zeros(self.D_spin, dtype=bool)
        ups = np.asarray(list(ups) if isinstance(ups, range) else ups, dtype=np.int64)
        if len(ups) == 0:
            return out
        _, tgt, _ = self._adj_expand(ups)
        out[tgt] = True
        return out

    def _dn_targets_from(self, lo: int, hi: int) -> np.ndarray:
        """Distinct dn-hop targets from sources i_dn in [lo,hi), as bool[Ds]."""
        return self._targets_bool(np.arange(lo, hi, dtype=np.int64))

    def n_vc(self, boundaries: np.ndarray, chunk: int = 2_000_000) -> np.ndarray:
        boundaries = np.asarray(boundaries, dtype=np.int64)
        P = len(boundaries) - 1
        Ds = self.D_spin
        out = np.zeros(P, dtype=np.int64)
        for p in range(P):
            a, b = int(boundaries[p]), int(boundaries[p + 1])
            u0, d0 = divmod(a, Ds)
            u1, d1 = divmod(b, Ds)
            if u0 == u1:  # block inside a single up-sector
                # up-hops: every target j_up != u0 is fully remote
                T0 = self._targets_bool([u0])
                n = int(T0.sum()) * (d1 - d0)
                # dn-hops from [d0,d1): targets outside [d0,d1) are remote
                tb = self._dn_targets_from(d0, d1)
                tb[d0:d1] = False
                out[p] = n + int(tb.sum())
                continue
            # full up-sectors in [u0(+1) .. u1)
            fu0 = u0 + 1 if d0 > 0 else u0
            F = self._targets_bool(range(fu0, u1))
            T0 = self._targets_bool([u0]) if d0 > 0 else np.zeros(Ds, dtype=bool)
            T1 = self._targets_bool([u1]) if d1 > 0 else np.zeros(Ds, dtype=bool)
            # coverage |i_dn set| for generic j_up (vectorized interval math)
            covA = Ds - d0  # from partial-first sources (i_dn in [d0,Ds))
            covB = d1  # from partial-last sources (i_dn in [0,d1))
            covAB = covA + covB - max(0, d1 - d0)  # union of the intervals
            cov = np.where(
                F, Ds, np.where(T0 & T1, covAB, np.where(T0, covA, np.where(T1, covB, 0)))
            ).astype(np.int64)
            # generic j_up: exclude locals (full sectors) and the two edges
            cov[fu0:u1] = 0
            cov[u0] = 0
            cov[u1 if d1 > 0 else u0] = 0
            total = int(cov.sum())
            # edge sector u0 (local i_dn in [d0,Ds)) — remote part m < d0
            if d0 > 0:
                e = np.zeros(Ds, dtype=bool)
                if F[u0]:
                    e[:d0] = True
                elif T1[u0]:
                    e[: min(d0, d1)] = True
                # dn-hops within u0 partial rows
                tb = self._dn_targets_from(d0, Ds)
                tb[d0:] = False
                e |= tb
                total += int(e.sum())
            # edge sector u1 (local i_dn in [0,d1)) — remote part m >= d1
            if d1 > 0:
                e = np.zeros(Ds, dtype=bool)
                if F[u1]:
                    e[d1:] = True
                elif T0[u1]:
                    e[max(d0, d1):] = True
                tb = self._dn_targets_from(0, d1)
                tb[:d1] = False
                e |= tb
                total += int(e.sum())
            out[p] = total
        return out

    def spectral_bounds_hint(self):
        w = 2 * self.t * self.n_sites  # loose kinetic bound
        lo = -w - self.ranpot * 2 * self.n_sites
        hi = w + self.U * min(self.n_fermions, self.n_sites) + self.ranpot * 2 * self.n_sites
        return (lo, hi)

    def describe(self) -> str:
        return (
            f"Hubbard,n_sites={self.n_sites},n_fermions={self.n_fermions} "
            f"(D={self.D}, U={self.U}, ranpot={self.ranpot})"
        )

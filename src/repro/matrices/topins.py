"""TopIns matrix — ScaMaC-pattern-equivalent generator.

3-D topological-insulator (Dirac/Wilson) 4-band lattice model:

    H = sum_{sites, d in {x,y,z}} psi†_r B_d psi_{r+e_d} + h.c.,
    B_d = (beta + i alpha_d)/2,

with the Dirac matrices alpha_d = sigma_x (x) sigma_d, beta = sigma_z (x) I.
Each hop block has exactly 2 nonzeros per row whose column union covers all
four orbitals, and there is no stored on-site term, reproducing Table 5:
n_nzr = 12 - 12/L (11.88 @ L=100, 11.98 @ L=500) and chi1[2] ~ 2/L = 0.02.
Index order is orbital-fastest: i = o + 4*(x + Lx*(y + Ly*z)).
Entries are complex (S_d = 16).
"""
from __future__ import annotations

import numpy as np

from .families import MatrixFamily, register

_s0 = np.eye(2)
_sx = np.array([[0, 1], [1, 0]], dtype=np.complex128)
_sy = np.array([[0, -1j], [1j, 0]], dtype=np.complex128)
_sz = np.array([[1, 0], [0, -1]], dtype=np.complex128)
_beta = np.kron(_sz, _s0)
_alpha = {
    "x": np.kron(_sx, _sx),
    "y": np.kron(_sx, _sy),
    "z": np.kron(_sx, _sz),
}
# forward hop blocks; backward hop along d is the Hermitian conjugate
_B = {d: (_beta + 1j * a) / 2.0 for d, a in _alpha.items()}


@register
class TopIns(MatrixFamily):
    name = "TopIns"
    is_complex = True

    def __init__(self, Lx: int = 10, Ly: int | None = None, Lz: int | None = None, t: float = 1.0):
        self.Lx = int(Lx)
        self.Ly = int(Ly) if Ly is not None else self.Lx
        self.Lz = int(Lz) if Lz is not None else self.Lx
        self.t = float(t)
        self.reach = 4 * self.Lx * self.Ly

    @property
    def D(self) -> int:
        return 4 * self.Lx * self.Ly * self.Lz

    def _decode(self, rows: np.ndarray):
        o = rows % 4
        site = rows // 4
        x = site % self.Lx
        y = (site // self.Lx) % self.Ly
        z = site // (self.Lx * self.Ly)
        return o, site, x, y, z

    def _neighbor_entries(self, rows, o, coord, extent, stride, d, conj: bool):
        """(rows_sel, cols, vals) for hop ±e_d (conj=True is the backward hop)."""
        sgn = -1 if conj else +1
        ok = (coord + sgn >= 0) & (coord + sgn < extent)
        r = rows[ok]
        oo = o[ok]
        nbr_base = r - oo + sgn * stride  # orbital-0 index of neighbour site
        B = _B[d].conj().T if conj else _B[d]
        cols, vals = [], []
        rsel = []
        for col_o in range(4):
            m = np.abs(B[oo, col_o]) > 0
            rsel.append(r[m])
            cols.append(nbr_base[m] + col_o)
            vals.append(self.t * B[oo[m], col_o])
        return np.concatenate(rsel), np.concatenate(cols), np.concatenate(vals)

    def row_cols(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        o, site, x, y, z = self._decode(rows)
        out_r, out_c = [], []
        for d, coord, extent, stride in (
            ("x", x, self.Lx, 4),
            ("y", y, self.Ly, 4 * self.Lx),
            ("z", z, self.Lz, 4 * self.Lx * self.Ly),
        ):
            for conj in (False, True):
                r, c, _ = self._neighbor_entries(rows, o, coord, extent, stride, d, conj)
                out_r.append(r)
                out_c.append(c)
        return np.concatenate(out_r), np.concatenate(out_c)

    def row_entries(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        o, site, x, y, z = self._decode(rows)
        out_r, out_c, out_v = [], [], []
        for d, coord, extent, stride in (
            ("x", x, self.Lx, 4),
            ("y", y, self.Ly, 4 * self.Lx),
            ("z", z, self.Lz, 4 * self.Lx * self.Ly),
        ):
            for conj in (False, True):
                r, c, v = self._neighbor_entries(rows, o, coord, extent, stride, d, conj)
                out_r.append(r)
                out_c.append(c)
                out_v.append(v)
        return np.concatenate(out_r), np.concatenate(out_c), np.concatenate(out_v)

    def spectral_bounds_hint(self):
        return (-6.5 * self.t, 6.5 * self.t)

    def describe(self) -> str:
        return f"TopIns,Lx={self.Lx},Ly={self.Ly},Lz={self.Lz} (D={self.D})"

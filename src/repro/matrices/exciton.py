"""Exciton matrix — ScaMaC-pattern-equivalent generator.

Models a bound electron-hole pair on an L-truncated 3-D lattice with three
orbital components per site (cf. Alvermann & Fehske, J. Phys. B 51, 044001):

  * kinetic 6-point stencil, orbital-diagonal hopping  (6 entries/row)
  * local 3x3 spin-orbit block, fully dense Hermitian  (3 entries/row)
  * attractive Coulomb diagonal  -V / max(r, 1)

Index order is orbital-fastest: i = o + 3*(x + S*(y + S*z)), S = 2L+1.
This reproduces the published sparsity characteristics exactly:
  n_nzr = 9 - 6/S  (8.96 @ L=75, 8.99 @ L=200),
  chi1[2] ~ 2/S    (0.01 @ L=75/200, Table 1).
Entries are complex (S_d = 16), as in the paper.
"""
from __future__ import annotations

import numpy as np

from .families import MatrixFamily, register

# dense Hermitian local block (orbital space); diagonal of the block is
# where the kinetic shift + Coulomb diagonal lives.
_SO = np.array(
    [[0.0, 1j, 1.0], [-1j, 0.0, 1j], [1.0, -1j, 0.0]], dtype=np.complex128
)


@register
class Exciton(MatrixFamily):
    name = "Exciton"
    is_complex = True

    def __init__(self, L: int = 10, t: float = 1.0, V: float = 2.0, so: float = 0.5):
        self.L = int(L)
        self.S = 2 * self.L + 1
        self.t, self.V, self.so = float(t), float(V), float(so)
        self.reach = 3 * self.S * self.S

    @property
    def D(self) -> int:
        return 3 * self.S**3

    # -------------------------------------------------------- pattern ----

    def _decode(self, rows: np.ndarray):
        o = rows % 3
        site = rows // 3
        x = site % self.S
        y = (site // self.S) % self.S
        z = site // (self.S * self.S)
        return o, site, x, y, z

    def row_cols(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        o, site, x, y, z = self._decode(rows)
        S = self.S
        out_r, out_c = [], []
        # local 3x3 block (includes the diagonal)
        for oo in range(3):
            out_r.append(rows)
            out_c.append(site * 3 + oo)
        # orbital-diagonal hops
        for coord, stride in ((x, 3), (y, 3 * S), (z, 3 * S * S)):
            for sgn in (+1, -1):
                ok = (coord + sgn >= 0) & (coord + sgn < S)
                out_r.append(rows[ok])
                out_c.append(rows[ok] + sgn * stride)
        return np.concatenate(out_r), np.concatenate(out_c)

    # -------------------------------------------------------- values ----

    def row_entries(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        o, site, x, y, z = self._decode(rows)
        S, L = self.S, self.L
        r = np.sqrt(
            (x - L).astype(np.float64) ** 2
            + (y - L).astype(np.float64) ** 2
            + (z - L).astype(np.float64) ** 2
        )
        diag = 6.0 * self.t - self.V / np.maximum(r, 1.0)
        out_r, out_c, out_v = [], [], []
        for oo in range(3):
            out_r.append(rows)
            out_c.append(site * 3 + oo)
            v = np.full(rows.shape, self.so * _SO[0, 0], dtype=np.complex128)
            for src in range(3):
                m = o == src
                v[m] = self.so * _SO[src, oo]
            v = v + np.where(o == oo, diag, 0.0)
            out_v.append(v)
        for coord, stride in ((x, 3), (y, 3 * S), (z, 3 * S * S)):
            for sgn in (+1, -1):
                ok = (coord + sgn >= 0) & (coord + sgn < S)
                out_r.append(rows[ok])
                out_c.append(rows[ok] + sgn * stride)
                out_v.append(np.full(int(ok.sum()), -self.t, dtype=np.complex128))
        return np.concatenate(out_r), np.concatenate(out_c), np.concatenate(out_v)

    def spectral_bounds_hint(self):
        # diag in [-V, 6t], hops 6*t, SO block norm ~ 2.2*so
        lo = -self.V - 6 * self.t - 3 * self.so
        hi = 12 * self.t + 3 * self.so
        return (lo, hi)

    def describe(self) -> str:
        return f"Exciton,L={self.L} (D={self.D}, n_nzr={9 - 6 / self.S:.2f})"

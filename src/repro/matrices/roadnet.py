"""RoadNet matrix — an irregular graph Laplacian with imbalanced
communication volume (χ₃/χ₂ > 2), the regime the paper flags as where
uniform partitions break down (road networks, nonlinear programming).

The graph is a long *ring road* — a 1-D chain where node i touches
i ± 1..w — plus a dense *commuter corridor*: a pseudo-random k-regular
bipartite bundle of edges between the "city" region ``[0, m)`` and the
"suburb" region ``[c0, c0 + m)`` on the far side of the chain
(``c0 = n//2`` by default). Under the engine's uniform row partition the
two corridor endpoints concentrate essentially all remote traffic on the
two blocks that own them, while every other block only exchanges its
w-wide band boundary:

  * χ₂ (aggregate volume / D) stays small — only ~2m + O(P·w) remote
    entries exist in total,
  * χ₃ = N_p·max_p n_vc/D is ~N_p/2 × larger: one block owns a corridor
    endpoint, so the max is ~m while the mean is ~2m/N_p.

That makes RoadNet the worst case for the padded all_to_all engine
(every pair pays the corridor's max pair volume L ≈ m) and the best case
for the sparsity-compressed neighbor-permute engine (``core/spmv.py
comm="compressed"``): the corridor occupies a single cyclic shift, the
band occupies shifts ±1, and all other rounds are skipped — per-device
moved entries drop from ``P·L ≈ P·m`` to ``H ≈ m + 2w``.

The corridor is deterministic and involutive so any row chunk generates
its own pattern in O(k) per row: city node s links to suburb node
``(a·s + b_t) mod m`` for k fixed offsets b_t (a coprime to m), and
suburb node d links back to ``a⁻¹·(d - b_t) mod m`` — both directions
are closed-form, no global state. Values are the graph Laplacian
(diag = degree, off-diag = -1), symmetric real with spectrum in
[0, 2·max_degree].
"""
from __future__ import annotations

import numpy as np

from .families import MatrixFamily, register


@register
class RoadNet(MatrixFamily):
    name = "RoadNet"
    is_complex = False

    def __init__(self, n: int = 48000, w: int = 2, m: int = 1200,
                 k: int = 4, c0: int | None = None, seed: int = 1):
        self.n = int(n)
        self.w = int(w)
        self.m = int(m)
        self.k = int(k)
        self.c0 = int(c0) if c0 is not None else self.n // 2
        if not (self.m <= self.c0 and self.c0 + self.m <= self.n):
            raise ValueError("corridor regions [0, m) and [c0, c0+m) must "
                             "be disjoint and inside [0, n)")
        if not 1 <= self.k <= self.m:
            raise ValueError("need 1 <= k <= m corridor edges per node")
        rng = np.random.default_rng(seed)
        # multiplier coprime to m scatters each city node's k suburb links
        # across the whole endpoint region (ruling out accidental locality)
        a = int(rng.integers(1, self.m))
        while np.gcd(a, self.m) != 1:
            a = int(rng.integers(1, self.m))
        self.a = a
        self.a_inv = pow(a, -1, self.m)
        self.b = np.sort(rng.choice(self.m, size=self.k, replace=False))
        self.reach = self.c0 + self.m  # corridor span bounds |col - row|

    @property
    def D(self) -> int:
        return self.n

    # -------------------------------------------------------- pattern ----

    def _corridor(self, rows: np.ndarray):
        """Yield (row_sel, cols) corridor edges incident to ``rows``."""
        city = rows < self.m
        if city.any():
            s = rows[city]
            for t in range(self.k):
                yield rows[city], self.c0 + (self.a * s + self.b[t]) % self.m
        suburb = (rows >= self.c0) & (rows < self.c0 + self.m)
        if suburb.any():
            d = rows[suburb] - self.c0
            for t in range(self.k):
                yield rows[suburb], (self.a_inv * (d - self.b[t])) % self.m

    def row_cols(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        out_r, out_c = [rows], [rows]  # Laplacian diagonal
        for d in range(1, self.w + 1):
            for sgn in (-1, 1):
                c = rows + sgn * d
                sel = (c >= 0) & (c < self.n)
                out_r.append(rows[sel])
                out_c.append(c[sel])
        for r, c in self._corridor(rows):
            out_r.append(r)
            out_c.append(c)
        return np.concatenate(out_r), np.concatenate(out_c)

    def row_entries(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        # degree = band neighbors (clipped at the chain ends) + corridor
        deg = (np.minimum(rows + self.w, self.n - 1)
               - np.maximum(rows - self.w, 0)).astype(np.float64)
        in_corridor = (rows < self.m) | ((rows >= self.c0)
                                         & (rows < self.c0 + self.m))
        deg += self.k * in_corridor
        out_r, out_c, out_v = [rows], [rows], [deg]
        for d in range(1, self.w + 1):
            for sgn in (-1, 1):
                c = rows + sgn * d
                sel = (c >= 0) & (c < self.n)
                out_r.append(rows[sel])
                out_c.append(c[sel])
                out_v.append(np.full(int(sel.sum()), -1.0))
        for r, c in self._corridor(rows):
            out_r.append(r)
            out_c.append(c)
            out_v.append(np.full(len(r), -1.0))
        return (np.concatenate(out_r), np.concatenate(out_c),
                np.concatenate(out_v))

    def est_nnz(self, probe_rows: int = 4096) -> int:
        """Exact closed form: diagonal + end-clipped band + 2·m·k
        corridor entries (no duplicates by construction)."""
        return (self.n + 2 * self.w * self.n - self.w * (self.w + 1)
                + 2 * self.m * self.k)

    def spectral_bounds_hint(self):
        return (0.0, 2.0 * (2 * self.w + self.k))

    def describe(self) -> str:
        return (f"RoadNet,n={self.n},w={self.w},m={self.m},k={self.k} "
                f"(D={self.D})")

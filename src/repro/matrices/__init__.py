"""ScaMaC-equivalent scalable matrix collection (host-side generators)."""
from .families import MatrixFamily, available_families, get_family
from .sparse import CSR, csr_from_coo, csr_to_ell, uniform_partition
from .exciton import Exciton
from .hubbard import Hubbard
from .hubnet import HubNet
from .roadnet import RoadNet
from .spinchain import SpinChainXXZ
from .topins import TopIns

__all__ = [
    "MatrixFamily",
    "available_families",
    "get_family",
    "CSR",
    "csr_from_coo",
    "csr_to_ell",
    "uniform_partition",
    "Exciton",
    "Hubbard",
    "HubNet",
    "RoadNet",
    "SpinChainXXZ",
    "TopIns",
]

"""Matrix-free / structured device formats built from the generators.

``dia_from_family`` extracts the diagonal-offset (DIA) representation used
by the flagship Pallas kernel (kernels/cheb_dia.py): lattice Hamiltonians
(Exciton, TopIns) are unions of a few dozen shifted diagonals, so the
SpMMV becomes gather-free shifted FMAs — the TPU-native reformulation of
SELL-C-sigma (DESIGN.md §3).

``iter_row_entries`` / ``collect_row_entries`` are the **windowed
generator protocol** for streaming-scale instances (D ≥ 10⁷): a family's
``row_entries`` is called on bounded windows of the requested rows, so no
caller ever materializes one giant whole-shard COO temporary — this is
how ``build_dist_ell`` builds each shard's ELL block for matrix-free
RoadNet/HubNet without an explicit CSR anywhere (the pattern exists only
as generator output, window by window). The concatenated result carries
exactly the same (row, col, value) multiset as a single ``row_entries``
call — entry *order* may differ across window sizes, which downstream
consumers must not rely on (``build_dist_ell`` lexsorts per shard, so the
built operator is bit-identical for every window size).
"""
from __future__ import annotations

import numpy as np

from .families import MatrixFamily

#: Default window (rows per generator call) of the streamed protocol —
#: big enough to amortize the per-call vectorization, small enough that
#: a ~10-entry/row family's per-window temporaries stay a few MB.
DEFAULT_WINDOW = 262_144


def iter_row_entries(fam: MatrixFamily, rows: np.ndarray,
                     window: int = DEFAULT_WINDOW):
    """Yield ``(row_idx, col_idx, values)`` chunks of ``rows``, at most
    ``window`` rows per generator call."""
    rows = np.asarray(rows, dtype=np.int64)
    for lo in range(0, max(len(rows), 1), window):
        yield fam.row_entries(rows[lo: lo + window])


def collect_row_entries(fam: MatrixFamily, rows: np.ndarray,
                        window: int = DEFAULT_WINDOW):
    """``row_entries`` of ``rows`` via windowed generator calls.

    Same (row, col, value) multiset as one whole-set call — order may
    differ (each window emits its own diagonal/band/corridor segments),
    and per-call temporaries are bounded by ``window`` rows instead of
    ``len(rows)``.
    """
    rows = np.asarray(rows, dtype=np.int64)
    if len(rows) <= window:
        return fam.row_entries(rows)
    parts = list(iter_row_entries(fam, rows, window))
    rs, cs, vs = zip(*parts)
    return np.concatenate(rs), np.concatenate(cs), np.concatenate(vs)


def dia_from_family(fam: MatrixFamily, pad_to: int = 8, rows: slice | None = None,
                    max_diags: int = 128):
    """Extract (offsets, dvals [n_diag, R_pad], R_pad) for a row block.

    ``rows`` selects a contiguous block (default: all rows). Offsets are
    col - row; entries whose target falls outside the block land on the
    same offsets (the caller provides x with halo so i + off indexes it).
    """
    lo = rows.start if rows else 0
    hi = rows.stop if rows else fam.D
    r, c, v = collect_row_entries(fam, np.arange(lo, hi, dtype=np.int64))
    off = c - r
    offsets = np.unique(off)
    if len(offsets) > max_diags:
        raise ValueError(
            f"{fam.name}: {len(offsets)} distinct diagonals — not DIA-structured"
        )
    R = hi - lo
    R_pad = -(-R // pad_to) * pad_to
    dtype = np.complex64 if fam.is_complex else np.float32
    dvals = np.zeros((len(offsets), R_pad), dtype=dtype)
    pos = np.searchsorted(offsets, off)
    dvals[pos, r - lo] = v.astype(dtype)
    return [int(o) for o in offsets], dvals, R_pad

"""Matrix-free / structured device formats built from the generators.

``dia_from_family`` extracts the diagonal-offset (DIA) representation used
by the flagship Pallas kernel (kernels/cheb_dia.py): lattice Hamiltonians
(Exciton, TopIns) are unions of a few dozen shifted diagonals, so the
SpMMV becomes gather-free shifted FMAs — the TPU-native reformulation of
SELL-C-sigma (DESIGN.md §3).
"""
from __future__ import annotations

import numpy as np

from .families import MatrixFamily


def dia_from_family(fam: MatrixFamily, pad_to: int = 8, rows: slice | None = None,
                    max_diags: int = 128):
    """Extract (offsets, dvals [n_diag, R_pad], R_pad) for a row block.

    ``rows`` selects a contiguous block (default: all rows). Offsets are
    col - row; entries whose target falls outside the block land on the
    same offsets (the caller provides x with halo so i + off indexes it).
    """
    lo = rows.start if rows else 0
    hi = rows.stop if rows else fam.D
    r, c, v = fam.row_entries(np.arange(lo, hi, dtype=np.int64))
    off = c - r
    offsets = np.unique(off)
    if len(offsets) > max_diags:
        raise ValueError(
            f"{fam.name}: {len(offsets)} distinct diagonals — not DIA-structured"
        )
    R = hi - lo
    R_pad = -(-R // pad_to) * pad_to
    dtype = np.complex64 if fam.is_complex else np.float32
    dvals = np.zeros((len(offsets), R_pad), dtype=dtype)
    pos = np.searchsorted(offsets, off)
    dvals[pos, r - lo] = v.astype(dtype)
    return [int(o) for o in offsets], dvals, R_pad

"""HubNet matrix — a hub-and-spoke "airline" graph Laplacian that is the
worst case for the *cyclic* neighbor schedule and the showcase for the
*matching* schedule (``core/spmv.py schedule="matching"``).

The graph is a 1-D chain with a w-wide band (node i touches i ± 1..w —
the light "regional" traffic) plus ``h`` *hub airports*: disjoint node
regions of ``m`` nodes each, placed at pseudo-random positions along the
chain and linked into a single pseudo-random cycle of dense *corridors*
(hub i's region ↔ the next hub's region, k involutive bipartite edges
per node — the same closed-form construction as RoadNet's commuter
corridor, one corridor per consecutive hub pair).

Under the engine's uniform row partition each corridor concentrates
~m distinct remote columns on the one block that owns its endpoint
region, while every other pair of blocks only exchanges its band
boundary:

  * few hot receivers — only the h hub blocks carry corridor traffic,
    so χ₃ = N_p·max_p n_vc/D exceeds χ₂ = Σ_p n_vc/D by ≈ N_p/h
    (χ₃/χ₂ ≫ 1 for h ≪ N_p),
  * the corridors land on *many distinct cyclic shifts* (pseudo-random
    hub placement), so the cyclic schedule pays one full ~m-sized round
    per corridor shift: ``H_cyclic ≈ min(2h, N_p-1)·m`` saturates toward
    the padded a2a's ``N_p·m`` — per-round padding buys almost nothing
    here,
  * the hub blocks are (mostly) pairwise distinct and the corridor
    cycle visits each region once as source and once as destination, so
    a matching packs *all* forward corridors into one permutation round
    and all backward corridors into another: ``H_matching ≈ 2m + 2w``,
    beating cyclic by ≈ h.

That makes HubNet the family where ``--layout auto`` demonstrably picks
``schedule="matching"``: the greedy matching decomposition recovers the
factor h that both the padded all_to_all (χ₃) and the cyclic rounds
(one round per shift) leave on the wire.

The corridors are deterministic and involutive so any row chunk
generates its own pattern in O(k) per row: source node ``c_i + s`` links
to ``c_j + (a·s + b_t) mod m`` for k fixed offsets b_t (a coprime to m),
and destination node ``c_j + d`` links back to
``c_i + a⁻¹·(d - b_t) mod m``. Values are the graph Laplacian
(diag = degree, off-diag = -1), symmetric real with spectrum in
[0, 2·max_degree].
"""
from __future__ import annotations

import numpy as np

from .families import MatrixFamily, register


@register
class HubNet(MatrixFamily):
    name = "HubNet"
    is_complex = False

    def __init__(self, n: int = 48000, w: int = 2, h: int = 5,
                 m: int = 512, k: int = 4, seed: int = 1):
        self.n = int(n)
        self.w = int(w)
        self.h = int(h)
        self.m = int(m)
        self.k = int(k)
        if self.h < 3:
            raise ValueError("need h >= 3 hubs (a 2-cycle would duplicate "
                             "corridor edges)")
        if self.m < 2:
            raise ValueError("need m >= 2 nodes per hub region (the "
                             "corridor multiplier needs a nontrivial "
                             "residue ring)")
        if not 1 <= self.k <= self.m:
            raise ValueError("need 1 <= k <= m corridor edges per node")
        rng = np.random.default_rng(seed)
        # pseudo-random hub placement with gaps wide enough that regions
        # are disjoint and band edges never reach a foreign region
        gap = self.m + self.w
        if self.h * (self.m + gap) >= self.n:
            raise ValueError(f"n={self.n} too small for {self.h} disjoint "
                             f"hub regions of m={self.m}")
        for _ in range(1000):
            pos = np.sort(rng.integers(0, self.n - self.m, size=self.h))
            if (np.diff(pos) > gap).all():
                break
        else:  # pragma: no cover - the size guard above makes this rare
            raise ValueError("could not place disjoint hub regions")
        self.pos = pos
        # one pseudo-random cycle over the hubs: region order[j] sends a
        # corridor to region order[j+1] — every region is the source of
        # exactly one corridor and the destination of exactly one
        order = rng.permutation(self.h)
        self.corridors = tuple(
            (int(order[j]), int(order[(j + 1) % self.h]))
            for j in range(self.h))
        # multiplier coprime to m scatters each source node's k links
        # across the whole destination region (no accidental locality)
        a = int(rng.integers(1, self.m))
        while np.gcd(a, self.m) != 1:
            a = int(rng.integers(1, self.m))
        self.a = a
        self.a_inv = pow(a, -1, self.m)
        self.b = np.sort(rng.choice(self.m, size=self.k, replace=False))
        # corridor span bounds |col - row| (windows the exact χ scan)
        self.reach = int(max(abs(int(self.pos[j]) - int(self.pos[i]))
                             for i, j in self.corridors) + self.m)

    @property
    def D(self) -> int:
        return self.n

    # -------------------------------------------------------- pattern ----

    def _corridor(self, rows: np.ndarray):
        """Yield (row_sel, cols) corridor edges incident to ``rows`` —
        both directions of every corridor, via the involutive map."""
        for i, j in self.corridors:
            ci, cj = int(self.pos[i]), int(self.pos[j])
            src = (rows >= ci) & (rows < ci + self.m)
            if src.any():
                s = rows[src] - ci
                for t in range(self.k):
                    yield rows[src], cj + (self.a * s + self.b[t]) % self.m
            dst = (rows >= cj) & (rows < cj + self.m)
            if dst.any():
                d = rows[dst] - cj
                for t in range(self.k):
                    yield rows[dst], ci + (self.a_inv * (d - self.b[t])) % self.m

    def _in_region(self, rows: np.ndarray) -> np.ndarray:
        hit = np.zeros(len(rows), dtype=bool)
        for c in self.pos:
            hit |= (rows >= c) & (rows < c + self.m)
        return hit

    def row_cols(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        out_r, out_c = [rows], [rows]  # Laplacian diagonal
        for d in range(1, self.w + 1):
            for sgn in (-1, 1):
                c = rows + sgn * d
                sel = (c >= 0) & (c < self.n)
                out_r.append(rows[sel])
                out_c.append(c[sel])
        for r, c in self._corridor(rows):
            out_r.append(r)
            out_c.append(c)
        return np.concatenate(out_r), np.concatenate(out_c)

    def row_entries(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        # degree = band neighbors (clipped at the chain ends) + corridors
        deg = (np.minimum(rows + self.w, self.n - 1)
               - np.maximum(rows - self.w, 0)).astype(np.float64)
        deg += 2 * self.k * self._in_region(rows)
        out_r, out_c, out_v = [rows], [rows], [deg]
        for d in range(1, self.w + 1):
            for sgn in (-1, 1):
                c = rows + sgn * d
                sel = (c >= 0) & (c < self.n)
                out_r.append(rows[sel])
                out_c.append(c[sel])
                out_v.append(np.full(int(sel.sum()), -1.0))
        for r, c in self._corridor(rows):
            out_r.append(r)
            out_c.append(c)
            out_v.append(np.full(len(r), -1.0))
        return (np.concatenate(out_r), np.concatenate(out_c),
                np.concatenate(out_v))

    def est_nnz(self, probe_rows: int = 4096) -> int:
        """Exact closed form: diagonal + end-clipped band + h corridors
        of 2·m·k entries each (every region is one corridor's source and
        another's destination)."""
        return (self.n + 2 * self.w * self.n - self.w * (self.w + 1)
                + 2 * self.h * self.m * self.k)

    def spectral_bounds_hint(self):
        return (0.0, 2.0 * (2 * self.w + 2 * self.k))

    def describe(self) -> str:
        return (f"HubNet,n={self.n},w={self.w},h={self.h},m={self.m},"
                f"k={self.k} (D={self.D})")

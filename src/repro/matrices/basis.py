"""Occupation-number basis utilities for many-body matrices (Hubbard, SpinChainXXZ).

Configurations of ``k`` particles on ``n`` sites are represented as n-bit
masks. The basis is ordered by *increasing numeric value* of the mask (the
standard combinadic / combinatorial-number-system order, which is what
ScaMaC-style generators use). Rank/unrank are fully vectorized so that
bases with 1e8+ configurations can be processed in chunks.
"""
from __future__ import annotations

import numpy as np
from functools import lru_cache

__all__ = [
    "binom_table",
    "enumerate_masks",
    "rank_masks",
    "unrank",
    "hop_neighbors",
]


@lru_cache(maxsize=None)
def binom_table(n_max: int) -> np.ndarray:
    """(n_max+1, n_max+1) table of binomial coefficients C[n, k] in int64."""
    C = np.zeros((n_max + 1, n_max + 1), dtype=np.int64)
    C[:, 0] = 1
    for n in range(1, n_max + 1):
        for k in range(1, n + 1):
            C[n, k] = C[n - 1, k - 1] + C[n - 1, k]
    return C


def enumerate_masks(n: int, k: int) -> np.ndarray:
    """All n-bit masks with popcount k, in increasing numeric order.

    Only intended for small bases (C(n,k) ≲ 2e7); larger bases should be
    processed through :func:`unrank` in chunks.
    """
    C = binom_table(n)
    D = int(C[n, k])
    return unrank(np.arange(D, dtype=np.int64), n, k)


def rank_masks(masks: np.ndarray, n: int, k: int) -> np.ndarray:
    """Rank of each mask in the increasing-numeric-order C(n,k) basis.

    Vectorized combinadic ranking: rank(m) = sum over set bits at position p
    (with c set bits at positions <= p) of C(p, c).
    """
    C = binom_table(n)
    masks = np.asarray(masks, dtype=np.int64)
    rank = np.zeros(masks.shape, dtype=np.int64)
    c = np.zeros(masks.shape, dtype=np.int64)
    for p in range(n):
        bit = (masks >> p) & 1
        c += bit
        # C[p, c] contribution where the bit is set
        rank += np.where(bit == 1, C[p, np.minimum(c, p + 1)], 0)
    return rank


def unrank(ranks: np.ndarray, n: int, k: int) -> np.ndarray:
    """Inverse of :func:`rank_masks` (vectorized greedy combinadic unrank)."""
    C = binom_table(n)
    r = np.asarray(ranks, dtype=np.int64).copy()
    masks = np.zeros(r.shape, dtype=np.int64)
    kk = np.full(r.shape, k, dtype=np.int64)
    for p in range(n - 1, -1, -1):
        # set bit p iff C(p, kk) <= r (and kk > 0)
        c = C[p, np.minimum(kk, p + 1)]
        take = (kk > 0) & (r >= c) & (kk <= p + 1)
        r = np.where(take, r - c, r)
        masks = np.where(take, masks | (np.int64(1) << p), masks)
        kk = np.where(take, kk - 1, kk)
    return masks


def hop_neighbors(masks: np.ndarray, n: int, k: int, periodic: bool = False):
    """Nearest-neighbour hop targets on a 1-D chain.

    For every mask and bond (i, i+1) with differing occupations, the hop
    swaps the two bits: target = mask XOR (2^i | 2^{i+1}).

    Returns ``(src_idx, tgt_masks, bond)`` where ``src_idx`` indexes into
    ``masks``. Open boundary conditions by default (matches ScaMaC
    n_nzr = n_sites at half filling: (n_s-1) bonds, plus stored diagonal
    only when an interaction/potential term is enabled).
    """
    masks = np.asarray(masks, dtype=np.int64)
    src_list, tgt_list, bond_list = [], [], []
    bonds = n if periodic else n - 1
    for b in range(bonds):
        i, j = b, (b + 1) % n
        flip = (np.int64(1) << i) | (np.int64(1) << j)
        bi = (masks >> i) & 1
        bj = (masks >> j) & 1
        sel = np.nonzero(bi != bj)[0]
        src_list.append(sel)
        tgt_list.append(masks[sel] ^ flip)
        bond_list.append(np.full(sel.shape, b, dtype=np.int32))
    return (
        np.concatenate(src_list),
        np.concatenate(tgt_list),
        np.concatenate(bond_list),
    )

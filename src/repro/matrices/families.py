"""Matrix family base class — ScaMaC-equivalent scalable matrices.

Each family provides:
  * ``build_csr()``    — explicit CSR (values + pattern) for instances that
                         fit in host memory (used for tests, small solves),
  * ``row_cols(rows)`` — vectorized sparsity-pattern generation for a chunk
                         of row indices (used for exact χ counting at full
                         scale without materializing the matrix),
  * ``n_vc(boundaries)`` — exact number of *distinct remote* column indices
                         per row block (Eq. 5 of the paper). The generic
                         implementation streams ``row_cols`` in chunks;
                         families with tensor-product structure (Hubbard)
                         override it with an O(D_spin) exact computation.
"""
from __future__ import annotations

import abc
import numpy as np

from .sparse import CSR, uniform_partition

_REGISTRY: dict[str, type] = {}


def register(cls):
    _REGISTRY[cls.name] = cls
    return cls


def get_family(name: str, **params):
    return _REGISTRY[name](**params)


def available_families():
    return sorted(_REGISTRY)


class MatrixFamily(abc.ABC):
    """A scalable sparse Hermitian matrix defined by its generator."""

    name: str = "abstract"
    #: True if matrix entries are complex (S_d = 16), else real (S_d = 8)
    is_complex: bool = False

    @property
    @abc.abstractmethod
    def D(self) -> int:
        ...

    @abc.abstractmethod
    def row_cols(self, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Return (row_idx, col_idx) COO pattern entries for the given rows.

        ``row_idx`` repeats entries of ``rows``; both int64. Must be exact
        (no duplicates within a row required, duplicates are tolerated by
        the distinct-count logic).
        """

    @abc.abstractmethod
    def row_entries(self, rows: np.ndarray) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Return (row_idx, col_idx, values) for the given rows."""

    #: max |col - row| the pattern can reach, or None if unbounded.
    reach: int | None = None

    @property
    def S_d(self) -> int:
        return 16 if self.is_complex else 8

    def build_csr(self, max_D: int = 50_000_000) -> CSR:
        if self.D > max_D:
            raise MemoryError(f"{self.name}: D={self.D} too large for explicit CSR")
        from .sparse import csr_from_coo

        rows, cols, vals = self.row_entries(np.arange(self.D, dtype=np.int64))
        return csr_from_coo(rows, cols, vals, (self.D, self.D))

    # ---------------------------------------------------------------- χ --

    def n_vc(self, boundaries: np.ndarray, chunk: int = 2_000_000) -> np.ndarray:
        """Exact distinct-remote-column count per block (Eq. 5), streamed."""
        boundaries = np.asarray(boundaries, dtype=np.int64)
        P = len(boundaries) - 1
        out = np.zeros(P, dtype=np.int64)
        for p in range(P):
            a, b = int(boundaries[p]), int(boundaries[p + 1])
            remote: list[np.ndarray] = []
            for lo, hi in self._scan_ranges(a, b):
                for c0 in range(lo, hi, chunk):
                    c1 = min(c0 + chunk, hi)
                    _, cols = self.row_cols(np.arange(c0, c1, dtype=np.int64))
                    cols = cols[(cols < a) | (cols >= b)]
                    if cols.size:
                        remote.append(np.unique(cols))
            out[p] = np.unique(np.concatenate(remote)).size if remote else 0
        return out

    def _scan_ranges(self, a: int, b: int):
        """Row sub-ranges of [a,b) that can produce remote columns."""
        if self.reach is None or (b - a) <= 2 * self.reach:
            return [(a, b)]
        return [(a, a + self.reach), (b - self.reach, b)]

    def n_vm(self, boundaries: np.ndarray) -> np.ndarray:
        """Local vector entries per block; = block size (Eq. 3 note)."""
        boundaries = np.asarray(boundaries, dtype=np.int64)
        return np.diff(boundaries)

    def est_nnz(self, probe_rows: int = 4096) -> int:
        """Estimated stored entries of the whole matrix — a deterministic
        evenly-spaced row probe scaled to D (exact when the probe covers
        every row). The streaming planner's benchmarks normalize planning
        time by this without a pattern pass; families with closed-form
        counts (RoadNet, HubNet) override it exactly."""
        n = min(self.D, int(probe_rows))
        rows = np.unique(np.linspace(0, self.D - 1, max(n, 1)).astype(np.int64))
        r, _ = self.row_cols(rows)
        return int(round(len(r) * self.D / max(len(rows), 1)))

    # ------------------------------------------------------------ values --

    def spectral_bounds_hint(self) -> tuple[float, float] | None:
        """Optional analytic inclusion interval (else Lanczos computes it)."""
        return None

    def describe(self) -> str:
        return f"{self.name}(D={self.D})"

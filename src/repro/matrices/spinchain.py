"""SpinChainXXZ matrix — ScaMaC-pattern-equivalent generator.

XXZ spin-1/2 chain (open boundaries) in the fixed-magnetization sector with
``n_up`` up-spins on ``n_sites`` sites:

    H = sum_b [ (Jxy/2)(S+_i S-_{i+1} + h.c.) + Jz Sz_i Sz_{i+1} ]

Basis: configurations in increasing-bitmask (combinadic) order, dimension
D = C(n_sites, n_up). Reproduces Table 5 exactly: n_nzr = (n_sites-1)+1 at
half filling with the Jz diagonal stored (13 @ 24/12, 16 @ 30/15).

Hop-target ranks are computed with the O(1) combinadic rank-delta trick
(no unranking of targets), which lets the exact χ metric stream over
D ~ 1.5e8 bases in minutes.
"""
from __future__ import annotations

import numpy as np

from .basis import binom_table, unrank
from .families import MatrixFamily, register


@register
class SpinChainXXZ(MatrixFamily):
    name = "SpinChainXXZ"
    is_complex = False

    def __init__(self, n_sites: int = 8, n_up: int = 4, Jxy: float = 1.0, Jz: float = 1.0):
        self.n_sites, self.n_up = int(n_sites), int(n_up)
        self.Jxy, self.Jz = float(Jxy), float(Jz)
        self._C = binom_table(self.n_sites)
        self.reach = None  # rank jumps can span the basis

    @property
    def D(self) -> int:
        return int(self._C[self.n_sites, self.n_up])

    # -------------------------------------------------------- pattern ----

    def _hops(self, rows: np.ndarray, masks: np.ndarray):
        """Yield (sel, target_rank) per bond using the rank-delta formula.

        Swapping occupations across bond (i, i+1) changes the combinadic
        rank by ±(C(i+1, c) - C(i, c)) with c = popcount(mask & low(i+2)).
        """
        n = self.n_sites
        C = self._C
        for i in range(n - 1):
            bi = (masks >> i) & 1
            bj = (masks >> (i + 1)) & 1
            sel = np.nonzero(bi != bj)[0]
            if sel.size == 0:
                continue
            m = masks[sel]
            lowmask = (np.int64(1) << (i + 2)) - 1
            c = np.bitwise_count((m & lowmask).astype(np.uint64)).astype(np.int64)
            delta = C[i + 1, c] - C[i, c]
            up_move = ((m >> i) & 1) == 1  # bit moves i -> i+1: rank += delta
            tgt = rows[sel] + np.where(up_move, delta, -delta)
            yield sel, tgt

    def row_cols(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        masks = unrank(rows, self.n_sites, self.n_up)
        out_r = [rows]  # Jz diagonal
        out_c = [rows]
        for sel, tgt in self._hops(rows, masks):
            out_r.append(rows[sel])
            out_c.append(tgt)
        return np.concatenate(out_r), np.concatenate(out_c)

    def row_entries(self, rows: np.ndarray):
        rows = np.asarray(rows, dtype=np.int64)
        masks = unrank(rows, self.n_sites, self.n_up)
        # diagonal: Jz * sum_b (n_i - 1/2)(n_{i+1} - 1/2)
        diag = np.zeros(len(rows))
        for i in range(self.n_sites - 1):
            zi = ((masks >> i) & 1).astype(np.float64) - 0.5
            zj = ((masks >> (i + 1)) & 1).astype(np.float64) - 0.5
            diag += self.Jz * zi * zj
        out_r, out_c, out_v = [rows], [rows], [diag]
        for sel, tgt in self._hops(rows, masks):
            out_r.append(rows[sel])
            out_c.append(tgt)
            out_v.append(np.full(sel.shape, 0.5 * self.Jxy))
        return np.concatenate(out_r), np.concatenate(out_c), np.concatenate(out_v)

    def spectral_bounds_hint(self):
        nb = self.n_sites - 1
        w = 0.5 * abs(self.Jxy) * nb + 0.25 * abs(self.Jz) * nb
        return (-w, w)

    def describe(self) -> str:
        return f"SpinChainXXZ,n_sites={self.n_sites},n_up={self.n_up} (D={self.D})"

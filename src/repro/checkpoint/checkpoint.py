"""Fault-tolerant sharded checkpointing (no orbax dependency).

Layout on disk:
    <dir>/step_<n>/manifest.json      — pytree structure, shapes, dtypes,
                                        PartitionSpecs, mesh shape, step,
                                        data-pipeline state
    <dir>/step_<n>/arr_<i>.npy        — one file per leaf (host-gathered on
                                        this single-host container; on a
                                        real cluster each host writes its
                                        addressable shards — the format is
                                        the same, keyed by shard index)
    <dir>/step_<n>/_COMMITTED         — atomic-commit marker written last

Restart semantics:
  * restore() ignores uncommitted (crashed mid-write) checkpoints,
  * **elastic restart**: the target mesh may have a different shape than
    the one that saved — leaves are re-sharded from the logical array
    (the manifest stores logical shapes, so any mesh works),
  * step auto-discovery: restore(dir) loads the newest committed step.
"""
from __future__ import annotations

import json
import os
import shutil

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _spec_to_json(spec) -> list:
    out = []
    for a in (tuple(spec) if spec is not None else ()):
        if a is None:
            out.append(None)
        elif isinstance(a, tuple):
            out.append(list(a))
        else:
            out.append(a)
    return out


def _spec_from_json(j) -> P:
    return P(*[tuple(a) if isinstance(a, list) else a for a in j])


def _mesh_of(leaves) -> dict | None:
    """Axis names/sizes of the saving mesh, from the first leaf with a
    NamedSharding. The manifest's elastic-restart claim needs this on
    disk: a restore onto a *larger* mesh (grow) must be able to tell it
    re-sharded, and debugging a failed elastic restore needs to know
    what shape wrote the step."""
    for leaf in leaves:
        mesh = getattr(getattr(leaf, "sharding", None), "mesh", None)
        if mesh is not None and hasattr(mesh, "axis_names"):
            return {"axes": list(mesh.axis_names),
                    "shape": [int(mesh.shape[a]) for a in mesh.axis_names]}
    return None


def save(directory: str, step: int, tree, specs=None, extra: dict | None = None):
    """Write a committed checkpoint of ``tree`` at ``step``."""
    path = os.path.join(directory, f"step_{step:08d}")
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    spec_leaves = (jax.tree_util.tree_flatten(specs)[0]
                   if specs is not None else [None] * len(leaves))
    meta = {"step": step, "n_leaves": len(leaves),
            "treedef": str(treedef), "extra": extra or {},
            "mesh": _mesh_of(leaves), "leaves": []}
    for i, (leaf, sp) in enumerate(zip(leaves, spec_leaves)):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        meta["leaves"].append({
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "spec": _spec_to_json(sp) if sp is not None else None,
        })
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.replace(tmp, path)
    with open(os.path.join(path, "_COMMITTED"), "w") as f:
        f.write("ok")
    return path


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name, "_COMMITTED")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, target_tree, mesh: Mesh | None = None,
            specs=None, step: int | None = None):
    """Load a checkpoint onto ``mesh`` (possibly a different shape than the
    saving mesh — elastic restart). ``target_tree`` provides the pytree
    structure. Returns (tree, step, extra)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    leaves, treedef = jax.tree_util.tree_flatten(target_tree)
    assert len(leaves) == meta["n_leaves"], "pytree structure changed"
    spec_leaves = (jax.tree_util.tree_flatten(specs)[0]
                   if specs is not None else [None] * len(leaves))
    out = []
    for i, (leaf, sp, lm) in enumerate(zip(leaves, spec_leaves, meta["leaves"])):
        arr = np.load(os.path.join(path, f"arr_{i}.npy"))
        assert list(arr.shape) == lm["shape"]
        if mesh is not None:
            use = sp if sp is not None else (
                _spec_from_json(lm["spec"]) if lm["spec"] is not None else P())
            out.append(jax.device_put(arr, NamedSharding(mesh, use)))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step, meta["extra"]


class CheckpointManager:
    """Keep the last ``keep`` committed checkpoints, save every
    ``interval`` steps; survives being pointed at a half-written dir."""

    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.directory = directory
        self.interval = interval
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def maybe_save(self, step: int, tree, specs=None, extra=None) -> bool:
        if step % self.interval:
            return False
        save(self.directory, step, tree, specs, extra)
        self._gc()
        return True

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.directory, n, "_COMMITTED"))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

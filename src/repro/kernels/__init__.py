"""Pallas TPU kernels and their jnp reference implementations.

``ops.py`` dispatches between the Pallas kernels (``ell_gather.py`` ELL
SpMV, ``cheb_dia.py`` fused DIA Chebyshev step) and the pure-jnp
references in ``ref.py`` — the distributed engine (``core/spmv.py``)
calls through ``ops.ell_spmv`` / ``ops.ell_spmv_split`` when built with
``use_kernel=True``.
"""

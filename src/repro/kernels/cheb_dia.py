"""Pallas TPU kernel: fused Chebyshev step for DIA (diagonal-offset) SpMMV.

TPU adaptation of the paper's fused SpMV+axpy kernel (Alg. 2 step 7,
Kreutzer et al. [19]). On CPU the fused kernel exists to keep the vector
traffic factor at κ=5; on TPU we additionally re-think the *format*:

  * The flagship matrices (Exciton/TopIns stencils, and the paper's class
    of lattice Hamiltonians generally) are unions of a few dozen shifted
    diagonals. SELL-C-σ's row sorting serves CPU SIMD lanes; on TPU the
    lane dimension is the *vector block* (n_b >= 128 after padding), so
    gather-free shifted-diagonal FMAs on (8,128) VREG tiles are the
    natural format: every op is a static-stride VMEM load + FMA, the MXU
    is bypassed (SpMMV is bandwidth-bound) and the VPU streams at b_m.

  * Grid = (row blocks, n_b blocks, diagonals), accumulating over the
    innermost diagonal axis into the output block (whose index map is
    constant along that axis, so the block is revisited consecutively).
    The x operand is passed twice with diagonal-dependent index maps
    (aligned blocks k and k+1) so an unaligned offset is assembled from
    two aligned VMEM tiles with one dynamic sublane slice — no HBM gather
    exists on the critical path.

  * The fused epilogue 2a*(A x) + 2b*w1 - w2 runs on the last diagonal,
    so W2 is read exactly once from HBM (κ = 5, not 6 — paper §3.2).

Block sizes: BR rows (multiple of 8 sublanes) x BN vector columns
(multiple of 128 lanes). VMEM footprint/step ≈ (2 x-tiles + w1 + w2 + out
+ slice temp) * BR * BN * 4B ≈ 6 * 512 * 256 * 4B ≈ 3.1 MiB « 16 MiB.

Complex matrices are handled in ops.py by splitting into real/imag DIA
planes (TPU has no native complex VREG type); this kernel is real-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific grid spec (scalar prefetch); absent on some backends
    from jax.experimental.pallas import tpu as pltpu

    _GRID_SPEC = pltpu.PrefetchScalarGridSpec
except Exception:  # pragma: no cover
    _GRID_SPEC = None

DEFAULT_BR = 512
DEFAULT_BN = 256


def _kernel(off_blk, off_in, ab, dvals, x0, x1, w1, w2, out, *, n_diag, br):
    d = pl.program_id(2)

    @pl.when(d == 0)
    def _init():
        out[...] = jnp.zeros_like(out)

    shift = off_in[d]
    xx = jnp.concatenate([x0[...], x1[...]], axis=0)
    xs = jax.lax.dynamic_slice_in_dim(xx, shift, br, axis=0)
    dv = dvals[0, :]
    out[...] += dv[:, None] * xs

    @pl.when(d == n_diag - 1)
    def _epilogue():
        a2 = 2.0 * ab[0]
        b2 = 2.0 * ab[1]
        out[...] = a2 * out[...] + b2 * w1[...] - w2[...]


@functools.partial(jax.jit, static_argnames=("offsets", "br", "bn", "interpret"))
def cheb_dia(
    offsets: tuple[int, ...],
    dvals: jax.Array,  # [n_diag, R] per-diagonal values (0 where invalid)
    x: jax.Array,      # [Rx, nb], Rx >= R (halo may be appended)
    w1: jax.Array,     # [R, nb]
    w2: jax.Array,     # [R, nb]
    alpha,
    beta,
    br: int = DEFAULT_BR,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
):
    """y = 2a*(A@x) + 2b*w1 - w2 for the DIA matrix given by (offsets, dvals).

    Rows where i + offset falls outside [0, Rx) must carry dvals == 0 (the
    host builder guarantees this); their x tiles are clamped loads whose
    contribution is multiplied by zero.
    """
    n_diag = len(offsets)
    R, nb = w1.shape
    Rx = x.shape[0]
    assert R % br == 0 and nb % bn == 0, (R, nb, br, bn)
    assert Rx % br == 0
    nxb = Rx // br
    off_blk = jnp.asarray([o // br for o in offsets], jnp.int32)
    off_in = jnp.asarray([o % br for o in offsets], jnp.int32)
    ab = jnp.stack([jnp.asarray(alpha, dvals.dtype), jnp.asarray(beta, dvals.dtype)])

    grid = (R // br, nb // bn, n_diag)

    def x_map(k):  # k = 0 or 1: aligned block at floor(offset/br) + k, clamped
        def im(rb, cb, d, off_blk_ref, off_in_ref, ab_ref):
            blk = rb + off_blk_ref[d] + k
            blk = jnp.clip(blk, 0, nxb - 1)
            return blk, cb

        return im

    kernel = functools.partial(_kernel, n_diag=n_diag, br=br)
    if _GRID_SPEC is not None:
        grid_spec = _GRID_SPEC(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, br), lambda rb, cb, d, *_: (d, rb)),  # dvals
                pl.BlockSpec((br, bn), x_map(0)),
                pl.BlockSpec((br, bn), x_map(1)),
                pl.BlockSpec((br, bn), lambda rb, cb, d, *_: (rb, cb)),  # w1
                pl.BlockSpec((br, bn), lambda rb, cb, d, *_: (rb, cb)),  # w2
            ],
            out_specs=pl.BlockSpec((br, bn), lambda rb, cb, d, *_: (rb, cb)),
        )
        return pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((R, nb), w1.dtype),
            interpret=interpret,
        )(off_blk, off_in, ab, dvals, x, x, w1, w2)
    raise NotImplementedError("PrefetchScalarGridSpec unavailable")

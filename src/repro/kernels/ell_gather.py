"""Pallas TPU kernel: blocked ELL SpMMV with VMEM gather (irregular matrices).

For matrices that are not diagonal-structured (the Hubbard dn-sector hop
graph, SpinChainXXZ), the local contraction y[r] = Σ_w vals[r,w] x[cols[r,w]]
needs a gather. TPU adaptation: the gather must be VMEM-resident, so the
host pre-buckets each row block's entries by *column block* (tile format:
row-block x col-block ELL with tile-local columns). The kernel grid is
(row blocks, n_b blocks, tiles); each step loads one x column-block into
VMEM and gathers rows from it with `jnp.take` along the sublane axis.

Caveat recorded in DESIGN.md: Mosaic's sublane dynamic-gather support is
newer than the rest of the ops used here; the kernel is validated in
interpret mode on CPU (this container) and the ops.py dispatcher keeps the
scan-of-gathers jnp path as the fallback on real hardware.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _GRID_SPEC = pltpu.PrefetchScalarGridSpec
except Exception:  # pragma: no cover
    _GRID_SPEC = None

DEFAULT_BR = 256
DEFAULT_BC = 2048  # x rows per column block resident in VMEM
DEFAULT_BN = 128


def build_tiles(cols: np.ndarray, vals: np.ndarray, Rx: int, br: int, bc: int):
    """Re-bucket an ELL block [R, W] into (row-block x col-block) tiles.

    Returns (tile_cb [RB, T], tcols [RB, T, br, Wt], tvals [...]) where T is
    the padded tile count and Wt the padded per-tile width. Padded entries
    point at tile-local column 0 with value 0.

    The tiling is *order-preserving for arbitrary slot orders*: each entry
    is placed in the earliest tile at-or-after its row's previously used
    tile whose column block matches (a new tile is opened otherwise), so
    every row visits its tiles — and its slots inside each tile — in the
    original slot order. The kernel's tile-major accumulation therefore
    reproduces the jnp scan's per-element addition chain bit-for-bit even
    when a row's columns are not monotone in column block (e.g. the
    re-based halo addresses of the compressed engines).
    """
    R, W = cols.shape
    RB = R // br
    tiles: list[list[tuple[int, np.ndarray, np.ndarray]]] = []
    T = 1
    Wt = 1
    for rb in range(RB):
        c = cols[rb * br : (rb + 1) * br]
        v = vals[rb * br : (rb + 1) * br]
        nz = v != 0
        cb_of = c // bc
        tile_cbs: list[int] = []           # column block of each tile, in order
        entries: list[list[tuple[int, int]]] = []  # per tile: (row, slot)
        last_t = np.full(br, -1, dtype=np.int64)
        for w in range(W):
            for r in np.nonzero(nz[:, w])[0]:
                cb = int(cb_of[r, w])
                lo = max(int(last_t[r]), 0)
                for t in range(lo, len(tile_cbs)):
                    if tile_cbs[t] == cb:
                        break
                else:
                    t = len(tile_cbs)
                    tile_cbs.append(cb)
                    entries.append([])
                entries[t].append((int(r), int(w)))
                last_t[r] = t
        row_tiles = []
        for cb, ent in zip(tile_cbs, entries):
            counts = np.zeros(br, dtype=np.int64)
            for r, _ in ent:
                counts[r] += 1
            w_t = int(counts.max())
            tc = np.zeros((br, w_t), dtype=np.int32)
            tv = np.zeros((br, w_t), dtype=vals.dtype)
            fill = np.zeros(br, dtype=np.int64)
            for r, w in ent:
                tc[r, fill[r]] = c[r, w] - cb * bc
                tv[r, fill[r]] = v[r, w]
                fill[r] += 1
            row_tiles.append((cb, tc, tv))
            Wt = max(Wt, w_t)
        T = max(T, len(row_tiles))
        tiles.append(row_tiles)
    tile_cb = np.zeros((RB, T), dtype=np.int32)
    tcols = np.zeros((RB, T, br, Wt), dtype=np.int32)
    tvals = np.zeros((RB, T, br, Wt), dtype=vals.dtype)
    for rb, row_tiles in enumerate(tiles):
        for t, (cb, tc, tv) in enumerate(row_tiles):
            tile_cb[rb, t] = cb
            tcols[rb, t, :, : tc.shape[1]] = tc
            tvals[rb, t, :, : tv.shape[1]] = tv
    return tile_cb, tcols, tvals


def _kernel(tile_cb, tcols, tvals, xblk, y0blk, out, *, n_tiles):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out[...] = y0blk[...]

    c = tcols[0, 0]  # [br, Wt] tile-local columns
    v = tvals[0, 0]
    xb = xblk[...]  # [bc, bn]

    # rolled slot loop, NOT an unrolled python loop: XLA compiles an
    # unrolled mul-add chain with FMA contraction (differently rounded),
    # while the rolled loop emits the same one-mul-one-add iteration body
    # as the engines' lax.scan — bit-identical accumulation
    def slot(w, acc):
        cw = jax.lax.dynamic_slice_in_dim(c, w, 1, axis=1)[:, 0]
        vw = jax.lax.dynamic_slice_in_dim(v, w, 1, axis=1)
        return acc + vw * jnp.take(xb, cw, axis=0)

    out[...] = jax.lax.fori_loop(0, c.shape[1], slot, out[...])


@functools.partial(jax.jit, static_argnames=("br", "bc", "bn", "interpret"))
def ell_gather_spmv(
    tile_cb: jax.Array,  # [RB, T] col-block index per tile (scalar prefetch)
    tcols: jax.Array,    # [RB, T, br, Wt]
    tvals: jax.Array,    # [RB, T, br, Wt]
    x: jax.Array,        # [Rx_pad, nb] (padded to multiple of bc)
    y0: jax.Array | None = None,  # [R, nb] accumulator threaded into the tiles
    br: int = DEFAULT_BR,
    bc: int = DEFAULT_BC,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
):
    """Tiled ELL contraction ``y0 + A @ x`` (``y0 = 0`` when omitted).

    The optional ``y0`` operand initializes each output block at tile 0,
    so a caller that has already accumulated (e.g. the split-phase local
    block before the halo block) THREADS its accumulator through the
    kernel: per output element the addition chain is y0, then the
    entries in the order-preserving tile sequence of :func:`build_tiles`
    — the same slot order as the jnp scan, which is what keeps kernel-on
    and kernel-off engines bit-identical.
    """
    RB, T, _, Wt = tcols.shape
    R = RB * br
    Rx, nb = x.shape
    assert Rx % bc == 0 and nb % bn == 0
    if y0 is None:
        y0 = jnp.zeros((R, nb), dtype=x.dtype)
    grid = (RB, nb // bn, T)
    if _GRID_SPEC is None:
        raise NotImplementedError
    grid_spec = _GRID_SPEC(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, br, Wt), lambda rb, cb, t, cbref: (rb, t, 0, 0)),
            pl.BlockSpec((1, 1, br, Wt), lambda rb, cb, t, cbref: (rb, t, 0, 0)),
            pl.BlockSpec((bc, bn), lambda rb, cb, t, cbref: (cbref[rb, t], cb)),
            pl.BlockSpec((br, bn), lambda rb, cb, t, cbref: (rb, cb)),
        ],
        out_specs=pl.BlockSpec((br, bn), lambda rb, cb, t, cbref: (rb, cb)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_tiles=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, nb), y0.dtype),
        interpret=interpret,
    )(tile_cb, tcols, tvals, x, y0)

"""Pallas TPU kernel: blocked ELL SpMMV with VMEM gather (irregular matrices).

For matrices that are not diagonal-structured (the Hubbard dn-sector hop
graph, SpinChainXXZ), the local contraction y[r] = Σ_w vals[r,w] x[cols[r,w]]
needs a gather. TPU adaptation: the gather must be VMEM-resident, so the
host pre-buckets each row block's entries by *column block* (tile format:
row-block x col-block ELL with tile-local columns). The kernel grid is
(row blocks, n_b blocks, tiles); each step loads one x column-block into
VMEM and gathers rows from it with `jnp.take` along the sublane axis.

Caveat recorded in DESIGN.md: Mosaic's sublane dynamic-gather support is
newer than the rest of the ops used here; the kernel is validated in
interpret mode on CPU (this container) and the ops.py dispatcher keeps the
scan-of-gathers jnp path as the fallback on real hardware.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    _GRID_SPEC = pltpu.PrefetchScalarGridSpec
except Exception:  # pragma: no cover
    _GRID_SPEC = None

DEFAULT_BR = 256
DEFAULT_BC = 2048  # x rows per column block resident in VMEM
DEFAULT_BN = 128


def build_tiles(cols: np.ndarray, vals: np.ndarray, Rx: int, br: int, bc: int):
    """Re-bucket an ELL block [R, W] into (row-block x col-block) tiles.

    Returns (tile_cb [RB, T], tcols [RB, T, br, Wt], tvals [...]) where T is
    the padded tile count and Wt the padded per-tile width. Padded entries
    point at tile-local column 0 with value 0.
    """
    R, W = cols.shape
    RB = R // br
    n_cb = -(-Rx // bc)
    tiles: list[list[tuple[int, np.ndarray, np.ndarray, np.ndarray]]] = []
    T = 1
    Wt = 1
    for rb in range(RB):
        c = cols[rb * br : (rb + 1) * br]
        v = vals[rb * br : (rb + 1) * br]
        nz = v != 0
        cb_of = c // bc
        row_tiles = []
        for cb in np.unique(cb_of[nz]):
            m = nz & (cb_of == cb)
            w_t = int(m.sum(axis=1).max())
            tc = np.zeros((br, w_t), dtype=np.int32)
            tv = np.zeros((br, w_t), dtype=vals.dtype)
            for r in range(br):
                sel = np.nonzero(m[r])[0]
                tc[r, : len(sel)] = c[r, sel] - cb * bc
                tv[r, : len(sel)] = v[r, sel]
            row_tiles.append((int(cb), tc, tv))
            Wt = max(Wt, w_t)
        T = max(T, len(row_tiles))
        tiles.append(row_tiles)
    tile_cb = np.zeros((RB, T), dtype=np.int32)
    tcols = np.zeros((RB, T, br, Wt), dtype=np.int32)
    tvals = np.zeros((RB, T, br, Wt), dtype=vals.dtype)
    for rb, row_tiles in enumerate(tiles):
        for t, (cb, tc, tv) in enumerate(row_tiles):
            tile_cb[rb, t] = cb
            tcols[rb, t, :, : tc.shape[1]] = tc
            tvals[rb, t, :, : tv.shape[1]] = tv
    return tile_cb, tcols, tvals


def _kernel(tile_cb, tcols, tvals, xblk, out, *, n_tiles):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out[...] = jnp.zeros_like(out)

    c = tcols[0, 0]  # [br, Wt] tile-local columns
    v = tvals[0, 0]
    xb = xblk[...]  # [bc, bn]
    acc = out[...]
    for w in range(c.shape[1]):
        acc = acc + v[:, w : w + 1] * jnp.take(xb, c[:, w], axis=0)
    out[...] = acc


@functools.partial(jax.jit, static_argnames=("br", "bc", "bn", "interpret"))
def ell_gather_spmv(
    tile_cb: jax.Array,  # [RB, T] col-block index per tile (scalar prefetch)
    tcols: jax.Array,    # [RB, T, br, Wt]
    tvals: jax.Array,    # [RB, T, br, Wt]
    x: jax.Array,        # [Rx_pad, nb] (padded to multiple of bc)
    br: int = DEFAULT_BR,
    bc: int = DEFAULT_BC,
    bn: int = DEFAULT_BN,
    interpret: bool = False,
):
    RB, T, _, Wt = tcols.shape
    R = RB * br
    Rx, nb = x.shape
    assert Rx % bc == 0 and nb % bn == 0
    grid = (RB, nb // bn, T)
    if _GRID_SPEC is None:
        raise NotImplementedError
    grid_spec = _GRID_SPEC(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, br, Wt), lambda rb, cb, t, cbref: (rb, t, 0, 0)),
            pl.BlockSpec((1, 1, br, Wt), lambda rb, cb, t, cbref: (rb, t, 0, 0)),
            pl.BlockSpec((bc, bn), lambda rb, cb, t, cbref: (cbref[rb, t], cb)),
        ],
        out_specs=pl.BlockSpec((br, bn), lambda rb, cb, t, cbref: (rb, cb)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_tiles=T),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, nb), x.dtype),
        interpret=interpret,
    )(tile_cb, tcols, tvals, x)

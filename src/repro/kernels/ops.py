"""Dispatch layer for the Pallas kernels.

``prefer_pallas()`` is True only on TPU backends; on CPU (this container)
the jnp reference path runs inside jit, and kernels are exercised through
``interpret=True`` in the tests. Complex DIA matrices are decomposed into
real/imaginary planes (4 real kernel calls) since TPU VREGs have no
complex type.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .cheb_dia import cheb_dia as _cheb_dia_kernel


def prefer_pallas() -> bool:
    return jax.default_backend() == "tpu"


def ell_spmv(cols, vals, x):
    """Local ELL contraction (scan-of-gathers; the Pallas tile kernel in
    ell_gather.py is opted in by the operator builder on TPU). Both comm
    engines dispatch here — the compressed (neighbor-permute) engine only
    re-bases column values into its compact halo buffer, so the same
    contraction body serves ``comm="a2a"`` and ``comm="compressed"``."""
    return ref.ell_spmv_ref(cols, vals, x)


def ell_spmv_split(cols_loc, vals_loc, cols_halo, vals_halo, x, halo):
    """Split-phase ELL contraction for the overlap engines.

    The local block never reads the halo buffer, so the caller can launch
    the halo exchange first and XLA overlaps it with the local contraction.
    The halo block gathers only from the (small) received buffer — on TPU
    it stays VMEM-resident, which is exactly the regime the ell_gather tile
    kernel wants (one column block, no re-bucketing). With the compressed
    engine the received buffer shrinks further (``Σ_k L_k`` instead of
    ``P·L`` rows); ``cols_halo`` then indexes that compact buffer."""
    return ref.ell_spmv_split_ref(cols_loc, vals_loc, cols_halo, vals_halo,
                                  x, halo)


def cheb_dia(offsets, dvals, x, w1, w2, alpha, beta, *, interpret=None, force_ref=False):
    """Fused Chebyshev DIA step with real/complex dispatch."""
    interpret = (not prefer_pallas()) if interpret is None else interpret
    if force_ref or (interpret and _too_small(dvals, w1)):
        return ref.cheb_dia_ref(offsets, dvals, x, w1, w2, alpha, beta)
    if jnp.iscomplexobj(dvals) or jnp.iscomplexobj(x):
        dr, di = jnp.real(dvals), jnp.imag(dvals)
        xr, xi = jnp.real(x), jnp.imag(x)
        w1r, w1i = jnp.real(w1), jnp.imag(w1)
        w2r, w2i = jnp.real(w2), jnp.imag(w2)
        zeros = jnp.zeros_like(w1r)
        call = functools.partial(_call_real, offsets, interpret=interpret)
        # (Ar + iAi)(xr + ixi): real = Ar xr - Ai xi ; imag = Ar xi + Ai xr
        yr = call(dr, xr, w1r, w2r, alpha, beta) - (
            call(di, xi, zeros, zeros, alpha, 0.0)
        )
        yi = call(dr, xi, w1i, w2i, alpha, beta) + (
            call(di, xr, zeros, zeros, alpha, 0.0)
        )
        return yr + 1j * yi
    return _call_real(offsets, dvals, x, w1, w2, alpha, beta, interpret=interpret)


def _call_real(offsets, dvals, x, w1, w2, alpha, beta, *, interpret):
    R, nb = w1.shape
    br = _pick_block(R, (512, 256, 128, 64, 32, 16, 8))
    bn = _pick_block(nb, (256, 128) if not interpret else (256, 128, 64, 32, 16, 8, 4, 2, 1))
    if br is None or bn is None or x.shape[0] % br:
        return ref.cheb_dia_ref(offsets, dvals, x, w1, w2, alpha, beta)
    return _cheb_dia_kernel(
        tuple(int(o) for o in offsets), dvals, x, w1, w2, alpha, beta,
        br=br, bn=bn, interpret=interpret,
    )


def _pick_block(n, candidates):
    for c in candidates:
        if n % c == 0:
            return c
    return None


def _too_small(dvals, w1) -> bool:
    return w1.shape[0] < 8 or w1.shape[1] < 1

"""Dispatch layer for the Pallas kernels.

``prefer_pallas()`` is True only on TPU backends; on CPU (this container)
the kernels run in ``interpret=True`` mode (or fall back to the jnp
reference path where a block decomposition does not exist). Complex DIA
matrices are decomposed into real/imaginary planes (4 real kernel calls)
since TPU VREGs have no complex type; the ref-vs-kernel decision is made
ONCE, before the decomposition, so a fallback runs one complex reference
call instead of four real ones.

Two host-side planners feed the distributed engine (``core/spmv.py``):

* :func:`plan_ell_tiles` re-buckets a stacked ELL block into the
  (row-block x col-block) tile format of ``ell_gather.py`` at operator
  build time — tiles can only be built from *concrete* host arrays, so
  the planner returns ``None`` on traced/abstract operands (e.g. the
  dryrun surrogate operator) and the engine keeps the jnp scan path.
* :func:`plan_dia` extracts a DIA (offset, diagonal-values) form of a
  zero-halo local block for the fused ``cheb_dia`` Chebyshev kernel,
  with offsets sorted ascending so the per-row accumulation order equals
  the ELL slot order (ascending column) bit-for-bit.

All kernel entry points thread an explicit accumulator (``y0``) so the
per-output-element floating-point addition chain is identical to the
``lax.scan`` reference — the engines' twelve-way bit-identity grid
depends on it.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np
import jax
import jax.numpy as jnp

from . import ref
from .cheb_dia import cheb_dia as _cheb_dia_kernel
from .ell_gather import build_tiles, ell_gather_spmv

#: Row-block candidates for the tile kernel (first divisor of R wins).
ELL_BR_CANDIDATES = (256, 128, 64, 32, 16, 8)

#: x rows resident per VMEM column block of the tile kernel.
ELL_BC = 512

#: Max distinct diagonal offsets before plan_dia refuses (the DIA form
#: stores n_diag * R values; past a few dozen diagonals the gather-free
#: format stops paying for itself).
DIA_MAX_DIAGS = 64


def prefer_pallas() -> bool:
    return jax.default_backend() == "tpu"


def is_concrete(a) -> bool:
    """True when ``a`` is a host-readable array (numpy, or a committed
    jax array) — i.e. NOT a tracer and NOT a ShapeDtypeStruct surrogate.
    The host-side planners require concrete operands; the engine falls
    back to the jnp path otherwise."""
    if isinstance(a, np.ndarray):
        return True
    return isinstance(a, jax.Array) and not isinstance(a, jax.core.Tracer)


# ------------------------------------------------------------------ ELL --


@dataclasses.dataclass(frozen=True)
class EllTilePlan:
    """Host-built tile batch of a stacked [P, R, W] ELL block.

    Arrays keep the leading shard axis so the engine can pass them
    through ``shard_map`` next to the block they were built from; the
    static block sizes travel with the plan (they parameterize the
    kernel grid)."""

    tile_cb: jax.Array  # [P, RB, T]
    tcols: jax.Array    # [P, RB, T, br, Wt]
    tvals: jax.Array    # [P, RB, T, br, Wt]
    br: int
    bc: int

    def arrays(self) -> tuple[jax.Array, jax.Array, jax.Array]:
        return (self.tile_cb, self.tcols, self.tvals)


def plan_ell_tiles(cols, vals, Rx: int, *, bc: int = ELL_BC,
                   br_candidates=ELL_BR_CANDIDATES) -> EllTilePlan | None:
    """Build the ell_gather tile batch for a stacked [P, R, W] ELL block.

    Returns ``None`` (caller keeps the jnp scan path) when

    * the operands are not concrete host arrays (dryrun surrogates),
    * the value dtype is not real floating (the tile kernel is real-only),
    * no row-block candidate divides R, or the block is empty (W == 0).

    Per shard the tiles are built order-preserving (each entry goes to
    the earliest tile at-or-after its row's last-used tile with a
    matching column block), so the kernel's tile-major accumulation
    visits every stored entry in exactly the scan order — for ANY slot
    order, including the non-monotone re-based halo addresses of the
    compressed engines — and kernel-on == kernel-off bit-for-bit
    (padded slots add a bit-neutral ``+ 0.0``).
    """
    if not (is_concrete(cols) and is_concrete(vals)):
        return None
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    if not np.issubdtype(vals.dtype, np.floating):
        return None
    P, R, W = cols.shape
    if W == 0 or R == 0:
        return None
    br = _pick_block(R, br_candidates)
    if br is None:
        return None
    per_shard = [build_tiles(cols[p], vals[p], Rx, br, bc) for p in range(P)]
    T = max(tb.shape[1] for tb, _, _ in per_shard)
    Wt = max(tc.shape[3] for _, tc, _ in per_shard)
    RB = R // br
    tile_cb = np.zeros((P, RB, T), dtype=np.int32)
    tcols = np.zeros((P, RB, T, br, Wt), dtype=np.int32)
    tvals = np.zeros((P, RB, T, br, Wt), dtype=vals.dtype)
    for p, (tb, tc, tv) in enumerate(per_shard):
        tile_cb[p, :, : tb.shape[1]] = tb
        tcols[p, :, : tc.shape[1], :, : tc.shape[3]] = tc
        tvals[p, :, : tv.shape[1], :, : tv.shape[3]] = tv
    return EllTilePlan(tile_cb=jnp.asarray(tile_cb), tcols=jnp.asarray(tcols),
                       tvals=jnp.asarray(tvals), br=br, bc=bc)


def ell_spmv_tiled(tile_cb, tcols, tvals, x, y0=None, *, br: int, bc: int,
                   cols=None, vals=None, interpret=None):
    """Contract a per-device tile batch against ``x``, threading ``y0``.

    ``tile_cb [RB, T]`` / ``tcols``/``tvals [RB, T, br, Wt]`` are one
    shard's slice of an :class:`EllTilePlan`. The vector-block size bn is
    chosen at trace time from ``x.shape[1]``; if no kernel-compatible bn
    exists on the real-hardware path the jnp scan runs instead (pass the
    original ``cols``/``vals`` to enable that fallback — interpret mode
    always has bn=1 available, so on CPU the kernel always runs).
    """
    interpret = (not prefer_pallas()) if interpret is None else interpret
    nb = x.shape[1]
    bn = _pick_block(nb, (256, 128) if not interpret
                    else (256, 128, 64, 32, 16, 8, 4, 2, 1))
    if bn is None:
        if cols is None or vals is None:
            raise ValueError("no kernel-compatible bn and no fallback block")
        acc = y0
        if acc is None:
            acc = jnp.zeros((cols.shape[0], nb),
                            dtype=jnp.result_type(vals, x))
        return ref.ell_spmv_acc_ref(acc, cols, vals, x)
    pad = (-x.shape[0]) % bc
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return ell_gather_spmv(tile_cb, tcols, tvals, x, y0,
                           br=br, bc=bc, bn=bn, interpret=interpret)


def ell_spmv(cols, vals, x):
    """Local ELL contraction (scan-of-gathers jnp reference). The Pallas
    tile kernel is opted in by the operator builder via
    :func:`plan_ell_tiles` + :func:`ell_spmv_tiled`; this entry point is
    the shared fallback body. Both comm engines dispatch here — the
    compressed (neighbor-permute) engine only re-bases column values into
    its compact halo buffer, so the same contraction body serves
    ``comm="a2a"`` and ``comm="compressed"``."""
    return ref.ell_spmv_ref(cols, vals, x)


def ell_spmv_split(cols_loc, vals_loc, cols_halo, vals_halo, x, halo):
    """Split-phase ELL contraction for the overlap engines.

    The local block never reads the halo buffer, so the caller can launch
    the halo exchange first and XLA overlaps it with the local contraction.
    The halo block gathers only from the (small) received buffer — on TPU
    it stays VMEM-resident, which is exactly the regime the ell_gather tile
    kernel wants (one column block, no re-bucketing). With the compressed
    engine the received buffer shrinks further (``Σ_k L_k`` instead of
    ``P·L`` rows); ``cols_halo`` then indexes that compact buffer."""
    return ref.ell_spmv_split_ref(cols_loc, vals_loc, cols_halo, vals_halo,
                                  x, halo)


# ------------------------------------------------------------------ DIA --


@dataclasses.dataclass(frozen=True)
class DiaPlan:
    """Host-extracted DIA form of a stacked zero-halo [P, R, W] local
    block: ``offsets`` sorted ascending (so the per-row accumulation
    order equals the ELL slot order), ``dvals[p, d, r]`` the value at
    (r, r + offsets[d]) of shard p (0 where the diagonal has no entry)."""

    offsets: tuple[int, ...]
    dvals: jax.Array  # [P, n_diag, R]


def plan_dia(cols, vals, R: int, *, max_diags: int = DIA_MAX_DIAGS
             ) -> DiaPlan | None:
    """Extract the DIA form of a stacked local ELL block, or ``None``.

    Refuses (caller keeps the ELL path) when the operands are not
    concrete, not real floating, reference columns outside ``[0, R)``
    (i.e. the block has halo entries), or need more than ``max_diags``
    distinct diagonals — the fused ``cheb_dia`` kernel is only dispatched
    for comm-free diagonal-structured operators.
    """
    if not (is_concrete(cols) and is_concrete(vals)):
        return None
    cols = np.asarray(cols)
    vals = np.asarray(vals)
    if not np.issubdtype(vals.dtype, np.floating):
        return None
    P, Rb, W = cols.shape
    if W == 0 or Rb != R:
        return None
    stored = vals != 0
    if not stored.any():
        return None
    if cols[stored].max() >= R:
        return None  # halo entries: not a comm-free local block
    rows = np.broadcast_to(np.arange(R)[None, :, None], cols.shape)
    offs = cols.astype(np.int64) - rows
    uniq = np.unique(offs[stored])
    if len(uniq) > max_diags:
        return None
    dvals = np.zeros((P, len(uniq), R), dtype=vals.dtype)
    dpos = {int(o): d for d, o in enumerate(uniq)}
    for p in range(P):
        rr, ww = np.nonzero(stored[p])
        for r, w in zip(rr, ww):
            dvals[p, dpos[int(offs[p, r, w])], r] = vals[p, r, w]
    return DiaPlan(offsets=tuple(int(o) for o in uniq),
                   dvals=jnp.asarray(dvals))


def cheb_dia(offsets, dvals, x, w1, w2, alpha, beta, *, interpret=None,
             force_ref=False):
    """Fused Chebyshev DIA step with real/complex dispatch.

    The kernel-vs-reference decision (``_too_small``, ragged R/nb via
    ``_pick_block``, ``x.shape[0] % br``) is made ONCE up front; a
    complex operand that falls back therefore runs a single complex
    reference call, not four real-plane reference calls.
    """
    interpret = (not prefer_pallas()) if interpret is None else interpret
    R, nb = w1.shape
    br = _pick_block(R, (512, 256, 128, 64, 32, 16, 8))
    bn = _pick_block(nb, (256, 128) if not interpret
                    else (256, 128, 64, 32, 16, 8, 4, 2, 1))
    if (force_ref or (interpret and _too_small(dvals, w1))
            or br is None or bn is None or x.shape[0] % br):
        return ref.cheb_dia_ref(offsets, dvals, x, w1, w2, alpha, beta)
    call = functools.partial(_call_real, offsets, br=br, bn=bn,
                             interpret=interpret)
    if jnp.iscomplexobj(dvals) or jnp.iscomplexobj(x):
        dr, di = jnp.real(dvals), jnp.imag(dvals)
        xr, xi = jnp.real(x), jnp.imag(x)
        w1r, w1i = jnp.real(w1), jnp.imag(w1)
        w2r, w2i = jnp.real(w2), jnp.imag(w2)
        zeros = jnp.zeros_like(w1r)
        # (Ar + iAi)(xr + ixi): real = Ar xr - Ai xi ; imag = Ar xi + Ai xr
        yr = call(dr, xr, w1r, w2r, alpha, beta) - (
            call(di, xi, zeros, zeros, alpha, 0.0)
        )
        yi = call(dr, xi, w1i, w2i, alpha, beta) + (
            call(di, xr, zeros, zeros, alpha, 0.0)
        )
        return yr + 1j * yi
    return call(dvals, x, w1, w2, alpha, beta)


def _call_real(offsets, dvals, x, w1, w2, alpha, beta, *, br, bn, interpret):
    return _cheb_dia_kernel(
        tuple(int(o) for o in offsets), dvals, x, w1, w2, alpha, beta,
        br=br, bn=bn, interpret=interpret,
    )


def _pick_block(n, candidates):
    for c in candidates:
        if n % c == 0:
            return c
    return None


def _too_small(dvals, w1) -> bool:
    return w1.shape[0] < 8 or w1.shape[1] < 1

"""Pure-jnp oracles for the Pallas kernels (shape-exact references)."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def ell_spmv_acc_ref(acc, cols, vals, x):
    """Accumulator-threaded ELL contraction: the W-step scan adds one slot
    per step into ``acc``, so per output element the floating-point
    addition order is exactly the slot order (ascending column). Every
    engine path — jnp, tile kernel, split-phase, round-pipelined — must
    reduce to this chain (possibly with bit-neutral ``+ 0.0`` pad adds
    interspersed) for the cross-engine bit-identity grid to hold."""
    def body(acc, cw):
        c, v = cw
        return acc + v[:, None] * jnp.take(x, c, axis=0), None

    acc, _ = lax.scan(body, acc, (cols.T, vals.T))
    return acc


def ell_spmv_ref(cols, vals, x):
    """y[r] = sum_w vals[r, w] * x[cols[r, w]];  cols [R,W], x [Rx, nb]."""
    acc0 = jnp.zeros((cols.shape[0], x.shape[1]), dtype=jnp.result_type(vals, x))
    return ell_spmv_acc_ref(acc0, cols, vals, x)


def ell_spmv_split_ref(cols_loc, vals_loc, cols_halo, vals_halo, x, halo):
    """Split-phase ELL contraction: local block against the resident shard
    x [R, nb], halo block against the received buffer halo [P*L, nb]. Per
    row, local entries accumulate before halo entries — the unsplit ELL
    slot order. The halo block THREADS the local accumulator (rather than
    summing separately and adding) so the addition chain is the one of
    :func:`ell_spmv_acc_ref` over the concatenated slots, bit-for-bit."""
    y = ell_spmv_ref(cols_loc, vals_loc, x)
    if cols_halo.shape[1]:
        y = ell_spmv_acc_ref(y, cols_halo, vals_halo, halo)
    return y


def cheb_dia_ref(offsets, dvals, x, w1, w2, alpha, beta):
    """Fused Chebyshev step for a DIA (diagonal-offset) matrix.

    y = 2*alpha*(A@x) + 2*beta*w1 - w2 with
    (A@x)[i] = sum_d dvals[d, i] * x[i + offsets[d]]  (zero out of range).

    offsets: static tuple of ints; dvals [n_diag, R]; x [Rx, nb] where x may
    be longer than R (local rows + halo appended); w1/w2 [R, nb].
    """
    R = dvals.shape[1]
    nb = x.shape[1]
    acc = jnp.zeros((R, nb), dtype=jnp.result_type(dvals, x))
    idx = jnp.arange(R)
    for d, off in enumerate(offsets):
        j = idx + off
        ok = (j >= 0) & (j < x.shape[0])
        xo = jnp.take(x, jnp.clip(j, 0, x.shape[0] - 1), axis=0)
        acc = acc + jnp.where(ok[:, None], dvals[d][:, None] * xo, 0)
    return 2.0 * alpha * acc + 2.0 * beta * w1 - w2

"""Collective census: compile an engine cell (never execute it) and
attribute every collective in the optimized HLO to a predicted term.

The paper's point is that the communication structure of the solver is
known from the sparsity pattern *before running any code*; this pass
holds the compiled artifact to that claim. One census cell lowers the
standard FD macro-iteration — TSQR, redistribution to the filter layout,
a degree-``n`` Chebyshev filter over the chosen SpMV engine,
redistribution back, and a Gram all-reduce — with ``.lower().compile()``
only (no jit execution of the solver loop), walks the HLO via
:func:`repro.launch.hlo_analysis.collective_census`, and compares the
measured (kind, operand bytes, multiplicity) multiset against the
predicted terms:

* halo exchange — ``SpmvCommPlan.spmv_collectives`` × filter degree
  (one padded ``all-to-all``, or one ``collective-permute`` per neighbor
  round);
* TSQR butterfly — log2(P) ``collective-permute`` rounds of the
  [N_s, N_s] R factor;
* redistribution — two tiled ``all-to-all`` ops when N_col > 1 (XLA
  prints either the full local slice or only the moved fraction as the
  operand, so the term carries both admissible byte sizes);
* Gram reduction — one [N_s, N_s] ``all-reduce`` (the same term shape
  the Lanczos per-step reductions produce; Lanczos itself is a host
  loop and is not part of the compiled cell).

Any measured collective not covered by a term — a spurious all-gather
from an accidental resharding, say — is an *unattributed collective*
error; any term the HLO does not realize is a *missing collective*
error. Both directions must be exactly empty for the cell to pass.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["ExpectedTerm", "CensusReport", "attribute", "expected_census",
           "run_census_cell"]

_TOL = 1e-6


@dataclasses.dataclass(frozen=True)
class ExpectedTerm:
    """One predicted collective term: ``count`` executions of ``kind``
    with ``bytes`` operand bytes each. ``alt_bytes`` lists other operand
    sizes the same op may legally print (dialect differences such as
    full-slice vs moved-only all-to-all operands)."""

    label: str
    kind: str
    bytes: int
    count: float
    alt_bytes: tuple = ()


@dataclasses.dataclass
class CensusReport:
    """Attribution of a compiled cell's collectives to predicted terms."""

    cell: str
    expected: list  # [ExpectedTerm]
    measured: list  # [hlo_analysis.CollectiveOp]
    errors: list

    @property
    def ok(self) -> bool:
        return not self.errors

    def describe(self) -> str:
        lines = [f"census[{self.cell}]: "
                 f"{'OK' if self.ok else f'{len(self.errors)} error(s)'}"]
        lines.append("  predicted:")
        for t in self.expected:
            lines.append(f"    {t.label:<28s} {t.count:g} x "
                         f"{t.kind}({t.bytes}B)")
        lines.append("  measured:")
        agg: dict = {}
        for c in self.measured:
            agg[(c.kind, c.bytes)] = agg.get((c.kind, c.bytes), 0.0) + c.mult
        for (kind, b), m in sorted(agg.items()):
            lines.append(f"    {m:g} x {kind}({b}B)")
        lines += [f"  ERROR: {e}" for e in self.errors]
        return "\n".join(lines)


def attribute(measured, expected, cell: str = "",
              extra_errors=()) -> CensusReport:
    """Match the measured collective multiset against the predicted terms
    — exact in both directions. Terms and ops are aggregated by
    (kind, bytes-per-op), so byte-size collisions between terms simply
    add their counts; ``alt_bytes`` sizes are tried once the primary
    size is exhausted."""
    errors = list(extra_errors)
    meas_mult: dict = {}
    meas_names: dict = {}
    for c in measured:
        key = (c.kind, c.bytes)
        meas_mult[key] = meas_mult.get(key, 0.0) + c.mult
        meas_names.setdefault(key, []).append(c.name)
    remaining = dict(meas_mult)
    for t in expected:
        need = float(t.count)
        for b in (t.bytes,) + tuple(t.alt_bytes):
            key = (t.kind, int(b))
            take = min(need, remaining.get(key, 0.0))
            if take > 0:
                remaining[key] -= take
                need -= take
            if need <= _TOL:
                break
        if need > _TOL:
            errors.append(
                f"[{cell}] missing collective: predicted term {t.label!r} "
                f"({t.count:g} x {t.kind}({t.bytes}B)) is short by "
                f"{need:g} in the compiled HLO")
    for (kind, b), mult in sorted(remaining.items()):
        if mult > _TOL:
            names = ", ".join(meas_names[(kind, b)][:4])
            errors.append(
                f"[{cell}] unattributed collective: {mult:g} x "
                f"{kind}({b}B) matches no predicted term (ops: {names})")
    return CensusReport(cell=cell, expected=list(expected),
                        measured=list(measured), errors=errors)


def expected_census(cp, *, comm: str, schedule: str, degree: int, n_b: int,
                    S_d: int, n_s: int, P_total: int, n_col: int,
                    D_pad: int) -> list:
    """Predicted terms of one FD macro-iteration: the halo exchange of
    ``degree`` SpMV applications plus the layout-level collectives.
    ``n_b`` is the filter layout's local bundle width (n_s / N_col).

    A depth-s plan (``cp.sstep > 1``) swaps the per-SpMV halo term for
    the χ(A^s) exchange terms of :meth:`SpmvCommPlan.sstep_collectives`
    — one single-width seed exchange plus ``⌈degree/s⌉ - 1``
    width-doubled group exchanges, already whole-filter counts."""
    terms = []
    if getattr(cp, "sstep", 1) > 1:
        for k, (kind, b, cnt) in enumerate(cp.sstep_collectives(
                comm, schedule, n_b, S_d, degree)):
            terms.append(ExpectedTerm(
                label=f"sstep-exchange[{comm}/{schedule}#{k}]",
                kind=kind, bytes=b, count=cnt))
    else:
        for kind, b, cnt in cp.spmv_collectives(comm, schedule, n_b, S_d):
            terms.append(ExpectedTerm(
                label=f"halo-exchange[{comm}/{schedule}]", kind=kind,
                bytes=b, count=cnt * degree))
    if P_total > 1:
        levels = int(math.log2(P_total))
        terms.append(ExpectedTerm("tsqr-butterfly", "collective-permute",
                                  n_s * n_s * S_d, levels))
        terms.append(ExpectedTerm("gram-allreduce", "all-reduce",
                                  n_s * n_s * S_d, 1))
    if n_col > 1:
        full = (D_pad // P_total) * n_s * S_d
        moved = full * (n_col - 1) // n_col
        for leg in ("to_panel", "to_stack"):
            terms.append(ExpectedTerm(f"redistribute[{leg}]", "all-to-all",
                                      full, 1, alt_bytes=(moved,)))
    return terms


def run_census_cell(matrix, *, P_total: int, layout: str = "panel",
                    comm: str = "a2a", schedule: str = "cyclic",
                    overlap: bool = False, use_kernel: bool = False,
                    balance: str = "rows", reorder: str = "none",
                    sstep: int = 1,
                    n_s: int = 8, degree: int = 6,
                    dtype=None, wrap=None) -> CensusReport:
    """Compile one engine cell on a fake-CPU mesh of ``P_total`` devices
    and attribute its collectives. Returns the :class:`CensusReport`;
    never executes the compiled program.

    The cell is the FD macro-iteration at small scale: TSQR in the stack
    layout, redistribution into ``layout``, a degree-``degree`` Chebyshev
    filter over ``make_spmv(comm=..., schedule=..., overlap=...)``,
    redistribution back, and one Gram product. ``balance``/``reorder``
    lower the cell on a planned :class:`~repro.core.partition.RowMap`
    (planned at the filter level with ``block_multiple`` so its padded
    extent divides the full mesh). ``use_kernel`` lowers the kernelized
    engine (``make_spmv(use_kernel=True)``, Pallas interpret mode on
    CPU); the predicted terms are *identical* to the jnp cell's — the
    kernels only replace the local contraction, never the exchange — so
    the census holds the kernelized engines to exactly the same
    collective attribution (the cell tag gains ``+krn``). ``sstep > 1``
    lowers the communication-avoiding s-step filter cell
    (``build_sstep_ell`` + ``make_sstep_cheb``, the ``+s2``/``+s3``
    tags): the filter then runs ⌈degree/s⌉ depth-s ghost exchanges and
    the census attributes every one to the χ(A^s) terms of
    ``SpmvCommPlan.sstep_collectives``. ``wrap`` is
    the planted-defect seam
    used by the negative tests: ``wrap(iteration, mesh, stack_layout)``
    may return a mutated iteration whose extra collectives the census
    must then flag.
    """
    import jax
    import jax.numpy as jnp

    from ..core import layouts as lo
    from ..core.chebyshev import chebyshev_filter
    from ..core.orthogonalize import make_gram, make_tsqr
    from ..core.partition import plan_rowmap
    from ..core.planner import comm_plan, layout_on_mesh
    from ..core.redistribute import make_redistribute
    from ..core.spmv import (build_dist_ell, build_sstep_ell, make_spmv,
                             make_sstep_cheb)
    from ..launch.hlo_analysis import collective_census

    if len(jax.devices()) < P_total:
        raise RuntimeError(
            f"census needs {P_total} devices but only {len(jax.devices())} "
            f"are visible — set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={P_total} before importing jax")
    if degree < 2:
        raise ValueError("chebyshev_filter needs degree >= 2")
    dtype = jnp.dtype(dtype or
                      (jnp.float64 if jax.config.jax_enable_x64
                       else jnp.float32))
    S_d = dtype.itemsize

    # mesh + layouts, mirroring FilterDiag: the stack layout shards D over
    # every axis (row axes slowest), the filter layout is the chosen one
    n_row_mesh = max(P_total // 2, 1)
    n_col_mesh = P_total // n_row_mesh
    mesh = lo.make_solver_mesh(n_row_mesh, n_col_mesh)
    panel_l = layout_on_mesh(mesh, layout)
    stack_l = lo.Layout("stack", panel_l.dist_axes + panel_l.bundle_axes, ())
    N_row = panel_l.n_row(mesh)
    N_col = panel_l.n_col(mesh)
    n_s = -(-n_s // max(N_col, 1)) * max(N_col, 1)
    n_b = n_s // max(N_col, 1)

    sstep = int(sstep)
    if sstep < 1:
        raise ValueError(f"sstep must be >= 1 (got {sstep})")
    extra_errors = []
    rowmap = None
    if (balance, reorder) != ("rows", "none"):
        if N_row > 1:
            rowmap = plan_rowmap(matrix, N_row, balance=balance,
                                 reorder=reorder, sstep=sstep,
                                 block_multiple=P_total // N_row)
            if rowmap.identity:
                rowmap = None  # planned map degenerated to equal rows
        else:
            balance, reorder = "rows", "none"  # no halo to re-balance
    D = matrix.shape[0] if hasattr(matrix, "shape") else matrix.D
    D_pad = rowmap.D_pad if rowmap is not None \
        else -(-D // P_total) * P_total

    if sstep > 1:
        # depth-s cell: the real SstepEll (one exchange per s recurrence
        # steps) and the pattern-only depth-s plan it must agree with
        sell = build_sstep_ell(matrix, N_row, sstep, dtype=dtype,
                               d_pad=D_pad, rowmap=rowmap)
        if rowmap is not None:
            cp = comm_plan(matrix, N_row, rowmap=rowmap, sstep=sstep)
        else:
            cp = comm_plan(matrix, N_row, d_pad=D_pad, sstep=sstep)
        if cp.L != sell.L:
            extra_errors.append(f"depth-{sstep} comm_plan L = {cp.L} != "
                                f"engine L = {sell.L}")
        if (cp.pair_counts is not None and sell.pair_counts is not None
                and not np.array_equal(cp.pair_counts, sell.pair_counts)):
            extra_errors.append(f"depth-{sstep} comm_plan pair_counts "
                                f"diverge from the built operator's")
        cheb_apply = make_sstep_cheb(mesh, panel_l, sell,
                                     use_kernel=use_kernel,
                                     overlap=overlap, comm=comm,
                                     schedule=schedule)
    else:
        ell = build_dist_ell(matrix, N_row, dtype=dtype, d_pad=D_pad,
                             split_halo=overlap, rowmap=rowmap)
        if rowmap is not None:
            cp = comm_plan(matrix, N_row, rowmap=rowmap)
        else:
            cp = comm_plan(matrix, N_row, d_pad=D_pad, exact=True)
        # static plan vs built engine: the census prediction below comes
        # from the pattern-only comm_plan, so it only proves anything if
        # the plan and the operator agree on the volumes
        if cp.L != ell.L:
            extra_errors.append(f"comm_plan L = {cp.L} != engine L = "
                                f"{ell.L}")
        if (cp.pair_counts is not None and ell.pair_counts is not None
                and not np.array_equal(cp.pair_counts, ell.pair_counts)):
            extra_errors.append("comm_plan pair_counts diverge from the "
                                "built operator's pair_counts")
        spmv = make_spmv(mesh, panel_l, ell, use_kernel=use_kernel,
                         overlap=overlap, comm=comm, schedule=schedule)
    tsqr = make_tsqr(mesh, stack_l)
    to_panel, to_stack = make_redistribute(mesh, stack_l, panel_l)
    gram = make_gram(mesh, stack_l)
    mu = np.linspace(1.0, 0.5, degree + 1)

    def iteration(V):
        Q, _ = tsqr(V)
        Vp = to_panel(Q)
        if sstep > 1:
            W = cheb_apply(Vp, mu, 0.5, 0.1)
        else:
            W = chebyshev_filter(spmv, mu, 0.5, 0.1, Vp)
        Vs = to_stack(W)
        return Vs, gram(Vs, Vs)

    if wrap is not None:
        iteration = wrap(iteration, mesh, stack_l)

    vsh = jax.NamedSharding(mesh, stack_l.vec_pspec())
    V = jax.ShapeDtypeStruct((D_pad, n_s), dtype)
    with mesh:
        compiled = jax.jit(iteration, in_shardings=(vsh,),
                           out_shardings=(vsh, None)).lower(V).compile()
    measured = collective_census(compiled.as_text())
    expected = expected_census(cp, comm=comm, schedule=schedule,
                               degree=degree, n_b=n_b, S_d=S_d, n_s=n_s,
                               P_total=P_total, n_col=N_col, D_pad=D_pad)
    cell = (f"{layout}/{comm}-{schedule}{'+ov' if overlap else ''}"
            f"{'+krn' if use_kernel else ''}"
            f"{f'+s{sstep}' if sstep > 1 else ''}"
            f"/{balance}+{reorder}/P{P_total}")
    return attribute(measured, expected, cell=cell,
                     extra_errors=[f"[{cell}] {e}" for e in extra_errors])

"""Static communication verifier (``scripts/check_comm.py`` backend).

Three passes convert the repo's runtime identity checks into compile-time
guarantees:

* :mod:`repro.analysis.plan_lint` — pattern-only invariants of the
  neighbor schedules, row maps, and :class:`~repro.core.planner.
  SpmvCommPlan` byte accounting (no jax, no compilation).
* :mod:`repro.analysis.overlap_check` — jaxpr dependency proof that the
  split-phase engine's halo collective is independent of the local
  contraction (tracing only, no compilation).
* :mod:`repro.analysis.census` — compile (never execute) an engine cell
  and attribute every collective op in the optimized HLO to a predicted
  term from ``comm_plan``; unattributed or missing collectives are
  errors.

See docs/analysis.md for what each pass proves and how to read reports.
"""
from .census import (CensusReport, ExpectedTerm, attribute,  # noqa: F401
                     expected_census, run_census_cell)
from .overlap_check import OverlapReport, check_split_phase  # noqa: F401
from .plan_lint import (lint_comm_plan, lint_dist_ell,  # noqa: F401
                        lint_rounds, lint_rowmap, lint_schedules,
                        run_plan_lint)

__all__ = [
    "CensusReport", "ExpectedTerm", "attribute", "expected_census",
    "run_census_cell", "OverlapReport", "check_split_phase",
    "lint_comm_plan", "lint_dist_ell", "lint_rounds", "lint_rowmap",
    "lint_schedules", "run_plan_lint",
]

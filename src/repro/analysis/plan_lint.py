"""Plan-invariant linter: pattern-only checks, no compilation at all.

Everything here runs on numpy data that exists *before* any engine is
built — the pair-volume matrix, the neighbor schedules derived from it,
the planned row map, and the :class:`~repro.core.planner.SpmvCommPlan`
byte accounting. The invariants are exactly the assumptions the SpMV
engines and the χ-driven planner silently rely on:

* every neighbor round is a valid partial permutation (each device at
  most once as source, at most once as destination, never to itself)
  whose pad equals the max scheduled pair volume;
* every nonzero (sender, receiver) pair is scheduled in exactly one
  round with enough pad — no dropped and no double-sent pairs;
* ``H_matching <= H_cyclic`` (the matching scheduler's construction
  guarantee) and both are bounded by the padded a2a's ``(P-1) * L``;
* a zero-halo partition yields empty schedules and zero predicted bytes;
* the RowMap embed/extract is a bijection (eigenvector un-permutation
  cannot lose rows);
* ``SpmvCommPlan`` bytes are internally consistent across the comm /
  schedule / partition axes and against its own pair counts.

Each function returns a list of human-readable error strings (empty =
clean); ``run_plan_lint`` orchestrates all of them for one matrix.
"""
from __future__ import annotations

import numpy as np

__all__ = ["lint_rounds", "lint_schedules", "lint_rowmap",
           "lint_comm_plan", "lint_dist_ell", "lint_sstep",
           "lint_sampled_plan", "run_plan_lint"]


def lint_rounds(pair_counts, perms, round_L, label: str = "") -> list[str]:
    """Check one schedule's rounds against the pair-volume matrix.

    ``perms``/``round_L`` are in :func:`repro.core.spmv.neighbor_schedule`
    format. Violations found here are exactly what would corrupt the
    compressed engine's receive-buffer layout (``DistEll._round_offsets``
    assigns each scheduled pair a contiguous ``round_L[r]`` slot range).
    """
    pc = np.asarray(pair_counts)
    P = pc.shape[0]
    tag = f"[{label}] " if label else ""
    errors: list[str] = []
    if pc.shape != (P, P):
        return [f"{tag}pair_counts is not square: {pc.shape}"]
    if len(perms) != len(round_L):
        errors.append(f"{tag}{len(perms)} rounds but {len(round_L)} pads")
    seen: dict[tuple[int, int], int] = {}
    for r, (perm, Lr) in enumerate(zip(perms, round_L)):
        srcs = [s for s, _ in perm]
        dsts = [d for _, d in perm]
        if len(set(srcs)) != len(srcs):
            errors.append(f"{tag}round {r} repeats a source device: not a "
                          f"partial permutation ({sorted(perm)})")
        if len(set(dsts)) != len(dsts):
            errors.append(f"{tag}round {r} repeats a destination device: "
                          f"not a partial permutation ({sorted(perm)})")
        for s, d in perm:
            if s == d:
                errors.append(f"{tag}round {r} schedules a self-send "
                              f"({s} -> {d})")
            if not (0 <= s < P and 0 <= d < P):
                errors.append(f"{tag}round {r} pair ({s}, {d}) outside "
                              f"device range [0, {P})")
                continue
            if (s, d) in seen:
                errors.append(f"{tag}pair ({s} -> {d}) double-sent: "
                              f"scheduled in rounds {seen[s, d]} and {r}")
            seen[s, d] = r
            if pc[s, d] > Lr:
                errors.append(f"{tag}round {r} pad {Lr} < pair volume "
                              f"L[{s},{d}] = {int(pc[s, d])} (truncated send)")
        vols = [int(pc[s, d]) for s, d in perm
                if 0 <= s < P and 0 <= d < P]
        if vols and Lr != max(vols):
            errors.append(f"{tag}round {r} pad {Lr} != max scheduled pair "
                          f"volume {max(vols)} (wasted or short pad)")
        if Lr <= 0:
            errors.append(f"{tag}round {r} has nonpositive pad {Lr}")
    for s in range(P):
        for d in range(P):
            if s != d and pc[s, d] and (s, d) not in seen:
                errors.append(f"{tag}nonzero pair ({s} -> {d}, volume "
                              f"{int(pc[s, d])}) scheduled in no round "
                              f"(dropped halo data)")
    return errors


def lint_schedules(pair_counts, label: str = "") -> list[str]:
    """Derive both schedulers from ``pair_counts`` via the engine's own
    :func:`~repro.core.spmv.neighbor_schedule` and lint each, plus the
    cross-schedule invariants (H_matching <= H_cyclic <= (P-1)·L; empty
    pair matrix -> empty schedules)."""
    from ..core.spmv import SPMV_SCHEDULES, neighbor_schedule

    pc = np.asarray(pair_counts)
    tag = f"[{label}] " if label else ""
    errors: list[str] = []
    H = {}
    for sched in SPMV_SCHEDULES:
        perms, round_L = neighbor_schedule(pc, sched)
        errors += lint_rounds(pc, perms, round_L,
                              label=f"{label}:{sched}" if label else sched)
        H[sched] = int(sum(round_L))
        if not pc.any() and perms:
            errors.append(f"{tag}zero-halo pair matrix but schedule "
                          f"{sched!r} has {len(perms)} rounds")
    if H["matching"] > H["cyclic"]:
        errors.append(f"{tag}H_matching = {H['matching']} > H_cyclic = "
                      f"{H['cyclic']} (matching must never pay more)")
    L = int(pc.max()) if pc.size else 0
    P = pc.shape[0]
    if H["cyclic"] > max(P - 1, 0) * L:
        errors.append(f"{tag}H_cyclic = {H['cyclic']} exceeds the padded "
                      f"a2a bound (P-1)*L = {(P - 1) * L}")
    return errors


def lint_rowmap(rowmap, label: str = "") -> list[str]:
    """RowMap structural invariants: monotone boundaries covering [0, D),
    blocks within the padded extent, and a bijective embed/extract."""
    tag = f"[{label}] " if label else ""
    errors: list[str] = []
    b = np.asarray(rowmap.boundaries, dtype=np.int64)
    if b.shape != (rowmap.P + 1,):
        errors.append(f"{tag}boundaries shape {b.shape} != (P+1,) = "
                      f"({rowmap.P + 1},)")
        return errors
    if b[0] != 0 or b[-1] != rowmap.D:
        errors.append(f"{tag}boundaries do not span [0, D): "
                      f"b[0]={int(b[0])}, b[-1]={int(b[-1])}, D={rowmap.D}")
    if (np.diff(b) < 0).any():
        errors.append(f"{tag}boundaries not monotone: {b.tolist()}")
    sizes = np.diff(b)
    if (sizes > rowmap.R).any():
        p = int(np.argmax(sizes))
        errors.append(f"{tag}block {p} holds {int(sizes[p])} rows > padded "
                      f"extent R = {rowmap.R}")
    perm = np.asarray(rowmap.perm)
    if perm.shape != (rowmap.D,) or np.unique(perm).size != rowmap.D:
        errors.append(f"{tag}perm is not a permutation of [0, D)")
    if not rowmap.is_bijection():
        errors.append(f"{tag}embed/extract is not a bijection "
                      f"(extract(embed(X)) != X)")
    else:
        # spot-check the roundtrip on data — cheap and fully independent
        # of the is_bijection() implementation
        rng = np.random.default_rng(0)
        X = rng.standard_normal(rowmap.D)
        if not np.array_equal(rowmap.extract(rowmap.embed(X)), X):
            errors.append(f"{tag}extract(embed(X)) != X on random data")
    return errors


def lint_comm_plan(cp, label: str = "", n_b: int = 3, S_d: int = 8
                   ) -> list[str]:
    """SpmvCommPlan internal consistency across the engine axes.

    On the exact path this cross-checks ``L``/``n_vc`` against the pair
    counts, lints both neighbor schedules, and verifies the byte
    accounting (``a2a_bytes_per_device``, ``comm_bytes_per_device``) is
    the moved-entry count times ``n_b * S_d`` for both engines.
    """
    tag = f"[{label}] " if label else ""
    errors: list[str] = []
    if cp.n_row <= 1 or cp.L == 0:
        # zero-halo plan: everything must collapse to "no communication"
        if cp.a2a_bytes_per_device(n_b, S_d) != 0:
            errors.append(f"{tag}zero-halo plan predicts nonzero a2a bytes")
        if cp.moved_entries_per_device("a2a") != 0:
            errors.append(f"{tag}zero-halo plan moves a2a entries")
        if cp.pair_counts is not None:
            if cp.pair_counts.any():
                errors.append(f"{tag}zero-halo plan carries nonzero "
                              f"pair_counts")
            for sched in ("cyclic", "matching"):
                if cp.permute_schedule(sched)[0]:
                    errors.append(f"{tag}zero-halo plan has {sched} rounds")
        return errors
    pc = cp.pair_counts
    if pc is not None:
        pc = np.asarray(pc)
        if np.diagonal(pc).any():
            errors.append(f"{tag}pair_counts has nonzero diagonal "
                          f"(self-halo)")
        if int(pc.max()) != cp.L:
            errors.append(f"{tag}L = {cp.L} != max pair volume "
                          f"{int(pc.max())}")
        recv = pc.sum(axis=0)
        if not np.array_equal(recv, np.asarray(cp.n_vc)):
            errors.append(f"{tag}column sums of pair_counts disagree with "
                          f"n_vc (remote-column accounting broken)")
        errors += lint_schedules(pc, label=label)
        for sched in ("cyclic", "matching"):
            H = int(sum(cp.permute_schedule(sched)[1]))
            if cp.moved_entries_per_device("compressed", sched) != H:
                errors.append(f"{tag}moved_entries(compressed, {sched}) != "
                              f"round sum H = {H}")
            want = H * n_b * S_d
            got = cp.comm_bytes_per_device("compressed", n_b, S_d, sched)
            if got != want:
                errors.append(f"{tag}comm_bytes(compressed, {sched}) = "
                              f"{got} != H*n_b*S_d = {want}")
            terms = cp.spmv_collectives("compressed", sched, n_b, S_d)
            if sum(b * c for _, b, c in terms) != want:
                errors.append(f"{tag}spmv_collectives(compressed, {sched}) "
                              f"bytes disagree with comm_bytes ({want})")
    moved = cp.moved_entries_per_device("a2a")
    if moved != cp.n_row * cp.L:
        errors.append(f"{tag}moved_entries(a2a) = {moved} != P*L = "
                      f"{cp.n_row * cp.L}")
    if cp.a2a_bytes_per_device(n_b, S_d) != moved * n_b * S_d:
        errors.append(f"{tag}a2a_bytes_per_device != moved*n_b*S_d")
    terms = cp.spmv_collectives("a2a", "cyclic", n_b, S_d)
    if sum(b * c for _, b, c in terms) != moved * n_b * S_d:
        errors.append(f"{tag}spmv_collectives(a2a) bytes disagree with "
                      f"a2a_bytes_per_device")
    if cp.rowmap is not None:
        errors += lint_rowmap(cp.rowmap, label=label)
    return errors


def lint_dist_ell(ell, label: str = "") -> list[str]:
    """Engine-side invariants of a built operator: the schedules the
    engine will actually execute (``DistEll.neighbor_plan``) must match
    the ones re-derived from its own pair counts, and the send indices
    must stay inside the local row block."""
    from ..core.spmv import SPMV_SCHEDULES, neighbor_schedule

    tag = f"[{label}] " if label else ""
    errors: list[str] = []
    send = np.asarray(ell.send_idx)
    if send.size and (send.min() < 0 or send.max() >= ell.R):
        errors.append(f"{tag}send_idx outside the local row block "
                      f"[0, R={ell.R})")
    if ell.pair_counts is None:
        return errors
    pc = np.asarray(ell.pair_counts)
    if int(pc.max(initial=0)) > ell.L:
        errors.append(f"{tag}pair volume {int(pc.max())} exceeds the "
                      f"padded slot count L = {ell.L}")
    for sched in SPMV_SCHEDULES:
        perms, round_L = neighbor_schedule(pc, sched)
        if not pc.any():
            if perms:
                errors.append(f"{tag}zero-halo operator but {sched} "
                              f"schedule has rounds")
            continue
        plan = ell.neighbor_plan(schedule=sched)
        if plan.perms != perms or plan.round_L != round_L:
            errors.append(f"{tag}engine {sched} schedule diverges from "
                          f"neighbor_schedule(pair_counts) — plan and "
                          f"engine no longer share one source of truth")
        errors += lint_rounds(pc, plan.perms, plan.round_L,
                              label=f"{label}:{sched}" if label else sched)
        pairs = plan.scheduled_pairs()
        if len(set(pairs)) != len(pairs):
            errors.append(f"{tag}{sched} schedule repeats a (src, dst) "
                          f"pair across rounds")
    return errors


def lint_sstep(cp1, cps, label: str = "", n_b: int = 3, S_d: int = 8,
               degree: int = 8) -> list[str]:
    """Depth-s ghost-zone plan invariants against the depth-1 plan.

    ``cp1`` is the classic per-SpMV halo plan, ``cps`` the depth-s plan
    of the SAME matrix on the SAME partition. Two families of checks:

    * **ghost coverage** — the depth-s ghost set contains the depth-1
      halo (``n_vc_s >= n_vc_1`` and ``pair_counts_s >= pair_counts_1``
      elementwise; BFS reachability is monotone in depth), and the
      per-depth cumulative counts ``ghost_cum`` rise monotonically from
      0 to the full ghost count, with depth 1 matching the classic halo;
    * **byte accounting** — the plan's own column sums, pad ``L``, and
      the whole-filter :meth:`SpmvCommPlan.sstep_collectives` terms,
      whose total must equal ``moved x (2.ceil(n/s) - 1) x n_b x S_d``
      for both comm engines (the first exchange ships single width, the
      remaining ``ceil(n/s) - 1`` ship the doubled ``[w1 | w2]`` payload).
    """
    tag = f"[{label}] " if label else ""
    errors: list[str] = []
    s = int(getattr(cps, "sstep", 1))
    if s < 2:
        return [f"{tag}lint_sstep called on a depth-{s} plan"]
    if getattr(cp1, "sstep", 1) != 1:
        errors.append(f"{tag}reference plan has sstep = {cp1.sstep} != 1")
    if cps.n_row != cp1.n_row:
        return errors + [f"{tag}plans disagree on the shard count "
                         f"({cps.n_row} vs {cp1.n_row})"]
    # --- ghost coverage -------------------------------------------------
    nv1 = np.asarray(cp1.n_vc, dtype=np.int64)
    nvs = np.asarray(cps.n_vc, dtype=np.int64)
    if (nvs < nv1).any():
        errors.append(f"{tag}depth-{s} ghost count smaller than the "
                      f"depth-1 halo on some shard (coverage hole)")
    if (cp1.pair_counts is not None and cps.pair_counts is not None
            and (np.asarray(cps.pair_counts)
                 < np.asarray(cp1.pair_counts)).any()):
        errors.append(f"{tag}depth-{s} pair_counts drop below the "
                      f"depth-1 volumes for some (sender, receiver) pair")
    gc = cps.ghost_cum
    if gc is None or len(gc) != s + 1:
        errors.append(f"{tag}ghost_cum missing or wrong length "
                      f"({None if gc is None else len(gc)} != {s + 1})")
    else:
        if gc[0] != 0:
            errors.append(f"{tag}ghost_cum[0] = {gc[0]} != 0")
        if any(gc[d] > gc[d + 1] for d in range(s)):
            errors.append(f"{tag}ghost_cum not monotone: {gc}")
        if int(gc[s]) != int(nvs.max(initial=0)):
            errors.append(f"{tag}ghost_cum[{s}] = {gc[s]} != max ghost "
                          f"count {int(nvs.max(initial=0))}")
        if int(gc[1]) != int(nv1.max(initial=0)):
            errors.append(f"{tag}ghost_cum[1] = {gc[1]} != depth-1 halo "
                          f"max {int(nv1.max(initial=0))} (depth-1 slice "
                          f"of the BFS diverges from the classic plan)")
        if cps.sstep_work_factor() < 1.0:
            errors.append(f"{tag}sstep_work_factor < 1")
    # --- byte accounting ------------------------------------------------
    if cps.pair_counts is not None:
        pcs = np.asarray(cps.pair_counts)
        if int(pcs.max(initial=0)) != cps.L:
            errors.append(f"{tag}depth-{s} L = {cps.L} != max pair "
                          f"volume {int(pcs.max(initial=0))}")
        if not np.array_equal(pcs.sum(axis=0), nvs):
            errors.append(f"{tag}depth-{s} pair_counts column sums "
                          f"disagree with n_vc")
    ng = cps.n_groups(degree)
    if ng != -(-degree // s):
        errors.append(f"{tag}n_groups({degree}) = {ng} != ceil({degree}/"
                      f"{s})")
    for comm, sched in (("a2a", "cyclic"), ("compressed", "cyclic"),
                        ("compressed", "matching")):
        moved = cps.moved_entries_per_device(comm, sched)
        want = moved * (2 * ng - 1) * n_b * S_d
        terms = cps.sstep_collectives(comm, sched, n_b, S_d, degree)
        got = sum(b * c for _, b, c in terms)
        if got != want:
            errors.append(f"{tag}sstep_collectives({comm}, {sched}) total "
                          f"bytes {got} != moved*(2*ng-1)*n_b*S_d = {want}")
        if sum(c for _, _, c in terms) != ng * cps.rounds_per_exchange(
                comm, sched):
            errors.append(f"{tag}sstep_collectives({comm}, {sched}) op "
                          f"count disagrees with ng * rounds_per_exchange")
    return errors


def lint_sampled_plan(cp, band=None, label: str = "") -> list[str]:
    """Sampled-plan invariants: the estimated plan must satisfy every
    structural :func:`lint_comm_plan` check (the engines consume it
    through the same code paths as an exact plan), it must be marked
    estimated (``exact=False`` is what keeps the s-step axis off it),
    and its advertised confidence band (``core/sketch.py ChiBand``) must
    be well-formed and contain the plan's own center χ — a band that
    excludes its own point estimate is a broken error model, whatever
    the true values are."""
    tag = f"[{label}] " if label else ""
    errors = lint_comm_plan(cp, label=label)
    if cp.exact:
        errors.append(f"{tag}sampled plan is marked exact=True (the "
                      f"planner would trust it for depth-s ghosts)")
    if band is not None:
        if not band.valid():
            errors.append(f"{tag}confidence band is malformed: {band}")
        elif not band.contains(cp.chi):
            errors.append(f"{tag}band does not contain the plan's own "
                          f"center χ estimate ({cp.chi})")
    return errors


def run_plan_lint(matrix, n_rows=(4, 8), balances=("rows", "commvol"),
                  label: str = "") -> list[str]:
    """Full pattern-only lint of one matrix: comm plans (and their
    schedules, byte accounting, and row maps) at every shard count in
    ``n_rows`` crossed with the partition ``balances``."""
    from ..core.partition import plan_rowmap
    from ..core.planner import comm_plan

    errors: list[str] = []
    for P in n_rows:
        for balance in balances:
            cell = f"{label}P{P}:{balance}" if label else f"P{P}:{balance}"
            if balance == "rows":
                cp = comm_plan(matrix, P, exact=True)
            else:
                rm = plan_rowmap(matrix, P, balance=balance)
                errors += lint_rowmap(rm, label=cell)
                cp = comm_plan(matrix, P, rowmap=rm)
            errors += lint_comm_plan(cp, label=cell)
    return errors

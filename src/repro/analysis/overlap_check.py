"""Overlap dependency checker: prove the split-phase engine's halo
exchange is independent of the local contraction, from the jaxpr alone.

The split-phase (``overlap=True``) SpMV engines issue the halo collective
*before* the local contraction so XLA's async scheduler can hide the
exchange behind local work. That only helps if the dependence structure
permits it; this pass traverses the closed jaxpr of an engine closure
(tracing only — nothing is compiled or executed) and checks two
conditions:

* **(A) independent exchange** — no halo collective (``all_to_all`` /
  ``ppermute``) takes a transitive data dependence on any contraction
  output. A violation means the exchange cannot start until local
  compute finishes: the engine silently lost its overlap.
* **(B) hideable work** — at least one contraction has no transitive
  dependence on any collective, i.e. there *is* local work the exchange
  can hide behind. The plain engines fail exactly this condition (their
  single contraction consumes the received halo), which is the built-in
  sanity check that the pass is not vacuous.

Contractions are ``lax.scan`` / ``while`` / ``dot_general`` /
``pallas_call`` equations (the ELL contraction is a scan over slot
columns in the jnp engines and a ``pallas_call`` in the kernelized
ones). Sub-jaxprs of ``pjit`` / ``shard_map`` / custom-derivative
wrappers are traversed with per-variable precision; bodies of
sequential loops and kernels are traversed conservatively (every body
input inherits the loop's union dependence set), so a collective nested
*inside* a sequential contraction loop is reported as dependent.

The round-pipelined compressed engine needs a sharper statement than
(A)/(B): its halo contraction is split into per-round sub-blocks, and
the whole point is that round ``r``'s contraction must not wait for any
round ``> r``'s collective. :func:`check_round_pipeline` proves this as
a *prefix-chain* property of the jaxpr — see its docstring. The
unpipelined body fails the proof (its single halo contraction witnesses
only the full chain), which is the built-in non-vacuity control.
"""
from __future__ import annotations

import dataclasses

from jax import core as jax_core
import jax

__all__ = ["OverlapReport", "PipelineReport", "check_split_phase",
           "check_round_pipeline", "HALO_PRIMITIVES",
           "COLLECTIVE_PRIMITIVES", "CONTRACTION_PRIMITIVES"]

HALO_PRIMITIVES = frozenset({"all_to_all", "ppermute"})
COLLECTIVE_PRIMITIVES = HALO_PRIMITIVES | {
    "psum", "all_gather", "reduce_scatter", "pmax", "pmin", "pgather"}
CONTRACTION_PRIMITIVES = frozenset({"scan", "while", "dot_general",
                                    "pallas_call"})

# containers traversed with exact per-variable dependence mapping
# (their invars line up 1:1 with the sub-jaxpr's invars)
_PRECISE_CONTAINERS = ("pjit", "shard_map", "closed_call", "core_call",
                       "remat", "checkpoint", "custom_jvp_call",
                       "custom_vjp_call", "custom_jvp_call_jaxpr")


@dataclasses.dataclass
class OverlapReport:
    """Result of one split-phase dependency check."""

    collectives: list  # (label, primitive, depends_on_contraction: bool)
    contractions: list  # (label, depends_on_collective: bool)
    errors: list

    @property
    def ok(self) -> bool:
        return not self.errors

    @property
    def independent_contractions(self) -> int:
        """Contractions with no collective ancestor — the local work the
        exchange can hide behind."""
        return sum(1 for _, dep in self.contractions if not dep)

    def describe(self) -> str:
        lines = [f"collectives: {len(self.collectives)}, contractions: "
                 f"{len(self.contractions)} "
                 f"({self.independent_contractions} independent)"]
        for label, prim, dep in self.collectives:
            lines.append(f"  {label}: {prim} "
                         f"{'DEPENDS ON CONTRACTION' if dep else 'independent'}")
        lines += [f"  ERROR: {e}" for e in self.errors]
        return "\n".join(lines)


def _sub_jaxprs(value):
    if isinstance(value, jax_core.ClosedJaxpr):
        return [value.jaxpr]
    if isinstance(value, jax_core.Jaxpr):
        return [value]
    if isinstance(value, (tuple, list)):
        return [j for v in value for j in _sub_jaxprs(v)]
    return []


class _Recorder:
    def __init__(self):
        self.counter = 0
        self.collectives = []  # (label, prim, frozenset deps)
        self.contractions = []  # (label, frozenset deps)

    def fresh(self, prim: str) -> str:
        self.counter += 1
        return f"{prim}#{self.counter}"


_EMPTY: frozenset = frozenset()


def _walk(jaxpr, in_deps, rec: _Recorder):
    """Propagate per-variable dependence sets through one jaxpr; each set
    holds ("contract"|"coll", label) tags of ancestor equations. Returns
    the outvars' sets."""
    env: dict = {}

    def read(atom):
        if isinstance(atom, jax_core.Literal):
            return _EMPTY
        return env.get(atom, _EMPTY)

    for v, d in zip(jaxpr.invars, in_deps):
        env[v] = d
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        ins = [read(a) for a in eqn.invars]
        union = frozenset().union(*ins) if ins else _EMPTY
        subs = [j for v in eqn.params.values() for j in _sub_jaxprs(v)]
        if (prim in _PRECISE_CONTAINERS and len(subs) == 1
                and len(subs[0].invars) == len(eqn.invars)):
            outs = _walk(subs[0], ins, rec)
            for v, d in zip(eqn.outvars, outs):
                env[v] = d
            continue
        node = union
        if prim in CONTRACTION_PRIMITIVES:
            label = rec.fresh(prim)
            rec.contractions.append((label, union))
            node = node | {("contract", label)}
        if prim in COLLECTIVE_PRIMITIVES:
            label = rec.fresh(prim)
            rec.collectives.append((label, prim, union))
            node = node | {("coll", label)}
        # conservative traversal of remaining sub-jaxprs (loop bodies,
        # branches): every body input inherits the node's dependence set
        # and everything found inside feeds back into the outputs
        for sj in subs:
            inner = _walk(sj, [node] * len(sj.invars), rec)
            for d in inner:
                node = node | d
        for v in eqn.outvars:
            env[v] = node
    return [read(v) for v in jaxpr.outvars]


def check_split_phase(fn, *args, halo_primitives=HALO_PRIMITIVES,
                      expect_halo: bool = True) -> OverlapReport:
    """Trace ``fn(*args)`` (ShapeDtypeStructs suffice) and prove the
    split-phase conditions (A) and (B) on its jaxpr.

    ``expect_halo=False`` skips condition (B) and the no-halo error — for
    zero-halo cells (pillar layout / single shard) where the engine
    legitimately emits no exchange.
    """
    closed = jax.make_jaxpr(fn)(*args)
    rec = _Recorder()
    _walk(closed.jaxpr, [_EMPTY] * len(closed.jaxpr.invars), rec)

    def has(deps, kind):
        return any(k == kind for k, _ in deps)

    collectives = []
    errors = []
    halo_seen = False
    for label, prim, deps in rec.collectives:
        if prim not in halo_primitives:
            continue
        halo_seen = True
        dep = has(deps, "contract")
        collectives.append((label, prim, dep))
        if dep:
            culprits = sorted(lbl for k, lbl in deps if k == "contract")
            errors.append(
                f"halo collective {label} ({prim}) depends on contraction "
                f"output(s) {culprits}: the exchange cannot start before "
                f"local compute — split-phase overlap is lost")
    contractions = [(label, has(deps, "coll"))
                    for label, deps in rec.contractions]
    if expect_halo:
        if not halo_seen:
            errors.append("no halo collective found in the jaxpr — nothing "
                          "to overlap (wrong closure, or a zero-halo cell "
                          "checked with expect_halo=True)")
        elif not any(not dep for _, dep in contractions):
            errors.append(
                "no contraction is independent of the collectives: there "
                "is no local work the halo exchange could hide behind "
                "(the plain engines fail exactly this)")
    return OverlapReport(collectives=collectives, contractions=contractions,
                        errors=errors)


@dataclasses.dataclass
class PipelineReport:
    """Result of one round-pipeline prefix-chain proof."""

    n_rounds: int
    prefix_lengths: list  # sorted prefix lengths witnessed by contractions
    contractions: list  # (label, prefix length | None when not a prefix)
    errors: list

    @property
    def ok(self) -> bool:
        return not self.errors

    def describe(self) -> str:
        lines = [f"rounds: {self.n_rounds}, contractions: "
                 f"{len(self.contractions)}, prefix lengths witnessed: "
                 f"{self.prefix_lengths}"]
        for label, k in self.contractions:
            lines.append(f"  {label}: "
                         f"{'NOT A PREFIX' if k is None else f'prefix {k}'}")
        lines += [f"  ERROR: {e}" for e in self.errors]
        return "\n".join(lines)


def check_round_pipeline(fn, *args,
                         halo_primitives=HALO_PRIMITIVES) -> PipelineReport:
    """Trace ``fn(*args)`` and prove the round-pipelined engine's
    split-phase structure as a prefix-chain property of its jaxpr.

    Let ``c_1 .. c_n`` be the halo collectives in program order (the
    ``ppermute`` rounds of the compressed schedule). The proof requires:

    * **(a) prefix dependence** — every contraction's halo-collective
      dependence set is a *prefix* ``{c_1 .. c_k}`` of the chain. This
      is exactly "round ``r``'s contraction depends on no later round's
      collective": a contraction that consumed ``c_3`` without ``c_2``
      would wait on a round it does not need, and one whose set skips an
      earlier round would be reading an incompletely assembled buffer.
    * **(b) endpoints witnessed** — some contraction has prefix length
      0 (the local block, contracted before any exchange lands) and some
      has length ``n`` (the final round's halo slice is contracted).
    * **(c) strict interleaving** — for ``n >= 2``, some contraction
      witnesses a prefix length strictly between 0 and ``n``. The
      *unpipelined* split-phase body satisfies (a) and (b) — its single
      halo contraction depends on the full chain — but fails (c), so it
      cannot masquerade as pipelined; that failure is the checker's
      non-vacuity control (``make_spmv(..., pipeline=False)``).

    Bodies of sequential loops and Pallas kernels are traversed
    conservatively, so contractions nested inside the recorded ones
    re-witness the same prefix lengths and cannot weaken the proof.
    """
    closed = jax.make_jaxpr(fn)(*args)
    rec = _Recorder()
    _walk(closed.jaxpr, [_EMPTY] * len(closed.jaxpr.invars), rec)
    halo = [label for label, prim, _ in rec.collectives
            if prim in halo_primitives]
    order = {lbl: i for i, lbl in enumerate(halo)}
    n = len(halo)
    errors = []
    contractions = []
    lengths: set = set()
    for label, deps in rec.contractions:
        hidx = sorted(order[lbl] for k, lbl in deps
                      if k == "coll" and lbl in order)
        if hidx != list(range(len(hidx))):
            contractions.append((label, None))
            errors.append(
                f"contraction {label} depends on halo collectives "
                f"{[halo[i] for i in hidx]} — not a prefix of the "
                f"program-order round chain {halo}: it waits on a later "
                f"round's collective without consuming every earlier one")
            continue
        contractions.append((label, len(hidx)))
        lengths.add(len(hidx))
    if 0 not in lengths:
        errors.append(
            "no contraction is independent of the halo rounds (prefix "
            "length 0 missing): no local block is contracted while the "
            "exchange is in flight")
    if n and n not in lengths:
        errors.append(
            f"no contraction consumes the full {n}-round chain (prefix "
            f"length {n} missing): the final round's halo slice is never "
            f"contracted")
    if n >= 2 and not any(0 < k < n for k in lengths):
        errors.append(
            f"no contraction witnesses a strict prefix of the {n}-round "
            f"chain (lengths seen: {sorted(lengths)}): every halo "
            f"contraction waits for the last round's collective — the "
            f"engine is not round-pipelined")
    return PipelineReport(n_rounds=n, prefix_lengths=sorted(lengths),
                          contractions=contractions, errors=errors)

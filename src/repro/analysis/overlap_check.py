"""Overlap dependency checker: prove the split-phase engine's halo
exchange is independent of the local contraction, from the jaxpr alone.

The split-phase (``overlap=True``) SpMV engines issue the halo collective
*before* the local contraction so XLA's async scheduler can hide the
exchange behind local work. That only helps if the dependence structure
permits it; this pass traverses the closed jaxpr of an engine closure
(tracing only — nothing is compiled or executed) and checks two
conditions:

* **(A) independent exchange** — no halo collective (``all_to_all`` /
  ``ppermute``) takes a transitive data dependence on any contraction
  output. A violation means the exchange cannot start until local
  compute finishes: the engine silently lost its overlap.
* **(B) hideable work** — at least one contraction has no transitive
  dependence on any collective, i.e. there *is* local work the exchange
  can hide behind. The plain engines fail exactly this condition (their
  single contraction consumes the received halo), which is the built-in
  sanity check that the pass is not vacuous.

Contractions are ``lax.scan`` / ``while`` / ``dot_general`` equations
(the ELL contraction is a scan over slot columns). Sub-jaxprs of
``pjit`` / ``shard_map`` / custom-derivative wrappers are traversed with
per-variable precision; bodies of sequential loops are traversed
conservatively (every body input inherits the loop's union dependence
set), so a collective nested *inside* a sequential contraction loop is
reported as dependent — which is what a future round-pipelined engine
must explicitly reason about, not silently pass.
"""
from __future__ import annotations

import dataclasses

from jax import core as jax_core
import jax

__all__ = ["OverlapReport", "check_split_phase", "HALO_PRIMITIVES",
           "COLLECTIVE_PRIMITIVES", "CONTRACTION_PRIMITIVES"]

HALO_PRIMITIVES = frozenset({"all_to_all", "ppermute"})
COLLECTIVE_PRIMITIVES = HALO_PRIMITIVES | {
    "psum", "all_gather", "reduce_scatter", "pmax", "pmin", "pgather"}
CONTRACTION_PRIMITIVES = frozenset({"scan", "while", "dot_general"})

# containers traversed with exact per-variable dependence mapping
# (their invars line up 1:1 with the sub-jaxpr's invars)
_PRECISE_CONTAINERS = ("pjit", "shard_map", "closed_call", "core_call",
                       "remat", "checkpoint", "custom_jvp_call",
                       "custom_vjp_call", "custom_jvp_call_jaxpr")


@dataclasses.dataclass
class OverlapReport:
    """Result of one split-phase dependency check."""

    collectives: list  # (label, primitive, depends_on_contraction: bool)
    contractions: list  # (label, depends_on_collective: bool)
    errors: list

    @property
    def ok(self) -> bool:
        return not self.errors

    @property
    def independent_contractions(self) -> int:
        """Contractions with no collective ancestor — the local work the
        exchange can hide behind."""
        return sum(1 for _, dep in self.contractions if not dep)

    def describe(self) -> str:
        lines = [f"collectives: {len(self.collectives)}, contractions: "
                 f"{len(self.contractions)} "
                 f"({self.independent_contractions} independent)"]
        for label, prim, dep in self.collectives:
            lines.append(f"  {label}: {prim} "
                         f"{'DEPENDS ON CONTRACTION' if dep else 'independent'}")
        lines += [f"  ERROR: {e}" for e in self.errors]
        return "\n".join(lines)


def _sub_jaxprs(value):
    if isinstance(value, jax_core.ClosedJaxpr):
        return [value.jaxpr]
    if isinstance(value, jax_core.Jaxpr):
        return [value]
    if isinstance(value, (tuple, list)):
        return [j for v in value for j in _sub_jaxprs(v)]
    return []


class _Recorder:
    def __init__(self):
        self.counter = 0
        self.collectives = []  # (label, prim, frozenset deps)
        self.contractions = []  # (label, frozenset deps)

    def fresh(self, prim: str) -> str:
        self.counter += 1
        return f"{prim}#{self.counter}"


_EMPTY: frozenset = frozenset()


def _walk(jaxpr, in_deps, rec: _Recorder):
    """Propagate per-variable dependence sets through one jaxpr; each set
    holds ("contract"|"coll", label) tags of ancestor equations. Returns
    the outvars' sets."""
    env: dict = {}

    def read(atom):
        if isinstance(atom, jax_core.Literal):
            return _EMPTY
        return env.get(atom, _EMPTY)

    for v, d in zip(jaxpr.invars, in_deps):
        env[v] = d
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        ins = [read(a) for a in eqn.invars]
        union = frozenset().union(*ins) if ins else _EMPTY
        subs = [j for v in eqn.params.values() for j in _sub_jaxprs(v)]
        if (prim in _PRECISE_CONTAINERS and len(subs) == 1
                and len(subs[0].invars) == len(eqn.invars)):
            outs = _walk(subs[0], ins, rec)
            for v, d in zip(eqn.outvars, outs):
                env[v] = d
            continue
        node = union
        if prim in CONTRACTION_PRIMITIVES:
            label = rec.fresh(prim)
            rec.contractions.append((label, union))
            node = node | {("contract", label)}
        if prim in COLLECTIVE_PRIMITIVES:
            label = rec.fresh(prim)
            rec.collectives.append((label, prim, union))
            node = node | {("coll", label)}
        # conservative traversal of remaining sub-jaxprs (loop bodies,
        # branches): every body input inherits the node's dependence set
        # and everything found inside feeds back into the outputs
        for sj in subs:
            inner = _walk(sj, [node] * len(sj.invars), rec)
            for d in inner:
                node = node | d
        for v in eqn.outvars:
            env[v] = node
    return [read(v) for v in jaxpr.outvars]


def check_split_phase(fn, *args, halo_primitives=HALO_PRIMITIVES,
                      expect_halo: bool = True) -> OverlapReport:
    """Trace ``fn(*args)`` (ShapeDtypeStructs suffice) and prove the
    split-phase conditions (A) and (B) on its jaxpr.

    ``expect_halo=False`` skips condition (B) and the no-halo error — for
    zero-halo cells (pillar layout / single shard) where the engine
    legitimately emits no exchange.
    """
    closed = jax.make_jaxpr(fn)(*args)
    rec = _Recorder()
    _walk(closed.jaxpr, [_EMPTY] * len(closed.jaxpr.invars), rec)

    def has(deps, kind):
        return any(k == kind for k, _ in deps)

    collectives = []
    errors = []
    halo_seen = False
    for label, prim, deps in rec.collectives:
        if prim not in halo_primitives:
            continue
        halo_seen = True
        dep = has(deps, "contract")
        collectives.append((label, prim, dep))
        if dep:
            culprits = sorted(lbl for k, lbl in deps if k == "contract")
            errors.append(
                f"halo collective {label} ({prim}) depends on contraction "
                f"output(s) {culprits}: the exchange cannot start before "
                f"local compute — split-phase overlap is lost")
    contractions = [(label, has(deps, "coll"))
                    for label, deps in rec.contractions]
    if expect_halo:
        if not halo_seen:
            errors.append("no halo collective found in the jaxpr — nothing "
                          "to overlap (wrong closure, or a zero-halo cell "
                          "checked with expect_halo=True)")
        elif not any(not dep for _, dep in contractions):
            errors.append(
                "no contraction is independent of the collectives: there "
                "is no local work the halo exchange could hide behind "
                "(the plain engines fail exactly this)")
    return OverlapReport(collectives=collectives, contractions=contractions,
                        errors=errors)

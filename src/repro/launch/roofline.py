"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds per step (v5e):

  compute    = HLO_FLOPs_per_chip / 197 TFLOP/s (bf16)
  memory     = HLO_bytes_per_chip / 819 GB/s (HBM)
  collective = collective_bytes_per_chip / 50 GB/s (ICI link)

cost_analysis() of the SPMD-partitioned executable reports *per-chip*
flops/bytes. Collective bytes are not in cost_analysis: we parse the
optimized HLO and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (per-chip shapes).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-chip bytes moved through each collective kind (operand sizes)."""
    out: dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "-done" in ls[:120]:
            continue
        m = None
        for c in _COLLECTIVES:
            if re.search(rf"= [a-z0-9\[\]\(\), {{}}]*{c}(-start)?\(", ls) or \
               re.search(rf"\b{c}(-start)?\(", ls):
                m = c
                break
        if m is None:
            continue
        # operand types appear inside the call parens; result type before '='
        paren = ls.split("(", 1)[-1]
        shapes = _SHAPE_RE.findall(paren)
        if not shapes:  # fall back to the result type
            shapes = _SHAPE_RE.findall(ls.split("=")[0] + "=" +
                                       ls.split("=", 1)[1].split(m)[0])
        out[m] += sum(_shape_bytes(dt, dims) for dt, dims in shapes)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_chip: float
    hbm_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict
    model_flops_total: float
    n_chips: int

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs * chips): remat/redundancy waste."""
        tot = self.flops_per_chip * self.n_chips
        return self.model_flops_total / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / additive step time (how close to roofline)."""
        t_total = self.t_compute + self.t_memory + self.t_collective
        t_useful = (self.model_flops_total / self.n_chips) / PEAK_FLOPS
        return t_useful / t_total if t_total else 0.0

    @property
    def roofline_fraction_overlap(self) -> float:
        """Same, assuming perfect compute/memory/collective overlap (max)."""
        t_total = max(self.t_compute, self.t_memory, self.t_collective)
        t_useful = (self.model_flops_total / self.n_chips) / PEAK_FLOPS
        return t_useful / t_total if t_total else 0.0

    xla_cost: dict | None = None

    def row(self) -> dict:
        return {
            "xla_cost": self.xla_cost,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "roofline_fraction_overlap": self.roofline_fraction_overlap,
            "coll_breakdown": self.coll_breakdown,
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
        }


def analyze(compiled, model_flops_total: float, n_chips: int) -> Roofline:
    """Roofline terms from the compiled artifact.

    Uses the loop-aware HLO analyzer (launch/hlo_analysis.py) because
    cost_analysis() counts while-loop bodies once (validated against
    unrolled modules in tests); raw cost_analysis values are kept in
    ``xla_cost`` for reference.
    """
    from .hlo_analysis import analyze_hlo, xla_cost_analysis

    cost = xla_cost_analysis(compiled)
    h = analyze_hlo(compiled.as_text())
    r = Roofline(
        flops_per_chip=h.flops,
        hbm_bytes_per_chip=h.hbm_bytes,
        coll_bytes_per_chip=h.coll_bytes,
        coll_breakdown={k: int(v) for k, v in h.coll_breakdown.items()},
        model_flops_total=model_flops_total,
        n_chips=n_chips,
    )
    r.xla_cost = {"flops": float(cost.get("flops", 0.0)),
                  "bytes_accessed": float(cost.get("bytes accessed", 0.0))}
    return r


def memory_summary(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        ma = None
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out

"""Production mesh construction.

``make_production_mesh`` is a function (not a module-level constant) so
importing this module never touches jax device state. The single-pod mesh
is 16 x 16 = 256 chips (v5e pod); multi-pod adds a leading ``pod`` axis
(2 pods = 512 chips). The ``pod`` axis extends the *vertical* layer of the
paper: work sharded along it (vector bundles, data-parallel replicas)
never communicates during SpMV / forward-backward — only gradient
reduction and redistribution cross it.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, have {len(devices)}; "
            "run under src/repro/launch/dryrun.py which sets "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512"
        )
    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)

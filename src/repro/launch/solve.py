"""Eigensolver launcher: FD on a ScaMaC-style matrix with selectable
vector layout (the paper's production entry point).

  PYTHONPATH=src python -m repro.launch.solve --family SpinChainXXZ \
      --params n_sites=14,n_up=7 --n-target 8 --target -0.16 \
      --n-row 4 --n-col 2

``--layout auto`` hands the choice to the χ-driven planner
(``core/planner.py``): it enumerates every (n_row x n_col) mesh split,
layout, comm engine (padded ``a2a`` vs sparsity-``compressed`` neighbor
ppermute), round scheduler (``cyclic`` shifts vs greedy ``matching``
rounds for the compressed engine), overlap option, and row partition
(equal ``rows`` vs planned ``commvol`` boundaries,
``core/partition.py``), scores each with the analytic perf model from
the sparsity pattern alone, prints the ranking, and runs the
minimum-predicted-time configuration (``--n-row/--n-col`` are then
ignored; ``--spmv-overlap``, ``--spmv-comm``, ``--spmv-schedule``,
``--spmv-balance``, and ``--spmv-reorder`` are decided by the plan —
an explicitly requested ``--spmv-reorder rcm`` widens the planner's
partition axis).
``--machine`` points the planner at calibrated constants
(``dryrun --fit-machine``) instead of the built-in TPU-v5e model.

``--degraded-ok`` continues with a reduced search space if a column group
is lost (the vertical layer is fault-isolating: bundles of search vectors
are statistically interchangeable).

``--plan-cache PATH`` puts a persistent plan cache in front of the
``--layout auto`` planner (``service/plan_cache.py``): repeat matrices
skip ``plan_layout`` entirely and run the byte-identical cached engine
plan. ``--serve REQUESTS.json`` switches to service mode
(``service/batcher.py``): the JSON lists eigensolve requests; compatible
requests (same sparsity pattern, same planned engine cell) are batched
into one panel as extra vector columns and demuxed bit-identically to
solo solves — see docs/service.md.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import numpy as np
import jax

from ..core import FDConfig, FilterDiag, make_solver_mesh, panel
from ..core.layouts import Layout
from ..matrices import get_family


def parse_params(s: str) -> dict:
    out = {}
    for kv in (s or "").split(","):
        if not kv:
            continue
        k, v = kv.split("=")
        try:
            out[k] = int(v)
        except ValueError:
            out[k] = float(v)
    return out


def solve(family: str, params: dict, fd: FDConfig, n_row: int, n_col: int,
          verbose: bool = True, degraded_ok: bool = False,
          machine=None, plan_cache: str | None = None):
    jax.config.update("jax_enable_x64", True)
    n_dev = len(jax.devices())
    mat = get_family(family, **params)
    rowmap = None
    if fd.layout == "auto":
        # χ-driven planner: pick the mesh split AND both SpMV engine axes
        # (overlap, comm) from the sparsity pattern before any mesh is
        # built (core/planner.py). The caller's config is left untouched
        # so it can be reused for another matrix (the plan depends on the
        # pattern). With --plan-cache the result persists keyed by the
        # pattern hash — a repeat matrix skips the planner entirely.
        from ..core import perf_model as pm
        from ..service.plan_cache import PlanCache, cached_plan_layout

        cache = PlanCache(plan_cache) if plan_cache else None
        plan, hit = cached_plan_layout(
            mat, n_dev, n_search=fd.n_search,
            cache=cache,
            d_pad=-(-mat.D // n_dev) * n_dev,
            machine=machine or pm.TPU_V5E,
            reorder=tuple(dict.fromkeys(("none", fd.spmv_reorder))),
            kernel=tuple(dict.fromkeys((False, fd.spmv_kernel))),
            sstep=tuple(dict.fromkeys((1, fd.spmv_sstep))),
            plan_mode=fd.plan_mode)
        if verbose and cache is not None:
            print(f"[plan-cache] {'hit' if hit else 'miss'} "
                  f"({plan_cache})")
        best = plan.best
        if verbose:
            print(plan.report())
            print(f"[auto] running {best.describe()} "
                  f"(spmv_overlap={best.overlap}, spmv_comm={best.comm}, "
                  f"spmv_schedule={best.schedule}, "
                  f"spmv_balance={best.balance}, "
                  f"spmv_reorder={best.reorder}, "
                  f"spmv_kernel={best.kernel}, "
                  f"spmv_sstep={best.sstep})")
        n_row, n_col = best.n_row, best.n_col
        # the chosen split realizes the planned layout; the winning
        # candidate's rowmap (planned at P = n_row·n_col) is handed to
        # FilterDiag verbatim so the map is never re-planned
        rowmap = best.rowmap
        fd = dataclasses.replace(fd, layout="panel", spmv_overlap=best.overlap,
                                 spmv_comm=best.comm,
                                 spmv_schedule=best.schedule,
                                 spmv_balance=best.balance,
                                 spmv_reorder=best.reorder,
                                 spmv_kernel=best.kernel,
                                 spmv_sstep=best.sstep)
    if n_row * n_col > n_dev:
        raise RuntimeError(f"mesh {n_row}x{n_col} needs {n_row*n_col} devices, "
                           f"have {n_dev}")
    mesh = make_solver_mesh(n_row, n_col)
    try:
        with mesh:
            fdd = FilterDiag(mat, mesh, fd, rowmap=rowmap)
            return fdd.solve(verbose=verbose)
    except Exception:
        if not degraded_ok or n_col == 1:
            raise
        # degraded mode: drop one column group worth of search vectors.
        # The device count changed, so any auto-planned rowmap is stale —
        # FilterDiag re-plans one from fd2's balance/reorder fields.
        fd2 = FDConfig(**{**fd.__dict__,
                          "n_search": fd.n_search - fd.n_search // n_col})
        mesh2 = make_solver_mesh(n_row, n_col - 1) if n_col > 1 else mesh
        with mesh2:
            fdd = FilterDiag(mat, mesh2, fd2)
            return fdd.solve(verbose=verbose)


def serve(requests_path: str, plan_cache: str | None = None,
          machine=None, verbose: bool = True):
    """Service mode: solve a JSON batch of eigensolve requests.

    The file is ``{"requests": [{...}], "checkpoint_root": optional,
    "service_seed": optional}``; each request gives ``req_id``,
    ``family``/``params`` and the per-tenant fields (``n_target``,
    ``n_search``, ``target``, ``tol``, ``max_iters``, ``seed``).
    Compatible requests are batched into one panel (docs/service.md).
    """
    jax.config.update("jax_enable_x64", True)
    from ..service import EigenService, SolveRequest
    from ..service.plan_cache import PlanCache

    with open(requests_path) as f:
        spec = json.load(f)
    cache = PlanCache(plan_cache) if plan_cache else None
    svc = EigenService(plan_cache=cache, machine=machine,
                       ckpt_root=spec.get("checkpoint_root"),
                       service_seed=int(spec.get("service_seed", 0)),
                       verbose=verbose)
    for r in spec["requests"]:
        svc.submit(SolveRequest(
            req_id=str(r["req_id"]), family=r["family"],
            params=dict(r.get("params", {})),
            n_target=int(r.get("n_target", 4)),
            n_search=int(r.get("n_search", 16)),
            target=float(r.get("target", 0.0)),
            tol=float(r.get("tol", 1e-9)),
            max_iters=int(r.get("max_iters", 40)),
            seed=int(r.get("seed", 7))))
    results = svc.drain()
    if verbose:
        if cache is not None:
            print(f"[plan-cache] hits={cache.hits} misses={cache.misses} "
                  f"plan_calls={cache.plan_calls}")
        for rid in sorted(results):
            r = results[rid]
            print(f"[{rid}] converged {r.n_converged} in {r.iterations} "
                  f"iterations / {r.total_spmvs} SpMVs; eigenvalues "
                  f"{np.array2string(r.eigenvalues, precision=8)}")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--family")
    ap.add_argument("--params", default="")
    ap.add_argument("--n-target", type=int, default=8)
    ap.add_argument("--n-search", type=int, default=32)
    ap.add_argument("--target", type=float, default=0.0)
    ap.add_argument("--tol", type=float, default=1e-10)
    ap.add_argument("--max-iters", type=int, default=40)
    ap.add_argument("--n-row", type=int, default=1,
                    help="horizontal-layer width N_row (D sliced over "
                         "N_row row shards; SpMV halo exchange runs here)")
    ap.add_argument("--n-col", type=int, default=1,
                    help="vertical-layer width N_col (search vectors split "
                         "into N_col bundles; no SpMV communication)")
    ap.add_argument("--layout", default="panel",
                    choices=["stack", "panel", "pillar", "auto"],
                    help="filter-phase vector layout on the mesh: 'stack' "
                         "(N_col=1, D over all devices), 'panel' (N_row x "
                         "N_col grid), 'pillar' (N_row=1, comm-free SpMV), "
                         "or 'auto' — the χ-driven planner picks the mesh "
                         "split AND the overlap engine from the sparsity "
                         "pattern (overrides --n-row/--n-col/--spmv-overlap)")
    ap.add_argument("--spmv-overlap", action="store_true",
                    help="split-phase SpMV engine: issue the halo "
                         "exchange first and contract the local ELL block "
                         "while the bytes are in flight (the dry-run's "
                         "'+ov' layout suffix; T = max(T_comm, T_local) + "
                         "T_halo instead of additive Eq. 12)")
    ap.add_argument("--spmv-comm", default="a2a",
                    choices=["a2a", "compressed"],
                    help="halo-exchange engine: 'a2a' (one all_to_all "
                         "padded to the global max pair volume — moved "
                         "bytes scale with chi3) or 'compressed' "
                         "(neighbor ppermute rounds padded per round, "
                         "empty pairs skipped — moved bytes ~ chi2; the "
                         "dry-run's '+cmp' suffix; decided by --layout "
                         "auto)")
    ap.add_argument("--spmv-schedule", default="cyclic",
                    choices=["cyclic", "matching"],
                    help="round scheduler of the compressed halo "
                         "exchange: 'cyclic' (one ppermute round per "
                         "nonzero cyclic shift, pad = that shift's max "
                         "pair) or 'matching' (greedy max-weight "
                         "matchings — hot pairs of different shifts "
                         "share one round's pad, H_matching <= "
                         "H_cyclic; the dry-run's '+mat' suffix; "
                         "decided by --layout auto)")
    ap.add_argument("--spmv-balance", default="rows",
                    choices=["rows", "commvol"],
                    help="row partition of the horizontal layer: 'rows' "
                         "(the paper's equal row blocks) or 'commvol' "
                         "(core/partition.py plans non-uniform shard "
                         "boundaries that minimize the engines' wire "
                         "volumes — per-row cost alpha*nnz + beta*cut, "
                         "prefix-balanced then refined by greedy cut "
                         "descent; the dry-run's '+cv' suffix; decided "
                         "by --layout auto)")
    ap.add_argument("--spmv-reorder", default="none",
                    choices=["none", "rcm"],
                    help="row order applied before partitioning: 'none' "
                         "or 'rcm' (reverse-Cuthill-McKee bandwidth "
                         "reduction — eigenvalues unchanged, "
                         "eigenvectors un-permuted on output; the "
                         "dry-run's '+rcm' suffix; with --layout auto "
                         "an explicit 'rcm' widens the planner's "
                         "partition axis)")
    ap.add_argument("--spmv-kernel", action="store_true",
                    help="Pallas kernel engine: dispatch the local ELL "
                         "contraction to the ell_gather tile kernel and "
                         "the fused Chebyshev recurrence step to the "
                         "cheb_dia kernel where the operator is comm-free "
                         "diagonal-structured (interpret mode off-TPU; "
                         "bit-identical to the jnp engines — see "
                         "docs/kernels.md; with --layout auto an explicit "
                         "kernel request widens the planner's kernel "
                         "axis, scored with the fused kappa=5 term)")
    ap.add_argument("--spmv-sstep", type=int, default=1,
                    help="communication-avoiding s-step filter (seventh "
                         "engine axis): apply the degree-n Chebyshev "
                         "filter in ceil(n/s) depth-s ghost exchanges "
                         "instead of n per-SpMV halo exchanges — the "
                         "exchange ships the depth-s BFS ghost zone once "
                         "and s recurrence steps run on the extended "
                         "block (redundant ghost-row work, fewer "
                         "latency-bound rounds; the dry-run's '+s2'/'+s3' "
                         "cell suffixes; bit-identical to the s=1 engines "
                         "— see docs/s-step.md). With --layout auto an "
                         "explicit s > 1 widens the planner's s-step "
                         "axis, scored with the alpha-latency machine "
                         "term (s > 1 wins only when rounds, not bytes, "
                         "dominate)")
    ap.add_argument("--plan-mode", default="auto",
                    choices=["exact", "sampled", "auto"],
                    help="pattern-pass strategy for planning (partition "
                         "boundaries, chi counts, comm plans): 'exact' "
                         "(full pattern scans; the partition axis is "
                         "silently dropped past the size gate), 'sampled' "
                         "(core/sketch.py plans from a seeded row "
                         "subsample — Horvitz-Thompson chi/L estimates "
                         "with a confidence band and a coarsened commvol "
                         "descent; D >= 1e7 matrix-free instances plan in "
                         "seconds), or 'auto' (exact below the gate, "
                         "sampled above it)")
    ap.add_argument("--machine", default="tpu-v5e",
                    help="machine model for --layout auto planning: "
                         "'tpu-v5e', 'meggie', or a path to a JSON model "
                         "saved by `dryrun --fit-machine` (calibrated "
                         "b_c/kappa)")
    ap.add_argument("--degraded-ok", action="store_true")
    ap.add_argument("--plan-cache", default=None, metavar="PATH",
                    help="persistent plan cache (service/plan_cache.py): "
                         "a merge-on-write JSON store of --layout auto "
                         "planner results keyed by (pattern hash, P, "
                         "machine fingerprint) — a repeat matrix skips "
                         "plan_layout and runs the byte-identical cached "
                         "engine plan; bumped cache_version invalidates "
                         "old entries wholesale")
    ap.add_argument("--serve", default=None, metavar="REQUESTS.json",
                    help="service mode (service/batcher.py): solve a JSON "
                         "batch of eigensolve requests; compatible "
                         "requests (same sparsity pattern, same planned "
                         "engine cell) share one SpMV panel as extra "
                         "vector columns and demux bit-identically to "
                         "solo solves (--family etc. are ignored; "
                         "see docs/service.md)")
    args = ap.parse_args(argv)
    if args.spmv_schedule != "cyclic" and args.spmv_comm != "compressed" \
            and args.layout != "auto":
        ap.error(f"--spmv-schedule {args.spmv_schedule} requires "
                 "--spmv-comm compressed (or --layout auto, which picks "
                 "both)")
    from ..core import perf_model as pm

    machine = pm.resolve_machine(args.machine)
    if args.serve:
        serve(args.serve, plan_cache=args.plan_cache, machine=machine)
        return
    if not args.family:
        ap.error("--family is required (unless --serve is given)")
    fd = FDConfig(n_target=args.n_target, n_search=args.n_search,
                  target=args.target, tol=args.tol, max_iters=args.max_iters,
                  layout=args.layout, spmv_overlap=args.spmv_overlap,
                  spmv_comm=args.spmv_comm,
                  spmv_schedule=args.spmv_schedule,
                  spmv_balance=args.spmv_balance,
                  spmv_reorder=args.spmv_reorder,
                  spmv_kernel=args.spmv_kernel,
                  spmv_sstep=args.spmv_sstep,
                  plan_mode=args.plan_mode)
    res = solve(args.family, parse_params(args.params), fd,
                args.n_row, args.n_col, degraded_ok=args.degraded_ok,
                machine=machine, plan_cache=args.plan_cache)
    print(f"converged {res.n_converged} eigenpairs in {res.iterations} "
          f"iterations / {res.total_spmvs} SpMVs "
          f"({res.redistributions} redistributions, "
          f"{100*res.redist_time/max(res.wall_time,1e-9):.1f}% redistribution time)")
    print("eigenvalues:", np.array2string(res.eigenvalues, precision=10))


if __name__ == "__main__":
    main()

"""Eigensolver launcher: FD on a ScaMaC-style matrix with selectable
vector layout (the paper's production entry point).

  PYTHONPATH=src python -m repro.launch.solve --family SpinChainXXZ \
      --params n_sites=14,n_up=7 --n-target 8 --target -0.16 \
      --n-row 4 --n-col 2

``--degraded-ok`` continues with a reduced search space if a column group
is lost (the vertical layer is fault-isolating: bundles of search vectors
are statistically interchangeable).
"""
from __future__ import annotations

import argparse

import numpy as np
import jax

from ..core import FDConfig, FilterDiag, make_solver_mesh, panel
from ..core.layouts import Layout
from ..matrices import get_family


def parse_params(s: str) -> dict:
    out = {}
    for kv in (s or "").split(","):
        if not kv:
            continue
        k, v = kv.split("=")
        try:
            out[k] = int(v)
        except ValueError:
            out[k] = float(v)
    return out


def solve(family: str, params: dict, fd: FDConfig, n_row: int, n_col: int,
          verbose: bool = True, degraded_ok: bool = False):
    jax.config.update("jax_enable_x64", True)
    n_dev = len(jax.devices())
    if n_row * n_col > n_dev:
        raise RuntimeError(f"mesh {n_row}x{n_col} needs {n_row*n_col} devices, "
                           f"have {n_dev}")
    mat = get_family(family, **params)
    mesh = make_solver_mesh(n_row, n_col)
    try:
        with mesh:
            fdd = FilterDiag(mat, mesh, fd)
            return fdd.solve(verbose=verbose)
    except Exception:
        if not degraded_ok or n_col == 1:
            raise
        # degraded mode: drop one column group worth of search vectors
        fd2 = FDConfig(**{**fd.__dict__,
                          "n_search": fd.n_search - fd.n_search // n_col})
        mesh2 = make_solver_mesh(n_row, n_col - 1) if n_col > 1 else mesh
        with mesh2:
            fdd = FilterDiag(mat, mesh2, fd2)
            return fdd.solve(verbose=verbose)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", required=True)
    ap.add_argument("--params", default="")
    ap.add_argument("--n-target", type=int, default=8)
    ap.add_argument("--n-search", type=int, default=32)
    ap.add_argument("--target", type=float, default=0.0)
    ap.add_argument("--tol", type=float, default=1e-10)
    ap.add_argument("--max-iters", type=int, default=40)
    ap.add_argument("--n-row", type=int, default=1)
    ap.add_argument("--n-col", type=int, default=1)
    ap.add_argument("--spmv-overlap", action="store_true",
                    help="split-phase SpMV: hide the halo all_to_all behind "
                         "the local ELL contraction")
    ap.add_argument("--degraded-ok", action="store_true")
    args = ap.parse_args(argv)
    fd = FDConfig(n_target=args.n_target, n_search=args.n_search,
                  target=args.target, tol=args.tol, max_iters=args.max_iters,
                  spmv_overlap=args.spmv_overlap)
    res = solve(args.family, parse_params(args.params), fd,
                args.n_row, args.n_col, degraded_ok=args.degraded_ok)
    print(f"converged {res.n_converged} eigenpairs in {res.iterations} "
          f"iterations / {res.total_spmvs} SpMVs "
          f"({res.redistributions} redistributions, "
          f"{100*res.redist_time/max(res.wall_time,1e-9):.1f}% redistribution time)")
    print("eigenvalues:", np.array2string(res.eigenvalues, precision=10))


if __name__ == "__main__":
    main()

"""Training launcher: data pipeline -> train_step loop with checkpointing,
health tracking, and (multi-pod) compressed cross-pod gradient reduction.

On this CPU container it runs reduced configs end-to-end (the examples use
it); on a cluster the same entry point runs the full configs — the mesh
and shardings are identical to the dry-run's.

XLA flags for the real run (latency hiding / collective overlap) are
centralized in ``tpu_xla_flags()`` and documented in EXPERIMENTS §Perf.
"""
from __future__ import annotations

import argparse
import logging
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import get_config, get_smoke_config
from ..data import DataConfig, TokenPipeline
from ..models import steps as steps_mod
from ..models import transformer as tfm
from ..optim import adamw
from ..runtime import StepTimer
from ..checkpoint import CheckpointManager, restore
from .shardings import batch_pspecs, opt_pspecs, param_pspecs, to_shardings

log = logging.getLogger("repro.train")


def tpu_xla_flags() -> str:
    """Production XLA flags: enable async collectives + latency-hiding
    scheduler so the halo/gradient collectives overlap local compute."""
    return " ".join([
        "--xla_enable_async_all_gather=true",
        "--xla_enable_async_collective_permute=true",
        "--xla_tpu_enable_async_collective_fusion=true",
        "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true",
        "--xla_latency_hiding_scheduler_rerun=2",
    ])


def train(arch: str, *, steps: int = 50, batch: int = 8, seq: int = 128,
          smoke: bool = True, mesh=None, ckpt_dir: str | None = None,
          log_every: int = 10, opt_overrides: dict | None = None):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    ocfg = adamw.AdamWConfig(moment_dtype=cfg.optimizer_dtype, warmup_steps=10,
                             total_steps=steps, **(opt_overrides or {}))
    pipe = TokenPipeline(cfg)
    key = jax.random.PRNGKey(0)
    params, opt_state = steps_mod.init_train_state(cfg, ocfg, key)
    step_fn = steps_mod.make_train_step(cfg, ocfg)
    if mesh is not None:
        pshape = jax.eval_shape(lambda: tfm.init_params(cfg, key))
        pspec = param_pspecs(cfg, mesh, pshape)
        psh = to_shardings(mesh, pspec)
        params = jax.device_put(params, psh)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    manager = CheckpointManager(ckpt_dir, interval=max(steps // 3, 1)) if ckpt_dir else None
    start = 0
    if manager is not None:
        try:
            (params, opt_state), start, extra = restore(
                manager.directory, (params, opt_state))
            start += 1
            log.info("resumed at step %d", start)
        except FileNotFoundError:
            pass
    timer = StepTimer()
    losses = []
    for i in range(start, steps):
        b = pipe.batch(i, batch, seq)
        timer.start()
        params, opt_state, metrics = jitted(params, opt_state, b)
        loss = float(metrics["loss"])
        timer.stop()
        losses.append(loss)
        if manager is not None:
            manager.maybe_save(i, (params, opt_state), extra={"pipeline_index": i})
        if i % log_every == 0 or i == steps - 1:
            print(f"[train {arch}] step {i:5d} loss {loss:.4f} "
                  f"({timer.ewma:.3f}s/step ewma)")
    return params, opt_state, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)
    train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
          smoke=not args.full, ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()

"""PartitionSpec rules: map params / batches / decode states onto the mesh.

Mesh semantics (DESIGN.md §4): ``model`` = horizontal layer (tensor
parallel), ``data`` (+``pod``) = vertical layer (batch / bundles).
``fsdp_tp`` additionally shards the big weight matrices (and hence
optimizer state) along the data axes — ZeRO-3-style, required for
arctic-480b / deepseek-67b.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey, tree_map_with_path

from ..models.config import ModelConfig

TP = "model"


def dp_axes(mesh: Mesh):
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _axes_ok(mesh: Mesh, shape, spec: P) -> bool:
    """True if every sharded dim divides evenly (jit input requirement)."""
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if dim % n:
            return False
    return True


def _pick(mesh: Mesh, shape, *candidates: P) -> P:
    for c in candidates:
        if _axes_ok(mesh, shape, c):
            return c
    return P(*([None] * len(shape)))


def _param_rule(pstr: str, shape, cfg: ModelConfig, mesh: Mesh) -> P:
    ndim = len(shape)
    dp = dp_axes(mesh)
    fsdp = dp if cfg.param_sharding == "fsdp_tp" else None
    stacked = pstr.startswith("segments/")
    lead = (None,) if stacked else ()

    def spec(*tail):
        full = lead + tail
        assert len(full) == ndim, (pstr, ndim, full)
        # drop the fsdp axes (not TP) if they don't divide
        if _axes_ok(mesh, shape, P(*full)):
            return P(*full)
        relaxed = tuple(None if (a == fsdp and a is not None) else a for a in full)
        if _axes_ok(mesh, shape, P(*relaxed)):
            return P(*relaxed)
        return _pick(mesh, shape, P(*full), P(*relaxed))

    last = pstr.rsplit("/", 1)[-1]
    # ---------------- embeddings / head ----------------
    if pstr in ("embed/table", "lm_head/table"):
        # vocab on model (the LM-head layout switch); fall back to sharding
        # d_model when the vocab is not 16-divisible (hubert/granite/hymba)
        return _pick(mesh, shape, P(TP, fsdp), P(TP, None), P(fsdp, TP),
                     P(None, TP))
    if pstr.startswith("frontend/"):
        return _pick(mesh, shape, P(None, TP) if ndim == 2 else P(TP))
    # ---------------- norms & small vectors ----------------
    if "norm" in pstr or last in ("scale", "bias", "b", "mu_x", "w0", "dt_bias",
                                  "ln_scale", "q_norm", "k_norm", "D"):
        return spec(*([None] * (ndim - len(lead))))
    # ---------------- attention ----------------
    if "/attn/" in pstr:
        if "/wo/" in pstr:
            return spec(TP, fsdp)
        return spec(fsdp, TP)  # wq/wk/wv: output (heads) dim on model
    # ---------------- MoE ----------------
    if "/moe/router" in pstr:
        return spec(None, None)
    if "/moe/experts/" in pstr:
        if cfg.moe_expert_sharding == "data_zero":
            # storage sharded over data axes (ZeRO), replicated at compute:
            # dispatch math stays shard-local (no collectives) and GSPMD
            # re-gathers the small expert weights once per layer.
            zshard = dp  # shard the widest inner dim over the data axes
            if last == "down":  # [E, ff, d]
                return _pick(mesh, shape, P(*(lead + (None, zshard, None))),
                             P(*(lead + (None, None, zshard))))
            return _pick(mesh, shape, P(*(lead + (None, zshard, None))),  # [E,d,ff]
                         P(*(lead + (None, None, zshard))))
        # expert parallelism on model; if n_experts is not 16-divisible
        # fall back to TP inside the expert ffn dim
        if last == "down":
            return _pick(mesh, shape, P(*(lead + (TP, None, fsdp))),
                         P(*(lead + (TP, None, None))),
                         P(*(lead + (None, TP, fsdp))),
                         P(*(lead + (None, TP, None))))
        return _pick(mesh, shape, P(*(lead + (TP, fsdp, None))),
                     P(*(lead + (TP, None, None))),
                     P(*(lead + (None, fsdp, TP))),
                     P(*(lead + (None, None, TP))))
    if "/moe/dense/" in pstr or "/mlp/" in pstr:
        if "down" in pstr:
            return spec(TP, fsdp)
        return spec(fsdp, TP)
    # ---------------- RWKV6 ----------------
    if "/time_mix/" in pstr:
        if last in ("Wr", "Wk", "Wv", "Wg"):
            return spec(fsdp, TP)
        if last == "Wo":
            return spec(TP, fsdp)
        if last == "u":
            return spec(TP, None)
        return spec(*([None] * (ndim - len(lead))))  # loras, mu
    if "/channel_mix/" in pstr:
        if last == "Wv":
            return spec(TP, fsdp)
        return spec(fsdp, TP) if last in ("Wk", "Wr") else spec(*([None] * (ndim - len(lead))))
    # ---------------- Mamba ----------------
    if "/mamba/" in pstr:
        if last == "in_proj":
            return spec(fsdp, TP)
        if last in ("x_proj", "out_proj", "A_log"):
            return spec(TP, None)
        if last == "conv_w":
            return spec(None, TP)
        return spec(*([None] * (ndim - len(lead))))
    # default: replicate
    return P(*([None] * ndim))


def param_pspecs(cfg: ModelConfig, mesh: Mesh, params_shape):
    """PartitionSpec pytree matching the params pytree (shapes suffice)."""

    def rule(path, leaf):
        return _param_rule(_path_str(path), tuple(leaf.shape), cfg, mesh)

    return tree_map_with_path(rule, params_shape)


def opt_pspecs(cfg: ModelConfig, mesh: Mesh, opt_state_shape, params_spec):
    """Optimizer-state specs: moments follow params; int8 codes are
    flat-sharded across every mesh axis (pure memory layout)."""
    flat_axes = tuple(mesh.axis_names)

    def rule(path, leaf):
        pstr = _path_str(path)
        if pstr == "step":
            return P()
        # strip leading m/ or v/
        sub = pstr.split("/", 1)[1] if "/" in pstr else pstr
        if cfg.optimizer_dtype == "int8":
            # (codes [nblk, BLOCK], scales [nblk, 1]) leaves — flat-sharded
            return _pick(mesh, leaf.shape, P(flat_axes, None),
                         P(("data", "model"), None), P(("model",), None),
                         P(("data",), None))
        ps = params_spec
        for k in sub.split("/"):
            ps = ps[int(k)] if isinstance(ps, list) else ps[k]
        return ps

    return tree_map_with_path(rule, opt_state_shape)


def batch_pspecs(cfg: ModelConfig, mesh: Mesh, batch_shape):
    dp = dp_axes(mesh)

    def rule(path, leaf):
        b = leaf.shape[0] if leaf.ndim else 1
        bdp = dp if (dp and b % _axes_size(mesh, dp) == 0) else ()
        return P(bdp if bdp else None, *([None] * (leaf.ndim - 1)))

    return tree_map_with_path(rule, batch_shape)


def _axes_size(mesh, axes):
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def decode_state_pspecs(cfg: ModelConfig, mesh: Mesh, state_shape, batch: int):
    """Ring/KV caches: batch on data axes when divisible, ring axis (S / W)
    sharded on ``model`` — decode softmax then reduces tiny [B,H] partials
    over ``model`` instead of moving the cache."""
    dp = dp_axes(mesh)
    bdp = dp if batch % _axes_size(mesh, dp) == 0 else None

    def rule(path, leaf):
        pstr = _path_str(path)
        last = pstr.rsplit("/", 1)[-1]
        nd = leaf.ndim
        sh = tuple(leaf.shape)
        if last in ("k", "v"):  # [Ls,B,W,H,hd]: ring axis W on model
            return _pick(mesh, sh, P(None, bdp, TP, None, None),
                         P(None, bdp, None, None, None))
        if last == "wkv":  # [Ls,B,H,hd,hd]
            return _pick(mesh, sh, P(None, bdp, TP, None, None),
                         P(None, bdp, None, None, None))
        if last == "ssm":  # [Ls,B,di,N]
            return _pick(mesh, sh, P(None, bdp, TP, None),
                         P(None, bdp, None, None))
        if last == "conv":  # [Ls,B,3,di]
            return _pick(mesh, sh, P(None, bdp, None, TP),
                         P(None, bdp, None, None))
        if last in ("x_tm", "x_cm"):
            return _pick(mesh, sh, P(None, bdp, None))
        return P(*([None] * nd))

    return tree_map_with_path(rule, state_shape)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and extract memory/cost/roofline data.

This is the proof that the distribution config is coherent without real
hardware: a sharding mismatch, compile-time OOM, or unsupported collective
fails the compile. Inputs are ShapeDtypeStructs — nothing is allocated.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out cache.json]
  PYTHONPATH=src python -m repro.launch.dryrun --eigen exciton200 --layout pillar
  PYTHONPATH=src python -m repro.launch.dryrun --eigen hubbard16 --layout panel+ov --plan
  PYTHONPATH=src python -m repro.launch.dryrun --eigen roadnet48k --layout panel \
      --spmv-comm compressed --plan
  PYTHONPATH=src python -m repro.launch.dryrun --eigen hubnet48k --layout panel \
      --spmv-comm compressed --spmv-schedule matching --plan
  PYTHONPATH=src python -m repro.launch.dryrun --eigen hubnet48k --layout panel \
      --spmv-comm compressed --spmv-schedule matching --spmv-balance commvol --plan
  PYTHONPATH=src python -m repro.launch.dryrun --eigen hubnet48k --layout panel \
      --spmv-sstep 2 --verify
  PYTHONPATH=src python -m repro.launch.dryrun --fit-machine --fit-out machine_fit.json
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import functools
import json
import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

from ..configs import ARCHS, EIGEN_CONFIGS, get_config
from ..models import decode as dec
from ..models import steps as steps_mod
from ..models import transformer as tfm
from ..models.config import ModelConfig, SHAPES, applicable_shapes
from ..optim import adamw
from . import roofline as rl
from .mesh import make_production_mesh
from .shardings import (batch_pspecs, decode_state_pspecs, dp_axes,
                        opt_pspecs, param_pspecs, to_shardings)


# ----------------------------------------------------------- input specs --

def batch_specs(cfg: ModelConfig, batch: int, seq: int):
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    S = jax.ShapeDtypeStruct
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        return {
            "features": S((batch, seq, cfg.frontend_dim), dt),
            "mask": S((batch, seq), jnp.bool_),
            "labels": S((batch, seq), jnp.int32),
        }
    if cfg.family == "vlm":
        npfx = min(cfg.n_prefix_embeds, max(seq // 8, 1))
        return {
            "tokens": S((batch, seq - npfx), jnp.int32),
            "patches": S((batch, npfx, cfg.frontend_dim), dt),
            "labels": S((batch, seq - npfx), jnp.int32),
        }
    return {
        "tokens": S((batch, seq), jnp.int32),
        "labels": S((batch, seq), jnp.int32),
    }


def input_specs(arch: str, shape: str):
    """(cfg, cell, spec pytrees) for one dry-run cell."""
    cfg = get_config(arch)
    cell = SHAPES[shape]
    if cell.kind in ("train", "prefill"):
        return cfg, cell, batch_specs(cfg, cell.global_batch, cell.seq_len)
    return cfg, cell, None


def _params_shape(cfg):
    return jax.eval_shape(lambda: tfm.init_params(cfg, jax.random.PRNGKey(0)))


def _model_flops(cfg: ModelConfig, cell) -> float:
    """MODEL_FLOPS: 6*N_active*D_tokens (train) / 2*N_active*D_tokens (fwd)."""
    n = cfg.n_active_params()
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    return (6.0 if cell.kind == "train" else 2.0) * n * tokens


# ------------------------------------------------------------- cell runs --

def lower_cell(arch: str, shape: str, mesh) -> tuple:
    """Build the jitted step for one cell and lower it on the mesh."""
    cfg, cell, batch = input_specs(arch, shape)
    pshape = _params_shape(cfg)
    pspec = param_pspecs(cfg, mesh, pshape)
    psh = to_shardings(mesh, pspec)
    if cell.kind == "train":
        ocfg = adamw.AdamWConfig(moment_dtype=cfg.optimizer_dtype)
        oshape = jax.eval_shape(functools.partial(adamw.init_state, ocfg), pshape)
        osh = to_shardings(mesh, opt_pspecs(cfg, mesh, oshape, pspec))
        bsh = to_shardings(mesh, batch_pspecs(cfg, mesh, batch))
        step = steps_mod.make_train_step(cfg, ocfg)
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(pshape, oshape, batch)
    elif cell.kind == "prefill":
        sshape = jax.eval_shape(functools.partial(
            dec.init_decode_state, cfg, cell.global_batch, cell.seq_len))
        ssh = to_shardings(mesh, decode_state_pspecs(cfg, mesh, sshape, cell.global_batch))
        bsh = to_shardings(mesh, batch_pspecs(cfg, mesh, batch))
        step = steps_mod.make_prefill_step(cfg, cell.seq_len)
        jitted = jax.jit(step, in_shardings=(psh, bsh), out_shardings=(None, ssh))
        lowered = jitted.lower(pshape, batch)
    else:  # decode: one new token against a seq_len-deep cache
        B = cell.global_batch
        sshape = jax.eval_shape(functools.partial(
            dec.init_decode_state, cfg, B, cell.seq_len))
        ssh = to_shardings(mesh, decode_state_pspecs(cfg, mesh, sshape, B))
        dp = dp_axes(mesh)
        tok_spec = batch_pspecs(cfg, mesh, {"t": jax.ShapeDtypeStruct((B,), jnp.int32)})["t"]
        tsh = to_shardings(mesh, tok_spec)
        step = steps_mod.make_decode_step(cfg)
        jitted = jax.jit(step, in_shardings=(psh, ssh, tsh, None),
                         out_shardings=(None, ssh), donate_argnums=(1,))
        lowered = jitted.lower(
            pshape, sshape, jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32))
    return cfg, cell, lowered


def run_cell(arch: str, shape: str, multi_pod: bool = False, verbose=True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    with mesh:
        cfg, cell, lowered = lower_cell(arch, shape, mesh)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = rl.memory_summary(compiled)
        roof = rl.analyze(compiled, _model_flops(cfg, cell), n_chips)
    rec = {
        "arch": arch, "shape": shape, "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips, "status": "ok",
        "t_lower_s": round(t_lower, 1), "t_compile_s": round(t_compile, 1),
        "memory": mem, "model_flops": _model_flops(cfg, cell),
        **roof.row(),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape} on {rec['mesh']}: OK "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        print(f"  memory_analysis: {mem}")
        print(f"  cost: flops/chip={roof.flops_per_chip:.3e} "
              f"bytes/chip={roof.hbm_bytes_per_chip:.3e} "
              f"coll bytes/chip={roof.coll_bytes_per_chip:.3e}")
        print(f"  roofline: compute={roof.t_compute*1e3:.2f}ms "
              f"memory={roof.t_memory*1e3:.2f}ms "
              f"collective={roof.t_collective*1e3:.2f}ms "
              f"dominant={roof.dominant} "
              f"useful={roof.useful_flops_ratio:.2f} "
              f"frac={roof.roofline_fraction:.3f}")
    return rec


# -------------------------------------------------- eigensolver dry-runs --

def run_eigen(name: str, layout_name: str = "pillar", multi_pod: bool = False,
              n_search: int | None = None, verbose=True,
              plan: bool = False, spmv_comm: str = "a2a",
              spmv_schedule: str = "cyclic", spmv_balance: str = "rows",
              spmv_reorder: str = "none", spmv_kernel: bool = False,
              spmv_sstep: int = 1, plan_mode: str = "auto",
              machine=None, verify: bool = False) -> dict:
    """Lower one FD macro-iteration (filter + redistributions + TSQR) for a
    paper config on the production mesh, using a reduced-bandwidth ELL
    surrogate with the *exact* χ-derived comm plan of the real matrix.

    ``layout_name`` may carry a ``+ov`` suffix (e.g. ``panel+ov``) to lower
    the split-phase overlap SpMV engine instead of the baseline; the record
    then also carries the overlap-aware perf-model prediction so the sweep
    can quantify when overlap restores scalability.

    ``spmv_comm="compressed"`` lowers the sparsity-compressed neighbor-
    permute engine instead of the padded all_to_all: the surrogate carries
    the real matrix's neighbor schedule (exact per-pair volumes where the
    pattern pass is affordable — CSR, small D, or finite ``reach`` — and
    the uniform χ-estimate otherwise), so the HLO-measured
    collective-permute volume is the engine's true wire footprint.
    ``spmv_schedule`` picks how those rounds are derived — ``"cyclic"``
    shifts (the ``+cmp`` cell suffix) or greedy ``"matching"`` rounds
    (``+mat``) — on the exact path; the estimated path always lowers the
    uniform cyclic rounds.

    ``spmv_balance``/``spmv_reorder`` lower the cell on a *planned* row
    partition (``core/partition.py``: commvol boundaries and/or the RCM
    row order, the ``+cv``/``+rcm`` cell suffixes): the surrogate then
    carries the planned map's exact per-pair volumes, so the
    HLO-measured bytes are the partitioned engine's true wire footprint.
    Requested partitions that cannot be planned (no halo at N_row = 1,
    or the per-row pattern pass unaffordable at this D) are relabeled
    back to ``rows``/``none`` so the record never claims a partition
    that did not lower.

    ``spmv_kernel=True`` requests the Pallas kernel engine (the ``+krn``
    cell suffix). The surrogate's plan arrays are ShapeDtypeStructs /
    tracers, so the host-side tile planner (``kernels/ops.py``) finds
    nothing concrete and the engine falls back to the jnp contraction by
    design — the lowered collectives (and hence every predicted ==
    measured check) are identical to the kernel-off cell, which is
    exactly the census contract the kernels must keep.

    ``spmv_sstep > 1`` lowers the communication-avoiding s-step filter
    cell (the ``+s2``/``+s3`` suffixes): the surrogate carries the real
    pattern's depth-s ghost plan (``comm_plan(..., sstep=s)``), the
    filter runs ⌈degree/s⌉ depth-s exchanges — a single-width seed
    exchange plus width-doubled ``[w1 | w2]`` group exchanges — and the
    ``--verify`` census attributes every one of them to the χ(A^s)
    terms of ``SpmvCommPlan.sstep_collectives``. s-step cells lower the
    plain (non-overlap) engine only, and need the exact pattern pass
    (requests it cannot honor are relabeled back to ``s = 1``).

    ``plan=True`` adds the χ-driven planner panel: the full candidate
    ranking (``core/planner.py``) for this matrix on the production mesh,
    plus the predicted SpMV collective volume of the lowered cell next to
    the HLO-measured one — prediction and measurement in one place; on a
    planned partition it also prints the before/after χ and pad volumes
    of the re-balanced rows."""
    from ..configs import get_config as gc
    from ..core import layouts as L
    from ..core.filter_diag import FDConfig
    from ..core import spmv as spmv_mod
    from ..core.orthogonalize import make_tsqr
    from ..core.redistribute import make_redistribute
    from ..core.chebyshev import chebyshev_filter
    from ..matrices import get_family

    conf = gc(name)
    fd: FDConfig = conf["fd"]
    overlap = layout_name.endswith("+ov")
    if overlap:
        layout_name = layout_name[:-3]
    mesh = make_production_mesh(multi_pod=multi_pod)
    axes = mesh.axis_names
    # map the solver layers onto the production mesh:
    #   horizontal (D) -> "model", vertical (bundles) -> "data" (+"pod")
    col_axes = tuple(a for a in axes if a != "model")
    if layout_name == "stack":
        panel_l = L.Layout("panel", ("model",) + col_axes, ())
    elif layout_name == "pillar":
        panel_l = L.Layout("pillar", (), ("model",) + col_axes)
    else:
        panel_l = L.Layout("panel", ("model",), col_axes)
    stack_l = L.Layout("stack", panel_l.dist_axes + panel_l.bundle_axes, ())
    mspec = dict(conf["matrix"])
    fam = get_family(mspec.pop("family"), **mspec)
    D = fam.D
    P_total = mesh.devices.size
    N_row = panel_l.n_row(mesh)
    n_s = n_search or fd.n_search
    # pad N_s to the bundle count
    n_col = panel_l.n_col(mesh)
    n_s = -(-n_s // max(n_col, 1)) * max(n_col, 1)
    dt = jnp.complex64 if fam.is_complex else jnp.float32

    # planned row partition of the cell (core/partition.py): the map is
    # planned at the cell's N_row with block_multiple = P_total/N_row so
    # its padded extent divides the full mesh (TSQR + redistribution run
    # at P_total). Unplannable requests are relabeled to rows/none.
    from ..core.partition import partition_plan_default, plan_rowmap

    rowmap = None
    use_sampled = plan_mode == "sampled" or (
        plan_mode == "auto" and not partition_plan_default(fam, N_row))
    if (spmv_balance, spmv_reorder) != ("rows", "none") and N_row > 1 \
            and partition_plan_default(fam, N_row, plan_mode) \
            and not (use_sampled and spmv_reorder != "none"):
        # sampled planning covers the commvol axis only (RCM needs the
        # full adjacency) — unplannable requests relabel below as usual
        rowmap = plan_rowmap(fam, N_row, balance=spmv_balance,
                             reorder=spmv_reorder,
                             block_multiple=P_total // N_row,
                             plan_mode=plan_mode)
        if rowmap.identity:
            rowmap = None
    if rowmap is None:
        spmv_balance, spmv_reorder = "rows", "none"
    D_pad = rowmap.D_pad if rowmap is not None \
        else -(-D // P_total) * P_total

    # surrogate distributed operator: exact comm plan (χ-padded all_to_all
    # or the compressed neighbor schedule) on a bandwidth-matched synthetic
    # ELL. Only ShapeDtypeStructs are built — the plan arrays are jit
    # *arguments*, nothing is allocated.
    from ..core.planner import comm_plan as _comm_plan
    from ..core.planner import exact_comm_default

    cp_part = None
    if rowmap is not None:
        if use_sampled and not exact_comm_default(fam):
            # the exact mapped pattern pass is exactly what sampled mode
            # avoids — estimate the planned map's volumes the same way
            from ..core.sketch import sampled_comm_plan
            cp_part = sampled_comm_plan(fam, N_row, rowmap=rowmap)
        else:
            cp_part = _comm_plan(fam, N_row, rowmap=rowmap)
        n_vc = cp_part.n_vc
    else:
        n_vc = fam.n_vc(np.minimum(np.arange(N_row + 1) * (D_pad // N_row), D)) if N_row > 1 else np.zeros(1)
    t0 = time.time()
    W = int(round(_nnzr(fam)))
    R = D_pad // N_row
    if N_row <= 1:
        L = 1
    elif cp_part is not None:
        L = max(cp_part.L, 1)  # the planned partition's exact pair max
    else:
        L = max(-(-int(n_vc.max()) // max(N_row - 1, 1)), 1)
    # overlap surrogate: split the width budget into local + halo parts
    # (halo rows ~ ceil(n_vc / R) entries wide on average)
    W_halo = max(1, -(-int(n_vc.max()) // max(R, 1))) if N_row > 1 else 1
    W_loc = max(1, W - W_halo)
    compressed = spmv_comm == "compressed" and N_row > 1
    perms, round_L = (), ()
    cp_nbr = None
    if compressed:
        # neighbor schedule of the real pattern: exact per-pair volumes
        # when the pattern pass is affordable, uniform χ-estimate rounds
        # otherwise (the prediction below always uses THIS schedule, so
        # predicted == measured stays exact either way). On a planned
        # partition the schedule comes from the planned map's own counts.
        if cp_part is not None:
            cp_nbr = cp_part
            perms, round_L = cp_nbr.permute_schedule(spmv_schedule)
        elif exact_comm_default(fam):
            cp_nbr = _comm_plan(fam, N_row, d_pad=D_pad, exact=True)
            perms, round_L = cp_nbr.permute_schedule(spmv_schedule)
        else:
            # without per-pair counts only the uniform cyclic rounds can
            # be lowered — relabel so the cell/record never claim a
            # matching engine that did not run
            spmv_schedule = "cyclic"
            perms = tuple(tuple((j, (j + k) % N_row) for j in range(N_row))
                          for k in range(1, N_row))
            round_L = (L,) * (N_row - 1)
    H = int(sum(round_L))

    # communication-avoiding s-step cell (the +s2/+s3 suffixes): the
    # surrogate carries the real pattern's depth-s ghost plan so the
    # lowered ⌈degree/s⌉ exchanges (one single-width seed + width-doubled
    # group payloads) are the engine's true wire footprint. Plain engine
    # only; requests the exact pattern pass cannot honor fall back to
    # the per-step cell so the record never claims an s that did not
    # lower.
    sstep = max(int(spmv_sstep), 1)
    if sstep > 1 and overlap:
        raise ValueError("s-step dry-run cells lower the plain engine "
                         "only (drop the '+ov' layout suffix)")
    if sstep > 1 and N_row <= 1:
        sstep = 1  # comm-free layout: every s is the same cell
    if sstep > 1 and not exact_comm_default(fam):
        # depth-s ghosts need the exact pattern pass whether or not a
        # partition was planned (a sampled rowmap does not change that)
        if verbose:
            print(f"[dryrun-eigen] {name}: depth-{sstep} ghost plan needs "
                  "the exact pattern pass — relabeling to s=1")
        sstep = 1
    cp_s = None
    G_s = L_s = 0
    perms_s, round_L_s = (), ()
    if sstep > 1:
        cp_s = (_comm_plan(fam, N_row, rowmap=rowmap, sstep=sstep)
                if rowmap is not None
                else _comm_plan(fam, N_row, d_pad=D_pad, sstep=sstep))
        G_s = int(cp_s.n_vc.max())
        L_s = int(cp_s.L)
        if G_s == 0:
            sstep, cp_s = 1, None  # no halo at this split
        elif compressed:
            perms_s, round_L_s = cp_s.permute_schedule(spmv_schedule)
            perms, round_L = perms_s, round_L_s  # the lowered schedule
            H = int(sum(round_L_s))

    ell_spec = dict(
        cols=jax.ShapeDtypeStruct((N_row, R, W), jnp.int32),
        vals=jax.ShapeDtypeStruct((N_row, R, W), dt),
        send_idx=jax.ShapeDtypeStruct((N_row, N_row, L), jnp.int32),
        cols_loc=jax.ShapeDtypeStruct((N_row, R, W_loc), jnp.int32),
        vals_loc=jax.ShapeDtypeStruct((N_row, R, W_loc), dt),
        cols_halo=jax.ShapeDtypeStruct((N_row, R, W_halo), jnp.int32),
        vals_halo=jax.ShapeDtypeStruct((N_row, R, W_halo), dt),
        send_nbr=jax.ShapeDtypeStruct((N_row, max(H, 1)), jnp.int32),
    )
    tsqr = make_tsqr(mesh, stack_l)
    to_panel, to_stack = make_redistribute(mesh, stack_l, panel_l)
    degree = 32

    # one surrogate body per engine combination; plan arrays arrive as jit
    # arguments and are planted pre-split (and pre-scheduled) on the
    # DistEll so the device code never materializes host data from tracers
    def make_nbr(send_nbr, cols_nbr, cols_halo_nbr):
        plan = spmv_mod.NeighborPlan(perms=perms, round_L=round_L,
                                     send_nbr=send_nbr, cols_nbr=cols_nbr,
                                     cols_halo_nbr=cols_halo_nbr)
        return {spmv_schedule: plan}

    def fd_iteration(V, mu, alpha, beta, cols, vals, send_idx, send_nbr):
        nbr = make_nbr(send_nbr, cols, cols) if compressed else None
        ell = spmv_mod.DistEll(cols=cols, vals=vals, send_idx=send_idx,
                               R=R, L=L, P=N_row, D=D, nbr=nbr)
        spmv = spmv_mod.make_spmv(mesh, panel_l, ell, comm=spmv_comm,
                                  schedule=spmv_schedule,
                                  use_kernel=spmv_kernel)
        Q, _ = tsqr(V)
        Vp = to_panel(Q)
        Vp = chebyshev_filter(spmv, mu, alpha, beta, Vp)
        return to_stack(Vp)

    def fd_iteration_ov(V, mu, alpha, beta, cols_loc, vals_loc, cols_halo,
                        vals_halo, send_idx, send_nbr):
        nbr = make_nbr(send_nbr, cols_loc, cols_halo) if compressed else None
        ell = spmv_mod.DistEll(cols=cols_loc, vals=vals_loc, send_idx=send_idx,
                               R=R, L=L, P=N_row, D=D,
                               cols_loc=cols_loc, vals_loc=vals_loc,
                               cols_halo=cols_halo, vals_halo=vals_halo,
                               nbr=nbr)
        spmv = spmv_mod.make_spmv(mesh, panel_l, ell, overlap=True,
                                  comm=spmv_comm, schedule=spmv_schedule,
                                  use_kernel=spmv_kernel)
        Q, _ = tsqr(V)
        Vp = to_panel(Q)
        Vp = chebyshev_filter(spmv, mu, alpha, beta, Vp)
        return to_stack(Vp)

    def fd_iteration_ss(V, mu, alpha, beta, ex_a, ex_b, *steps_flat):
        # depth-s surrogate: the per-step ELL blocks and the exchange
        # plan arrive as jit arguments; the compressed schedule's host
        # rounds (perms_s/round_L_s) are planted so neighbor_plan never
        # touches tracer pair counts
        steps = tuple((steps_flat[2 * i], steps_flat[2 * i + 1])
                      for i in range(sstep))
        nbr = None
        if compressed:
            nbr = {spmv_schedule: spmv_mod.SstepNeighbor(
                perms=perms_s, round_L=round_L_s,
                send_nbr=ex_a, gather=ex_b)}
        sell = spmv_mod.SstepEll(steps=steps, send_idx=ex_a, gather_a2a=ex_b,
                                 R=R, G=G_s, L=L_s, P=N_row, D=D, s=sstep,
                                 nbr=nbr)
        cheb = spmv_mod.make_sstep_cheb(mesh, panel_l, sell,
                                        comm=spmv_comm,
                                        schedule=spmv_schedule,
                                        use_kernel=spmv_kernel)
        Q, _ = tsqr(V)
        Vp = to_panel(Q)
        Vp = cheb(Vp, mu, alpha, beta)
        return to_stack(Vp)

    V = jax.ShapeDtypeStruct((D_pad, n_s), dt)
    mu = jax.ShapeDtypeStruct((degree + 1,), jnp.float32)
    dist = panel_l.dist_axes
    from jax.sharding import PartitionSpec as PS
    plan_sh = jax.NamedSharding(mesh, PS(dist if dist else None, None, None))
    send_sh = jax.NamedSharding(mesh, PS(dist if dist else None, None))
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    with mesh:
        vsh = jax.NamedSharding(mesh, stack_l.vec_pspec())
        if sstep > 1:
            S = jax.ShapeDtypeStruct
            if compressed:
                ex_specs = (S((N_row, max(H, 1)), jnp.int32),
                            S((N_row, G_s), jnp.int32))
                ex_sh = (send_sh, send_sh)
            else:
                ex_specs = (S((N_row, N_row, L_s), jnp.int32),
                            S((N_row, G_s), jnp.int32))
                ex_sh = (plan_sh, send_sh)
            step_specs = tuple(
                spec for _ in range(sstep)
                for spec in (S((N_row, R + G_s, W), jnp.int32),
                             S((N_row, R + G_s, W), dt)))
            jitted = jax.jit(fd_iteration_ss,
                             in_shardings=(vsh, None, None, None) + ex_sh
                             + (plan_sh,) * (2 * sstep),
                             out_shardings=vsh, donate_argnums=(0,))
            lowered = jitted.lower(V, mu, scalar, scalar,
                                   *ex_specs, *step_specs)
        elif overlap:
            jitted = jax.jit(fd_iteration_ov,
                             in_shardings=(vsh, None, None, None)
                             + (plan_sh,) * 5 + (send_sh,),
                             out_shardings=vsh, donate_argnums=(0,))
            lowered = jitted.lower(V, mu, scalar, scalar,
                                   ell_spec["cols_loc"], ell_spec["vals_loc"],
                                   ell_spec["cols_halo"], ell_spec["vals_halo"],
                                   ell_spec["send_idx"], ell_spec["send_nbr"])
        else:
            jitted = jax.jit(fd_iteration,
                             in_shardings=(vsh, None, None, None,
                                           plan_sh, plan_sh, plan_sh, send_sh),
                             out_shardings=vsh, donate_argnums=(0,))
            lowered = jitted.lower(V, mu, scalar, scalar,
                                   ell_spec["cols"], ell_spec["vals"],
                                   ell_spec["send_idx"], ell_spec["send_nbr"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = rl.memory_summary(compiled)
        # useful flops: degree SpMVs (2*nnz*n_s) + TSQR (2*D*Ns^2)
        nnz = fam.D * _nnzr(fam)
        useful = degree * 2.0 * nnz * n_s * (4 if fam.is_complex else 1) \
            + 2.0 * D * n_s * n_s
        roof = rl.analyze(compiled, useful, mesh.devices.size)
    cmp_tag = ("" if not compressed
               else "+mat" if spmv_schedule == "matching" else "+cmp")
    part_tag = ("+cv" if spmv_balance == "commvol" else "") + \
        ("+rcm" if spmv_reorder == "rcm" else "")
    krn_tag = "+krn" if spmv_kernel else ""
    ss_tag = f"+s{sstep}" if sstep > 1 else ""
    rec = {
        "arch": name,
        "shape": (f"fd_iter[{layout_name}{part_tag}{cmp_tag}"
                  f"{'+ov' if overlap else ''}{krn_tag}{ss_tag},"
                  f"Ns={n_s},deg={degree}]"),
        "mesh": "2x16x16" if multi_pod else "16x16", "n_chips": mesh.devices.size,
        "status": "ok", "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1), "memory": mem,
        "model_flops": useful, **roof.row(),
        "chi_comm_plan_L": int(L), "n_vc_max": int(n_vc.max()) if N_row > 1 else 0,
        "spmv_comm": spmv_comm, "spmv_schedule": spmv_schedule,
        "spmv_balance": spmv_balance, "spmv_reorder": spmv_reorder,
        "spmv_kernel": spmv_kernel, "spmv_sstep": sstep,
        "nbr_H": H, "nbr_rounds": len(perms),
    }
    if sstep > 1:
        rec["sstep_L"] = L_s
        rec["sstep_ghosts_max"] = G_s
        rec["sstep_groups"] = cp_s.n_groups(degree)
        rec["sstep_work_factor"] = round(cp_s.sstep_work_factor(), 4)
    if verify:
        # static communication verifier (repro.analysis): attribute every
        # collective in the compiled HLO to a χ-predicted term and lint
        # the lowered neighbor schedule. The dry-run cell has no Gram
        # product, so the predicted terms are degree halo exchanges + the
        # TSQR butterfly + (when N_col > 1) the two redistributions.
        from ..analysis.census import ExpectedTerm, attribute
        from ..analysis.plan_lint import lint_rounds
        from .hlo_analysis import collective_census

        S_cell = jnp.dtype(dt).itemsize
        n_b_cell = max(n_s // max(n_col, 1), 1)
        terms = []
        if sstep > 1:
            # χ(A^s) attribution: one single-width seed exchange plus
            # ⌈degree/s⌉ - 1 width-doubled group exchanges, already
            # whole-filter terms (sstep_collectives is NOT degree-scaled)
            for k, (kind, byts, cnt) in enumerate(cp_s.sstep_collectives(
                    spmv_comm, spmv_schedule, n_b_cell, S_cell, degree)):
                terms.append(ExpectedTerm(
                    f"sstep-exchange[{spmv_comm}/s{sstep}#{k}]",
                    kind, int(byts), cnt))
        elif N_row > 1 and L > 0:
            if compressed:
                for Lk in round_L:
                    terms.append(ExpectedTerm(
                        f"halo-exchange[compressed/{spmv_schedule}]",
                        "collective-permute",
                        int(Lk) * n_b_cell * S_cell, degree))
            else:
                terms.append(ExpectedTerm(
                    "halo-exchange[a2a]", "all-to-all",
                    N_row * L * n_b_cell * S_cell, degree))
        if P_total > 1:
            terms.append(ExpectedTerm(
                "tsqr-butterfly", "collective-permute", n_s * n_s * S_cell,
                int(np.ceil(np.log2(P_total)))))
        if n_col > 1:
            full = (D_pad // P_total) * n_s * S_cell
            for leg in ("to_panel", "to_stack"):
                terms.append(ExpectedTerm(
                    f"redistribute[{leg}]", "all-to-all", full, 1,
                    alt_bytes=(full * (n_col - 1) // n_col,)))
        extra = []
        if sstep > 1 and perms_s:
            # lint the depth-s rounds against the depth-s pair volumes
            extra = lint_rounds(cp_s.pair_counts, perms_s, round_L_s,
                                label=f"{name}/{spmv_schedule}+s{sstep}")
        elif cp_nbr is not None and cp_nbr.pair_counts is not None and perms:
            extra = lint_rounds(cp_nbr.pair_counts, perms, round_L,
                                label=f"{name}/{spmv_schedule}")
        report = attribute(collective_census(compiled.as_text()), terms,
                           cell=rec["shape"], extra_errors=list(extra))
        rec["verify_ok"] = report.ok
        rec["verify_errors"] = report.errors
        if verbose or not report.ok:
            print(report.describe())
    if rowmap is not None:
        sizes = rowmap.block_sizes(N_row)
        rec["partition_rows_min"] = int(sizes.min())
        rec["partition_rows_max"] = int(sizes.max())
    if compressed:
        # round-sum comm prediction of the lowered schedule (identical to
        # the χ-path by construction — perf_model.schedule_comm_time),
        # priced on the same machine model the --plan ranking uses
        from ..core import perf_model as _pmsc

        rec["t_comm_schedule_s"] = _pmsc.schedule_comm_time(
            machine or _pmsc.TPU_V5E, round_L, n_b=n_s // max(n_col, 1),
            S_d=jnp.dtype(dt).itemsize)
    # perf-model per-Chebyshev-iteration prediction for this cell: additive
    # Eq. 12 vs the overlap engine's max(T_comm, T_local) + T_halo — the
    # sweep uses the ratio to see where overlap restores scalability
    if N_row > 1:
        from ..core import perf_model as pm
        from ..core.metrics import chi_from_nvc

        if rowmap is not None:
            n_vm = rowmap.block_sizes(N_row)
        else:
            bnd = np.minimum(np.arange(N_row + 1) * (D_pad // N_row), D)
            n_vm = np.diff(bnd)
        chim = chi_from_nvc(n_vc, n_vm, D)
        n_b_loc = max(n_s // max(n_col, 1), 1)
        kw = dict(D=D, N_p=N_row, n_b=n_b_loc, chi=chim.chi1,
                  n_nzr=_nnzr(fam), S_d=jnp.dtype(dt).itemsize)
        rec["t_model_additive_s"] = pm.cheb_iter_time(pm.TPU_V5E, **kw)
        rec["t_model_overlap_s"] = pm.cheb_iter_time_overlap(pm.TPU_V5E, **kw)
        rec["overlap_model_speedup"] = round(
            rec["t_model_additive_s"] / rec["t_model_overlap_s"], 3)
    if plan:
        # planner panel: ranking from the pattern alone + predicted vs
        # measured all-to-all volume of this lowered cell (χ is the
        # prediction; the HLO parse is the measurement)
        from ..core.planner import plan_for_mesh
        from ..core.redistribute import redistribution_volume

        P_t = mesh.devices.size
        S_cell = jnp.dtype(dt).itemsize
        from ..core import perf_model as _pm
        from ..core.planner import exact_comm_default

        # exact pair counts (and hence compressed candidates) whenever the
        # pattern pass is windowed/cheap; unbounded generators at paper
        # scale reuse the n_vc already computed above (estimated path —
        # the planner then only ranks the a2a engines, by design). A
        # comm plan already built for the compressed schedule is handed
        # through so the lowered n_row's pattern pass is never paid twice
        exact_ok = exact_comm_default(fam)
        # precomputed plans/counts only describe the equal-rows partition
        # — never hand the planned map's counts to the rows combo
        lp = plan_for_mesh(fam, mesh, n_search=n_s, row_axes=("model",),
                           degree=degree, S_d=S_cell,
                           exact_comm=None if exact_ok else False,
                           d_pad=D_pad, n_nzr=_nnzr(fam),
                           machine=machine or _pm.TPU_V5E,
                           plan_mode=plan_mode,
                           reorder=tuple(dict.fromkeys(
                               ("none", spmv_reorder))),
                           sstep=tuple(dict.fromkeys((1, sstep))),
                           comm_plan_by_row=None
                           if cp_nbr is None or rowmap is not None
                           else {N_row: cp_nbr},
                           n_vc_by_row=None
                           if exact_ok or N_row <= 1 or rowmap is not None
                           else {N_row: n_vc})
        if rowmap is not None and exact_ok:
            # before/after panel: the equal-rows partition's χ and pad
            # volumes vs the planned map's, at the lowered N_row (needs
            # the exact pattern pass — skipped on the sampled-only path)
            cp_before = _comm_plan(fam, N_row,
                                   d_pad=-(-D // P_total) * P_total,
                                   exact=True)
            for tag, cp_x in (("before", cp_before), ("after", cp_part)):
                chim_x = cp_x.chi
                rec[f"partition_{tag}"] = {
                    "chi1": round(chim_x.chi1, 4),
                    "chi2": round(chim_x.chi2, 4),
                    "chi3": round(chim_x.chi3, 4),
                    "a2a_pad_entries": cp_x.moved_entries_per_device("a2a"),
                    "H_cyclic": cp_x.moved_entries_per_device(
                        "compressed", "cyclic"),
                    "H_matching": cp_x.moved_entries_per_device(
                        "compressed", "matching"),
                }
            if verbose:
                b, a = rec["partition_before"], rec["partition_after"]
                print(f"[plan] partition {spmv_balance}/{spmv_reorder} "
                      f"before -> after at N_row={N_row}:")
                print(f"       chi2 {b['chi2']:.4f} -> {a['chi2']:.4f}  "
                      f"chi3 {b['chi3']:.4f} -> {a['chi3']:.4f}")
                print(f"       pad entries/device: a2a "
                      f"{b['a2a_pad_entries']} -> {a['a2a_pad_entries']}  "
                      f"cyclic {b['H_cyclic']} -> {a['H_cyclic']}  "
                      f"matching {b['H_matching']} -> {a['H_matching']}")
        # predicted per-chip SpMV collective operand bytes of THIS cell:
        # degree halo exchanges — the [N_row, L, n_b] all_to_all send
        # buffer, or the compressed engine's Σ_k L_k ppermute segments —
        # plus 2 redistributions (full local slice; Eq. 17/18 is the moved
        # subset — XLA prints either convention, so report both)
        n_b_cell = n_s // max(n_col, 1)
        if sstep > 1:
            # whole-filter depth-s exchange bytes (seed + doubled groups)
            pred_spmv = sum(b * c for _, b, c in cp_s.sstep_collectives(
                spmv_comm, spmv_schedule, n_b_cell, S_cell, degree))
        else:
            spmv_entries = (H if compressed else N_row * L) \
                if N_row > 1 else 0
            pred_spmv = degree * spmv_entries * n_b_cell * S_cell
        # TSQR butterfly: log2(P) ppermute rounds of the N_s x N_s R factor
        # (orthogonalize.py) — counted with the SpMV permutes by the HLO
        # parse, so predict it too
        pred_tsqr = P_t.bit_length() - 1 if P_t & (P_t - 1) == 0 \
            else int(np.ceil(np.log2(P_t)))
        pred_tsqr *= n_s * n_s * S_cell
        pred_red_full = 2 * (D_pad // P_t) * n_s * S_cell if n_col > 1 else 0
        pred_red_moved = 2 * int(redistribution_volume(
            D_pad, n_s, P_t, n_col, S_cell)["bytes_total"] / P_t) \
            if n_col > 1 else 0
        meas_a2a = int(roof.coll_breakdown.get("all-to-all", 0))
        meas_perm = int(roof.coll_breakdown.get("collective-permute", 0))
        # the compressed engine's SpMV bytes are collective-permutes; the
        # redistribution stays an all_to_all — sum both kinds so the
        # predicted==measured check covers every engine. Two honest
        # conventions for the redistribution operand (XLA may print the
        # full local slice or only the moved subset) — report BOTH ratios;
        # agreement means one of them is ~1, and the spmv term (the χ
        # prediction proper) is identical in both
        meas = meas_a2a + meas_perm
        pred_full = pred_spmv + pred_tsqr + pred_red_full
        pred_moved = pred_spmv + pred_tsqr + pred_red_moved
        rec["plan_best"] = lp.best.describe()
        rec["plan_chi1"] = lp.best.chi1
        rec["plan_pred_spmv_bytes"] = pred_spmv
        rec["plan_pred_a2a_bytes_full"] = pred_full
        rec["plan_pred_a2a_bytes_moved"] = pred_moved
        rec["plan_measured_a2a_bytes"] = meas_a2a
        rec["plan_measured_permute_bytes"] = meas_perm
        if verbose:
            print(lp.report())
            r_full = meas / pred_full if pred_full else float("nan")
            r_moved = meas / pred_moved if pred_moved else float("nan")
            kind = "permute" if compressed else "a2a"
            print(f"[plan] cell spmv({kind})/chip predicted: {degree}x"
                  f"{pred_spmv // max(degree, 1)} + tsqr {pred_tsqr} "
                  f"+ redist(full) {pred_red_full} = {pred_full} | "
                  f"redist(moved) {pred_red_moved} = {pred_moved}  measured "
                  f"a2a {meas_a2a} + permute {meas_perm}  "
                  f"ratio full {r_full:.3f} / moved {r_moved:.3f}")
    if verbose:
        print(f"[dryrun-eigen] {name} "
              f"[{layout_name}{part_tag}{cmp_tag}"
              f"{'+ov' if overlap else ''}{krn_tag}{ss_tag}] "
              f"on {rec['mesh']}: OK "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        if "overlap_model_speedup" in rec:
            print(f"  perf model/iter: additive={rec['t_model_additive_s']*1e3:.2f}ms "
                  f"overlap={rec['t_model_overlap_s']*1e3:.2f}ms "
                  f"(x{rec['overlap_model_speedup']:.2f} if overlapped)")
        print(f"  memory_analysis: {mem}")
        print(f"  roofline: compute={roof.t_compute*1e3:.2f}ms "
              f"memory={roof.t_memory*1e3:.2f}ms "
              f"collective={roof.t_collective*1e3:.2f}ms dominant={roof.dominant}")
    return rec


def _nnzr(fam) -> float:
    probe = np.arange(0, min(fam.D, 4096), dtype=np.int64)
    r, _ = fam.row_cols(probe)
    return len(r) / len(probe)


# -------------------------------------------------- machine-model fitting --

def fit_machine(eigen: str | None = None, out_path: str = "machine_fit.json",
                n_devices: int = 8, n_search: int = 16, reps: int = 20,
                verbose: bool = True):
    """Calibrate the planner's machine constants from *measured* dry-run
    iteration times (ROADMAP "feed measured dry-run times back").

    Runs the real fused Chebyshev step (baseline a2a engine) of a small
    matrix instance across several mesh splits on ``n_devices`` local
    devices, times each, and least-squares fits b_c, κ and the
    per-round launch latency α via ``MachineModel.fit`` (b_m is kept
    from the TPU_V5E base — the paper fixes b_m from STREAM and fits
    the rest the same way). Every sample carries its collective round
    count (one a2a per fused step when N_row > 1), and each halo split
    is timed twice — at the full block width and at a *tiny* width-n_col
    block whose wire bytes are negligible but whose round count is
    unchanged — so the α column is not collinear with the χ·bytes
    column and the latency term is identifiable (see
    ``MachineModel.fit``). The fitted model is saved as JSON for
    ``solve --machine <path>`` / ``dryrun --plan --machine <path>``, so
    planner rankings (including the s-step axis, which only wins under
    high α) can use calibrated constants instead of the hardcoded
    MEGGIE/TPU_V5E numbers.
    """
    from ..core import perf_model as pm
    from ..core import spmv as spmv_mod
    from ..core.layouts import make_solver_mesh, panel, stack
    from ..core.planner import comm_plan, estimate_nnzr
    from ..matrices import SpinChainXXZ, get_family

    if eigen:
        mspec = dict(get_smoke_matrix(eigen))
        fam = get_family(mspec.pop("family"), **mspec)
    else:
        fam = SpinChainXXZ(12, 6)
    csr = fam.build_csr()
    D = csr.shape[0]
    n_nzr = estimate_nnzr(csr)
    S_d = None  # set from the dtype the engine actually runs (see below)
    devices = jax.devices()[:n_devices]
    samples = []
    base = pm.TPU_V5E
    if verbose:
        print(f"[fit-machine] timing {fam.describe()} fused Chebyshev steps "
              f"on {n_devices} devices")
    splits = sorted({n for n in (n_devices, n_devices // 2, n_devices // 4)
                     if n >= 1}, reverse=True)
    for n_row in splits:
        n_col = n_devices // n_row
        if n_search % n_col:
            continue
        mesh = make_solver_mesh(n_row, n_col, devices=devices)
        lay = stack(mesh) if n_col == 1 else panel(mesh)
        D_pad = -(-D // n_devices) * n_devices
        ell = spmv_mod.build_dist_ell(csr, n_row, d_pad=D_pad)
        # Eq. 12's S_d must describe the elements the timed engine moves:
        # without jax_enable_x64 (this module never sets it) the operator
        # and vectors run in float32/complex64, not the host float64
        S_d = int(ell.vals.dtype.itemsize)
        cp = comm_plan(csr, n_row, d_pad=D_pad)
        chi_eng = pm.engine_chi(cp.moved_entries_per_device("a2a"), D, n_row)
        rng = np.random.default_rng(0)
        W1 = np.zeros((D_pad, n_search))
        W1[:D] = rng.standard_normal((D, n_search))
        W2 = np.zeros_like(W1)
        W2[:D] = rng.standard_normal((D, n_search))
        rounds = 1.0 if n_row > 1 else 0.0  # one a2a per fused step
        with mesh:
            sh = lay.vec_sharding(mesh)
            step = jax.jit(spmv_mod.make_fused_cheb_step(mesh, lay, ell))
            # full-width cell + (on halo splits) a tiny width-n_col cell:
            # same round count, negligible wire bytes — the contrast that
            # makes the α latency column identifiable
            widths = [n_search] + ([n_col] if n_row > 1 else [])
            for width in widths:
                w1 = jax.device_put(jnp.asarray(W1[:, :width]), sh)
                w2 = jax.device_put(jnp.asarray(W2[:, :width]), sh)
                y = step(w1, w2, 0.7, -0.2)
                jax.block_until_ready(y)  # compile outside the timing
                t0 = time.perf_counter()
                for _ in range(reps):
                    y = step(w1, w2, 0.7, -0.2)
                jax.block_until_ready(y)
                t = (time.perf_counter() - t0) / reps
                samples.append(dict(t=t, D=D, N_p=n_row,
                                    n_b=width // n_col, chi=chi_eng,
                                    n_nzr=n_nzr, S_d=S_d, rounds=rounds))
                if verbose:
                    print(f"[fit-machine] {n_row}x{n_col} n_b="
                          f"{width // n_col}: chi_eng={chi_eng:.3f} "
                          f"rounds={rounds:g} t={t * 1e6:.1f}us")
    fitted = pm.MachineModel.fit(samples, b_m=base.b_m, name="fitted-local")
    pm.save_machine(fitted, out_path)
    if verbose:
        bc = fitted.b_c / 1e9 if fitted.b_c != float("inf") else float("inf")
        print(f"[fit-machine] fitted b_c={bc:.2f} GB/s kappa={fitted.kappa:.2f} "
              f"alpha={fitted.alpha*1e6:.2f}us "
              f"(b_m fixed at {fitted.b_m/1e9:.0f} GB/s) -> {out_path}")
    return fitted


def get_smoke_matrix(eigen: str) -> dict:
    """Matrix spec of a config's reduced SMOKE instance (fit-machine runs
    real iterations, so the full paper-scale instance is out of reach)."""
    from ..configs import get_smoke_config

    return get_smoke_config(eigen)["matrix"]


# ------------------------------------------------------------------ main --

def iter_cells():
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape, cell in applicable_shapes(cfg).items():
            yield arch, shape, cell


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--eigen", help="paper config dry-run (exciton200/"
                                    "hubbard16/roadnet48k/hubnet48k)")
    ap.add_argument("--layout", default="pillar",
                    choices=["stack", "panel", "pillar", "panel+ov", "stack+ov"],
                    help="eigensolver vector layout for --eigen cells; the "
                         "'+ov' suffix lowers the split-phase overlap SpMV "
                         "engine (halo all_to_all issued before the local "
                         "contraction — the --spmv-overlap flag of "
                         "repro.launch.solve)")
    ap.add_argument("--spmv-comm", default="a2a",
                    choices=["a2a", "compressed"],
                    help="halo-exchange engine for --eigen cells: 'a2a' "
                         "(padded all_to_all, chi3-scaled bytes) or "
                         "'compressed' (neighbor ppermute rounds with "
                         "per-round padding, chi2-scaled bytes — the "
                         "'+cmp' shape suffix; --spmv-comm of "
                         "repro.launch.solve)")
    ap.add_argument("--spmv-schedule", default="cyclic",
                    choices=["cyclic", "matching"],
                    help="round scheduler of the compressed halo "
                         "exchange for --eigen cells: 'cyclic' (one "
                         "ppermute round per nonzero cyclic shift) or "
                         "'matching' (greedy max-weight matching "
                         "rounds, the '+mat' shape suffix; "
                         "--spmv-schedule of repro.launch.solve)")
    ap.add_argument("--spmv-balance", default="rows",
                    choices=["rows", "commvol"],
                    help="row partition for --eigen cells: 'rows' (equal "
                         "blocks) or 'commvol' (planned non-uniform "
                         "boundaries, core/partition.py — the '+cv' cell "
                         "suffix; the surrogate carries the planned "
                         "map's exact per-pair volumes)")
    ap.add_argument("--spmv-reorder", default="none",
                    choices=["none", "rcm"],
                    help="row order for --eigen cells: 'none' or 'rcm' "
                         "(reverse-Cuthill-McKee, applied before "
                         "partitioning — the '+rcm' cell suffix)")
    ap.add_argument("--spmv-kernel", action="store_true",
                    help="request the Pallas kernel engine for --eigen "
                         "cells (the '+krn' cell suffix; --spmv-kernel of "
                         "repro.launch.solve). The surrogate's plan "
                         "arrays are abstract, so the cell lowers the jnp "
                         "fallback with IDENTICAL collectives — the "
                         "kernel census contract (docs/kernels.md)")
    ap.add_argument("--spmv-sstep", type=int, default=1,
                    help="communication-avoiding s-step filter cell for "
                         "--eigen (the '+s2'/'+s3' shape suffixes; "
                         "--spmv-sstep of repro.launch.solve): the "
                         "surrogate carries the real pattern's depth-s "
                         "ghost plan and the lowered filter runs "
                         "ceil(degree/s) exchanges — a single-width "
                         "seed plus width-doubled [w1|w2] group "
                         "payloads — instead of one per SpMV; with "
                         "--verify every exchange is attributed to the "
                         "chi(A^s) terms of sstep_collectives; plain "
                         "(non-overlap) cells only")
    ap.add_argument("--plan-mode", default="auto",
                    choices=["exact", "sampled", "auto"],
                    help="pattern-pass strategy for the --eigen cell's "
                         "planning (partition boundaries and the --plan "
                         "ranking): 'exact' (full scans, the partition "
                         "axis is dropped past the size gate), 'sampled' "
                         "(core/sketch.py: seeded row subsample, "
                         "Horvitz-Thompson chi/L estimates, coarsened "
                         "commvol descent), or 'auto' (exact below the "
                         "gate, sampled above; --plan-mode of "
                         "repro.launch.solve)")
    ap.add_argument("--plan", action="store_true",
                    help="with --eigen: print the χ-driven planner ranking "
                         "(core/planner.py) and the predicted vs HLO-measured "
                         "SpMV collective volume of the lowered cell (on a "
                         "planned partition also the before/after χ and "
                         "pad volumes)")
    ap.add_argument("--verify", action="store_true",
                    help="with --eigen: run the static communication "
                         "verifier on the compiled cell — attribute every "
                         "HLO collective to a χ-predicted term "
                         "(repro.analysis.census) and lint the lowered "
                         "neighbor schedule; exits nonzero on any "
                         "unattributed or missing collective")
    ap.add_argument("--fit-machine", action="store_true",
                    help="time real fused Chebyshev iterations of a small "
                         "instance across mesh splits on local devices, fit "
                         "b_c and kappa (MachineModel.fit), and save the "
                         "calibrated model to --fit-out for "
                         "`solve --machine <path>` planner rankings")
    ap.add_argument("--fit-out", default="machine_fit.json",
                    help="JSON path for the --fit-machine result")
    ap.add_argument("--machine", default="tpu-v5e",
                    help="machine model for the --plan ranking: 'tpu-v5e', "
                         "'meggie', or a JSON path saved by --fit-machine")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="append JSON records here")
    args = ap.parse_args(argv)
    if args.spmv_schedule != "cyclic" and args.spmv_comm != "compressed":
        ap.error(f"--spmv-schedule {args.spmv_schedule} requires "
                 "--spmv-comm compressed")

    records = []
    try:
        if args.fit_machine:
            fit_machine(args.eigen, args.fit_out)
            return records
        if args.eigen:
            from ..core import perf_model as pm

            machine = pm.resolve_machine(args.machine)
            records.append(run_eigen(args.eigen, args.layout, args.multi_pod,
                                     plan=args.plan,
                                     spmv_comm=args.spmv_comm,
                                     spmv_schedule=args.spmv_schedule,
                                     spmv_balance=args.spmv_balance,
                                     spmv_reorder=args.spmv_reorder,
                                     spmv_kernel=args.spmv_kernel,
                                     spmv_sstep=args.spmv_sstep,
                                     plan_mode=args.plan_mode,
                                     machine=machine, verify=args.verify))
        elif args.all:
            for arch, shape, cell in iter_cells():
                if cell is None:
                    records.append({"arch": arch, "shape": shape,
                                    "mesh": "2x16x16" if args.multi_pod else "16x16",
                                    "status": "skip"})
                    continue
                records.append(run_cell(arch, shape, args.multi_pod))
        else:
            cfg = get_config(args.arch)
            cell = applicable_shapes(cfg)[args.shape]
            if cell is None:
                records.append({"arch": args.arch, "shape": args.shape,
                                "status": "skip"})
                print(f"[dryrun] {args.arch} x {args.shape}: SKIP (see DESIGN.md)")
            else:
                records.append(run_cell(args.arch, args.shape, args.multi_pod))
    finally:
        if args.out and records:
            with open(args.out, "a") as f:
                for r in records:
                    f.write(json.dumps(r) + "\n")
    if args.verify and any(r.get("verify_errors") for r in records):
        sys.exit(1)
    return records


if __name__ == "__main__":
    main()

"""Loop-aware cost extraction from optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scanned program (layer scans, loss-chunk scans, Chebyshev iterations) is
undercounted by the trip count. The optimized HLO annotates
``backend_config={"known_trip_count":{"n":...}}`` on while ops, which lets
us propagate an exact execution *multiplicity* to every computation and
re-aggregate:

  flops            — from dot ops (2 * |result| * |contraction|), conv ignored
                     (no conv ops in this codebase's models)
  collective bytes — operand bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute
                     (async ``-start``/``-done`` pairs counted once, on the
                     start; tuple-shaped operand lists are summed per leaf)
  hbm bytes        — operands+result of ops at fusion granularity
                     (internal fused computations are not double counted)

The parser is deliberately defensive about HLO-text dialects: operands may
be printed bare (``%arg.1``) or with inline types (``f32[8,32]{1,0}
%arg.1``), names may or may not carry the ``%`` sigil, the trip count may
sit on the while line or on a continuation line, and computation names may
be mangled (``region_0.35``, ``wide.wide.body``, ``...clone``). Validated
against cost_analysis() on fully-unrolled small models (where XLA's
numbers are exact) in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_TYPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose operands/results never touch HBM as real traffic
_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "after-all", "partition-id", "replica-id",
    "iota", "copy-start", "copy-done",
}
# an op-defining line: optional ROOT, optional % sigil, name, '='
_OP_LINE = re.compile(r"^(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*\S")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * _DTYPE_BYTES.get(dtype, 0)


def _typed_tokens_bytes(text: str) -> int:
    """Sum the byte sizes of every inline-typed leaf (``f32[8,32]``) in a
    fragment — handles tuple shapes by summing their leaves."""
    return sum(_shape_bytes(d, dims) for d, dims in _TYPE_RE.findall(text))


def _split_computations(text: str) -> dict[str, list[str]]:
    """Computation name -> its lines, with continuation lines joined onto
    the op line they belong to (trip counts / configs may wrap)."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in text.splitlines():
        if line.startswith("HloModule"):
            continue
        if (not line.startswith(" ") and "{" in line and "(" in line
                and "->" in line):
            name = line.split("(", 1)[0]
            name = name.replace("ENTRY", "").strip().lstrip("%").strip()
            cur = name
            comps[cur] = [line]
        elif cur is not None:
            if line.startswith("}"):
                cur = None
            elif _OP_LINE.match(line.strip()) or len(comps[cur]) == 1:
                comps[cur].append(line)
            elif line.strip():
                # continuation of a wrapped op line (e.g. backend_config on
                # its own line) — join so per-line regexes still see it
                comps[cur][-1] = comps[cur][-1].rstrip() + " " + line.strip()
    return comps


def _entry_name(text: str, comps) -> str | None:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            name = line.split("(", 1)[0].replace("ENTRY", "").strip().lstrip("%").strip()
            if name in comps:
                return name
    return None


_REF_WHILE = re.compile(r"body=%?([\w\.\-]+)")
_REF_COND = re.compile(r"condition=%?([\w\.\-]+)")
_REF_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_REF_APPLY = re.compile(r"to_apply=%?([\w\.\-]+)")
# matches "known_trip_count":{"n":"6"}, known_trip_count{n:6} (proto text)
# and known_trip_count = {n = 6} variants
_TRIP = re.compile(r'known_trip_count["\s]*[=:]?\s*\{[^}]*?n["\s]*[=:]\s*"?(\d+)')


def _call_edges(comps) -> dict[str, list[tuple[str, float]]]:
    """Computation name -> [(callee, per-call multiplicity), ...].

    While bodies carry their ``known_trip_count``; conditions run trip+1
    times. Async ``-done``/``-update`` op lines are skipped entirely: on
    some HLO dialects they re-print the ``calls=`` reference to the same
    wrapped computation the ``-start`` already points at, and counting
    both would double the inner collective's multiplicity (the audit
    behind tests/test_hlo_analysis.py::test_async_wrapped_counted_once).
    """
    edges: dict[str, list[tuple[str, float]]] = {}
    for name, lines in comps.items():
        es: list[tuple[str, float]] = []
        for ln in lines:
            body = _REF_WHILE.search(ln)
            if body:
                t = _TRIP.search(ln)
                trip = float(t.group(1)) if t else 1.0
                es.append((body.group(1), trip))
                c = _REF_COND.search(ln)
                if c:
                    es.append((c.group(1), trip + 1))
                continue
            op = _parse_op(ln.strip())
            if op is not None and (op.opname.endswith("-done")
                                   or op.opname.endswith("-update")):
                continue
            for ref in _REF_CALLS.findall(ln) + _REF_APPLY.findall(ln):
                es.append((ref, 1.0))
        edges[name] = es
    return edges


def _multiplicities(comps, entry) -> dict[str, float]:
    if entry is None:
        entry = next(iter(comps))
    edges = _call_edges(comps)
    mult = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    # iterate to fixpoint over the (acyclic) call graph
    for _ in range(64):
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        changed = False
        for name, es in edges.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for ref, k in es:
                if ref in new:
                    new[ref] += m * k
        for k in comps:
            if abs(new[k] - mult[k]) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    return mult


# --------------------------------------------------------------- op parse --


@dataclasses.dataclass
class _Op:
    name: str
    result: str    # text of the result type (may be a tuple)
    opname: str    # e.g. "dot", "all-to-all-start", "fusion"
    operands: str  # raw operand-list text (commas inside types possible)
    attrs: str     # everything after the closing operand paren


def _balanced(s: str, i: int) -> int:
    """Index just past the paren group opening at s[i] ('(' expected)."""
    depth = 0
    for j in range(i, len(s)):
        if s[j] == "(":
            depth += 1
        elif s[j] == ")":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(s)


def _parse_op(ls: str) -> _Op | None:
    """Parse one op line: ``[ROOT] %name = <result> opname(<operands>), attrs``."""
    s = ls.strip()
    if s.startswith("ROOT"):
        s = s[4:].strip()
    m = re.match(r"%?([\w\.\-]+)\s*=\s*", s)
    if not m:
        return None
    name = m.group(1)
    s = s[m.end():]
    # result type: either a tuple "(...)" or a single "dtype[dims]{layout}"
    if s.startswith("("):
        j = _balanced(s, 0)
        result, s = s[:j], s[j:].lstrip()
    else:
        tm = re.match(r"[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?", s)
        if not tm:
            return None
        result, s = tm.group(0), s[tm.end():].lstrip()
    om = re.match(r"([\w\-]+)\s*\(", s)
    if not om:
        return None
    opname = om.group(1)
    k = _balanced(s, om.end() - 1)
    operands = s[om.end():k - 1]
    attrs = s[k:]
    return _Op(name=name, result=result, opname=opname, operands=operands,
               attrs=attrs)


def _operand_list(opstr: str) -> list[str]:
    """Split an operand string at top-level commas (commas inside type
    annotations like ``f32[8,32]{1,0}`` or nested tuples don't split)."""
    out, depth, start = [], 0, 0
    for i, ch in enumerate(opstr):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(opstr[start:i].strip())
            start = i + 1
    tail = opstr[start:].strip()
    if tail:
        out.append(tail)
    return out


def _operand_type(op: str, sym) -> tuple[str, str] | None:
    """(dtype, dims) of one operand: inline annotation if present, else a
    symbol-table lookup of the trailing name."""
    tm = _TYPE_RE.search(op)
    if tm:
        return tm.group(1), tm.group(2)
    nm = re.search(r"%?([\w\.\-]+)\s*$", op)
    if nm and nm.group(1) in sym:
        return sym[nm.group(1)]
    return None


def _operand_bytes(opstr: str, sym) -> int:
    """Total bytes of an operand list; inline types win, bare names fall
    back to the symbol table."""
    b = _typed_tokens_bytes(opstr)
    if b:
        return b
    total = 0
    for op in _operand_list(opstr):
        t = _operand_type(op, sym)
        if t:
            total += _shape_bytes(*t)
    return total


def _symbols(lines) -> dict[str, tuple[str, str]]:
    """name -> (dtype, dims) for every defined value + typed params."""
    sym: dict[str, tuple[str, str]] = {}
    header = lines[0]
    for m in re.finditer(r"([\w\.\-]+):\s*([a-z][a-z0-9]*)\[([0-9,]*)\]", header):
        sym[m.group(1)] = (m.group(2), m.group(3))
    for ln in lines[1:]:
        ls = ln.strip()
        if not _OP_LINE.match(ls):
            continue
        m = re.match(r"(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(?:\()?([a-z][a-z0-9]*)\[([0-9,]*)\]", ls)
        if m:
            sym[m.group(1)] = (m.group(2), m.group(3))
    return sym


def _dot_flops(op: _Op, sym) -> float:
    if op.opname != "dot":
        return 0.0
    res_elems = sum(_shape_elems(dims) for _, dims in _TYPE_RE.findall(op.result))
    ops = _operand_list(op.operands)
    lhs = _operand_type(ops[0], sym) if ops else None
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.attrs)
    contract = 1
    if lhs and cd:
        dims = [int(x) for x in lhs[1].split(",") if x] if lhs[1] else []
        for ci in cd.group(1).split(","):
            if ci and int(ci) < len(dims):
                contract *= dims[int(ci)]
    return 2.0 * res_elems * contract


def _collective_kind(opname: str) -> str | None:
    """Collective kind for an opname, counting async pairs once (start)."""
    if opname.endswith("-done") or opname.endswith("-update"):
        return None
    base = opname[:-6] if opname.endswith("-start") else opname
    return base if base in _COLLECTIVES else None


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions, which
    variously return a dict, a per-device list of dicts, or None."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


@dataclasses.dataclass
class HloCosts:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_breakdown: dict[str, float]
    per_collective: list  # (kind, bytes, multiplicity) heavy hitters


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One executed collective op in optimized HLO: ``bytes`` is the operand
    payload of a single execution, ``mult`` the loop-propagated execution
    count (async ``-start``/``-done`` pairs appear once)."""

    kind: str         # one of _COLLECTIVES
    bytes: int        # operand bytes of one execution
    mult: float       # execution multiplicity (trip counts propagated)
    name: str         # HLO op name
    computation: str  # enclosing computation


def _census_ops(comps, mult) -> list[CollectiveOp]:
    out = []
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        sym = _symbols(lines)
        for ln in lines[1:]:
            op = _parse_op(ln.strip())
            if op is None:
                continue
            kind = _collective_kind(op.opname)
            if kind:
                b = _operand_bytes(op.operands, sym)
                if b == 0:  # fall back to result type
                    b = _typed_tokens_bytes(op.result)
                out.append(CollectiveOp(kind=kind, bytes=b, mult=m,
                                        name=op.name, computation=name))
    out.sort(key=lambda c: (-c.bytes * c.mult, c.kind, c.name))
    return out


def collective_census(text: str) -> list[CollectiveOp]:
    """Every executed collective op of an optimized HLO module, with exact
    loop multiplicities — the *uncapped* census behind the static verifier
    (``repro.analysis.census``). ``analyze_hlo``'s ``per_collective`` is
    the same list truncated to the 20 heaviest entries."""
    comps = _split_computations(text)
    mult = _multiplicities(comps, _entry_name(text, comps))
    return _census_ops(comps, mult)


def analyze_hlo(text: str) -> HloCosts:
    comps = _split_computations(text)
    entry = _entry_name(text, comps)
    mult = _multiplicities(comps, entry)
    # computations reached only via calls=/to_apply= from fusions are
    # "internal": their ops don't touch HBM individually.
    internal = set()
    for name, lines in comps.items():
        for ln in lines:
            if " fusion(" in ln or "kind=kLoop" in ln or "kind=kOutput" in ln or "kind=kInput" in ln:
                for ref in _REF_CALLS.findall(ln):
                    internal.add(ref)
            for ref in _REF_APPLY.findall(ln):
                internal.add(ref)

    flops = 0.0
    hbm = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        sym = _symbols(lines)
        in_internal = name in internal
        for ln in lines[1:]:
            ls = ln.strip()
            op = _parse_op(ls)
            if op is None:
                continue
            f = _dot_flops(op, sym)
            if f:
                flops += m * f
            if not in_internal and op.opname not in _SKIP_BYTES_OPS:
                b = _typed_tokens_bytes(op.result) + _operand_bytes(op.operands, sym)
                hbm += m * b
    census = _census_ops(comps, mult)
    coll = {c: 0.0 for c in _COLLECTIVES}
    for c in census:
        coll[c.kind] += c.mult * c.bytes
    heavy = [(c.kind, c.bytes, c.mult) for c in census]
    return HloCosts(flops=flops, hbm_bytes=hbm, coll_bytes=sum(coll.values()),
                    coll_breakdown=coll, per_collective=heavy[:20])

"""Loop-aware cost extraction from optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scanned program (layer scans, loss-chunk scans, Chebyshev iterations) is
undercounted by the trip count. The optimized HLO annotates
``backend_config={"known_trip_count":{"n":...}}`` on while ops, which lets
us propagate an exact execution *multiplicity* to every computation and
re-aggregate:

  flops            — from dot ops (2 * |result| * |contraction|), conv ignored
                     (no conv ops in this codebase's models)
  collective bytes — operand bytes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute
  hbm bytes        — operands+result of ops at fusion granularity
                     (internal fused computations are not double counted)

Validated against cost_analysis() on fully-unrolled small models (where
XLA's numbers are exact) in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_TYPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SKIP_BYTES_OPS = ("parameter(", "constant(", "tuple(", "get-tuple-element(",
                   "bitcast(", " while(", "conditional(", "after-all(",
                   "partition-id(", "replica-id(", "iota(")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * _DTYPE_BYTES.get(dtype, 0)


def _split_computations(text: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    header = None
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line and ("(" in line) and "->" in line:
            name = line.split("(", 1)[0].strip().lstrip("%").replace("ENTRY ", "").replace("ENTRY%", "")
            name = name.replace("ENTRY", "").strip().lstrip("%").strip()
            cur = name
            comps[cur] = [line]
        elif cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _entry_name(text: str, comps) -> str | None:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            name = line.split("(", 1)[0].replace("ENTRY", "").strip().lstrip("%").strip()
            if name in comps:
                return name
    return None


_REF_WHILE = re.compile(r"body=%([\w\.\-]+)")
_REF_COND = re.compile(r"condition=%([\w\.\-]+)")
_REF_CALLS = re.compile(r"calls=%([\w\.\-]+)")
_REF_APPLY = re.compile(r"to_apply=%([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count[":{\s]+n["\s:]+"?(\d+)')


def _multiplicities(comps, entry) -> dict[str, float]:
    mult = {name: 0.0 for name in comps}
    if entry is None:
        entry = next(iter(comps))
    mult[entry] = 1.0
    # iterate to fixpoint over the (acyclic) call graph
    for _ in range(64):
        new = {name: 0.0 for name in comps}
        new[entry] = 1.0
        changed = False
        for name, lines in comps.items():
            m = mult.get(name, 0.0)
            if m == 0.0:
                continue
            for ln in lines:
                body = _REF_WHILE.search(ln)
                if body:
                    t = _TRIP.search(ln)
                    trip = float(t.group(1)) if t else 1.0
                    if body.group(1) in new:
                        new[body.group(1)] += m * trip
                    c = _REF_COND.search(ln)
                    if c and c.group(1) in new:
                        new[c.group(1)] += m * (trip + 1)
                    continue
                for ref in _REF_CALLS.findall(ln) + _REF_APPLY.findall(ln):
                    if ref in new:
                        new[ref] += m
        for k in comps:
            if abs(new[k] - mult[k]) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    return mult


def _symbols(lines) -> dict[str, tuple[str, str]]:
    """name -> (dtype, dims) for every defined value + typed params."""
    sym: dict[str, tuple[str, str]] = {}
    header = lines[0]
    for m in re.finditer(r"([\w\.\-]+):\s*([a-z][a-z0-9]*)\[([0-9,]*)\]", header):
        sym[m.group(1)] = (m.group(2), m.group(3))
    for ln in lines[1:]:
        ls = ln.strip()
        if not ls.startswith("%") and not ls.startswith("ROOT"):
            continue
        ls2 = ls[5:].strip() if ls.startswith("ROOT") else ls
        m = re.match(r"%([\w\.\-]+)\s*=\s*(?:\()?([a-z][a-z0-9]*)\[([0-9,]*)\]", ls2)
        if m:
            sym[m.group(1)] = (m.group(2), m.group(3))
    return sym


def _dot_flops(ls: str, sym) -> float:
    m = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=\s*([a-z][a-z0-9]*)\[([0-9,]*)\][^=]*dot\(([^)]*)\)", ls)
    if not m:
        return 0.0
    res_elems = _shape_elems(m.group(2))
    ops = [o.strip().lstrip("%") for o in m.group(3).split(",")]
    lhs = sym.get(ops[0]) if ops else None
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ls)
    contract = 1
    if lhs and cd:
        dims = [int(x) for x in lhs[1].split(",") if x] if lhs[1] else []
        for ci in cd.group(1).split(","):
            if ci and int(ci) < len(dims):
                contract *= dims[int(ci)]
    return 2.0 * res_elems * contract


@dataclasses.dataclass
class HloCosts:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_breakdown: dict[str, float]
    per_collective: list  # (kind, bytes, multiplicity) heavy hitters


def analyze_hlo(text: str) -> HloCosts:
    comps = _split_computations(text)
    entry = _entry_name(text, comps)
    mult = _multiplicities(comps, entry)
    # computations reached only via calls=/to_apply= from fusions are
    # "internal": their ops don't touch HBM individually.
    internal = set()
    for name, lines in comps.items():
        for ln in lines:
            if " fusion(" in ln or "kind=kLoop" in ln or "kind=kOutput" in ln or "kind=kInput" in ln:
                for ref in _REF_CALLS.findall(ln):
                    internal.add(ref)
            for ref in _REF_APPLY.findall(ln):
                internal.add(ref)

    flops = 0.0
    hbm = 0.0
    coll = {c: 0.0 for c in _COLLECTIVES}
    heavy = []
    for name, lines in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0:
            continue
        sym = _symbols(lines)
        in_internal = name in internal
        for ln in lines[1:]:
            ls = ln.strip()
            if not (ls.startswith("%") or ls.startswith("ROOT")):
                continue
            f = _dot_flops(ls, sym)
            if f:
                flops += m * f
            kind = None
            for c in _COLLECTIVES:
                if re.search(rf"\b{c}(-start)?\(", ls) and "-done" not in ls.split("=")[0]:
                    kind = c
                    break
            if kind:
                ops_m = re.search(rf"{kind}(?:-start)?\(([^)]*)\)", ls)
                b = 0
                if ops_m:
                    for o in ops_m.group(1).split(","):
                        o = o.strip().lstrip("%")
                        if o in sym:
                            b += _shape_bytes(*sym[o])
                if b == 0:  # fall back to result type
                    tm = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=\s*([a-z][a-z0-9]*)\[([0-9,]*)\]", ls)
                    if tm:
                        b = _shape_bytes(tm.group(1), tm.group(2))
                coll[kind] += m * b
                heavy.append((kind, b, m))
            if not in_internal and not any(s in ls for s in _SKIP_BYTES_OPS):
                tm = re.match(r"(?:ROOT\s+)?%[\w\.\-]+\s*=\s*(?:\()?([a-z][a-z0-9]*)\[([0-9,]*)\]", ls)
                if tm:
                    b = _shape_bytes(tm.group(1), tm.group(2))
                    # operands
                    call = re.search(r"\(([^)]*)\)", ls.split("=", 1)[1])
                    if call:
                        for o in call.group(1).split(","):
                            o = o.strip().lstrip("%")
                            if o in sym:
                                b += _shape_bytes(*sym[o])
                    hbm += m * b
    heavy.sort(key=lambda x: -x[1] * x[2])
    return HloCosts(flops=flops, hbm_bytes=hbm, coll_bytes=sum(coll.values()),
                    coll_breakdown=coll, per_collective=heavy[:20])

"""Data pipeline: deterministic, shardable, restartable.

A counter-based (stateless) generator: batch ``i`` is a pure function of
(seed, i), so (a) every host can produce exactly its own shard without
coordination, (b) restart-from-checkpoint replays nothing and skips
nothing (the pipeline state is just the step counter in the checkpoint
manifest's ``extra``), (c) elastic restarts re-partition cleanly.

Synthetic corpus: a Zipf-ish unigram mixture with injected n-gram
structure so the LM loss actually decreases (used by examples/train_lm.py
and the integration tests).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    zipf_a: float = 1.2
    ngram: int = 3  # injected structure length


class TokenPipeline:
    def __init__(self, cfg: ModelConfig, dcfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.dcfg = dcfg
        rng = np.random.default_rng(dcfg.seed)
        V = max(cfg.vocab, 2)
        ranks = np.arange(1, V + 1)
        p = ranks ** (-dcfg.zipf_a)
        self.unigram = p / p.sum()
        # deterministic "grammar": token t is often followed by succ[t]
        self.succ = rng.permutation(V)

    def batch(self, index: int, batch: int, seq: int) -> dict:
        """Batch ``index`` (pure function of (seed, index))."""
        cfg = self.cfg
        rng = np.random.default_rng((self.dcfg.seed, index))
        if cfg.family == "audio":
            feats = rng.standard_normal((batch, seq, cfg.frontend_dim)).astype(np.float32)
            mask = rng.random((batch, seq)) < 0.08
            labels = rng.integers(0, cfg.vocab, (batch, seq))
            return {"features": jnp.asarray(feats, jnp.dtype(cfg.dtype)),
                    "mask": jnp.asarray(mask),
                    "labels": jnp.asarray(labels, jnp.int32)}
        toks = rng.choice(len(self.unigram), size=(batch, seq), p=self.unigram)
        follow = rng.random((batch, seq)) < 0.6
        for k in range(1, self.dcfg.ngram):
            toks[:, k::self.dcfg.ngram] = np.where(
                follow[:, k::self.dcfg.ngram],
                self.succ[toks[:, k - 1::self.dcfg.ngram][:, : toks[:, k::self.dcfg.ngram].shape[1]]],
                toks[:, k::self.dcfg.ngram],
            )
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = -1  # no target for the final position
        if cfg.family == "vlm":
            npfx = min(cfg.n_prefix_embeds, max(seq // 8, 1))
            patches = rng.standard_normal((batch, npfx, cfg.frontend_dim)).astype(np.float32)
            return {"tokens": jnp.asarray(toks[:, : seq - npfx], jnp.int32),
                    "patches": jnp.asarray(patches, jnp.dtype(cfg.dtype)),
                    "labels": jnp.asarray(labels[:, : seq - npfx], jnp.int32)}
        return {"tokens": jnp.asarray(toks, jnp.int32),
                "labels": jnp.asarray(labels, jnp.int32)}

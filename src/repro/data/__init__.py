"""Deterministic, restartable synthetic-token data pipeline."""
from .pipeline import DataConfig, TokenPipeline

__all__ = ["DataConfig", "TokenPipeline"]

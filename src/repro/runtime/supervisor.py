"""Training supervisor: crash/restart orchestration with elastic meshes.

Single-host embodiment of the 1000-node design: run the step loop, catch
failures (simulated or real), restore from the last committed checkpoint
— possibly onto a smaller mesh (lost pod) — and continue. The dry-run
proves the large-mesh programs compile; this proves the restart logic is
sound end-to-end (exercised in tests/test_runtime.py with fault
injection).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable

from ..checkpoint import CheckpointManager, restore
from .health import HealthMonitor, StepTimer

log = logging.getLogger("repro.supervisor")


@dataclasses.dataclass
class SupervisorConfig:
    max_restarts: int = 3
    checkpoint_interval: int = 50
    keep_checkpoints: int = 3


class Supervisor:
    def __init__(self, ckpt_dir: str, cfg: SupervisorConfig = SupervisorConfig()):
        self.cfg = cfg
        self.manager = CheckpointManager(
            ckpt_dir, interval=cfg.checkpoint_interval, keep=cfg.keep_checkpoints
        )
        self.timer = StepTimer()
        self.restarts = 0

    def run(self, *, init_state: Callable, step_fn: Callable, n_steps: int,
            state_specs=None, fault_hook: Callable | None = None):
        """Run ``n_steps`` of ``step_fn(state, step) -> state`` with
        checkpoint/restart. ``init_state()`` builds a fresh state;
        ``fault_hook(step)`` may raise to simulate node failure."""
        state = None
        start = 0
        try:
            state, start, extra = restore(self.manager.directory, init_state(),
                                          specs=state_specs)
            log.info("restored checkpoint at step %d", start)
            start += 1
        except FileNotFoundError:
            state = init_state()
        step = start
        while step < n_steps:
            try:
                if fault_hook is not None:
                    fault_hook(step)
                self.timer.start()
                state = step_fn(state, step)
                self.timer.stop()
                self.manager.maybe_save(step, state, specs=state_specs,
                                        extra={"pipeline_index": step})
                step += 1
            except Exception as e:  # noqa: BLE001 — restart on any fault
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                log.warning("step %d failed (%s); restarting (%d/%d)",
                            step, e, self.restarts, self.cfg.max_restarts)
                try:
                    state, last, _ = restore(self.manager.directory, init_state(),
                                             specs=state_specs)
                    step = last + 1
                except FileNotFoundError:
                    state = init_state()
                    step = 0
        return state, step

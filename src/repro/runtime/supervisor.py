"""Training supervisor: crash/restart orchestration with elastic meshes.

Single-host embodiment of the 1000-node design: run the step loop, catch
failures (simulated or real), restore from the last committed checkpoint
— possibly onto a smaller mesh (lost pod) — and continue. The dry-run
proves the large-mesh programs compile; this proves the restart logic is
sound end-to-end (exercised in tests/test_runtime.py with fault
injection).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable

from ..checkpoint import CheckpointManager, restore
from .health import HealthMonitor, StepTimer, StragglerWatchdog

log = logging.getLogger("repro.supervisor")


@dataclasses.dataclass
class SupervisorConfig:
    max_restarts: int = 3
    checkpoint_interval: int = 50
    keep_checkpoints: int = 3


class Supervisor:
    def __init__(self, ckpt_dir: str, cfg: SupervisorConfig = SupervisorConfig()):
        self.cfg = cfg
        self.manager = CheckpointManager(
            ckpt_dir, interval=cfg.checkpoint_interval, keep=cfg.keep_checkpoints
        )
        self.timer = StepTimer()
        self.restarts = 0

    def run(self, *, init_state: Callable, step_fn: Callable, n_steps: int,
            state_specs=None, fault_hook: Callable | None = None):
        """Run ``n_steps`` of ``step_fn(state, step) -> state`` with
        checkpoint/restart. ``init_state()`` builds a fresh state;
        ``fault_hook(step)`` may raise to simulate node failure."""
        state = None
        start = 0
        try:
            state, start, extra = restore(self.manager.directory, init_state(),
                                          specs=state_specs)
            log.info("restored checkpoint at step %d", start)
            start += 1
        except FileNotFoundError:
            state = init_state()
        step = start
        while step < n_steps:
            try:
                if fault_hook is not None:
                    fault_hook(step)
                self.timer.start()
                state = step_fn(state, step)
                self.timer.stop()
                self.manager.maybe_save(step, state, specs=state_specs,
                                        extra={"pipeline_index": step})
                step += 1
            except Exception as e:  # noqa: BLE001 — restart on any fault
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                log.warning("step %d failed (%s); restarting (%d/%d)",
                            step, e, self.restarts, self.cfg.max_restarts)
                try:
                    state, last, _ = restore(self.manager.directory, init_state(),
                                             specs=state_specs)
                    step = last + 1
                except FileNotFoundError:
                    state = init_state()
                    step = 0
        return state, step

    def run_job(self, job, *, fault_hook: Callable | None = None,
                watchdog: StragglerWatchdog | None = None,
                on_straggler: Callable | None = None):
        """Drive a resumable job (the ``service/jobs.py`` protocol:
        ``template / init / step / done / step_index / pack / unpack``)
        to completion with checkpoint/restart.

        Unlike :meth:`run`, the job owns its state pytree split — device
        leaves via ``pack``/``unpack``, host fields in the manifest extra
        — and its own termination (``done``), so an eigensolve that
        converges early stops early. ``fault_hook(step)`` may raise to
        inject a failure; the loop restores from the last committed
        checkpoint (``checkpoint/`` ``_COMMITTED`` semantics: an
        uncommitted step is ignored, the previous one restored). A
        :class:`~repro.runtime.health.StragglerWatchdog`, when given,
        observes every step and triggers ``on_straggler(step, dt)`` —
        the remedy ladder's log/alert rung.
        """
        def _restore():
            tree, _, extra = restore(self.manager.directory, job.template(),
                                     mesh=getattr(job, "mesh", None),
                                     specs=getattr(job, "specs", None))
            return job.unpack(tree, extra)

        try:
            state = _restore()
            log.info("resumed job at step %d", job.step_index(state))
        except FileNotFoundError:
            state = job.init()
        while not job.done(state):
            try:
                if fault_hook is not None:
                    fault_hook(job.step_index(state))
                self.timer.start()
                state = job.step(state)
                dt = self.timer.stop()
                if watchdog is not None and watchdog.observe(
                        job.step_index(state), dt):
                    log.warning("straggling step %d (%.3fs, ewma %.3fs)",
                                job.step_index(state), dt,
                                watchdog.timer.ewma)
                    if on_straggler is not None:
                        on_straggler(job.step_index(state), dt)
                tree, extra = job.pack(state)
                self.manager.maybe_save(job.step_index(state), tree,
                                        specs=getattr(job, "specs", None),
                                        extra=extra)
            except Exception as e:  # noqa: BLE001 — restart on any fault
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise
                log.warning("job step failed (%s); restarting (%d/%d)",
                            e, self.restarts, self.cfg.max_restarts)
                try:
                    state = _restore()
                except FileNotFoundError:
                    state = job.init()
        return state

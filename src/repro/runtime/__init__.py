"""Fault-tolerance runtime: health tracking, straggler detection, restart."""
from .health import HealthMonitor, StepTimer, StragglerWatchdog
from .supervisor import Supervisor, SupervisorConfig

__all__ = ["HealthMonitor", "StepTimer", "StragglerWatchdog",
           "Supervisor", "SupervisorConfig"]

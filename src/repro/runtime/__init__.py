"""Fault-tolerance runtime: health tracking, straggler detection, restart."""
from .health import HealthMonitor, StepTimer
from .supervisor import Supervisor

__all__ = ["HealthMonitor", "StepTimer", "Supervisor"]

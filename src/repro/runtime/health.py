"""Step-time health tracking and straggler detection.

On a real multi-host deployment each host runs a StepTimer and publishes
its per-step wall time; the HealthMonitor (rank 0 or an external
controller) flags hosts whose EWMA step time exceeds k standard
deviations of the fleet — the straggler remedy ladder is:

  1. log + alert,
  2. re-balance: for the eigensolver, re-partition matrix rows by
     communication volume (the paper's own χ₂-vs-χ₃ imbalance fix);
     for LM training, shrink the straggler's microbatch share,
  3. evict + elastic restart from the last committed checkpoint
     (checkpoint/ restores onto the shrunken mesh).

This module is pure bookkeeping (no jax) so it is trivially testable and
can run in the controller process.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class StepTimer:
    alpha: float = 0.1  # EWMA factor
    ewma: float | None = None
    var: float = 0.0
    count: int = 0
    _t0: float | None = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> float:
        dt = time.perf_counter() - self._t0
        self.observe(dt)
        return dt

    def observe(self, dt: float):
        if self.ewma is None:
            self.ewma = dt
        else:
            d = dt - self.ewma
            self.ewma += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.count += 1

    @property
    def std(self) -> float:
        return self.var ** 0.5


@dataclasses.dataclass
class StragglerWatchdog:
    """Single-job straggler hook: flags a step whose wall time exceeds the
    job's own EWMA by ``k_sigma`` standard deviations.

    The fleet-level :class:`HealthMonitor` compares hosts against each
    other; a supervised eigensolve job has one step stream, so the
    reference is its own history (after ``warmup`` observations). The
    supervisor (``Supervisor.run_job``) calls :meth:`observe` per
    iteration and invokes its ``on_straggler`` remedy callback when the
    step is flagged — step 1 of the remedy ladder above; steps 2/3
    (commvol re-partition, elastic restart from the last committed
    checkpoint) are what the plan cache and ``checkpoint/`` provide.
    """

    k_sigma: float = 3.0
    warmup: int = 3
    min_slack: float = 1e-3  # absolute floor [s] — jitter is not a straggler
    timer: StepTimer = dataclasses.field(default_factory=StepTimer)
    flagged: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = (self.timer.count >= self.warmup
                and self.timer.ewma is not None
                and dt > self.timer.ewma
                + max(self.k_sigma * self.timer.std, self.min_slack))
        self.timer.observe(dt)
        if slow:
            self.flagged.append((step, dt))
        return slow


class HealthMonitor:
    """Fleet-level view: flags stragglers and dead hosts."""

    def __init__(self, n_hosts: int, k_sigma: float = 3.0,
                 heartbeat_timeout: float = 60.0):
        self.n_hosts = n_hosts
        self.k_sigma = k_sigma
        self.heartbeat_timeout = heartbeat_timeout
        self.timers = {h: StepTimer() for h in range(n_hosts)}
        self.last_seen = {h: time.monotonic() for h in range(n_hosts)}

    def report(self, host: int, step_time: float):
        self.timers[host].observe(step_time)
        self.last_seen[host] = time.monotonic()

    def stragglers(self) -> list[int]:
        ew = [t.ewma for t in self.timers.values() if t.ewma is not None]
        if len(ew) < max(2, self.n_hosts // 2):
            return []
        med = sorted(ew)[len(ew) // 2]
        spread = max(1e-9, 1.4826 * sorted(abs(e - med) for e in ew)[len(ew) // 2])
        out = []
        for h, t in self.timers.items():
            if t.ewma is not None and t.ewma > med + self.k_sigma * spread:
                out.append(h)
        return out

    def dead(self) -> list[int]:
        now = time.monotonic()
        return [h for h, ts in self.last_seen.items()
                if now - ts > self.heartbeat_timeout]

    def rebalance_fractions(self) -> list[float]:
        """Microbatch share per host inversely proportional to step time."""
        ew = [self.timers[h].ewma or 1.0 for h in range(self.n_hosts)]
        inv = [1.0 / e for e in ew]
        s = sum(inv)
        return [x / s for x in inv]

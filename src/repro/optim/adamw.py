"""AdamW with optional 8-bit (block-quantized) moment storage.

Quantized moments are the memory-side trick that lets arctic-480b's
optimizer state fit a single pod (the paper's "pillar trades memory for
performance" caveat, transplanted to optimizer-state layout): m/v are kept
as int8 codes with per-block fp32 scales (block = last axis groups of 256),
dequantized on the fly inside the update. Error is bounded by the block
max; the quantization round-trips are unit-tested against fp32 AdamW.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # float32 | bfloat16 | int8
    warmup_steps: int = 100
    total_steps: int = 10_000


def _q8_encode(x):
    """Block-quantize along the flattened last axis: (codes int8, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blk = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blk), axis=1, keepdims=True) / 127.0
    codes = jnp.round(blk / jnp.maximum(scale, 1e-30)).astype(jnp.int8)
    return codes, scale.astype(jnp.float32)


def _q8_decode(codes, scale, shape):
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)
    n = int(np.prod(shape))
    return flat[:n].reshape(shape)


def _store(x, dtype):
    if dtype == "int8":
        return _q8_encode(x)
    return x.astype(jnp.dtype(dtype))


def _load(s, dtype, shape):
    if dtype == "int8":
        return _q8_decode(s[0], s[1], shape)
    return s.astype(jnp.float32)


def _store_v(x, dtype):
    """Second moment: quantize in sqrt-space (v >= 0 with a huge in-block
    dynamic range; linear int8 on v underflows and destabilizes the
    preconditioner — storing sqrt(v) halves the exponent range)."""
    if dtype == "int8":
        return _q8_encode(jnp.sqrt(jnp.maximum(x, 0.0)))
    return x.astype(jnp.dtype(dtype))


def _load_v(s, dtype, shape):
    if dtype == "int8":
        u = _q8_decode(s[0], s[1], shape)
        # half-step floor: never dequantize a stored-positive v to zero
        blk = jnp.repeat(s[1][:, 0], BLOCK)[: int(np.prod(shape))].reshape(shape)
        u = jnp.where(u > 0, jnp.maximum(u, blk * 0.5), 0.0)
        return u * u
    return s.astype(jnp.float32)


def init_state(cfg: AdamWConfig, params):
    def zeros_like_stored(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _store(z, cfg.moment_dtype)

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like_stored, params),
        "v": jax.tree.map(zeros_like_stored, params),
    }


def _lr_at(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(grads):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))


def apply_updates(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    lr = _lr_at(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m_s, v_s in zip(flat_p, flat_g, flat_m, flat_v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * _load(m_s, cfg.moment_dtype, p.shape) + (1 - cfg.b1) * g
        v = cfg.b2 * _load_v(v_s, cfg.moment_dtype, p.shape) + (1 - cfg.b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + cfg.weight_decay * pf)
        new_p.append(pf.astype(p.dtype))
        new_m.append(_store(m, cfg.moment_dtype))
        new_v.append(_store_v(v, cfg.moment_dtype))
    params = jax.tree_util.tree_unflatten(treedef, new_p)
    state = {
        "step": step,
        "m": jax.tree_util.tree_unflatten(treedef, new_m),
        "v": jax.tree_util.tree_unflatten(treedef, new_v),
    }
    return params, state, {"grad_norm": gn, "lr": lr}

"""Cross-pod gradient compression with error feedback.

The pod axis is the slow link (data-center network / optical ICI between
pods vs. in-pod ICI) — the LM-side instance of the paper's b_m/b_c gap.
Within a pod gradients reduce in full precision (GSPMD all-reduce over
``data``); across pods we exchange int8-quantized partial gradients with
an error-feedback residual so compression noise is unbiased over steps
(Seide et al. / EF-SGD):

    q_t = Q(g_t + e_t);  e_{t+1} = (g_t + e_t) - dQ(q_t)

For 2 pods the exchange is one ppermute of int8 codes + local sum — an
8x byte reduction on the slow link. Used by launch/train.py via
``cross_pod_reduce`` inside shard_map over the pod axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

BLOCK = 512


def _quantize(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    blk = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blk), axis=1, keepdims=True) / 127.0
    codes = jnp.round(blk / jnp.maximum(scale, 1e-30)).astype(jnp.int8)
    return codes, scale


def _dequantize(codes, scale, shape):
    import numpy as np

    flat = (codes.astype(jnp.float32) * scale).reshape(-1)
    return flat[: int(np.prod(shape))].reshape(shape)


def make_cross_pod_reduce(mesh: Mesh, pspecs, enabled: bool = True):
    """Return reduce(grads, err) -> (grads_mean, new_err) over the pod axis.

    ``pspecs`` is the PartitionSpec tree of the gradient leaves (their
    data/model sharding is preserved; only the pod axis is reduced, with
    int8 exchange). With enabled=False this is a plain pmean (baseline
    for §Perf)."""
    if "pod" not in mesh.axis_names:
        return lambda g, e: (g, e)
    n_pods = mesh.shape["pod"]

    def reduce_leaf(g, err, spec):
        def local(gb, eb):
            if not enabled:
                return lax.pmean(gb, "pod"), eb
            acc = gb + eb
            codes, scale = _quantize(acc)
            # exchange with every other pod (ring of ppermutes)
            total = _dequantize(codes, scale, gb.shape)
            new_err = acc - total  # own quantization error
            for shift in range(1, n_pods):
                perm = [(i, (i + shift) % n_pods) for i in range(n_pods)]
                c = lax.ppermute(codes, "pod", perm)
                s = lax.ppermute(scale, "pod", perm)
                total = total + _dequantize(c, s, gb.shape)
            return total / n_pods, new_err

        fn = shard_map(local, mesh=mesh, in_specs=(spec, spec),
                       out_specs=(spec, spec), check_rep=False)
        return fn(g, err)

    def reduce_tree(grads, err_tree):
        pairs = jax.tree.map(reduce_leaf, grads, err_tree, pspecs,
                             is_leaf=lambda x: isinstance(x, P))
        g = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        e = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return g, e

    return reduce_tree

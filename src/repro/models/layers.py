"""Shared neural-net primitives (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Init = jax.nn.initializers


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------ norms --

def init_norm(key, d, cfg):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), _dtype(cfg)), "bias": jnp.zeros((d,), _dtype(cfg))}
    return {"scale": jnp.ones((d,), _dtype(cfg))}


def apply_norm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if "bias" in p:
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(ms + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def rms_head_norm(scale, x, eps=1e-6):
    """Per-head RMS norm (qk_norm), x [..., H, hd]."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ----------------------------------------------------------------- linear --

def init_linear(key, d_in, d_out, cfg, bias=False, scale=None):
    std = scale if scale is not None else 1.0 / np.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out)) * std).astype(_dtype(cfg))}
    if bias:
        p["b"] = jnp.zeros((d_out,), _dtype(cfg))
    return p


def apply_linear(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ----------------------------------------------------------------- rotary --

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float64) / hd))


def apply_rope(x, positions, theta):
    """x [..., S, H, hd] (or [..., H, hd] with scalar positions broadcast)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ------------------------------------------------------------------- MLPs --

def init_mlp(key, d, d_ff, cfg):
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {
            "gate": init_linear(ks[0], d, d_ff, cfg),
            "up": init_linear(ks[1], d, d_ff, cfg),
            "down": init_linear(ks[2], d_ff, d, cfg),
        }
    return {"up": init_linear(ks[0], d, d_ff, cfg), "down": init_linear(ks[1], d_ff, d, cfg)}


def apply_mlp(p, x, activation: str):
    if activation == "swiglu":
        h = jax.nn.silu(apply_linear(p["gate"], x)) * apply_linear(p["up"], x)
    elif activation == "squared_relu":
        h = jnp.square(jax.nn.relu(apply_linear(p["up"], x)))
    else:
        h = jax.nn.gelu(apply_linear(p["up"], x))
    return apply_linear(p["down"], h)


# -------------------------------------------------------------- embedding --

def init_embed(key, vocab, d, cfg):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(_dtype(cfg))}


def embed(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p, x):
    """Logits via the (tied or separate) output table [vocab, d]."""
    return x @ p["table"].T

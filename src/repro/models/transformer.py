"""Model assembly for the architecture pool.

Layer parameters are stacked along a leading [n_layers] axis and the layer
loop is a (rematerialized) ``lax.scan`` — one compiled block body per model
regardless of depth, which keeps dry-run lowering cheap for 95-layer
configs. The hybrid (Hymba) family is unrolled instead because its layers
are heterogeneous (3 global-attention layers among sliding-window ones,
each with a differently-shaped decode cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .config import ModelConfig
from .layers import (apply_linear, apply_mlp, apply_norm, embed, init_embed,
                     init_linear, init_mlp, init_norm, unembed)


# ------------------------------------------------------------------ blocks --

def init_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    p = {"norm1": init_norm(ks[0], cfg.d_model, cfg)}
    if cfg.family == "ssm":  # rwkv6
        p["time_mix"] = ssm.init_rwkv_time_mix(ks[1], cfg)
        p["norm2"] = init_norm(ks[2], cfg.d_model, cfg)
        p["channel_mix"] = ssm.init_rwkv_channel_mix(ks[3], cfg)
        return p
    p["attn"] = attn.init_attention(ks[1], cfg)
    if cfg.hybrid:
        p["mamba"] = ssm.init_mamba(ks[2], cfg)
        p["norm_attn"] = init_norm(ks[3], cfg.d_model, cfg)
        p["norm_mamba"] = init_norm(ks[4], cfg.d_model, cfg)
    p["norm2"] = init_norm(ks[5], cfg.d_model, cfg)
    if cfg.n_experts:
        p["moe"] = moe_mod.init_moe(ks[6], cfg)
    else:
        p["mlp"] = init_mlp(ks[6], cfg.d_model, cfg.d_ff, cfg)
    return p


def block_forward(p, cfg: ModelConfig, x, positions, *, window, causal=True,
                  collect=False):
    """One layer, train/prefill path. Returns (x, aux_loss, state|None).

    With ``collect=True`` the per-layer decode state (kv / recurrent
    states) is also returned so prefill can hand off to decode.
    """
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        h, wkv, x_tm = ssm.rwkv_time_mix(p["time_mix"], cfg, apply_norm(p["norm1"], x))
        x = x + h
        h, x_cm = ssm.rwkv_channel_mix(p["channel_mix"], cfg, apply_norm(p["norm2"], x))
        st = {"wkv": wkv, "x_tm": x_tm, "x_cm": x_cm} if collect else None
        return x + h, aux, st
    xin = apply_norm(p["norm1"], x)
    a = attn.attention_block(p["attn"], cfg, xin, positions, causal=causal,
                             window=window, return_kv=collect)
    kv = None
    if collect:
        a, kv = a
    st = {"kv": kv} if collect else None
    if cfg.hybrid:
        m, h_ssm, conv = ssm.mamba_block(p["mamba"], cfg, xin)
        a = 0.5 * (apply_norm(p["norm_attn"], a) + apply_norm(p["norm_mamba"], m))
        if collect:
            st["ssm"], st["conv"] = h_ssm, conv
    x = x + a
    xin = apply_norm(p["norm2"], x)
    if cfg.n_experts:
        y, aux = moe_mod.apply_moe(p["moe"], cfg, xin)
    else:
        y = apply_mlp(p["mlp"], xin, cfg.activation)
    return x + y, aux, st


# ------------------------------------------------------------------ model --

def init_params(cfg: ModelConfig, key):
    ks = jax.random.split(key, 6)
    p = {}
    if cfg.embed_inputs:  # audio/vlm stub frontend: linear feature projection
        p["frontend"] = init_linear(ks[0], cfg.frontend_dim, cfg.d_model, cfg)
    if cfg.vocab:
        p["embed"] = init_embed(ks[1], cfg.vocab, cfg.d_model, cfg)
    segs = cfg.segments()
    skeys = jax.random.split(ks[2], len(segs))
    p["segments"] = []
    for (a, b, w), sk in zip(segs, skeys):
        lkeys = jax.random.split(sk, b - a)
        p["segments"].append(jax.vmap(lambda k: init_block(k, cfg))(lkeys))
    p["final_norm"] = init_norm(ks[3], cfg.d_model, cfg)
    if cfg.vocab and not cfg.tie_embeddings:
        p["lm_head"] = init_embed(ks[4], cfg.vocab, cfg.d_model, cfg)
    return p


def backbone(params, cfg: ModelConfig, x, positions, *, causal=True):
    """Run all layers on embedded input x [B,S,d]. Returns (h, aux_loss).

    One lax.scan per homogeneous segment (see ModelConfig.segments)."""
    aux = jnp.zeros((), jnp.float32)
    for (a, b, w), blocks in zip(cfg.segments(), params["segments"]):

        def body(carry, lp, _w=w):
            xx, au = carry
            xx, al, _ = block_forward(lp, cfg, xx, positions, window=_w,
                                      causal=causal)
            return (xx, au + al), None

        f = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = lax.scan(f, (x, aux), blocks)
    return apply_norm(params["final_norm"], x), aux


def _ring_place(k, W: int):
    """Scatter the last W positions of k [..., S, H, hd] into ring slots
    (slot = pos % W), matching decode's ring-buffer addressing."""
    S = k.shape[1]
    take = min(W, S)
    src = k[:, S - take:]
    slots = (jnp.arange(S - take, S)) % W
    ring = jnp.zeros(k.shape[:1] + (W,) + k.shape[2:], k.dtype)
    return ring.at[:, slots].set(src)


def backbone_with_state(params, cfg: ModelConfig, batch, max_len: int):
    """Prefill: full-sequence forward that also builds the decode state.
    Returns (last-position logits [B, vocab], decode_state list per segment)."""
    x, positions, _, _ = embed_batch(params, cfg, batch)
    B, S, d = x.shape
    states = []
    for (a, b, w), blocks in zip(cfg.segments(), params["segments"]):

        def body(xx, lp, _w=w):
            xx, _, st = block_forward(lp, cfg, xx, positions, window=_w,
                                      causal=True, collect=True)
            return xx, st

        x, sts = lax.scan(body, x, blocks)
        if cfg.family == "ssm":
            states.append(sts)  # stacked {wkv, x_tm, x_cm} over the segment
        else:
            k, v = sts.pop("kv")  # [Ls,B,S,H,hd]
            W = min(w, max_len) if w > 0 else max_len
            sts["k"] = jax.vmap(lambda kk: _ring_place(kk, W))(k)
            sts["v"] = jax.vmap(lambda vv: _ring_place(vv, W))(v)
            states.append(sts)
    h = apply_norm(params["final_norm"], x)
    logits = h[:, -1] @ lm_head_table(params, cfg).T
    return logits, states


def embed_batch(params, cfg: ModelConfig, batch):
    """Map a batch dict to (x [B,S,d], positions [S], labels/None, mask/None)."""
    if cfg.family == "audio":
        x = apply_linear(params["frontend"], batch["features"])
        if "mask" in batch:  # masked-prediction: zero out masked frames
            x = jnp.where(batch["mask"][..., None], 0.0, x)
        S = x.shape[1]
        return x, jnp.arange(S), batch.get("labels"), batch.get("mask")
    if cfg.family == "vlm":
        tx = embed(params["embed"], batch["tokens"])
        px = apply_linear(params["frontend"], batch["patches"])
        x = jnp.concatenate([px, tx], axis=1)
        S = x.shape[1]
        labels = batch.get("labels")
        return x, jnp.arange(S), labels, None
    x = embed(params["embed"], batch["tokens"])
    S = x.shape[1]
    return x, jnp.arange(S), batch.get("labels"), None


def lm_head_table(params, cfg: ModelConfig):
    return params["embed" if cfg.tie_embeddings else "lm_head"]["table"]


def chunked_ce_loss(table, h, labels, chunk: int, mask=None):
    """Cross-entropy without materializing [B,S,V]: scan over S chunks.

    ``mask`` selects positions contributing to the loss (audio masked-pred);
    None means all positions with label >= 0.
    """
    B, S, d = h.shape
    chunk = min(chunk, S)
    n = -(-S // chunk)
    S_pad = n * chunk
    if S_pad != S:
        h = jnp.pad(h, ((0, 0), (0, S_pad - S), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, S_pad - S)), constant_values=-1)
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, S_pad - S)))
    hs = jnp.moveaxis(h.reshape(B, n, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(B, n, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, n, chunk), 1, 0) if mask is not None else None

    def body(carry, inp):
        tot, cnt = carry
        if ms is None:
            hc, lc = inp
            valid = lc >= 0
        else:
            hc, lc, mc = inp
            valid = (lc >= 0) & mc
        logits = (hc @ table.T).astype(jnp.float32)  # [B,C,V]
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        tot = (tot + jnp.sum(jnp.where(valid, logz - ll, 0.0))).astype(jnp.float32)
        cnt = cnt + jnp.sum(valid).astype(jnp.int32)
        return (tot, cnt), None

    xs = (hs, ls) if ms is None else (hs, ls, ms)
    body_fn = jax.checkpoint(body)
    (tot, cnt), _ = lax.scan(body_fn, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), xs)
    return tot / jnp.maximum(cnt, 1)


def loss_fn(params, cfg: ModelConfig, batch):
    x, positions, labels, mask = embed_batch(params, cfg, batch)
    h, aux = backbone(params, cfg, x, positions, causal=not cfg.encoder_only)
    if cfg.family == "vlm":  # loss only over the text region
        npfx = batch["patches"].shape[1]
        h = h[:, npfx:]
    table = lm_head_table(params, cfg)
    ce = chunked_ce_loss(table, h, labels, cfg.loss_chunk, mask)
    return ce + aux, {"ce": ce, "aux": aux}

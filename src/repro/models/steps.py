"""Step functions: train_step / prefill_step / decode_step builders."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..optim import adamw
from .config import ModelConfig
from . import decode as dec
from . import transformer as tfm

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "make_batch", "init_train_state"]


def init_train_state(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, key):
    params = tfm.init_params(cfg, key)
    opt_state = adamw.init_state(opt_cfg, params)
    return params, opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig):
    def train_step(params, opt_state, batch):
        def lf(p):
            return tfm.loss_fn(p, cfg, batch)

        (loss, parts), grads = jax.value_and_grad(lf, has_aux=True)(params)
        params, opt_state, om = adamw.apply_updates(opt_cfg, params, grads, opt_state)
        metrics = {"loss": loss, **parts, **om}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return tfm.backbone_with_state(params, cfg, batch, max_len)

    return prefill_step


def make_decode_step(cfg: ModelConfig):
    def decode_step(params, state, token, pos):
        return dec.decode_step(params, cfg, state, token, pos)

    return decode_step


def make_batch(cfg: ModelConfig, batch: int, seq: int, key=None, np_like=False):
    """Construct a synthetic batch matching the arch's input contract
    (tokens for LMs, feature frames for audio, patches+tokens for VLM)."""
    import numpy as np

    rng = np.random.default_rng(0 if key is None else key)
    if cfg.family == "audio":
        return {
            "features": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.frontend_dim)), jnp.dtype(cfg.dtype)
            ),
            "mask": jnp.asarray(rng.random((batch, seq)) < 0.08),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
        }
    if cfg.family == "vlm":
        npfx = min(cfg.n_prefix_embeds, max(seq // 8, 1))
        s_text = seq - npfx
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, s_text)), jnp.int32),
            "patches": jnp.asarray(
                rng.standard_normal((batch, npfx, cfg.frontend_dim)), jnp.dtype(cfg.dtype)
            ),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (batch, s_text)), jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32),
    }

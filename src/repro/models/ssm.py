"""State-space / linear-recurrence blocks: RWKV6 (Finch) and Mamba-style
selective SSM (for the Hymba hybrid). Sequence recurrences use lax.scan;
decode threads O(1) per-layer states (no KV cache — the reason these archs
run the long_500k cell).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .layers import apply_linear, init_linear


# =========================================================== RWKV6 (Finch) ==

LORA_R = 32
DECAY_LORA_R = 64


def init_rwkv_time_mix(key, cfg):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 12)
    dt = jnp.dtype(cfg.dtype)

    def nrm(k, shape, fan):
        return (jax.random.normal(k, shape) / np.sqrt(fan)).astype(dt)

    return {
        "mu_x": jnp.zeros((d,), dt),
        "mu": jnp.zeros((5, d), dt),  # r, w, k, v, g interpolation
        "lora_A": nrm(ks[0], (d, 5 * LORA_R), d),
        "lora_B": nrm(ks[1], (5, LORA_R, d), LORA_R),
        "w0": jnp.full((d,), -6.0, dt),  # decay bias (slow decay init)
        "wA": nrm(ks[2], (d, DECAY_LORA_R), d),
        "wB": nrm(ks[3], (DECAY_LORA_R, d), DECAY_LORA_R) * 0.1,
        "Wr": nrm(ks[4], (d, d), d),
        "Wk": nrm(ks[5], (d, d), d),
        "Wv": nrm(ks[6], (d, d), d),
        "Wg": nrm(ks[7], (d, d), d),
        "Wo": nrm(ks[8], (d, d), d),
        "u": nrm(ks[9], (H, hd), hd),  # per-head bonus
        "ln_scale": jnp.ones((d,), dt),  # group-norm over heads
    }


def _rwkv_mix(p, x, x_shift):
    """Data-dependent token-shift interpolation (5 projections)."""
    xx = x_shift - x
    xxx = x + xx * p["mu_x"]
    m = jnp.tanh(xxx @ p["lora_A"])  # [B,S,5R]
    B, S = x.shape[:2]
    m = m.reshape(B, S, 5, LORA_R)
    delta = jnp.einsum("bsfr,frd->bsfd", m, p["lora_B"])  # [B,S,5,d]
    mixed = x[:, :, None, :] + xx[:, :, None, :] * (p["mu"][None, None] + delta)
    return [mixed[:, :, i] for i in range(5)]  # r, w, k, v, g inputs


def _rwkv_decay(p, xw):
    """Data-dependent per-channel decay w in (0, 1)."""
    ww = p["w0"].astype(jnp.float32) + jnp.tanh(xw @ p["wA"]).astype(jnp.float32) @ p["wB"].astype(jnp.float32)
    return jnp.exp(-jnp.exp(ww))  # [B,S,d]


def _wkv_scan(r, k, v, w, u, state):
    """Reference per-token recurrence. r/k/v/w [B,S,H,hd] f32."""

    def step(s, inp):
        rt, kt, vt, wt = inp  # [B,H,hd] each
        kv = kt[..., :, None] * vt[..., None, :]  # [B,H,hd,hd]
        y = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, w))
    state, ys = lax.scan(step, state, xs)  # ys [S,B,H,hd]
    return jnp.moveaxis(ys, 0, 1), state


_EXP_CLAMP = 30.0  # bounds exp(-L_s); pairs beyond it contribute < e^-30


def _wkv_chunked(r, k, v, w, u, state, chunk: int):
    """Chunk-parallel WKV6 (TPU adaptation — see EXPERIMENTS §Perf).

    Within a chunk of C tokens the recurrence unrolls to matmuls:
      y_t = A_t @ S_0 + strict_tril(A B^T) V + diag(r·u·k) V,
      A_t = r_t * exp(L_{t-1}),  B_s = k_s * exp(-L_s),
      L_t = sum_{u<=t} log w_u   (log w = -exp(ww) is available exactly).
    The state crosses chunk boundaries only: S -> exp(L_C)*(S + B^T V).
    HBM state traffic drops from S trips to S/C trips and the inner work
    becomes MXU matmuls instead of per-token VPU outer products.
    exp(-L_s) is clamped at e^30: affected (t,s) pairs have true weight
    exp(L_t - L_s) < e^-30 — below f32 resolution of the sum.
    """
    B, S, H, hd = r.shape
    C = chunk
    n = S // C
    rc = r.reshape(B, n, C, H, hd)
    kc = k.reshape(B, n, C, H, hd)
    vc = v.reshape(B, n, C, H, hd)
    logw = jnp.log(jnp.maximum(w, 1e-38)).reshape(B, n, C, H, hd)
    L = jnp.cumsum(logw, axis=2)  # L_t, inclusive
    Lm1 = L - logw  # L_{t-1}
    A = rc * jnp.exp(Lm1)  # [B,n,C,H,hd]
    Bm = kc * jnp.exp(jnp.minimum(-L, _EXP_CLAMP))
    scores = jnp.einsum("bnthk,bnshk->bnhts", A, Bm)  # [B,n,H,C,C]
    tri = jnp.tril(jnp.ones((C, C), bool), -1)
    scores = jnp.where(tri[None, None, None], scores, 0.0)
    bonus = jnp.einsum("bnthk,bnthk->bnth", rc, u[None, None, None] * kc)
    intra = jnp.einsum("bnhts,bnshv->bnthv", scores, vc) \
        + bonus[..., None] * vc
    # cross-chunk state pass (sequential over n, not S)
    decay_tot = jnp.exp(L[:, :, -1])  # [B,n,H,hd]
    kTv = jnp.einsum("bnshk,bnshv->bnhkv", Bm, vc)  # [B,n,H,hd,hd]

    def carry_fn(s, inp):
        dec, kv_, a_ = inp  # [B,H,hd], [B,H,hd,hd], [B,C,H,hd]
        y0 = jnp.einsum("bthk,bhkv->bthv", a_, s)
        s = dec[..., :, None] * (s + kv_)
        return s, y0

    state, y0 = lax.scan(
        carry_fn, state,
        (jnp.moveaxis(decay_tot, 1, 0), jnp.moveaxis(kTv, 1, 0),
         jnp.moveaxis(A, 1, 0)),
    )
    y = intra + jnp.moveaxis(y0, 0, 1).reshape(B, n, C, H, hd)
    return y.reshape(B, S, H, hd), state


def rwkv_time_mix(p, cfg, x, *, state=None, x_prev=None):
    """x [B,S,d]. state: [B,H,hd,hd] WKV state; x_prev [B,d] last token.
    Returns (y, new_state, new_x_prev)."""
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    x_shift = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xr, xw, xk, xv, xg = _rwkv_mix(p, x, x_shift)
    r = (xr @ p["Wr"]).reshape(B, S, H, hd)
    k = (xk @ p["Wk"]).reshape(B, S, H, hd)
    v = (xv @ p["Wv"]).reshape(B, S, H, hd)
    g = jax.nn.silu(xg @ p["Wg"])
    w = _rwkv_decay(p, xw).reshape(B, S, H, hd)
    u = p["u"].astype(jnp.float32)

    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)

    args = (r.astype(jnp.float32), k.astype(jnp.float32),
            v.astype(jnp.float32), w.astype(jnp.float32))
    C = getattr(cfg, "ssm_chunk", 64)
    if C and S % C == 0 and S > C:
        ys, state = _wkv_chunked(*args, u, state, C)
    else:
        ys, state = _wkv_scan(*args, u, state)
    y = ys.reshape(B, S, d)
    # group-norm over each head then gate
    yf = y.reshape(B, S, H, hd)
    mu = yf.mean(axis=-1, keepdims=True)
    var = yf.var(axis=-1, keepdims=True)
    yf = (yf - mu) * lax.rsqrt(var + 1e-5)
    y = (yf.reshape(B, S, d) * p["ln_scale"].astype(jnp.float32)).astype(x.dtype)
    y = (y * g.astype(y.dtype)) @ p["Wo"]
    return y, state, x[:, -1]


def init_rwkv_channel_mix(key, cfg):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.dtype)

    def nrm(k, shape, fan):
        return (jax.random.normal(k, shape) / np.sqrt(fan)).astype(dt)

    return {
        "mu_k": jnp.zeros((d,), dt),
        "mu_r": jnp.zeros((d,), dt),
        "Wk": nrm(ks[0], (d, ff), d),
        "Wv": nrm(ks[1], (ff, d), ff),
        "Wr": nrm(ks[2], (d, d), d),
    }


def rwkv_channel_mix(p, cfg, x, *, x_prev=None):
    B, S, d = x.shape
    if x_prev is None:
        x_prev = jnp.zeros((B, d), x.dtype)
    x_shift = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    xx = x_shift - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["Wk"]))
    y = jax.nn.sigmoid(xr @ p["Wr"]) * (kk @ p["Wv"])
    return y, x[:, -1]


# ==================================================== Mamba selective SSM ==

def init_mamba(key, cfg):
    d = cfg.d_model
    di = cfg.d_inner
    N = cfg.ssm_state
    dt_rank = max(d // 16, 1)
    ks = jax.random.split(key, 8)
    dtype = jnp.dtype(cfg.dtype)

    def nrm(k, shape, fan):
        return (jax.random.normal(k, shape) / np.sqrt(fan)).astype(dtype)

    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": nrm(ks[0], (d, 2 * di), d),
        "conv_w": nrm(ks[1], (4, di), 4),  # depthwise causal conv, kernel 4
        "x_proj": nrm(ks[2], (di, dt_rank + 2 * N), di),
        "dt_proj": nrm(ks[3], (dt_rank, di), dt_rank),
        "dt_bias": jnp.zeros((di,), dtype),
        "A_log": jnp.log(A),  # [di, N] float32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": nrm(ks[4], (di, d), di),
    }


def _mamba_scan(A, dtv, Bv, Cv, xv, state):
    """h_t = exp(dt*A) h + dt*B x ; y_t = C·h. Shapes per step:
    dtv [B,di], Bv [B,N], Cv [B,N], xv [B,di]; state [B,di,N]."""

    def step(h, inp):
        dt_t, B_t, C_t, x_t = inp
        dA = jnp.exp(dt_t[..., None] * A[None])  # [B,di,N]
        dBx = (dt_t * x_t)[..., None] * B_t[:, None, :]  # [B,di,N]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    return lax.scan(step, state, (dtv, Bv, Cv, xv))


def mamba_block(p, cfg, x, *, state=None, conv_state=None):
    """x [B,S,d] -> (y, ssm_state [B,di,N], conv_state [B,3,di])."""
    B, S, d = x.shape
    di = cfg.d_inner
    N = cfg.ssm_state
    dt_rank = p["dt_proj"].shape[0]
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)  # [B,S,di]
    # causal depthwise conv (kernel 4)
    if conv_state is None:
        conv_state = jnp.zeros((B, 3, di), xs.dtype)
    xpad = jnp.concatenate([conv_state, xs], axis=1)  # [B,S+3,di]
    w = p["conv_w"].astype(xs.dtype)
    xc = (
        xpad[:, 0:S] * w[0] + xpad[:, 1 : S + 1] * w[1]
        + xpad[:, 2 : S + 2] * w[2] + xpad[:, 3 : S + 3] * w[3]
    )
    xc = jax.nn.silu(xc)
    proj = xc @ p["x_proj"]  # [B,S,dt_rank+2N]
    dt_in, Bv, Cv = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"]).astype(jnp.float32)
    A = -jnp.exp(p["A_log"])  # [di,N]
    if state is None:
        state = jnp.zeros((B, di, N), jnp.float32)
    state, ys = _mamba_scan(
        A,
        jnp.moveaxis(dt, 1, 0),
        jnp.moveaxis(Bv.astype(jnp.float32), 1, 0),
        jnp.moveaxis(Cv.astype(jnp.float32), 1, 0),
        jnp.moveaxis(xc.astype(jnp.float32), 1, 0),
        state,
    )
    y = jnp.moveaxis(ys, 0, 1)  # [B,S,di]
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return y, state, xpad[:, -3:]

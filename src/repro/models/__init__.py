"""LM substrate: model definitions for the assigned architecture pool."""
from .config import ModelConfig, SHAPES, ShapeCell, applicable_shapes
from .steps import (init_train_state, make_batch, make_decode_step,
                    make_prefill_step, make_train_step)
from .transformer import init_params, loss_fn

__all__ = [
    "ModelConfig", "SHAPES", "ShapeCell", "applicable_shapes",
    "init_train_state", "make_batch", "make_decode_step", "make_prefill_step",
    "make_train_step", "init_params", "loss_fn",
]

"""Model configuration for the assigned architecture pool."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # explicit (qwen3); default d_model//n_heads
    # --- layer options ---
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    activation: str = "swiglu"  # swiglu | squared_relu | gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    dense_d_ff: int = 0
    router_aux_coef: float = 0.01
    # "model": expert parallelism on the model axis (arctic: big experts).
    # "data_zero": experts ZeRO-sharded for storage but *replicated at
    # compute* — dispatch is then shard-local with zero collectives
    # (granite: 40 tiny 512-wide experts; see EXPERIMENTS §Perf).
    moe_expert_sharding: str = "model"
    # --- SSM / hybrid ---
    attn_free: bool = False  # rwkv6
    hybrid: bool = False  # hymba: parallel attn + mamba heads per layer
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_chunk: int = 64  # chunk-parallel WKV/SSM block length (0 = scan)
    sliding_window: int = 0  # 0 = full attention
    global_attn_layers: tuple[int, ...] = ()  # hymba full-attn layers
    # --- modality / structure ---
    encoder_only: bool = False  # hubert
    embed_inputs: bool = False  # vlm/audio: frontend stub provides embeddings
    frontend_dim: int = 0  # stub feature dim (audio frames / vision patches)
    n_prefix_embeds: int = 0  # vlm: patch embeddings prepended to text
    # --- training ---
    dtype: str = "bfloat16"
    remat: bool = True
    loss_chunk: int = 512  # sequence chunking for the CE loss
    # --- sharding / memory policy ---
    param_sharding: str = "tp"  # tp | fsdp_tp (ZeRO-3 style)
    optimizer_dtype: str = "float32"  # float32 | bfloat16 | int8 (quantized moments)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.hd

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.hd

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch supports 500k-token decode (SSM/hybrid)."""
        return self.attn_free or self.hybrid

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def segments(self) -> list[tuple[int, int, int]]:
        """Contiguous (start, end, window) runs of identical layer type.

        Layers inside a segment are homogeneous, so each segment lowers as
        one lax.scan — a 95-layer model compiles one block body, and the
        hybrid arch (3 global-attention layers among sliding-window ones)
        compiles five bodies instead of 32 unrolled layers.
        """
        wins = [self.sliding_window] * self.n_layers
        for g in self.global_attn_layers:
            wins[g] = 0
        segs = []
        start = 0
        for i in range(1, self.n_layers + 1):
            if i == self.n_layers or wins[i] != wins[start]:
                segs.append((start, i, wins[start]))
                start = i
        return segs

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        p = self.vocab * d * (1 if self.tie_embeddings else 2)
        per = 0
        if not self.attn_free:
            per += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        else:
            per += 4 * d * d + 2 * d * self.d_ff  # rwkv time-mix + channel-mix
        if self.hybrid:
            per += 2 * d * self.d_inner + self.d_inner * (2 * self.ssm_state + 2)
        if self.n_experts:
            ff_mults = 3 if self.activation == "swiglu" else 2
            per += self.n_experts * ff_mults * d * self.d_ff + d * self.n_experts
            if self.dense_residual:
                per += ff_mults * d * (self.dense_d_ff or self.d_ff)
        elif not self.attn_free:
            ff_mults = 3 if self.activation == "swiglu" else 2
            per += ff_mults * d * self.d_ff
        return p + L * per

    def n_active_params(self) -> int:
        """Active parameters per token (MoE counts top_k experts only)."""
        if not self.n_experts:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        ff_mults = 3 if self.activation == "swiglu" else 2
        inactive = L * (self.n_experts - self.top_k) * ff_mults * d * self.d_ff
        return self.n_params() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (arch x input-shape) dry-run cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def applicable_shapes(cfg: ModelConfig) -> dict[str, ShapeCell | None]:
    """Shape cells for an arch; None marks a skip (recorded in DESIGN.md)."""
    out: dict[str, ShapeCell | None] = {}
    for name, cell in SHAPES.items():
        skip = None
        if cfg.encoder_only and cell.kind == "decode":
            skip = "encoder-only arch has no decode step"
        elif name == "long_500k" and not cfg.is_subquadratic:
            skip = "pure full-attention arch; 500k decode needs sub-quadratic attention"
        out[name] = None if skip else cell
    return out
